"""Execute the Python code blocks in docs/*.md so snippets cannot rot.

Rules (the contract `make docs-check` enforces):

* every fenced ```python block is executed; other fences (bash, json, text)
  are ignored,
* blocks in one file share a namespace and run in order, so a snippet may
  build on an earlier one's imports/variables — exactly as a reader would,
* a block is skipped ONLY when the line directly above its opening fence is
  the literal marker ``<!-- docs-check: skip -->`` (reserved for snippets
  whose runtime is unreasonable for CI, e.g. full-scale matrix runs); the
  skip is reported so it stays visible,
* each file runs with the CWD set to a private temp directory (snippets that
  write ``results/...`` stay sandboxed) and with ``src`` on ``sys.path``.

Usage: python tools/docs_check.py [docs ...]
Exits nonzero on the first failing block, printing file, line, and traceback.
"""

from __future__ import annotations

import os
import sys
import tempfile
import traceback

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "src"))
sys.path.insert(0, REPO)                      # benchmarks.* imports

SKIP_MARKER = "<!-- docs-check: skip -->"


def extract_blocks(path: str) -> list[tuple[int, bool, str]]:
    """[(first_code_line_no, skipped, source), ...] for ```python fences."""
    blocks = []
    lines = open(path).read().splitlines()
    i = 0
    while i < len(lines):
        stripped = lines[i].strip()
        if stripped.startswith("```") and stripped[3:].strip() == "python":
            skipped = i > 0 and lines[i - 1].strip() == SKIP_MARKER
            j = i + 1
            while j < len(lines) and not lines[j].strip().startswith("```"):
                j += 1
            blocks.append((i + 2, skipped, "\n".join(lines[i + 1 : j])))
            i = j
        i += 1
    return blocks


def run_file(path: str) -> tuple[int, int, int]:
    """Execute a file's blocks; returns (ran, skipped, failed)."""
    blocks = extract_blocks(path)
    if not blocks:
        return 0, 0, 0
    namespace: dict = {"__name__": f"docs_check:{os.path.basename(path)}"}
    ran = skipped = failed = 0
    cwd = os.getcwd()
    with tempfile.TemporaryDirectory(prefix="docs_check_") as tmp:
        os.chdir(tmp)
        try:
            for line_no, skip, src in blocks:
                where = f"{os.path.relpath(path, REPO)}:{line_no}"
                if skip:
                    skipped += 1
                    print(f"  SKIP {where} (explicit marker)")
                    continue
                try:
                    code = compile(src, where, "exec")
                    exec(code, namespace)
                    ran += 1
                    print(f"  ok   {where}")
                except Exception:
                    failed += 1
                    print(f"  FAIL {where}\n{traceback.format_exc()}")
                    break
        finally:
            os.chdir(cwd)
    return ran, skipped, failed


def main(argv: list[str]) -> int:
    targets = argv or [os.path.join(REPO, "docs")]
    files: list[str] = []
    for t in targets:
        t = os.path.abspath(t)          # paths must survive the chdir below
        if os.path.isdir(t):
            files += sorted(
                os.path.join(t, f) for f in os.listdir(t) if f.endswith(".md")
            )
        else:
            files.append(t)
    total_ran = total_skip = 0
    for path in files:
        print(f"[docs-check] {os.path.relpath(path, REPO)}")
        ran, skipped, failed = run_file(path)
        total_ran += ran
        total_skip += skipped
        if failed:
            print(f"[docs-check] FAILED in {path}")
            return 1
    print(f"[docs-check] {total_ran} blocks executed, {total_skip} skipped, "
          f"{len(files)} files — all green")
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
