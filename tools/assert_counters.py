"""Assert counter totals (and plan fields) in a run dir's telemetry trace.

`make smoke-matrix` uses this to turn the trace into a gate: the warm
persistent-compile-cache pass must report ``compiles==0``, and the stealing
pass must have planned under ``scheduler=steal``.  Assertions are simple
comparisons against the FINAL ``totals`` event's counters (or, for
standalone traces with no parent merge — serving queries, fleet workers —
the sum of all writers' cumulative snapshots), with missing keys reading
as 0:

    python tools/assert_counters.py RUN_DIR "compiles==0" "pcache.hits>0" \\
        --plan scheduler=steal

Exits nonzero (listing every failed assertion) when the trace disagrees.
"""

from __future__ import annotations

import argparse
import os
import re
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "src"))

_ASSERT = re.compile(r"^([\w.]+)\s*(==|!=|>=|<=|>|<)\s*(-?\d+)$")

_OPS = {
    "==": lambda a, b: a == b,
    "!=": lambda a, b: a != b,
    ">=": lambda a, b: a >= b,
    "<=": lambda a, b: a <= b,
    ">": lambda a, b: a > b,
    "<": lambda a, b: a < b,
}


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("run_dir", help="results dir holding the merged trace")
    ap.add_argument("asserts", nargs="*", metavar="KEY OP N",
                    help="counter assertions, e.g. 'compiles==0'")
    ap.add_argument("--plan", action="append", default=[],
                    metavar="FIELD=VALUE",
                    help="assert a field of the (first) plan event, e.g. "
                         "scheduler=steal")
    args = ap.parse_args(argv)

    from repro.telemetry import read_run

    events = read_run(args.run_dir)
    if not events:
        print(f"[assert_counters] no trace events under {args.run_dir}")
        return 1
    # the last totals event when a parent merged one, else the sum of all
    # writers' cumulative snapshots (standalone traces — serving, workers)
    from repro.telemetry.summarize import sum_counters

    counters = sum_counters(events)
    plans = [e for e in events if e.get("ev") == "plan"]

    failed: list[str] = []
    for spec in args.asserts:
        m = _ASSERT.match(spec)
        if m is None:
            failed.append(f"unparseable assertion {spec!r}")
            continue
        key, op, want = m.group(1), m.group(2), int(m.group(3))
        got = int(counters.get(key, 0))
        if not _OPS[op](got, want):
            failed.append(f"{key}={got} violates {spec!r}")
    for spec in args.plan:
        field, _, want = spec.partition("=")
        if not plans:
            failed.append(f"no plan event (wanted {spec!r})")
        elif str(plans[0].get(field)) != want:
            failed.append(
                f"plan.{field}={plans[0].get(field)!r} violates {spec!r}"
            )

    if failed:
        for f in failed:
            print(f"[assert_counters] FAIL: {f}")
        print(f"[assert_counters] counters were: {counters}")
        return 1
    checked = ", ".join(args.asserts + [f"plan:{p}" for p in args.plan])
    print(f"[assert_counters] ok: {checked}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
