"""Byte-compare the measurement VALUES of two stores.

The executor layer's contract is that every executor — serial, process,
futures, device — produces the same measured values, down to the byte, in
the merged store.  This tool checks exactly that: it loads two stores
(``.json`` or ``.sqlite``, inferred from the extension), serializes their
``(key, value)`` payloads canonically (sorted keys, full float repr via
``json``), and exits 0 iff the payloads are identical.

Metadata is deliberately excluded: the meta side-channel carries unit
journals and provenance whose wall-clocks legitimately differ between runs.
``--meta`` adds a *key-set* comparison of the metadata (still ignoring the
values, which embed timings).

Usage:
    python tools/compare_stores.py results/a_cache.json results/b_cache.json
"""

from __future__ import annotations

import argparse
import json
import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "src"))


def load(path: str):
    from repro.core import MeasurementStore, SqliteMeasurementStore

    if not os.path.exists(path):
        raise FileNotFoundError(path)
    if path.endswith(".sqlite") or path.endswith(".db"):
        return SqliteMeasurementStore(path)
    return MeasurementStore(path)


def values_bytes(store) -> bytes:
    return json.dumps(
        sorted((str(k), float(v)) for k, v in store.items()), sort_keys=True
    ).encode()


def meta_keys(store) -> set:
    if not hasattr(store, "meta_items"):
        return set()
    return {k for k, _ in store.meta_items()}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("store_a")
    ap.add_argument("store_b")
    ap.add_argument("--meta", action="store_true",
                    help="also compare metadata key sets")
    args = ap.parse_args(argv)

    a, b = load(args.store_a), load(args.store_b)
    pa, pb = values_bytes(a), values_bytes(b)
    n_a, n_b = len(list(a.items())), len(list(b.items()))
    if pa != pb:
        keys_a = {k for k, _ in a.items()}
        keys_b = {k for k, _ in b.items()}
        only_a, only_b = keys_a - keys_b, keys_b - keys_a
        diff = [
            k for k in keys_a & keys_b
            if float(dict(a.items())[k]) != float(dict(b.items())[k])
        ]
        print(f"DIFFER: {args.store_a} ({n_a} entries) vs "
              f"{args.store_b} ({n_b} entries)")
        for label, keys in (("only in A", only_a), ("only in B", only_b),
                            ("value mismatch", diff)):
            for k in sorted(keys)[:5]:
                print(f"  {label}: {k}")
            if len(keys) > 5:
                print(f"  {label}: ... {len(keys) - 5} more")
        return 1
    print(f"IDENTICAL: {n_a} measurement entries, {len(pa)} payload bytes")
    if args.meta:
        ma, mb = meta_keys(a), meta_keys(b)
        if ma != mb:
            print(f"META KEYS DIFFER: {len(ma - mb)} only in A, "
                  f"{len(mb - ma)} only in B")
            return 1
        print(f"meta key sets identical ({len(ma)} keys)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
