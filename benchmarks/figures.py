"""Paper figure/table reproductions from matrix results.

One function per paper artifact:
  fig2  — percentage-of-optimum per (algorithm x sample size) per combo
  fig3  — aggregate mean + bootstrap CI across combos
  fig4a — median speedup over Random Search
  fig4b — CLES (probability of beating RS)
plus the MWU significance companion the paper applies throughout.
"""

from __future__ import annotations

import json
import os

import numpy as np

from repro.core import MatrixResults, stats

ALGOS = ("rs", "rf", "ga", "bo_gp", "bo_tpe")


def _normalize_meta(meta: dict) -> dict:
    """Accept both a versioned RunRecord (the tune_matrix facade's output)
    and the legacy flat meta dict; always expose ``meta["optimum"]`` as the
    pct-of-optimum denominator (the backend's noise-free true optimum when
    available, else the best observed final)."""
    if "run_record_version" not in meta:
        return meta
    result = dict(meta.get("result", {}))
    flat = {**meta.get("extra", {}), **result}
    flat["optimum"] = result.get("true_optimum", result.get("best_observed"))
    flat["spec"] = meta.get("spec", {})
    flat["provenance"] = meta.get("provenance", {})
    # which measurement produced these numbers: "costmodel" (analytical,
    # has a true optimum) vs "pallas" (real execution — pct-of-optimum is
    # relative to best observed).  backend_provenance carries the detail
    # (interpret flag, device kind, repeats, warmup) when recorded.
    flat["backend"] = flat["spec"].get("backend", "costmodel")
    return flat


def load_all(results_dir: str) -> dict:
    """{(bench, chip): (MatrixResults, meta)} for every stored combo."""
    out = {}
    for fname in sorted(os.listdir(results_dir)):
        if not fname.endswith(".npz") or "_dataset_" in fname:
            continue
        bench, chip = fname[:-4].rsplit("_", 1)
        res = MatrixResults.load(os.path.join(results_dir, fname))
        with open(os.path.join(results_dir, f"{bench}_{chip}.json")) as f:
            meta = _normalize_meta(json.load(f))
        out[(bench, chip)] = (res, meta)
    return out


def fig2_pct_optimum(results: dict) -> dict:
    """{(bench, chip): {algo: {S: median pct-of-optimum}}}."""
    table = {}
    for key, (res, meta) in results.items():
        opt = meta["optimum"]
        table[key] = {
            algo: {
                s: float(np.median(stats.pct_of_optimum(res.finals(algo, s), opt)))
                for s in res.sample_sizes()
            }
            for algo in ALGOS
            if (algo, res.sample_sizes()[0]) in res.cells
        }
    return table


def fig3_aggregate(results: dict) -> dict:
    """{algo: {S: (mean, lo, hi)}} across all combos (bootstrap CI)."""
    f2 = fig2_pct_optimum(results)
    sample_sizes = sorted({s for t in f2.values() for a in t.values() for s in a})
    out = {}
    for algo in ALGOS:
        out[algo] = {}
        for s in sample_sizes:
            vals = np.array([t[algo][s] for t in f2.values() if algo in t and s in t[algo]])
            if len(vals):
                out[algo][s] = stats.bootstrap_ci(vals)
    return out


def fig4a_speedup(results: dict) -> dict:
    """{(bench, chip): {algo: {S: median speedup over RS}}}."""
    table = {}
    for key, (res, _) in results.items():
        table[key] = {}
        for algo in ALGOS:
            if algo == "rs":
                continue
            table[key][algo] = {
                s: stats.median_speedup(res.finals("rs", s), res.finals(algo, s))
                for s in res.sample_sizes()
            }
    return table


def fig4b_cles(results: dict) -> dict:
    """{(bench, chip): {algo: {S: P(algo beats RS)}}}."""
    table = {}
    for key, (res, _) in results.items():
        table[key] = {}
        for algo in ALGOS:
            if algo == "rs":
                continue
            table[key][algo] = {
                s: stats.cles_lower_better(res.finals(algo, s), res.finals("rs", s))
                for s in res.sample_sizes()
            }
    return table


def search_cost(results: dict) -> dict:
    """{(bench, chip): {algo: {S: wall seconds}}} — per-cell search cost.

    The work-unit layer records wall-clock per executed unit and the session
    aggregates it per cell into ``RunRecord.extra["cell_wall_s"]`` (sums of
    unit walls, so the number is total compute even for parallel runs).
    Plot alongside the quality tables: the paper's 'which algorithm at which
    sample size' question is really quality *per unit of search cost*.
    Combos recorded before the wall-clock landed are skipped.
    """
    table = {}
    for key, (_, meta) in results.items():
        rows = meta.get("cell_wall_s")
        if not rows:
            continue
        t: dict = {}
        for r in rows:
            t.setdefault(r["algo"], {})[r["sample_size"]] = float(r["wall_s"])
        table[key] = t
    return table


def mwu_vs_rs(results: dict) -> dict:
    """{(bench, chip): {algo: {S: p-value}}} (alpha = 0.01 in the paper)."""
    table = {}
    for key, (res, _) in results.items():
        table[key] = {}
        for algo in ALGOS:
            if algo == "rs":
                continue
            table[key][algo] = {
                s: stats.mann_whitney_u(
                    res.finals(algo, s), res.finals("rs", s)
                ).p_value
                for s in res.sample_sizes()
            }
    return table


# ------------------------------------------------------------ rendering
def render_fig2(table: dict) -> str:
    lines = []
    for (bench, chip), algos in sorted(table.items()):
        sizes = sorted(next(iter(algos.values())))
        lines.append(f"\n### pct-of-optimum — {bench} x {chip}")
        lines.append("| algo | " + " | ".join(f"S={s}" for s in sizes) + " |")
        lines.append("|---|" + "---|" * len(sizes))
        for algo, row in algos.items():
            lines.append(
                f"| {algo} | " + " | ".join(f"{row[s]:.1f}%" for s in sizes) + " |"
            )
    return "\n".join(lines)


def render_grid(table: dict, fmt: str = "{:.3f}", title: str = "") -> str:
    lines = []
    for (bench, chip), algos in sorted(table.items()):
        sizes = sorted(next(iter(algos.values())))
        lines.append(f"\n### {title} — {bench} x {chip}")
        lines.append("| algo | " + " | ".join(f"S={s}" for s in sizes) + " |")
        lines.append("|---|" + "---|" * len(sizes))
        for algo, row in algos.items():
            lines.append(
                f"| {algo} | " + " | ".join(fmt.format(row[s]) for s in sizes) + " |"
            )
    return "\n".join(lines)


def render_fig3(agg: dict) -> str:
    sizes = sorted({s for rows in agg.values() for s in rows})
    lines = ["| algo | " + " | ".join(f"S={s}" for s in sizes) + " |",
             "|---|" + "---|" * len(sizes)]
    for algo, rows in agg.items():
        cells = []
        for s in sizes:
            if s in rows:
                m, lo, hi = rows[s]
                cells.append(f"{m:.1f}% [{lo:.1f}, {hi:.1f}]")
            else:
                cells.append("-")
        lines.append(f"| {algo} | " + " | ".join(cells) + " |")
    return "\n".join(lines)
