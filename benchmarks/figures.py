"""Thin CLI/compat shim — the figure and table layer lives in
``repro.analysis`` now (stats, matplotlib figures, claim verdicts, report
generation; see ``docs/analysis_and_report.md``).

This module re-exports the old names so existing callers keep working, and

    PYTHONPATH=src python -m benchmarks.figures results/smoke_matrix

renders the full ``REPORT.md`` (same as ``python -m repro.analysis``).
"""

from __future__ import annotations

from repro.analysis import ALGOS, load_all
from repro.analysis.records import normalize_meta as _normalize_meta
from repro.analysis.report import (
    main,
    render_fig2,
    render_fig3,
    render_grid,
)
from repro.analysis.stats import (
    fig2_pct_optimum,
    fig3_aggregate,
    fig4a_speedup,
    fig4b_cles,
    mwu_vs_rs,
    search_cost,
)

__all__ = [
    "ALGOS",
    "_normalize_meta",
    "fig2_pct_optimum",
    "fig3_aggregate",
    "fig4a_speedup",
    "fig4b_cles",
    "load_all",
    "mwu_vs_rs",
    "render_fig2",
    "render_fig3",
    "render_grid",
    "search_cost",
]

if __name__ == "__main__":
    raise SystemExit(main())
