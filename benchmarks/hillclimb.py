import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimbing driver (EXPERIMENTS.md).

Applies the hypothesis -> change -> re-lower -> re-analyse loop to the three
chosen cells (worst roofline fraction, most collective-bound, most
paper-representative):

  H1  cast_bf16       cast fp32 master weights to bf16 BEFORE the layer
                      scan -> per-layer FSDP all-gathers move half the bytes
  H2  moe_constrain   shard-constrain the MoE dispatch tensors (group dim on
                      the data axes, expert dim on "model") so the SPMD
                      partitioner stops replicating the combine scatter
                      ('involuntary full rematerialization' warnings)
  H3  head_dim TP     shard attention head_dim over "model" when head count
                      is indivisible (yi-34b: 56 heads vs 16-way TP)

Each run records the three roofline terms before/after; results land in
results/perf/<cell>__<variant>.json and a summary table prints at the end.

    PYTHONPATH=src python -m benchmarks.hillclimb [--cells yi-34b:train_4k ...]
"""

import argparse
import json
import sys

sys.path.insert(0, "src")

import repro.models.moe as moe_mod
import repro.sharding.constrain as constrain_mod
from repro.launch.dryrun import run_cell
from repro.launch.roofline import roofline_row
from repro.sharding.rules import ShardingRules
from repro.train.step import TrainSettings

DEFAULT_CELLS = (
    "olmoe-1b-7b:train_4k",        # worst roofline fraction
    "deepseek-v2-236b:train_4k",   # most collective-bound
    "yi-34b:train_4k",             # canonical dense LM (paper-representative)
)

def _variant(cast_bf16=False, moe_constrain=False, head_dim_tp=False, fsdp_gather=False):
    return dict(cast_bf16=cast_bf16, moe_constrain=moe_constrain,
                head_dim_tp=head_dim_tp, fsdp_gather=fsdp_gather)


VARIANTS = {
    "baseline": _variant(),
    "H1_bf16gather": _variant(cast_bf16=True),
    "H2_moe_dispatch": _variant(moe_constrain=True),
    "H1+H2": _variant(cast_bf16=True, moe_constrain=True),
    "H1+H3_headdim": _variant(cast_bf16=True, head_dim_tp=True),
    "H1+H2+H3": _variant(cast_bf16=True, moe_constrain=True, head_dim_tp=True),
    "H4_fsdp_gather": _variant(fsdp_gather=True),
    "H4+H3": _variant(head_dim_tp=True, fsdp_gather=True),
}


def run_variant(arch: str, shape: str, name: str, v: dict) -> dict:
    moe_mod.CONSTRAIN_DISPATCH = v["moe_constrain"]
    constrain_mod.FSDP_GATHER_WEIGHTS = v.get("fsdp_gather", False)
    rules = ShardingRules()
    if v["head_dim_tp"]:
        rules = rules.with_overrides(head_dim=("model",))
    settings = TrainSettings(remat="dots", accum=1, cast_bf16=v["cast_bf16"])
    try:
        rec = run_cell(arch, shape, multi_pod=False, rules=rules,
                       settings=settings, save=False)
    finally:
        moe_mod.CONSTRAIN_DISPATCH = False
        constrain_mod.FSDP_GATHER_WEIGHTS = False
    row = roofline_row(arch, shape, record=rec)
    out = {
        "variant": name,
        "flags": v,
        "compute_s": row.compute_s,
        "memory_s": row.memory_s,
        "collective_s": row.collective_s,
        "step_s": row.step_s,
        "roofline_fraction": row.roofline_fraction,
        "dominant": row.dominant,
        "collectives": rec["collectives"]["bytes"],
        "peak_gib": row.peak_gib,
    }
    os.makedirs("results/perf", exist_ok=True)
    with open(f"results/perf/{arch}_{shape}__{name.replace('+','_')}.json", "w") as f:
        json.dump(out, f, indent=2)
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--cells", nargs="*", default=list(DEFAULT_CELLS))
    ap.add_argument("--variants", nargs="*", default=list(VARIANTS))
    args = ap.parse_args()

    summary = []
    for cell in args.cells:
        arch, shape = cell.split(":")
        is_moe = arch in ("olmoe-1b-7b", "deepseek-v2-236b")
        for name in args.variants:
            v = VARIANTS[name]
            if v["moe_constrain"] and not is_moe:
                continue
            if not is_moe and name in ("H2_moe_dispatch", "H1+H2", "H1+H2+H3"):
                continue
            print(f"[hillclimb] {cell} :: {name} ...", flush=True)
            out = run_variant(arch, shape, name, v)
            summary.append((cell, name, out))
            print(
                f"    step={out['step_s']:.3f}s  coll={out['collective_s']:.3f}s "
                f"comp={out['compute_s']:.3f}s  frac={out['roofline_fraction']:.3f} "
                f"dominant={out['dominant']}"
            )

    print("\n| cell | variant | step (s) | collective (s) | compute (s) | frac |")
    print("|---|---|---|---|---|---|")
    for cell, name, out in summary:
        print(f"| {cell} | {name} | {out['step_s']:.3f} | "
              f"{out['collective_s']:.3f} | {out['compute_s']:.3f} | "
              f"{out['roofline_fraction']:.3f} |")


if __name__ == "__main__":
    main()
