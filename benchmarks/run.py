"""Benchmark driver — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:
  * per (benchmark x chip x algorithm x sample-size): the median tuned
    runtime in µs, with pct-of-optimum as the derived column (Fig. 2),
  * aggregate mean + CI rows (Fig. 3),
  * speedup-over-RS and CLES rows (Fig. 4a / 4b),
  * searcher-overhead microbenchmarks (µs per sample of algorithm cost),
  * Pallas-kernel interpret-mode microbenchmarks vs their oracles.

By default reuses results/paper_matrix if the full background run exists;
otherwise runs a budget-scaled matrix (--budget, default 500 — a few
minutes on one core).  ``--full`` forces the paper-exact design.
"""

from __future__ import annotations

import argparse
import os
import sys
import time

import numpy as np

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(ROOT, "src"))
sys.path.insert(0, ROOT)

from benchmarks.paper_matrix import BENCHMARKS, CHIP_NAMES, combo_path, run_combo
from repro.analysis import load_all, validate
from repro.analysis.stats import (
    fig2_pct_optimum,
    fig3_aggregate,
    fig4a_speedup,
    fig4b_cles,
)
from repro.core import ExperimentDesign, TuningSession, TuningSpec


def ensure_matrix(out_dir: str, budget: int, shards: int = 1) -> str:
    full_dir = os.path.join("results", "paper_matrix")
    if all(
        os.path.exists(combo_path(full_dir, b, c))
        for b in BENCHMARKS
        for c in CHIP_NAMES
    ):
        return full_dir
    design = ExperimentDesign.scaled(budget=budget)
    os.makedirs(out_dir, exist_ok=True)
    for b in BENCHMARKS:
        for c in CHIP_NAMES:
            if not os.path.exists(combo_path(out_dir, b, c)):
                run_combo(b, c, design, out_dir, verbose=False, shards=shards)
    return out_dir


def table_fig2(results_dir: str) -> None:
    results = load_all(results_dir)
    f2 = fig2_pct_optimum(results)
    for (bench, chip), algos in sorted(f2.items()):
        res, meta = results[(bench, chip)]
        for algo, row in algos.items():
            for s, pct in row.items():
                med = float(np.median(res.finals(algo, s)))
                print(f"fig2/{bench}_{chip}/{algo}/S{s},{med*1e6:.2f},{pct:.2f}")


def table_fig3(results_dir: str) -> None:
    agg = fig3_aggregate(load_all(results_dir))
    for algo, rows in agg.items():
        for s, (m, lo, hi) in rows.items():
            print(f"fig3/{algo}/S{s},{m:.3f},{lo:.2f}..{hi:.2f}")


def table_fig4(results_dir: str) -> None:
    results = load_all(results_dir)
    sp = fig4a_speedup(results)
    cl = fig4b_cles(results)
    for key in sorted(sp):
        bench, chip = key
        for algo in sp[key]:
            for s in sp[key][algo]:
                print(
                    f"fig4a/{bench}_{chip}/{algo}/S{s},{sp[key][algo][s]:.4f},"
                    f"cles={cl[key][algo][s]:.4f}"
                )


def table_searcher_overhead() -> None:
    """Algorithm cost per sample (the paper ignores it by design — section V
    — but the framework reports it for completeness)."""
    for algo in ("rs", "rf", "ga", "bo_gp", "bo_tpe", "sa", "pso"):
        session = TuningSession(
            TuningSpec(kernel="harris", searcher=algo, budget=100, seed=0)
        )
        t0 = time.perf_counter()
        session.run()
        dt = time.perf_counter() - t0
        print(f"searcher_overhead/{algo},{dt/100*1e6:.1f},budget=100")


def table_engine_dispatch(budget: int = 400) -> None:
    """Batched ask/tell engine vs sequential dispatch on the vectorized
    cost-model backend: Python-level measurement dispatches and wall clock
    per searcher.  The batched path must dispatch >=5x less (it does ~100x
    less for the batch-friendly searchers)."""
    tot_b = tot_o = 0
    for algo in ("rs", "rf", "ga", "pso", "grid"):
        spec = TuningSpec(kernel="harris", searcher=algo, budget=budget, seed=0)
        sb = TuningSession(spec)
        t0 = time.perf_counter()
        sb.run()
        t_batch = time.perf_counter() - t0
        so = TuningSession(spec.replace(dispatch="one"))
        t0 = time.perf_counter()
        so.run()
        t_one = time.perf_counter() - t0
        tot_b += sb.measurement.n_dispatches
        tot_o += so.measurement.n_dispatches
        ratio = so.measurement.n_dispatches / max(1, sb.measurement.n_dispatches)
        print(
            f"engine_dispatch/{algo},{t_batch*1e6:.0f},"
            f"dispatches={sb.measurement.n_dispatches}v{so.measurement.n_dispatches} "
            f"ratio={ratio:.0f}x wall={t_one/max(t_batch,1e-9):.1f}x"
        )
    print(
        f"engine_dispatch/aggregate,{tot_b},"
        f"sequential={tot_o} ratio={tot_o/max(1,tot_b):.1f}x"
    )


def table_kernels() -> None:
    """Interpret-mode wall time of the real Pallas kernels (small images —
    interpret mode is a correctness vehicle, not a performance one)."""
    import jax.numpy as jnp

    from repro.kernels import TUNABLE_KERNELS, add_ref, harris_ref, mandelbrot_ref

    rng = np.random.default_rng(0)
    img = jnp.asarray(rng.normal(size=(128, 256)), jnp.float32)
    cfg = dict(t_x=2, t_y=1, t_z=2, w_x=1, w_y=1, w_z=2)

    def timeit(fn, *a, **k):
        fn(*a, **k)  # compile/warm
        t0 = time.perf_counter()
        for _ in range(3):
            np.asarray(fn(*a, **k))
        return (time.perf_counter() - t0) / 3

    t = timeit(TUNABLE_KERNELS["add"], img, img, cfg)
    r = timeit(add_ref, img, img)
    print(f"kernel_interpret/add,{t*1e6:.0f},ref_us={r*1e6:.0f}")
    t = timeit(TUNABLE_KERNELS["harris"], img, cfg)
    r = timeit(harris_ref, img)
    print(f"kernel_interpret/harris,{t*1e6:.0f},ref_us={r*1e6:.0f}")
    t = timeit(TUNABLE_KERNELS["mandelbrot"], 128, 256, cfg)
    r = timeit(mandelbrot_ref, 128, 256)
    print(f"kernel_interpret/mandelbrot,{t*1e6:.0f},ref_us={r*1e6:.0f}")


def table_pallas_backend(budget: int = 10) -> None:
    """The real-measurement path end-to-end: tune the add kernel through
    ``backend="pallas"`` (compile-and-time, validity pre-screen, compile
    cache) and report the tuned time plus the cache's figure of merit —
    compiles per sample served."""
    session = TuningSession(
        TuningSpec(
            kernel="add",
            searcher="ga",
            backend="pallas",
            backend_kwargs={"x": 128, "y": 256, "repeats": 3},
            budget=budget,
            final_repeats=3,
            seed=0,
        )
    )
    r = session.run()
    prov = session.measurement.provenance()
    print(
        f"pallas_backend/add,{r.final_value*1e6:.0f},"
        f"compiles={prov['n_compiles']}/{r.n_samples} "
        f"invalid={prov['n_invalid']} interpret={int(prov['interpret'])}"
    )


def table_pipeline_overlap(n_cfgs: int = 8, compile_ms: float = 25.0) -> None:
    """Compile-prefetch pipeline on a compile-bound synthetic workload: the
    first call per geometry sleeps ``compile_ms`` (standing in for Mosaic
    compilation), so the whole batch's compile cost is the serial floor the
    prefetcher exists to overlap.  Values must be identical pipelined or
    not; the wall-clock ratio is the PR's tracked perf number."""
    from repro.kernels.common import KernelBenchSpec
    from repro.pallas_bench import PallasMeasurement
    from repro.pallas_bench.workloads import PallasWorkload

    seen: set = set()

    def run(inputs, cfg, x, y):
        key = tuple(sorted(cfg.items()))
        if key not in seen:          # "compilation": first call per geometry
            seen.add(key)
            time.sleep(compile_ms / 1e3)
        return None

    bench = KernelBenchSpec(
        name="synthetic_compile", n_inputs=0,
        make_inputs=lambda x, y, seed: (), run=run,
    )
    cfgs = [
        dict(t_x=1 << i, t_y=1, t_z=1, w_x=1, w_y=1, w_z=1)
        for i in range(n_cfgs)
    ]
    walls, values = {}, {}
    for workers in (0, 4):
        seen.clear()
        # deterministic timing-stage clock: the VALUES must be identical
        # pipelined or not (only the wall-clock may differ), and a real
        # clock could never show that
        ticks = iter(range(10**9))
        m = PallasMeasurement(
            PallasWorkload(bench=bench, x=64, y=128),
            repeats=1, warmup=1, validate=False, pipeline_workers=workers,
            timer=lambda: float(next(ticks)),
        )
        t0 = time.perf_counter()
        values[workers] = m.measure_batch(cfgs)
        walls[workers] = time.perf_counter() - t0
        m.close()
    same = int(np.array_equal(values[0], values[4]))
    print(
        f"pipeline_overlap/prefetch_off,{walls[0]*1e6:.0f},configs={n_cfgs}"
    )
    print(
        f"pipeline_overlap/prefetch_on,{walls[4]*1e6:.0f},"
        f"speedup={walls[0]/max(walls[4], 1e-9):.2f}x identical={same}"
    )


def table_scheduler_tail(slow_ms: float = 30.0, workers: int = 2) -> None:
    """Work-stealing vs static scheduling around a straggler cell: one cell's
    experiments each pay a slow synthetic dispatch (standing in for a
    geometry that compiles/runs far slower than its neighbours), so the
    static one-partition-per-worker schedule stalls its join behind whichever
    worker drew the straggler while the stealing scheduler splits it by
    predicted cost and rebalances.  Values must be identical across serial /
    static / steal; the wall-clock ratio is the PR's tracked perf number."""
    from concurrent.futures import ThreadPoolExecutor

    from repro.core.backends import BACKENDS, Backend, register_backend
    from repro.core.measurement import BaseMeasurement
    from repro.core.runner import stable_seed
    from repro.core.space import Param, SearchSpace

    slow_s, n_exp, seed0 = 32, 4, 3

    class StragglerMeasurement(BaseMeasurement):
        """Deterministic pure-function values; experiments whose seed is in
        ``slow_seeds`` pay one ``slow_ms`` sleep per search dispatch."""

        def __init__(self, slow: bool, sleep_s: float):
            super().__init__()
            self._slow = slow
            self._sleep_s = sleep_s

        def _value(self, config) -> float:
            key = ",".join(f"{k}={v}" for k, v in sorted(config.items()))
            return 0.1 + (stable_seed(key) % 4096) / 4096.0

        def _measure_one(self, config) -> float:
            return self._value(config)

        def measure_batch(self, configs):
            self.n_samples += len(configs)
            self.n_dispatches += 1
            if self._slow:
                time.sleep(self._sleep_s)
            return np.array([self._value(c) for c in configs], dtype=np.float64)

    # the straggler cell is the largest sample size: the cost model's
    # samples-x-experiments weight marks it most expensive, so the stealing
    # split slices it first
    slow_seeds = tuple(stable_seed(seed0, "rs", slow_s, e) for e in range(n_exp))
    if "straggler" not in BACKENDS:
        register_backend(
            Backend(
                name="straggler",
                make=lambda kernel="straggler", seed=0, slow_seeds=(),
                slow_ms=0.0, **_: StragglerMeasurement(
                    seed in set(slow_seeds), slow_ms / 1e3
                ),
                default_space=lambda kernel="straggler", **_: SearchSpace(
                    [Param.int_range("t_x", 1, 16), Param.int_range("t_y", 1, 16)]
                ),
            )
        )
    spec = TuningSpec(
        kernel="straggler",
        backend="straggler",
        backend_kwargs={"slow_seeds": list(slow_seeds), "slow_ms": slow_ms},
        searcher="rs",
        algorithms=("rs",),
        design=ExperimentDesign(
            sample_sizes=(slow_s, 8, 10, 12),
            n_experiments=(n_exp,) * 4,
            final_repeats=3,
        ),
        dataset_size=None,
        seed=seed0,
    )

    def run(**kw):
        session = TuningSession(spec)
        t0 = time.perf_counter()
        res = session.run_matrix(**kw)
        return res, time.perf_counter() - t0

    serial, t_serial = run()
    static, t_static = run(
        executor="futures", max_workers=workers, scheduler="static",
        futures_pool=ThreadPoolExecutor(max_workers=workers),
    )
    steal, t_steal = run(
        executor="futures", max_workers=workers,
        futures_pool=ThreadPoolExecutor(max_workers=workers),
    )
    same = int(
        all(
            np.array_equal(
                serial.cells[k].final_values, other.cells[k].final_values
            )
            and np.array_equal(
                serial.cells[k].search_best_values,
                other.cells[k].search_best_values,
            )
            for other in (static, steal)
            for k in serial.cells
        )
    )
    assert same, "scheduler changed values — the speed-knob contract broke"
    print(f"scheduler_tail/serial,{t_serial*1e6:.0f},cells=4 straggler=S32")
    print(
        f"scheduler_tail/static,{t_static*1e6:.0f},"
        f"workers={workers} speedup_vs_serial="
        f"{t_serial/max(t_static,1e-9):.2f}x"
    )
    print(
        f"scheduler_tail/steal,{t_steal*1e6:.0f},"
        f"workers={workers} speedup_vs_static="
        f"{t_static/max(t_steal,1e-9):.2f}x identical={same}"
    )


def table_telemetry_overhead(budget: int = 400) -> None:
    """Tracing cost on the hot path: the same tuning run with the default
    no-op telemetry vs a real JSONL tracer.  The tuned result must be
    identical (telemetry is observability only); the per-sample delta in µs
    is the tracked overhead number."""
    import shutil
    import tempfile

    from repro.telemetry import TRACE_FILE, Telemetry

    spec = TuningSpec(kernel="harris", searcher="ga", budget=budget, seed=0)
    t0 = time.perf_counter()
    off = TuningSession(spec).run()
    t_off = time.perf_counter() - t0

    tmp = tempfile.mkdtemp(prefix="tel_overhead_")
    try:
        tel = Telemetry(os.path.join(tmp, TRACE_FILE))
        t0 = time.perf_counter()
        on = TuningSession(spec, telemetry=tel).run()
        t_on = time.perf_counter() - t0
        tel.close()
    finally:
        shutil.rmtree(tmp, ignore_errors=True)
    same = int(on.best_value == off.best_value)
    print(f"telemetry_overhead/off,{t_off/budget*1e6:.2f},budget={budget}")
    print(
        f"telemetry_overhead/on,{t_on/budget*1e6:.2f},"
        f"delta_us={(t_on-t_off)/budget*1e6:.2f} identical={same}"
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--budget", type=int, default=500)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--shards", type=int, default=1)
    args = ap.parse_args()

    t0 = time.time()
    if args.full:
        out = os.path.join("results", "paper_matrix")
        os.makedirs(out, exist_ok=True)
        for b in BENCHMARKS:
            for c in CHIP_NAMES:
                if not os.path.exists(combo_path(out, b, c)):
                    run_combo(b, c, ExperimentDesign.paper(), out,
                              shards=args.shards)
        results_dir = out
    else:
        results_dir = ensure_matrix(
            os.path.join("results", f"matrix_{args.budget}"), args.budget,
            shards=args.shards,
        )
    print(f"# matrix: {results_dir}")
    table_fig2(results_dir)
    table_fig3(results_dir)
    table_fig4(results_dir)
    table_searcher_overhead()
    table_engine_dispatch()
    table_kernels()
    table_pallas_backend()
    table_pipeline_overlap()
    table_scheduler_tail()
    table_telemetry_overhead()
    print("# paper-claims validation")
    checks = validate(results_dir)
    for name, v in checks.items():
        print(f"claim/{name},{v.status},{v.detail}")
    print(f"# total {time.time()-t0:.0f}s")


if __name__ == "__main__":
    main()
