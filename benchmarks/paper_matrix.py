"""Run the paper's full experiment matrix.

3 benchmarks (add / harris / mandelbrot)  x  3 chip models (v5e / v4 / v3)
x 5 algorithms (rs / rf / ga / bo_gp / bo_tpe)  x  sample sizes
{25, 50, 100, 200, 400} with experiment counts {800, 400, 200, 100, 50}
(or a budget-scaled design) — the reproduction of the paper's ~3,019,500
samples.  Results are persisted per (benchmark, chip) combo so interrupted
runs resume.

Usage:
    PYTHONPATH=src python -m benchmarks.paper_matrix --design paper
    PYTHONPATH=src python -m benchmarks.paper_matrix --design scaled --budget 2000
"""

from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from repro.core import ExperimentDesign, MatrixRunner, MeasurementStore, SampleDataset
from repro.costmodel import (
    CHIPS,
    WORKLOADS,
    CostModelMeasurement,
    executable_space,
    true_optimum,
)

ALGOS = ("rs", "rf", "ga", "bo_gp", "bo_tpe")
DATASET_SEED = 7
GEN_SEED = 999


def combo_path(out_dir: str, bench: str, chip: str) -> str:
    return os.path.join(out_dir, f"{bench}_{chip}.npz")


def run_combo(bench: str, chip_name: str, design: ExperimentDesign, out_dir: str,
              algorithms=ALGOS, seed: int = 0, verbose: bool = True,
              cache: bool = True, dispatch: str = "batch") -> None:
    w, chip = WORKLOADS[bench], CHIPS[chip_name]
    space = executable_space(w, chip)
    dataset = SampleDataset.generate(
        space,
        CostModelMeasurement(w, chip, seed=GEN_SEED),
        n=20000,
        seed=DATASET_SEED,
        # seeds in the filename: changing either invalidates the cache
        cache_path=(
            os.path.join(
                out_dir,
                f"{bench}_{chip_name}_dataset_s{DATASET_SEED}g{GEN_SEED}.npz",
            )
            if cache
            else None
        ),
    )
    opt_cfg, opt = true_optimum(w, chip)
    # persistent (kernel, config) cache: re-running an interrupted combo
    # serves every previously-measured cell from disk
    store = (
        MeasurementStore(os.path.join(out_dir, f"{bench}_{chip_name}_cache.json"))
        if cache
        else None
    )
    runner = MatrixRunner(
        space,
        lambda s: CostModelMeasurement(w, chip, seed=s),
        design,
        dataset=dataset,
        algorithms=algorithms,
        seed=seed,
        verbose=verbose,
        dispatch=dispatch,
        store=store,
        cache_key=f"{bench}/{chip_name}",
    )
    t0 = time.time()
    results = runner.run()
    results.save(combo_path(out_dir, bench, chip_name))
    meta = {
        "bench": bench,
        "chip": chip_name,
        "optimum": opt,
        "optimum_config": opt_cfg,
        "dataset_best": dataset.optimum,
        "design": {"sample_sizes": design.sample_sizes,
                   "n_experiments": design.n_experiments},
        "wall_s": time.time() - t0,
    }
    with open(os.path.join(out_dir, f"{bench}_{chip_name}.json"), "w") as f:
        json.dump(meta, f, indent=2)
    print(f"[matrix] {bench} x {chip_name} done in {meta['wall_s']:.0f}s "
          f"(optimum {opt*1e3:.3f} ms @ {opt_cfg})")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--design", choices=("paper", "scaled"), default="scaled")
    ap.add_argument("--budget", type=int, default=2000,
                    help="per-cell sample budget for --design scaled")
    ap.add_argument("--out", default=None)
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    design = (
        ExperimentDesign.paper()
        if args.design == "paper"
        else ExperimentDesign.scaled(budget=args.budget)
    )
    out_dir = args.out or os.path.join(
        "results", "paper_matrix" if args.design == "paper" else f"matrix_{args.budget}"
    )
    os.makedirs(out_dir, exist_ok=True)

    t0 = time.time()
    for bench in WORKLOADS:
        for chip_name in CHIPS:
            path = combo_path(out_dir, bench, chip_name)
            if os.path.exists(path) and not args.force:
                print(f"[matrix] skip existing {path}")
                continue
            run_combo(bench, chip_name, design, out_dir)
    print(f"[matrix] all combos done in {(time.time()-t0)/60:.1f} min -> {out_dir}")


if __name__ == "__main__":
    main()
