"""Run the paper's full experiment matrix through the ``repro.tune_matrix``
facade.

3 benchmarks (add / harris / mandelbrot)  x  3 chip models (v5e / v4 / v3)
x 5 algorithms (rs / rf / ga / bo_gp / bo_tpe)  x  sample sizes
{25, 50, 100, 200, 400} with experiment counts {800, 400, 200, 100, 50}
(or a budget-scaled design) — the reproduction of the paper's ~3,019,500
samples.  Each (benchmark, chip) combo is one declarative
:class:`TuningSpec`; results are persisted per combo (``.npz`` + versioned
``RunRecord`` JSON) so finished combos are skipped on re-run.

Each combo decomposes into work units run through the ``EXECUTORS``
registry: ``--executor process --max-workers N`` fans units (including
within-cell splits of the big-E rows) across N workers, bit-identical to
the serial run; ``--resume`` replays units an interrupted run already
journaled in the measurement store, re-measuring nothing.  ``--shards N``
is the legacy spelling of the process executor.

``--report`` renders ``REPORT.md`` (speedup/rank tables, figures, paper-claim
verdicts — see ``repro.analysis``) into the results dir after the run, so the
full-scale paper reproduction is "run the matrix, read REPORT.md".

``--telemetry`` writes a JSONL span trace into the results dir (workers
write ``trace.shard<k>.jsonl``, merged at join); ``--progress`` adds a
periodic one-line units-done/ETA update on stderr — observability only,
results and stores are bit-identical with either flag on or off.

Usage:
    PYTHONPATH=src python -m benchmarks.paper_matrix --design paper --report
    PYTHONPATH=src python -m benchmarks.paper_matrix --design scaled --budget 2000 \\
        --executor process --max-workers 4 --store sqlite --resume --report
"""

from __future__ import annotations

import argparse
import os
import time

import repro
from repro.core import ExperimentDesign, TuningSpec

BENCHMARKS = ("add", "harris", "mandelbrot")
CHIP_NAMES = ("v5e", "v4", "v3")
ALGOS = ("rs", "rf", "ga", "bo_gp", "bo_tpe")
DATASET_SEED = 7
GEN_SEED = 999


def combo_path(out_dir: str, bench: str, chip: str) -> str:
    return os.path.join(out_dir, f"{bench}_{chip}.npz")


def combo_spec(bench: str, chip_name: str, design: ExperimentDesign,
               out_dir: str, algorithms=ALGOS, seed: int = 0,
               cache: bool = True, dispatch: str = "batch",
               store: str = "json", backend: str = "costmodel") -> TuningSpec:
    """The declarative spec for one (benchmark, chip) combo.

    ``backend="pallas"`` swaps the analytical model for real kernel
    execution (interpret mode on CPU, Mosaic on TPU); the chip axis
    collapses to the pseudo-target ``"pallas"`` (the hardware IS the chip)
    and the 20k pre-generated dataset is skipped — generating it through
    real timings would dwarf the matrix itself.  RS/RF fall back to their
    searcher implementations.
    """
    store_ext = "sqlite" if store == "sqlite" else "json"
    pallas = backend == "pallas"
    return TuningSpec(
        kernel=bench,
        backend=backend,
        backend_kwargs={} if pallas else {"chip": chip_name},
        algorithms=tuple(algorithms),
        design=design,
        seed=seed,
        dispatch=dispatch,
        cache_key=f"{bench}/{chip_name}",
        # persistent (kernel, config) cache: re-running an interrupted combo
        # serves every previously-measured cell from disk
        store=store if cache else None,
        store_path=(
            os.path.join(out_dir, f"{bench}_{chip_name}_cache.{store_ext}")
            if cache
            else None
        ),
        # the 20k pre-generated dataset serving the non-SMBO methods
        # (seeds in the filename: changing either invalidates the cache)
        dataset_size=None if pallas else 20000,
        dataset_seed=DATASET_SEED,
        dataset_gen_seed=GEN_SEED,
        dataset_cache=(
            os.path.join(
                out_dir,
                f"{bench}_{chip_name}_dataset_s{DATASET_SEED}g{GEN_SEED}.npz",
            )
            if cache and not pallas
            else None
        ),
    )


def run_combo(bench: str, chip_name: str, design: ExperimentDesign, out_dir: str,
              algorithms=ALGOS, seed: int = 0, verbose: bool = True,
              cache: bool = True, dispatch: str = "batch", shards: int = 1,
              store: str = "json", backend: str = "costmodel",
              executor: str | None = None, max_workers: int | None = None,
              resume: bool = False,
              pipeline_workers: int | None = None,
              scheduler: str = "steal",
              compile_cache: str | None = None,
              telemetry_dir: str | None = None,
              progress: bool = False) -> None:
    spec = combo_spec(bench, chip_name, design, out_dir, algorithms=algorithms,
                      seed=seed, cache=cache, dispatch=dispatch, store=store,
                      backend=backend)
    t0 = time.time()
    reporter = None
    if progress and telemetry_dir is not None:
        from repro.telemetry import ProgressReporter

        # periodic units-done/total + ETA on stderr, fed by the live trace —
        # the fix for "--executor process prints nothing for minutes"
        reporter = ProgressReporter(telemetry_dir)
        reporter.start()
    try:
        repro.tune_matrix(spec, shards=shards, executor=executor,
                          max_workers=max_workers, resume=resume,
                          pipeline_workers=pipeline_workers,
                          scheduler=scheduler, compile_cache=compile_cache,
                          out_dir=out_dir, verbose=verbose,
                          telemetry_dir=telemetry_dir)
    finally:
        if reporter is not None:
            reporter.stop()
    record = repro.RunRecord.load(
        os.path.join(out_dir, f"{bench}_{chip_name}.json")
    )
    opt = record.result.get("true_optimum")
    opt_cfg = record.result.get("true_optimum_config")
    if opt is not None:
        detail = f"optimum {opt*1e3:.3f} ms @ {opt_cfg}"
    else:  # real-measurement backends have no analytic optimum
        detail = f"best observed {record.result['best_observed']*1e3:.3f} ms"
    print(f"[matrix] {bench} x {chip_name} done in {time.time() - t0:.0f}s "
          f"({detail})")


def index_matrix_winners(out_dir: str, serve_dir: str, *, benches, chips,
                         design: ExperimentDesign, store: str = "json",
                         backend: str = "costmodel", algorithms=ALGOS) -> int:
    """Fold every finished combo's measurement store into ``serve_dir``'s
    serving store (``serve_dir/store.sqlite``): one winners-index record per
    (kernel, geometry, chip).  Equivalent to ``python -m repro.serving index
    --dir serve_dir <combo stores>`` but driven off the matrix's own specs,
    so it never picks up foreign store files sitting in ``out_dir``."""
    from repro.core.stores import make_store
    from repro.serving import index_winners, open_serve_store

    os.makedirs(serve_dir, exist_ok=True)
    dst, _kind = open_serve_store(os.path.join(serve_dir, "store.sqlite"))
    total = 0
    try:
        for bench in benches:
            for chip_name in chips:
                spec = combo_spec(bench, chip_name, design, out_dir,
                                  algorithms=algorithms, store=store,
                                  backend=backend)
                if spec.store_path is None or not os.path.exists(spec.store_path):
                    continue
                src = make_store(spec.store, spec.store_path)
                try:
                    total += index_winners(dst, src, save=False)
                finally:
                    if hasattr(src, "close"):
                        src.close()
        dst.save()
    finally:
        if hasattr(dst, "close"):
            dst.close()
    return total


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--design", choices=("paper", "scaled", "smoke"),
                    default="scaled",
                    help="experiment design: the paper-exact matrix, the "
                         "budget-scaled one, or the tiny smoke design "
                         "(2 cells — CI-sized real-measurement runs)")
    ap.add_argument("--budget", type=int, default=2000,
                    help="per-cell sample budget for --design scaled")
    ap.add_argument("--shards", type=int, default=1,
                    help="legacy spelling of --executor process --max-workers N")
    ap.add_argument("--executor",
                    choices=("serial", "process", "futures", "device"),
                    default=None,
                    help="EXECUTORS registry entry running each combo's "
                         "work units (default: serial, or process when "
                         "workers > 1); 'device' pins worker threads to "
                         "jax.devices() for multi-chip hosts")
    ap.add_argument("--max-workers", type=int, default=None,
                    help="worker count for parallel executors (units fan "
                         "out, including within-cell splits of big-E rows)")
    ap.add_argument("--pipeline-workers", type=int, default=None,
                    help="compile-prefetch pool threads for the staged "
                         "pallas measurement pipeline (0/omitted: inline "
                         "compile-then-time; results are identical either "
                         "way)")
    ap.add_argument("--scheduler", choices=("steal", "static"),
                    default="steal",
                    help="how parallel executors hand units to workers: "
                         "'steal' over-splits cells by predicted cost and "
                         "lets workers pull from a shared queue; 'static' "
                         "is the legacy one-partition-per-worker schedule "
                         "(results are bit-identical either way)")
    ap.add_argument("--compile-cache", default=None, metavar="DIR",
                    help="persistent on-disk compile-artifact cache for the "
                         "staged pallas backend, shared across worker "
                         "processes and across runs (a warm re-run "
                         "recompiles nothing, even from a cold process)")
    ap.add_argument("--resume", action="store_true",
                    help="replay units journaled in the measurement store "
                         "by an interrupted run (zero re-measurements)")
    ap.add_argument("--bench", default=None,
                    help="run only this benchmark (default: all)")
    ap.add_argument("--chip", default=None,
                    help="run only this chip model (default: all)")
    ap.add_argument("--algos", default=None,
                    help="comma-separated algorithm subset (default: all 5)")
    ap.add_argument("--store", choices=("json", "sqlite"), default="json",
                    help="measurement-cache backend (sqlite for paper-exact runs)")
    ap.add_argument("--backend", choices=("costmodel", "pallas"),
                    default="costmodel",
                    help="analytical model, or real pallas_call execution "
                         "(interpret on CPU; use a scaled design — real "
                         "timings are wall-clock-bound)")
    ap.add_argument("--telemetry", action="store_true",
                    help="write a JSONL span trace (trace.jsonl, with "
                         "per-worker shards merged at join) into the results "
                         "dir; inspect with `python -m repro.telemetry "
                         "<results_dir>`")
    ap.add_argument("--progress", action="store_true",
                    help="print a periodic one-line progress/ETA update to "
                         "stderr while combos run (implies --telemetry; the "
                         "trace is the data source)")
    ap.add_argument("--report", action="store_true",
                    help="after the run, render REPORT.md (tables + figures "
                         "+ claim verdicts) into the results dir via "
                         "repro.analysis")
    ap.add_argument("--serve-dir", default=None, metavar="DIR",
                    help="after each combo, fold its store's per-geometry "
                         "winners into DIR's serving store (DIR/store.sqlite "
                         "— see `python -m repro.serving query`), so the "
                         "matrix doubles as the tuning-as-a-service "
                         "population step")
    ap.add_argument("--out", default=None)
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    if args.design == "paper":
        design = ExperimentDesign.paper()
        tag = "paper_matrix"
    elif args.design == "smoke":
        design = ExperimentDesign.smoke()
        tag = "matrix_smoke"
    else:
        design = ExperimentDesign.scaled(budget=args.budget)
        tag = f"matrix_{args.budget}"
    if args.backend != "costmodel":
        tag = f"{tag}_{args.backend}"
    out_dir = args.out or os.path.join("results", tag)
    os.makedirs(out_dir, exist_ok=True)

    # real measurement: the chip model axis collapses — the device is the chip
    chips = CHIP_NAMES if args.backend == "costmodel" else ("pallas",)
    benches = BENCHMARKS if args.bench is None else (args.bench,)
    if args.chip is not None:
        chips = (args.chip,)
    algos = ALGOS if args.algos is None else tuple(args.algos.split(","))
    t0 = time.time()
    for bench in benches:
        for chip_name in chips:
            path = combo_path(out_dir, bench, chip_name)
            if os.path.exists(path) and not args.force:
                print(f"[matrix] skip existing {path}")
                continue
            run_combo(bench, chip_name, design, out_dir, algorithms=algos,
                      shards=args.shards, store=args.store,
                      backend=args.backend, executor=args.executor,
                      max_workers=args.max_workers, resume=args.resume,
                      pipeline_workers=args.pipeline_workers,
                      scheduler=args.scheduler,
                      compile_cache=args.compile_cache,
                      telemetry_dir=(
                          out_dir if (args.telemetry or args.progress) else None
                      ),
                      progress=args.progress)
    print(f"[matrix] all combos done in {(time.time()-t0)/60:.1f} min -> {out_dir}")
    if args.serve_dir is not None:
        n = index_matrix_winners(out_dir, args.serve_dir, benches=benches,
                                 chips=chips, design=design, store=args.store,
                                 backend=args.backend, algorithms=algos)
        print(f"[matrix] serving winners index <- {n} record(s) "
              f"({os.path.join(args.serve_dir, 'store.sqlite')})")
    if args.report:
        from repro.analysis import generate_report

        print(f"[matrix] report -> {generate_report(out_dir)}")


if __name__ == "__main__":
    main()
