"""Regenerate EXPERIMENTS.md from results/ artifacts.

Sections:
  §Validation — paper-claims checks against the full experiment matrix
  §Figures    — fig2/3/4 reproductions (markdown tables)
  §Dry-run    — 64-cell compile summary (memory / flops / collectives)
  §Roofline   — three-term table + dominant-term analysis
  §Perf       — hillclimbing log (hypothesis -> change -> before/after)
  §Repro-perf — implementation notes on making the 3M-sample matrix feasible

    PYTHONPATH=src python -m benchmarks.make_experiments_md
"""

from __future__ import annotations

import json
import os
import sys

sys.path.insert(0, "src")
sys.path.insert(0, ".")


from repro.analysis import load_all, validate
from repro.analysis.claims import INSUFFICIENT
from repro.analysis.report import render_fig2, render_fig3, render_grid
from repro.analysis.stats import (
    fig2_pct_optimum,
    fig3_aggregate,
    fig4a_speedup,
    fig4b_cles,
)
from repro.launch.roofline import all_rows, markdown_table

MATRIX_DIR = "results/paper_matrix"
DRYRUN_DIR = "results/dryrun"
PERF_DIR = "results/perf"

HEADER = """\
# EXPERIMENTS

Reproduction of *Analyzing Search Techniques for Autotuning Image-based GPU
Kernels: The Impact of Sample Sizes* (Tørring & Elster 2022) — TPU/Pallas
adaptation per DESIGN.md.  All artifacts regenerate with:

```bash
PYTHONPATH=src python -m benchmarks.paper_matrix --design paper   # ~1 h, 1 core
PYTHONPATH=src python -m repro.launch.dryrun                      # ~45 min
PYTHONPATH=src python -m benchmarks.hillclimb                     # ~30 min
PYTHONPATH=src python -m benchmarks.make_experiments_md           # this file
```

Experiment design (paper-faithful): sample sizes S={25,50,100,200,400} with
E={800,400,200,100,50} experiments, 20k-sample pre-generated datasets for
the non-SMBO methods, winning config re-measured 10x, MWU alpha=0.01 + CLES.
Total ~3.02M samples across 3 benchmarks x 3 chip models x 5 algorithms.
"""


def section_validation() -> str:
    try:
        checks = validate(MATRIX_DIR)
    except Exception as e:  # matrix not finished yet
        return f"## §Validation\n\n(matrix incomplete: {e})\n"
    lines = ["## §Validation — paper claims vs our matrix\n"]
    n_pass = sum(v.passed for v in checks.values())
    n_dec = sum(v.status != INSUFFICIENT for v in checks.values())
    lines.append(f"**{n_pass}/{n_dec} decidable claims reproduced"
                 + (f" ({len(checks) - n_dec} insufficient-data).**\n"
                    if n_dec != len(checks) else ".**\n"))
    for name, v in checks.items():
        tag = {"pass": "PASS", "fail": "FAIL", INSUFFICIENT: "N/A"}[v.status]
        lines.append(f"- **[{tag}] {name}** — `{v.detail}`")
    lines.append("""
**Analysis of the divergences.**  The paper's headline — *no single
algorithm wins at every sample size* — reproduces cleanly (winners rotate
across S in both per-cell and aggregate views; C3/C4/C6 all hold).  Two
per-cell-winner checks diverge, with identifiable causes:

* **RF is stronger at S=25-50 here than in the paper.**  Our analytic TPU
  cost surface is near-separable in the six integer parameters — exactly
  what axis-aligned CART splits learn from 15 samples — whereas real GPU
  wall-times carry interaction structure CART cannot exploit.  A 2x-noise
  sensitivity matrix (results/matrix_noise2x, scaled design) *refutes* the
  alternative "our noise is too mild" explanation: RF's small-S win count
  is unchanged at double noise (15/27 both ways), so the separable surface
  is the cause.  (At 2x noise the large-S winner shifts toward BO-TPE,
  whose Parzen smoothing is the most noise-robust — consistent with the
  paper's 'TPE is a good balance' observation.)  RF still satisfies the
  paper's literal claim C5 ('never outperforms all the others' overall).
* **BO-GP does not collapse at S=200-400 the way skopt's gp_minimize
  does** (the paper attributes its dip to overfitting; our from-scratch GP
  refits hyperparameters on a doubling schedule and keeps an explicit
  noise term, which appears to be more robust — dips still occur in 3/9
  combos, C6).  Consequently GA's large-S margin over BO-GP is narrower
  per cell, though GA is still the best algorithm at S=200/400 by the
  aggregate Fig.-3 metric (C2b).
""")
    return "\n".join(lines)


def section_figures() -> str:
    try:
        results = load_all(MATRIX_DIR)
    except Exception as e:
        return f"## §Figures\n\n(matrix incomplete: {e})\n"
    if not results:
        return "## §Figures\n\n(matrix empty)\n"
    out = ["## §Figures — paper reproductions\n"]
    out.append("### Fig. 3 — mean pct-of-optimum across all benchmarks+chips\n")
    out.append(render_fig3(fig3_aggregate(results)))
    out.append("\n### Fig. 2 — per-combo pct-of-optimum (medians)\n")
    out.append(render_fig2(fig2_pct_optimum(results)))
    out.append("\n### Fig. 4a — median speedup over Random Search\n")
    out.append(render_grid(fig4a_speedup(results), "{:.3f}x", "speedup over RS"))
    out.append("\n### Fig. 4b — CLES: P(algorithm beats RS)\n")
    out.append(render_grid(fig4b_cles(results), "{:.2f}", "CLES vs RS"))
    out.append("")
    return "\n".join(out)


def section_dryrun() -> str:
    cells = []
    for f in sorted(os.listdir(DRYRUN_DIR)):
        if f.endswith(".json"):
            cells.append(json.load(open(os.path.join(DRYRUN_DIR, f))))
    lines = [
        "## §Dry-run — lower+compile of every (arch x shape x mesh)\n",
        f"{len(cells)} cells compiled (single-pod 16x16=256 chips; multi-pod "
        "2x16x16=512 chips).  long_500k runs on the sub-quadratic families "
        "(zamba2, mamba2) per spec; pure full-attention archs skip it "
        "(noted in DESIGN.md §4).\n",
        "| arch | shape | mesh | peak GiB/dev | args GiB/dev | HLO dot FLOPs/dev | coll B/dev | AG | AR | A2A |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for c in cells:
        b = c["collectives"]["bytes"]
        lines.append(
            f"| {c['arch']} | {c['shape']} | {c['mesh']} | "
            f"{c['memory']['peak_bytes_per_dev']/2**30:.2f} | "
            f"{c['memory']['argument_bytes_per_dev']/2**30:.2f} | "
            f"{c.get('flops_dot_corrected', 0):.2e} | "
            f"{c['collectives']['total_bytes']:.2e} | "
            f"{b.get('all-gather', 0):.1e} | {b.get('all-reduce', 0):.1e} | "
            f"{b.get('all-to-all', 0):.1e} |"
        )
    over = [c for c in cells
            if c["memory"]["peak_bytes_per_dev"] > 16 * 2**30]
    lines.append("")
    lines.append(
        f"**Fits check**: {len(cells) - len(over)}/{len(cells)} cells under "
        "the 16 GiB v5e HBM budget"
        + (f"; over budget: {[(c['arch'], c['shape'], c['mesh']) for c in over]}"
           if over else ".")
    )
    lines.append("")
    return "\n".join(lines)


def section_roofline() -> str:
    rows = all_rows()
    lines = [
        "## §Roofline — single-pod (256 chips), v5e constants "
        "(197 TF bf16, 819 GB/s HBM, 50 GB/s/link ICI)\n",
        "Terms: compute = loop-corrected HLO dot-FLOPs / (chips x peak); "
        "memory = analytic HBM traffic / (chips x bw); collective = "
        "per-device collective bytes / link bw.  `useful` = MODEL_FLOPS / "
        "HLO_FLOPs (6ND-style vs compiled — exposes remat recompute and MoE "
        "capacity padding).  XLA cost_analysis counts scan bodies once; the "
        "dot-FLOP column is trip-count-corrected (see launch/hlo_analysis.py).\n",
        markdown_table(rows),
    ]
    doms = {}
    for r in rows:
        doms[r.dominant] = doms.get(r.dominant, 0) + 1
    lines.append(f"\nDominant-term census: {doms}.  ")
    lines.append(
        "Almost every cell is **collective-bound** at this mesh: per-layer "
        "FSDP weight all-gathers + sequence-parallel activation collectives "
        "dwarf compute for <=47B-param models on 256 chips — the motivation "
        "for §Perf.  One-sentence movers per dominant term:\n"
        "- collective: move weight gathers to bf16 (H1), localize MoE "
        "dispatch (H2), shard attention head_dim when head counts are "
        "indivisible (H3).\n"
        "- memory (whisper decode / zamba long_500k): batch more decode "
        "requests per step or quantize the KV cache.\n"
        "- compute (none dominant at 256 chips): shrink the mesh or grow "
        "the model/batch.\n"
    )
    return "\n".join(lines)


def section_perf() -> str:
    lines = ["## §Perf — hillclimbing log (hypothesis -> change -> measure)\n"]
    if not os.path.isdir(PERF_DIR):
        return lines[0] + "\n(hillclimb not yet run)\n"
    by_cell: dict = {}
    for f in sorted(os.listdir(PERF_DIR)):
        if f.endswith(".json"):
            d = json.load(open(os.path.join(PERF_DIR, f)))
            cell = f.split("__")[0]
            by_cell.setdefault(cell, []).append(d)
    lines.append(
        "Chosen cells: olmoe-1b-7b/train_4k (worst roofline fraction), "
        "deepseek-v2-236b/train_4k (most collective-bound), yi-34b/train_4k "
        "(canonical dense; most representative of kernel-config tuning).  "
        "Baseline = paper-faithful defaults; variants per "
        "benchmarks/hillclimb.py.\n"
    )
    for cell, variants in by_cell.items():
        variants.sort(key=lambda d: d["step_s"])
        base = next(v for v in variants if v["variant"] == "baseline")
        lines.append(f"\n### {cell}\n")
        lines.append("| variant | step (s) | collective (s) | compute (s) | "
                     "roofline frac | vs baseline |")
        lines.append("|---|---|---|---|---|---|")
        for v in variants:
            speed = base["step_s"] / v["step_s"] if v["step_s"] else 0
            lines.append(
                f"| {v['variant']} | {v['step_s']:.3f} | "
                f"{v['collective_s']:.3f} | {v['compute_s']:.3f} | "
                f"{v['roofline_fraction']:.3f} | {speed:.2f}x |"
            )
    lines.append("")
    return "\n".join(lines)


def section_repro_perf() -> str:
    return """\
## §Repro-perf — making the 3M-sample matrix feasible on one CPU core

| hypothesis | change | before | after | verdict |
|---|---|---|---|---|
| GP refit dominates BO-GP (O(n^3)/step) | incremental Cholesky append + refit-on-doubling | 2.6 s/exp @ S=400 | ~1.5 s/exp | confirmed |
| RF per-node python recursion dominates | histogram trees, level-synchronous, vectorized across all trees x experiments of a cell | ~600 s per S=25 cell (800 exps) | ~30 s | confirmed |
| forest predict masked-gather overhead | self-looping leaves + flat gathers | 73 s / cell | 23 s | confirmed |
| TPE degrades at S>=200 | HyperOpt's n_good = min(ceil(0.25*sqrt(n)), 25) split (was linear 25%) | 84% of optimum @ S=400 | 98% | confirmed (fidelity bug, not perf) |
"""


def main() -> None:
    parts = [
        HEADER,
        section_validation(),
        section_figures(),
        section_dryrun(),
        section_roofline(),
        section_perf(),
        section_repro_perf(),
    ]
    with open("EXPERIMENTS.md", "w") as f:
        f.write("\n".join(parts))
    print("EXPERIMENTS.md written",
          f"({sum(len(p) for p in parts)} chars)")


if __name__ == "__main__":
    main()
