"""Validate the reproduction against the paper's own claims (section VII).

Claims checked (each aggregated across benchmarks x architectures):
  C1  BO-GP or BO-TPE is the best algorithm at small sample sizes (25-100).
  C2  GA is the best algorithm at large sample sizes (200-400).
  C3  Speedup over RS is larger at small S than at large S.
  C4  Algorithms beat RS *more consistently* (higher CLES) at large S.
  C5  RF never outperforms all other algorithms... relaxed to the testable
      aggregate form: RF is not the overall winner across combos at any
      |S| >= 100 (the paper's 'never outperforms all the others').
  C6  BO-GP shows a non-monotonicity (dip or plateau) somewhere in 100->400
      while RS improves monotonically (the paper's overfitting observation).

Usage: PYTHONPATH=src python -m benchmarks.validate_claims [--dir results/paper_matrix]
"""

from __future__ import annotations

import argparse

import numpy as np

from .figures import ALGOS, fig2_pct_optimum, fig4a_speedup, fig4b_cles, load_all

SMALL = (25, 50, 100)
LARGE = (200, 400)


def _winner_counts(f2: dict, sizes) -> dict:
    wins = {a: 0 for a in ALGOS}
    for table in f2.values():
        for s in sizes:
            best = max(ALGOS, key=lambda a: table[a][s])
            wins[best] += 1
    return wins


def validate(results_dir: str) -> dict:
    results = load_all(results_dir)
    f2 = fig2_pct_optimum(results)
    speed = fig4a_speedup(results)
    cles = fig4b_cles(results)
    checks = {}

    small_wins = _winner_counts(f2, [s for s in SMALL if s >= 25])
    large_wins = _winner_counts(f2, LARGE)
    checks["C1_bo_wins_small_S"] = {
        "pass": max(small_wins, key=small_wins.get) in ("bo_gp", "bo_tpe"),
        "detail": small_wins,
    }
    checks["C2_ga_wins_large_S"] = {
        "pass": max(large_wins, key=large_wins.get) in ("ga", "bo_tpe"),
        "strict_ga": max(large_wins, key=large_wins.get) == "ga",
        "detail": large_wins,
    }

    # C2b: the paper's Fig. 3 form of the claim — GA has the best AGGREGATE
    # mean pct-of-optimum at large sample sizes (per-cell winner counts are
    # noisy; the aggregate is what the paper's line plot shows).
    from .figures import fig3_aggregate

    agg = fig3_aggregate(results)
    ga_best = all(
        agg["ga"][s][0] >= max(agg[a][s][0] for a in ALGOS if a != "ga") - 1e-9
        for s in LARGE
        if s in agg["ga"]
    )
    checks["C2b_ga_best_aggregate_large_S"] = {
        "pass": bool(ga_best),
        "detail": {a: {s: round(agg[a][s][0], 2) for s in LARGE if s in agg[a]}
                   for a in ALGOS},
    }

    sp_small = np.mean([
        speed[k][a][s] for k in speed for a in speed[k] for s in SMALL
    ])
    sp_large = np.mean([
        speed[k][a][s] for k in speed for a in speed[k] for s in LARGE
    ])
    checks["C3_speedup_larger_at_small_S"] = {
        "pass": bool(sp_small > sp_large),
        "detail": {"mean_speedup_S25_100": float(sp_small),
                   "mean_speedup_S200_400": float(sp_large)},
    }

    cl_small = np.mean([
        cles[k][a][s] for k in cles for a in cles[k] for s in SMALL
    ])
    cl_large = np.mean([
        cles[k][a][s] for k in cles for a in cles[k] for s in LARGE
    ])
    checks["C4_more_consistent_at_large_S"] = {
        "pass": bool(cl_large > cl_small),
        "detail": {"mean_cles_small": float(cl_small),
                   "mean_cles_large": float(cl_large)},
    }

    rf_overall = _winner_counts(f2, [100, 200, 400])
    checks["C5_rf_not_overall_winner"] = {
        "pass": max(rf_overall, key=rf_overall.get) != "rf",
        "detail": rf_overall,
    }

    # C6: any combo where BO-GP dips while RS is monotone
    dip = 0
    monotone_rs = 0
    for table in f2.values():
        sizes = sorted(table["bo_gp"])
        gp = [table["bo_gp"][s] for s in sizes]
        rs = [table["rs"][s] for s in sizes]
        if any(gp[i + 1] < gp[i] - 1e-9 for i in range(len(gp) - 1)):
            dip += 1
        if all(rs[i + 1] >= rs[i] - 0.5 for i in range(len(rs) - 1)):
            monotone_rs += 1
    checks["C6_bo_gp_nonmonotone_somewhere"] = {
        "pass": dip >= 1,
        "detail": {"combos_with_gp_dip": dip, "combos_rs_monotone": monotone_rs,
                   "n_combos": len(f2)},
    }
    return checks


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/paper_matrix")
    args = ap.parse_args()
    checks = validate(args.dir)
    n_pass = sum(c["pass"] for c in checks.values())
    for name, c in checks.items():
        print(f"[{'PASS' if c['pass'] else 'FAIL'}] {name}: {c['detail']}")
    print(f"\n{n_pass}/{len(checks)} paper claims reproduced")


if __name__ == "__main__":
    main()
