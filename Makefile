# Tier-1 verification (see ROADMAP.md): the full test suite must collect and
# pass with or without the optional dev deps (hypothesis/scipy tests skip
# themselves when absent).
PYTHON ?= python

.PHONY: test test-fast bench lint staticcheck install-dev smoke-pallas smoke-matrix smoke-device smoke-serve docs-check report

test:
	PYTHONPATH=src $(PYTHON) -m pytest -x -q

# tier-2: the real-measurement path end-to-end — tunes the add kernel with a
# tiny budget through BACKENDS["pallas"] (interpret mode on CPU); exits
# nonzero if the tuned config did not actually run
smoke-pallas:
	PYTHONPATH=src $(PYTHON) examples/tune_kernel_interpret.py

# tier-2: a small paper matrix through the work-unit executor layer — first
# pass fans units across 2 worker processes, second pass (--force, same
# store) must resume entirely from the unit journal and then render the
# analysis REPORT.md (tables + figures + claim verdicts, uploaded as a CI
# artifact).  A third pass re-runs the same matrix with --telemetry
# --progress into a fresh store: telemetry is a pure observability knob, so
# the traced store's measurement values must be identical to the untraced
# one, and the merged trace must drive summarize + Chrome export
# (docs/telemetry.md).  A fourth pass re-runs under --scheduler static:
# the scheduler is a pure speed knob, so its store must be byte-identical
# to the (default) stealing passes, whose trace must carry the steal
# counters.  Finally, two serial pallas runs against FRESH stores sharing
# one --compile-cache dir: the cold pass populates it, and the warm pass —
# a cold process re-measuring everything — must report compiles == 0
smoke-matrix:
	rm -rf results/smoke_matrix results/smoke_matrix_tel \
	  results/smoke_matrix_static results/smoke_cc_cold results/smoke_cc_warm \
	  results/smoke_cc_cache
	PYTHONPATH=src $(PYTHON) -m benchmarks.paper_matrix --design scaled --budget 100 \
	  --bench add --chip v5e --algos rs,ga --out results/smoke_matrix \
	  --executor process --max-workers 2 --resume
	PYTHONPATH=src $(PYTHON) -m benchmarks.paper_matrix --design scaled --budget 100 \
	  --bench add --chip v5e --algos rs,ga --out results/smoke_matrix \
	  --executor process --max-workers 2 --resume --force --report
	test -f results/smoke_matrix/REPORT.md
	PYTHONPATH=src $(PYTHON) -m benchmarks.paper_matrix --design scaled --budget 100 \
	  --bench add --chip v5e --algos rs,ga --out results/smoke_matrix_tel \
	  --executor process --max-workers 2 --resume --telemetry --progress
	$(PYTHON) tools/compare_stores.py \
	  results/smoke_matrix/add_v5e_cache.json \
	  results/smoke_matrix_tel/add_v5e_cache.json
	test -f results/smoke_matrix_tel/trace.jsonl
	PYTHONPATH=src $(PYTHON) -m repro.telemetry summarize results/smoke_matrix_tel
	PYTHONPATH=src $(PYTHON) -m repro.telemetry export results/smoke_matrix_tel
	test -f results/smoke_matrix_tel/trace_chrome.json
	PYTHONPATH=src $(PYTHON) -m benchmarks.paper_matrix --design scaled --budget 100 \
	  --bench add --chip v5e --algos rs,ga --out results/smoke_matrix_static \
	  --executor process --max-workers 2 --scheduler static --resume
	$(PYTHON) tools/compare_stores.py \
	  results/smoke_matrix/add_v5e_cache.json \
	  results/smoke_matrix_static/add_v5e_cache.json
	$(PYTHON) tools/assert_counters.py results/smoke_matrix_tel \
	  "units_completed>0" --plan scheduler=steal
	PYTHONPATH=src $(PYTHON) -m benchmarks.paper_matrix --design smoke \
	  --backend pallas --bench add --algos rs --out results/smoke_cc_cold \
	  --compile-cache results/smoke_cc_cache --telemetry
	$(PYTHON) tools/assert_counters.py results/smoke_cc_cold \
	  "compiles>0" "pcache.stores>0"
	PYTHONPATH=src $(PYTHON) -m benchmarks.paper_matrix --design smoke \
	  --backend pallas --bench add --algos rs --out results/smoke_cc_warm \
	  --compile-cache results/smoke_cc_cache --telemetry
	$(PYTHON) tools/assert_counters.py results/smoke_cc_warm \
	  "compiles==0" "pcache.hits>0"

# tier-2: the device executor on a host faked to 4 chips
# (XLA_FLAGS=--xla_force_host_platform_device_count=4) — the merged store's
# measurement values must be byte-identical to a serial run of the same
# spec, and the device run renders the analysis REPORT.md (CI artifact)
smoke-device:
	rm -rf results/smoke_device
	PYTHONPATH=src $(PYTHON) -m benchmarks.paper_matrix --design scaled --budget 100 \
	  --bench add --chip v5e --algos rs,ga --out results/smoke_device/serial
	XLA_FLAGS=--xla_force_host_platform_device_count=4 PYTHONPATH=src \
	  $(PYTHON) -m benchmarks.paper_matrix --design scaled --budget 100 \
	  --bench add --chip v5e --algos rs,ga --out results/smoke_device/device \
	  --executor device --max-workers 4 --resume --report
	$(PYTHON) tools/compare_stores.py \
	  results/smoke_device/serial/add_v5e_cache.json \
	  results/smoke_device/device/add_v5e_cache.json
	test -f results/smoke_device/device/REPORT.md

# tier-2: tuning-as-a-service end to end (docs/serving.md) — a small matrix
# populates a serve dir's winners index (--serve-dir), a cold exact-geometry
# query must hit in under 10ms, then the full miss -> enqueue -> fleet
# worker -> collect -> hit loop runs against the same dir; the collected
# store must be byte-identical to a serial replay of the job, and the serve
# dir's trace (CI artifact) must carry the serve.* / fleet.* counters
smoke-serve:
	rm -rf results/smoke_serve results/smoke_serve_matrix
	PYTHONPATH=src $(PYTHON) -m benchmarks.paper_matrix --design scaled --budget 100 \
	  --bench add --chip v5e --algos rs,ga --out results/smoke_serve_matrix \
	  --serve-dir results/smoke_serve
	PYTHONPATH=src $(PYTHON) -m repro.serving query --dir results/smoke_serve \
	  --kernel add --x 8192 --y 8192 --device v5e --expect hit --max-ms 10 \
	  --telemetry
	PYTHONPATH=src $(PYTHON) -m repro.serving query --dir results/smoke_serve \
	  --kernel add --x 4096 --y 4096 --device v5e --expect nearest --telemetry
	PYTHONPATH=src $(PYTHON) -m repro.serving query --dir results/smoke_serve \
	  --kernel harris --x 8192 --y 8192 --device v5e --enqueue --expect miss \
	  --telemetry
	PYTHONPATH=src $(PYTHON) -m repro.serving worker --dir results/smoke_serve \
	  --max-jobs 1 --telemetry
	PYTHONPATH=src $(PYTHON) -m repro.serving collect --dir results/smoke_serve \
	  --telemetry
	PYTHONPATH=src $(PYTHON) -m repro.serving query --dir results/smoke_serve \
	  --kernel harris --x 8192 --y 8192 --device v5e --expect hit --max-ms 10 \
	  --telemetry
	PYTHONPATH=src $(PYTHON) -m repro.serving replay --dir results/smoke_serve \
	  --job $$(PYTHONPATH=src $(PYTHON) -m repro.serving jobs \
	    --dir results/smoke_serve | \
	    $(PYTHON) -c 'import json,sys; print(json.loads(sys.stdin.readline())["id"])') \
	  --out results/smoke_serve/serial.json
	$(PYTHON) tools/compare_stores.py results/smoke_serve/store.sqlite \
	  results/smoke_serve/serial.json
	$(PYTHON) tools/assert_counters.py results/smoke_serve \
	  "serve.hits>0" "serve.misses>0" "serve.enqueued>0" \
	  "fleet.units_run>0" "fleet.jobs_completed>0" "fleet.jobs_collected>0"

# render REPORT.md from any results directory: make report DIR=results/matrix_100
report:
	PYTHONPATH=src $(PYTHON) -m repro.analysis $(DIR)

# tier-2: extract and execute every runnable python snippet in docs/*.md
# (see tools/docs_check.py for the skip-marker contract)
docs-check:
	$(PYTHON) tools/docs_check.py docs

lint:
	ruff check src tests benchmarks examples tools

# tier-1: the determinism/provenance/registry static gate (docs/static_analysis.md)
# — AST + registry pass over src, then the spec-level pre-flight on the
# full paper matrix
staticcheck:
	PYTHONPATH=src $(PYTHON) -m repro.staticcheck src
	PYTHONPATH=src $(PYTHON) -m repro.staticcheck --preflight-paper

test-fast:
	PYTHONPATH=src $(PYTHON) -m pytest -x -q tests/test_space.py tests/test_searchers.py tests/test_costmodel.py tests/test_stats.py tests/test_surrogates.py

bench:
	PYTHONPATH=src $(PYTHON) benchmarks/run.py --budget 100

install-dev:
	pip install -r requirements-dev.txt
