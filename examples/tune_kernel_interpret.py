"""Tune a REAL Pallas kernel by wall-clock measurement — via the facade.

Runs the actual ``pl.pallas_call`` add kernel in interpret mode on small
images and lets the GA pick block geometry by measured time — the paper's
loop with a real measurement function (DESIGN.md 2.2 backend 2).  The
measurement chain is declared through the ``BACKENDS`` registry: a
``"cached"`` backend (one measurement per distinct config, per the paper)
wrapping a ``"timing"`` backend around the kernel runner.  Interpret mode
timings reflect Python-level grid overhead rather than TPU behaviour, so
this example is about exercising the full real-measurement path, not about
the specific winner.

Specs whose backend kwargs hold live callables work in-process but cannot
be serialized or sharded — name-only backends (``"costmodel"``) can.

    PYTHONPATH=src python examples/tune_kernel_interpret.py
"""

import numpy as np
import jax.numpy as jnp

import repro
from repro.core import Param, SearchSpace, TuningSpec
from repro.kernels import add

X, Y = 256, 512
BUDGET = 12


def main() -> None:
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.normal(size=(X, Y)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(X, Y)), jnp.float32)

    # small space: interpret mode is slow, keep the sweep tight
    space = SearchSpace(
        [
            Param.int_range("t_x", 1, 4),
            Param.int_range("t_y", 1, 4),
            Param.int_range("t_z", 1, 4),
            Param.int_range("w_x", 1, 2),
            Param.int_range("w_y", 1, 2),
            Param.int_range("w_z", 1, 2),
        ]
    )

    def run_kernel(cfg):
        np.asarray(add(a, b, cfg))  # block until done

    spec = TuningSpec(
        kernel="add_interpret",
        searcher="ga",
        backend="cached",
        backend_kwargs={
            "inner": "timing",
            "inner_kwargs": {"runner": run_kernel, "warmup": 1},
        },
        space=space,
        budget=BUDGET,
        final_repeats=5,
        seed=0,
    )
    r = repro.tune(spec)
    print(f"GA best config after {r.n_samples} real kernel timings: {r.best_config}")
    print(f"measured {r.best_value*1e3:.2f} ms per call (interpret mode)")
    print(f"final config re-measured 5x (paper protocol): {r.final_value*1e3:.2f} ms")


if __name__ == "__main__":
    main()
