"""Tune a REAL Pallas kernel by measured wall-clock — `backend="pallas"`.

Runs the actual ``pl.pallas_call`` add kernel (interpret mode on CPU; the
same spec lowers to Mosaic on a real TPU) and lets the GA pick block
geometry by measured time — the paper's loop with a real measurement
function.  The backend is selected *by name* from the ``BACKENDS`` registry,
so the whole run is described by a JSON-serializable spec: shard workers,
resumed runs, and remote executors rebuild the identical problem from the
spec alone (deterministic inputs, validity pre-screen, compile-once-per-
geometry cache — see docs/pallas_backend.md).

Interpret-mode timings reflect Python-level grid overhead rather than TPU
behaviour, so this example is about exercising the full real-measurement
path, not about the specific winner.  It doubles as the CI smoke for that
path (``make smoke-pallas``).

    PYTHONPATH=src python examples/tune_kernel_interpret.py
"""

import numpy as np

import repro
from repro.core import TuningSpec

X, Y = 128, 256
BUDGET = 12


def main() -> None:
    spec = TuningSpec(
        kernel="add",
        searcher="ga",
        backend="pallas",
        backend_kwargs={"x": X, "y": Y, "repeats": 3, "warmup": 1},
        budget=BUDGET,
        final_repeats=5,
        seed=0,
    )
    # the whole run is data — this is what shard workers receive
    print(f"spec: {spec.to_json()}\n")

    r = repro.tune(spec)
    print(f"GA best config after {r.n_samples} real kernel measurements: "
          f"{r.best_config}")
    print(f"measured {r.best_value*1e3:.2f} ms per call (interpret mode)")
    print(f"final config re-measured 5x (paper protocol): "
          f"{r.final_value*1e3:.2f} ms")
    if not np.isfinite(r.final_value):
        raise SystemExit("smoke failure: tuned config did not run")


if __name__ == "__main__":
    main()
