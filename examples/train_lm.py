"""End-to-end training driver with the fault-tolerant runtime.

Trains a Mamba2 LM on the synthetic Zipfian stream with checkpointing,
straggler watchdog, and crash-resume — the full production loop at
CPU-feasible scale (a ~15M-param model by default; --full trains the real
mamba2-130m config, which needs real accelerators to be pleasant).

    PYTHONPATH=src python examples/train_lm.py --steps 200
    PYTHONPATH=src python examples/train_lm.py --steps 200 --resume  # again
"""

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs import REGISTRY
from repro.data import DataConfig, make_train_batch
from repro.models import build_model, init_params, param_count
from repro.optim import AdamWConfig
from repro.runtime import RunnerConfig, TrainingRunner
from repro.train import TrainSettings, init_train_state, make_train_step


def small_config():
    base = REGISTRY["mamba2-130m"]
    return dataclasses.replace(
        base, n_layers=6, d_model=256, vocab=8192,
        ssm=dataclasses.replace(base.ssm, d_state=32, chunk=64),
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--full", action="store_true", help="real mamba2-130m config")
    ap.add_argument("--ckpt", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = REGISTRY["mamba2-130m"] if args.full else small_config()
    model = build_model(cfg)
    n = param_count(model.spec())
    print(f"arch={cfg.name} params={n/1e6:.1f}M steps={args.steps} "
          f"batch={args.batch} seq={args.seq}")

    params = init_params(model.spec(), jax.random.PRNGKey(0))
    state = init_train_state(model, params)
    step_fn = jax.jit(make_train_step(
        model,
        TrainSettings(remat="none",
                      optimizer=AdamWConfig(lr=1e-3, warmup_steps=20)),
    ))
    dc = DataConfig(seed=0)
    make_batch = lambda s: make_train_batch(dc, cfg, args.seq, args.batch, s)

    runner = TrainingRunner(
        RunnerConfig(ckpt_dir=args.ckpt, ckpt_every=50), step_fn, make_batch
    )
    t0 = time.time()
    state, report = runner.run(state, n_steps=args.steps)
    dt = time.time() - t0
    tok_s = report.steps_run * args.batch * args.seq / max(dt, 1e-9)
    print(f"\nresumed from: {report.restored_from}")
    print(f"steps run: {report.steps_run} in {dt:.0f}s ({tok_s:.0f} tok/s)")
    if report.losses:
        k = max(1, len(report.losses) // 10)
        first = float(np.mean(report.losses[:k]))
        last = float(np.mean(report.losses[-k:]))
        print(f"loss: {first:.3f} -> {last:.3f}")
    print(f"stragglers flagged: {len(report.stragglers)}")


if __name__ == "__main__":
    main()
