import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Beyond-paper: autotune the DISTRIBUTED-TRAINING config with the paper's
search algorithms.

The measurement function is the compiled dry-run's dominant roofline term
(repro.launch.roofline) — i.e. the paper's empirical-search loop pointed at
a production objective: which remat policy / sequence-parallelism setting /
FSDP axis / microbatching minimizes the modelled step time of yi-34b
train_4k on the 256-chip mesh.  Each sample costs a real XLA lower+compile
(~30-60 s on this CPU), so the budget is small; BO-TPE is the right tool at
tiny budgets — exactly the paper's S=25 regime conclusion.

    PYTHONPATH=src python examples/tune_sharding.py [--budget 6]
"""

import argparse
import time

import jax

from repro.configs import REGISTRY, SHAPES
from repro.core import CachedMeasurement, CallableMeasurement, Param, SearchSpace, make_searcher
from repro.launch.hlo_analysis import collective_stats, dot_flops
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import HBM_BW, ICI_BW, PEAK_FLOPS, memory_bytes
from repro.sharding.rules import ShardingRules
from repro.train.step import TrainSettings, make_train_step


def step_time_model(arch_name: str, shape_name: str, cfg: dict) -> float:
    """Lower + compile with the candidate config; return max roofline term."""
    arch, shape = REGISTRY[arch_name], SHAPES[shape_name]
    mesh = make_production_mesh()
    rules = ShardingRules()
    if cfg["head_dim_tp"]:
        rules = rules.with_overrides(head_dim=("model",))
    if not cfg["seq_parallel"]:
        import repro.sharding.constrain as constrain_mod
        constrain_mod.constrain_residual, saved = (lambda x: x), constrain_mod.constrain_residual
    try:
        with mesh:
            settings = TrainSettings(remat=cfg["remat"], accum=cfg["accum"])
            fn, args = _build(arch, shape, mesh, rules, settings)
            compiled = fn.lower(*args).compile()
            hlo = compiled.as_text()
        coll = collective_stats(hlo)["total_bytes"] / ICI_BW
        comp = dot_flops(hlo)["flops"] / PEAK_FLOPS
        mem = memory_bytes(arch, shape) / (256 * HBM_BW)
        return max(coll, comp, mem)
    finally:
        if not cfg["seq_parallel"]:
            constrain_mod.constrain_residual = saved


def _build(arch, shape, mesh, rules, settings):
    """build_step with explicit TrainSettings (train shapes only)."""
    import jax.numpy as jnp
    import numpy as np

    from repro.launch.specs import train_batch_specs
    from repro.models import abstract_params, build_model, param_axes

    model = build_model(arch)
    spec = model.spec()
    aparams = abstract_params(spec)
    axes = param_axes(spec)
    p_shard = rules.tree_shardings(axes, aparams, mesh)
    step = make_train_step(model, settings, grad_shardings=p_shard)
    fp32 = lambda t: jax.tree_util.tree_map(
        lambda a: jax.ShapeDtypeStruct(a.shape, jnp.float32), t
    )
    astate = {
        "params": aparams,
        "opt": {"m": fp32(aparams), "v": fp32(aparams),
                "step": jax.ShapeDtypeStruct((), jnp.int32)},
    }
    s_shard = {
        "params": p_shard,
        "opt": {"m": p_shard, "v": p_shard,
                "step": jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())},
    }
    abatch = train_batch_specs(arch, shape)
    b_shard = {
        k: rules.sharding_for(("batch",) + (None,) * (v.ndim - 1), v.shape, mesh)
        for k, v in abatch.items()
    }
    fn = jax.jit(step, in_shardings=(s_shard, b_shard), out_shardings=(s_shard, None))
    return fn, (astate, abatch)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--budget", type=int, default=6)
    ap.add_argument("--arch", default="yi-34b")
    args = ap.parse_args()

    space = SearchSpace(
        [
            Param.choice("remat", ("none", "dots", "full")),
            Param.choice("accum", (1, 2, 4)),
            Param.choice("seq_parallel", (False, True)),
            Param.choice("head_dim_tp", (False, True)),
        ]
    )

    def measure(cfg):
        t0 = time.time()
        s = step_time_model(args.arch, "train_4k", cfg)
        print(f"  cfg={cfg} -> modelled step {s:.2f}s  (compile {time.time()-t0:.0f}s)")
        return s

    # bo_tpe proposes single configs after its random init batch; the engine
    # driver still routes each batch through measure_batch, and the memoizing
    # wrapper collapses duplicate proposals before they reach a compile.
    m = CachedMeasurement(CallableMeasurement(measure))
    r = make_searcher("bo_tpe", space, seed=0).run(m, args.budget)
    print(f"\nbest distributed config for {args.arch} train_4k: {r.best_config}")
    print(f"modelled step time {r.best_value:.2f}s")


if __name__ == "__main__":
    main()
