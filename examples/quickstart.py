"""Quickstart: autotune a TPU kernel config with every paper algorithm.

Tunes the Harris-corner kernel's 6-parameter space (DESIGN.md 2.1) on the
v5e chip model with a 100-sample budget and compares the algorithms the
paper compares — then runs the statistics the paper runs (MWU + CLES).

Every search below routes through the batched ask/tell engine:
``searcher.run(measurement, budget)`` drives the searcher's proposal batches
through ``measure_batch`` (one vectorized dispatch per batch).  The
``ask_tell_demo`` shows the protocol underneath ``run`` — the form to use
when an external system (a real TPU queue, a cluster scheduler) owns the
evaluation loop.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import PAPER_ALGORITHMS, make_searcher, stats
from repro.costmodel import (
    CHIPS,
    WORKLOADS,
    CostModelMeasurement,
    executable_space,
    true_optimum,
)

BUDGET = 100
REPEATS = 20


def ask_tell_demo(space, w, chip) -> None:
    """Drive one search by hand through the ask/tell protocol."""
    searcher = make_searcher("ga", space, seed=0)
    measurement = CostModelMeasurement(w, chip, seed=0)
    searcher.start(BUDGET)
    n_batches = 0
    while not searcher.done:
        configs = searcher.ask()          # the algorithm's natural batch
        if not configs:
            break
        searcher.tell(configs, measurement.measure_batch(configs))
        n_batches += 1
    result = searcher.finish()
    print(
        f"ask/tell: {result.n_samples} samples in {n_batches} batches "
        f"({measurement.n_dispatches} measurement dispatches), "
        f"best={result.best_value*1e3:.3f} ms\n"
    )


def main() -> None:
    w, chip = WORKLOADS["harris"], CHIPS["v5e"]
    space = executable_space(w, chip)
    opt_cfg, opt = true_optimum(w, chip)
    print(f"benchmark=harris chip=v5e |S|={space.cardinality:,} budget={BUDGET}")
    print(f"true optimum: {opt*1e3:.3f} ms @ {opt_cfg}\n")

    ask_tell_demo(space, w, chip)

    finals = {}
    for algo in PAPER_ALGORITHMS:
        runs = []
        for seed in range(REPEATS):
            m = CostModelMeasurement(w, chip, seed=seed)
            r = make_searcher(algo, space, seed=seed).run(m, BUDGET)
            runs.append(m.measure_final(r.best_config, repeats=10))
        finals[algo] = np.array(runs)
        print(
            f"{algo:7s} median={np.median(runs)*1e3:7.3f} ms "
            f"({opt/np.median(runs)*100:5.1f}% of optimum)"
        )

    print("\nvs Random Search (MWU alpha=0.01, CLES):")
    for algo in PAPER_ALGORITHMS[1:]:
        cmp = stats.compare_algorithms(finals[algo], finals["rs"])
        print(
            f"{algo:7s} speedup={cmp['speedup_a_over_b']:.3f}x "
            f"P(beats RS)={cmp['cles_a_beats_b']:.2f} "
            f"p={cmp['mwu_p']:.4f} significant={cmp['significant']}"
        )


if __name__ == "__main__":
    main()
