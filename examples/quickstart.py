"""Quickstart: autotune a TPU kernel config with every paper algorithm —
through the one-call public API.

Tunes the Harris-corner kernel's 6-parameter space (DESIGN.md 2.1) on the
v5e chip model with a 100-sample budget and compares the algorithms the
paper compares — then runs the statistics the paper runs (MWU + CLES).

Everything goes through the declarative facade: a :class:`TuningSpec` names
the kernel, the searcher, and the measurement backend (resolved from the
``BACKENDS`` registry), and ``repro.tune(spec)`` drives the batched
ask/tell engine and the paper's final re-measurement.  The
``ask_tell_demo`` shows the protocol underneath — the form to use when an
external system (a real TPU queue, a cluster scheduler) owns the
evaluation loop.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

import repro
from repro.core import PAPER_ALGORITHMS, TuningSession, TuningSpec, make_searcher, stats

BUDGET = 100
REPEATS = 20

SPEC = TuningSpec(
    kernel="harris",
    backend="costmodel",
    backend_kwargs={"chip": "v5e"},
    budget=BUDGET,
)


def ask_tell_demo() -> None:
    """Drive one search by hand through the ask/tell protocol."""
    session = TuningSession(SPEC)                 # resolves space + backend
    searcher = make_searcher("ga", session.space, seed=0)
    measurement = repro.make_measurement("costmodel", kernel="harris", chip="v5e", seed=0)
    searcher.start(BUDGET)
    n_batches = 0
    while not searcher.done:
        configs = searcher.ask()          # the algorithm's natural batch
        if not configs:
            break
        searcher.tell(configs, measurement.measure_batch(configs))
        n_batches += 1
    result = searcher.finish()
    print(
        f"ask/tell: {result.n_samples} samples in {n_batches} batches "
        f"({measurement.n_dispatches} measurement dispatches), "
        f"best={result.best_value*1e3:.3f} ms\n"
    )


def main() -> None:
    session = TuningSession(SPEC)
    opt_cfg, opt = repro.BACKENDS["costmodel"].true_optimum(kernel="harris", chip="v5e")
    print(f"benchmark=harris chip=v5e |S|={session.space.cardinality:,} budget={BUDGET}")
    print(f"true optimum: {opt*1e3:.3f} ms @ {opt_cfg}\n")

    ask_tell_demo()

    finals = {}
    for algo in PAPER_ALGORITHMS:
        runs = []
        for seed in range(REPEATS):
            r = repro.tune(SPEC.replace(searcher=algo, seed=seed))
            runs.append(r.final_value)     # median-of-10 re-measurement
        finals[algo] = np.array(runs)
        print(
            f"{algo:7s} median={np.median(runs)*1e3:7.3f} ms "
            f"({opt/np.median(runs)*100:5.1f}% of optimum)"
        )

    print("\nvs Random Search (MWU alpha=0.01, CLES):")
    for algo in PAPER_ALGORITHMS[1:]:
        cmp = stats.compare_algorithms(finals[algo], finals["rs"])
        print(
            f"{algo:7s} speedup={cmp['speedup_a_over_b']:.3f}x "
            f"P(beats RS)={cmp['cles_a_beats_b']:.2f} "
            f"p={cmp['mwu_p']:.4f} significant={cmp['significant']}"
        )

    # same declarative API, REAL measurement: swap the backend name and the
    # engine compiles and times the actual pl.pallas_call kernel (interpret
    # mode on CPU, Mosaic on TPU) — see docs/pallas_backend.md
    r = repro.tune(
        SPEC.replace(backend="pallas",
                     backend_kwargs={"repeats": 3}, budget=10, final_repeats=3)
    )
    print(f"\nreal-measurement harris (backend='pallas', interpret mode): "
          f"{r.final_value*1e3:.2f} ms @ {r.best_config}")


if __name__ == "__main__":
    main()
