"""Model zoo correctness.

The heavy hitters:
  * SSD chunked algorithm vs the naive per-token recurrence oracle,
  * MoE sort-based dispatch vs a per-token dense oracle (ample capacity),
  * MLA absorbed decode vs standard prefill (same math, two dataflows),
  * prefill/decode equivalence for every family: feeding tokens one at a
    time through decode_step must reproduce forward()'s last-position
    logits (validates KV caches, conv/ssm states, position handling).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import REGISTRY
from repro.core.space import Config  # noqa: F401  (import sanity)
from repro.models import build_model, init_params
from repro.models.mamba2 import SSMDims, mamba2_decode, mamba2_forward, ssd_chunked
from repro.models.moe import MoEDims, moe_forward

RNG = jax.random.PRNGKey(42)


# ---------------------------------------------------------------- SSD


def naive_ssm_recurrence(x, dt, a_log, b, c):
    """Per-token state-space recurrence oracle (fp64 for stability)."""
    bs, s, h, p = x.shape
    n = b.shape[-1]
    A = -np.exp(np.asarray(a_log, np.float64))
    xb = np.asarray(x, np.float64)
    dtb = np.asarray(dt, np.float64)
    bb = np.asarray(b, np.float64)
    cb = np.asarray(c, np.float64)
    state = np.zeros((bs, h, p, n))
    out = np.zeros_like(xb)
    for t in range(s):
        decay = np.exp(dtb[:, t] * A[None, :])                  # (B, H)
        upd = np.einsum("bh,bhp,bn->bhpn", dtb[:, t], xb[:, t], bb[:, t, 0])
        state = state * decay[..., None, None] + upd
        out[:, t] = np.einsum("bhpn,bn->bhp", state, cb[:, t, 0])
    return out


@pytest.mark.parametrize("seq,chunk", [(64, 16), (96, 32), (128, 128)])
def test_ssd_chunked_matches_naive_recurrence(seq, chunk):
    rng = np.random.default_rng(0)
    bs, h, p, n = 2, 3, 8, 4
    x = jnp.asarray(rng.normal(size=(bs, seq, h, p)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, size=(bs, seq, h)), jnp.float32)
    a_log = jnp.asarray(rng.uniform(-1, 0.5, size=(h,)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(bs, seq, 1, n)), jnp.float32)
    c = jnp.asarray(rng.normal(size=(bs, seq, 1, n)), jnp.float32)
    got = np.asarray(ssd_chunked(x, dt, a_log, b, c, chunk), np.float64)
    ref = naive_ssm_recurrence(x, dt, a_log, b, c)
    np.testing.assert_allclose(got, ref, rtol=2e-3, atol=2e-3)


def test_mamba2_decode_matches_forward():
    """Run the block over a sequence via per-token decode and compare with
    the chunked forward."""
    dims = SSMDims(d_model=32, d_state=8, d_conv=4, expand=2, head_dim=8,
                   n_groups=1, chunk=16)
    from repro.models.param import init_params as ip
    from repro.models.ssm import mamba_layer_spec
    spec = mamba_layer_spec(1, dims)
    params = ip(spec, RNG)
    lp = jax.tree_util.tree_map(lambda a: a[0], params)  # un-stack layer 0
    lp = dict(lp)
    lp.pop("pre_norm")
    rng = np.random.default_rng(1)
    bs, s = 2, 32
    x = jnp.asarray(rng.normal(size=(bs, s, 32)) * 0.3, jnp.float32)
    full = mamba2_forward(x, lp, dims)
    cache = {
        "conv": jnp.zeros((bs, dims.d_conv - 1, dims.conv_dim), jnp.float32),
        "ssm": jnp.zeros((bs, dims.n_heads, dims.head_dim, dims.d_state),
                         jnp.float32),
    }
    outs = []
    for t in range(s):
        o, cache = mamba2_decode(x[:, t:t + 1], lp, dims, cache)
        outs.append(o)
    step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(step), np.asarray(full),
                               rtol=5e-3, atol=5e-3)


# ---------------------------------------------------------------- MoE


def naive_moe(x, params, dims):
    """Per-token oracle: route, run chosen experts densely, combine."""
    t, d = x.shape
    logits = np.asarray(x, np.float64) @ np.asarray(params["router"], np.float64)
    probs = np.exp(logits - logits.max(-1, keepdims=True))
    probs /= probs.sum(-1, keepdims=True)
    out = np.zeros((t, d))
    order = np.argsort(-probs, axis=-1, kind="stable")[:, : dims.top_k]
    for i in range(t):
        gates = probs[i, order[i]]
        gates = gates / gates.sum()
        for gate, e in zip(gates, order[i], strict=True):
            h = np.asarray(x[i], np.float64)
            g = h @ np.asarray(params["gate"][e], np.float64)
            u = h @ np.asarray(params["up"][e], np.float64)
            silu = g / (1.0 + np.exp(-g))
            out[i] += gate * ((silu * u) @ np.asarray(params["down"][e], np.float64))
    return out


def test_moe_matches_dense_oracle():
    rng = np.random.default_rng(0)
    t, d, f, e, k = 32, 16, 24, 4, 2
    dims = MoEDims(n_experts=e, top_k=k, d_model=d, d_ff=f,
                   capacity_factor=8.0, groups=1)  # ample capacity: no drops
    params = {
        "router": jnp.asarray(rng.normal(size=(d, e)) * 0.5, jnp.float32),
        "gate": jnp.asarray(rng.normal(size=(e, d, f)) * 0.2, jnp.float32),
        "up": jnp.asarray(rng.normal(size=(e, d, f)) * 0.2, jnp.float32),
        "down": jnp.asarray(rng.normal(size=(e, f, d)) * 0.2, jnp.float32),
    }
    x = jnp.asarray(rng.normal(size=(1, t, d)), jnp.float32)
    out, aux = moe_forward(x, params, dims)
    ref = naive_moe(np.asarray(x[0]), params, dims)
    np.testing.assert_allclose(np.asarray(out[0]), ref, rtol=2e-3, atol=2e-3)
    assert np.isfinite(float(aux))


def test_moe_capacity_drops_are_partial_not_corrupt():
    """With capacity_factor << 1 many tokens drop, but surviving outputs
    must stay finite and bounded."""
    rng = np.random.default_rng(1)
    t, d, f, e, k = 64, 8, 8, 4, 2
    dims = MoEDims(n_experts=e, top_k=k, d_model=d, d_ff=f,
                   capacity_factor=0.25, groups=1)
    params = {
        "router": jnp.asarray(rng.normal(size=(d, e)), jnp.float32),
        "gate": jnp.asarray(rng.normal(size=(e, d, f)) * 0.2, jnp.float32),
        "up": jnp.asarray(rng.normal(size=(e, d, f)) * 0.2, jnp.float32),
        "down": jnp.asarray(rng.normal(size=(e, f, d)) * 0.2, jnp.float32),
    }
    x = jnp.asarray(rng.normal(size=(2, t // 2, d)), jnp.float32)
    out, _ = moe_forward(x, params, dims)
    assert np.isfinite(np.asarray(out)).all()


def test_moe_grouping_invariance():
    """groups=1 vs groups=2 changes dispatch locality, not results
    (ample capacity)."""
    rng = np.random.default_rng(2)
    t, d, f, e, k = 32, 8, 8, 4, 2
    params = {
        "router": jnp.asarray(rng.normal(size=(d, e)), jnp.float32),
        "gate": jnp.asarray(rng.normal(size=(e, d, f)) * 0.2, jnp.float32),
        "up": jnp.asarray(rng.normal(size=(e, d, f)) * 0.2, jnp.float32),
        "down": jnp.asarray(rng.normal(size=(e, f, d)) * 0.2, jnp.float32),
    }
    x = jnp.asarray(rng.normal(size=(2, t, d)), jnp.float32)
    d1 = MoEDims(n_experts=e, top_k=k, d_model=d, d_ff=f, capacity_factor=8.0, groups=1)
    d2 = MoEDims(n_experts=e, top_k=k, d_model=d, d_ff=f, capacity_factor=8.0, groups=2)
    o1, _ = moe_forward(x, params, d1)
    o2, _ = moe_forward(x, params, d2)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=1e-4, atol=1e-5)


# ------------------------------------------------- prefill/decode equivalence


EQUIV_ARCHS = ["yi-34b", "granite-34b", "olmoe-1b-7b", "deepseek-v2-236b",
               "mamba2-130m", "zamba2-1.2b", "chameleon-34b"]


@pytest.mark.parametrize("name", EQUIV_ARCHS)
def test_prefill_decode_equivalence(name):
    cfg = REGISTRY[name].reduced()
    model = build_model(cfg)
    params = init_params(model.spec(), RNG)
    rng = np.random.default_rng(3)
    bs, s = 2, 12
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (bs, s)), jnp.int32)
    logits_full, _ = model.forward(params, tokens)

    cache = model.init_cache(bs, 16)
    decode = jax.jit(model.decode_step)
    last = None
    for t in range(s):
        cache_len = jnp.full((bs,), t, jnp.int32)
        last, cache = decode(params, cache, cache_len, tokens[:, t:t + 1])
    got = np.asarray(last[:, 0], np.float32)
    want = np.asarray(logits_full[:, -1], np.float32)
    # bf16 compute accumulated over steps: compare top-1 and correlation
    assert (got.argmax(-1) == want.argmax(-1)).mean() >= 0.99
    corr = np.corrcoef(got.ravel(), want.ravel())[0, 1]
    assert corr > 0.99, corr


def test_whisper_prefill_decode_equivalence():
    cfg = REGISTRY["whisper-medium"].reduced()
    model = build_model(cfg)
    params = init_params(model.spec(), RNG)
    rng = np.random.default_rng(4)
    bs, frames, t = 2, 32, 8
    src = jnp.asarray(rng.normal(size=(bs, frames, cfg.d_model)) * 0.1, jnp.float32)
    dec = jnp.asarray(rng.integers(0, cfg.vocab, (bs, t)), jnp.int32)
    enc_out = model.encode(params, src)
    logits_full = model.decode_train(params, enc_out, dec)
    cache = model.init_cache(params, enc_out, bs)
    last = None
    for i in range(t):
        cache_len = jnp.full((bs,), i, jnp.int32)
        last, cache = model.decode_step(params, cache, cache_len, dec[:, i:i + 1])
    got = np.asarray(last[:, 0], np.float32)
    want = np.asarray(logits_full[:, -1], np.float32)
    assert (got.argmax(-1) == want.argmax(-1)).all()
    assert np.corrcoef(got.ravel(), want.ravel())[0, 1] > 0.99


# ------------------------------------------------- smoke: all 10 archs


@pytest.mark.parametrize("name", sorted(REGISTRY))
def test_reduced_arch_forward_and_decode(name):
    cfg = REGISTRY[name].reduced()
    model = build_model(cfg)
    params = init_params(model.spec(), RNG)
    rng = np.random.default_rng(5)
    if cfg.family == "encdec":
        batch = {
            "src_embeds": jnp.asarray(rng.normal(size=(2, 64, cfg.d_model)) * 0.1,
                                      jnp.float32),
            "dec_tokens": jnp.asarray(rng.integers(0, cfg.vocab, (2, 16)), jnp.int32),
        }
        logits, aux = model.forward(params, batch)
        assert logits.shape == (2, 16, cfg.vocab)
    else:
        tokens = jnp.asarray(rng.integers(0, cfg.vocab, (2, 64)), jnp.int32)
        logits, aux = model.forward(params, tokens)
        assert logits.shape == (2, 64, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("remat", ["none", "dots", "full"])
def test_remat_policies_agree(remat):
    cfg = REGISTRY["yi-34b"].reduced()
    model = build_model(cfg)
    params = init_params(model.spec(), RNG)
    tokens = jnp.asarray(np.arange(32).reshape(2, 16) % cfg.vocab, jnp.int32)
    base, _ = model.forward(params, tokens, remat="none")
    out, _ = model.forward(params, tokens, remat=remat)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(base, np.float32), rtol=1e-5, atol=1e-5)
