"""Tests for ``repro.staticcheck`` — the static gate on the paper-scale run.

The fixture corpus under ``tests/fixtures/staticcheck/`` pins golden output:
every rule family has a *_bad fixture proving the violation is caught, a
*_suppressed fixture proving ``# repro: allow[RULE]`` is honored, and (for
DET/PROV) a *_good fixture proving the compliant spelling passes.  The PROV
regression test re-introduces the ``pipeline_workers``-in-cache-key bug on
a copy of ``api.py`` and requires the checker to fail.
"""

from __future__ import annotations

import os
import shutil
import subprocess
import sys

import pytest

from repro.core.api import TuningSpec
from repro.core.experiment import ExperimentDesign
from repro.core.space import Param, SearchSpace
from repro.staticcheck import Finding, check_paths, format_finding
from repro.staticcheck.catalog import RULES, resolve_select
from repro.staticcheck.findings import apply_suppressions, suppressions_for
from repro.staticcheck.spec_rules import (
    check_cache_key_namespaces,
    preflight_design,
    preflight_spec,
)

FIXTURES = os.path.join(os.path.dirname(__file__), "fixtures", "staticcheck")
REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


def fixture(*parts: str) -> str:
    return os.path.join(FIXTURES, *parts)


def rules_in(findings: list[Finding]) -> set[str]:
    return {f.rule for f in findings}


# ------------------------------------------------------------------ corpus


def test_golden_output_over_corpus():
    """The full fixture corpus produces exactly the pinned findings."""
    findings = check_paths([FIXTURES], registry=False)
    got = [
        format_finding(f).replace(FIXTURES + os.sep, "") for f in findings
    ]
    with open(fixture("expected_bad.txt"), encoding="utf-8") as fh:
        expected = fh.read().splitlines()
    assert got == expected


@pytest.mark.parametrize(
    "family,bad,suppressed,expected_rules",
    [
        ("DET", "det_bad.py", "det_suppressed.py",
         {"DET001", "DET002", "DET003"}),
        ("LIB", "lib_bad.py", "lib_suppressed.py", {"LIB001"}),
        ("SER", "ser_bad.py", "ser_suppressed.py", {"SER003"}),
    ],
)
def test_violation_caught_and_suppression_honored(
    family, bad, suppressed, expected_rules
):
    bad_findings = check_paths([fixture(bad)], registry=False)
    assert rules_in(bad_findings) == expected_rules
    assert all(f.path.endswith(bad) for f in bad_findings)
    assert check_paths([fixture(suppressed)], registry=False) == []


def test_prov_violation_caught_and_clean_sink_passes():
    bad = check_paths([fixture("prov_bad")], registry=False)
    assert rules_in(bad) == {"PROV001"}
    assert "pipeline_workers" in bad[0].message
    assert "injector.py" in bad[0].message  # names the injection site
    assert check_paths([fixture("prov_good")], registry=False) == []


def test_prov_suppression_honored(tmp_path):
    shutil.copy(fixture("prov_bad", "injector.py"), tmp_path / "injector.py")
    sink = open(fixture("prov_bad", "sink.py"), encoding="utf-8").read()
    sink = sink.replace(
        "def default_cache_key(self) -> str:",
        "def default_cache_key(self) -> str:  # repro: allow[PROV001]",
    )
    (tmp_path / "sink.py").write_text(sink)
    assert check_paths([str(tmp_path)], registry=False) == []


def test_det_good_fixture_is_clean():
    assert check_paths([fixture("det_good.py")], registry=False) == []


# ------------------------------------------------ the repo itself is clean


def test_repo_src_is_clean():
    """`python -m repro.staticcheck src` exits 0 (the acceptance gate)."""
    findings = check_paths([SRC], registry=True)
    errors = [f for f in findings if f.severity == "error"]
    assert errors == [], [format_finding(f) for f in errors]


def test_reintroducing_cache_key_bug_fails_prov(tmp_path):
    """Deleting the pipeline_workers filter in default_cache_key must make
    PROV001 fire — the regression the rule exists to stop."""
    api = open(
        os.path.join(SRC, "repro", "core", "api.py"), encoding="utf-8"
    ).read()
    broken = api.replace(
        'if k not in ("pipeline_workers", "compile_cache")',
        'if k not in ("never_this_knob",)',
    )
    assert broken != api, "filter moved? update this test"
    (tmp_path / "api.py").write_text(broken)
    findings = check_paths([str(tmp_path / "api.py")], registry=False)
    assert "PROV001" in rules_in(findings)


# ------------------------------------------------------------ suppressions


def test_suppression_parsing_rules_and_families():
    src = "x = 1  # repro: allow[DET001, SER]\ny = 2\n"
    allowed = suppressions_for(src)
    assert allowed == {1: frozenset({"DET001", "SER"})}
    f_exact = Finding("p.py", 1, "DET001", "m")
    f_family = Finding("p.py", 1, "SER003", "m")
    f_other = Finding("p.py", 1, "LIB001", "m")
    kept, n = apply_suppressions(
        [f_exact, f_family, f_other], {"p.py": src}
    )
    assert kept == [f_other]
    assert n == 2


def test_select_expands_families():
    assert resolve_select("DET") == frozenset({"DET001", "DET002", "DET003"})
    assert resolve_select("DET001,PROV") == frozenset({"DET001", "PROV001"})
    with pytest.raises(KeyError):
        resolve_select("NOPE")


def test_github_format_annotations():
    f = Finding("src/x.py", 12, "DET001", "msg here", col=4)
    out = format_finding(f, "github")
    assert out == "::error file=src/x.py,line=12,col=4,title=DET001::msg here"
    info = Finding("<spec>", 0, "SPEC001", "space: 8", severity="info")
    assert format_finding(info, "github").startswith("::notice file=<spec>")


# ------------------------------------------------------- registry checks


def test_registry_checks_clean_on_real_registries():
    from repro.staticcheck.reg import check_registries

    errors = [f for f in check_registries() if f.severity == "error"]
    assert errors == [], [format_finding(f) for f in errors]


def test_reg001_catches_propose_less_searcher():
    from repro.core.searchers import SEARCHERS
    from repro.core.searchers.base import Searcher
    from repro.staticcheck.reg import check_searchers

    class Hollow(Searcher):
        name = "_hollow"

    SEARCHERS["_hollow"] = Hollow
    try:
        findings = check_searchers()
        assert any(
            f.rule == "REG001" and "_propose" in f.message for f in findings
        )
    finally:
        del SEARCHERS["_hollow"]


def test_reg002_catches_broken_store():
    from repro.core.stores import STORES
    from repro.staticcheck.reg import check_executors_and_stores

    class NotAStore:
        pass

    STORES["_broken"] = NotAStore
    try:
        findings = check_executors_and_stores()
        assert any(
            f.rule == "REG002" and "_broken" in f.message for f in findings
        )
    finally:
        del STORES["_broken"]


def test_reg003_catches_incomplete_kernel_bench():
    from repro.kernels import KERNEL_BENCHES
    from repro.kernels.common import KernelBenchSpec
    from repro.staticcheck.reg import check_kernels

    KERNEL_BENCHES["_stub"] = KernelBenchSpec(name="_stub", n_inputs=1)
    try:
        findings = check_kernels()
        assert any(
            f.rule == "REG003" and "make_inputs" in f.message
            for f in findings
        )
    finally:
        del KERNEL_BENCHES["_stub"]


def test_ser002_catches_callable_default():
    from repro.core.backends import BACKENDS, Backend
    from repro.staticcheck.reg import check_backends

    def make(kernel="k", seed=0, hook=print):
        raise NotImplementedError

    BACKENDS["_lambda"] = Backend(name="_lambda", make=make)
    try:
        findings = check_backends()
        assert any(
            f.rule == "SER002" and "_lambda" in f.message for f in findings
        )
    finally:
        del BACKENDS["_lambda"]


# -------------------------------------------------------------- pre-flight


def tiny_spec(**kw) -> TuningSpec:
    space = SearchSpace([Param("a", (1, 2, 4)), Param("b", (1, 2))])
    kw.setdefault("kernel", "k")
    kw.setdefault("backend", "callable")
    kw.setdefault("space", space)
    return TuningSpec(**kw)


def test_preflight_reports_space_size():
    findings = preflight_spec(tiny_spec())
    info = [f for f in findings if f.rule == "SPEC001"]
    assert len(info) == 1 and "6 configs" in info[0].message
    assert all(f.severity != "error" for f in findings)


def test_preflight_catches_unsatisfiable_constraint():
    space = SearchSpace(
        [Param("a", (1, 2, 4)), Param("b", (1, 2))],
        constraint=lambda cfg: False,
    )
    findings = preflight_spec(tiny_spec(space=space))
    assert "SPEC002" in rules_in(findings)


def test_preflight_paper_design_seeds_collision_free():
    findings = preflight_design(
        ExperimentDesign.paper(),
        algorithms=("rs", "rf", "ga", "bo_gp", "bo_tpe"),
    )
    assert "SPEC003" not in rules_in(findings)


def test_preflight_warns_on_paper_scale_without_store():
    design = ExperimentDesign.paper()
    findings = preflight_design(design, algorithms=("rs", "rf", "ga"))
    spec4 = [f for f in findings if f.rule == "SPEC004"]
    assert len(spec4) == 1 and spec4[0].severity == "warning"


def test_preflight_flags_thin_experiment_rows():
    design = ExperimentDesign(sample_sizes=(25,), n_experiments=(5,))
    findings = preflight_design(design)
    assert "SPEC005" in rules_in(findings)


def test_cache_key_namespace_collision_detected():
    a = tiny_spec(store="json", store_path="cache.json", seed=0)
    b = tiny_spec(store="json", store_path="cache.json", seed=1)
    findings = check_cache_key_namespaces([a, b])
    assert "SPEC003" in rules_in(findings)
    # identical specs sharing a store are fine (that IS the resume path)
    assert check_cache_key_namespaces([a, a]) == []


# --------------------------------------------------------------------- CLI


def run_cli(*args: str) -> subprocess.CompletedProcess:
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    return subprocess.run(
        [sys.executable, "-m", "repro.staticcheck", *args],
        capture_output=True,
        text=True,
        cwd=REPO,
        env=env,
    )


def test_cli_exit_codes_and_formats():
    bad = run_cli(fixture("det_bad.py"), "--no-registry")
    assert bad.returncode == 1
    assert "DET001" in bad.stdout

    good = run_cli(fixture("det_good.py"), "--no-registry")
    assert good.returncode == 0
    assert "clean" in good.stdout

    gh = run_cli(
        fixture("det_bad.py"), "--no-registry", "--format", "github"
    )
    assert gh.returncode == 1
    assert "::error file=" in gh.stdout

    sel = run_cli(
        fixture("det_bad.py"), "--no-registry", "--select", "LIB"
    )
    assert sel.returncode == 0  # DET findings filtered out

    usage = run_cli()
    assert usage.returncode == 2


def test_cli_list_rules_covers_catalog():
    out = run_cli("--list-rules")
    assert out.returncode == 0
    for rule_id in RULES:
        if rule_id != "PARSE":
            assert rule_id in out.stdout
