"""DET fixture: the same violations, each explicitly allowed."""

import random
import time
from datetime import datetime

import numpy as np


def wall_clock():
    t0 = time.time()  # repro: allow[DET001]
    t1 = time.perf_counter()  # repro: allow[DET001]
    stamp = datetime.now()  # repro: allow[DET]
    return t0, t1, stamp


def unseeded():
    a = np.random.rand(3)  # repro: allow[DET002]
    b = random.random()  # repro: allow[DET002]
    return a, b


def set_order(keys: set):
    out = []
    for k in keys:  # repro: allow[DET003]
        out.append(k)
    listed = list({1, 2, 3})  # repro: allow[DET003]
    return out, listed
