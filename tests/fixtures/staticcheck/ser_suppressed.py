"""SER fixture: the same lambda, explicitly allowed (in-process only)."""


def build(tune):
    return tune(
        kernel="k",
        searcher_kwargs={"score_fn": lambda cfg: 0.0},  # repro: allow[SER003]
        backend_kwargs={"chip": "v5e"},
    )
