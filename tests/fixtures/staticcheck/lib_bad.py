"""LIB fixture: bare assert guarding runtime state."""


class Model:
    def __init__(self):
        self.fitted = None

    def predict(self, x):
        assert self.fitted is not None, "call fit first"
        return self.fitted * x
