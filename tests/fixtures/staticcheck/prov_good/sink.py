"""PROV fixture: the sink correctly filters the knob back out."""


class Spec:
    backend_kwargs: dict = {}
    kernel = "k"
    backend = "b"

    def default_cache_key(self) -> str:
        kwargs = {
            k: v
            for k, v in self.backend_kwargs.items()
            if k != "pipeline_workers"
        }
        kw = ",".join(f"{k}={v}" for k, v in sorted(kwargs.items()))
        return f"{self.kernel}/{self.backend}/{kw}"
