"""OBS fixture: the same leak, explicitly allowed on the mention line."""


class Spec:
    kernel = "k"
    trace_path = "trace.jsonl"

    def default_cache_key(self) -> str:
        return f"{self.kernel}/{self.trace_path}"  # repro: allow[OBS001]
