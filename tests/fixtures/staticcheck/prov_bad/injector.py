"""PROV fixture: the speed knob injected into backend_kwargs."""


def enable_pipeline(spec, n: int):
    return spec.replace(
        backend_kwargs={**spec.backend_kwargs, "pipeline_workers": int(n)}
    )
