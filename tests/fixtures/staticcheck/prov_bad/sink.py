"""PROV fixture: a cache-key sink that forgets to exclude the knob."""


class Spec:
    backend_kwargs: dict = {}
    kernel = "k"
    backend = "b"

    def default_cache_key(self) -> str:
        kwargs = dict(self.backend_kwargs)
        kw = ",".join(f"{k}={v}" for k, v in sorted(kwargs.items()))
        return f"{self.kernel}/{self.backend}/{kw}"
