"""OBS fixture: telemetry identifiers leaking into identity sinks.

The sinks deliberately avoid ``backend_kwargs`` so the corpus-wide PROV001
liveness (from ``prov_bad/``) cannot also fire on them — this file pins
OBS001 alone.
"""


class Spec:
    kernel = "k"
    trace_path = "trace.jsonl"

    def default_cache_key(self) -> str:
        return f"{self.kernel}/{self.trace_path}"

    def journal_namespace(self) -> str:
        mode = "telemetry"
        return f"{self.kernel}|{mode}"
