"""SER fixture: a lambda embedded in a *_kwargs dict literal."""


def build(tune):
    return tune(
        kernel="k",
        searcher_kwargs={"score_fn": lambda cfg: 0.0},
        backend_kwargs={"chip": "v5e"},
    )
