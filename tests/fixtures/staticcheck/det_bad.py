"""DET fixture: every determinism rule violated once, no suppressions."""

import random
import time
from datetime import datetime

import numpy as np


def wall_clock():
    t0 = time.time()
    t1 = time.perf_counter()
    stamp = datetime.now()
    return t0, t1, stamp


def unseeded():
    a = np.random.rand(3)
    b = random.random()
    return a, b


def set_order(keys: set):
    out = []
    for k in keys:
        out.append(k)
    listed = list({1, 2, 3})
    return out, listed
