"""LIB fixture: the same assert, explicitly allowed."""


class Model:
    def __init__(self):
        self.fitted = None

    def predict(self, x):
        assert self.fitted is not None  # repro: allow[LIB001]
        return self.fitted * x
