"""DET fixture: the compliant spellings of everything det_bad.py does."""

import numpy as np

from repro.core.clock import monotonic


def wall_clock():
    return monotonic()


def seeded():
    rng = np.random.default_rng(0)
    return rng.random(3)


def set_order(keys: set):
    out = list(sorted(keys))
    n = len(keys)          # order-insensitive reductions are fine
    return out, n, min(keys), max(keys)
