"""The repro.analysis subsystem: loading, deterministic bootstrap stats,
executor-invariant tables, three-valued claim verdicts, report generation,
and the single budget-clipping convention shared with TuningResult."""

import os

import numpy as np
import pytest

import repro
from repro import analysis
from repro.analysis import claims as aclaims
from repro.analysis import report as areport
from repro.analysis import stats as astats
from repro.analysis.records import ALGOS
from repro.core import (
    CellResult,
    ExperimentDesign,
    MatrixResults,
    TuningResult,
    TuningSpec,
)

SMOKE_SPEC = TuningSpec(
    kernel="harris",
    backend_kwargs={"chip": "v5e"},
    algorithms=("rs", "rf", "ga", "bo_gp", "bo_tpe"),
    design=ExperimentDesign.smoke(),
    seed=3,
    dataset_size=400,
)


@pytest.fixture(scope="module")
def results_dir(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("analysis") / "mat")
    repro.tune_matrix(SMOKE_SPEC, out_dir=out)
    return out


@pytest.fixture(scope="module")
def results(results_dir):
    return analysis.load_all(results_dir)


# ------------------------------------------------------------------ loading


def test_load_all_normalizes_run_record(results):
    (res, meta) = results[("harris", "v5e")]
    assert meta["optimum_is_true"] is True          # costmodel true optimum
    assert meta["optimum"] > 0
    assert meta["spec"]["kernel"] == "harris"
    assert meta["backend"] == "costmodel"
    assert set(res.algorithms()) == set(ALGOS)


def test_normalize_meta_accepts_legacy_flat_dict():
    meta = analysis.normalize_meta({"optimum": 2.0, "bench": "add"})
    assert meta["optimum"] == 2.0
    assert meta["optimum_is_true"] is True
    assert meta["spec"] == {} and meta["backend"] == "costmodel"


def test_present_algorithms_intersects_combos(results):
    assert analysis.present_algorithms(results) == list(ALGOS)
    assert analysis.present_algorithms({}) == []


# --------------------------------------------------------- bootstrap tables


def test_bootstrap_cis_deterministic_under_fixed_seed(results):
    a = astats.speedup_with_ci(results, n_boot=300, seed=0)
    b = astats.speedup_with_ci(results, n_boot=300, seed=0)
    assert a == b                                   # bit-identical, not close
    c = astats.speedup_with_ci(results, n_boot=300, seed=1)
    flat = [
        (x[1], x[2], y[1], y[2])
        for k in a
        for alg in a[k]
        for x, y in zip(a[k][alg].values(), c[k][alg].values(), strict=True)
    ]
    assert any(x[:2] != x[2:] for x in flat)        # seed actually matters


def test_speedup_ci_brackets_point_estimate(results):
    point = astats.fig4a_speedup(results)
    table = astats.speedup_with_ci(results, n_boot=300)
    for key in table:
        for algo, row in table[key].items():
            for s, (mid, lo, hi) in row.items():
                assert lo <= hi
                assert mid == point[key][algo][s]


def test_speedup_table_bit_stable_across_executors(tmp_path):
    """The acceptance bar for the whole chain: serial and process executors
    produce byte-identical RunRecords/arrays, so every analysis table —
    including the seeded bootstrap CIs — is bit-identical too."""
    spec = TuningSpec(
        kernel="harris",
        backend_kwargs={"chip": "v5e"},
        algorithms=("rs", "rf", "ga"),
        design=ExperimentDesign(sample_sizes=(25,), n_experiments=(4,),
                                final_repeats=3),
        seed=11,
        dataset_size=200,
    )
    tables = {}
    for name, kwargs in {
        "serial": {},
        "process": dict(executor="process", max_workers=3),
    }.items():
        out = str(tmp_path / name)
        repro.tune_matrix(spec, out_dir=out, **kwargs)
        loaded = analysis.load_all(out)
        tables[name] = (
            astats.fig4a_speedup(loaded),
            astats.speedup_with_ci(loaded, n_boot=200),
            astats.fig2_pct_optimum(loaded),
            astats.rank_table(loaded),
        )
    assert tables["serial"] == tables["process"]


# ----------------------------------------------------------------- rankings


def test_rank_table_is_a_permutation_per_size(results):
    ranks = astats.rank_table(results)[("harris", "v5e")]
    for s in (25, 50):
        assert sorted(ranks[a][s] for a in ALGOS) == [1, 2, 3, 4, 5]
    means = astats.mean_ranks(results)
    assert set(means) == set(ALGOS)
    winners = astats.winners_by_size(results)
    assert all(sum(w.values()) == 1 for w in winners.values())  # one combo


# ------------------------------------------------------------------- claims


def test_claims_insufficient_on_smoke_results(results):
    """Tiny matrices must yield insufficient-data, never a false verdict."""
    checks = analysis.check_claims(results)
    assert set(checks) == {
        "C1_bo_wins_small_S", "C2_ga_wins_large_S",
        "C2b_ga_best_aggregate_large_S", "C3_speedup_larger_at_small_S",
        "C4_more_consistent_at_large_S", "C5_rf_not_overall_winner",
        "C6_bo_gp_nonmonotone_somewhere",
    }
    for v in checks.values():
        assert v.status == aclaims.INSUFFICIENT, (v.claim, v.status)
        assert not v.passed
        assert "reason" in v.detail


def test_ragged_matrix_yields_insufficient_not_crash(results_dir):
    """A combo missing one (algo, S) cell — not a whole algorithm — must
    still produce insufficient-data verdicts and render ragged tables."""
    res_full = analysis.load_all(results_dir)
    (full, meta) = res_full[("harris", "v5e")]
    ragged = MatrixResults()
    for (algo, s), cell in full.cells.items():
        if (algo, s) != ("bo_tpe", 50):
            ragged.add(cell)
    results = {("harris", "v5e"): (ragged, meta)}
    checks = analysis.check_claims(results)
    assert all(v.status == aclaims.INSUFFICIENT for v in checks.values())
    # tables stay usable: bo_tpe keeps its S=25 column, drops S=50
    f2 = astats.fig2_pct_optimum(results)[("harris", "v5e")]
    assert 25 in f2["bo_tpe"] and 50 not in f2["bo_tpe"]
    assert "- |" in areport.render_fig2({("harris", "v5e"): f2})
    ranks = astats.rank_table(results)[("harris", "v5e")]
    assert sorted(a for a in ranks if 50 in ranks[a]) == sorted(
        a for a in ALGOS if a != "bo_tpe"
    )


def test_missing_cell_beats_experiment_floor_in_sufficiency():
    """With enough repeats everywhere else, a single missing (algo, S) cell
    is the reported insufficiency — not a KeyError from winner counting."""
    medians = {
        (a, s): 1.2
        for a in ALGOS
        for s in (25, 50, 100, 200, 400)
        if (a, s) != ("bo_tpe", 50)
    }
    results, _ = _synthetic_results(medians, n_exp=30)
    checks = analysis.check_claims(results)
    v = checks["C1_bo_wins_small_S"]
    assert v.status == aclaims.INSUFFICIENT
    assert "no bo_tpe/S=50 cell" in v.detail["reason"]


def test_report_on_rs_only_results(tmp_path):
    """Baseline-only results (nothing to compare against RS) must still
    produce a report — empty comparison tables, no crash."""
    out = str(tmp_path / "rs_only")
    spec = TuningSpec(
        kernel="harris", backend_kwargs={"chip": "v5e"},
        algorithms=("rs",),
        design=ExperimentDesign(sample_sizes=(25,), n_experiments=(3,),
                                final_repeats=3),
        dataset_size=100,
    )
    repro.tune_matrix(spec, out_dir=out)
    path = analysis.generate_report(out, n_boot=50)
    text = open(path).read()
    assert "Paper-claim verdicts" in text
    assert "(no data)" in text                   # empty speedup table
    figs = os.path.join(out, "figures")
    if analysis.HAVE_MATPLOTLIB:
        assert "speedup_vs_sample_size.png" not in os.listdir(figs)


def test_claims_insufficient_on_missing_algorithms(results_dir):
    res = analysis.load_all(results_dir)
    (full, meta) = res[("harris", "v5e")]
    partial = MatrixResults()
    for (algo, _s), cell in full.cells.items():
        if algo != "bo_tpe":
            partial.add(cell)
    checks = analysis.check_claims({("harris", "v5e"): (partial, meta)})
    assert all(v.status == aclaims.INSUFFICIENT for v in checks.values())
    assert "bo_tpe" in checks["C1_bo_wins_small_S"].detail["reason"]


def _synthetic_results(medians: dict, n_exp: int = 30, spread: dict = None):
    """One synthetic combo: finals per (algo, S) drawn around ``medians``
    with per-size ``spread`` (distribution overlap drives CLES)."""
    rng = np.random.default_rng(0)
    sizes = sorted({s for _, s in medians})
    res = MatrixResults()
    for (algo, s), m in medians.items():
        vals = np.maximum(
            m + rng.normal(0, (spread or {}).get(s, 0.01), size=n_exp), 1.0
        )
        res.add(CellResult(
            algo=algo, sample_size=s, final_values=vals,
            search_best_values=vals.copy(),
            n_samples_used=np.full(n_exp, s),
        ))
    meta = {"optimum": 1.0, "optimum_is_true": True, "spec": {},
            "provenance": {}, "backend": "synthetic"}
    return {("synth", "chip"): (res, meta)}, sizes


def test_claims_decidable_on_sufficient_synthetic_data():
    """A matrix engineered to satisfy every claim passes all seven —
    proving the predicates evaluate once the data clears the bar."""
    base = {
        # small S: BO-GP clearly best, RS worst; large S: GA best, RS
        # improving monotonically; BO-GP dips at 200 (the C6 shape).
        "rs":     {25: 1.60, 50: 1.55, 100: 1.50, 200: 1.30, 400: 1.25},
        "rf":     {25: 1.40, 50: 1.38, 100: 1.35, 200: 1.18, 400: 1.15},
        "ga":     {25: 1.30, 50: 1.25, 100: 1.20, 200: 1.02, 400: 1.01},
        "bo_gp":  {25: 1.05, 50: 1.06, 100: 1.08, 200: 1.25, 400: 1.12},
        "bo_tpe": {25: 1.15, 50: 1.12, 100: 1.10, 200: 1.08, 400: 1.05},
    }
    medians = {(a, s): v for a, row in base.items() for s, v in row.items()}
    # broad overlap at small S (CLES < 1), near-deterministic at large S
    spread = {25: 0.15, 50: 0.15, 100: 0.12, 200: 0.005, 400: 0.005}
    results, _ = _synthetic_results(medians, spread=spread)
    checks = analysis.check_claims(results)
    for v in checks.values():
        assert v.status == aclaims.PASS, (v.claim, v.status, v.detail)


def test_claims_fail_cleanly_when_contradicted():
    """RF winning everywhere must FAIL C1/C5 — a verdict, not a data gap."""
    medians = {
        (a, s): (1.05 if a == "rf" else 1.5)
        for a in ALGOS
        for s in (25, 50, 100, 200, 400)
    }
    results, _ = _synthetic_results(medians)
    checks = analysis.check_claims(results)
    assert checks["C1_bo_wins_small_S"].status == aclaims.FAIL
    assert checks["C5_rf_not_overall_winner"].status == aclaims.FAIL


# ------------------------------------------------------------------- report


def test_report_roundtrips_on_results_dir(results_dir):
    path = analysis.generate_report(results_dir, n_boot=200)
    assert path == os.path.join(results_dir, "REPORT.md")
    text = open(path).read()
    for needle in (
        "median speedup over RS (95% bootstrap CI)",
        "pct-of-optimum — harris x v5e",
        "Paper-claim verdicts",
        "insufficient-data",
        "spec fingerprint",
    ):
        assert needle in text, needle
    if analysis.HAVE_MATPLOTLIB:
        figs = os.listdir(os.path.join(results_dir, "figures"))
        assert len(figs) >= 2
        for f in figs:
            assert f"figures/{f}" in text        # report links every figure


def test_report_cli(results_dir, capsys):
    assert areport.main([results_dir, "--n-boot", "50"]) == 0
    assert "REPORT.md" in capsys.readouterr().out


def test_claims_cli(results_dir, capsys):
    assert aclaims.main([results_dir]) == 0
    out = capsys.readouterr().out
    assert "insufficient-data" in out or "N/A" in out


# ----------------------------------------------- budget-clipping convention


def test_trajectory_budget_convention():
    r = TuningResult(algo="rs", best_config={}, best_value=2.0,
                     history_values=[3.0, 2.0, 4.0], n_samples=3)
    np.testing.assert_array_equal(r.trajectory(), [3.0, 2.0, 2.0])
    # early-terminated search holds its final best up to the budget
    np.testing.assert_array_equal(r.trajectory(5), [3.0, 2.0, 2.0, 2.0, 2.0])
    with pytest.raises(ValueError, match="never clip"):
        r.trajectory(2)
    with pytest.raises(ValueError, match="budget must be >= 1"):
        r.trajectory(0)
    with pytest.raises(ValueError, match="empty sample history"):
        TuningResult(algo="rs", best_config={}, best_value=np.inf).trajectory()


def test_stats_layer_agrees_with_trajectory():
    r = TuningResult(algo="ga", best_config={}, best_value=1.0,
                     history_values=[5.0, 1.0], n_samples=2)
    assert astats.best_at_budget(r, 2) == 1.0
    assert astats.best_at_budget(r, 400) == 1.0      # ended-early convention
    np.testing.assert_array_equal(
        astats.budget_curve(r, [1, 2, 10]), [5.0, 1.0, 1.0]
    )


def test_figures_degrade_gracefully(tmp_path):
    assert analysis.make_figures({}, str(tmp_path / "figs")) == []
