"""Training layer: loss behaviour, grad-accum equivalence, optimizer."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import REGISTRY
from repro.data import DataConfig, make_train_batch
from repro.models import build_model, init_params
from repro.optim import AdamWConfig, apply_updates, global_norm, init_state
from repro.train import TrainSettings, cross_entropy, init_train_state, make_train_step

RNG = jax.random.PRNGKey(0)


def _tiny_state(name="mamba2-130m"):
    cfg = REGISTRY[name].reduced()
    model = build_model(cfg)
    params = init_params(model.spec(), RNG)
    return cfg, model, init_train_state(model, params)


def test_cross_entropy_uniform_is_log_vocab():
    v = 64
    logits = jnp.zeros((2, 8, v))
    labels = jnp.zeros((2, 8), jnp.int32)
    assert float(cross_entropy(logits, labels)) == pytest.approx(np.log(v), rel=1e-5)


def test_loss_decreases_over_steps():
    cfg, model, state = _tiny_state()
    settings = TrainSettings(
        remat="none", optimizer=AdamWConfig(lr=3e-3, warmup_steps=1)
    )
    step = jax.jit(make_train_step(model, settings))
    dc = DataConfig(seed=0)
    batch = make_train_batch(dc, cfg, seq_len=32, batch=4, step=0)
    losses = []
    for _ in range(12):
        state, metrics = step(state, batch)   # overfit one batch
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] * 0.8, losses


def test_grad_accum_matches_single_batch():
    """accum=2 over a batch == accum=1 on the same batch (same update)."""
    cfg, model, state = _tiny_state()
    dc = DataConfig(seed=1)
    batch = make_train_batch(dc, cfg, seq_len=16, batch=4, step=0)
    s1 = jax.jit(make_train_step(model, TrainSettings(remat="none", accum=1)))
    s2 = jax.jit(make_train_step(model, TrainSettings(remat="none", accum=2)))
    st1, m1 = s1(state, batch)
    st2, m2 = s2(state, batch)
    # bf16 forward: reduction order differs between one batch and two
    # microbatches; agreement is to ~1e-5 relative
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-4)
    l1 = jax.tree_util.tree_leaves(st1["params"])
    l2 = jax.tree_util.tree_leaves(st2["params"])
    for a, b in zip(l1, l2, strict=True):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), rtol=2e-4, atol=2e-5)


def test_adamw_clipping_and_decay():
    params = {"w": jnp.ones((4,), jnp.float32)}
    grads = {"w": jnp.full((4,), 100.0)}
    cfg = AdamWConfig(lr=0.1, clip_norm=1.0, weight_decay=0.0, warmup_steps=1)
    state = init_state(params)
    new_p, new_state, metrics = apply_updates(params, grads, state, cfg)
    assert float(metrics["grad_norm"]) == pytest.approx(200.0)
    assert int(new_state["step"]) == 1
    # clipped update magnitude bounded by lr
    assert np.abs(np.asarray(new_p["w"]) - 1.0).max() <= 0.11


def test_global_norm():
    t = {"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])}
    assert float(global_norm(t)) == pytest.approx(5.0)


def test_warmup_schedule():
    from repro.optim import schedule
    cfg = AdamWConfig(lr=1.0, warmup_steps=10)
    assert float(schedule(cfg, jnp.asarray(5))) == pytest.approx(0.5)
    assert float(schedule(cfg, jnp.asarray(100))) == pytest.approx(1.0)


def test_data_pipeline_deterministic_and_step_dependent():
    cfg = REGISTRY["mamba2-130m"].reduced()
    dc = DataConfig(seed=0)
    b1 = make_train_batch(dc, cfg, 16, 2, step=3)
    b2 = make_train_batch(dc, cfg, 16, 2, step=3)
    b3 = make_train_batch(dc, cfg, 16, 2, step=4)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    # labels are tokens shifted left by one
    np.testing.assert_array_equal(np.asarray(b1["tokens"])[:, 1:],
                                  np.asarray(b1["labels"])[:, :-1])


def test_moe_arch_trains():
    cfg = REGISTRY["olmoe-1b-7b"].reduced()
    model = build_model(cfg)
    state = init_train_state(model, init_params(model.spec(), RNG))
    step = jax.jit(make_train_step(model, TrainSettings(remat="dots")))
    batch = make_train_batch(DataConfig(), cfg, 16, 4, 0)
    state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["aux"]) > 0.0   # load-balance loss active
