"""Batched ask/tell evaluation engine: budget exactness, batched/sequential
parity, protocol mechanics, dispatch counting, and the persistent cache."""

import numpy as np
import pytest

from repro.core import (
    EXTRA_ALGORITHMS,
    PAPER_ALGORITHMS,
    CallableMeasurement,
    DiskCachedMeasurement,
    ExperimentDesign,
    MeasurementStore,
    TuningSession,
    TuningSpec,
    config_key,
    drive,
    make_searcher,
    paper_space,
)
from repro.costmodel import CHIPS, WORKLOADS, CostModelMeasurement

ALL = PAPER_ALGORITHMS + EXTRA_ALGORITHMS


def smooth(cfg):
    x = np.array([cfg["t_x"] / 16, cfg["t_y"] / 16, cfg["t_z"] / 16,
                  cfg["w_x"] / 8, cfg["w_y"] / 8, cfg["w_z"] / 8])
    target = np.array([0.5, 0.75, 0.25, 0.6, 0.9, 0.3])
    return 1.0 + float(((x - target) ** 2).sum())


def smooth_batch(cfgs):
    return np.array([smooth(c) for c in cfgs], dtype=np.float64)


@pytest.fixture(scope="module")
def space():
    return paper_space()


# -------------------------------------------------- budget exactness


@pytest.mark.parametrize("algo", ALL)
@pytest.mark.parametrize("budget", [5, 25, 60])
def test_batched_driver_consumes_exact_budget(space, algo, budget):
    """Every searcher, driven batched, uses exactly its sample budget —
    audited against the measurement's own counter, not the result."""
    m = CallableMeasurement(smooth)
    r = make_searcher(algo, space, seed=0).run(m, budget, dispatch="batch")
    assert r.n_samples == budget
    assert m.n_samples == budget
    assert len(r.history_values) == budget


@pytest.mark.parametrize("algo", ALL)
def test_sequential_driver_consumes_exact_budget(space, algo):
    m = CallableMeasurement(smooth)
    r = make_searcher(algo, space, seed=0).run(m, 40, dispatch="one")
    assert r.n_samples == 40
    assert m.n_samples == 40


# -------------------------------------------------- batched == sequential


@pytest.mark.parametrize("algo", ["rs", "ga"])
def test_batched_matches_sequential_history(space, algo):
    """Identical histories (configs AND values) for a fixed seed whether the
    engine dispatches batches or single configs."""
    rb = make_searcher(algo, space, seed=11).run(
        CallableMeasurement(smooth, batch_fn=smooth_batch), 60, dispatch="batch"
    )
    rs = make_searcher(algo, space, seed=11).run(
        CallableMeasurement(smooth), 60, dispatch="one"
    )
    assert rb.history_configs == rs.history_configs
    assert rb.history_values == rs.history_values
    assert rb.best_config == rs.best_config
    assert rb.best_value == rs.best_value


@pytest.mark.parametrize("algo", ALL)
def test_batched_matches_sequential_on_costmodel(space, algo):
    """The cost-model backend's counter-based noise is dispatch-invariant,
    so parity holds for every searcher even under noise."""
    w, chip = WORKLOADS["harris"], CHIPS["v5e"]
    rb = make_searcher(algo, space, seed=3).run(
        CostModelMeasurement(w, chip, seed=5), 30, dispatch="batch"
    )
    rs = make_searcher(algo, space, seed=3).run(
        CostModelMeasurement(w, chip, seed=5), 30, dispatch="one"
    )
    assert rb.history_values == rs.history_values
    assert rb.best_value == rs.best_value


# -------------------------------------------------- ask/tell protocol


def test_ask_tell_protocol_chunks(space):
    """ask(n) may split an algorithm batch; history order is preserved."""
    s = make_searcher("rs", space, seed=2)
    s.start(20)
    served = 0
    while not s.done:
        configs = s.ask(7)
        if not configs:
            break
        assert len(configs) <= 7
        s.tell(configs, [smooth(c) for c in configs])
        served += len(configs)
    r = s.finish()
    assert served == 20
    assert r.n_samples == 20


def test_ask_twice_without_tell_raises(space):
    s = make_searcher("rs", space, seed=0)
    s.start(10)
    s.ask(3)
    with pytest.raises(RuntimeError):
        s.ask(3)


def test_tell_mismatched_configs_raises(space):
    s = make_searcher("rs", space, seed=0)
    s.start(10)
    configs = s.ask(2)
    with pytest.raises(ValueError):
        s.tell(list(reversed(configs)), [1.0, 2.0])


def test_run_without_session_raises(space):
    s = make_searcher("rs", space, seed=0)
    with pytest.raises(RuntimeError):
        s.ask()


# -------------------------------------------------- dispatch counting


def test_batched_dispatch_is_order_of_magnitude_cheaper(space):
    """rs proposes its whole budget as one batch: 1 dispatch vs 400."""
    w, chip = WORKLOADS["add"], CHIPS["v5e"]
    mb = CostModelMeasurement(w, chip, seed=0)
    make_searcher("rs", space, seed=0).run(mb, 400, dispatch="batch")
    mo = CostModelMeasurement(w, chip, seed=0)
    make_searcher("rs", space, seed=0).run(mo, 400, dispatch="one")
    assert mb.n_dispatches == 1
    assert mo.n_dispatches == 400
    assert mb.n_dispatches * 5 <= mo.n_dispatches


# -------------------------------------------------- persistent disk cache


def test_disk_cache_serves_repeat_runs(space, tmp_path):
    path = str(tmp_path / "cache.json")
    w, chip = WORKLOADS["harris"], CHIPS["v5e"]

    store = MeasurementStore(path)
    inner1 = CostModelMeasurement(w, chip, seed=9)
    m1 = DiskCachedMeasurement(inner1, store, prefix="harris/v5e/seed=9")
    r1 = make_searcher("ga", space, seed=4).run(m1, 40)
    assert m1.n_samples == 40
    assert m1.n_misses == 40
    store.save()

    # a fresh process re-running the same cell: zero inner measurements
    store2 = MeasurementStore(path)
    inner2 = CostModelMeasurement(w, chip, seed=9)
    m2 = DiskCachedMeasurement(inner2, store2, prefix="harris/v5e/seed=9")
    r2 = make_searcher("ga", space, seed=4).run(m2, 40)
    assert m2.n_samples == 40          # budget audit unchanged by cache hits
    assert m2.n_misses == 0
    assert inner2.n_samples == 0
    assert r1.history_values == r2.history_values

    # a different experiment stream shares the file but not the entries
    m3 = DiskCachedMeasurement(
        CostModelMeasurement(w, chip, seed=10), store2, prefix="harris/v5e/seed=10"
    )
    make_searcher("ga", space, seed=4).run(m3, 10)
    assert m3.n_misses == 10


def test_disk_cache_measure_final_memoized(tmp_path):
    w, chip = WORKLOADS["add"], CHIPS["v4"]
    store = MeasurementStore(str(tmp_path / "c.json"))
    cfg = dict(t_x=1, t_y=2, t_z=1, w_x=1, w_y=1, w_z=1)
    m = DiskCachedMeasurement(CostModelMeasurement(w, chip, seed=0), store, "k")
    a = m.measure_final(cfg)
    b = m.measure_final(cfg)
    assert a == b


def test_config_key_is_order_insensitive():
    assert config_key({"b": 2, "a": 1}) == config_key({"a": 1, "b": 2})


def test_disk_cache_keeps_noise_alignment_on_partial_hits(space, tmp_path):
    """A cache that is warm for only a PREFIX of the stream must not shift
    the noise indices of the later, uncached samples (hits advance the
    inner backend's counter via skip_samples)."""
    w, chip = WORKLOADS["harris"], CHIPS["v5e"]
    rng = np.random.default_rng(0)
    configs = space.sample_batch(rng, 30)

    cold = CostModelMeasurement(w, chip, seed=1).measure_batch(configs)

    store = MeasurementStore(str(tmp_path / "c.json"))
    # warm the first 10 entries only (simulates an interrupted run)
    m_warm = DiskCachedMeasurement(CostModelMeasurement(w, chip, seed=1), store, "p")
    m_warm.measure_batch(configs[:10])
    m_resume = DiskCachedMeasurement(
        CostModelMeasurement(w, chip, seed=1), store, "p"
    )
    resumed = m_resume.measure_batch(configs)
    assert m_resume.n_misses == 20
    np.testing.assert_array_equal(resumed, cold)


def test_encode_batch_roundtrips_and_rejects_foreign_values(space):
    rng = np.random.default_rng(5)
    idx = space.sample_indices(rng, 50)
    cfgs = space.decode_batch(idx)
    np.testing.assert_array_equal(space.encode_batch(cfgs), idx)
    assert space.encode_batch([]).shape == (0, space.n_params)
    with pytest.raises(ValueError):
        space.encode_batch([dict(cfgs[0], t_x=999)])


def test_reset_clears_dispatch_counter(space):
    m = CallableMeasurement(smooth)
    m.measure_batch(space.sample_batch(np.random.default_rng(0), 5))
    assert m.n_dispatches > 0
    m.reset()
    assert m.n_dispatches == 0 and m.n_samples == 0


# -------------------------------------------------- matrix session parity


def test_session_dispatch_parity_per_cell():
    """The full matrix smoke run: batched and sequential dispatch agree on
    per-cell n_samples_used (and, noise being dispatch-invariant, finals)."""

    def run(dispatch):
        spec = TuningSpec(
            kernel="harris",
            backend_kwargs={"chip": "v5e"},
            algorithms=("rs", "ga", "bo_tpe"),
            design=ExperimentDesign(sample_sizes=(25,), n_experiments=(3,)),
            dispatch=dispatch,
        )
        return TuningSession(spec).run_matrix()

    rb, ro = run("batch"), run("one")
    for key in rb.cells:
        assert np.array_equal(
            rb.cells[key].n_samples_used, ro.cells[key].n_samples_used
        )
        np.testing.assert_array_equal(
            rb.cells[key].final_values, ro.cells[key].final_values
        )


def test_session_with_store_never_remeasures(tmp_path):
    """In-process overrides (live measurement factory + store object) still
    run through the session's serial executor; a warm store serves the
    second run entirely from disk."""
    w, chip = WORKLOADS["add"], CHIPS["v5e"]
    path = str(tmp_path / "matrix_cache.json")

    counters = []

    def factory(seed):
        m = CostModelMeasurement(w, chip, seed=seed)
        counters.append(m)
        return m

    def run():
        spec = TuningSpec(
            kernel="add",
            backend_kwargs={"chip": "v5e"},
            algorithms=("rs", "ga"),
            design=ExperimentDesign(sample_sizes=(25,), n_experiments=(2,)),
            cache_key="add/v5e",
        )
        return TuningSession(
            spec, measurement_factory=factory, store=MeasurementStore(path)
        ).run_matrix()

    r1 = run()
    first_inner = sum(m.n_samples for m in counters)
    assert first_inner > 0
    counters.clear()
    r2 = run()
    assert sum(m.n_samples for m in counters) == 0   # everything from disk
    for key in r1.cells:
        np.testing.assert_array_equal(
            r1.cells[key].final_values, r2.cells[key].final_values
        )
