"""Property tests for the store layer and unit-result merging.

Randomized invariants rather than example-based pins:

* arbitrary (key, value, meta, winners) content survives a JSON-store
  save/load round-trip, a sqlite save/reopen round-trip, and a cross-format
  absorb — the two backends are interchangeable bit-for-bit;
* prefix queries (``meta_items``, ``best_item``) agree between the python
  scan and the sqlite ``LIKE`` (whose ``%`` / ``_`` / ``\\`` escaping is
  exactly the kind of thing only adversarial keys catch);
* ``merge_unit_results`` reassembles any contiguous fragmentation of any
  cell set back to the unfragmented arrays, and rejects every gap,
  duplicate, and overlap;
* ``UnitJournal.cover`` composes fragments journaled under different unit
  boundaries into any covered query unit, positionally exact;
* the winner merge is order-independent: folding any permutation of
  records yields the same best value and freshness.

Runs under ``hypothesis`` when installed (randomized seeds, shrinking);
falls back to a deterministic seed sweep otherwise — the container image
does not ship hypothesis, and the properties hold either way.
"""

from __future__ import annotations

import functools
import json
import random
import string

import numpy as np
import pytest

from repro.core import ExperimentUnit, UnitResult, merge_unit_results
from repro.core.stores import (
    MeasurementStore,
    SqliteMeasurementStore,
    absorb_winners,
    merge_winner_payloads,
)
from repro.core.workunits import UnitJournal

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def property_test(cases: int = 40):
    """Run ``fn(rng)`` across many seeds — hypothesis-driven when available
    (it explores and shrinks the seed space), a fixed sweep otherwise."""

    def deco(fn):
        if HAVE_HYPOTHESIS:
            @settings(max_examples=cases, deadline=None)
            @given(st.integers(min_value=0, max_value=2**63 - 1))
            @functools.wraps(fn)
            def hyp_wrapper(seed):
                fn(random.Random(seed))
            return hyp_wrapper

        @functools.wraps(fn)
        def sweep_wrapper():
            for seed in range(cases):
                fn(random.Random(seed))
        return sweep_wrapper

    return deco


KEY_ALPHABET = string.ascii_letters + string.digits + "/|=,.:%_\\-+ é€"


def rand_key(rng: random.Random) -> str:
    return "".join(
        rng.choice(KEY_ALPHABET) for _ in range(rng.randint(1, 24))
    )


def rand_value(rng: random.Random) -> float:
    v = rng.choice([
        rng.uniform(-1e6, 1e6),
        rng.uniform(-1e-9, 1e-9),
        float(rng.randint(-10, 10)),
        5e-324 * rng.randint(1, 9),            # subnormals
        rng.uniform(0, 1) * 10 ** rng.randint(-300, 300),
    ])
    return float(v)


def rand_store_content(rng: random.Random) -> tuple[dict, dict, dict]:
    values = {rand_key(rng): rand_value(rng)
              for _ in range(rng.randint(0, 30))}
    meta = {rand_key(rng): rand_key(rng) for _ in range(rng.randint(0, 10))}
    winners = {
        f"k{i}|x={rng.randint(1, 9999)}|y={rng.randint(1, 9999)}|dev": json.dumps(
            {"config": {"t": rng.randint(1, 64)},
             "value": rand_value(rng),
             "fresh": rng.uniform(0, 1e9),
             "fingerprint": rand_key(rng)},
            sort_keys=True,
        )
        for i in range(rng.randint(0, 5))
    }
    return values, meta, winners


def fill(store, values, meta, winners):
    for k, v in values.items():
        store.put(k, v)
    for k, v in meta.items():
        store.put_meta(k, v)
    for k, v in winners.items():
        store.put_winner(k, v)


def snapshot(store) -> tuple[dict, dict, dict]:
    return (dict(store.items()), dict(store.meta_items()),
            dict(store.winner_items()))


# ------------------------------------------------------- store round-tripping


@property_test()
def prop_json_store_roundtrip(rng):
    import tempfile
    values, meta, winners = rand_store_content(rng)
    with tempfile.TemporaryDirectory() as d:
        path = f"{d}/s.json"
        store = MeasurementStore(path)
        fill(store, values, meta, winners)
        store.save()
        assert snapshot(MeasurementStore(path)) == (values, meta, winners)


def test_json_store_roundtrip():
    prop_json_store_roundtrip()


@property_test()
def prop_sqlite_store_roundtrip(rng):
    import tempfile
    values, meta, winners = rand_store_content(rng)
    with tempfile.TemporaryDirectory() as d:
        path = f"{d}/s.sqlite"
        store = SqliteMeasurementStore(path)
        fill(store, values, meta, winners)
        store.save()
        store.close()
        reopened = SqliteMeasurementStore(path)
        assert snapshot(reopened) == (values, meta, winners)
        reopened.close()


def test_sqlite_store_roundtrip():
    prop_sqlite_store_roundtrip()


@property_test()
def prop_cross_format_absorb_is_lossless(rng):
    values, meta, winners = rand_store_content(rng)
    src = MeasurementStore(None)
    fill(src, values, meta, winners)
    dst = SqliteMeasurementStore(None)
    dst.update(src.items())
    dst.update_meta(src.meta_items())
    absorb_winners(dst, src)
    got_values, got_meta, got_winners = snapshot(dst)
    assert (got_values, got_meta) == (values, meta)
    # absorb merges: with an empty dst every src record lands verbatim
    assert got_winners == winners
    dst.close()


def test_cross_format_absorb_is_lossless():
    prop_cross_format_absorb_is_lossless()


# ------------------------------------------------------------ prefix queries


@property_test()
def prop_meta_prefix_query_matches_python_scan(rng):
    _, meta, _ = rand_store_content(rng)
    js, sq = MeasurementStore(None), SqliteMeasurementStore(None)
    for k, v in meta.items():
        js.put_meta(k, v)
        sq.put_meta(k, v)
    # prefixes biased toward LIKE metacharacters and real key heads
    prefix = rng.choice(
        ["%", "_", "\\", "%_", "k", ""]
        + [k[: rng.randint(0, len(k))] for k in (list(meta) or ["x"])]
    )
    expect = {k: v for k, v in meta.items() if k.startswith(prefix)}
    assert dict(js.meta_items(prefix=prefix)) == expect
    assert dict(sq.meta_items(prefix=prefix)) == expect
    sq.close()


def test_meta_prefix_query_matches_python_scan():
    prop_meta_prefix_query_matches_python_scan()


@property_test()
def prop_best_item_agrees_across_backends(rng):
    values, _, _ = rand_store_content(rng)
    js, sq = MeasurementStore(None), SqliteMeasurementStore(None)
    for k, v in values.items():
        js.put(k, v)
        sq.put(k, v)
    prefix = rng.choice(
        ["", "%", "_"] + [k[: rng.randint(0, len(k))]
                          for k in (list(values) or ["x"])]
    )
    contains = rng.choice([None, "|", "final", "%", "_"])
    expect = None
    for k, v in values.items():
        if not k.startswith(prefix):
            continue
        if contains is not None and contains not in k:
            continue
        if expect is None or (v, k) < (expect[1], expect[0]):
            expect = (k, v)
    assert js.best_item(prefix, contains) == expect
    assert sq.best_item(prefix, contains) == expect
    sq.close()


def test_best_item_agrees_across_backends():
    prop_best_item_agrees_across_backends()


# ------------------------------------------------------- merge_unit_results


def rand_partition(rng: random.Random, n: int) -> list[tuple[int, int]]:
    """A random contiguous partition of [0, n)."""
    cuts = sorted(rng.sample(range(1, n), rng.randint(0, n - 1))) if n > 1 else []
    bounds = [0, *cuts, n]
    return list(zip(bounds[:-1], bounds[1:], strict=False))


def fragments_for(cells, rng) -> list[UnitResult]:
    frags = []
    for algo, s, e in cells:
        for lo, hi in rand_partition(rng, e):
            unit = ExperimentUnit(algo=algo, sample_size=s,
                                  exp_lo=lo, exp_hi=hi, n_exp=e)
            idx = np.arange(lo, hi, dtype=np.float64)
            frags.append(UnitResult(
                unit=unit,
                final_values=idx + 0.5,
                search_best_values=idx + 0.25,
                n_samples_used=np.arange(lo, hi, dtype=np.int64),
                wall_s=float(hi - lo),
            ))
    rng.shuffle(frags)
    return frags


@property_test()
def prop_merge_reassembles_any_fragmentation(rng):
    cells = [
        (algo, s, rng.randint(1, 12))
        for algo, s in {("rs", 25), ("ga", 50), ("rf", 100)}
        if rng.random() < 0.8
    ] or [("rs", 25, 4)]
    frags = fragments_for(cells, rng)
    merged, walls = merge_unit_results(cells, frags)
    assert [(c.algo, c.sample_size) for c in merged] == [
        (a, s) for a, s, _ in cells
    ]
    for cell, (_, _, e) in zip(merged, cells, strict=True):
        np.testing.assert_array_equal(
            cell.final_values, np.arange(e, dtype=np.float64) + 0.5
        )
        np.testing.assert_array_equal(
            cell.n_samples_used, np.arange(e, dtype=np.int64)
        )
    for (algo, s, e) in cells:
        # wall clock is additive over fragments: sums back to the cell total
        assert walls[(algo, s)]["wall_s"] == pytest.approx(float(e))


def test_merge_reassembles_any_fragmentation():
    prop_merge_reassembles_any_fragmentation()


@property_test()
def prop_merge_rejects_gaps_and_duplicates(rng):
    e = rng.randint(2, 10)
    cells = [("rs", 25, e)]
    frags = fragments_for(cells, rng)
    if rng.random() < 0.5 or len(frags) == 1:
        # drop one fragment -> coverage gap (or, for a single fragment,
        # an empty cell)
        drop = rng.randrange(len(frags))
        broken = [f for i, f in enumerate(frags) if i != drop]
        with pytest.raises(ValueError):
            merge_unit_results(cells, broken)
    else:
        dup = rng.choice(frags)
        with pytest.raises(ValueError, match="duplicate"):
            merge_unit_results(cells, [*frags, dup])


def test_merge_rejects_gaps_and_duplicates():
    prop_merge_rejects_gaps_and_duplicates()


# --------------------------------------------------------- journal coverage


@property_test(cases=30)
def prop_journal_cover_composes_fragments(rng):
    e = rng.randint(1, 12)
    store = MeasurementStore(None)
    journal = UnitJournal(store, "ns", min_flush_s=0.0)
    for frag in fragments_for([("ga", 25, e)], rng):
        journal.put(frag)
    lo = rng.randrange(e)
    hi = rng.randint(lo + 1, e)
    query = ExperimentUnit(algo="ga", sample_size=25,
                           exp_lo=lo, exp_hi=hi, n_exp=e)
    got = journal.cover(query)
    assert got is not None
    np.testing.assert_array_equal(
        got.final_values, np.arange(lo, hi, dtype=np.float64) + 0.5
    )
    np.testing.assert_array_equal(
        got.n_samples_used, np.arange(lo, hi, dtype=np.int64)
    )
    # a different cell is never covered
    other = ExperimentUnit(algo="rs", sample_size=25,
                           exp_lo=0, exp_hi=1, n_exp=e)
    assert journal.cover(other) is None


def test_journal_cover_composes_fragments():
    prop_journal_cover_composes_fragments()


# ------------------------------------------------------------- winner merge


@property_test()
def prop_winner_merge_is_order_independent(rng):
    n = rng.randint(1, 8)
    payloads = [
        json.dumps({
            "config": {"i": i},
            "value": rng.choice([1.0, 2.0, rng.uniform(0, 3)]),
            "fresh": rng.uniform(0, 100),
        }, sort_keys=True)
        for i in range(n)
    ]

    def fold(order):
        acc = None
        for p in order:
            acc = merge_winner_payloads(acc, p)
        return json.loads(acc)

    shuffled = list(payloads)
    rng.shuffle(shuffled)
    a, b = fold(payloads), fold(shuffled)
    assert a["value"] == b["value"]
    assert a["fresh"] == b["fresh"]
    assert a["value"] == min(json.loads(p)["value"] for p in payloads)
    assert a["fresh"] == max(json.loads(p)["fresh"] for p in payloads)


def test_winner_merge_is_order_independent():
    prop_winner_merge_is_order_independent()
