"""Surrogate models: reference forest vs batched forest, GP sanity."""

import numpy as np
import pytest

from repro.core import paper_space
from repro.core.surrogates import GaussianProcess, RandomForestRegressor, RegressionTree
from repro.core.surrogates.forest_batched import BatchedForest
from repro.core.surrogates.gp import expected_improvement, matern52


@pytest.fixture(scope="module")
def space():
    return paper_space(constrained=False)


def _toy(X):
    return (X[:, 0] - 8.0) ** 2 + 3.0 * X[:, 3] + 0.5 * X[:, 1]


def test_tree_fits_exactly_on_training_data():
    rng = np.random.default_rng(0)
    X = rng.integers(0, 10, size=(60, 3)).astype(float)
    y = rng.normal(size=60)
    # grown to purity, a CART tree memorizes distinct rows
    tree = RegressionTree(rng=rng).fit(X, y)
    pred = tree.predict(X)
    # rows may repeat; group identical rows and compare means
    key = [tuple(r) for r in X]
    for k in set(key):
        mask = np.array([kk == k for kk in key])
        np.testing.assert_allclose(pred[mask], y[mask].mean(), atol=1e-9)


def test_batched_forest_matches_reference(space):
    rng = np.random.default_rng(1)
    X = space.sample_indices(rng, 250)
    y = _toy(X) + rng.normal(0, 0.05, len(X))
    pool = space.sample_indices(rng, 400)
    ref = RandomForestRegressor(n_estimators=40, seed=0).fit(X.astype(float), y)
    bat = BatchedForest(space.cardinalities, n_estimators=40, seed=0).fit(X[None], y[None])
    pr, pb = ref.predict(pool.astype(float)), bat.predict(pool)[0]
    corr = np.corrcoef(pr, pb)[0, 1]
    assert corr > 0.97, corr


def test_batched_forest_multi_forest_independence(space):
    """Forest g must depend only on its own training slice."""
    rng = np.random.default_rng(2)
    X = np.stack([space.sample_indices(rng, 60) for _ in range(3)])
    y = np.stack([_toy(x) for x in X])
    pool = space.sample_indices(rng, 128)
    all3 = BatchedForest(space.cardinalities, n_estimators=20, seed=0).fit(X, y)
    solo = BatchedForest(space.cardinalities, n_estimators=20, seed=0).fit(
        X[1][None], y[1][None]
    )
    # bootstrap seeds differ between G=3 and G=1 fits, so compare quality,
    # not bitwise equality: both should rank the pool nearly identically
    p3 = all3.predict(pool)[1]
    p1 = solo.predict(pool)[0]
    true = _toy(pool)
    assert np.corrcoef(p3, true)[0, 1] > 0.9
    assert np.corrcoef(p1, true)[0, 1] > 0.9


def test_batched_forest_learns_signal(space):
    rng = np.random.default_rng(3)
    X = space.sample_indices(rng, 300)
    y = _toy(X)
    pool = space.sample_indices(rng, 300)
    bat = BatchedForest(space.cardinalities, n_estimators=50, seed=1).fit(X[None], y[None])
    pred = bat.predict(pool)[0]
    assert np.corrcoef(pred, _toy(pool))[0, 1] > 0.98


def test_gp_interpolates_and_uncertainty_grows():
    rng = np.random.default_rng(0)
    X = rng.uniform(0, 1, size=(30, 2))
    y = np.sin(4 * X[:, 0]) + X[:, 1]
    gp = GaussianProcess()
    gp.fit(X, y)
    mu, sigma = gp.predict(X)
    np.testing.assert_allclose(mu, y, atol=0.15)
    far = np.array([[10.0, 10.0]])
    _, sigma_far = gp.predict(far)
    assert sigma_far[0] > sigma.mean()


def test_gp_incremental_add_matches_batch_fit():
    rng = np.random.default_rng(4)
    X = rng.uniform(0, 1, size=(24, 3))
    y = (X**2).sum(1)
    batch = GaussianProcess()
    batch.fit(X, y)
    online = GaussianProcess()
    # mirror the hyperparameters so only the Cholesky path differs
    online.lengthscales = (batch.lengthscale,)
    online.noises = (batch.noise,)
    for x, v in zip(X, y, strict=True):
        online.add(x, v)
    Xs = rng.uniform(0, 1, size=(16, 3))
    mu_b, s_b = batch.predict(Xs)
    mu_o, s_o = online.predict(Xs)
    np.testing.assert_allclose(mu_o, mu_b, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(s_o, s_b, rtol=1e-4, atol=1e-6)


def test_matern52_psd():
    rng = np.random.default_rng(5)
    X = rng.uniform(0, 1, size=(40, 4))
    K = matern52(X, X, 0.5)
    np.testing.assert_allclose(K, K.T, atol=1e-12)
    w = np.linalg.eigvalsh(K)
    assert w.min() > -1e-8


def test_expected_improvement_properties():
    mu = np.array([0.0, 1.0, 2.0])
    sigma = np.array([1.0, 1.0, 1.0])
    ei = expected_improvement(mu, sigma, best=1.0)
    assert ei[0] > ei[1] > ei[2] > 0
    # zero uncertainty, worse mean -> zero EI
    ei_worse = expected_improvement(np.array([2.0]), np.array([1e-15]), 1.0)[0]
    assert ei_worse == pytest.approx(0.0, abs=1e-12)
