"""Analytical TPU cost model: scalar/batch agreement (property-tested),
executability constraint, architecture sensitivity."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.costmodel import (
    CHIPS,
    FAILURE_RUNTIME,
    WORKLOADS,
    CostModelMeasurement,
    executable_space,
    is_executable,
    runtime_model,
    runtime_model_batch,
    true_optimum,
    vmem_bytes,
)

cfg_strategy = st.fixed_dictionaries({
    "t_x": st.integers(1, 16),
    "t_y": st.integers(1, 16),
    "t_z": st.integers(1, 16),
    "w_x": st.integers(1, 8),
    "w_y": st.integers(1, 8),
    "w_z": st.integers(1, 8),
})


@given(cfg_strategy, st.sampled_from(sorted(WORKLOADS)), st.sampled_from(sorted(CHIPS)))
@settings(max_examples=150, deadline=None)
def test_scalar_and_batch_models_agree(cfg, wname, cname):
    w, chip = WORKLOADS[wname], CHIPS[cname]
    scalar = runtime_model(w, chip, cfg)
    row = np.array([[cfg["t_x"], cfg["t_y"], cfg["t_z"],
                     cfg["w_x"], cfg["w_y"], cfg["w_z"]]], dtype=float)
    batch = runtime_model_batch(w, chip, row)[0]
    assert scalar == pytest.approx(batch, rel=1e-12)


@given(cfg_strategy)
@settings(max_examples=80, deadline=None)
def test_invalid_configs_get_failure_penalty(cfg):
    w, chip = WORKLOADS["harris"], CHIPS["v3"]   # smallest VMEM
    if not is_executable(w, chip, cfg):
        assert runtime_model(w, chip, cfg) == FAILURE_RUNTIME
    else:
        assert runtime_model(w, chip, cfg) < FAILURE_RUNTIME


def test_vmem_grows_with_block_and_depth():
    w = WORKLOADS["add"]
    small = dict(t_x=1, t_y=1, t_z=1, w_x=1, w_y=1, w_z=1)
    assert vmem_bytes(w, dict(small, t_x=8)) > vmem_bytes(w, small)
    assert vmem_bytes(w, dict(small, w_z=4)) > vmem_bytes(w, small)


def test_executable_space_only_yields_valid(space_seed=0):
    w, chip = WORKLOADS["add"], CHIPS["v3"]
    space = executable_space(w, chip)
    rng = np.random.default_rng(space_seed)
    for cfg in space.sample_batch(rng, 100):
        assert is_executable(w, chip, cfg)


def test_optima_differ_across_chips():
    """Same benchmark, different architecture -> different optimum config
    (the paper's performance-portability premise)."""
    w = WORKLOADS["add"]
    cfgs = {c: true_optimum(w, CHIPS[c])[0] for c in CHIPS}
    assert cfgs["v5e"] != cfgs["v3"] or cfgs["v4"] != cfgs["v3"]


def test_measurement_noise_and_final_median():
    w, chip = WORKLOADS["harris"], CHIPS["v5e"]
    cfg = dict(t_x=2, t_y=8, t_z=4, w_x=1, w_y=1, w_z=2)
    m = CostModelMeasurement(w, chip, seed=0)
    draws = [m.measure(cfg) for _ in range(50)]
    assert np.std(draws) > 0  # noisy during search
    base = runtime_model(w, chip, cfg)
    final = m.measure_final(cfg, repeats=10)
    assert abs(final / base - 1.0) < 0.15
    noiseless = CostModelMeasurement(w, chip, seed=0, noise=False)
    assert noiseless.measure(cfg) == base


def test_memory_bound_add_insensitive_to_wz_overlap():
    """add is HBM-bound: double-buffering cannot beat the DMA floor."""
    w, chip = WORKLOADS["add"], CHIPS["v5e"]
    base = dict(t_x=4, t_y=16, t_z=16, w_x=1, w_y=1)
    t1 = runtime_model(w, chip, dict(base, w_z=1))
    t2 = runtime_model(w, chip, dict(base, w_z=2))
    assert t2 > t1 * 0.9  # no dramatic win from overlap
