"""Pallas kernels vs pure-jnp oracles across shape/dtype/config sweeps
(interpret mode on CPU; same pallas_call lowers to Mosaic on TPU)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import add, add_ref, harris, harris_ref, mandelbrot, mandelbrot_ref

CONFIGS = [
    {},                                                   # defaults
    dict(t_x=2, t_y=1, t_z=2, w_x=2, w_y=2, w_z=2),
    dict(t_x=1, t_y=2, t_z=3, w_x=3, w_y=1, w_z=1),
    dict(t_x=4, t_y=1, t_z=1, w_x=1, w_y=4, w_z=4),
]

SHAPES = [(64, 128), (128, 256), (96, 384), (40, 128)]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("cfg", CONFIGS)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_add_matches_ref(shape, cfg, dtype):
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.normal(size=shape), dtype)
    b = jnp.asarray(rng.normal(size=shape), dtype)
    out = add(a, b, cfg)
    assert out.dtype == dtype
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(add_ref(a, b), np.float32),
        rtol=1e-6, atol=1e-6,
    )


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("cfg", CONFIGS)
def test_harris_matches_ref(shape, cfg):
    rng = np.random.default_rng(1)
    img = jnp.asarray(rng.normal(size=shape), jnp.float32)
    out = np.asarray(harris(img, cfg))
    ref = np.asarray(harris_ref(img))
    denom = np.abs(ref).max()
    assert np.abs(out - ref).max() / denom < 1e-5


@pytest.mark.parametrize("shape", [(64, 128), (96, 256), (50, 130)])
@pytest.mark.parametrize("cfg", CONFIGS)
def test_mandelbrot_matches_ref(shape, cfg):
    """Escape-iteration counts are chaotic at the set boundary: FMA
    contraction differences legitimately move a handful of pixels by a few
    iterations -> 'discrete boundary' tolerance: >=99.5% exact, violations
    within +-4."""
    x, y = shape
    out = np.asarray(mandelbrot(x, y, cfg))
    ref = np.asarray(mandelbrot_ref(x, y))
    exact = (out == ref).mean()
    assert exact >= 0.995, exact
    assert np.abs(out - ref).max() <= 4


def test_mandelbrot_interior_is_max_iter():
    out = np.asarray(mandelbrot(64, 64, max_iter=32))
    # the middle of the classic view contains the set -> full iteration count
    assert out.max() == 32


def test_add_odd_shapes_pad_correctly():
    rng = np.random.default_rng(2)
    a = jnp.asarray(rng.normal(size=(56, 200)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(56, 200)), jnp.float32)
    out = add(a, b, dict(t_x=3, t_y=1, t_z=2, w_x=2, w_y=3))
    np.testing.assert_allclose(out, a + b, rtol=1e-6)
