"""The persistent cross-process compile cache (``repro.pallas_bench
.compile_cache``) and its integration into ``PallasMeasurement``.

Covers the file protocol in isolation (atomic entries, fingerprint misses,
claim/steal/wait, exactly-once ``compute``), true cross-process contention
(two subprocesses hammering the same keys compute each exactly once), the
acceptance criterion that a COLD process re-running against a warm cache
directory reports ``n_compiles == 0``, and the provenance promise that the
``compile_cache`` knob never reaches cache keys or journal namespaces.
"""

import json
import os
import subprocess
import sys
import threading
import time

import numpy as np
import pytest

from repro.core import ExperimentDesign, TuningSession, TuningSpec
from repro.pallas_bench.compile_cache import (
    FORMAT_VERSION,
    CompileCache,
    runtime_fingerprint,
)
from repro.telemetry import for_run_dir, read_run

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")

#: explicit fingerprint so protocol tests never import jax
FP = {"format": FORMAT_VERSION, "jax": "test", "platform": "cpu",
      "device_kind": "fake"}


def cache(tmp_path, **kw) -> CompileCache:
    kw.setdefault("fingerprint", dict(FP))
    return CompileCache(str(tmp_path / "cc"), **kw)


# ------------------------------------------------------------------ keys


def test_key_stable_and_sensitive(tmp_path):
    c = cache(tmp_path)
    k = c.key(kernel="add", x=64, y=128, geometry=[2, 1, 2])
    assert k == c.key(kernel="add", x=64, y=128, geometry=[2, 1, 2])
    assert len(k) == 32 and int(k, 16) >= 0
    assert k != c.key(kernel="add", x=64, y=128, geometry=[2, 1, 4])
    assert k != c.key(kernel="harris", x=64, y=128, geometry=[2, 1, 2])
    # the runtime fingerprint is part of every key
    other = CompileCache(c.root, fingerprint={**FP, "jax": "other"})
    assert k != other.key(kernel="add", x=64, y=128, geometry=[2, 1, 2])


def test_runtime_fingerprint_has_jax_identity():
    fp = runtime_fingerprint()
    assert fp["format"] == FORMAT_VERSION
    assert fp["jax"] and fp["platform"] and fp["device_kind"]


# --------------------------------------------------------------- entries


def test_put_get_roundtrip_and_fingerprint_mismatch(tmp_path):
    c = cache(tmp_path)
    assert c.get("k") is None
    c.put("k", status="ok", artifact=b"blob")
    entry = c.get("k")
    assert entry["status"] == "ok" and entry["artifact"] == b"blob"
    c.put("bad", status="invalid", reason="vmem:9 > 1", stage="compile")
    assert c.get("bad")["reason"] == "vmem:9 > 1"
    # an entry written under a different runtime is a miss, never served
    other = CompileCache(c.root, fingerprint={**FP, "device_kind": "real"})
    assert other.get("k") is None
    assert c.get("k") is not None  # and the entry itself is untouched


def test_corrupt_entry_is_miss(tmp_path):
    c = cache(tmp_path)
    c.put("k", status="ok")
    with open(c._entry_path("k"), "wb") as f:
        f.write(b"\x80\x04 torn pickle")
    assert c.get("k") is None


# ---------------------------------------------------------------- claims


def test_claim_is_exclusive_until_released(tmp_path):
    c = cache(tmp_path)
    assert c.claim("k") is True
    assert c.claim("k") is False      # held
    c.release("k")
    assert c.claim("k") is True       # reclaimable after release
    c.release("k")
    c.release("k")                    # double-release is harmless


def test_stale_claim_is_stolen(tmp_path):
    c = cache(tmp_path, claim_timeout_s=0.05)
    assert c.claim("k")
    old = time.time() - 60
    os.utime(c._claim_path("k"), (old, old))
    # the dead holder's claim is removed and the caller inherits the compile
    assert c.claim("k") is True


def test_wait_times_out_then_serves_published_entry(tmp_path):
    c = cache(tmp_path, poll_s=0.01)
    assert c.claim("k")
    assert c.wait("k", timeout_s=0.05) is None   # holder never published

    def publish():
        time.sleep(0.05)
        c.put("k", status="ok")
        c.release("k")

    t = threading.Thread(target=publish)
    t.start()
    entry = c.wait("k", timeout_s=5.0)
    t.join()
    assert entry is not None and entry["status"] == "ok"


def test_wait_returns_when_holder_vanishes_without_entry(tmp_path):
    c = cache(tmp_path, poll_s=0.01)
    assert c.claim("k")

    def vanish():
        time.sleep(0.05)
        c.release("k")                 # died without ever publishing

    t = threading.Thread(target=vanish)
    t.start()
    assert c.wait("k", timeout_s=5.0) is None
    t.join()


# --------------------------------------------------------------- compute


def test_compute_serves_and_computes_exactly_once(tmp_path):
    c = cache(tmp_path)
    calls = []

    def fn():
        calls.append(1)
        return {"status": "ok", "artifact": b"x"}

    entry, computed = c.compute("k", fn)
    assert computed is True and entry["artifact"] == b"x"
    entry, computed = c.compute("k", fn)
    assert computed is False and entry["artifact"] == b"x"
    assert len(calls) == 1
    assert not os.path.exists(c._claim_path("k"))  # claim released


def test_compute_double_checks_under_the_claim(tmp_path):
    """The get -> claim race: another process publishes (and releases) the
    key between our miss and our successful claim.  The post-claim re-read
    must serve that entry instead of recomputing."""
    c = cache(tmp_path)
    c.put("k", status="ok", artifact=b"theirs")

    class RacyCache(CompileCache):
        """First ``get`` misses — as if the entry landed a moment later."""

        missed = False

        def get(self, key):
            if not RacyCache.missed:
                RacyCache.missed = True
                return None
            return super().get(key)

    racy = RacyCache(c.root, fingerprint=dict(FP))
    entry, computed = racy.compute(
        "k", lambda: pytest.fail("recomputed a published key")
    )
    assert computed is False and entry["artifact"] == b"theirs"


def test_compute_falls_back_locally_when_holder_wedges(tmp_path):
    c = cache(tmp_path, poll_s=0.01)
    assert c.claim("k")               # a wedged holder that never publishes
    fast = CompileCache(c.root, fingerprint=dict(FP), poll_s=0.01,
                        claim_timeout_s=0.05)
    # the claim is fresh (not stale) but wait() times out -> local compute
    # without publishing: correctness over dedup when a peer wedges
    entry, computed = fast.compute("k", lambda: {"status": "ok"})
    assert computed is True and entry["status"] == "ok"
    assert fast.get("k") is None      # nothing published over the claim


CONTENTION_SCRIPT = """
import json, sys, time
from repro.pallas_bench.compile_cache import CompileCache, FORMAT_VERSION

FP = {"format": FORMAT_VERSION, "jax": "test", "platform": "cpu",
      "device_kind": "fake"}
cc = CompileCache(sys.argv[1], fingerprint=FP, poll_s=0.01)
computed = 0
for i in range(6):
    def fn(i=i):
        time.sleep(0.2)
        return {"status": "ok", "artifact": ("art%d" % i).encode()}
    entry, here = cc.compute("key%d" % i, fn)
    assert entry["artifact"] == ("art%d" % i).encode(), entry
    computed += bool(here)
print(json.dumps(computed))
"""


def test_two_processes_share_the_cache_without_double_compiles(tmp_path):
    """Two concurrent processes computing the same 6 keys: every key is
    computed exactly once across both (claims + the post-claim double-check
    make the dedup exact, not best-effort), and nobody corrupts anybody."""
    root = str(tmp_path / "cc")
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    procs = [
        subprocess.Popen(
            [sys.executable, "-c", CONTENTION_SCRIPT, root],
            stdout=subprocess.PIPE, stderr=subprocess.PIPE,
            text=True, cwd=REPO, env=env,
        )
        for _ in range(2)
    ]
    outs = [p.communicate(timeout=120) for p in procs]
    assert all(p.returncode == 0 for p in procs), outs
    computed = [json.loads(out.strip().splitlines()[-1]) for out, _ in outs]
    assert sum(computed) == 6, (computed, outs)
    cc = CompileCache(root, fingerprint=dict(FP))
    for i in range(6):
        assert cc.get(f"key{i}")["artifact"] == f"art{i}".encode()
        assert not os.path.exists(cc._claim_path(f"key{i}"))


# --------------------------------------- pallas integration, cold process


PALLAS_SCRIPT = """
import itertools, json, sys
from repro.pallas_bench import PallasMeasurement, make_workload

ticks = itertools.count()
m = PallasMeasurement(
    make_workload("add", x=64, y=128), repeats=1, warmup=1,
    compile_cache=sys.argv[1], timer=lambda: float(next(ticks)),
)
cfgs = [
    dict(t_x=2, t_y=1, t_z=2, w_x=1, w_y=1, w_z=1),
    dict(t_x=1, t_y=1, t_z=1, w_x=1, w_y=1, w_z=1),
]
vals = [float(m.measure(c)) for c in cfgs]
prov = m.provenance()
print(json.dumps({
    "n_compiles": m.n_compiles,
    "hits": m.run_pcache_hits,
    "vals": vals,
    "prov_cache": prov["compile_cache"],
    "prov_hits": prov["n_pcache_hits"],
}))
"""


def run_pallas_process(root: str) -> dict:
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, "-c", PALLAS_SCRIPT, root],
        capture_output=True, text=True, cwd=REPO, env=env, timeout=300,
    )
    assert out.returncode == 0, out.stderr
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_cold_process_rerun_against_warm_cache_compiles_nothing(tmp_path):
    """The acceptance criterion: a brand-new PROCESS (no in-memory state at
    all) re-running against a warm cache directory reports ``n_compiles ==
    0`` — every geometry is served from disk, values identical."""
    root = str(tmp_path / "cc")
    first = run_pallas_process(root)
    assert first["n_compiles"] == 2 and first["hits"] == 0
    second = run_pallas_process(root)
    assert second["n_compiles"] == 0, second
    assert second["hits"] == 2
    assert second["vals"] == first["vals"]   # deterministic timer: identical
    assert second["prov_cache"] is True and second["prov_hits"] == 2


# ------------------------------------------------- provenance exclusions


def test_compile_cache_knob_never_reaches_provenance_namespaces(tmp_path):
    plain = matrix_spec(str(tmp_path / "a.json"))
    knobbed = plain.replace(
        backend_kwargs={**plain.backend_kwargs,
                        "compile_cache": str(tmp_path / "cc"),
                        "pipeline_workers": 2}
    )
    assert knobbed.default_cache_key() == plain.default_cache_key()
    s_plain, s_knobbed = TuningSession(plain), TuningSession(knobbed)
    assert s_knobbed.cache_key == s_plain.cache_key
    ns_plain, ns_knobbed = (
        s_plain.journal_namespace(), s_knobbed.journal_namespace()
    )
    assert ns_plain is not None
    assert ns_knobbed == ns_plain


# ------------------------------------------------- matrix-level warm run


def matrix_spec(store_path: str) -> TuningSpec:
    from repro.core.space import Param, SearchSpace

    space = SearchSpace(
        [
            Param.int_range("t_x", 1, 2),
            Param.choice("t_y", (1,)),
            Param.int_range("t_z", 1, 2),
            Param.choice("w_x", (1,)),
            Param.choice("w_y", (1,)),
            Param.choice("w_z", (1,)),
        ]
    )
    return TuningSpec(
        kernel="add",
        searcher="rs",
        backend="pallas",
        backend_kwargs={"x": 64, "y": 128, "repeats": 1, "warmup": 1},
        space=space,
        algorithms=("rs",),
        design=ExperimentDesign(
            sample_sizes=(3,), n_experiments=(2,), final_repeats=1
        ),
        seed=0,
        store="json",
        store_path=store_path,
    )


def test_matrix_warm_cache_rerun_reports_zero_compiles(tmp_path):
    """End to end through ``run_matrix(compile_cache=...)``: the second run
    uses a FRESH measurement store (so every config is re-measured, nothing
    is served from the store) yet compiles nothing — the persistent cache
    alone absorbs every compile, and the telemetry totals prove it."""
    cc_dir = str(tmp_path / "cc")

    run1_dir = str(tmp_path / "run1")
    tel1 = for_run_dir(run1_dir)
    s1 = TuningSession(matrix_spec(str(tmp_path / "a.json")), telemetry=tel1)
    res1 = s1.run_matrix(compile_cache=cc_dir)
    tel1.close()
    totals1 = [e for e in read_run(run1_dir) if e["ev"] == "totals"][-1]["counters"]
    assert totals1.get("compiles", 0) > 0
    assert totals1.get("pcache.stores", 0) > 0

    run2_dir = str(tmp_path / "run2")
    tel2 = for_run_dir(run2_dir)
    s2 = TuningSession(matrix_spec(str(tmp_path / "b.json")), telemetry=tel2)
    res2 = s2.run_matrix(compile_cache=cc_dir)
    tel2.close()
    totals2 = [e for e in read_run(run2_dir) if e["ev"] == "totals"][-1]["counters"]
    assert totals2.get("compiles", 0) == 0, totals2
    assert totals2.get("pcache.hits", 0) > 0

    # same matrix shape came back (values are fresh wall-clock timings — the
    # cache serves the same compiled program, not the same measurements)
    assert set(res2.cells) == set(res1.cells)
    for key in res1.cells:
        assert np.isfinite(res2.cells[key].final_values).all()


def test_compile_cache_requires_staged_backend(tmp_path):
    from repro.core.space import Param, SearchSpace

    spec = TuningSpec(
        kernel="k", backend="callable",
        space=SearchSpace([Param("a", (1, 2))]),
        algorithms=("rs",),
        design=ExperimentDesign(sample_sizes=(2,), n_experiments=(1,)),
    )
    with pytest.raises(ValueError, match="compile_cache"):
        TuningSession(spec).run_matrix(compile_cache=str(tmp_path / "cc"))
