"""Tests for the ``tools/`` CLIs — currently ``compare_stores``.

The executor layer's byte-identity contract is only as trustworthy as the
tool that checks it, so the tool gets its own tests: identical stores exit
0, a single-ulp value divergence exits nonzero *and names the offending
key*, and the json/sqlite loaders agree.
"""

from __future__ import annotations

import importlib.util
import os
import sys

import pytest

from repro.core.stores import MeasurementStore, SqliteMeasurementStore

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def load_tool():
    path = os.path.join(REPO, "tools", "compare_stores.py")
    spec = importlib.util.spec_from_file_location("compare_stores", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


@pytest.fixture(scope="module")
def tool():
    return load_tool()


def write_store(path: str, entries: dict[str, float]):
    store = (
        SqliteMeasurementStore(path)
        if path.endswith(".sqlite")
        else MeasurementStore(path)
    )
    for k, v in entries.items():
        store.put(k, v)
    store.save()
    return store


ENTRIES = {"k/seed=1|a=1": 0.25, "k/seed=1|a=2": 0.5, "k/seed=2|a=1": 0.125}


def test_identical_stores_exit_zero(tool, tmp_path, capsys):
    a, b = str(tmp_path / "a.json"), str(tmp_path / "b.json")
    write_store(a, ENTRIES)
    write_store(b, ENTRIES)
    assert tool.main([a, b]) == 0
    out = capsys.readouterr().out
    assert "IDENTICAL" in out
    assert f"{len(ENTRIES)} measurement entries" in out


def test_value_divergence_exits_nonzero_and_names_key(
    tool, tmp_path, capsys
):
    a, b = str(tmp_path / "a.json"), str(tmp_path / "b.json")
    write_store(a, ENTRIES)
    diverged = dict(ENTRIES)
    # one-byte divergence: the smallest representable nudge on one value
    diverged["k/seed=1|a=2"] = float.fromhex("0x1.0000000000001p-1")
    write_store(b, diverged)
    assert tool.main([a, b]) == 1
    out = capsys.readouterr().out
    assert "DIFFER" in out
    assert "value mismatch: k/seed=1|a=2" in out
    # the untouched keys are NOT reported
    assert "k/seed=1|a=1" not in out.replace("k/seed=1|a=2", "")


def test_missing_key_reported_by_side(tool, tmp_path, capsys):
    a, b = str(tmp_path / "a.json"), str(tmp_path / "b.json")
    write_store(a, ENTRIES)
    only_b = dict(ENTRIES)
    extra = only_b.pop("k/seed=2|a=1")
    write_store(b, {**only_b, "k/seed=9|fresh": extra})
    assert tool.main([a, b]) == 1
    out = capsys.readouterr().out
    assert "only in A: k/seed=2|a=1" in out
    assert "only in B: k/seed=9|fresh" in out


def test_sqlite_and_json_stores_compare(tool, tmp_path, capsys):
    a, b = str(tmp_path / "a.json"), str(tmp_path / "b.sqlite")
    write_store(a, ENTRIES)
    write_store(b, ENTRIES)
    assert tool.main([a, b]) == 0
    assert "IDENTICAL" in capsys.readouterr().out


def test_meta_key_sets_compared(tool, tmp_path, capsys):
    a, b = str(tmp_path / "a.json"), str(tmp_path / "b.json")
    sa = write_store(a, ENTRIES)
    sb = write_store(b, ENTRIES)
    sa.put_meta("unit|x", "done")
    sa.save()
    assert tool.main([a, b]) == 0          # values still identical
    capsys.readouterr()
    assert tool.main([a, b, "--meta"]) == 1
    assert "META KEYS DIFFER" in capsys.readouterr().out
    sb.put_meta("unit|x", "done too")      # meta VALUES may differ freely
    sb.save()
    assert tool.main([a, b, "--meta"]) == 0


def test_missing_file_raises(tool, tmp_path):
    a = str(tmp_path / "a.json")
    write_store(a, ENTRIES)
    with pytest.raises(FileNotFoundError):
        tool.main([a, str(tmp_path / "nope.json")])


if __name__ == "__main__":
    sys.exit(pytest.main([__file__, "-q"]))
