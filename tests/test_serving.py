"""Tuning-as-a-service: the winners index, the query API, the HTTP
endpoint, the job queue, and the fleet loop.

The serving layer's load-bearing promises, each pinned here:

* winners survive save/load round-trips in BOTH store backends, and the
  merge policy (lower value wins, ties keep newer, freshness never moves
  backwards) holds however records race;
* ``best_config`` resolves hit / stale / nearest / miss deterministically,
  misses enqueue idempotent jobs, and the HTTP endpoint is the same
  function over a socket;
* concurrent readers — threads in-process plus spawned subprocesses —
  never see a torn winner while a writer updates the index (WAL-mode
  sqlite + atomic payload merges), and freshness observed by any single
  reader is monotonic;
* a fleet worker drains a miss-enqueued job into a store the collector
  absorbs, after which the same query is a hit.
"""

from __future__ import annotations

import json
import os
import sqlite3
import subprocess
import sys
import threading
import time
import urllib.error
import urllib.request

import pytest

from repro.core import ExperimentDesign, TuningSession, TuningSpec
from repro.core.stores import (
    MeasurementStore,
    SqliteMeasurementStore,
    absorb_winners,
    make_store,
    merge_winner_payloads,
)
from repro.serving import (
    FleetWorker,
    JobQueue,
    ServeResult,
    WinnerRecord,
    best_config,
    collect_jobs,
    default_miss_spec,
    index_winners,
    job_id_for_spec,
    lookup_winner,
    nearest_winner,
    record_winner,
)
from repro.serving.http import ServingState, make_server
from repro.serving.winners import (
    parse_config_from_store_key,
    parse_winner_key,
    winner_key,
)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def rec(kernel="add", x=8192, y=8192, device="v5e", value=1.0, fresh=100.0,
        config=None, **kw) -> WinnerRecord:
    return WinnerRecord(kernel=kernel, x=x, y=y, device=device,
                        config=config or {"t_x": 4}, value=value,
                        fresh=fresh, **kw)


# ------------------------------------------------------------- key + payload


def test_winner_key_roundtrip():
    key = winner_key("harris", 4096, 2048, "v4")
    assert key == "harris|x=4096|y=2048|v4"
    assert parse_winner_key(key) == ("harris", 4096, 2048, "v4")
    assert parse_winner_key("not-a-winner-key") is None
    assert parse_winner_key("k|x=a|y=2|d") is None


def test_parse_config_from_store_key_skips_final_marker():
    cfg = parse_config_from_store_key(
        "add/v5e/seed=17|t_x=9,t_y=16,w_x=3.5,name=foo|final3"
    )
    assert cfg == {"t_x": 9, "t_y": 16, "w_x": 3.5, "name": "foo"}
    assert parse_config_from_store_key("no-config-here") is None


def test_merge_policy_lower_value_wins():
    worse = rec(value=2.0, fresh=50.0).to_payload()
    better = rec(value=1.0, fresh=10.0).to_payload()
    for old, new in ((worse, better), (better, worse)):
        merged = json.loads(merge_winner_payloads(old, new))
        assert merged["value"] == 1.0
        # freshness is monotonic even when the older record's config wins
        assert merged["fresh"] == 50.0


def test_merge_policy_tie_keeps_newer_config():
    a = rec(value=1.0, fresh=10.0, config={"t_x": 1}).to_payload()
    b = rec(value=1.0, fresh=20.0, config={"t_x": 2}).to_payload()
    assert json.loads(merge_winner_payloads(a, b))["config"] == {"t_x": 2}
    assert json.loads(merge_winner_payloads(b, a))["config"] == {"t_x": 2}


def test_merge_policy_unparseable_loses():
    good = rec(value=5.0).to_payload()
    assert merge_winner_payloads("not json{", good) == good
    assert merge_winner_payloads(None, good) == good
    merged = json.loads(merge_winner_payloads(good, "not json{"))
    assert merged["value"] == 5.0


# ------------------------------------------------------ store round-tripping


@pytest.mark.parametrize("kind", ["json", "sqlite"])
def test_winners_survive_save_load(tmp_path, kind):
    path = str(tmp_path / f"s.{'sqlite' if kind == 'sqlite' else 'json'}")
    store = make_store(kind, path)
    store.put("add/v5e/seed=1|t_x=4", 0.5)
    r = rec(value=0.5, fresh=123.0)
    store.put_winner(r.key, r.to_payload())
    store.save()
    if hasattr(store, "close"):
        store.close()

    reopened = make_store(kind, path)
    got = lookup_winner(reopened, "add", 8192, 8192, "v5e")
    assert got is not None
    assert (got.value, got.fresh, got.config) == (0.5, 123.0, {"t_x": 4})
    assert reopened.get("add/v5e/seed=1|t_x=4") == 0.5
    assert dict(reopened.winner_items()) == {r.key: r.to_payload()}
    if hasattr(reopened, "close"):
        reopened.close()


def test_json_store_without_winners_keeps_legacy_format(tmp_path):
    path = str(tmp_path / "s.json")
    store = MeasurementStore(path)
    store.put("k", 1.0)
    store.save()
    assert "winners" not in json.load(open(path))
    store.put_winner("add|x=1|y=1|d", rec().to_payload())
    store.save()
    assert json.load(open(path))["__format__"] == 3


def test_record_winner_applies_merge_policy_in_store(tmp_path):
    # put_winner is deliberately last-writer-wins (a raw channel); the merge
    # policy is record_winner's job, in both backends
    for kind in ("json", "sqlite"):
        store = make_store(kind, None)
        record_winner(store, rec(value=1.0, fresh=10.0), save=False)
        record_winner(store, rec(value=2.0, fresh=99.0), save=False)
        kept = json.loads(store.get_winner(rec().key))
        assert kept["value"] == 1.0 and kept["fresh"] == 99.0


def test_absorb_winners_merges(tmp_path):
    dst, src = make_store("json", None), make_store("sqlite", None)
    dst.put_winner("k|x=1|y=1|d", rec(value=2.0, fresh=1.0).to_payload())
    src.put_winner("k|x=1|y=1|d", rec(value=1.0, fresh=2.0).to_payload())
    src.put_winner("k|x=2|y=2|d", rec(x=2, y=2, value=3.0).to_payload())
    absorb_winners(dst, src)
    assert json.loads(dst.get_winner("k|x=1|y=1|d"))["value"] == 1.0
    assert len(dict(dst.winner_items())) == 2


def test_index_winners_counts_and_merges():
    dst, a, b = (make_store("json", None) for _ in range(3))
    a.put_winner("k|x=1|y=1|d", rec(value=2.0).to_payload())
    b.put_winner("k|x=1|y=1|d", rec(value=1.0).to_payload())
    assert index_winners(dst, a, save=False) == 1
    assert index_winners(dst, b, save=False) == 1
    assert json.loads(dst.get_winner("k|x=1|y=1|d"))["value"] == 1.0


# --------------------------------------------------- session -> winners index


SMOKE_SPEC = TuningSpec(
    kernel="add",
    backend_kwargs={"chip": "v5e"},
    algorithms=("rs",),
    design=ExperimentDesign(
        sample_sizes=(25,), n_experiments=(4,), final_repeats=3
    ),
    seed=11,
)


def test_session_records_winner_transactionally(tmp_path):
    spec = SMOKE_SPEC.replace(store="json",
                              store_path=str(tmp_path / "c.json"))
    session = TuningSession(spec)
    session.run_matrix()
    store = MeasurementStore(spec.store_path)
    got = lookup_winner(store, "add", 8192, 8192, "v5e")
    assert got is not None
    # the winner points at a measurement the same store actually holds
    assert store.get(got.store_key) == got.value
    assert got.value == min(v for k, v in store.items() if "|final" in k)
    assert got.config == parse_config_from_store_key(got.store_key)
    assert got.fingerprint == session.journal_namespace()
    assert got.fresh > 0


# ------------------------------------------------------------------- serving


def serve_store_with(records) -> object:
    store = make_store("json", None)
    for r in records:
        store.put_winner(r.key, r.to_payload())
    return store


def test_best_config_hit_stale_nearest_miss():
    store = serve_store_with([
        rec(x=8192, y=8192, value=1.0, fresh=1000.0),
        rec(x=1024, y=1024, value=2.0, fresh=1000.0),
    ])
    hit = best_config(store, "add", 8192, 8192, "v5e", now=1010.0)
    assert (hit.status, hit.value, hit.age_s) == ("hit", 1.0, 10.0)
    assert hit.matched_key == "add|x=8192|y=8192|v5e"

    stale = best_config(store, "add", 8192, 8192, "v5e", max_age_s=5.0,
                        now=1010.0)
    assert stale.status == "stale" and stale.config == hit.config

    near = best_config(store, "add", 2048, 2048, "v5e")
    assert near.status == "nearest"
    assert near.matched_key == "add|x=1024|y=1024|v5e"  # closer in log-space

    for kernel, device in (("harris", "v5e"), ("add", "v4")):
        assert best_config(store, kernel, 8192, 8192, device).status == "miss"


def test_nearest_is_log_space_and_deterministic():
    store = serve_store_with([
        rec(x=4096, y=4096, value=1.0),   # 2x down from 8192
        rec(x=32768, y=32768, value=2.0)  # 4x up
    ])
    near = nearest_winner(store, "add", 8192, 8192, "v5e")
    assert near.x == 4096


def test_miss_enqueues_idempotent_job(tmp_path):
    store = make_store("sqlite", str(tmp_path / "s.sqlite"))
    queue = JobQueue(store, "sqlite", str(tmp_path / "s.sqlite"),
                     str(tmp_path / "q"))
    res = best_config(store, "add", 8192, 8192, "v5e", queue=queue)
    assert res.status == "miss" and res.job_id is not None
    again = best_config(store, "add", 8192, 8192, "v5e", queue=queue)
    assert again.job_id == res.job_id
    assert queue.depth() == 1
    job = queue.job(res.job_id)
    assert job["state"] == "pending"
    assert job["spec"]["kernel"] == "add"
    store.close()


def test_default_miss_spec_backend_split():
    cm = default_miss_spec("add", 8192, 8192, "v4")
    assert cm.backend == "costmodel"
    assert cm.backend_kwargs == {"chip": "v4"}
    pl = default_miss_spec("add", 512, 256, "tpu-v5e")
    assert pl.backend == "pallas"
    assert pl.backend_kwargs == {"x": 512, "y": 256}


def test_serve_result_dict_shape():
    d = ServeResult(status="miss", kernel="k", x=1, y=2, device="d").to_dict()
    assert d["status"] == "miss" and d["config"] is None and d["job_id"] is None


# ---------------------------------------------------------------------- http


def test_http_endpoint(tmp_path):
    store = serve_store_with([rec(value=1.5, fresh=100.0)])
    queue = JobQueue(store, "json", str(tmp_path / "s.json"),
                     str(tmp_path / "q"))
    server = make_server(ServingState(store, queue=queue), port=0)
    host, port = server.server_address[:2]
    t = threading.Thread(target=server.serve_forever, daemon=True)
    t.start()
    try:
        def get(path):
            with urllib.request.urlopen(f"http://{host}:{port}{path}") as r:
                return r.status, json.loads(r.read())

        assert get("/healthz") == (200, {"ok": True})

        code, body = get("/best_config?kernel=add&x=8192&y=8192&device=v5e")
        assert code == 200
        assert body["status"] == "hit" and body["value"] == 1.5

        code, body = get("/best_config?kernel=nope&x=4&y=4&device=v5e")
        assert code == 200 and body["status"] == "miss"
        assert body["job_id"]  # queue attached: the miss enqueued a job

        code, body = get("/stats")
        assert code == 200 and body["winners"] == 1
        assert body["queue_depth"] == 1

        with pytest.raises(urllib.error.HTTPError) as err:
            urllib.request.urlopen(
                f"http://{host}:{port}/best_config?kernel=add&x=nope&y=1&device=d"
            )
        assert err.value.code == 400
    finally:
        server.shutdown()
        server.server_close()


# -------------------------------------------------------- concurrent serving


HAMMER = r"""
import json, sys
sys.path.insert(0, sys.argv[1])
from repro.core.stores import make_store
from repro.serving import best_config
store = make_store("sqlite", sys.argv[2])
last_fresh = 0.0
for _ in range(120):
    res = best_config(store, "add", 8192, 8192, "v5e")
    if res.status != "hit":
        sys.exit(f"unexpected status {res.status}")
    # consistency: value and config were written as one payload; a torn
    # read would decouple them
    if res.config["i"] != int(round(1000.0 - res.value)):
        sys.exit(f"torn read: value={res.value} config={res.config}")
    if res.fresh < last_fresh:
        sys.exit(f"freshness went backwards: {res.fresh} < {last_fresh}")
    last_fresh = res.fresh
store.close()
print("ok")
"""


def test_concurrent_readers_never_see_torn_winners(tmp_path):
    """N reader threads + 2 spawned reader subprocesses hammer
    ``best_config`` while a writer thread rewrites the winner through the
    merge policy.  Every observed record must be internally consistent
    (value matches config — they're written as one payload) and each
    reader's observed freshness must be monotonic."""
    path = str(tmp_path / "serve.sqlite")
    seed_store = SqliteMeasurementStore(path, autosave_every=0)

    # sqlite serving store runs WAL with a busy timeout (the concurrency
    # contract): verify the pragmas actually took
    assert seed_store._conn.execute(
        "PRAGMA journal_mode").fetchone()[0].lower() == "wal"
    assert seed_store._conn.execute(
        "PRAGMA busy_timeout").fetchone()[0] == 5000

    def winner_at(i: int) -> WinnerRecord:
        # decreasing value => each update wins the merge; fresh stamps are
        # record_winner's wall clock, which only moves forward
        return rec(value=1000.0 - i, config={"i": i})

    record_winner(seed_store, winner_at(0))
    seed_store.close()

    stop = threading.Event()
    errors: list[str] = []

    def writer():
        # sqlite connections are thread-bound: the writer owns its handle
        store = SqliteMeasurementStore(path, autosave_every=0)
        i = 0
        try:
            while not stop.is_set():
                i += 1
                record_winner(store, winner_at(i))
        finally:
            store.close()

    def reader():
        store = SqliteMeasurementStore(path)
        last_fresh = 0.0
        try:
            for _ in range(200):
                res = best_config(store, "add", 8192, 8192, "v5e")
                if res.status != "hit":
                    errors.append(f"status {res.status}")
                    return
                if res.config["i"] != int(round(1000.0 - res.value)):
                    errors.append(f"torn: {res.value} vs {res.config}")
                    return
                if res.fresh < last_fresh:
                    errors.append(f"fresh regressed {res.fresh}<{last_fresh}")
                    return
                last_fresh = res.fresh
        finally:
            store.close()

    wt = threading.Thread(target=writer)
    readers = [threading.Thread(target=reader) for _ in range(4)]
    procs = [
        subprocess.Popen(
            [sys.executable, "-c", HAMMER, os.path.join(REPO, "src"), path],
            stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True,
        )
        for _ in range(2)
    ]
    wt.start()
    for r in readers:
        r.start()
    for r in readers:
        r.join(timeout=120)
    outs = [p.communicate(timeout=120)[0] for p in procs]
    stop.set()
    wt.join(timeout=120)

    assert errors == []
    for p, out in zip(procs, outs, strict=True):
        assert p.returncode == 0, out
        assert "ok" in out


# ------------------------------------------------------------------ JobQueue


def test_job_id_ignores_storage_fields():
    a = default_miss_spec("add", 8192, 8192, "v5e").to_dict()
    b = dict(a, store="sqlite", store_path="/somewhere/else.sqlite")
    assert job_id_for_spec(a) == job_id_for_spec(b)
    c = dict(a, kernel="harris")
    assert job_id_for_spec(a) != job_id_for_spec(c)


def queue_at(tmp_path, name="q") -> JobQueue:
    return JobQueue(make_store("json", None), "json",
                    str(tmp_path / "s.json"), str(tmp_path / name),
                    claim_timeout_s=0.2)


def test_claim_is_exclusive_then_released(tmp_path):
    q = queue_at(tmp_path)
    assert q.claim_unit("j1", "rs/S25/E4/e0:4", "w1") == "fresh"
    assert q.claim_unit("j1", "rs/S25/E4/e0:4", "w2") is None
    assert q.unit_claimed("j1", "rs/S25/E4/e0:4")
    q.release_unit("j1", "rs/S25/E4/e0:4")
    assert q.claim_unit("j1", "rs/S25/E4/e0:4", "w2") == "fresh"


def test_stale_claim_is_stolen(tmp_path):
    q = queue_at(tmp_path)
    assert q.claim_unit("j1", "u", "victim") == "fresh"
    path = q._claim_path("j1", "u")
    old = time.time() - 60.0
    os.utime(path, (old, old))    # the victim "died" a minute ago
    assert q.claim_unit("j1", "u", "peer") == "stolen"
    assert open(path).read() == "peer"


def test_heartbeat_prevents_steal(tmp_path):
    q = queue_at(tmp_path)
    q.claim_unit("j1", "u", "w1")
    path = q._claim_path("j1", "u")
    old = time.time() - 60.0
    os.utime(path, (old, old))
    q.heartbeat_unit("j1", "u")   # long unit, still alive
    assert q.claim_unit("j1", "u", "peer") is None


def test_done_markers_are_atomic_json(tmp_path):
    q = queue_at(tmp_path)
    assert q.unit_done("j1", "u") is None
    q.write_unit_done("j1", "u", {"ident": "w1", "stolen": False})
    assert q.unit_done("j1", "u") == {"ident": "w1", "stolen": False}
    q.cleanup_job_files("j1")
    assert q.unit_done("j1", "u") is None
    assert not any(f.startswith("j1.") for f in os.listdir(q.qdir))


def test_mark_done_persists_through_store(tmp_path):
    path = str(tmp_path / "s.json")
    store = make_store("json", path)
    q = JobQueue(store, "json", path, str(tmp_path / "q"))
    jid = q.enqueue(SMOKE_SPEC)
    assert [j["id"] for j in q.pending_jobs()] == [jid]
    q.mark_done(jid, ident="collect")
    assert q.pending_jobs() == [] and q.job(jid)["state"] == "done"
    # a fresh handle sees it too — the record rode the store
    q2 = JobQueue(make_store("json", path), "json", path, str(tmp_path / "q"))
    assert q2.job(jid)["state"] == "done"


# ------------------------------------------------------------- fleet end2end


def test_fleet_fills_a_miss_end_to_end(tmp_path):
    """miss -> enqueue -> one fleet worker drains -> collect -> hit, with
    the collected measurements byte-identical to a serial run."""
    path = str(tmp_path / "serve.sqlite")
    store = make_store("sqlite", path)
    queue = JobQueue(store, "sqlite", path, str(tmp_path / "queue"))
    spec = SMOKE_SPEC
    res = best_config(store, "add", 8192, 8192, "v5e", queue=queue,
                      enqueue_spec=spec)
    assert res.status == "miss" and res.job_id
    store.close()

    worker = FleetWorker("sqlite", path, str(tmp_path / "queue"), ident="w1")
    assert worker.drain(max_jobs=1, timeout_s=120.0) == 1
    collected = collect_jobs("sqlite", path, str(tmp_path / "queue"))
    assert collected == [res.job_id]

    store = make_store("sqlite", path)
    hit = best_config(store, "add", 8192, 8192, "v5e")
    assert hit.status == "hit"
    assert hit.fingerprint  # provenance rode along
    q = JobQueue(store, "sqlite", path, str(tmp_path / "queue"))
    assert q.depth() == 0 and q.job(res.job_id)["state"] == "done"

    # byte-identity vs the serial reference
    serial = TuningSession(
        spec.replace(store="json", store_path=str(tmp_path / "serial.json"))
    )
    serial.run_matrix()
    fleet_values = {
        k: v for k, v in store.items() if not k.startswith("__")
    }
    serial_values = dict(MeasurementStore(str(tmp_path / "serial.json")).items())
    assert fleet_values == serial_values
    store.close()


# --------------------------------------------------------- staticcheck knobs


def test_staticcheck_sets_cover_serving_knobs():
    """The serving layer's pacing/plumbing knobs are registered with the
    static gate: PROV001 guards fleet pacing out of provenance, OBS001
    keeps serve-dir plumbing out of identity sinks."""
    from repro.staticcheck.obs import TELEMETRY_TOKENS
    from repro.staticcheck.prov import SPEED_KNOBS

    assert {"claim_timeout_s", "poll_s", "stall_s"} <= set(SPEED_KNOBS)
    assert {"serve_dir", "qdir", "queue_dir"} <= set(TELEMETRY_TOKENS)
