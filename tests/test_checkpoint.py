"""Checkpointing + fault-tolerant runner: roundtrip, atomicity, resume,
failure injection, straggler detection, elastic reshard."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import AsyncCheckpointer, latest_step, restore, save
from repro.configs import REGISTRY
from repro.data import DataConfig, make_train_batch
from repro.models import build_model, init_params, param_axes
from repro.runtime import (
    InjectedFailure,
    RunnerConfig,
    TrainingRunner,
    degraded_mesh,
    reshard,
)
from repro.sharding import ShardingRules
from repro.train import TrainSettings, init_train_state, make_train_step

RNG = jax.random.PRNGKey(0)


def _state():
    cfg = REGISTRY["mamba2-130m"].reduced()
    model = build_model(cfg)
    return cfg, model, init_train_state(model, init_params(model.spec(), RNG))


def test_save_restore_roundtrip(tmp_path):
    _, _, state = _state()
    d = str(tmp_path / "ckpt")
    save(d, 7, state)
    assert latest_step(d) == 7
    zeros = jax.tree_util.tree_map(jnp.zeros_like, state)
    restored, step = restore(d, zeros)
    assert step == 7
    for a, b in zip(jax.tree_util.tree_leaves(state),
                    jax.tree_util.tree_leaves(restored), strict=True):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_checkpointer_gc(tmp_path):
    _, _, state = _state()
    d = str(tmp_path / "ckpt")
    ck = AsyncCheckpointer(d, keep=2)
    for s in (1, 2, 3, 4):
        ck.save(s, state)
    ck.wait()
    steps = sorted(int(p.split("_")[1]) for p in os.listdir(d))
    assert steps == [3, 4]


def test_runner_failure_injection_and_resume(tmp_path):
    cfg, model, state = _state()
    step_fn = jax.jit(make_train_step(model, TrainSettings(remat="none")))
    dc = DataConfig(seed=0)
    make_batch = lambda s: make_train_batch(dc, cfg, 16, 2, s)
    d = str(tmp_path / "ckpt")

    runner = TrainingRunner(
        RunnerConfig(ckpt_dir=d, ckpt_every=3, fail_at_step=7), step_fn, make_batch
    )
    with pytest.raises(InjectedFailure):
        runner.run(state, n_steps=10)
    assert latest_step(d) == 6  # last periodic checkpoint before the crash

    # 'restart the job': fresh runner, no failure -> resumes from step 6
    runner2 = TrainingRunner(RunnerConfig(ckpt_dir=d, ckpt_every=3), step_fn, make_batch)
    final_state, report = runner2.run(state, n_steps=10)
    assert report.restored_from == 6
    assert report.steps_run == 4  # 6 -> 10
    assert latest_step(d) == 10


def test_runner_restart_reproduces_uninterrupted_run(tmp_path):
    """Crash + resume must land on the SAME weights as a run that never
    crashed (pure-function-of-step data pipeline + checkpoint fidelity)."""
    cfg, model, state0 = _state()
    step_fn = jax.jit(make_train_step(model, TrainSettings(remat="none")))
    dc = DataConfig(seed=0)
    make_batch = lambda s: make_train_batch(dc, cfg, 16, 2, s)

    d1 = str(tmp_path / "a")
    r = TrainingRunner(RunnerConfig(ckpt_dir=d1, ckpt_every=2), step_fn, make_batch)
    ref_state, _ = r.run(state0, n_steps=6)

    d2 = str(tmp_path / "b")
    r1 = TrainingRunner(RunnerConfig(ckpt_dir=d2, ckpt_every=2, fail_at_step=4),
                        step_fn, make_batch)
    with pytest.raises(InjectedFailure):
        r1.run(state0, n_steps=6)
    r2 = TrainingRunner(RunnerConfig(ckpt_dir=d2, ckpt_every=2), step_fn, make_batch)
    resumed_state, _ = r2.run(state0, n_steps=6)

    for a, b in zip(jax.tree_util.tree_leaves(ref_state["params"]),
                    jax.tree_util.tree_leaves(resumed_state["params"]), strict=True):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-7)


def test_straggler_watchdog(tmp_path):
    cfg, model, state = _state()
    import time as _time

    calls = {"n": 0}
    inner = jax.jit(make_train_step(model, TrainSettings(remat="none")))

    def slow_step(st, batch):
        calls["n"] += 1
        out = inner(st, batch)
        jax.block_until_ready(out[1]["loss"])
        if calls["n"] == 9:
            _time.sleep(1.0)   # simulated straggler host
        return out

    dc = DataConfig(seed=0)
    runner = TrainingRunner(
        RunnerConfig(ckpt_dir=str(tmp_path / "c"), ckpt_every=100,
                     straggler_factor=3.0),
        slow_step,
        lambda s: make_train_batch(dc, cfg, 16, 2, s),
    )
    _, report = runner.run(state, n_steps=10)
    assert any(ev.step == 8 for ev in report.stragglers), report.stragglers


def test_elastic_reshard_smoke():
    """Sharding is derived, never stored: the same params re-place onto a
    degraded mesh."""
    cfg, model, state = _state()
    mesh = degraded_mesh(np.array(jax.devices()), lost_fraction=0.0)
    axes = param_axes(model.spec())
    moved = reshard(state["params"], axes, ShardingRules(), mesh)
    for a, b in zip(jax.tree_util.tree_leaves(state["params"]),
                    jax.tree_util.tree_leaves(moved), strict=True):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
