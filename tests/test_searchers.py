"""Searcher behaviour: budget exactness, determinism, constraint handling,
and relative quality on a smooth objective."""

import numpy as np
import pytest

from repro.core import (
    EXTRA_ALGORITHMS,
    PAPER_ALGORITHMS,
    CallableMeasurement,
    make_searcher,
    paper_space,
)

ALL = PAPER_ALGORITHMS + EXTRA_ALGORITHMS


def smooth(cfg):
    x = np.array([cfg["t_x"] / 16, cfg["t_y"] / 16, cfg["t_z"] / 16,
                  cfg["w_x"] / 8, cfg["w_y"] / 8, cfg["w_z"] / 8])
    target = np.array([0.5, 0.75, 0.25, 0.6, 0.9, 0.3])
    return 1.0 + float(((x - target) ** 2).sum())


@pytest.fixture(scope="module")
def space():
    return paper_space()


@pytest.mark.parametrize("algo", ALL)
@pytest.mark.parametrize("budget", [5, 25, 60])
def test_budget_never_exceeded(space, algo, budget):
    m = CallableMeasurement(smooth)
    r = make_searcher(algo, space, seed=0).run(m, budget)
    assert r.n_samples <= budget
    assert m.n_samples <= budget
    assert np.isfinite(r.best_value)
    assert set(r.best_config) == set(space.names)


@pytest.mark.parametrize("algo", ALL)
def test_deterministic_given_seed(space, algo):
    r1 = make_searcher(algo, space, seed=7).run(CallableMeasurement(smooth), 40)
    r2 = make_searcher(algo, space, seed=7).run(CallableMeasurement(smooth), 40)
    assert r1.best_value == r2.best_value
    assert r1.best_config == r2.best_config


@pytest.mark.parametrize("algo", ("rs", "rf", "ga", "sa", "pso", "grid"))
def test_constrained_searchers_respect_constraint(space, algo):
    seen = []

    def f(cfg):
        seen.append(cfg)
        return smooth(cfg)

    make_searcher(algo, space, seed=1).run(CallableMeasurement(f), 40)
    for cfg in seen:
        assert cfg["w_x"] * cfg["w_y"] * cfg["w_z"] <= 256


def test_smbo_ignores_constraints(space):
    """Paper section V.C: SMBO methods search the raw space."""
    s = make_searcher("bo_tpe", space, seed=0)
    assert s.space.constraint is None
    s = make_searcher("bo_gp", space, seed=0)
    assert s.space.constraint is None


def test_advanced_beat_random_on_smooth_objective(space):
    """On a smooth bowl with a healthy budget, BO/GA should beat RS on
    median over repeats (the paper's core expectation at S=100)."""
    def median_best(algo, n_rep=7, budget=100):
        vals = []
        for seed in range(n_rep):
            m = CallableMeasurement(smooth)
            vals.append(make_searcher(algo, space, seed=seed).run(m, budget).best_value)
        return float(np.median(vals))

    rs = median_best("rs")
    assert median_best("bo_gp") < rs
    assert median_best("bo_tpe") < rs
    assert median_best("ga") <= rs * 1.02  # GA at least matches RS here


def test_trajectory_monotone(space):
    m = CallableMeasurement(smooth)
    r = make_searcher("ga", space, seed=3).run(m, 60)
    traj = r.trajectory()
    assert (np.diff(traj) <= 1e-12).all()


def test_rf_result_comes_from_predictions(space):
    """Paper: RF stores the best of the 10 *predictions*, not the best
    training sample."""
    m = CallableMeasurement(smooth)
    s = make_searcher("rf", space, seed=5)
    r = s.run(m, 50)
    # best_value must equal one of the last 10 history entries
    tail = r.history_values[-10:]
    assert min(tail) == r.best_value
