"""Sharding rules + HLO analysis unit tests (single-device safe)."""

import jax
import numpy as np
from jax.sharding import Mesh, PartitionSpec

from repro.launch.hlo_analysis import (
    collective_stats,
    computation_multipliers,
    dot_flops,
    parse_computations,
    shape_bytes,
)
from repro.sharding import ShardingRules


def _mesh_1x1():
    dev = np.asarray(jax.devices()[:1]).reshape(1, 1)
    return Mesh(dev, ("data", "model"))


class _FakeAxis(dict):
    pass


class _FakeMesh:
    """Shape-only stand-in so rules can be tested for a 16x16 mesh without
    512 devices."""

    def __init__(self, shape: dict):
        self.shape = shape
        self.axis_names = tuple(shape)


def test_spec_for_divisible_dims():
    rules = ShardingRules()
    mesh = _FakeMesh({"data": 16, "model": 16})
    spec = rules.spec_for(("vocab", "embed"), (64000, 7168), mesh)
    assert spec == PartitionSpec("model", "data")


def test_spec_for_indivisible_falls_back():
    rules = ShardingRules()
    mesh = _FakeMesh({"data": 16, "model": 16})
    # 56 heads don't divide 16 -> replicated; head_dim 128 does
    spec = rules.spec_for(("embed", "heads", "head_dim"), (7168, 56, 128), mesh)
    assert spec[1] is None


def test_spec_for_never_reuses_axis():
    rules = ShardingRules()
    mesh = _FakeMesh({"data": 16, "model": 16})
    # batch takes data; kv_seq also wants data -> must stay unassigned
    spec = rules.spec_for(("batch", "kv_seq"), (128, 32768), mesh)
    assert spec[0] == "data"
    assert spec[1] is None


def test_spec_for_multi_axis_prefix():
    rules = ShardingRules()
    mesh = _FakeMesh({"pod": 2, "data": 16, "model": 16})
    # batch 2 divides pod only -> prefix fallback
    spec = rules.spec_for(("batch",), (2,), mesh)
    assert spec == PartitionSpec("pod")
    spec = rules.spec_for(("batch",), (1,), mesh)
    assert spec == PartitionSpec(None)


def test_overrides():
    rules = ShardingRules().with_overrides(kv_seq=())
    mesh = _FakeMesh({"data": 16, "model": 16})
    spec = rules.spec_for(("kv_seq",), (32768,), mesh)
    assert spec == PartitionSpec(None)


# --------------------------------------------------------- HLO analysis

_HLO = """\
HloModule test, entry_computation_layout={()->f32[]}

%body.1 (arg.1: (s32[], f32[128,256])) -> (s32[], f32[128,256]) {
  %arg.1 = (s32[], f32[128,256]{1,0}) parameter(0)
  %gte.1 = f32[128,256]{1,0} get-tuple-element(%arg.1), index=1
  %ag = f32[256,256]{1,0} all-gather(%gte.1), replica_groups={}, dimensions={0}
  %dot.1 = f32[128,256]{1,0} dot(%gte.1, %ag), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %tuple.1 = (s32[], f32[128,256]{1,0}) tuple(%gte.1, %dot.1)
}

%cond.1 (arg.2: (s32[], f32[128,256])) -> pred[] {
  %arg.2 = (s32[], f32[128,256]{1,0}) parameter(0)
  %gte.2 = s32[] get-tuple-element(%arg.2), index=0
  %k = s32[] constant(24)
  ROOT %cmp = pred[] compare(%gte.2, %k), direction=LT
}

ENTRY %main.1 () -> f32[] {
  %init = (s32[], f32[128,256]{1,0}) tuple()
  %while.1 = (s32[], f32[128,256]{1,0}) while(%init), condition=%cond.1, body=%body.1
  %ar = f32[128,256]{1,0} all-reduce(%init), to_apply=%cond.1
  ROOT %out = f32[] constant(0)
}
"""


def test_shape_bytes():
    assert shape_bytes("f32[128,256]{1,0}") == 128 * 256 * 4
    assert shape_bytes("(bf16[2,2], s32[])") == 8 + 4
    assert shape_bytes("pred[]") == 1


def test_parse_computations_and_multipliers():
    comps = parse_computations(_HLO)
    assert set(comps) == {"body.1", "cond.1", "main.1"}
    assert comps["main.1"].entry
    mult = computation_multipliers(comps)
    assert mult["body.1"] == 24
    assert mult["main.1"] == 1


def test_collective_stats_loop_corrected():
    cs = collective_stats(_HLO)
    ag = 256 * 256 * 4
    ar = (4 + 128 * 256 * 4)  # tuple shape of %init? no — all-reduce output
    assert cs["bytes"]["all-gather"] == ag * 24
    assert cs["bytes_uncorrected"]["all-gather"] == ag
    assert cs["counts"]["all-gather"] == 24
    assert cs["bytes"]["all-reduce"] == 128 * 256 * 4


def test_dot_flops_loop_corrected():
    d = dot_flops(_HLO)
    per = 2 * (128 * 256) * 256
    assert d["flops_uncorrected"] == per
    assert d["flops"] == per * 24


def test_build_step_single_device_mesh():
    """The dry-run machinery itself, on a 1x1 mesh with a reduced arch —
    exercises shardings, lowering and the analysis pipeline in-process."""

    from repro.configs import REGISTRY
    from repro.configs.base import ShapeCfg
    from repro.launch.dryrun import build_step

    mesh = _mesh_1x1()
    arch = REGISTRY["mamba2-130m"].reduced()
    shape = ShapeCfg("tiny_train", seq_len=64, global_batch=2, kind="train")
    with mesh:
        fn, args = build_step(arch, shape, mesh, ShardingRules())
        lowered = fn.lower(*args)
        compiled = lowered.compile()
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0]
        assert cost.get("flops", 0) > 0
        hlo = compiled.as_text()
        d = dot_flops(hlo)
        assert d["flops"] >= d["flops_uncorrected"] > 0
