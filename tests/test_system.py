"""End-to-end system tests: the paper's full pipeline at smoke scale."""

import numpy as np
import pytest

from repro.core import (
    ExperimentDesign,
    MatrixResults,
    SampleDataset,
    TuningSession,
    TuningSpec,
    stats,
)
from repro.costmodel import (
    CHIPS,
    WORKLOADS,
    CostModelMeasurement,
    executable_space,
    true_optimum,
)


@pytest.fixture(scope="module")
def smoke_matrix():
    w, chip = WORKLOADS["harris"], CHIPS["v5e"]
    space = executable_space(w, chip)
    ds = SampleDataset.generate(space, CostModelMeasurement(w, chip, seed=9), n=800, seed=1)
    spec = TuningSpec(
        kernel="harris",
        backend_kwargs={"chip": "v5e"},
        algorithms=("rs", "rf", "ga", "bo_gp", "bo_tpe"),
        design=ExperimentDesign.smoke(),
    )
    session = TuningSession(spec, dataset=ds)
    return session.run_matrix(), true_optimum(w, chip)[1]


def test_matrix_has_all_cells(smoke_matrix):
    results, _ = smoke_matrix
    assert set(results.algorithms()) == {"rs", "rf", "ga", "bo_gp", "bo_tpe"}
    assert results.sample_sizes() == [25, 50]
    for (_algo, s), cell in results.cells.items():
        assert len(cell.final_values) == {25: 8, 50: 4}[s]
        assert (cell.n_samples_used <= s).all()


def test_finals_are_sane(smoke_matrix):
    results, opt = smoke_matrix
    for cell in results.cells.values():
        assert np.isfinite(cell.final_values).all()
        # no tuned result can beat the noise-free optimum by more than the
        # noise floor
        assert (cell.final_values > opt * 0.8).all()


def test_results_roundtrip(smoke_matrix, tmp_path):
    results, _ = smoke_matrix
    p = str(tmp_path / "m.npz")
    results.save(p)
    loaded = MatrixResults.load(p)
    assert set(loaded.cells) == set(results.cells)
    for k in results.cells:
        np.testing.assert_array_equal(
            loaded.cells[k].final_values, results.cells[k].final_values
        )


def test_paper_design_consumes_dataset_exactly():
    d = ExperimentDesign.paper()
    assert d.sample_sizes == (25, 50, 100, 200, 400)
    assert d.n_experiments == (800, 400, 200, 100, 50)
    for s, e in d.rows():
        assert s * e == 20000   # each row consumes the 20k dataset once
    assert d.total_search_samples == 100_000


def test_paper_sample_count_reproduced():
    """EXACTLY 3,019,500 samples (paper section VII footnote): 3 SMBO
    algos x 100k search samples, plus ONE 20k pre-generated dataset per
    combo SHARED by RS and RF, plus RF's 10 measured predictions per
    experiment — x 9 (benchmark x architecture) combos.  Our runner uses
    the same shared-dataset scheme."""
    d = ExperimentDesign.paper()
    smbo = 3 * d.total_search_samples               # 300,000
    shared_dataset = 20_000                          # serves RS and RF
    rf_predictions = sum(10 * e for e in d.n_experiments)  # 15,500
    per_combo = smbo + shared_dataset + rf_predictions
    assert 9 * per_combo == 3_019_500


def test_stats_pipeline_on_matrix(smoke_matrix):
    results, opt = smoke_matrix
    rs = results.finals("rs", 25)
    gp = results.finals("bo_gp", 25)
    out = stats.compare_algorithms(gp, rs)
    assert 0.0 <= out["cles_a_beats_b"] <= 1.0
    assert 0.0 <= out["mwu_p"] <= 1.0


def test_figures_render(smoke_matrix, tmp_path):
    import json
    import os
    import sys

    results, opt = smoke_matrix
    d = tmp_path / "mat"
    d.mkdir()
    results.save(str(d / "harris_v5e.npz"))
    (d / "harris_v5e.json").write_text(json.dumps({"optimum": opt}))
    sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    from benchmarks.figures import (
        fig2_pct_optimum,
        fig3_aggregate,
        fig4a_speedup,
        fig4b_cles,
        load_all,
        render_fig2,
        render_fig3,
    )

    res = load_all(str(d))
    f2 = fig2_pct_optimum(res)
    assert ("harris", "v5e") in f2
    assert render_fig2(f2)
    assert render_fig3(fig3_aggregate(res))
    assert fig4a_speedup(res)[("harris", "v5e")]["bo_gp"]
    assert fig4b_cles(res)[("harris", "v5e")]["ga"]
