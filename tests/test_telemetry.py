"""repro.telemetry: the tracer/null sink API, the observability-only
contract (telemetry on/off produces identical stores across serial /
process / device executors), deterministic shard-trace merging including
kill-and-resume recovery, progress scanning, summarize tables, Chrome
export schema, and the ``python -m repro.telemetry`` CLI."""

import itertools
import json
import os

import numpy as np
import pytest

from repro.core import (
    ExperimentDesign,
    MeasurementStore,
    TuningSession,
    TuningSpec,
)
from repro.telemetry import (
    NULL_TELEMETRY,
    TRACE_FILE,
    Telemetry,
    chrome_trace,
    export_chrome,
    for_run_dir,
    format_progress,
    read_events,
    read_run,
    scan_progress,
    summarize,
)
from repro.telemetry.__main__ import main as telemetry_cli
from repro.telemetry.null import _NULL_SPAN
from repro.telemetry.progress import scan_events

SPEC = TuningSpec(
    kernel="harris",
    backend_kwargs={"chip": "v5e"},
    algorithms=("rs", "ga"),
    design=ExperimentDesign(sample_sizes=(25,), n_experiments=(4,), final_repeats=3),
    seed=11,
    dataset_size=200,
)


def counter_clock():
    ticks = itertools.count()
    return lambda: float(next(ticks))


def store_values_bytes(path: str) -> bytes:
    """Canonical bytes of a store's measurement VALUES (journal metadata
    carries wall-clocks, which legitimately vary run to run)."""
    return json.dumps(
        sorted(MeasurementStore(path).items()), sort_keys=True
    ).encode()


def assert_same_cells(a, b):
    assert set(a.cells) == set(b.cells)
    for key in a.cells:
        np.testing.assert_array_equal(
            a.cells[key].final_values, b.cells[key].final_values
        )
        np.testing.assert_array_equal(
            a.cells[key].search_best_values, b.cells[key].search_best_values
        )


# ------------------------------------------------------------- null telemetry


def test_null_telemetry_is_the_default_and_allocation_free():
    """The disabled path must not pay for telemetry: ``span()`` hands back
    one shared context manager regardless of arguments, every counter/event
    method is a no-op, and the session wires the singleton by default."""
    tel = NULL_TELEMETRY
    assert tel.enabled is False
    assert tel.span("unit", unit="x") is _NULL_SPAN
    assert tel.span("matrix") is tel.span("experiment", experiment=3)
    with tel.span("round"):
        pass
    tel.inc("compiles")
    tel.gauge("depth", 4)
    tel.event("plan", units_total=8)
    tel.stage("compile", 0.5, key="g")
    tel.emit_counters()
    assert tel.counters_snapshot() == {}
    assert tel.shard_path(0) is None and tel.shard_src(0) is None
    assert tel.absorb(["anything"]) == 0 and tel.recover() == 0
    tel.close()
    assert TuningSession(SPEC).telemetry is NULL_TELEMETRY


# ------------------------------------------------------------------- tracer


def test_tracer_spans_counters_and_failed_span(tmp_path):
    path = str(tmp_path / TRACE_FILE)
    tel = Telemetry(path, clock=counter_clock())
    with tel.span("unit", unit="ga/S25"):
        tel.stage("compile", 0.5, key="g1")
        tel.inc("compiles")
        tel.inc("compiles")
    with pytest.raises(RuntimeError, match="boom"):
        with tel.span("unit", unit="bad"):
            raise RuntimeError("boom")
    tel.gauge("prefetch_inflight", 3)
    tel.close()

    events = read_events(path)
    # per-writer total order, all stamped with this writer's src
    assert [e["seq"] for e in events] == list(range(len(events)))
    assert {e["src"] for e in events} == {"main"}
    begin, stage, end = events[0], events[1], events[2]
    assert begin["ev"] == "begin" and begin["unit"] == "ga/S25"
    assert stage["ev"] == "stage" and stage["dur"] == 0.5 and stage["key"] == "g1"
    assert end["ev"] == "end" and end["dur"] > 0 and "ok" not in end
    bad = [e for e in events if e.get("unit") == "bad" and e["ev"] == "end"]
    assert bad and bad[0]["ok"] is False          # the span died visibly
    counters = [e for e in events if e["ev"] == "counters"]
    assert counters[-1]["counters"] == {"compiles": 2}
    gauge = [e for e in events if e["ev"] == "gauge"][0]
    assert gauge["gauge"] == "prefetch_inflight" and gauge["value"] == 3


def test_reader_skips_torn_and_malformed_lines(tmp_path):
    path = str(tmp_path / TRACE_FILE)
    with open(path, "w") as f:
        f.write('{"ev": "plan", "seq": 0}\n')
        f.write("not json\n")
        f.write('{"ev": "end", "seq": 1, "span": "unit"')   # torn tail
    events = read_events(path)
    assert [e["ev"] for e in events] == ["plan"]
    assert read_events(str(tmp_path / "missing.jsonl")) == []


# ------------------------------------------ on/off identity across executors


def run_pair(tmp_path, run_dir, **matrix_kwargs):
    """The same matrix with telemetry off and on; returns both sessions'
    results plus the store paths."""
    off_path = str(tmp_path / "off.json")
    on_path = str(tmp_path / "on.json")
    res_off = TuningSession(
        SPEC.replace(store="json", store_path=off_path)
    ).run_matrix(**matrix_kwargs)
    tel = for_run_dir(str(run_dir))
    on = TuningSession(
        SPEC.replace(store="json", store_path=on_path), telemetry=tel
    )
    res_on = on.run_matrix(**matrix_kwargs)
    tel.close()
    return res_off, res_on, on, off_path, on_path


def test_serial_identical_store_and_trace_covers_every_unit(tmp_path):
    run_dir = tmp_path / "run"
    res_off, res_on, session, off_path, on_path = run_pair(tmp_path, run_dir)
    assert store_values_bytes(off_path) == store_values_bytes(on_path)
    assert_same_cells(res_off, res_on)

    events = read_run(str(run_dir))
    n_units = len(session.last_unit_plan)
    assert n_units > 0
    unit_ends = [e for e in events if e["ev"] == "end" and e.get("span") == "unit"]
    assert len(unit_ends) == n_units
    plan = [e for e in events if e["ev"] == "plan"][0]
    assert plan["units_total"] == n_units
    assert plan["experiments_total"] == 8          # 2 algos x 4 experiments
    totals = [e for e in events if e["ev"] == "totals"][-1]["counters"]
    assert totals["units_completed"] == n_units
    assert totals["experiments_completed"] == 8
    # the merged counters ride along in the RunRecord for the report layer
    assert session.last_record.extra["telemetry"]["counters"] == totals
    prov = session.last_record.provenance
    assert "repro_version" in prov                 # satellite: build identity


def test_process_executor_identical_store_and_merged_shards(tmp_path):
    run_dir = tmp_path / "run"
    res_off, res_on, session, off_path, on_path = run_pair(
        tmp_path, run_dir, executor="process", max_workers=3
    )
    assert store_values_bytes(off_path) == store_values_bytes(on_path)
    assert_same_cells(res_off, res_on)
    # shard traces were absorbed into the main trace and deleted
    assert os.listdir(run_dir) == [TRACE_FILE]
    events = read_run(str(run_dir))
    srcs = {e["src"] for e in events}
    assert "main" in srcs and any(s.startswith("shard") for s in srcs)
    unit_ends = [e for e in events if e["ev"] == "end" and e.get("span") == "unit"]
    assert len(unit_ends) == len(session.last_unit_plan)
    totals = [e for e in events if e["ev"] == "totals"][-1]["counters"]
    assert totals["units_completed"] == len(session.last_unit_plan)


def test_device_executor_identical_store(tmp_path):
    run_dir = tmp_path / "run"
    with pytest.warns(UserWarning):      # single-device host: workers capped
        res_off, res_on, session, off_path, on_path = run_pair(
            tmp_path, run_dir, executor="device", max_workers=2
        )
    assert store_values_bytes(off_path) == store_values_bytes(on_path)
    assert_same_cells(res_off, res_on)
    assert os.listdir(run_dir) == [TRACE_FILE]


# --------------------------------------------------- shard merge + recovery


def shard_lines(run_dir, shard, lines):
    path = os.path.join(run_dir, f"trace.shard{shard}.jsonl")
    with open(path, "w") as f:
        f.write("\n".join(lines))
    return path


def test_absorb_is_deterministic_and_preserves_order(tmp_path):
    def build(run_dir):
        os.makedirs(run_dir)
        tel = Telemetry(os.path.join(run_dir, TRACE_FILE), clock=counter_clock())
        tel.event("plan", units_total=2)
        # absorb in shard order, each file's internal order preserved
        shard_lines(run_dir, 1, ['{"src": "shard1", "seq": 0, "ev": "x"}\n'])
        shard_lines(run_dir, 0, [
            '{"src": "shard0", "seq": 0, "ev": "a"}',
            '{"src": "shard0", "seq": 1, "ev": "b"}\n',
        ])
        n = tel.recover()
        tel.close()
        assert n == 2
        with open(os.path.join(run_dir, TRACE_FILE), "rb") as f:
            return f.read()

    a = build(str(tmp_path / "a"))
    b = build(str(tmp_path / "b"))
    assert a == b                                  # same inputs, same bytes
    events = read_events(str(tmp_path / "a" / TRACE_FILE))
    assert [e.get("src", "main") for e in events[-3:]] == [
        "shard0", "shard0", "shard1",
    ]
    assert not [n for n in os.listdir(tmp_path / "a") if "shard" in n]


def test_absorb_pads_torn_shard_tail(tmp_path):
    """A worker killed mid-write leaves a shard trace without a trailing
    newline; absorbing it must not glue the next file's first event onto
    the torn line."""
    run_dir = str(tmp_path)
    tel = for_run_dir(run_dir)
    tel.event("plan")
    shard_lines(run_dir, 0, ['{"ev": "stage", "src": "shard0"'])   # torn
    shard_lines(run_dir, 1, ['{"ev": "gauge", "src": "shard1", "value": 1}\n'])
    assert tel.recover() == 2
    tel.close()
    events = read_events(os.path.join(run_dir, TRACE_FILE))
    assert [e["ev"] for e in events] == ["plan", "gauge"]


def test_matrix_resume_recovers_orphan_shard_traces(tmp_path):
    """The kill-and-resume path end to end: a killed parallel run leaves
    ``trace.shard<k>.jsonl`` beside the trace; the resumed run absorbs them
    before emitting its own plan, so pre-kill spans sit before the new plan
    and never inflate the resumed session's progress."""
    run_dir = tmp_path / "run"
    os.makedirs(run_dir)
    orphan = shard_lines(
        str(run_dir), 0,
        ['{"src": "shard0", "seq": 0, "ev": "end", "span": "experiment"}\n'],
    )
    tel = for_run_dir(str(run_dir))
    spec = SPEC.replace(store="json", store_path=str(tmp_path / "s.json"))
    session = TuningSession(spec, telemetry=tel)
    session.run_matrix(resume=True, executor="process", max_workers=2)
    tel.close()
    assert not os.path.exists(orphan)
    events = read_run(str(run_dir))
    plan_idx = max(i for i, e in enumerate(events) if e["ev"] == "plan")
    orphan_idx = [
        i for i, e in enumerate(events)
        if e.get("src") == "shard0" and e.get("seq") == 0
    ]
    assert orphan_idx and orphan_idx[0] < plan_idx
    state = scan_events(events)
    assert state.complete
    assert state.experiments_done == 8             # the orphan didn't count


# ------------------------------------------------------------------ progress


def test_scan_events_is_positional_after_the_last_plan():
    events = [
        {"ev": "end", "span": "experiment"},       # stale pre-plan activity
        {"ev": "plan", "units_total": 4, "experiments_total": 8,
         "units_done_resume": 1, "experiments_done_resume": 2},
        {"ev": "end", "span": "unit"},
        {"ev": "end", "span": "experiment"},
        {"ev": "end", "span": "experiment"},
        {"ev": "begin", "span": "unit"},           # dangling begin: not done
    ]
    state = scan_events(events)
    assert state.has_plan
    assert (state.units_done, state.units_total) == (2, 4)
    assert (state.experiments_done, state.experiments_total) == (4, 8)
    assert not state.complete
    line = format_progress(state, eta_s=90.0)
    assert "units 2/4" in line and "experiments 4/8 (50%)" in line
    assert "ETA 90s" in line


# ------------------------------------------------- summarize / export / CLI


@pytest.fixture(scope="module")
def traced_run(tmp_path_factory):
    """One serial telemetry run shared by the consumer-side tests."""
    tmp = tmp_path_factory.mktemp("traced")
    run_dir = str(tmp / "run")
    tel = for_run_dir(run_dir)
    session = TuningSession(
        SPEC.replace(store="json", store_path=str(tmp / "s.json")),
        telemetry=tel,
    )
    session.run_matrix()
    tel.close()
    return run_dir


def test_summarize_counts_and_progress(traced_run):
    s = summarize(traced_run)
    assert s["units_done"] == 2 and s["experiments_done"] == 8
    assert s["counters"]["experiments_completed"] == 8
    assert s["counters"]["store_misses"] > 0
    state = scan_progress(traced_run)
    assert state.complete

    # per-cell aggregates come from the parent's merged cell events
    cells = {(c["algo"], c["sample_size"]): c for c in s["cells"]}
    assert set(cells) == {("rs", 25), ("ga", 25)}
    assert all(c["n_experiments"] == 4 for c in cells.values())


def test_chrome_export_schema(traced_run):
    path = export_chrome(traced_run)
    assert path == os.path.join(traced_run, "trace_chrome.json")
    with open(path) as f:
        doc = json.load(f)
    events = doc["traceEvents"]
    assert events
    phases = {e["ph"] for e in events}
    assert phases <= {"B", "E", "X", "C", "i", "M"}
    for e in events:
        assert isinstance(e["name"], str) and "pid" in e
        if e["ph"] != "M":
            assert e["ts"] >= 0                    # per-src normalized
        if e["ph"] == "X":
            assert e["dur"] >= 0
    # every span that began also ended (clean run: balanced flame stack)
    assert sum(e["ph"] == "B" for e in events) == sum(
        e["ph"] == "E" for e in events
    )
    # one process track per writer, named via metadata
    meta = [e for e in events if e["ph"] == "M"]
    assert {m["args"]["name"] for m in meta} == {"main"}


def test_chrome_export_normalizes_per_writer_epochs():
    events = [
        {"t": 100.0, "seq": 0, "src": "main", "ev": "begin", "span": "matrix"},
        {"t": 5.0, "seq": 0, "src": "shard0", "ev": "stage",
         "stage": "compile", "dur": 0.25},
        {"t": 101.0, "seq": 1, "src": "main", "ev": "end", "span": "matrix",
         "dur": 1.0},
    ]
    doc = chrome_trace(events)
    by = {(e["ph"], e.get("name")): e for e in doc["traceEvents"]}
    assert by[("B", "matrix")]["ts"] == 0.0        # main's own epoch
    assert by[("X", "compile")]["ts"] == 0.0       # shard0's own epoch
    assert by[("X", "compile")]["dur"] == 0.25e6
    assert by[("B", "matrix")]["pid"] != by[("X", "compile")]["pid"]


def test_cli_summarize_tail_export(traced_run, tmp_path, capsys):
    assert telemetry_cli([traced_run]) == 0        # bare run dir summarizes
    out = capsys.readouterr().out
    assert "counter totals" in out and "per-cell stage breakdown" in out

    assert telemetry_cli(["tail", traced_run]) == 0
    out = capsys.readouterr().out
    assert "units 2/2" in out and "experiments 8/8 (100%)" in out

    dest = str(tmp_path / "chrome.json")
    assert telemetry_cli(["export", traced_run, "-o", dest]) == 0
    with open(dest) as f:
        assert json.load(f)["traceEvents"]
