"""Statistics layer vs scipy + CLES identities (paper section II.C)."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import stats

scipy_stats = pytest.importorskip("scipy.stats")


@given(st.integers(0, 2**31 - 1), st.integers(5, 60), st.integers(5, 60))
@settings(max_examples=40, deadline=None)
def test_mwu_matches_scipy(seed, n_a, n_b):
    rng = np.random.default_rng(seed)
    a = rng.normal(0, 1, n_a)
    b = rng.normal(0.3, 1.2, n_b)
    ours = stats.mann_whitney_u(a, b)
    ref = scipy_stats.mannwhitneyu(a, b, alternative="two-sided",
                                   method="asymptotic", use_continuity=True)
    assert ours.u == pytest.approx(ref.statistic)
    assert ours.p_value == pytest.approx(ref.pvalue, rel=1e-6)


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=30, deadline=None)
def test_mwu_with_ties_matches_scipy(seed):
    rng = np.random.default_rng(seed)
    a = rng.integers(0, 5, 30).astype(float)
    b = rng.integers(0, 5, 25).astype(float)
    ours = stats.mann_whitney_u(a, b)
    ref = scipy_stats.mannwhitneyu(a, b, alternative="two-sided",
                                   method="asymptotic", use_continuity=True)
    assert ours.p_value == pytest.approx(ref.pvalue, rel=1e-6)


@given(st.integers(0, 2**31 - 1), st.integers(3, 40), st.integers(3, 40))
@settings(max_examples=40, deadline=None)
def test_cles_equals_pairwise_definition(seed, n_a, n_b):
    """Rank-based CLES == brute-force  P(A > B) + 0.5 P(A == B)  (eq. 1)."""
    rng = np.random.default_rng(seed)
    a = rng.integers(0, 8, n_a).astype(float)
    b = rng.integers(0, 8, n_b).astype(float)
    brute = np.mean((a[:, None] > b[None, :]) + 0.5 * (a[:, None] == b[None, :]))
    assert stats.cles(a, b) == pytest.approx(brute)


def test_cles_symmetry():
    rng = np.random.default_rng(1)
    a, b = rng.normal(size=20), rng.normal(size=30)
    assert stats.cles(a, b) + stats.cles(b, a) == pytest.approx(1.0)


def test_cles_lower_better_direction():
    fast = np.array([1.0, 1.1, 0.9])
    slow = np.array([2.0, 2.1, 1.9])
    # fast algorithm beats slow with probability 1
    assert stats.cles_lower_better(fast, slow) == pytest.approx(1.0)
    assert stats.cles_lower_better(slow, fast) == pytest.approx(0.0)


def test_median_speedup():
    assert stats.median_speedup(np.array([2.0, 2.0]), np.array([1.0, 1.0])) == 2.0


def test_pct_of_optimum():
    out = stats.pct_of_optimum(np.array([2.0, 1.0]), optimum=1.0)
    np.testing.assert_allclose(out, [50.0, 100.0])


def test_significance_threshold_is_papers():
    assert stats.ALPHA == 0.01


def test_compare_algorithms_keys():
    rng = np.random.default_rng(0)
    out = stats.compare_algorithms(rng.normal(1, 0.1, 50), rng.normal(1.2, 0.1, 50))
    assert set(out) >= {"median_a", "median_b", "speedup_a_over_b",
                        "cles_a_beats_b", "mwu_p", "significant"}
    assert out["significant"]  # clearly separated populations
