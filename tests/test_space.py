"""Property tests for the search space (hypothesis)."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import Param, SearchSpace, paper_space


def test_paper_space_cardinality():
    assert paper_space().cardinality == 2_097_152  # 16^3 * 8^3, as in the paper


def test_constraint_matches_paper_rule():
    space = paper_space(constrained=True)
    rng = np.random.default_rng(0)
    for cfg in space.sample_batch(rng, 200):
        assert cfg["w_x"] * cfg["w_y"] * cfg["w_z"] <= 256


@given(st.integers(0, 2**31 - 1), st.integers(1, 64))
@settings(max_examples=25, deadline=None)
def test_sample_within_bounds(seed, n):
    space = paper_space(constrained=False)
    rng = np.random.default_rng(seed)
    idx = space.sample_indices(rng, n)
    assert idx.shape == (n, 6)
    assert (idx >= 0).all()
    assert (idx < space.cardinalities).all()


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_encode_decode_roundtrip(seed):
    space = paper_space(constrained=False)
    rng = np.random.default_rng(seed)
    idx = space.sample_indices(rng, 8)
    for row in idx:
        cfg = space.decode(row)
        np.testing.assert_array_equal(space.encode(cfg), row)


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_flat_keys_unique_and_consistent(seed):
    space = paper_space(constrained=False)
    rng = np.random.default_rng(seed)
    idx = space.sample_indices(rng, 256)
    keys = space.flat_keys(idx)
    uniq_rows = len({tuple(r) for r in idx.tolist()})
    assert len(set(keys.tolist())) == uniq_rows
    assert (keys >= 0).all() and (keys < space.cardinality).all()


@given(st.integers(0, 2**31 - 1), st.floats(0.0, 1.0))
@settings(max_examples=20, deadline=None)
def test_mutate_batch_matches_bounds(seed, p):
    space = paper_space(constrained=False)
    rng = np.random.default_rng(seed)
    base = space.sample_indices(rng, 1)[0]
    out = space.mutate_batch(rng, base, p, 64)
    assert out.shape == (64, 6)
    assert (out >= 0).all() and (out < space.cardinalities).all()
    if p == 0.0:
        assert (out == base).all()


def test_unit_cube_roundtrip():
    space = paper_space(constrained=False)
    rng = np.random.default_rng(3)
    idx = space.sample_indices(rng, 100)
    u = space.to_unit(idx)
    assert (u > 0).all() and (u < 1).all()
    np.testing.assert_array_equal(space.from_unit(u), idx)


def test_neighbor_moves_one_axis():
    space = paper_space(constrained=False)
    rng = np.random.default_rng(0)
    idx = space.sample_indices(rng, 1)[0]
    for _ in range(50):
        nxt = space.neighbor(rng, idx)
        diff = (nxt != idx).sum()
        assert diff <= 1
        assert (nxt >= 0).all() and (nxt < space.cardinalities).all()


def test_duplicate_param_names_rejected():
    with pytest.raises(ValueError):
        SearchSpace([Param.int_range("a", 1, 4), Param.int_range("a", 1, 2)])
