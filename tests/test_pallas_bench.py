"""The real-measurement subsystem: pallas_bench + its engine/API wiring.

Covers the ISSUE-3 acceptance surface: compile-and-time measurement with a
keyed compilation cache, the validity pre-screen mapping bad configs to
structured inf penalties (not exceptions), searchers surviving non-finite
tells, penalty reasons round-tripping through both measurement stores, the
name-serializable ``BACKENDS["pallas"]`` path through ``repro.tune`` /
sharded ``tune_matrix``, and zero-recompile warm-store re-runs.
"""

import json
import math
import shutil
import time

import numpy as np
import pytest

import repro
from repro.core import (
    CallableMeasurement,
    DiskCachedMeasurement,
    MeasurementStore,
    Param,
    SearchSpace,
    SqliteMeasurementStore,
    TimingMeasurement,
    TuningSession,
    TuningSpec,
    config_key,
    make_searcher,
)
from repro.core.experiment import ExperimentDesign
from repro.kernels.common import KernelBenchSpec, geometry_from_config
from repro.pallas_bench import (
    InvalidMeasurement,
    PallasMeasurement,
    PallasWorkload,
    default_space,
    make_workload,
    validate_config,
    vmem_footprint,
)

GOOD = dict(t_x=2, t_y=1, t_z=2, w_x=1, w_y=1, w_z=1)

# tiny all-valid space on a (64, 128) problem: <= 16 distinct geometries,
# so interpret-mode tests stay fast
SMALL_SPACE = SearchSpace(
    [
        Param.int_range("t_x", 1, 2),
        Param.choice("t_y", (1,)),
        Param.int_range("t_z", 1, 2),
        Param.int_range("w_x", 1, 2),
        Param.choice("w_y", (1,)),
        Param.int_range("w_z", 1, 2),
    ]
)


def small_spec(**overrides) -> TuningSpec:
    kw = dict(
        kernel="add",
        searcher="ga",
        backend="pallas",
        backend_kwargs={"x": 64, "y": 128, "repeats": 2, "warmup": 1},
        space=SMALL_SPACE,
        budget=6,
        final_repeats=2,
        seed=0,
    )
    kw.update(overrides)
    return TuningSpec(**kw)


# ------------------------------------------------------------- workloads


def test_workload_inputs_deterministic_across_instances():
    a1 = make_workload("add", x=64, y=128).materialize()
    a2 = make_workload("add", x=64, y=128).materialize()
    assert len(a1) == 2
    for u, v in zip(a1, a2, strict=True):
        np.testing.assert_array_equal(np.asarray(u), np.asarray(v))
    # a different input_seed gives a different problem
    b = make_workload("add", x=64, y=128, input_seed=1).materialize()
    assert not np.array_equal(np.asarray(a1[0]), np.asarray(b[0]))


def test_workload_unknown_kernel_and_tiny_problem():
    with pytest.raises(KeyError):
        make_workload("nope")
    with pytest.raises(ValueError):
        make_workload("add", x=4, y=64)


def test_mandelbrot_workload_has_no_inputs():
    w = make_workload("mandelbrot", x=64, y=128)
    assert w.materialize() == ()


# -------------------------------------------------------------- validity


def test_validate_rules():
    w = make_workload("add", x=64, y=128)
    assert validate_config(w, GOOD) is None
    # block taller than the padded image
    r = validate_config(w, dict(t_x=16, t_y=1, t_z=16, w_x=1, w_y=1, w_z=1))
    assert r is not None and r.startswith("block:")
    # block wider than the padded image
    r = validate_config(w, dict(t_x=1, t_y=2, t_z=1, w_x=1, w_y=1, w_z=1))
    assert r is not None and r.startswith("block:")
    # vmem blowout on a workload big enough that blocks fit the image
    big = make_workload("harris", x=4096, y=4096)
    cfg = dict(t_x=16, t_y=16, t_z=2, w_x=1, w_y=1, w_z=8)
    r = validate_config(big, cfg, vmem_limit=1 << 20)
    assert r is not None and r.startswith("vmem:")
    assert vmem_footprint(big.bench, geometry_from_config(cfg)) > (1 << 20)
    # grid bound
    r = validate_config(w, GOOD, max_grid=1)
    assert r is not None and r.startswith("grid:")


def test_invalid_measurement_meta_roundtrip():
    bad = InvalidMeasurement(reason="vmem:9 bytes > 1", stage="compile")
    back = InvalidMeasurement.from_meta(bad.to_meta())
    assert back.stage == "compile"
    assert back.reason == "vmem:9 bytes > 1"
    assert math.isinf(back.penalty)


# ----------------------------------------------------- PallasMeasurement


def test_measure_valid_and_invalid():
    m = PallasMeasurement(make_workload("add", x=64, y=128), repeats=2)
    v = m.measure(GOOD)
    assert np.isfinite(v) and v > 0
    assert len(m.repeats_for(GOOD)) == 2
    bad = dict(t_x=16, t_y=16, t_z=16, w_x=1, w_y=1, w_z=1)
    assert math.isinf(m.measure(bad))
    assert m.reason_for(bad).startswith("validity:block:")
    assert m.reason_for(GOOD) is None
    # invalid configs never reach the compiler
    assert m.n_compiles == 1


def test_compile_cache_shared_across_wz():
    m = PallasMeasurement(make_workload("add", x=64, y=128), repeats=1)
    for wz in (1, 2, 8):
        assert np.isfinite(m.measure({**GOOD, "w_z": wz}))
    assert m.n_compiles == 1
    m.measure({**GOOD, "t_x": 1})
    assert m.n_compiles == 2


def test_measure_batch_is_one_dispatch():
    m = PallasMeasurement(make_workload("add", x=64, y=128), repeats=1)
    vals = m.measure_batch([GOOD, {**GOOD, "w_z": 2}, {**GOOD, "t_x": 16, "t_z": 16}])
    assert vals.shape == (3,)
    assert np.isfinite(vals[:2]).all() and math.isinf(vals[2])
    assert m.n_dispatches == 1 and m.n_samples == 3


def test_run_failure_maps_to_penalty():
    def boom(inputs, cfg, x, y):
        raise RuntimeError("mosaic says no")

    bench = KernelBenchSpec(
        name="boom", n_inputs=0, make_inputs=lambda x, y, seed: (), run=boom
    )
    m = PallasMeasurement(PallasWorkload(bench=bench, x=64, y=128), repeats=1)
    v = m.measure(GOOD)
    assert math.isinf(v)
    assert "mosaic says no" in m.reason_for(GOOD)
    assert m.reason_for(GOOD).startswith("compile:")
    # the failed geometry is cached: no retry on the next proposal
    assert math.isinf(m.measure({**GOOD, "w_z": 2})) and m.n_compiles == 1


def test_measure_final_reuses_compiled_program():
    m = PallasMeasurement(make_workload("add", x=64, y=128), repeats=1)
    m.measure(GOOD)
    final = m.measure_final(GOOD, repeats=4)
    assert np.isfinite(final)
    assert len(m.final_repeat_log[config_key(GOOD)]) == 4
    assert m.n_compiles == 1
    prov = m.provenance()
    assert prov["backend"] == "pallas" and prov["interpret"] is True
    assert prov["repeats"] == 1 and prov["warmup"] == 1
    assert prov["device_kind"]


# ------------------------------------------------- TimingMeasurement fix


class _AsyncResult:
    """Mimics a jax DeviceArray: work 'completes' only when fenced."""

    def __init__(self, log, delay_s):
        self._log = log
        self._delay = delay_s

    def block_until_ready(self):
        time.sleep(self._delay)
        self._log.append("fenced")


def test_timing_measurement_fences_inside_timed_region():
    log = []

    def runner(cfg):
        log.append("run")
        return _AsyncResult(log, 0.02)

    t = TimingMeasurement(runner, warmup=1)
    v = t.measure(dict(a=1))
    # warmup call + timed call, each fenced
    assert log == ["run", "fenced", "run", "fenced"]
    # the fence's sleep happened INSIDE the timed region
    assert v >= 0.015


def test_timing_measurement_always_warms_at_least_once():
    calls = []
    t = TimingMeasurement(lambda cfg: calls.append(1), warmup=0)
    t.measure(dict(a=1))
    assert len(calls) == 2  # 1 forced warmup (compile analogue) + 1 timed


# ------------------------------------------- searchers vs inf penalties

# roomier than SMALL_SPACE (64 configs) so a 16-sample budget cannot
# exhaust it — searcher behaviour, not exhaustion, is under test here
SEARCH_SPACE = SearchSpace(
    [
        Param.int_range("t_x", 1, 2),
        Param.choice("t_y", (1,)),
        Param.int_range("t_z", 1, 8),
        Param.int_range("w_x", 1, 2),
        Param.choice("w_y", (1,)),
        Param.int_range("w_z", 1, 2),
    ]
)


def _half_invalid_measurement():
    """Finite objective on t_x==1, inf otherwise (an invalid region)."""

    def fn(cfg):
        if cfg["t_x"] == 1:
            return 1.0 + 0.1 * cfg["t_z"] + 0.01 * cfg["w_x"]
        return float("inf")

    return CallableMeasurement(fn)


@pytest.mark.parametrize("algo", ["ga", "bo_gp", "bo_tpe", "rs", "sa"])
def test_searchers_survive_inf_tells(algo):
    s = make_searcher(algo, SEARCH_SPACE, seed=0)
    r = s.run(_half_invalid_measurement(), 16)
    assert r.n_samples == 16
    assert np.isfinite(r.best_value)
    assert r.best_config["t_x"] == 1
    # penalties are preserved verbatim in the history
    assert any(math.isinf(v) for v in r.history_values)


def test_ga_terminates_on_exhausted_space():
    """A space smaller than the budget must end the search, not livelock."""
    r = make_searcher("ga", SMALL_SPACE, seed=0).run(
        _half_invalid_measurement(), 16
    )
    assert 0 < r.n_samples <= 16
    assert np.isfinite(r.best_value)


def test_bo_gp_reclips_penalties_when_finite_max_grows():
    """An early penalty (clipped against nothing: 1.0) must not become the
    GP's incumbent once finite observations larger than it arrive — the
    stored penalties are re-clipped above the growing finite max."""
    space = SearchSpace([Param.int_range("t_x", 1, 2), Param.int_range("t_z", 1, 8)])

    def fn(cfg):  # invalid half; finite values all well above 1.0
        return float("inf") if cfg["t_x"] == 2 else 5.0 + 0.1 * cfg["t_z"]

    r = make_searcher("bo_gp", space, seed=3).run(CallableMeasurement(fn), 12)
    assert r.n_samples == 12
    assert np.isfinite(r.best_value) and r.best_value >= 5.0
    assert r.best_config["t_x"] == 1


def test_bo_gp_survives_all_inf_start():
    space = SearchSpace([Param.int_range("t_x", 2, 3), Param.int_range("t_z", 1, 4)])

    def fn(cfg):  # nothing is ever finite
        return float("inf")

    r = make_searcher("bo_gp", space, seed=0).run(CallableMeasurement(fn), 8)
    assert r.n_samples == 8 and math.isinf(r.best_value)


# ------------------------------------------------ store penalty metadata


@pytest.mark.parametrize("store_cls", [MeasurementStore, SqliteMeasurementStore])
def test_store_roundtrips_inf_and_reason(tmp_path, store_cls):
    path = str(tmp_path / "cache.bin")
    store = store_cls(path)
    store.put("k|a=1", float("inf"))
    store.put_meta("k|a=1", "validity:vmem:9 bytes > 1")
    store.put("k|a=2", 0.5)
    store.save()
    if hasattr(store, "close"):
        store.close()
    back = store_cls(path)
    assert math.isinf(back.get("k|a=1"))
    assert back.get("k|a=2") == 0.5
    assert back.get_meta("k|a=1") == "validity:vmem:9 bytes > 1"
    assert back.get_meta("k|a=2") is None
    assert dict(back.meta_items()) == {"k|a=1": "validity:vmem:9 bytes > 1"}


def test_json_store_without_meta_keeps_legacy_format(tmp_path):
    path = str(tmp_path / "cache.json")
    store = MeasurementStore(path)
    store.put("k", 1.0)
    store.save()
    with open(path) as f:
        assert json.load(f) == {"k": 1.0}


def test_disk_cache_records_and_serves_penalty_reasons(tmp_path):
    path = str(tmp_path / "cache.json")
    store = MeasurementStore(path)
    inner = PallasMeasurement(make_workload("add", x=64, y=128), repeats=1)
    m = DiskCachedMeasurement(inner, store, prefix="add/pallas/seed=0")
    bad = dict(t_x=16, t_y=16, t_z=16, w_x=1, w_y=1, w_z=1)
    m.measure_batch([GOOD, bad])
    store.save()

    # a FRESH wrapper over the persisted store serves the penalty from disk,
    # reason included, without touching the (cold) inner backend
    store2 = MeasurementStore(path)
    inner2 = PallasMeasurement(make_workload("add", x=64, y=128), repeats=1)
    m2 = DiskCachedMeasurement(inner2, store2, prefix="add/pallas/seed=0")
    vals = m2.measure_batch([GOOD, bad])
    assert np.isfinite(vals[0]) and math.isinf(vals[1])
    assert m2.n_misses == 0 and inner2.n_compiles == 0
    assert m2.reason_for(bad).startswith("validity:block:")


# ---------------------------------------------------- facade end-to-end


def test_tune_pallas_by_name_records_provenance(tmp_path):
    record_path = str(tmp_path / "record.json")
    spec = small_spec()
    spec.to_json()  # name-serializable — the whole point
    r = repro.tune(spec, record_path=record_path)
    assert 0 < r.n_samples <= 6
    assert np.isfinite(r.best_value) and np.isfinite(r.final_value)
    rec = repro.RunRecord.load(record_path)
    prov = rec.extra["backend_provenance"]
    assert prov["backend"] == "pallas"
    assert prov["interpret"] is True
    assert prov["repeats"] == 2 and prov["warmup"] == 1
    assert len(rec.result["final_repeat_times"]) == 2  # final_repeats
    assert rec.spec["backend"] == "pallas"


def test_tune_pallas_default_space_constraint_roundtrips():
    space = default_space("add", x=64, y=128)
    spec = TuningSpec(kernel="add", backend="pallas",
                      backend_kwargs={"x": 64, "y": 128}, space=space, budget=4)
    back = TuningSpec.from_json(spec.to_json())
    assert back.space.constraint is not None
    ok = dict(t_x=1, t_y=1, t_z=1, w_x=1, w_y=1, w_z=1)
    bad = dict(t_x=16, t_y=16, t_z=16, w_x=1, w_y=1, w_z=1)
    assert back.space.is_valid(ok) and not back.space.is_valid(bad)


def test_warm_store_rerun_zero_recompiles(tmp_path):
    spec = small_spec(store="json", store_path=str(tmp_path / "cache.json"),
                      budget=4)
    s1 = TuningSession(spec)
    s1.run()
    inner1 = s1.measurement.provenance()
    assert inner1["n_compiles"] > 0

    s2 = TuningSession(spec)
    r2 = s2.run()
    prov = s2.measurement.provenance()
    assert prov["n_compiles"] == 0
    assert prov["cache_misses"] == 0
    assert np.isfinite(r2.final_value)


def store_sections(path):
    """(values, non-journal meta, journal keys) of a JSON store file.  Unit-
    journal entries carry per-run wall-clocks, which legitimately differ
    between two runs of the same matrix; everything else must not.  Serving
    winners (format 3) fold into values minus their wall-clock ``fresh``
    stamp — the winner's config/value/provenance must be run-invariant."""
    import json

    with open(path) as f:
        raw = json.load(f)
    if not (isinstance(raw, dict) and raw.get("__format__") in (2, 3)):
        return raw, {}, set()
    meta = raw.get("meta", {})
    journal = {k for k in meta if k.startswith("__unit__|")}
    values = dict(raw["values"])
    for key, payload in raw.get("winners", {}).items():
        rec = json.loads(payload)
        rec.pop("fresh", None)
        values["__winner__|" + key] = json.dumps(rec, sort_keys=True)
    return (
        values,
        {k: v for k, v in meta.items() if k not in journal},
        journal,
    )


def test_matrix_sharded_warm_store_bit_identical(tmp_path):
    design = ExperimentDesign(sample_sizes=(3, 4), n_experiments=(2, 1),
                              final_repeats=2)
    single = str(tmp_path / "single.json")
    spec = small_spec(budget=None, design=design, algorithms=("rs", "ga"),
                      store="json", store_path=single)
    res1 = repro.tune_matrix(spec)
    vals1, meta1, journal1 = store_sections(single)

    # warm sharded re-run against a COPY of the single-process store:
    # workers seed their shard stores from it, so nothing is re-measured
    # and the merged store's measurements come back bit-identical (the unit
    # journal's wall-clocks are the only thing allowed to move)
    shard_path = str(tmp_path / "shard.json")
    shutil.copy(single, shard_path)
    res2 = repro.tune_matrix(spec.replace(store_path=shard_path), shards=2)
    vals2, meta2, journal2 = store_sections(shard_path)
    assert vals2 == vals1
    assert meta2 == meta1
    # the stealing scheduler over-splits cells, journaling finer-grained
    # fragments on top of the serial run's whole-cell entries — every
    # original entry survives, measurements untouched
    assert journal1 <= journal2
    for key in res1.cells:
        np.testing.assert_array_equal(
            res1.cells[key].final_values, res2.cells[key].final_values
        )
