"""Staged measurement pipeline + device executor: prefetch on/off equivalence
(identical values, identical compile counts), per-stage clocks and per-run
provenance counters, fail-fast future draining that journals completed work,
and `device`-executor bit-identity / resume (in-process and on a 4-fake-device
subprocess via XLA_FLAGS)."""

import itertools
import json
import os
import subprocess
import sys
import threading
import time
from concurrent.futures import ThreadPoolExecutor

import numpy as np
import pytest

import repro
from repro.core import (
    ExperimentDesign,
    MeasurementStore,
    StageClock,
    TuningSession,
    TuningSpec,
    build_units,
)
from repro.core.api import STEAL_OVERSPLIT

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SPEC = TuningSpec(
    kernel="harris",
    backend_kwargs={"chip": "v5e"},
    algorithms=("rs", "ga"),
    design=ExperimentDesign(sample_sizes=(25,), n_experiments=(4,), final_repeats=3),
    seed=11,
)


def counter_timer():
    """Deterministic timing-stage clock: measured values become pure
    functions of call order, so pipelined and inline runs can be compared
    for exact equality."""
    ticks = itertools.count()
    return lambda: float(next(ticks))


def pallas_measurement(**kwargs):
    from repro.pallas_bench import PallasMeasurement, make_workload

    return PallasMeasurement(make_workload("add", x=16, y=256), **kwargs)


def batch_configs():
    """A batch mixing valid configs, screened-out configs, and geometry
    duplicates (w_z does not enter the add program)."""
    return [
        dict(t_x=tx, t_y=1, t_z=tz, w_x=1, w_y=1, w_z=wz)
        for tx, tz, wz in itertools.product((1, 2, 4, 16), (1, 2), (1, 2))
    ]


# ------------------------------------------------------------------ StageClock


def test_stage_clock_accumulates_and_resets():
    clock = StageClock()
    with clock.stage("compile"):
        pass
    clock.add("compile", 1.5)
    clock.add("time", 0.25)
    t = clock.times()
    assert t["compile"] >= 1.5 and t["time"] == 0.25
    clock.reset()
    assert clock.times() == {}


def test_stage_clock_is_thread_safe():
    clock = StageClock()

    def worker():
        for _ in range(1000):
            clock.add("compile", 0.001)

    threads = [threading.Thread(target=worker) for _ in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert abs(clock.times()["compile"] - 4.0) < 1e-6


# ------------------------------------------------- prefetch on/off equivalence


def test_prefetch_equivalence_in_interpret_mode():
    """The acceptance bar: with the compile prefetcher enabled, measured
    value arrays and n_compiles are identical to the inline path."""
    cfgs = batch_configs()
    inline = pallas_measurement(repeats=3, timer=counter_timer())
    v_inline = inline.measure_batch(cfgs)
    piped = pallas_measurement(repeats=3, pipeline_workers=3, timer=counter_timer())
    v_piped = piped.measure_batch(cfgs)
    piped.close()
    np.testing.assert_array_equal(v_inline, v_piped)
    assert inline.n_compiles == piped.n_compiles
    assert inline.run_compiles == piped.run_compiles
    assert np.isfinite(v_inline).any() and np.isinf(v_inline).any()


def test_prefetch_skips_screened_out_geometries():
    """The prefetcher must not compile configs the inline path would screen
    out — otherwise n_compiles diverges between the two paths."""
    # t_x=16 on a 16-row image fails the validity screen for add's geometry
    cfgs = batch_configs()
    inline = pallas_measurement(repeats=1)
    inline.measure_batch(cfgs)
    piped = pallas_measurement(repeats=1, pipeline_workers=4)
    piped.measure_batch(cfgs)
    piped.close()
    assert piped.n_compiles == inline.n_compiles
    assert sorted(piped._compiled) == sorted(inline._compiled)


def test_pipeline_pool_is_reusable_after_close():
    m = pallas_measurement(repeats=1, pipeline_workers=2)
    cfgs = batch_configs()[:4]
    a = m.measure_batch(cfgs)
    m.close()
    b = m.measure_batch(cfgs)           # pool rebuilds lazily
    np.testing.assert_array_equal(np.isfinite(a), np.isfinite(b))
    m.close()


def test_prefetched_compile_failures_are_penalties():
    """A geometry whose compile raises becomes a cached inf penalty through
    the prefetcher exactly as it does inline."""
    from repro.kernels.common import KernelBenchSpec
    from repro.pallas_bench import PallasMeasurement
    from repro.pallas_bench.workloads import PallasWorkload

    def boom(inputs, cfg, x, y):
        raise RuntimeError("no lowering for you")

    bench = KernelBenchSpec(
        name="boom", n_inputs=0, make_inputs=lambda x, y, seed: (), run=boom
    )
    m = PallasMeasurement(
        PallasWorkload(bench=bench, x=64, y=128),
        repeats=2, validate=False, pipeline_workers=2,
    )
    cfgs = [dict(t_x=1, t_y=1, t_z=z, w_x=1, w_y=1, w_z=1) for z in (1, 2, 2)]
    vals = m.measure_batch(cfgs)
    m.close()
    assert np.isinf(vals).all()
    assert m.n_compiles == 2            # one per distinct geometry, cached
    assert "no lowering" in m.reason_for(cfgs[0])


# ------------------------------------------------ per-run provenance counters


def test_provenance_counters_are_per_run():
    """n_compiles / n_invalid in provenance report work since the last
    reset(), not lifetime totals — a later matrix cell must not inherit an
    earlier cell's counts (the compile cache itself survives by design)."""
    m = pallas_measurement(repeats=1)
    m.measure_batch(batch_configs())
    first = m.provenance()
    assert first["n_compiles"] > 0 and first["n_invalid"] > 0
    assert first["n_compiles_total"] == m.n_compiles
    assert set(first["stage_s"]) == {"screen", "compile", "time", "record"}

    m.reset()
    blank = m.provenance()
    assert blank["n_compiles"] == 0 and blank["n_invalid"] == 0
    assert blank["n_compiles_total"] == first["n_compiles_total"]
    assert blank["stage_s"] == {}

    # warm re-measure: cache hits mean zero fresh compiles this run
    m.measure_batch(batch_configs())
    warm = m.provenance()
    assert warm["n_compiles"] == 0
    assert warm["n_invalid"] == first["n_invalid"]   # penalties re-served
    assert warm["n_compiles_total"] == first["n_compiles_total"]
    assert warm["stage_s"].get("compile", 0.0) == 0.0
    assert warm["stage_s"]["time"] > 0.0


def test_invalid_reasons_survive_reset():
    m = pallas_measurement(repeats=1)
    bad = dict(t_x=16, t_y=1, t_z=1, w_x=1, w_y=1, w_z=1)
    m.measure_batch([bad])
    reason = m.reason_for(bad)
    assert reason is not None
    m.reset()
    assert m.reason_for(bad) == reason


def test_stage_times_flow_through_wrappers_and_units(tmp_path):
    """Session-level plumbing: a staged backend's clocks land in the unit's
    stage_s (through the disk-cache wrapper) and in the record's compile/
    measure columns."""
    spec = TuningSpec(
        kernel="add",
        backend="pallas",
        backend_kwargs={"x": 16, "y": 256, "repeats": 1},
        algorithms=("rs",),
        design=ExperimentDesign(
            sample_sizes=(4,), n_experiments=(2,), final_repeats=2
        ),
        seed=3,
        store="json",
        store_path=str(tmp_path / "c.json"),
    )
    session = TuningSession(spec)
    session.run_matrix()
    rows = session.last_record.extra["cell_wall_s"]
    assert rows[0]["compile_s"] > 0.0 and rows[0]["measure_s"] >= 0.0
    assert rows[0]["wall_s"] >= rows[0]["compile_s"]

    # warm second run: everything served from the store, so no compile time
    warm = TuningSession(spec)
    warm.run_matrix()
    wrows = warm.last_record.extra["cell_wall_s"]
    assert wrows[0]["compile_s"] == 0.0 and wrows[0]["measure_s"] == 0.0


# --------------------------------------------------------- fail-fast draining


def arm_failing_unit(monkeypatch, bad_key: str):
    """Patch run_unit to raise once for the unit whose key is bad_key,
    recording every unit that actually ran."""
    ran = []
    armed = {"on": True}
    orig = TuningSession.run_unit

    def spy(self, u):
        ran.append(u.key)
        if armed["on"] and u.key == bad_key:
            raise RuntimeError(f"worker died on {u.key}")
        return orig(self, u)

    monkeypatch.setattr(TuningSession, "run_unit", spy)
    return ran, armed


def planned_units(spec, workers):
    """The decomposition a parallel run_matrix will build under the default
    stealing scheduler (cost-weighted oversplit)."""
    session = TuningSession(spec)
    return build_units(
        session.cells(),
        min_units=workers * STEAL_OVERSPLIT,
        cost=session._unit_cost(),
    )


def test_futures_failure_reraises_and_journals_completed(tmp_path, monkeypatch):
    """One failing worker no longer hides behind submission-order waits: the
    exception surfaces, and the healthy workers' journaled units are merged
    into the parent store so a resume re-runs only what actually failed."""
    spec = SPEC.replace(store="json", store_path=str(tmp_path / "c.json"))
    units = planned_units(spec, 2)
    bad = units[-1].key
    ran, armed = arm_failing_unit(monkeypatch, bad)

    with pytest.raises(RuntimeError, match="worker died"):
        TuningSession(spec).run_matrix(
            executor="futures", max_workers=2,
            futures_pool=ThreadPoolExecutor(max_workers=2),
        )
    assert bad in ran
    done_before = set(ran) - {bad}

    armed["on"] = False
    ran.clear()
    # resume with the same worker count so the decomposition matches the
    # journaled fragments exactly; with one pending unit the parallel
    # request degrades (with a warning) to serial
    with pytest.warns(UserWarning, match="degrades to serial"):
        res = TuningSession(spec).run_matrix(
            resume=True, executor="futures", max_workers=2,
            futures_pool=ThreadPoolExecutor(max_workers=2),
        )
    assert set(ran) == {bad}            # completed units served from journal
    assert not (done_before & set(ran))
    clean = repro.tune_matrix(SPEC)
    for key in clean.cells:
        np.testing.assert_array_equal(
            clean.cells[key].final_values, res.cells[key].final_values
        )


def test_device_executor_failure_then_resume(tmp_path, monkeypatch):
    """Kill-and-resume through the device executor's shard journals: a unit
    failure mid-run leaves the completed units journaled in the (merged)
    shard stores; the resumed device run re-executes only the failure."""
    spec = SPEC.replace(store="json", store_path=str(tmp_path / "c.json"))
    units = planned_units(spec, 2)
    bad = units[-1].key
    ran, armed = arm_failing_unit(monkeypatch, bad)

    with pytest.raises(RuntimeError, match="worker died"):
        with pytest.warns(UserWarning):   # 1 CPU device < 2 workers: capped
            TuningSession(spec).run_matrix(executor="device", max_workers=2)
    armed["on"] = False
    ran.clear()
    with pytest.warns(UserWarning):       # 1 pending unit: degrades to serial
        res = TuningSession(spec).run_matrix(
            resume=True, executor="device", max_workers=2
        )
    assert set(ran) == {bad}
    clean = repro.tune_matrix(SPEC)
    for key in clean.cells:
        np.testing.assert_array_equal(
            clean.cells[key].final_values, res.cells[key].final_values
        )


# ------------------------------------------------------------ device executor


def store_values_bytes(path: str) -> bytes:
    return json.dumps(
        sorted(MeasurementStore(path).items()), sort_keys=True
    ).encode()


def test_device_executor_bit_identical_to_serial(tmp_path):
    serial_path = str(tmp_path / "serial.json")
    device_path = str(tmp_path / "device.json")
    base = TuningSession(SPEC.replace(store="json", store_path=serial_path))
    serial = base.run_matrix()
    dev_session = TuningSession(SPEC.replace(store="json", store_path=device_path))
    with pytest.warns(UserWarning):       # single-device host: capped
        device = dev_session.run_matrix(executor="device", max_workers=2)
    for key in serial.cells:
        np.testing.assert_array_equal(
            serial.cells[key].final_values, device.cells[key].final_values
        )
    assert base.last_record.result["cells"] == dev_session.last_record.result["cells"]
    assert store_values_bytes(serial_path) == store_values_bytes(device_path)
    assert not [f for f in os.listdir(tmp_path) if ".shard" in f]


FOUR_DEVICE_SCRIPT = """
import json, sys
import jax
assert len(jax.devices()) == 4, jax.devices()
from repro.core import (
    ExperimentDesign, MeasurementStore, TuningSession, TuningSpec,
)
tmp = sys.argv[1]
spec = TuningSpec(
    kernel="harris", backend_kwargs={"chip": "v5e"}, algorithms=("rs", "ga"),
    design=ExperimentDesign(sample_sizes=(25,), n_experiments=(4,),
                            final_repeats=3),
    seed=11,
)
paths = {}
for name, kwargs in (
    ("serial", {}),
    ("device", dict(executor="device", max_workers=4)),
):
    path = f"{tmp}/{name}.json"
    session = TuningSession(spec.replace(store="json", store_path=path))
    res = session.run_matrix(**kwargs)
    paths[name] = path

def values_bytes(p):
    return json.dumps(sorted(MeasurementStore(p).items()), sort_keys=True)

assert values_bytes(paths["serial"]) == values_bytes(paths["device"])
print("DEVICE_OK")
"""


def test_device_executor_on_four_fake_devices(tmp_path):
    """The acceptance bar: EXECUTORS["device"] on a host faked to 4 CPU
    devices produces a merged store byte-identical to serial.  XLA_FLAGS
    must be set before jax initializes, hence the subprocess."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run(
        [sys.executable, "-c", FOUR_DEVICE_SCRIPT, str(tmp_path)],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert out.returncode == 0, out.stderr
    assert "DEVICE_OK" in out.stdout
