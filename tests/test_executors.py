"""Work-unit executor layer: decomposition, executor equivalence (serial ≡
process ≡ futures ≡ legacy shards=N, bit-identical), within-cell splits of
big-E rows, journal-based kill-and-resume, and degrade warnings."""

import json
import os
import shutil
from concurrent.futures import ThreadPoolExecutor

import numpy as np
import pytest

import repro
from repro.core import (
    EXECUTORS,
    ExperimentDesign,
    ExperimentUnit,
    MeasurementStore,
    TuningSession,
    TuningSpec,
    UnitResult,
    build_units,
    merge_unit_results,
)
from repro.core.executors import (
    ExecutionPlan,
    run_units,
    shard_namespace,
    shard_store_path,
)

SMOKE = dict(kernel="harris", backend_kwargs={"chip": "v5e"})

SPEC = TuningSpec(
    **SMOKE,
    algorithms=("rs", "rf", "ga"),
    design=ExperimentDesign(sample_sizes=(25,), n_experiments=(4,), final_repeats=3),
    seed=11,
    dataset_size=200,
)


def unit(algo="ga", s=25, lo=0, hi=4, e=4):
    return ExperimentUnit(algo=algo, sample_size=s, exp_lo=lo, exp_hi=hi, n_exp=e)


def assert_same_cells(a, b):
    assert set(a.cells) == set(b.cells)
    for key in a.cells:
        np.testing.assert_array_equal(
            a.cells[key].final_values, b.cells[key].final_values
        )
        np.testing.assert_array_equal(
            a.cells[key].search_best_values, b.cells[key].search_best_values
        )
        np.testing.assert_array_equal(
            a.cells[key].n_samples_used, b.cells[key].n_samples_used
        )


def store_values_bytes(path: str) -> bytes:
    """Canonical bytes of a JSON store's measurement VALUES (journal entries
    in the metadata side-channel carry wall-clocks, which legitimately vary
    run to run)."""
    return json.dumps(
        sorted(MeasurementStore(path).items()), sort_keys=True
    ).encode()


# ------------------------------------------------------------- decomposition


def test_build_units_one_per_cell_by_default():
    cells = [("rs", 25, 8), ("ga", 50, 4)]
    units = build_units(cells)
    assert [u.key for u in units] == ["rs/S25/E8/e0:8", "ga/S50/E4/e0:4"]


def test_build_units_splits_largest_until_min_units():
    units = build_units([("ga", 25, 8)], min_units=4)
    assert [(u.exp_lo, u.exp_hi) for u in units] == [(0, 2), (2, 4), (4, 6), (6, 8)]
    assert all(u.n_exp == 8 for u in units)
    # more workers than experiments: stops at one experiment per unit
    units = build_units([("ga", 25, 2)], min_units=16)
    assert len(units) == 2


def test_build_units_caps_unit_experiments():
    units = build_units([("rs", 25, 5)], max_unit_experiments=2)
    assert [(u.exp_lo, u.exp_hi) for u in units] == [(0, 2), (2, 4), (4, 5)]


def test_unit_validation_and_roundtrip():
    with pytest.raises(ValueError, match="invalid experiment range"):
        ExperimentUnit(algo="ga", sample_size=25, exp_lo=3, exp_hi=3, n_exp=4)
    u = unit(lo=1, hi=3)
    assert ExperimentUnit.from_dict(u.to_dict()) == u
    r = UnitResult(
        unit=u,
        final_values=np.array([1.0, 2.0]),
        search_best_values=np.array([1.5, 2.5]),
        n_samples_used=np.array([25, 25]),
        wall_s=0.5,
    )
    again = UnitResult.from_dict(json.loads(json.dumps(r.to_dict())))
    np.testing.assert_array_equal(again.final_values, r.final_values)
    assert again.unit == u


def test_merge_detects_gaps_and_duplicates():
    cells = [("ga", 25, 4)]
    a = UnitResult(unit=unit(lo=0, hi=2), final_values=np.ones(2),
                   search_best_values=np.ones(2), n_samples_used=np.ones(2))
    b = UnitResult(unit=unit(lo=2, hi=4), final_values=np.ones(2),
                   search_best_values=np.ones(2), n_samples_used=np.ones(2))
    merged, walls = merge_unit_results(cells, [b, a])   # order-insensitive
    assert len(merged) == 1 and len(merged[0].final_values) == 4
    assert walls[("ga", 25)]["wall_s"] == a.wall_s + b.wall_s
    assert walls[("ga", 25)]["compile_s"] == 0.0   # unstaged: no breakdown
    assert walls[("ga", 25)]["measure_s"] == 0.0
    with pytest.raises(ValueError, match="duplicate unit"):
        merge_unit_results(cells, [a, a, b])
    with pytest.raises(ValueError, match="coverage gap|covered only"):
        merge_unit_results(cells, [a])


def test_executor_registry():
    assert {"serial", "process", "futures", "device"} <= set(EXECUTORS)
    assert repro.EXECUTORS is EXECUTORS
    with pytest.raises(KeyError, match="unknown executor"):
        run_units("warp", ExecutionPlan(session=None))
    with pytest.raises(KeyError, match="unknown executor"):
        TuningSession(SPEC).run_matrix(executor="warp")


# ------------------------------------------------------- executor equivalence


def test_all_executors_bit_identical(tmp_path):
    """serial ≡ legacy shards=N ≡ process ≡ futures: identical CellResults,
    identical RunRecord cell summaries, byte-identical merged store values —
    including within-cell splits of the rf/rs dataset-served paths."""
    runs = {
        "serial": dict(),
        "legacy": dict(shards=2),
        "process": dict(executor="process", max_workers=3),
        "futures": dict(
            executor="futures", max_workers=3,
            futures_pool=ThreadPoolExecutor(max_workers=3),
        ),
    }
    results, records, bytes_ = {}, {}, {}
    for name, kwargs in runs.items():
        path = str(tmp_path / f"{name}.json")
        session = TuningSession(
            SPEC.replace(store="json", store_path=path)
        )
        results[name] = session.run_matrix(**kwargs)
        records[name] = session.last_record.result
        bytes_[name] = store_values_bytes(path)
    for name in ("legacy", "process", "futures"):
        assert_same_cells(results["serial"], results[name])
        assert records[name]["cells"] == records["serial"]["cells"]
        assert bytes_[name] == bytes_["serial"]
    # shard stores were merged and cleaned up
    assert not [f for f in os.listdir(tmp_path) if ".shard" in f]


def test_within_cell_split_of_big_e_row():
    """A single-cell matrix — where the old `len(cells) > 1` guard silently
    ran serial — now splits the cell across workers, bit-identically."""
    spec = SPEC.replace(
        algorithms=("ga",),
        design=ExperimentDesign(sample_sizes=(25,), n_experiments=(6,), final_repeats=3),
        dataset_size=None,
    )
    serial = TuningSession(spec)
    base = serial.run_matrix()
    assert len(serial.last_unit_plan) == 1
    sharded = TuningSession(spec)
    split = sharded.run_matrix(executor="process", max_workers=3)
    assert len(sharded.last_unit_plan) >= 3      # the cell actually split
    assert_same_cells(base, split)


def test_unit_experiments_cap_is_bit_identical():
    spec = SPEC.replace(algorithms=("rs", "rf"))
    base = repro.tune_matrix(spec)
    session = TuningSession(spec)
    capped = session.run_matrix(unit_experiments=1)
    assert len(session.last_unit_plan) == 8      # 2 cells x 4 experiments
    assert_same_cells(base, capped)


def test_futures_pool_alone_implies_parallel_executor():
    """Passing a pool IS the parallelism request: no max_workers/executor
    needed, and the pool must actually be used (not silently degraded).
    Under the default stealing scheduler every unit is its own submission;
    under static there is exactly one payload per worker."""
    class CountingPool(ThreadPoolExecutor):
        submits = 0

        def submit(self, *args, **kwargs):
            type(self).submits += 1
            return super().submit(*args, **kwargs)

    spec = SPEC.replace(algorithms=("rs", "ga"), dataset_size=None)
    base = repro.tune_matrix(spec)
    session = TuningSession(spec)
    res = session.run_matrix(futures_pool=CountingPool(max_workers=2))
    assert CountingPool.submits == len(session.last_unit_plan) >= 2
    assert_same_cells(base, res)
    CountingPool.submits = 0
    res = repro.tune_matrix(
        spec, futures_pool=CountingPool(max_workers=2), scheduler="static"
    )
    assert CountingPool.submits == 2
    assert_same_cells(base, res)
    with pytest.raises(ValueError, match="futures_pool"):
        repro.tune_matrix(spec, executor="process",
                          futures_pool=ThreadPoolExecutor(max_workers=2))


def test_futures_default_pool_spawns_processes(tmp_path):
    spec = SPEC.replace(
        algorithms=("rs",), dataset_size=None,
        store="json", store_path=str(tmp_path / "f.json"),
    )
    base = repro.tune_matrix(spec.replace(store=None, store_path=None))
    res = repro.tune_matrix(spec, executor="futures", max_workers=2)
    assert_same_cells(base, res)


# --------------------------------------------------------- degrade + errors


def test_parallel_request_degrades_to_serial_with_warning():
    spec = SPEC.replace(
        algorithms=("ga",),
        design=ExperimentDesign(sample_sizes=(25,), n_experiments=(1,), final_repeats=3),
        dataset_size=None,
    )
    with pytest.warns(UserWarning, match="degrades to serial"):
        res = TuningSession(spec).run_matrix(shards=4)
    assert set(res.cells) == {("ga", 25)}


def test_resume_without_store_warns():
    spec = SPEC.replace(algorithms=("ga",), dataset_size=None)
    with pytest.warns(UserWarning, match="persistent store"):
        repro.tune_matrix(spec, resume=True)


def test_parallel_run_rejects_in_process_overrides():
    from repro.core import make_measurement

    session = TuningSession(
        SPEC,
        measurement_factory=lambda s: make_measurement(
            "costmodel", kernel="harris", seed=s
        ),
    )
    for executor in ("process", "futures"):
        with pytest.raises(RuntimeError, match="serialized spec"):
            session.run_matrix(executor=executor, max_workers=2)


# ------------------------------------------------------------ kill-and-resume


def spy_run_unit(monkeypatch):
    ran = []
    orig = TuningSession.run_unit

    def spy(self, u):
        ran.append(u.key)
        return orig(self, u)

    monkeypatch.setattr(TuningSession, "run_unit", spy)
    return ran


def test_resume_skips_journaled_units(tmp_path, monkeypatch):
    """A run interrupted after K units resumes from the journal: completed
    units are never re-executed (zero re-measurements — run_unit is not even
    called) and the final matrix is bit-identical to an uninterrupted run."""
    clean = repro.tune_matrix(SPEC)
    spec = SPEC.replace(store="json", store_path=str(tmp_path / "c.json"))
    # "interrupted" run: execute + journal only the first 2 of 4+ units
    partial = TuningSession(spec)
    units = build_units(partial.cells(), min_units=4)
    journal = partial.unit_journal()
    for u in units[:2]:
        journal.put(partial.run_unit(u))
    partial.save_store()

    ran = spy_run_unit(monkeypatch)
    resumed = TuningSession(spec)
    res = resumed.run_matrix(resume=True, max_workers=4, executor="serial",
                             unit_experiments=None)
    # the serial resume re-plans with min_units=1 (whole cells); journaled
    # fine-grained fragments must still be composed/skipped
    done_keys = {u.key for u in units[:2]}
    assert not (done_keys & set(ran))
    assert_same_cells(clean, res)


def test_resume_ignores_journal_from_a_different_spec(tmp_path, monkeypatch):
    """The journal namespace fingerprints the WHOLE spec (minus storage
    fields): entries written under different searcher_kwargs / dataset
    settings must never be served to a resumed run."""
    spec = SPEC.replace(
        algorithms=("ga",), dataset_size=None,
        searcher="ga", searcher_kwargs={"pop_size": 8},
        store="json", store_path=str(tmp_path / "c.json"),
    )
    first = TuningSession(spec)
    first.run_matrix(resume=True)

    changed = spec.replace(searcher_kwargs={"pop_size": 12})
    ran = spy_run_unit(monkeypatch)
    res = TuningSession(changed).run_matrix(resume=True)
    assert len(ran) == len(build_units(TuningSession(changed).cells()))
    assert_same_cells(repro.tune_matrix(changed.replace(store=None, store_path=None)), res)


def test_resume_with_process_executor_after_serial_partial(tmp_path):
    """Cross-executor resume: units journaled by an interrupted serial run
    are skipped by a process-executor resume (journal payload bytes are
    untouched — a re-run would rewrite its wall-clock)."""
    spec = SPEC.replace(store="json", store_path=str(tmp_path / "c.json"))
    partial = TuningSession(spec)
    units = build_units(partial.cells(), min_units=3)
    journal = partial.unit_journal()
    done = [partial.run_unit(u) for u in units[:2]]
    for r in done:
        journal.put(r)
    partial.save_store()
    before = {
        journal.key(r.unit): partial.store.get_meta(journal.key(r.unit))
        for r in done
    }

    resumed = TuningSession(spec)
    res = resumed.run_matrix(resume=True, executor="process", max_workers=3)
    after_store = MeasurementStore(spec.store_path)
    for k, v in before.items():
        assert after_store.get_meta(k) == v     # entry untouched => not re-run
    assert_same_cells(repro.tune_matrix(SPEC), res)


def test_resume_recovers_killed_workers_shard_stores(tmp_path, monkeypatch):
    """A parallel run killed before the merge leaves *.shard<k> stores whose
    journals hold the workers' completed units; a resumed run absorbs them
    and re-executes nothing that finished."""
    spec = SPEC.replace(
        algorithms=("rs", "ga"),
        store="json", store_path=str(tmp_path / "c.json"),
    )
    # simulate the killed worker: a full serial run journaled into a store
    # that never became the parent store
    ghost = TuningSession(spec.replace(store_path=str(tmp_path / "ghost.json")))
    ghost_res = ghost.run_matrix()

    ran = spy_run_unit(monkeypatch)
    resumed = TuningSession(spec)
    shard = shard_store_path(resumed, 0)
    shutil.move(str(tmp_path / "ghost.json"), shard)
    res = resumed.run_matrix(resume=True)
    assert ran == []                            # everything recovered
    assert not os.path.exists(shard)
    assert_same_cells(ghost_res, res)


# ----------------------------------------------------- stealing scheduler


def test_steal_static_and_device_schedulers_bit_identical(tmp_path):
    """serial ≡ process(steal) ≡ process(static) ≡ device(steal) ≡
    futures(steal): identical cells and byte-identical store values, no
    leftover shard stores — the scheduler is pure wall-clock."""
    spec = SPEC.replace(algorithms=("rs", "ga"), dataset_size=None)
    runs = {
        "serial": dict(),
        "steal": dict(executor="process", max_workers=2, scheduler="steal"),
        "static": dict(executor="process", max_workers=2, scheduler="static"),
        "futures": dict(
            executor="futures", max_workers=2,
            futures_pool=ThreadPoolExecutor(max_workers=2),
        ),
    }
    results, bytes_ = {}, {}
    for name, kwargs in runs.items():
        path = str(tmp_path / f"{name}.json")
        session = TuningSession(spec.replace(store="json", store_path=path))
        results[name] = session.run_matrix(**kwargs)
        bytes_[name] = store_values_bytes(path)
    path = str(tmp_path / "device.json")
    session = TuningSession(spec.replace(store="json", store_path=path))
    with pytest.warns(UserWarning):          # single-device host: capped
        results["device"] = session.run_matrix(executor="device", max_workers=2)
    bytes_["device"] = store_values_bytes(path)
    for name in ("steal", "static", "futures", "device"):
        assert_same_cells(results["serial"], results[name])
        assert bytes_[name] == bytes_["serial"]
    assert not [f for f in os.listdir(tmp_path) if ".shard" in f]


def test_steal_run_emits_scheduler_telemetry(tmp_path):
    from repro.telemetry import for_run_dir, read_run

    run_dir = str(tmp_path / "run")
    tel = for_run_dir(run_dir)
    spec = SPEC.replace(
        algorithms=("rs", "ga"), dataset_size=None,
        store="json", store_path=str(tmp_path / "c.json"),
    )
    session = TuningSession(spec, telemetry=tel)
    session.run_matrix(executor="process", max_workers=2)
    tel.close()
    events = read_run(run_dir)
    plan = [e for e in events if e["ev"] == "plan"][0]
    assert plan["scheduler"] == "steal"
    # the queue drains one gauge tick per retired unit, ending at zero
    depths = [
        e["value"] for e in events
        if e["ev"] == "gauge" and e["gauge"] == "scheduler.queue_depth"
    ]
    assert len(depths) == len(session.last_unit_plan)
    assert sorted(depths, reverse=True) == depths and depths[-1] == 0
    # steals may legitimately be zero on a fast matrix; the counter must
    # simply never exceed what could have been rebalanced
    totals = [e for e in events if e["ev"] == "totals"][-1]["counters"]
    assert 0 <= totals.get("scheduler.steals", 0) <= len(depths)
    assert totals["units_completed"] == len(session.last_unit_plan)


def test_static_scheduler_plan_event_and_rejects_unknown(tmp_path):
    from repro.telemetry import for_run_dir, read_run

    run_dir = str(tmp_path / "run")
    tel = for_run_dir(run_dir)
    spec = SPEC.replace(algorithms=("rs", "ga"), dataset_size=None)
    TuningSession(spec, telemetry=tel).run_matrix(
        executor="process", max_workers=2, scheduler="static"
    )
    tel.close()
    plan = [e for e in read_run(run_dir) if e["ev"] == "plan"][0]
    assert plan["scheduler"] == "static"
    with pytest.raises(ValueError, match="unknown scheduler"):
        TuningSession(spec).run_matrix(scheduler="warp")


def test_process_steal_parent_failure_still_merges_shards(tmp_path, monkeypatch):
    """Fail-fast parity for the stealing path: when the parent's drain dies,
    completed workers' shard stores are absorbed before the error surfaces,
    so a resume re-executes nothing that finished."""
    import concurrent.futures as cf

    import repro.core.executors as ex

    spec = SPEC.replace(
        algorithms=("rs", "ga"), dataset_size=None,
        store="json", store_path=str(tmp_path / "c.json"),
    )
    clean = repro.tune_matrix(spec.replace(store=None, store_path=None))

    def dying_drain(plan, futures, n_workers):
        cf.wait(list(futures))               # let every unit finish first
        raise RuntimeError("parent died mid-drain")

    monkeypatch.setattr(ex, "_drain_steal", dying_drain)
    with pytest.raises(RuntimeError, match="parent died mid-drain"):
        TuningSession(spec).run_matrix(executor="process", max_workers=2)
    monkeypatch.undo()
    assert not [f for f in os.listdir(tmp_path) if ".shard" in f]

    ran = spy_run_unit(monkeypatch)
    res = TuningSession(spec).run_matrix(resume=True)
    assert ran == []                         # every unit came from the journal
    assert_same_cells(clean, res)


def test_resume_recovers_pid_shaped_steal_shards(tmp_path, monkeypatch):
    """Steal workers name shards by pid, not slot index — recovery globs, so
    a leftover ``*.shard31337`` from a killed stealing run is absorbed the
    same as the legacy ``*.shard0``."""
    spec = SPEC.replace(
        algorithms=("rs", "ga"),
        store="json", store_path=str(tmp_path / "c.json"),
    )
    ghost = TuningSession(spec.replace(store_path=str(tmp_path / "ghost.json")))
    ghost_res = ghost.run_matrix()

    ran = spy_run_unit(monkeypatch)
    resumed = TuningSession(spec)
    shard = shard_store_path(resumed, 31337)
    shutil.move(str(tmp_path / "ghost.json"), shard)
    res = resumed.run_matrix(resume=True)
    assert ran == []
    assert not os.path.exists(shard)
    assert_same_cells(ghost_res, res)


def test_recovery_ignores_other_specs_shards(tmp_path, monkeypatch):
    """Regression: shard filenames carry the journal-namespace digest, so a
    resumed run must NOT absorb a shard left behind by a *different* spec
    writing through the same store path (absorbing it would orphan journal
    entries and serve values from the wrong experiment)."""
    spec_a = SPEC.replace(
        algorithms=("rs",), store="json", store_path=str(tmp_path / "c.json"),
    )
    spec_b = spec_a.replace(seed=SPEC.seed + 1)   # different experiment stream
    assert (shard_namespace(TuningSession(spec_a))
            != shard_namespace(TuningSession(spec_b)))

    # a killed run of spec B left a fully-journaled shard beside c.json
    ghost = TuningSession(spec_b.replace(store_path=str(tmp_path / "ghost.json")))
    ghost.run_matrix()
    foreign = shard_store_path(TuningSession(spec_b), 0)
    shutil.move(str(tmp_path / "ghost.json"), foreign)

    ran = spy_run_unit(monkeypatch)
    res_a = TuningSession(spec_a).run_matrix(resume=True)
    assert ran != []                    # nothing recovered: A ran its own units
    assert os.path.exists(foreign)      # B's shard survives untouched

    # and B itself can still resume from its shard afterwards
    ran_b = spy_run_unit(monkeypatch)
    res_b = TuningSession(spec_b).run_matrix(resume=True)
    assert ran_b == []
    assert not os.path.exists(foreign)
    del res_a, res_b


# ------------------------------------------------------------- wall-clock


def test_cell_wall_clock_lands_in_record_and_figures(tmp_path):
    out = str(tmp_path / "out")
    repro.tune_matrix(SPEC.replace(cache_key="harris/v5e"), out_dir=out)
    rec = repro.RunRecord.load(os.path.join(out, "harris_v5e.json"))
    walls = rec.extra["cell_wall_s"]
    assert {(w["algo"], w["sample_size"]) for w in walls} == {
        ("rs", 25), ("rf", 25), ("ga", 25)
    }
    assert all(w["wall_s"] >= 0 for w in walls)
    # the costmodel backend is unstaged: breakdown columns exist but are 0
    assert all(w["compile_s"] == 0.0 and w["measure_s"] == 0.0 for w in walls)

    import sys
    sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    from benchmarks.figures import load_all, render_grid, search_cost

    table = search_cost(load_all(out))
    cell = table[("harris", "v5e")]["ga"][25]
    assert cell["wall"] >= 0 and cell["compile"] == 0.0 and cell["measure"] == 0.0
    assert "search cost" in render_grid(
        table, fmt="{0[wall]:.2f}s", title="search cost"
    )


# ------------------------------------------------------- fleet chaos (SIGKILL)


def test_fleet_sigkill_peer_steals_and_store_is_byte_identical(tmp_path):
    """Three cross-process fleet workers; one is SIGKILLed mid-unit (inside
    its ``--stall-s`` window, holding a claim).  The peers must steal the
    dead worker's claim, finish the job, and the collected parent store must
    be byte-identical to a serial run of the same spec."""
    import importlib.util
    import signal
    import subprocess
    import sys
    import time

    from repro.core.stores import make_store
    from repro.serving import JobQueue, collect_jobs, job_id_for_spec

    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ, PYTHONPATH=os.path.join(repo, "src"))
    serve_dir = str(tmp_path / "serve")
    os.makedirs(serve_dir)
    store_path = os.path.join(serve_dir, "store.json")
    qdir = os.path.join(serve_dir, "queue")

    spec = SPEC.replace(store="json", store_path=store_path)
    store = make_store("json", store_path)
    queue = JobQueue(store, "json", store_path, qdir)
    jid = queue.enqueue(spec)
    assert jid == job_id_for_spec(
        spec.replace(store="json", store_path=store_path).to_dict()
    )

    def worker_cmd(ident, stall_s, claim_timeout_s, timeout_s):
        return [
            sys.executable, "-m", "repro.serving", "worker",
            "--dir", serve_dir, "--store", "json", "--ident", ident,
            "--stall-s", str(stall_s), "--claim-timeout-s", str(claim_timeout_s),
            "--timeout-s", str(timeout_s), "--poll-s", "0.05",
        ]

    # the victim stalls 60s after its first claim: an arbitrarily wide kill
    # window (we kill as soon as the claim file appears)
    victim = subprocess.Popen(
        worker_cmd("victim", 60, 1000, 120), env=env, cwd=repo,
        stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True,
    )
    try:
        deadline = time.monotonic() + 60
        claimed = None
        while time.monotonic() < deadline:
            for f in os.listdir(qdir) if os.path.isdir(qdir) else []:
                if f.endswith(".claim"):
                    with open(os.path.join(qdir, f)) as fh:
                        if fh.read() == "victim":
                            claimed = f
                            break
            if claimed or victim.poll() is not None:
                break
            time.sleep(0.05)
        assert claimed, (
            f"victim never claimed a unit: {victim.communicate()[0]!r}"
        )
        os.kill(victim.pid, signal.SIGKILL)
    finally:
        victim.wait(timeout=30)

    # peers arrive late: the victim's claim is already stale for them
    peers = [
        subprocess.Popen(
            worker_cmd(ident, 0, 1.0, 90), env=env, cwd=repo,
            stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True,
        )
        for ident in ("w2", "w3")
    ]
    outs = [p.communicate(timeout=120)[0] for p in peers]
    for p, out in zip(peers, outs, strict=True):
        assert p.returncode == 0, out

    # done markers, inspected BEFORE collect cleans them up: every unit has
    # one, none was run by the victim, and the victim's unit was stolen
    done = []
    for f in sorted(os.listdir(qdir)):
        if f.endswith(".done"):
            done.append(json.load(open(os.path.join(qdir, f))))
    assert done, "no done markers published"
    assert all(d["ident"] in ("w2", "w3") for d in done)
    stolen = [d for d in done if d["stolen"]]
    assert len(stolen) == 1, stolen
    assert stolen[0]["ident"] != "victim"

    assert collect_jobs("json", store_path, qdir) == [jid]
    q2 = JobQueue(make_store("json", store_path), "json", store_path, qdir)
    assert q2.job(jid)["state"] == "done"
    assert q2.job(jid)["done_ident"] == "collect"

    # byte-identity against the serial reference, through the same tool the
    # executor-equivalence contract ships (tools/compare_stores.py)
    serial_path = str(tmp_path / "serial.json")
    TuningSession(spec.replace(store_path=serial_path)).run_matrix()
    tool_spec = importlib.util.spec_from_file_location(
        "compare_stores", os.path.join(repo, "tools", "compare_stores.py")
    )
    tool = importlib.util.module_from_spec(tool_spec)
    tool_spec.loader.exec_module(tool)
    assert tool.values_bytes(tool.load(store_path)) == tool.values_bytes(
        tool.load(serial_path)
    )
    assert tool.main([store_path, serial_path]) == 0
