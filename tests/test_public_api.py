"""The public ``repro.tune`` facade: spec serialization, the backend/store
registries, the sharded session driver, and the deprecation shims."""

import os

import numpy as np
import pytest

import repro
from repro.core import (
    BACKENDS,
    CachedMeasurement,
    DiskCachedMeasurement,
    ExperimentDesign,
    MeasurementStore,
    RunRecord,
    SqliteMeasurementStore,
    TuningSession,
    TuningSpec,
    make_measurement,
    make_searcher,
    make_store,
    paper_space,
)
from repro.costmodel import CHIPS, WORKLOADS, CostModelMeasurement, executable_space

SMOKE = dict(kernel="harris", backend_kwargs={"chip": "v5e"})


# ------------------------------------------------------------ spec round-trip


def test_spec_roundtrips_through_json_with_derived_space():
    spec = TuningSpec(
        **SMOKE,
        searcher="ga",
        searcher_kwargs={"pop_size": 10},
        budget=50,
        seed=3,
        store="sqlite",
        store_path="/tmp/x.sqlite",
        dataset_size=400,
    )
    again = TuningSpec.from_json(spec.to_json())
    assert again == spec
    assert again.to_dict() == spec.to_dict()


def test_spec_roundtrips_explicit_space_and_design():
    spec = TuningSpec(
        kernel="harris",
        space=paper_space(),                       # named "paper_wg256" constraint
        design=ExperimentDesign.smoke(),
        algorithms=("rs", "ga"),
    )
    again = TuningSpec.from_json(spec.to_json())
    assert again.to_dict() == spec.to_dict()
    assert again.design == spec.design
    cfg_bad = dict(t_x=1, t_y=1, t_z=1, w_x=8, w_y=8, w_z=8)
    assert not again.space.is_valid(cfg_bad)       # constraint survived


def test_spec_roundtrips_vmem_constraint_space():
    w, chip = WORKLOADS["add"], CHIPS["v4"]
    spec = TuningSpec(kernel="add", space=executable_space(w, chip), budget=10)
    again = TuningSpec.from_json(spec.to_json())
    rng = np.random.default_rng(0)
    cfgs = paper_space().unconstrained().sample_batch(rng, 50)
    assert [spec.space.is_valid(c) for c in cfgs] == [
        again.space.is_valid(c) for c in cfgs
    ]


def test_spec_with_callable_backend_kwargs_is_not_serializable():
    spec = TuningSpec(
        kernel="k",
        backend="timing",
        backend_kwargs={"runner": lambda cfg: None},
        space=paper_space(),
        budget=5,
    )
    with pytest.raises(TypeError, match="not JSON-serializable"):
        spec.to_json()


def test_spec_validation_errors():
    with pytest.raises(KeyError, match="unknown searcher"):
        TuningSpec(kernel="k", searcher="nope")
    with pytest.raises(KeyError, match="unknown backend"):
        TuningSpec(kernel="k", backend="nope")
    with pytest.raises(KeyError, match="unknown store"):
        TuningSpec(kernel="k", store="nope")
    with pytest.raises(KeyError, match="unknown algorithms"):
        TuningSpec(kernel="k", algorithms=("rs", "nope"))
    with pytest.raises(ValueError, match="dispatch"):
        TuningSpec(kernel="k", dispatch="sideways")
    with pytest.raises(ValueError, match="budget"):
        TuningSpec(kernel="k", budget=0)
    with pytest.raises(ValueError, match="kernel"):
        TuningSpec(kernel="")


# ------------------------------------------------------------ BACKENDS registry


def test_make_measurement_resolves_costmodel():
    m = make_measurement("costmodel", kernel="harris", chip="v5e", seed=4)
    assert isinstance(m, CostModelMeasurement)
    assert m.seed == 4
    with pytest.raises(KeyError, match="unknown backend"):
        make_measurement("warp_drive")
    with pytest.raises(KeyError, match="unknown kernel"):
        make_measurement("costmodel", kernel="nope")
    with pytest.raises(KeyError, match="unknown chip"):
        make_measurement("costmodel", kernel="harris", chip="h100")


def test_make_measurement_wraps_inner_backends(tmp_path):
    m = make_measurement(
        "cached", inner="callable", inner_kwargs={"fn": lambda cfg: 1.0}
    )
    assert isinstance(m, CachedMeasurement)
    d = make_measurement(
        "disk",
        kernel="harris",
        seed=2,
        inner="costmodel",
        inner_kwargs={"chip": "v4"},
        store="sqlite",
        store_path=str(tmp_path / "c.sqlite"),
    )
    assert isinstance(d, DiskCachedMeasurement)
    assert d.prefix == "harris/seed=2"
    with pytest.raises(TypeError, match="inner must be"):
        make_measurement("cached", inner=42)


def test_backend_default_space_matches_executable_space():
    space = BACKENDS["costmodel"].default_space(kernel="add", chip="v3")
    ref = executable_space(WORKLOADS["add"], CHIPS["v3"])
    rng = np.random.default_rng(1)
    np.testing.assert_array_equal(
        space.sample_indices(rng, 20),
        ref.sample_indices(np.random.default_rng(1), 20),
    )


# ------------------------------------------------------------ stores


def test_sqlite_store_roundtrip_and_reload(tmp_path):
    path = str(tmp_path / "m.sqlite")
    s = make_store("sqlite", path)
    assert isinstance(s, SqliteMeasurementStore)
    s.put("a|x=1", 0.5)
    s.put("a|x=2", 0.25)
    s.save()
    s.close()
    s2 = make_store("sqlite", path)
    assert len(s2) == 2
    assert s2.get("a|x=1") == 0.5
    assert s2.get("missing") is None
    assert dict(s2.items())["a|x=2"] == 0.25
    s2.update([("b|y=1", 1.5)])
    assert len(s2) == 3
    with pytest.raises(KeyError, match="unknown store"):
        make_store("parquet", path)


def test_sqlite_store_behind_disk_cache_serves_repeats(tmp_path):
    path = str(tmp_path / "m.sqlite")
    w, chip = WORKLOADS["add"], CHIPS["v5e"]
    space = executable_space(w, chip)

    def run(store):
        inner = CostModelMeasurement(w, chip, seed=6)
        m = DiskCachedMeasurement(inner, store, prefix="add/v5e/seed=6")
        r = make_searcher("ga", space, seed=2).run(m, 30)
        return r, m

    r1, m1 = run(make_store("sqlite", path))
    m1._store.save()
    assert m1.n_misses == 30
    r2, m2 = run(make_store("sqlite", path))
    assert m2.n_misses == 0
    assert r1.history_values == r2.history_values


def test_spec_store_sqlite_is_used_by_session(tmp_path):
    path = str(tmp_path / "cell.sqlite")
    spec = TuningSpec(**SMOKE, searcher="rs", budget=20, store="sqlite",
                      store_path=path)
    repro.tune(spec)
    assert len(make_store("sqlite", path)) > 0


# ------------------------------------------------------------ tune() facade


def test_tune_matches_manual_drive_bit_identically():
    spec = TuningSpec(**SMOKE, searcher="ga", budget=30, seed=7)
    r1 = repro.tune(spec)
    w, chip = WORKLOADS["harris"], CHIPS["v5e"]
    m = CostModelMeasurement(w, chip, seed=7)
    r2 = make_searcher("ga", executable_space(w, chip), seed=7).run(m, 30)
    assert r1.history_values == r2.history_values
    assert r1.best_config == r2.best_config
    assert r1.n_samples == 30
    # the facade applies the paper's final re-measurement; ask/tell does not
    assert r1.final_value is not None
    assert r2.final_value is None


def test_tune_writes_run_record(tmp_path):
    path = str(tmp_path / "rec.json")
    spec = TuningSpec(**SMOKE, searcher="rs", budget=10, seed=1)
    r = repro.tune(spec, record_path=path)
    rec = RunRecord.load(path)
    assert rec.version == 1
    assert rec.kind == "tune"
    assert rec.spec["kernel"] == "harris"
    assert rec.result["final_value"] == r.final_value
    assert rec.result["n_samples"] == 10
    assert "created_at" in rec.provenance and "numpy" in rec.provenance


def test_tune_requires_budget_and_matrix_requires_design():
    with pytest.raises(ValueError, match="budget"):
        repro.tune(TuningSpec(**SMOKE))
    with pytest.raises(ValueError, match="design"):
        repro.tune_matrix(TuningSpec(**SMOKE, budget=5))


def test_session_rejects_spaceless_backend():
    with pytest.raises(ValueError, match="no default space"):
        TuningSession(
            TuningSpec(kernel="k", backend="callable",
                       backend_kwargs={"fn": lambda c: 1.0}, budget=5)
        )


# ------------------------------------------------------------ matrix + shards


MATRIX_SPEC = TuningSpec(
    **SMOKE,
    algorithms=("rs", "ga", "bo_tpe"),
    design=ExperimentDesign(sample_sizes=(25,), n_experiments=(3,), final_repeats=3),
    seed=11,
    dataset_size=200,
)


def test_sharded_matrix_is_bit_identical_to_single_process(tmp_path):
    spec = MATRIX_SPEC.replace(
        store="json", store_path=str(tmp_path / "cache.json")
    )
    single = repro.tune_matrix(spec)
    sharded = repro.tune_matrix(spec, shards=2)
    assert set(single.cells) == set(sharded.cells)
    for key in single.cells:
        np.testing.assert_array_equal(
            single.cells[key].final_values, sharded.cells[key].final_values
        )
        np.testing.assert_array_equal(
            single.cells[key].search_best_values,
            sharded.cells[key].search_best_values,
        )
        np.testing.assert_array_equal(
            single.cells[key].n_samples_used, sharded.cells[key].n_samples_used
        )
    # shard stores were merged into the main store and cleaned up
    assert len(MeasurementStore(str(tmp_path / "cache.json"))) > 0
    assert not [f for f in os.listdir(tmp_path) if ".shard" in f]


def test_tune_matrix_out_dir_writes_npz_and_record(tmp_path):
    out = str(tmp_path / "out")
    results = repro.tune_matrix(
        MATRIX_SPEC.replace(cache_key="harris/v5e"), out_dir=out
    )
    assert os.path.exists(os.path.join(out, "harris_v5e.npz"))
    rec = RunRecord.load(os.path.join(out, "harris_v5e.json"))
    assert rec.kind == "tune_matrix"
    assert rec.result["best_observed"] == pytest.approx(results.optimum)
    assert rec.result["true_optimum"] <= rec.result["best_observed"]
    assert rec.result["dataset_best"] > 0
    assert {c["algo"] for c in rec.result["cells"]} == {"rs", "ga", "bo_tpe"}
    # the figure layer reads the record transparently
    import sys
    sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    from benchmarks.figures import load_all

    res = load_all(out)
    _, meta = res[("harris", "v5e")]
    assert meta["optimum"] == rec.result["true_optimum"]


def test_searcher_kwargs_apply_only_to_named_searcher():
    # GA kwargs must not crash SA cells sharing the matrix axis
    spec = TuningSpec(
        **SMOKE,
        searcher="ga",
        searcher_kwargs={"pop_size": 8},
        algorithms=("ga", "sa"),
        design=ExperimentDesign(sample_sizes=(25,), n_experiments=(2,), final_repeats=3),
    )
    results = repro.tune_matrix(spec)
    assert set(results.cells) == {("ga", 25), ("sa", 25)}


def test_sharded_record_keeps_dataset_best_without_cache_file(tmp_path):
    # no dataset_cache: the parent generates once, ships it to workers, and
    # the record still carries dataset_best
    out = str(tmp_path / "out")
    spec = MATRIX_SPEC.replace(dataset_cache=None)
    repro.tune_matrix(spec, shards=2, out_dir=out)
    rec = RunRecord.load(os.path.join(out, "harris_v5e.json"))
    assert rec.result["dataset_best"] > 0


def test_sharded_run_rejects_unserializable_backend():
    spec = TuningSpec(
        kernel="k",
        backend="timing",
        backend_kwargs={"runner": lambda cfg: None},
        space=paper_space(),
        algorithms=("rs", "ga"),
        design=ExperimentDesign(sample_sizes=(25,), n_experiments=(2,)),
    )
    with pytest.raises(RuntimeError, match="cannot be rebuilt in shard workers"):
        TuningSession(spec).run_matrix(shards=2)


def test_sharded_run_rejects_in_process_overrides():
    session = TuningSession(
        MATRIX_SPEC, measurement_factory=lambda s: make_measurement(
            "costmodel", kernel="harris", seed=s
        )
    )
    with pytest.raises(RuntimeError, match="serialized spec"):
        session.run_matrix(shards=2)


# ------------------------------------------------------------ overrides + shims


def test_matrix_runner_shim_is_gone():
    # the deprecated MatrixRunner facade was removed; in-process callers use
    # TuningSession keyword overrides instead
    with pytest.raises(ImportError):
        from repro.core import MatrixRunner  # noqa: F401


def test_session_overrides_match_facade():
    """A session built from live objects (space + measurement factory) is
    bit-identical to the spec-described facade run."""
    w, chip = WORKLOADS["harris"], CHIPS["v5e"]
    design = ExperimentDesign(sample_sizes=(25,), n_experiments=(2,), final_repeats=3)
    spec = TuningSpec(**SMOKE, algorithms=("rs", "ga"), design=design, seed=11)
    override = TuningSession(
        spec,
        space=executable_space(w, chip),
        measurement_factory=lambda s: CostModelMeasurement(w, chip, seed=s),
    ).run_matrix()
    facade = repro.tune_matrix(spec)
    assert set(override.cells) == set(facade.cells)
    for key in override.cells:
        np.testing.assert_array_equal(
            override.cells[key].final_values, facade.cells[key].final_values
        )


def test_searcher_run_shim_matches_session_loop():
    w, chip = WORKLOADS["harris"], CHIPS["v5e"]
    r_shim = make_searcher("rs", executable_space(w, chip), seed=5).run(
        CostModelMeasurement(w, chip, seed=5), 25
    )
    r_api = repro.tune(TuningSpec(**SMOKE, searcher="rs", budget=25, seed=5))
    assert r_shim.history_values == r_api.history_values


# ------------------------------------------------------------ result semantics


def test_trajectory_raises_clearly_on_empty_history():
    from repro.core import TuningResult

    with pytest.raises(ValueError, match="empty sample history"):
        TuningResult(algo="rs", best_config={}, best_value=np.inf).trajectory()
    r = TuningResult(algo="rs", best_config={}, best_value=1.0,
                     history_values=[3.0, 2.0, 2.5])
    np.testing.assert_array_equal(r.trajectory(), [3.0, 2.0, 2.0])


def test_finish_leaves_final_value_none_in_ask_tell_path():
    space = paper_space()
    s = make_searcher("rs", space, seed=0)
    s.start(5)
    cfgs = s.ask()
    s.tell(cfgs, np.ones(len(cfgs)))
    while not s.done:
        cfgs = s.ask()
        if not cfgs:
            break
        s.tell(cfgs, np.ones(len(cfgs)))
    r = s.finish()
    assert r.final_value is None
    assert r.n_samples == 5


# ------------------------------------------------------------ GA batch refill


def ga_batch_sizes(refill: bool, budget: int = 200):
    w, chip = WORKLOADS["harris"], CHIPS["v5e"]
    m = CostModelMeasurement(w, chip, seed=0)
    s = make_searcher("ga", paper_space(), seed=0, refill=refill)
    s.start(budget)
    sizes = []
    while not s.done:
        cfgs = s.ask()
        if not cfgs:
            break
        sizes.append(len(cfgs))
        s.tell(cfgs, m.measure_batch(cfgs))
    r = s.finish()
    assert r.n_samples == budget
    return sizes


def test_ga_refill_keeps_late_batches_full():
    base = ga_batch_sizes(refill=False)
    refilled = ga_batch_sizes(refill=True)
    # same budget in far fewer, fuller dispatch batches
    assert len(refilled) < len(base)
    # after init (pop 20) each generation proposes 10 fresh offspring; with
    # refill every non-trimmed batch stays full
    assert all(b == 10 for b in refilled[1:-1])
    assert min(base[1:-1]) < 10               # the shrinkage refill fixes
