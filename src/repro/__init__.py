"""repro — reproduction of "Analyzing Search Techniques for Autotuning
Image-based GPU Kernels: The Impact of Sample Sizes", grown toward a
production-scale jax/Pallas autotuning system.

The public front door is the declarative tuning facade::

    import repro
    from repro.core import ExperimentDesign, TuningSpec

    result = repro.tune(TuningSpec(kernel="harris", searcher="ga", budget=100))
    matrix = repro.tune_matrix(
        TuningSpec(kernel="harris", algorithms=("rs", "ga", "bo_tpe"),
                   design=ExperimentDesign.scaled(budget=500)),
        shards=2,
    )

See ``docs/public_api.md`` for the spec schema and the backend registry.
"""

__version__ = "0.9.0"

from .core.api import (
    RunRecord,
    TuningSession,
    TuningSpec,
    register_constraint,
    tune,
    tune_matrix,
)
from .core.backends import BACKENDS, Backend, make_measurement, register_backend
from .core.executors import EXECUTORS, Executor, register_executor
from .core.stores import STORES, make_store

__all__ = [
    "__version__",
    "BACKENDS",
    "Backend",
    "EXECUTORS",
    "Executor",
    "register_executor",
    "RunRecord",
    "STORES",
    "TuningSession",
    "TuningSpec",
    "make_measurement",
    "make_store",
    "register_backend",
    "register_constraint",
    "tune",
    "tune_matrix",
]
