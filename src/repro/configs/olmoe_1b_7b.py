"""OLMoE-1B-7B: 64-expert top-8 MoE [arXiv:2409.02060; hf]."""
from .base import ArchConfig, MoECfg

CONFIG = ArchConfig(
    name="olmoe-1b-7b", family="moe",
    n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=0, vocab=50304, head_dim=128,
    moe=MoECfg(n_experts=64, top_k=8, d_ff_expert=1024),
    source="arXiv:2409.02060",
)
