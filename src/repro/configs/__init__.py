"""Assigned-architecture registry: ``--arch <id>`` resolves here."""

from .base import SHAPES, ArchConfig, ShapeCfg, applicable_shapes
from .chameleon_34b import CONFIG as CHAMELEON_34B
from .deepseek_coder_33b import CONFIG as DEEPSEEK_CODER_33B
from .deepseek_v2_236b import CONFIG as DEEPSEEK_V2_236B
from .granite_34b import CONFIG as GRANITE_34B
from .mamba2_130m import CONFIG as MAMBA2_130M
from .olmoe_1b_7b import CONFIG as OLMOE_1B_7B
from .phi3_medium_14b import CONFIG as PHI3_MEDIUM_14B
from .whisper_medium import CONFIG as WHISPER_MEDIUM
from .yi_34b import CONFIG as YI_34B
from .zamba2_1_2b import CONFIG as ZAMBA2_1_2B

REGISTRY: dict[str, ArchConfig] = {
    c.name: c
    for c in (
        YI_34B,
        GRANITE_34B,
        PHI3_MEDIUM_14B,
        DEEPSEEK_CODER_33B,
        WHISPER_MEDIUM,
        ZAMBA2_1_2B,
        OLMOE_1B_7B,
        DEEPSEEK_V2_236B,
        MAMBA2_130M,
        CHAMELEON_34B,
    )
}


def get_arch(name: str) -> ArchConfig:
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(REGISTRY)}")
    return REGISTRY[name]


__all__ = ["REGISTRY", "get_arch", "ArchConfig", "ShapeCfg", "SHAPES", "applicable_shapes"]
