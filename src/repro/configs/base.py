"""Architecture configuration system.

One :class:`ArchConfig` describes every assigned architecture (``--arch
<id>`` resolves through :data:`repro.configs.REGISTRY`).  ``reduced()``
returns the family-preserving small config used by the CPU smoke tests;
the full config is exercised only through the dry-run (ShapeDtypeStruct,
no allocation).
"""

from __future__ import annotations

from dataclasses import dataclass, replace


@dataclass(frozen=True)
class MoECfg:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0
    capacity_factor: float = 1.25


@dataclass(frozen=True)
class MLACfg:
    q_lora: int = 1536
    kv_lora: int = 512
    d_nope: int = 128
    d_rope: int = 64
    d_v: int = 128


@dataclass(frozen=True)
class SSMCfg:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk: int = 256


@dataclass(frozen=True)
class EncDecCfg:
    n_enc_layers: int
    n_dec_layers: int
    max_src_len: int = 32768     # frame embeddings (frontend stub)
    dec_len: int = 448           # whisper decoder context


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense | moe | mla_moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    vocab: int
    n_heads: int = 0
    n_kv_heads: int = 0
    d_ff: int = 0
    head_dim: int = 128
    rope_theta: float = 10000.0
    norm: str = "rms"            # rms | ln
    act: str = "swiglu"          # swiglu | gelu
    tie_embeddings: bool = False
    moe: MoECfg | None = None
    mla: MLACfg | None = None
    ssm: SSMCfg | None = None
    encdec: EncDecCfg | None = None
    shared_attn_every: int = 0   # hybrid: shared attn block cadence
    frontend: str | None = None  # 'audio' | 'vq_image' — STUB per task spec
    source: str = ""             # public citation

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch run long_500k? (SSM / hybrid families only)."""
        return self.family in ("ssm", "hybrid")

    @property
    def has_decode(self) -> bool:
        return True  # all zoo members are (or contain) decoders

    def reduced(self) -> "ArchConfig":
        """Family-preserving smoke-test config."""
        kw: dict = dict(
            n_layers=min(self.n_layers, 2),
            d_model=256,
            vocab=512,
            d_ff=512 if self.d_ff else 0,
            head_dim=64,
            n_heads=4 if self.n_heads else 0,
        )
        if self.n_kv_heads:
            kw["n_kv_heads"] = min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4
        if self.moe:
            kw["moe"] = replace(
                self.moe, n_experts=min(self.moe.n_experts, 8),
                top_k=min(self.moe.top_k, 2), d_ff_expert=128,
            )
        if self.mla:
            kw["mla"] = MLACfg(q_lora=128, kv_lora=64, d_nope=32, d_rope=16, d_v=32)
            kw["head_dim"] = 32
        if self.ssm:
            kw["ssm"] = replace(self.ssm, d_state=16, head_dim=32, chunk=64)
        if self.encdec:
            kw["encdec"] = replace(
                self.encdec, n_enc_layers=2, n_dec_layers=2,
                max_src_len=128, dec_len=32,
            )
        return replace(self, **kw)


@dataclass(frozen=True)
class ShapeCfg:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # train | prefill | decode

    @property
    def is_train(self) -> bool:
        return self.kind == "train"


SHAPES: dict[str, ShapeCfg] = {
    "train_4k": ShapeCfg("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCfg("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCfg("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCfg("long_500k", 524288, 1, "decode"),
}


def applicable_shapes(cfg: ArchConfig) -> list[str]:
    """The dry-run cells for this arch (DESIGN.md section 4)."""
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.sub_quadratic:
        out.append("long_500k")
    return out
