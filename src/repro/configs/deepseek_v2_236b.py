"""DeepSeek-V2-236B: MLA (kv_lora=512) + 160-expert top-6 MoE with 2
shared experts [arXiv:2405.04434; hf].

Deviation noted in DESIGN.md: the released model keeps the first layer's
FFN dense; we use MoE in every layer (changes <0.5% of params)."""
from .base import ArchConfig, MLACfg, MoECfg

CONFIG = ArchConfig(
    name="deepseek-v2-236b", family="mla_moe",
    n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128,
    d_ff=0, vocab=102400, head_dim=128,
    mla=MLACfg(q_lora=1536, kv_lora=512, d_nope=128, d_rope=64, d_v=128),
    moe=MoECfg(n_experts=160, top_k=6, d_ff_expert=1536, n_shared=2),
    source="arXiv:2405.04434",
)
