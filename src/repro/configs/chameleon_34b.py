"""Chameleon-34B: early-fusion VLM backbone; VQ image tokens are ordinary
vocab entries, the VQ tokenizer frontend is STUBBED per the task spec
[arXiv:2405.09818; unverified]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="chameleon-34b", family="vlm",
    n_layers=48, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=22016, vocab=65536, head_dim=128,
    frontend="vq_image",
    source="arXiv:2405.09818",
)
