"""Whisper-medium: enc-dec audio backbone; conv frontend STUBBED —
input_specs provides precomputed frame embeddings [arXiv:2212.04356]."""
from .base import ArchConfig, EncDecCfg

CONFIG = ArchConfig(
    name="whisper-medium", family="encdec",
    n_layers=48,  # 24 enc + 24 dec
    d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab=51865, head_dim=64,
    norm="ln", act="gelu", tie_embeddings=True,
    encdec=EncDecCfg(n_enc_layers=24, n_dec_layers=24, max_src_len=32768, dec_len=448),
    frontend="audio",
    source="arXiv:2212.04356",
)
