"""Zamba2-1.2B: Mamba2 backbone + weight-shared attention blocks
[arXiv:2411.15242; hf].  38 mamba layers, shared GQA block every 6."""
from .base import ArchConfig, SSMCfg

CONFIG = ArchConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab=32000, head_dim=64,
    ssm=SSMCfg(d_state=64, d_conv=4, expand=2, head_dim=64, n_groups=1, chunk=256),
    shared_attn_every=6,
    source="arXiv:2411.15242",
)
