"""Mamba2-130M: pure SSM (SSD) [arXiv:2405.21060; unverified].
Attention-free: flash-attention tuning inapplicable — SSD chunk size is
the tuned kernel dimension instead (DESIGN.md section 4)."""
from .base import ArchConfig, SSMCfg

CONFIG = ArchConfig(
    name="mamba2-130m", family="ssm",
    n_layers=24, d_model=768, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab=50280,
    ssm=SSMCfg(d_state=128, d_conv=4, expand=2, head_dim=64, n_groups=1, chunk=256),
    source="arXiv:2405.21060",
)
