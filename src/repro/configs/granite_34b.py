"""Granite-34B-code: 88-layer MQA (kv=1) dense [arXiv:2405.04324; hf]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="granite-34b", family="dense",
    n_layers=88, d_model=6144, n_heads=48, n_kv_heads=1,
    d_ff=24576, vocab=49152, head_dim=128,
    source="arXiv:2405.04324",
)
