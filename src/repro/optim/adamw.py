"""AdamW with global-norm clipping, pure JAX (no optax dependency).

State (m, v) mirrors the parameter tree in fp32 and inherits the parameter
shardings (ZeRO-3: FSDP-sharded params => FSDP-sharded optimizer state —
see repro.sharding.rules).
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100


def init_state(params) -> dict:
    zeros = lambda t: jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), t
    )
    return {"m": zeros(params), "v": zeros(params), "step": jnp.zeros((), jnp.int32)}


def schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    warm = jnp.minimum(step.astype(jnp.float32) / max(cfg.warmup_steps, 1), 1.0)
    return cfg.lr * warm


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves))


def apply_updates(params, grads, state, cfg: AdamWConfig):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = schedule(cfg, step)

    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m_new = b1 * m + (1 - b1) * g
        v_new = b2 * v + (1 - b2) * g * g
        m_hat = m_new / bc1
        v_hat = v_new / bc2
        delta = m_hat / (jnp.sqrt(v_hat) + cfg.eps) + cfg.weight_decay * p.astype(
            jnp.float32
        )
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m_new, v_new

    flat_p, td = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(state["m"])
    flat_v = jax.tree_util.tree_leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v, strict=True)]
    new_p = jax.tree_util.tree_unflatten(td, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(td, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(td, [o[2] for o in out])
    return (
        new_p,
        {"m": new_m, "v": new_v, "step": step},
        {"grad_norm": gnorm, "lr": lr},
    )
