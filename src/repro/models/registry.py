"""Model registry: ArchConfig -> model instance."""

from __future__ import annotations

from ..configs.base import ArchConfig
from .decoder import DecoderLM
from .encdec import EncDecLM
from .hybrid import HybridLM
from .ssm import SSMLM

FAMILIES = {
    "dense": DecoderLM,
    "moe": DecoderLM,
    "mla_moe": DecoderLM,
    "vlm": DecoderLM,
    "ssm": SSMLM,
    "hybrid": HybridLM,
    "encdec": EncDecLM,
}


def build_model(cfg: ArchConfig, moe_groups: int = 1):
    if cfg.family not in FAMILIES:
        raise KeyError(f"unknown family {cfg.family}")
    return FAMILIES[cfg.family](cfg, moe_groups=moe_groups)
