from .decoder import DecoderLM
from .encdec import EncDecLM
from .hybrid import HybridLM
from .param import P, abstract_params, init_params, param_axes, param_count
from .registry import build_model
from .ssm import SSMLM

__all__ = [
    "P",
    "abstract_params",
    "init_params",
    "param_axes",
    "param_count",
    "build_model",
    "DecoderLM",
    "EncDecLM",
    "HybridLM",
    "SSMLM",
]
