"""Pure-SSM LM (mamba2-130m): embedding -> L x Mamba2/SSD blocks -> head.

Attention-free: the paper's flash-attention-style tuning is inapplicable;
the SSD chunk size takes its place as the tuned kernel dimension
(DESIGN.md section 4).  Sub-quadratic -> runs long_500k.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..sharding.constrain import (
    constrain_residual,
    gather_layer_weights,
    strip_layer_axis,
)
from .decoder import _maybe_remat
from .layers import COMPUTE_DTYPE, embed, lm_logits, rms_norm
from .mamba2 import SSMDims, mamba2_decode, mamba2_forward
from .param import P, param_axes


def ssm_dims(cfg: ArchConfig) -> SSMDims:
    s = cfg.ssm
    return SSMDims(
        d_model=cfg.d_model,
        d_state=s.d_state,
        d_conv=s.d_conv,
        expand=s.expand,
        head_dim=s.head_dim,
        n_groups=s.n_groups,
        chunk=s.chunk,
    )


def mamba_layer_spec(L: int, dims: SSMDims) -> dict:
    return {
        "pre_norm": P((L, dims.d_model), ("layers", "embed"), init="ones"),
        "in_proj": P((L, dims.d_model, dims.in_proj_dim),
                     ("layers", "embed", "ssm_inner"), init="scaled"),
        "conv_w": P((L, dims.d_conv, dims.conv_dim),
                    ("layers", None, "ssm_inner"), init="scaled"),
        "dt_bias": P((L, dims.n_heads), ("layers", "heads"), init="zeros"),
        "a_log": P((L, dims.n_heads), ("layers", "heads"), init="zeros"),
        "d_skip": P((L, dims.n_heads), ("layers", "heads"), init="ones"),
        "norm": P((L, dims.d_inner), ("layers", "ssm_inner"), init="ones"),
        "out_proj": P((L, dims.d_inner, dims.d_model),
                      ("layers", "ssm_inner", "embed"), init="scaled"),
    }


class SSMLM:
    def __init__(self, cfg: ArchConfig, moe_groups: int = 1):
        self.cfg = cfg
        self.dims = ssm_dims(cfg)

    def spec(self) -> dict:
        c = self.cfg
        return {
            "embed": P((c.vocab, c.d_model), ("vocab", "embed")),
            "layers": mamba_layer_spec(c.n_layers, self.dims),
            "final_norm": P((c.d_model,), ("embed",), init="ones"),
            "lm_head": P((c.d_model, c.vocab), ("embed", "vocab")),
        }

    def forward(self, params, tokens, remat: str = "none"):
        x = embed(tokens, params["embed"])
        layer_axes = strip_layer_axis(param_axes(self.spec()["layers"]))

        def block(x, lp):
            lp = gather_layer_weights(lp, layer_axes)
            h = rms_norm(x, lp["pre_norm"])
            return constrain_residual(x + mamba2_forward(h, lp, self.dims)), jnp.float32(0.0)

        block = _maybe_remat(block, remat)
        x, _ = jax.lax.scan(block, x, params["layers"])
        x = rms_norm(x, params["final_norm"])
        return lm_logits(x, params["lm_head"]), jnp.float32(0.0)

    def cache_axes(self) -> dict:
        return {
            "conv": ("layers", "batch", None, "ssm_inner"),
            "ssm": ("layers", "batch", "heads", None, None),
        }

    def init_cache(self, batch: int, max_len: int):
        d = self.dims
        L = self.cfg.n_layers
        return {
            "conv": jnp.zeros((L, batch, d.d_conv - 1, d.conv_dim), COMPUTE_DTYPE),
            "ssm": jnp.zeros((L, batch, d.n_heads, d.head_dim, d.d_state), jnp.float32),
        }

    def decode_step(self, params, cache, cache_len, tokens):
        x = embed(tokens, params["embed"])

        def block(x, scan_in):
            lp, cache_l = scan_in
            h = rms_norm(x, lp["pre_norm"])
            out, new_cache = mamba2_decode(h, lp, self.dims, cache_l)
            return x + out, new_cache

        x, new_cache = jax.lax.scan(block, x, (params["layers"], cache))
        x = rms_norm(x, params["final_norm"])
        return lm_logits(x, params["lm_head"]), new_cache
