"""Decoder-only LM covering the dense / moe / mla_moe / vlm families.

Scan-over-layers: per-layer parameters are stacked on a leading "layers"
dim and the block is applied with lax.scan, keeping HLO size and compile
time O(1) in depth (88-layer granite compiles as fast as 16-layer olmoe).
Remat policy is applied to the scanned block body.

Early-fusion VLM (chameleon) is this same class: its VQ image tokens are
ordinary vocabulary entries (the tokenizer frontend is a stub per the task
spec).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..sharding.constrain import (
    constrain_residual,
    gather_layer_weights,
    strip_layer_axis,
)
from .layers import (
    COMPUTE_DTYPE,
    apply_rope,
    attention,
    embed,
    lm_logits,
    rms_norm,
    swiglu,
)
from .mla import MLADims, mla_decode, mla_prefill
from .moe import MoEDims, moe_forward
from .param import P, param_axes

REMAT_POLICIES = {
    "none": None,
    "dots": jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims,
    "full": jax.checkpoint_policies.nothing_saveable,
}


def _maybe_remat(fn, remat: str):
    if remat == "none":
        return fn
    return jax.checkpoint(fn, policy=REMAT_POLICIES[remat], prevent_cse=True)


class DecoderLM:
    def __init__(self, cfg: ArchConfig, moe_groups: int = 1):
        self.cfg = cfg
        self.moe_groups = moe_groups

    # ------------------------------------------------------------- spec
    def spec(self) -> dict:
        c = self.cfg
        L, D, V = c.n_layers, c.d_model, c.vocab
        hd = c.head_dim
        layers: dict = {
            "attn_norm": P((L, D), ("layers", "embed"), init="ones"),
            "mlp_norm": P((L, D), ("layers", "embed"), init="ones"),
        }
        if c.mla:
            m = c.mla
            H = c.n_heads
            layers.update(
                w_dq=P((L, D, m.q_lora), ("layers", "embed", "q_lora"), init="scaled"),
                q_norm=P((L, m.q_lora), ("layers", "q_lora"), init="ones"),
                w_uq=P((L, m.q_lora, H, m.d_nope + m.d_rope),
                       ("layers", "q_lora", "heads", "head_dim"), init="scaled"),
                w_dkv=P((L, D, m.kv_lora), ("layers", "embed", "kv_lora"), init="scaled"),
                kv_norm=P((L, m.kv_lora), ("layers", "kv_lora"), init="ones"),
                w_uk=P((L, m.kv_lora, H, m.d_nope),
                       ("layers", "kv_lora", "heads", "head_dim"), init="scaled"),
                w_uv=P((L, m.kv_lora, H, m.d_v),
                       ("layers", "kv_lora", "heads", "head_dim"), init="scaled"),
                w_kr=P((L, D, m.d_rope), ("layers", "embed", "rope_dim"), init="scaled"),
                w_o=P((L, H, m.d_v, D), ("layers", "heads", "head_dim", "embed"),
                      init="scaled"),
            )
        else:
            H, Hkv = c.n_heads, c.n_kv_heads
            layers.update(
                wq=P((L, D, H, hd), ("layers", "embed", "heads", "head_dim"),
                     init="scaled"),
                wk=P((L, D, Hkv, hd), ("layers", "embed", "kv_heads", "head_dim"),
                     init="scaled"),
                wv=P((L, D, Hkv, hd), ("layers", "embed", "kv_heads", "head_dim"),
                     init="scaled"),
                wo=P((L, H, hd, D), ("layers", "heads", "head_dim", "embed"),
                     init="scaled"),
            )
        if c.moe:
            e, f = c.moe.n_experts, c.moe.d_ff_expert
            layers.update(
                router=P((L, D, e), ("layers", "embed", "experts"), init="scaled"),
                gate=P((L, e, D, f), ("layers", "experts", "embed", "ffn"),
                       init="scaled"),
                up=P((L, e, D, f), ("layers", "experts", "embed", "ffn"),
                     init="scaled"),
                down=P((L, e, f, D), ("layers", "experts", "ffn", "embed"),
                       init="scaled"),
            )
            if c.moe.n_shared:
                sf = c.moe.n_shared * f
                layers.update(
                    shared_gate=P((L, D, sf), ("layers", "embed", "ffn"), init="scaled"),
                    shared_up=P((L, D, sf), ("layers", "embed", "ffn"), init="scaled"),
                    shared_down=P((L, sf, D), ("layers", "ffn", "embed"), init="scaled"),
                )
        else:
            F = c.d_ff
            layers.update(
                w_gate=P((L, D, F), ("layers", "embed", "ffn"), init="scaled"),
                w_up=P((L, D, F), ("layers", "embed", "ffn"), init="scaled"),
                w_down=P((L, F, D), ("layers", "ffn", "embed"), init="scaled"),
            )
        spec = {
            "embed": P((V, D), ("vocab", "embed")),
            "layers": layers,
            "final_norm": P((D,), ("embed",), init="ones"),
        }
        if not c.tie_embeddings:
            spec["lm_head"] = P((D, V), ("embed", "vocab"))
        return spec

    # ------------------------------------------------------------- blocks
    def _attn_block(self, lp: dict, x, positions):
        c = self.cfg
        if c.mla:
            out, _ = mla_prefill(
                rms_norm(x, lp["attn_norm"]),
                lp,
                MLADims(n_heads=c.n_heads, **_mla_kw(c)),
                positions,
                c.rope_theta,
            )
            return out
        h = rms_norm(x, lp["attn_norm"])
        q = jnp.einsum("bsd,dhe->bshe", h, lp["wq"].astype(h.dtype))
        k = jnp.einsum("bsd,dhe->bshe", h, lp["wk"].astype(h.dtype))
        v = jnp.einsum("bsd,dhe->bshe", h, lp["wv"].astype(h.dtype))
        q = apply_rope(q, positions, c.rope_theta)
        k = apply_rope(k, positions, c.rope_theta)
        o = attention(q, k, v, causal=True)
        return jnp.einsum("bshe,hed->bsd", o, lp["wo"].astype(h.dtype))

    def _mlp_block(self, lp: dict, x):
        c = self.cfg
        h = rms_norm(x, lp["mlp_norm"])
        if c.moe:
            dims = MoEDims(
                n_experts=c.moe.n_experts,
                top_k=c.moe.top_k,
                d_model=c.d_model,
                d_ff=c.moe.d_ff_expert,
                n_shared=c.moe.n_shared,
                capacity_factor=c.moe.capacity_factor,
                groups=self.moe_groups,
            )
            out, aux = moe_forward(h, lp, dims)
            return out, aux
        return swiglu(h, lp["w_gate"], lp["w_up"], lp["w_down"]), jnp.float32(0.0)

    # ------------------------------------------------------------- forward
    def forward(
        self, params: dict, tokens: jnp.ndarray, remat: str = "none"
    ) -> tuple[jnp.ndarray, jnp.ndarray]:
        """tokens (B, S) -> (logits (B, S, V), aux_loss)."""
        b, s = tokens.shape
        x = embed(tokens, params["embed"])
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

        layer_axes = strip_layer_axis(param_axes(self.spec()["layers"]))

        def block(x, lp):
            lp = gather_layer_weights(lp, layer_axes)
            x = x + self._attn_block(lp, x, positions)
            mlp_out, aux = self._mlp_block(lp, x)
            return constrain_residual(x + mlp_out), aux

        block = _maybe_remat(block, remat)
        x, auxs = jax.lax.scan(block, x, params["layers"])
        x = rms_norm(x, params["final_norm"])
        head = (
            params["embed"].T if self.cfg.tie_embeddings else params["lm_head"]
        )
        return lm_logits(x, head), auxs.mean()

    # ------------------------------------------------------------- decode
    def cache_axes(self) -> dict:
        if self.cfg.mla:
            return {
                "c_kv": ("layers", "batch", "kv_seq", "kv_lora_cache"),
                "k_rope": ("layers", "batch", "kv_seq", "rope_cache"),
            }
        return {
            "k": ("layers", "batch", "kv_seq", "kv_heads", "kv_head_dim"),
            "v": ("layers", "batch", "kv_seq", "kv_heads", "kv_head_dim"),
        }

    def init_cache(self, batch: int, max_len: int):
        c = self.cfg
        L = c.n_layers
        if c.mla:
            m = c.mla
            return {
                "c_kv": jnp.zeros((L, batch, max_len, m.kv_lora), COMPUTE_DTYPE),
                "k_rope": jnp.zeros((L, batch, max_len, m.d_rope), COMPUTE_DTYPE),
            }
        return {
            "k": jnp.zeros((L, batch, max_len, c.n_kv_heads, c.head_dim), COMPUTE_DTYPE),
            "v": jnp.zeros((L, batch, max_len, c.n_kv_heads, c.head_dim), COMPUTE_DTYPE),
        }

    def decode_step(
        self,
        params: dict,
        cache: dict,
        cache_len: jnp.ndarray,     # (B,)
        tokens: jnp.ndarray,        # (B, 1)
    ):
        """One decode step; returns (logits (B, 1, V), new_cache)."""
        c = self.cfg
        x = embed(tokens, params["embed"])
        positions = cache_len[:, None]

        if c.mla:
            dims = MLADims(n_heads=c.n_heads, **_mla_kw(c))

            def block(x, scan_in):
                lp, cache_l = scan_in
                attn_in = rms_norm(x, lp["attn_norm"])
                out, new_cache = mla_decode(
                    attn_in, lp, dims, cache_l, cache_len, c.rope_theta
                )
                x = x + out
                mlp_out, _ = self._mlp_block(lp, x)
                return x + mlp_out, new_cache

        else:

            def block(x, scan_in):
                lp, cache_l = scan_in
                h = rms_norm(x, lp["attn_norm"])
                q = jnp.einsum("bsd,dhe->bshe", h, lp["wq"].astype(h.dtype))
                k = jnp.einsum("bsd,dhe->bshe", h, lp["wk"].astype(h.dtype))
                v = jnp.einsum("bsd,dhe->bshe", h, lp["wv"].astype(h.dtype))
                q = apply_rope(q, positions, c.rope_theta)
                k = apply_rope(k, positions, c.rope_theta)
                s_max = cache_l["k"].shape[1]
                oh = jax.nn.one_hot(cache_len, s_max, dtype=k.dtype)    # (B, S)
                k_all = cache_l["k"] + oh[:, :, None, None] * k
                v_all = cache_l["v"] + oh[:, :, None, None] * v
                # single-token decode: the kv_len mask IS the causal mask
                o = attention(q, k_all, v_all, causal=False, kv_len=cache_len + 1)
                x = x + jnp.einsum("bshe,hed->bsd", o, lp["wo"].astype(h.dtype))
                mlp_out, _ = self._mlp_block(lp, x)
                return x + mlp_out, {"k": k_all, "v": v_all}

        x, new_cache = jax.lax.scan(block, x, (params["layers"], cache))
        x = rms_norm(x, params["final_norm"])
        head = params["embed"].T if c.tie_embeddings else params["lm_head"]
        return lm_logits(x, head), new_cache


def _mla_kw(c: ArchConfig) -> dict:
    m = c.mla
    return dict(
        q_lora=m.q_lora, kv_lora=m.kv_lora, d_nope=m.d_nope,
        d_rope=m.d_rope, d_v=m.d_v,
    )
