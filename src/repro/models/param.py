"""Minimal parameter-spec system (no flax dependency).

A model is defined by a *spec tree*: nested dicts whose leaves are
:class:`P` — (shape, dtype, logical_axes, init).  From one spec we derive:

  * ``init_params(spec, rng)``     — materialized arrays (smoke tests, training)
  * ``abstract_params(spec)``      — ShapeDtypeStructs (dry-run, no allocation)
  * ``param_axes(spec)``           — logical-axis name tree for the sharding
                                     rules in repro.sharding.rules

Logical axis names used across the zoo:
    "layers"   — stacked per-layer leading dim (scan-over-layers)
    "vocab"    — vocabulary dim
    "embed"    — d_model
    "heads"    — attention heads (query)
    "kv_heads" — KV heads
    "head_dim" — per-head dim
    "ffn"      — MLP hidden dim
    "experts"  — MoE expert dim
    "ssm_inner" / "ssm_state" / "conv" — Mamba2 dims
    "q_lora" / "kv_lora" / "rope_dim"  — MLA dims
    None       — replicated dim
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class P:
    shape: tuple
    axes: tuple               # logical axis name (or None) per dim
    dtype: jnp.dtype = jnp.float32
    init: str = "normal"      # normal | zeros | ones | scaled (fan-in)

    def __post_init__(self):
        if len(self.shape) != len(self.axes):
            raise ValueError(f"shape {self.shape} vs axes {self.axes} rank mismatch")


def is_leaf(x) -> bool:
    return isinstance(x, P)


def _initializer(p: P, key: jax.Array) -> jax.Array:
    if p.init == "zeros":
        return jnp.zeros(p.shape, p.dtype)
    if p.init == "ones":
        return jnp.ones(p.shape, p.dtype)
    if p.init == "normal":
        return (0.02 * jax.random.normal(key, p.shape)).astype(p.dtype)
    if p.init == "scaled":  # fan-in scaled
        fan_in = p.shape[-2] if len(p.shape) >= 2 else p.shape[-1]
        return (jax.random.normal(key, p.shape) / np.sqrt(fan_in)).astype(p.dtype)
    raise ValueError(f"unknown init {p.init}")


def init_params(spec, rng: jax.Array):
    leaves, treedef = jax.tree_util.tree_flatten(spec, is_leaf=is_leaf)
    keys = jax.random.split(rng, len(leaves))
    vals = [_initializer(p, k) for p, k in zip(leaves, keys, strict=True)]
    return jax.tree_util.tree_unflatten(treedef, vals)


def abstract_params(spec):
    return jax.tree_util.tree_map(
        lambda p: jax.ShapeDtypeStruct(p.shape, p.dtype), spec, is_leaf=is_leaf
    )


def param_axes(spec):
    return jax.tree_util.tree_map(lambda p: p.axes, spec, is_leaf=is_leaf)


def param_count(spec) -> int:
    leaves = jax.tree_util.tree_leaves(spec, is_leaf=is_leaf)
    return int(sum(np.prod(p.shape) for p in leaves))
