"""Hybrid Mamba2 + shared-attention LM (zamba2-1.2b, arXiv:2411.15242).

Layer pattern: runs of ``shared_attn_every`` Mamba2 blocks, punctuated by a
single *weight-shared* GQA attention block (Zamba's signature trick: one
transformer block's weights reused at every insertion point; each insertion
keeps its own KV cache).  38 = 6 x 6 + 2 for zamba2-1.2b: six
(6-mamba + shared-attn) groups, then a 2-mamba tail.

Simplification vs the released checkpoints (noted in DESIGN.md): Zamba2
concatenates the original embedding into the shared block input and adds
per-invocation LoRA deltas; we apply the shared block on the hidden state
directly.  Structure (weight sharing + cadence + dual cache types) is
preserved — that is what the sharding/roofline care about.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..sharding.constrain import (
    constrain_residual,
    gather_layer_weights,
    strip_layer_axis,
)
from .decoder import _maybe_remat
from .layers import (
    COMPUTE_DTYPE,
    apply_rope,
    attention,
    embed,
    lm_logits,
    rms_norm,
    swiglu,
)
from .mamba2 import mamba2_decode, mamba2_forward
from .param import P, param_axes
from .ssm import mamba_layer_spec, ssm_dims


class HybridLM:
    def __init__(self, cfg: ArchConfig, moe_groups: int = 1):
        if cfg.shared_attn_every <= 0:
            raise ValueError("shared_attn_every must be > 0")
        self.cfg = cfg
        self.dims = ssm_dims(cfg)
        self.n_groups = cfg.n_layers // cfg.shared_attn_every
        self.tail = cfg.n_layers - self.n_groups * cfg.shared_attn_every

    # ------------------------------------------------------------- spec
    def spec(self) -> dict:
        c = self.cfg
        hd = c.head_dim
        shared = {
            "attn_norm": P((c.d_model,), ("embed",), init="ones"),
            "wq": P((c.d_model, c.n_heads, hd), ("embed", "heads", "head_dim"),
                    init="scaled"),
            "wk": P((c.d_model, c.n_kv_heads, hd), ("embed", "kv_heads", "head_dim"),
                    init="scaled"),
            "wv": P((c.d_model, c.n_kv_heads, hd), ("embed", "kv_heads", "head_dim"),
                    init="scaled"),
            "wo": P((c.n_heads, hd, c.d_model), ("heads", "head_dim", "embed"),
                    init="scaled"),
            "mlp_norm": P((c.d_model,), ("embed",), init="ones"),
            "w_gate": P((c.d_model, c.d_ff), ("embed", "ffn"), init="scaled"),
            "w_up": P((c.d_model, c.d_ff), ("embed", "ffn"), init="scaled"),
            "w_down": P((c.d_ff, c.d_model), ("ffn", "embed"), init="scaled"),
        }
        spec = {
            "embed": P((c.vocab, c.d_model), ("vocab", "embed")),
            "mamba": mamba_layer_spec(c.n_layers, self.dims),
            "shared_attn": shared,
            "final_norm": P((c.d_model,), ("embed",), init="ones"),
            "lm_head": P((c.d_model, c.vocab), ("embed", "vocab")),
        }
        return spec

    # ------------------------------------------------------------- helpers
    def _split_mamba(self, mamba_params):
        """Stacked (L, ...) -> grouped (G, every, ...) + tail (T, ...)."""
        every, g = self.cfg.shared_attn_every, self.n_groups
        grouped = jax.tree_util.tree_map(
            lambda a: a[: g * every].reshape((g, every) + a.shape[1:]), mamba_params
        )
        tail = jax.tree_util.tree_map(lambda a: a[g * every :], mamba_params)
        return grouped, tail

    def _shared_attn_block(self, sp, x, positions, cache=None, cache_len=None):
        c = self.cfg
        h = rms_norm(x, sp["attn_norm"])
        q = jnp.einsum("bsd,dhe->bshe", h, sp["wq"].astype(h.dtype))
        k = jnp.einsum("bsd,dhe->bshe", h, sp["wk"].astype(h.dtype))
        v = jnp.einsum("bsd,dhe->bshe", h, sp["wv"].astype(h.dtype))
        q = apply_rope(q, positions, c.rope_theta)
        k = apply_rope(k, positions, c.rope_theta)
        if cache is None:
            o = attention(q, k, v, causal=True)
            new_cache = None
        else:
            s_max = cache["k"].shape[1]
            oh = jax.nn.one_hot(cache_len, s_max, dtype=k.dtype)
            k_all = cache["k"] + oh[:, :, None, None] * k
            v_all = cache["v"] + oh[:, :, None, None] * v
            o = attention(q, k_all, v_all, causal=False, kv_len=cache_len + 1)
            new_cache = {"k": k_all, "v": v_all}
        x = x + jnp.einsum("bshe,hed->bsd", o, sp["wo"].astype(h.dtype))
        m = rms_norm(x, sp["mlp_norm"])
        x = x + swiglu(m, sp["w_gate"], sp["w_up"], sp["w_down"])
        return x, new_cache

    # ------------------------------------------------------------- forward
    def forward(self, params, tokens, remat: str = "none"):
        b, s = tokens.shape
        x = embed(tokens, params["embed"])
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        grouped, tail = self._split_mamba(params["mamba"])
        sp = params["shared_attn"]

        layer_axes = strip_layer_axis(param_axes(self.spec()["mamba"]))

        def mamba_block(x, lp):
            lp = gather_layer_weights(lp, layer_axes)
            h = rms_norm(x, lp["pre_norm"])
            return constrain_residual(x + mamba2_forward(h, lp, self.dims)), ()

        mamba_block = _maybe_remat(mamba_block, remat)

        def group(x, gp):
            x, _ = jax.lax.scan(mamba_block, x, gp)
            x, _ = self._shared_attn_block(sp, x, positions)
            return x, ()

        x, _ = jax.lax.scan(group, x, grouped)
        if self.tail:
            x, _ = jax.lax.scan(mamba_block, x, tail)
        x = rms_norm(x, params["final_norm"])
        return lm_logits(x, params["lm_head"]), jnp.float32(0.0)

    # ------------------------------------------------------------- decode
    def cache_axes(self) -> dict:
        return {
            "conv": ("layers", "batch", None, "ssm_inner"),
            "ssm": ("layers", "batch", "heads", None, None),
            "attn_k": (None, "batch", "kv_seq", "kv_heads", "kv_head_dim"),
            "attn_v": (None, "batch", "kv_seq", "kv_heads", "kv_head_dim"),
        }

    def init_cache(self, batch: int, max_len: int):
        d = self.dims
        c = self.cfg
        L, G = c.n_layers, self.n_groups
        return {
            "conv": jnp.zeros((L, batch, d.d_conv - 1, d.conv_dim), COMPUTE_DTYPE),
            "ssm": jnp.zeros((L, batch, d.n_heads, d.head_dim, d.d_state), jnp.float32),
            "attn_k": jnp.zeros((G, batch, max_len, c.n_kv_heads, c.head_dim),
                                COMPUTE_DTYPE),
            "attn_v": jnp.zeros((G, batch, max_len, c.n_kv_heads, c.head_dim),
                                COMPUTE_DTYPE),
        }

    def decode_step(self, params, cache, cache_len, tokens):
        c = self.cfg
        x = embed(tokens, params["embed"])
        positions = cache_len[:, None]
        sp = params["shared_attn"]
        every, g = c.shared_attn_every, self.n_groups

        mamba_cache = {"conv": cache["conv"], "ssm": cache["ssm"]}
        grouped, tail_p = self._split_mamba(params["mamba"])
        grouped_cache = jax.tree_util.tree_map(
            lambda a: a[: g * every].reshape((g, every) + a.shape[1:]), mamba_cache
        )
        tail_cache = jax.tree_util.tree_map(lambda a: a[g * every :], mamba_cache)

        def mamba_block(x, scan_in):
            lp, cache_l = scan_in
            h = rms_norm(x, lp["pre_norm"])
            out, new_cache = mamba2_decode(h, lp, self.dims, cache_l)
            return x + out, new_cache

        def group(x, scan_in):
            gp, gcache, acache = scan_in
            x, new_mcache = jax.lax.scan(mamba_block, x, (gp, gcache))
            x, new_acache = self._shared_attn_block(
                sp, x, positions, cache=acache, cache_len=cache_len
            )
            return x, (new_mcache, new_acache)

        attn_cache = {"k": cache["attn_k"], "v": cache["attn_v"]}
        x, (new_grouped, new_attn) = jax.lax.scan(
            group, x, (grouped, grouped_cache, attn_cache)
        )
        if self.tail:
            x, new_tail = jax.lax.scan(mamba_block, x, (tail_p, tail_cache))
        else:
            new_tail = tail_cache
        x = rms_norm(x, params["final_norm"])
        logits = lm_logits(x, params["lm_head"])

        def unsplit(gr, tl):
            flat = gr.reshape((g * every,) + gr.shape[2:])
            return jnp.concatenate([flat, tl], axis=0)

        new_cache = {
            "conv": unsplit(new_grouped["conv"], new_tail["conv"]),
            "ssm": unsplit(new_grouped["ssm"], new_tail["ssm"]),
            "attn_k": new_attn["k"],
            "attn_v": new_attn["v"],
        }
        return logits, new_cache
