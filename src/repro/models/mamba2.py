"""Mamba-2 / SSD (state-space duality) block (arXiv:2405.21060).

Training/prefill path: the chunked SSD algorithm — intra-chunk quadratic
('attention-like') term + inter-chunk recurrent state propagation via
lax.scan.  HLO size is O(1) in sequence length; memory is
O(S * Q + S/Q * H * P * N) instead of O(S^2).

Decode path: single-token recurrence on the (H, P, N) state with a rolling
depthwise-conv tail — the serve_step cache.

Shapes follow the Mamba-2 reference: d_inner = expand * d_model,
H = d_inner / head_dim heads, B/C shared across heads in n_groups groups.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .layers import rms_norm


@dataclass(frozen=True)
class SSMDims:
    d_model: int
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk: int = 256

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_heads(self) -> int:
        return self.d_inner // self.head_dim

    @property
    def conv_dim(self) -> int:
        return self.d_inner + 2 * self.n_groups * self.d_state

    @property
    def in_proj_dim(self) -> int:
        return 2 * self.d_inner + 2 * self.n_groups * self.d_state + self.n_heads


def _segsum(x: jnp.ndarray) -> jnp.ndarray:
    """x: (..., Q) -> (..., Q, Q);  out[i, j] = sum_{k in (j, i]} x[k] for
    i >= j, -inf above the diagonal."""
    q = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.arange(q)[:, None] >= jnp.arange(q)[None, :]
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(
    x: jnp.ndarray,       # (B, S, H, P)
    dt: jnp.ndarray,      # (B, S, H)   post-softplus
    a_log: jnp.ndarray,   # (H,)        A = -exp(a_log)
    b: jnp.ndarray,       # (B, S, G, N)
    c: jnp.ndarray,       # (B, S, G, N)
    chunk: int,
) -> jnp.ndarray:
    bsz, s, h, p = x.shape
    g, n = b.shape[2], b.shape[3]
    if s % chunk != 0:
        raise ValueError(f"seq len {s} not divisible by chunk {chunk}")
    nc = s // chunk
    rep = h // g

    A = -jnp.exp(a_log.astype(jnp.float32))                       # (H,)
    xc = x.reshape(bsz, nc, chunk, h, p)
    dtc = dt.reshape(bsz, nc, chunk, h).astype(jnp.float32)
    bc = jnp.repeat(b.reshape(bsz, nc, chunk, g, n), rep, axis=3)  # (B,nc,Q,H,N)
    cc = jnp.repeat(c.reshape(bsz, nc, chunk, g, n), rep, axis=3)

    dA = dtc * A[None, None, None, :]                              # (B,nc,Q,H)
    dA_cs = jnp.cumsum(dA, axis=2)                                 # (B,nc,Q,H)

    # --- intra-chunk (diagonal) term (fp32: the decode path computes the
    # same per-token contributions through the fp32 state recurrence, and the
    # two must agree for prefill/decode equivalence)
    L = jnp.exp(_segsum(dA.transpose(0, 1, 3, 2)))                 # (B,nc,H,Q,Q)
    scores = jnp.einsum(
        "bcqhn,bckhn->bchqk", cc.astype(jnp.float32), bc.astype(jnp.float32)
    )                                                              # (B,nc,H,Q,Q)
    att = scores * L * dtc.transpose(0, 1, 3, 2)[:, :, :, None, :]
    y_diag = jnp.einsum("bchqk,bckhp->bcqhp", att, xc.astype(jnp.float32))

    # --- per-chunk end states
    decay_states = jnp.exp(dA_cs[:, :, -1:, :] - dA_cs)           # (B,nc,Q,H)
    states = jnp.einsum(
        "bcqhn,bcqh,bcqhp->bchpn",
        bc.astype(jnp.float32),
        (decay_states * dtc),
        xc.astype(jnp.float32),
    )                                                              # (B,nc,H,P,N)

    # --- inter-chunk recurrence (sequential scan over chunks)
    chunk_decay = jnp.exp(dA_cs[:, :, -1, :])                      # (B,nc,H)

    def step(h_prev, inp):
        st, dec = inp                                              # (B,H,P,N), (B,H)
        h_new = h_prev * dec[..., None, None] + st
        return h_new, h_prev

    h0 = jnp.zeros((bsz, h, p, n), jnp.float32)
    _, h_in = jax.lax.scan(
        step,
        h0,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)),
    )
    h_in = h_in.transpose(1, 0, 2, 3, 4)                           # (B,nc,H,P,N)

    # --- inter-chunk output: y_off[q] = (C_q . h_in) * exp(dA_cs[q])
    decay_in = jnp.exp(dA_cs)                                      # (B,nc,Q,H)
    y_off = jnp.einsum(
        "bcqhn,bchpn,bcqh->bcqhp", cc.astype(jnp.float32), h_in, decay_in
    )

    return (y_diag + y_off).astype(x.dtype).reshape(bsz, s, h, p)


def _causal_depthwise_conv(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """x: (B, S, C), w: (K, C) — causal depthwise conv via shift-and-add
    (K is tiny, typically 4).  Accumulates and returns fp32 so the result is
    bitwise the sum the decode path computes over its rolling window (bf16
    partial sums here would make the conv output — and everything the SSM
    state is built from — diverge between prefill and decode)."""
    k = w.shape[0]
    xf = x.astype(jnp.float32)
    wf = w.astype(jnp.float32)
    out = jnp.zeros_like(xf)
    for i in range(k):
        shift = k - 1 - i
        xi = jnp.pad(xf, ((0, 0), (shift, 0), (0, 0)))[:, : x.shape[1], :]
        out = out + xi * wf[i][None, None, :]
    return out


def mamba2_forward(
    x: jnp.ndarray,        # (B, S, D)
    p: dict,
    dims: SSMDims,
) -> jnp.ndarray:
    bsz, s, _ = x.shape
    di, g, n, h, hd = (
        dims.d_inner,
        dims.n_groups,
        dims.d_state,
        dims.n_heads,
        dims.head_dim,
    )
    zxbcdt = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(x.dtype))
    # split points: z (di), xbc (conv_dim), dt (H)
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di : di + dims.conv_dim]
    dt = zxbcdt[..., di + dims.conv_dim :]

    # fp32 conv + silu, cast once: mirrors the decode window dataflow exactly
    xbc = jax.nn.silu(_causal_depthwise_conv(xbc, p["conv_w"])).astype(x.dtype)
    xs = xbc[..., :di]
    b = xbc[..., di : di + g * n].reshape(bsz, s, g, n)
    c = xbc[..., di + g * n :].reshape(bsz, s, g, n)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    y = ssd_chunked(
        xs.reshape(bsz, s, h, hd), dt, p["a_log"], b, c, min(dims.chunk, s)
    )
    y = y + xs.reshape(bsz, s, h, hd) * p["d_skip"].astype(x.dtype)[None, None, :, None]
    y = y.reshape(bsz, s, di)
    y = rms_norm(y * jax.nn.silu(z), p["norm"])
    return jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(x.dtype))


def mamba2_decode(
    x: jnp.ndarray,        # (B, 1, D)
    p: dict,
    dims: SSMDims,
    cache: dict,           # conv (B, K-1, conv_dim), ssm (B, H, P, N) fp32
) -> tuple[jnp.ndarray, dict]:
    bsz = x.shape[0]
    di, g, n, h, hd = (
        dims.d_inner,
        dims.n_groups,
        dims.d_state,
        dims.n_heads,
        dims.head_dim,
    )
    zxbcdt = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(x.dtype))[:, 0]
    z = zxbcdt[:, :di]
    xbc_new = zxbcdt[:, di : di + dims.conv_dim]
    dt = zxbcdt[:, di + dims.conv_dim :]

    conv_hist = jnp.concatenate([cache["conv"], xbc_new[:, None, :]], axis=1)
    w = p["conv_w"].astype(jnp.float32)                              # (K, C)
    # fp32 window sum + silu, cast once — bitwise the prefill conv dataflow
    xbc = jax.nn.silu(
        jnp.einsum("bkc,kc->bc", conv_hist.astype(jnp.float32), w)
    ).astype(x.dtype)
    new_conv = conv_hist[:, 1:]

    xs = xbc[:, :di].reshape(bsz, h, hd)
    b = xbc[:, di : di + g * n].reshape(bsz, g, n)
    c = xbc[:, di + g * n :].reshape(bsz, g, n)
    rep = h // g
    b = jnp.repeat(b, rep, axis=1)                                  # (B, H, N)
    c = jnp.repeat(c, rep, axis=1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["a_log"].astype(jnp.float32))
    decay = jnp.exp(dt * A[None, :])                                # (B, H)
    ssm = cache["ssm"] * decay[..., None, None] + jnp.einsum(
        "bh,bhp,bhn->bhpn", dt, xs.astype(jnp.float32), b.astype(jnp.float32)
    )
    y = jnp.einsum("bhpn,bhn->bhp", ssm, c.astype(jnp.float32)).astype(x.dtype)
    y = y + xs * p["d_skip"].astype(x.dtype)[None, :, None]
    y = y.reshape(bsz, 1, di)
    y = rms_norm(y * jax.nn.silu(z[:, None, :]), p["norm"])
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(x.dtype))
    return out, {"conv": new_conv, "ssm": ssm}
