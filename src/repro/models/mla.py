"""Multi-head Latent Attention (DeepSeek-V2, arXiv:2405.04434).

Prefill: standard formulation —
    c_q  = W_dq x             (q_lora)         q = W_uq RMSNorm(c_q)
    c_kv = W_dkv x            (kv_lora)        k_nope, v = W_uk/W_uv RMSNorm(c_kv)
    k_rope = RoPE(W_kr x)     (d_rope, shared across heads)
    score = q_nope . k_nope + q_rope . k_rope, scale 1/sqrt(d_nope + d_rope)

Decode: the *absorbed* formulation — the KV cache stores only the latent
``c_kv`` (kv_lora) and ``k_rope`` per token (this is MLA's entire point:
512 + 64 floats/token instead of 2 * H * 128).  W_uk is absorbed into the
query and W_uv into the output so no per-step (S, H, d) K/V tensors are
materialized:
    q_lat  = einsum(q_nope, W_uk)        (B, 1, H, kv_lora)
    score  = q_lat . norm(c_kv) + q_rope . k_rope
    o_lat  = probs . norm(c_kv)          (B, 1, H, kv_lora)
    out    = einsum(o_lat, W_uv)         (B, 1, H, d_v)
"""

from __future__ import annotations

import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .layers import apply_rope, rms_norm

MLA_CHUNK_THRESHOLD = 8192
MLA_Q_CHUNK = 2048


@dataclass(frozen=True)
class MLADims:
    n_heads: int
    q_lora: int = 1536
    kv_lora: int = 512
    d_nope: int = 128
    d_rope: int = 64
    d_v: int = 128

    @property
    def scale(self) -> float:
        return 1.0 / math.sqrt(self.d_nope + self.d_rope)


def mla_prefill(
    x: jnp.ndarray,          # (B, S, D)
    p: dict,
    dims: MLADims,
    positions: jnp.ndarray,  # (B, S)
    rope_theta: float = 10000.0,
) -> tuple[jnp.ndarray, dict]:
    """Returns (attn_out (B,S,D), cache {c_kv, k_rope})."""
    b, s, d = x.shape
    h, dn, dr, dv = dims.n_heads, dims.d_nope, dims.d_rope, dims.d_v

    cq = jnp.einsum("bsd,dq->bsq", x, p["w_dq"].astype(x.dtype))
    cq = rms_norm(cq, p["q_norm"])
    q = jnp.einsum("bsq,qhe->bshe", cq, p["w_uq"].astype(x.dtype))  # e = dn+dr
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, rope_theta)

    c_kv = jnp.einsum("bsd,dr->bsr", x, p["w_dkv"].astype(x.dtype))
    # Scores and context run in fp32 end-to-end: the decode path computes the
    # SAME quantities through the absorbed (latent-space) factorization, and
    # bf16 rounding of the intermediates is dataflow-dependent — it is what
    # made decode drift from prefill by the second token.  In fp32 the two
    # factorizations agree to ~1e-6, which survives the bf16 residual cast.
    c_kv_n = rms_norm(c_kv, p["kv_norm"]).astype(jnp.float32)
    k_nope = jnp.einsum("bsr,rhe->bshe", c_kv_n, p["w_uk"].astype(jnp.float32))
    v = jnp.einsum("bsr,rhe->bshe", c_kv_n, p["w_uv"].astype(jnp.float32))
    k_rope = jnp.einsum("bsd,de->bse", x, p["w_kr"].astype(x.dtype))
    k_rope = apply_rope(k_rope[:, :, None, :], positions, rope_theta)[:, :, 0]

    def attend(q_nope_c, q_rope_c, q_off):
        """One query chunk against the full K/V (fp32 throughout)."""
        sq = q_nope_c.shape[1]
        scores = (
            jnp.einsum("bqhe,bkhe->bhqk", q_nope_c.astype(jnp.float32), k_nope)
            + jnp.einsum(
                "bqhe,bke->bhqk",
                q_rope_c.astype(jnp.float32),
                k_rope.astype(jnp.float32),
            )
        ) * dims.scale
        mask = (jnp.arange(sq)[:, None] + q_off) >= jnp.arange(s)[None, :]
        scores = jnp.where(mask[None, None], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1)
        return jnp.einsum("bhqk,bkhe->bqhe", probs, v).astype(x.dtype)

    if s > MLA_CHUNK_THRESHOLD:
        # query-chunked dataflow: peak scores memory (B, H, chunk, S)
        nq = s // MLA_Q_CHUNK
        qn = q_nope.reshape(b, nq, MLA_Q_CHUNK, h, dn).transpose(1, 0, 2, 3, 4)
        qr = q_rope.reshape(b, nq, MLA_Q_CHUNK, h, dr).transpose(1, 0, 2, 3, 4)
        ctx = jax.lax.map(
            lambda args: attend(args[1], args[2], args[0] * MLA_Q_CHUNK),
            (jnp.arange(nq), qn, qr),
        )
        ctx = ctx.transpose(1, 0, 2, 3, 4).reshape(b, s, h, dims.d_v)
    else:
        ctx = attend(q_nope, q_rope, 0)                    # (B,S,H,dv)
    out = jnp.einsum("bqhe,hed->bqd", ctx, p["w_o"].astype(x.dtype))
    return out, {"c_kv": c_kv, "k_rope": k_rope}


def mla_decode(
    x: jnp.ndarray,          # (B, 1, D)
    p: dict,
    dims: MLADims,
    cache: dict,             # c_kv (B, S, kv_lora), k_rope (B, S, d_rope)
    cache_len: jnp.ndarray,  # (B,) current lengths (new token goes at this pos)
    rope_theta: float = 10000.0,
) -> tuple[jnp.ndarray, dict]:
    b, _, d = x.shape
    h, dn, dr = dims.n_heads, dims.d_nope, dims.d_rope
    s_max = cache["c_kv"].shape[1]
    positions = cache_len[:, None]                          # (B, 1)

    cq = rms_norm(jnp.einsum("bsd,dq->bsq", x, p["w_dq"].astype(x.dtype)), p["q_norm"])
    q = jnp.einsum("bsq,qhe->bshe", cq, p["w_uq"].astype(x.dtype))
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, rope_theta)

    c_kv_new = jnp.einsum("bsd,dr->bsr", x, p["w_dkv"].astype(x.dtype))
    k_rope_new = jnp.einsum("bsd,de->bse", x, p["w_kr"].astype(x.dtype))
    k_rope_new = apply_rope(k_rope_new[:, :, None, :], positions, rope_theta)[:, :, 0]

    # insert at cache_len
    oh = jax.nn.one_hot(cache_len, s_max, dtype=cache["c_kv"].dtype)  # (B, S)
    c_kv = cache["c_kv"] + oh[..., None] * c_kv_new
    k_rope = cache["k_rope"] + oh[..., None] * k_rope_new

    # Absorbed attention in latent space, fp32 throughout — see the matching
    # note in mla_prefill: prefill and decode factorize the same products
    # differently, so both must accumulate in fp32 for the decode cache/state
    # to track prefill.
    c_kv_n = rms_norm(c_kv, p["kv_norm"]).astype(jnp.float32)
    q_lat = jnp.einsum(
        "bshe,rhe->bshr", q_nope.astype(jnp.float32), p["w_uk"].astype(jnp.float32)
    )
    scores = (
        jnp.einsum("bshr,bkr->bhsk", q_lat, c_kv_n)
        + jnp.einsum(
            "bshe,bke->bhsk", q_rope.astype(jnp.float32), k_rope.astype(jnp.float32)
        )
    ) * dims.scale
    valid = jnp.arange(s_max)[None, :] <= cache_len[:, None]
    scores = jnp.where(valid[:, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    o_lat = jnp.einsum("bhsk,bkr->bshr", probs, c_kv_n)
    ctx = jnp.einsum(
        "bshr,rhe->bshe", o_lat, p["w_uv"].astype(jnp.float32)
    ).astype(x.dtype)
    out = jnp.einsum("bshe,hed->bsd", ctx, p["w_o"].astype(x.dtype))
    return out, {"c_kv": c_kv, "k_rope": k_rope}
