"""Core transformer layers: norms, RoPE, GQA attention (dense + chunked
online-softmax for long prefill), SwiGLU/GELU MLPs, embeddings.

All functions are pure: (params, inputs) -> outputs.  Compute dtype is
bf16 (cast at the edges); reductions (softmax, norms) run in fp32.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

COMPUTE_DTYPE = jnp.bfloat16

# ---------------------------------------------------------------- norms


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32)).astype(x.dtype)


def layer_norm(
    x: jnp.ndarray, scale: jnp.ndarray, bias: jnp.ndarray, eps: float = 1e-5
) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------- rope


def rope_freqs(head_dim: int, theta: float = 10000.0) -> jnp.ndarray:
    return 1.0 / (
        theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)
    )


def apply_rope(
    x: jnp.ndarray, positions: jnp.ndarray, theta: float = 10000.0
) -> jnp.ndarray:
    """x: (B, S, H, D); positions: (B, S) int32."""
    freqs = rope_freqs(x.shape[-1], theta)                       # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs    # (B, S, D/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------- attention


def _dense_attn(
    q: jnp.ndarray,      # (B, Sq, H, D)
    k: jnp.ndarray,      # (B, Sk, Hkv, D)
    v: jnp.ndarray,      # (B, Sk, Hkv, D)
    *,
    causal: bool,
    q_offset: jnp.ndarray | int = 0,
    kv_len: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """Reference attention with GQA head grouping; scores in fp32."""
    b, sq, h, d = q.shape
    hkv = k.shape[2]
    group = h // hkv
    qg = q.reshape(b, sq, hkv, group, d)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k).astype(jnp.float32)
    scores = scores * (1.0 / math.sqrt(d))
    sk = k.shape[1]
    if causal:
        qpos = jnp.arange(sq)[:, None] + q_offset
        kpos = jnp.arange(sk)[None, :]
        mask = qpos >= kpos
        scores = jnp.where(mask[None, None, None], scores, -1e30)
    if kv_len is not None:
        valid = jnp.arange(sk)[None, :] < kv_len[:, None]        # (B, Sk)
        scores = jnp.where(valid[:, None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v)
    return out.reshape(b, sq, h, d)


def _chunked_attn(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *, causal: bool, q_chunk: int
) -> jnp.ndarray:
    """Query-chunked online-softmax attention (flash-attention dataflow in
    pure JAX): peak score memory is (B, H, q_chunk, Sk) instead of
    (B, H, Sq, Sk).  Used for long prefill (Sq >= LONG_SEQ_THRESHOLD)."""
    b, sq, h, d = q.shape
    if sq % q_chunk != 0:
        raise ValueError(f"seq len {sq} not divisible by q_chunk {q_chunk}")
    n_chunks = sq // q_chunk
    qc = q.reshape(b, n_chunks, q_chunk, h, d).transpose(1, 0, 2, 3, 4)

    def one_chunk(i, q_i):
        return _dense_attn(
            q_i, k, v, causal=causal, q_offset=i * q_chunk
        )

    out = jax.lax.map(
        lambda args: one_chunk(args[0], args[1]),
        (jnp.arange(n_chunks), qc),
    )
    return out.transpose(1, 0, 2, 3, 4).reshape(b, sq, h, d)


LONG_SEQ_THRESHOLD = 8192
ATTN_Q_CHUNK = 2048


def attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    q_offset: jnp.ndarray | int = 0,
    kv_len: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """GQA attention; switches to query-chunked dataflow for long prefill."""
    sq, sk = q.shape[1], k.shape[1]
    if sq > LONG_SEQ_THRESHOLD and sq == sk and kv_len is None:
        return _chunked_attn(q, k, v, causal=causal, q_chunk=ATTN_Q_CHUNK)
    return _dense_attn(q, k, v, causal=causal, q_offset=q_offset, kv_len=kv_len)


# ---------------------------------------------------------------- mlps


def swiglu(x: jnp.ndarray, w_gate, w_up, w_down) -> jnp.ndarray:
    g = jnp.einsum("bsd,df->bsf", x, w_gate.astype(x.dtype))
    u = jnp.einsum("bsd,df->bsf", x, w_up.astype(x.dtype))
    return jnp.einsum("bsf,fd->bsd", jax.nn.silu(g) * u, w_down.astype(x.dtype))


def gelu_mlp(x: jnp.ndarray, w_up, b_up, w_down, b_down) -> jnp.ndarray:
    h = jnp.einsum("bsd,df->bsf", x, w_up.astype(x.dtype)) + b_up.astype(x.dtype)
    h = jax.nn.gelu(h)
    return jnp.einsum("bsf,fd->bsd", h, w_down.astype(x.dtype)) + b_down.astype(x.dtype)


# ---------------------------------------------------------------- embed / head


def embed(tokens: jnp.ndarray, table: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(table, tokens, axis=0).astype(COMPUTE_DTYPE)


def lm_logits(x: jnp.ndarray, head: jnp.ndarray) -> jnp.ndarray:
    return jnp.einsum("bsd,dv->bsv", x, head.astype(x.dtype))
