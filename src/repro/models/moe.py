"""Mixture-of-Experts layer with sort-based grouped dispatch.

Design (DESIGN.md section 5): tokens are reshaped into G groups (set to the
data-parallel shard count by the launcher so dispatch is local to a data
shard and the expert dimension is the only one that crosses chips).  Within
each group:

    1. router: softmax top-k over E experts,
    2. dispatch: stable-argsort the (tokens*k) expert assignments, give each
       assignment a slot within its expert via rank - segment_start, drop
       assignments past the per-expert capacity
       C = ceil(tokens_g * k / E * capacity_factor),
    3. gather to an (E, C, D) buffer (a padded row absorbs drops),
    4. batched expert FFN:  einsum('ecd,edf->ecf') SwiGLU,
    5. combine: scatter-add outputs * gate weights back to token positions.

This avoids the O(tokens * E * C) one-hot dispatch tensors of the GShard
formulation — the buffers here are O(tokens * k / G * D) per group — while
staying fully static-shaped (vmap over groups, no ragged shapes), which is
what pjit needs.  Capacity drops mirror production MoE (tokens past C fall
through with a zero update, residual carries them).

DeepSeek-style shared experts (always-on) are supported via ``n_shared``.
"""

from __future__ import annotations

import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp

#: EXPERIMENTS.md §Perf H2: constrain the grouped-dispatch tensors so the
#: gather/scatter stays local to a data shard (group dim on the batch axes,
#: expert dim on "model").  Without this XLA's SPMD partitioner falls back
#: to 'involuntary full rematerialization' — it REPLICATES the (T, D)
#: combine buffer per device and all-reduces it per layer (~1.2 TB/device
#: per step on deepseek-v2 train_4k).  Off by default (baseline).
CONSTRAIN_DISPATCH = False
#: finer-grained variant (§Perf H6): constrain ONLY the group-reshaped
#: activations (G on the data axes) — sharding propagation loses the group
#: dim at the (B,S,D)->(G,T,D) reshape and silently REPLICATES all groups on
#: every data shard; this pins it without touching the expert buffers.
CONSTRAIN_GROUPS_ONLY = False


def _constrain(x, *parts, group_level: bool = False):
    if not (CONSTRAIN_DISPATCH or (CONSTRAIN_GROUPS_ONLY and group_level)):
        return x
    from ..sharding.constrain import _active_mesh

    mesh = _active_mesh()
    if mesh is None:
        return x
    from jax.sharding import PartitionSpec

    def resolve(p, dim):
        if p == "batch":
            axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
            import numpy as _np

            n = int(_np.prod([mesh.shape[a] for a in axes])) if axes else 1
            if axes and x.shape[dim] % n == 0:
                return axes if len(axes) > 1 else axes[0]
            return None
        if p == "model":
            if "model" in mesh.axis_names and x.shape[dim] % mesh.shape["model"] == 0:
                return "model"
            return None
        return None

    return jax.lax.with_sharding_constraint(
        x, PartitionSpec(*(resolve(p, i) for i, p in enumerate(parts)))
    )


@dataclass(frozen=True)
class MoEDims:
    n_experts: int
    top_k: int
    d_model: int
    d_ff: int
    n_shared: int = 0
    capacity_factor: float = 1.25
    groups: int = 1


def router_topk(
    x: jnp.ndarray, w_router: jnp.ndarray, top_k: int
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """(T, D) -> gates (T, k) fp32 (renormalized), experts (T, k) int32,
    plus the full router probabilities (T, E) for the aux loss."""
    logits = jnp.einsum("td,de->te", x.astype(jnp.float32), w_router.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gates, experts = jax.lax.top_k(probs, top_k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    return gates, experts.astype(jnp.int32), probs


def _dispatch_indices(
    experts: jnp.ndarray, n_experts: int, capacity: int
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """experts: (A,) flat expert assignment per (token, k) pair.

    Returns (slot_table, keep):
      slot_table: (E, C) int32 indices into the flat assignment axis
                  (= A, i.e. 'dropped/empty' sentinel points at pad row A),
      keep: (A,) bool — assignment survived capacity.
    """
    a = experts.shape[0]
    order = jnp.argsort(experts, stable=True)              # (A,)
    sorted_e = experts[order]
    # rank of each sorted element within its expert segment
    pos = jnp.arange(a, dtype=jnp.int32)
    seg_start = jnp.searchsorted(sorted_e, jnp.arange(n_experts), side="left")
    rank = pos - seg_start[sorted_e]
    keep_sorted = rank < capacity
    # scatter into (E, C): slot (e, r) <- original assignment index
    flat_slot = sorted_e * capacity + rank
    slot_table = jnp.full((n_experts * capacity,), a, dtype=jnp.int32)
    slot_table = slot_table.at[
        jnp.where(keep_sorted, flat_slot, n_experts * capacity)
    ].set(jnp.where(keep_sorted, order.astype(jnp.int32), a), mode="drop")
    keep = jnp.zeros((a,), bool).at[order].set(keep_sorted)
    return slot_table.reshape(n_experts, capacity), keep


def moe_group_forward(
    x: jnp.ndarray,            # (T, D) one group's tokens
    w_router: jnp.ndarray,     # (D, E)
    w_gate: jnp.ndarray,       # (E, D, F)
    w_up: jnp.ndarray,         # (E, D, F)
    w_down: jnp.ndarray,       # (E, F, D)
    dims: MoEDims,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    t, d = x.shape
    e, k = dims.n_experts, dims.top_k
    capacity = math.ceil(t * k / e * dims.capacity_factor)
    capacity = max(8, min(capacity, t))

    gates, experts, probs = router_topk(x, w_router, k)
    flat_experts = experts.reshape(-1)                       # (T*k,)
    slot_table, _ = _dispatch_indices(flat_experts, e, capacity)

    token_of_assignment = jnp.concatenate(
        [jnp.repeat(jnp.arange(t, dtype=jnp.int32), k), jnp.array([t], jnp.int32)]
    )                                                         # (T*k + 1,)
    gate_of_assignment = jnp.concatenate(
        [gates.reshape(-1), jnp.zeros((1,), gates.dtype)]
    )

    tok_idx = token_of_assignment[slot_table]                 # (E, C) in [0..T]
    gate_w = gate_of_assignment[slot_table]                   # (E, C) fp32

    x_pad = jnp.concatenate([x, jnp.zeros((1, d), x.dtype)], axis=0)
    xs = x_pad[tok_idx]                                       # (E, C, D)
    xs = _constrain(xs, "model", None, None)                  # experts on EP axis

    g = jnp.einsum("ecd,edf->ecf", xs, w_gate.astype(x.dtype))
    u = jnp.einsum("ecd,edf->ecf", xs, w_up.astype(x.dtype))
    ys = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, w_down.astype(x.dtype))
    ys = ys * gate_w[..., None].astype(x.dtype)

    out = jnp.zeros((t + 1, d), x.dtype).at[tok_idx.reshape(-1)].add(
        ys.reshape(-1, d)
    )[:t]

    # load-balancing auxiliary loss (Switch-style): E * sum_e f_e * p_e
    frac_tokens = jnp.zeros((e,), jnp.float32).at[flat_experts].add(1.0) / (t * k)
    mean_probs = probs.mean(axis=0)
    aux = e * jnp.sum(frac_tokens * mean_probs)
    return out, aux


def moe_forward(
    x: jnp.ndarray,            # (B, S, D)
    params: dict,              # router, gate, up, down [, shared_*]
    dims: MoEDims,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    b, s, d = x.shape
    g = dims.groups
    tokens = b * s
    if tokens % g != 0:
        raise ValueError(f"token count {tokens} not divisible by group {g}")
    xg = x.reshape(g, tokens // g, d)
    xg = _constrain(xg, "batch", None, None, group_level=True)

    out, aux = jax.vmap(
        lambda xi: moe_group_forward(
            xi, params["router"], params["gate"], params["up"], params["down"], dims
        )
    )(xg)
    out = _constrain(out, "batch", None, None, group_level=True)
    out = out.reshape(b, s, d)

    if dims.n_shared:
        gsh = jnp.einsum("bsd,df->bsf", x, params["shared_gate"].astype(x.dtype))
        ush = jnp.einsum("bsd,df->bsf", x, params["shared_up"].astype(x.dtype))
        out = out + jnp.einsum(
            "bsf,fd->bsd", jax.nn.silu(gsh) * ush, params["shared_down"].astype(x.dtype)
        )
    return out, aux.mean()
