"""Encoder-decoder transformer backbone (whisper-medium, arXiv:2212.04356).

The audio conv frontend is a STUB per the task spec: ``input_specs`` feeds
precomputed frame embeddings (B, S_frames, D) straight into the encoder
(learned positional embeddings added).  The decoder is a standard causal
transformer with cross-attention; LayerNorm + GELU MLPs + biases, logits
tied to the decoder token embedding — whisper conventions.

Serve path: encoder output is projected ONCE into per-layer cross K/V at
cache init (cross-attention K/V never change during decode), then each
decode step runs self-attention against its growing cache plus frozen
cross-attention reads.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..sharding.constrain import constrain_residual
from .decoder import _maybe_remat
from .layers import COMPUTE_DTYPE, attention, layer_norm, lm_logits
from .param import P


def _attn_proj_spec(L: int, D: int, H: int, hd: int, prefix: str) -> dict:
    return {
        f"{prefix}wq": P((L, D, H, hd), ("layers", "embed", "heads", "head_dim"),
                         init="scaled"),
        f"{prefix}wk": P((L, D, H, hd), ("layers", "embed", "kv_heads", "head_dim"),
                         init="scaled"),
        f"{prefix}wv": P((L, D, H, hd), ("layers", "embed", "kv_heads", "head_dim"),
                         init="scaled"),
        f"{prefix}wo": P((L, H, hd, D), ("layers", "heads", "head_dim", "embed"),
                         init="scaled"),
        f"{prefix}bq": P((L, H, hd), ("layers", "heads", "head_dim"), init="zeros"),
        f"{prefix}bv": P((L, H, hd), ("layers", "heads", "head_dim"), init="zeros"),
        f"{prefix}bo": P((L, D), ("layers", "embed"), init="zeros"),
    }


def _ln_spec(L: int, D: int, name: str) -> dict:
    return {
        f"{name}_scale": P((L, D), ("layers", "embed"), init="ones"),
        f"{name}_bias": P((L, D), ("layers", "embed"), init="zeros"),
    }


def _mlp_spec(L: int, D: int, F: int) -> dict:
    return {
        "w_up": P((L, D, F), ("layers", "embed", "ffn"), init="scaled"),
        "b_up": P((L, F), ("layers", "ffn"), init="zeros"),
        "w_down": P((L, F, D), ("layers", "ffn", "embed"), init="scaled"),
        "b_down": P((L, D), ("layers", "embed"), init="zeros"),
    }


class EncDecLM:
    def __init__(self, cfg: ArchConfig, moe_groups: int = 1):
        self.cfg = cfg
        self.ed = cfg.encdec

    # ------------------------------------------------------------- spec
    def spec(self) -> dict:
        c, ed = self.cfg, self.ed
        D, H, hd, F = c.d_model, c.n_heads, c.head_dim, c.d_ff
        enc_layers = {
            **_ln_spec(ed.n_enc_layers, D, "ln1"),
            **_attn_proj_spec(ed.n_enc_layers, D, H, hd, ""),
            **_ln_spec(ed.n_enc_layers, D, "ln2"),
            **_mlp_spec(ed.n_enc_layers, D, F),
        }
        dec_layers = {
            **_ln_spec(ed.n_dec_layers, D, "ln1"),
            **_attn_proj_spec(ed.n_dec_layers, D, H, hd, "self_"),
            **_ln_spec(ed.n_dec_layers, D, "ln2"),
            **_attn_proj_spec(ed.n_dec_layers, D, H, hd, "cross_"),
            **_ln_spec(ed.n_dec_layers, D, "ln3"),
            **_mlp_spec(ed.n_dec_layers, D, F),
        }
        return {
            "enc_pos": P((ed.max_src_len, D), (None, "embed")),
            "enc_layers": enc_layers,
            "enc_final_scale": P((D,), ("embed",), init="ones"),
            "enc_final_bias": P((D,), ("embed",), init="zeros"),
            "dec_embed": P((c.vocab, D), ("vocab", "embed")),
            "dec_pos": P((ed.dec_len, D), (None, "embed")),
            "dec_layers": dec_layers,
            "dec_final_scale": P((D,), ("embed",), init="ones"),
            "dec_final_bias": P((D,), ("embed",), init="zeros"),
        }

    # ------------------------------------------------------------- blocks
    def _project(self, lp, prefix, x):
        q = (
            jnp.einsum("bsd,dhe->bshe", x, lp[f"{prefix}wq"].astype(x.dtype))
            + lp[f"{prefix}bq"].astype(x.dtype)
        )
        k = jnp.einsum("bsd,dhe->bshe", x, lp[f"{prefix}wk"].astype(x.dtype))
        v = (
            jnp.einsum("bsd,dhe->bshe", x, lp[f"{prefix}wv"].astype(x.dtype))
            + lp[f"{prefix}bv"].astype(x.dtype)
        )
        return q, k, v

    def _out(self, lp, prefix, o):
        return (
            jnp.einsum("bshe,hed->bsd", o, lp[f"{prefix}wo"].astype(o.dtype))
            + lp[f"{prefix}bo"].astype(o.dtype)
        )

    def _mlp(self, lp, x):
        h = jnp.einsum("bsd,df->bsf", x, lp["w_up"].astype(x.dtype)) + lp["b_up"].astype(x.dtype)
        h = jax.nn.gelu(h)
        return jnp.einsum("bsf,fd->bsd", h, lp["w_down"].astype(x.dtype)) + lp[
            "b_down"
        ].astype(x.dtype)

    # ------------------------------------------------------------- encoder
    def encode(self, params, src_embeds: jnp.ndarray, remat: str = "none"):
        """src_embeds: (B, S, D) precomputed frame embeddings (stub frontend)."""
        s = src_embeds.shape[1]
        x = src_embeds.astype(COMPUTE_DTYPE) + params["enc_pos"][:s].astype(
            COMPUTE_DTYPE
        )

        def block(x, lp):
            h = layer_norm(x, lp["ln1_scale"], lp["ln1_bias"])
            q, k, v = self._project(lp, "", h)
            o = attention(q, k, v, causal=False)
            x = x + self._out(lp, "", o)
            h = layer_norm(x, lp["ln2_scale"], lp["ln2_bias"])
            return constrain_residual(x + self._mlp(lp, h)), ()

        block = _maybe_remat(block, remat)
        x, _ = jax.lax.scan(block, x, params["enc_layers"])
        return layer_norm(x, params["enc_final_scale"], params["enc_final_bias"])

    # ------------------------------------------------------------- decoder
    def decode_train(self, params, enc_out, dec_tokens, remat: str = "none"):
        b, t = dec_tokens.shape
        x = jnp.take(params["dec_embed"], dec_tokens, axis=0).astype(COMPUTE_DTYPE)
        x = x + params["dec_pos"][:t].astype(COMPUTE_DTYPE)

        def block(x, lp):
            h = layer_norm(x, lp["ln1_scale"], lp["ln1_bias"])
            q, k, v = self._project(lp, "self_", h)
            o = attention(q, k, v, causal=True)
            x = x + self._out(lp, "self_", o)
            h = layer_norm(x, lp["ln2_scale"], lp["ln2_bias"])
            q, _, _ = self._project(lp, "cross_", h)
            _, ck, cv = self._project(lp, "cross_", enc_out)
            o = attention(q, ck, cv, causal=False)
            x = x + self._out(lp, "cross_", o)
            h = layer_norm(x, lp["ln3_scale"], lp["ln3_bias"])
            return constrain_residual(x + self._mlp(lp, h)), ()

        block = _maybe_remat(block, remat)
        x, _ = jax.lax.scan(block, x, params["dec_layers"])
        x = layer_norm(x, params["dec_final_scale"], params["dec_final_bias"])
        return lm_logits(x, params["dec_embed"].T)

    def forward(self, params, batch: dict, remat: str = "none"):
        """batch: src_embeds (B, S, D), dec_tokens (B, T)."""
        enc_out = self.encode(params, batch["src_embeds"], remat)
        logits = self.decode_train(params, enc_out, batch["dec_tokens"], remat)
        return logits, jnp.float32(0.0)

    # ------------------------------------------------------------- serving
    def cache_axes(self) -> dict:
        return {
            "self_k": ("layers", "batch", None, "heads", "kv_head_dim"),
            "self_v": ("layers", "batch", None, "heads", "kv_head_dim"),
            "cross_k": ("layers", "batch", "kv_seq", "heads", "kv_head_dim"),
            "cross_v": ("layers", "batch", "kv_seq", "heads", "kv_head_dim"),
        }

    def init_cache(self, params, enc_out: jnp.ndarray, batch: int):
        """Cross K/V projected once; empty growing self cache."""
        ed, c = self.ed, self.cfg
        L = ed.n_dec_layers

        def cross_kv(lp, x):
            k = jnp.einsum("bsd,dhe->bshe", x, lp["cross_wk"].astype(x.dtype))
            v = (
                jnp.einsum("bsd,dhe->bshe", x, lp["cross_wv"].astype(x.dtype))
                + lp["cross_bv"].astype(x.dtype)
            )
            return k, v

        ck, cv = jax.vmap(cross_kv, in_axes=(0, None))(params["dec_layers"], enc_out)
        return {
            "self_k": jnp.zeros((L, batch, ed.dec_len, c.n_heads, c.head_dim),
                                COMPUTE_DTYPE),
            "self_v": jnp.zeros((L, batch, ed.dec_len, c.n_heads, c.head_dim),
                                COMPUTE_DTYPE),
            "cross_k": ck,
            "cross_v": cv,
        }

    def decode_step(self, params, cache, cache_len, tokens):
        c = self.cfg
        x = jnp.take(params["dec_embed"], tokens, axis=0).astype(COMPUTE_DTYPE)
        pos_emb = jnp.take(params["dec_pos"], cache_len, axis=0).astype(COMPUTE_DTYPE)
        x = x + pos_emb[:, None, :]

        def block(x, scan_in):
            lp, cache_l = scan_in
            h = layer_norm(x, lp["ln1_scale"], lp["ln1_bias"])
            q, k, v = self._project(lp, "self_", h)
            s_max = cache_l["self_k"].shape[1]
            oh = jax.nn.one_hot(cache_len, s_max, dtype=k.dtype)
            k_all = cache_l["self_k"] + oh[:, :, None, None] * k
            v_all = cache_l["self_v"] + oh[:, :, None, None] * v
            o = attention(q, k_all, v_all, causal=False, kv_len=cache_len + 1)
            x = x + self._out(lp, "self_", o)
            h = layer_norm(x, lp["ln2_scale"], lp["ln2_bias"])
            q, _, _ = self._project(lp, "cross_", h)
            o = attention(q, cache_l["cross_k"], cache_l["cross_v"], causal=False)
            x = x + self._out(lp, "cross_", o)
            h = layer_norm(x, lp["ln3_scale"], lp["ln3_bias"])
            x = x + self._mlp(lp, h)
            new_cache = dict(cache_l, self_k=k_all, self_v=v_all)
            return x, new_cache

        x, new_cache = jax.lax.scan(block, x, (params["dec_layers"], cache))
        x = layer_norm(x, params["dec_final_scale"], params["dec_final_bias"])
        return lm_logits(x, params["dec_embed"].T), new_cache
