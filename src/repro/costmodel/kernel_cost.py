"""Analytical TPU pipeline cost model for the tunable image kernels.

This is the measurement function for the paper-matrix reproduction on a
CPU-only container (DESIGN.md section 2.2).  It models a Pallas TPU kernel as
a sequential grid of pipeline steps, each step DMAing one VMEM block from HBM
and computing on the VPU, with the 6 tunable parameters (DESIGN.md 2.1):

    t_x -> block rows        bm = 8 * t_x
    t_y -> block cols        bn = 128 * t_y
    t_z -> row coarsening    (row-tiles computed per grid step)
    w_x -> row-region split
    w_y -> col-region split
    w_z -> pipeline depth    (multi-buffering in VMEM)

Model terms (per step):
    dma_t     = block_bytes / (hbm_bw * dma_eff) + dma_setup
    compute_t = elems * flops_per_elem / vpu_flops
    step_t    = dma_t + compute_t                 (w_z == 1, no overlap)
              = max(dma_t, compute_t) * (1 + bubble(w_z))   otherwise
plus kernel-launch overhead, a pipeline warm-up of w_z DMA steps, padding
waste when block geometry does not divide the image, region-switch costs,
and a per-chip core count (v3 has two tensor cores -> w_x*w_y = 2 pays off
there, mirroring how the paper's optimal workgroup depends on GPU
generation).

The *executability constraint* — the TPU analogue of the paper's
"prod(workgroup) <= 256 threads" rule — is the VMEM footprint:
``vmem_bytes(cfg) <= chip.vmem_bytes``.  Non-SMBO methods receive a space
constrained to executable configs (paper section V.C); SMBO methods may
propose non-executable configs and observe a failure penalty.

All absolute constants are plausible-order calibrations; the paper's
statistics (medians, ranks, speedups, CLES) are invariant to monotone
rescaling per benchmark.
"""

from __future__ import annotations

from dataclasses import dataclass
from math import ceil

import numpy as np

from ..core.measurement import BaseMeasurement
from ..core.space import Config, Param, SearchSpace
from .noise import lognormal_noise
from .tpu import ChipModel

FAILURE_RUNTIME = 0.25  # seconds: 'kernel failed to fit / compile' penalty
ROW_DESCRIPTOR_S = 20e-9  # per-row DMA descriptor cost (strided HBM access)


@dataclass(frozen=True)
class KernelWorkload:
    name: str
    x: int = 8192
    y: int = 8192
    bpe: int = 4
    n_inputs: int = 1
    n_outputs: int = 1
    flops_per_elem: float = 1.0
    halo: int = 0            # stencil halo (rows AND cols), e.g. 2 for harris
    scratch_tiles: int = 0   # per-step intermediate (bm, bn) tiles in VMEM
    noise_sigma: float = 0.03

    def n_cores_for(self, chip: ChipModel) -> int:
        return 2 if chip.name == "v3" else 1


ADD = KernelWorkload(
    name="add", n_inputs=2, flops_per_elem=1.0, scratch_tiles=0, noise_sigma=0.05
)
HARRIS = KernelWorkload(
    name="harris",
    n_inputs=1,
    flops_per_elem=60.0,
    halo=2,
    scratch_tiles=5,
    noise_sigma=0.03,
)
MANDELBROT = KernelWorkload(
    name="mandelbrot",
    n_inputs=0,
    flops_per_elem=256 * 10.0,  # fixed-trip escape loop on the VPU
    scratch_tiles=2,
    noise_sigma=0.02,
)

WORKLOADS: dict[str, KernelWorkload] = {
    w.name: w for w in (ADD, HARRIS, MANDELBROT)
}


def geometry(cfg: Config) -> tuple[int, int, int, int, int, int]:
    return (
        8 * cfg["t_x"],
        128 * cfg["t_y"],
        cfg["t_z"],
        cfg["w_x"],
        cfg["w_y"],
        cfg["w_z"],
    )


def vmem_bytes(w: KernelWorkload, cfg: Config) -> int:
    bm, bn, tz, _, _, wz = geometry(cfg)
    rows = bm * tz
    in_block = w.n_inputs * (rows + 2 * w.halo) * (bn + 2 * w.halo) * w.bpe
    out_block = w.n_outputs * rows * bn * w.bpe
    scratch = w.scratch_tiles * bm * bn * w.bpe
    return (in_block + out_block) * wz + scratch


def is_executable(w: KernelWorkload, chip: ChipModel, cfg: Config) -> bool:
    return vmem_bytes(w, cfg) <= chip.vmem_bytes


def runtime_model(w: KernelWorkload, chip: ChipModel, cfg: Config) -> float:
    """Noise-free modelled runtime in seconds (FAILURE_RUNTIME if invalid)."""
    if not is_executable(w, chip, cfg):
        return FAILURE_RUNTIME
    bm, bn, tz, wx, wy, wz = geometry(cfg)
    rows_step = bm * tz

    # region split -> per-region padded step counts
    region_rows = ceil(w.x / wx)
    region_cols = ceil(w.y / wy)
    steps_r = ceil(region_rows / rows_step)
    steps_c = ceil(region_cols / bn)
    n_steps = wx * wy * steps_r * steps_c

    # per-step work (padded blocks do full work — padding waste is real)
    elems = rows_step * bn
    in_bytes = w.n_inputs * (rows_step + 2 * w.halo) * (bn + 2 * w.halo) * w.bpe
    out_bytes = w.n_outputs * elems * w.bpe

    # DMA efficiency: each block row is a strided HBM access -> per-row
    # descriptor cost; narrow blocks (small bn) are badly inefficient.
    n_rows_dma = w.n_inputs * (rows_step + 2 * w.halo) + w.n_outputs * rows_step
    dma_t = (
        (in_bytes + out_bytes) / chip.hbm_bw
        + n_rows_dma * ROW_DESCRIPTOR_S
        + chip.dma_setup_s
    )
    compute_t = elems * w.flops_per_elem / chip.vpu_flops_f32

    if wz == 1:
        step_t = dma_t + compute_t
    else:
        bubble = {2: 0.05, 3: 0.02}.get(wz, 0.01)
        step_t = max(dma_t, compute_t) * (1.0 + bubble)

    # multiple cores (v3): independent regions run in parallel across cores
    cores = w.n_cores_for(chip)
    parallel = min(wx * wy, cores)
    total = n_steps * step_t / parallel

    # region switching breaks DMA streaming locality
    switches = wx * wy - 1
    total += switches * 8.0 * chip.dma_setup_s
    # pipeline warm-up: wz blocks in flight before first compute retires
    total += wz * dma_t + chip.launch_s
    return float(total)


PARAM_ORDER = ("t_x", "t_y", "t_z", "w_x", "w_y", "w_z")


class CostModelMeasurement(BaseMeasurement):
    """Vectorized measurement backend: modelled runtime x log-normal noise.

    Each instance owns a *counter-based* noise stream (one per experiment in
    the runner), so experiments see independent noise — and ``measure_final``
    re-draws noise, reproducing the paper's 10x final re-measurement
    semantics.  Noise for sample ``i`` depends only on ``(seed, i)``
    (see :mod:`repro.costmodel.noise`), so a batched dispatch through
    :meth:`measure_batch` and a sequential one-at-a-time run produce
    IDENTICAL values — the property the engine's parity audits rely on.
    ``measure_batch`` evaluates the whole batch through the vectorized
    ``runtime_model_batch`` in ONE Python-level dispatch.
    """

    def __init__(
        self,
        workload: KernelWorkload,
        chip: ChipModel,
        seed: int = 0,
        noise: bool = True,
    ):
        super().__init__()
        self.workload = workload
        self.chip = chip
        self.noise = noise
        self.seed = seed
        self._draws = 0  # per-sample noise counter (advances hit or miss)

    def _noise_factors(self, n: int) -> np.ndarray:
        start = self._draws
        self._draws += n
        return lognormal_noise(self.seed, start, n, self.workload.noise_sigma)

    def skip_samples(self, n: int) -> None:
        self._draws += n

    def _measure_one(self, config: Config) -> float:
        base = runtime_model(self.workload, self.chip, config)
        if not self.noise:
            return base
        return base * float(self._noise_factors(1)[0])

    def measure_batch(self, configs) -> np.ndarray:
        if len(configs) == 0:
            return np.zeros(0, dtype=np.float64)
        self.n_samples += len(configs)
        self.n_dispatches += 1
        arr = np.array(
            [[c[k] for k in PARAM_ORDER] for c in configs], dtype=np.int64
        )
        base = runtime_model_batch(self.workload, self.chip, arr)
        if self.noise:
            base = base * self._noise_factors(len(configs))
        return np.asarray(base, dtype=np.float64)

    def measure_final(self, config: Config, repeats: int = 10) -> float:
        base = runtime_model(self.workload, self.chip, config)
        if not self.noise:
            return base
        return float(np.median(base * self._noise_factors(repeats)))

    def provenance(self) -> dict:
        return {
            "backend": "costmodel",
            "kernel": self.workload.name,
            "chip": self.chip.name,
            "noise": bool(self.noise),
            "timer": "analytical",
        }


def executable_space(w: KernelWorkload, chip: ChipModel) -> SearchSpace:
    """The paper's 6-param space constrained to executable configs
    (given to non-SMBO methods only — see DESIGN.md 2.1)."""
    params = [
        Param.int_range("t_x", 1, 16),
        Param.int_range("t_y", 1, 16),
        Param.int_range("t_z", 1, 16),
        Param.int_range("w_x", 1, 8),
        Param.int_range("w_y", 1, 8),
        Param.int_range("w_z", 1, 8),
    ]
    def fn(cfg: Config) -> bool:
        return is_executable(w, chip, cfg)

    # stable id so TuningSpec serialization can rebuild this space by name
    fn.constraint_id = f"vmem:{w.name}:{chip.name}"
    return SearchSpace(params, constraint=fn)


def true_optimum(w: KernelWorkload, chip: ChipModel) -> tuple[Config, float]:
    """Exhaustive noise-free optimum over the full 2,097,152-config space —
    used as the denominator of 'percentage of optimum' (paper Fig. 2).

    Vectorized sweep; ~2M model evaluations.
    """
    tx = np.arange(1, 17)
    ty = np.arange(1, 17)
    tz = np.arange(1, 17)
    wx = np.arange(1, 9)
    wy = np.arange(1, 9)
    wzv = np.arange(1, 9)
    TX, TY, TZ, WX, WY, WZ = np.meshgrid(tx, ty, tz, wx, wy, wzv, indexing="ij")
    flat = np.stack([a.ravel() for a in (TX, TY, TZ, WX, WY, WZ)], axis=1)
    times = runtime_model_batch(w, chip, flat)
    j = int(np.argmin(times))
    cfg = dict(zip(("t_x", "t_y", "t_z", "w_x", "w_y", "w_z"), map(int, flat[j]), strict=True))
    return cfg, float(times[j])


def mean_runtime_estimate(
    w: KernelWorkload, chip: ChipModel, n_probe: int = 256, seed: int = 0
) -> float:
    """Deterministic mean modelled runtime over a pseudo-random probe of the
    full 6-parameter grid — the per-sample duration scale the work-unit
    scheduler uses to predict unit costs before anything has run.

    A seeded generator over a fixed probe size makes the estimate a pure
    function of ``(workload, chip, n_probe, seed)``: two processes planning
    the same matrix predict identical unit costs and therefore build
    identical unit decompositions.  Invalid geometries contribute their
    ``FAILURE_RUNTIME`` penalty, exactly as a random searcher pays it.
    """
    rng = np.random.default_rng(seed)
    probe = np.stack(
        [
            rng.integers(1, 17, size=n_probe),   # t_x
            rng.integers(1, 17, size=n_probe),   # t_y
            rng.integers(1, 17, size=n_probe),   # t_z
            rng.integers(1, 9, size=n_probe),    # w_x
            rng.integers(1, 9, size=n_probe),    # w_y
            rng.integers(1, 9, size=n_probe),    # w_z
        ],
        axis=1,
    )
    return float(np.mean(runtime_model_batch(w, chip, probe)))


def runtime_model_batch(
    w: KernelWorkload, chip: ChipModel, params: np.ndarray
) -> np.ndarray:
    """Vectorized ``runtime_model`` over rows of (t_x,t_y,t_z,w_x,w_y,w_z).

    Keep in exact agreement with ``runtime_model`` (property-tested)."""
    p = np.asarray(params, dtype=np.float64)
    bm, bn, tz, wx, wy, wz = (
        8 * p[:, 0],
        128 * p[:, 1],
        p[:, 2],
        p[:, 3],
        p[:, 4],
        p[:, 5],
    )
    rows_step = bm * tz
    in_block = w.n_inputs * (rows_step + 2 * w.halo) * (bn + 2 * w.halo) * w.bpe
    out_block = w.n_outputs * rows_step * bn * w.bpe
    scratch = w.scratch_tiles * bm * bn * w.bpe
    vmem = (in_block + out_block) * wz + scratch
    ok = vmem <= chip.vmem_bytes

    region_rows = np.ceil(w.x / wx)
    region_cols = np.ceil(w.y / wy)
    steps_r = np.ceil(region_rows / rows_step)
    steps_c = np.ceil(region_cols / bn)
    n_steps = wx * wy * steps_r * steps_c

    elems = rows_step * bn
    in_bytes = in_block
    out_bytes = out_block
    n_rows_dma = w.n_inputs * (rows_step + 2 * w.halo) + w.n_outputs * rows_step
    dma_t = (
        (in_bytes + out_bytes) / chip.hbm_bw
        + n_rows_dma * ROW_DESCRIPTOR_S
        + chip.dma_setup_s
    )
    compute_t = elems * w.flops_per_elem / chip.vpu_flops_f32

    bubble = np.where(wz == 2, 0.05, np.where(wz == 3, 0.02, 0.01))
    step_t = np.where(
        wz == 1, dma_t + compute_t, np.maximum(dma_t, compute_t) * (1.0 + bubble)
    )
    cores = w.n_cores_for(chip)
    parallel = np.minimum(wx * wy, cores)
    total = n_steps * step_t / parallel
    total += (wx * wy - 1) * 8.0 * chip.dma_setup_s
    total += wz * dma_t + chip.launch_s
    return np.where(ok, total, FAILURE_RUNTIME)
