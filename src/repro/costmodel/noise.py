"""Counter-based (dispatch-invariant) measurement noise.

The batched evaluation engine serves a whole proposal batch in one call;
the sequential driver serves the same configs one at a time.  For the two
paths to produce *identical* noisy observations — which is what makes
batched-vs-sequential parity auditable on the cost-model backend — the
noise for sample ``i`` of a stream must depend only on ``(seed, i)``, never
on how many samples shared a dispatch.

numpy's stateful Generators cannot provide that (a size-n draw consumes a
different amount of state than n size-1 draws), so we derive uniforms from
a splitmix64 hash of the sample counter and push them through Box-Muller.
Everything is vectorized; a batch of n samples costs four hashed uniforms
per sample with no Python-level loop.
"""

from __future__ import annotations

import numpy as np

_MASK = np.uint64(0xFFFFFFFFFFFFFFFF)
_GOLDEN = np.uint64(0x9E3779B97F4A7C15)


def splitmix64(x: np.ndarray) -> np.ndarray:
    """Vectorized splitmix64 finalizer (uint64 -> uint64).

    Wrapping uint64 arithmetic is the algorithm; numpy's overflow warning is
    suppressed for exactly that reason.
    """
    with np.errstate(over="ignore"):
        x = (np.asarray(x, dtype=np.uint64) + _GOLDEN) & _MASK
        x = ((x ^ (x >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)) & _MASK
        x = ((x ^ (x >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)) & _MASK
        return x ^ (x >> np.uint64(31))


def hashed_uniform(key: int, idx: np.ndarray, stream: int) -> np.ndarray:
    """u[i] in [0, 1) depending only on (key, idx[i], stream)."""
    k = splitmix64(np.uint64(key & 0xFFFFFFFFFFFFFFFF))
    base = (np.asarray(idx, dtype=np.uint64) * np.uint64(4)
            + np.uint64(stream)) & _MASK
    h = splitmix64((base + k) & _MASK)
    return (h >> np.uint64(11)).astype(np.float64) * (1.0 / (1 << 53))


def lognormal_noise(
    key: int,
    start: int,
    n: int,
    sigma: float,
    straggler_p: float = 0.01,
    straggler_lo: float = 1.1,
    straggler_hi: float = 1.5,
) -> np.ndarray:
    """Multiplicative noise factors for samples [start, start+n).

    Log-normal (mean 0, ``sigma``) runtime variance with a rare OS-jitter
    straggler tail — the model the paper's per-sample measurements assume.
    """
    idx = np.arange(start, start + n, dtype=np.uint64)
    u1 = hashed_uniform(key, idx, 0)
    u2 = hashed_uniform(key, idx, 1)
    u3 = hashed_uniform(key, idx, 2)
    u4 = hashed_uniform(key, idx, 3)
    z = np.sqrt(-2.0 * np.log1p(-u1)) * np.cos(2.0 * np.pi * u2)
    f = np.exp(sigma * z)
    straggler = u3 < straggler_p
    return np.where(
        straggler, f * (straggler_lo + (straggler_hi - straggler_lo) * u4), f
    )
