"""TPU chip models.

The paper compares three GPU architectures (RTX Titan 2019, Titan V 2017,
GTX 980 2014).  Our TPU adaptation uses three chip generations in the same
role: v5e (the roofline target mandated for this repo), a v4-class chip and
a v3-class chip.  Numbers are public spec-sheet values; the per-step DMA
overheads are calibrated so relative kernel behaviour (memory-bound add,
stencil harris, compute-bound mandelbrot) is plausible — the *absolute*
seconds only matter up to the monotone transformations the paper's
statistics use (medians, ranks, speedup ratios).
"""

from __future__ import annotations

from dataclasses import dataclass

MiB = 1024 * 1024


@dataclass(frozen=True)
class ChipModel:
    name: str
    peak_flops_bf16: float      # MXU, FLOP/s
    vpu_flops_f32: float        # vector unit, FLOP/s (stencils/fractals live here)
    hbm_bw: float               # bytes/s
    vmem_bytes: int             # per-core VMEM (the paper's workgroup<=256 analogue)
    ici_bw: float               # bytes/s per link (used by the distributed tuner)
    dma_setup_s: float          # per-grid-step DMA/program overhead
    launch_s: float             # per-kernel launch overhead
    mxu_dim: int = 128
    sublanes: int = 8
    lanes: int = 128


# v5e: 197 TFLOP/s bf16, 819 GB/s HBM, 128 MiB VMEM, ~50 GB/s/link ICI
V5E = ChipModel(
    name="v5e",
    peak_flops_bf16=197e12,
    vpu_flops_f32=4.1e12,
    hbm_bw=819e9,
    vmem_bytes=128 * MiB,
    ici_bw=50e9,
    dma_setup_s=0.4e-6,
    launch_s=2.0e-6,
)

# v4-class: 275 TFLOP/s bf16, 1228 GB/s HBM
V4 = ChipModel(
    name="v4",
    peak_flops_bf16=275e12,
    vpu_flops_f32=4.3e12,
    hbm_bw=1228e9,
    vmem_bytes=128 * MiB,
    ici_bw=45e9,
    dma_setup_s=0.5e-6,
    launch_s=2.5e-6,
)

# v3-class: 123 TFLOP/s bf16, 900 GB/s HBM, much smaller VMEM —
# plays the GTX 980 role: older part, different constraint surface.
V3 = ChipModel(
    name="v3",
    peak_flops_bf16=123e12,
    vpu_flops_f32=1.9e12,
    hbm_bw=900e9,
    vmem_bytes=32 * MiB,
    ici_bw=35e9,
    dma_setup_s=0.9e-6,
    launch_s=4.0e-6,
)

CHIPS: dict[str, ChipModel] = {c.name: c for c in (V5E, V4, V3)}
