"""The paper's qualitative claims (section VII) as machine-checkable
predicates with three-valued verdicts.

Claims checked (each aggregated across benchmarks x architectures):

  C1  BO-GP or BO-TPE is the best algorithm at small sample sizes (25-100).
  C2  GA is the best algorithm at large sample sizes (200-400); ``C2b`` is
      the Fig.-3 aggregate form (per-cell winner counts are noisy).
  C3  Speedup over RS is larger at small S than at large S (the paper's
      'largest gains in the low sample-size range').
  C4  Algorithms beat RS *more consistently* (higher CLES) at large S.
  C5  RF never outperforms all other algorithms, relaxed to the testable
      aggregate form: RF is not the overall winner at any S >= 100.
  C6  BO-GP shows a non-monotonicity (dip) somewhere in 100->400 while RS
      improves monotonically (the paper's overfitting observation).

Every check returns a :class:`ClaimVerdict` whose status is ``"pass"``,
``"fail"``, or — crucially — ``"insufficient-data"``: a claim about winner
statistics evaluated on a 3-experiment smoke matrix is *noise*, not a
falsification, so tiny results must never produce a false FAIL (or a hollow
PASS).  The sufficiency rules are explicit and documented:

* every paper algorithm must be present in every combo
  (:data:`~repro.analysis.records.ALGOS`),
* each cell entering a claim needs at least :data:`MIN_EXPERIMENTS`
  experiment repeats (the paper's own floor is 50),
* range claims (small vs large S) need at least one sample size observed on
  BOTH sides; monotonicity claims need the full size ladder.

``python -m repro.analysis.claims <results_dir>`` prints the verdicts
(successor of the retired ``benchmarks/validate_claims.py``).
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from .records import ALGOS, load_all
from .stats import (
    fig2_pct_optimum,
    fig3_aggregate,
    fig4a_speedup,
    fig4b_cles,
    winners_by_size,
)

SMALL = (25, 50, 100)
LARGE = (200, 400)

#: minimum experiment repeats per cell before winner/rank statistics count
#: as evidence.  The paper's smallest cell has E=50; below ~20 repeats the
#: per-cell winner is dominated by sampling noise (medians of <20 noisy
#: finals routinely reorder under reseeding), so claims report
#: ``insufficient-data`` instead of a verdict.
MIN_EXPERIMENTS = 20

PASS, FAIL, INSUFFICIENT = "pass", "fail", "insufficient-data"


@dataclass(frozen=True)
class ClaimVerdict:
    claim: str
    statement: str
    status: str                      # "pass" | "fail" | "insufficient-data"
    detail: dict = field(default_factory=dict)

    @property
    def passed(self) -> bool:
        return self.status == PASS

    def __str__(self) -> str:
        tag = {PASS: "PASS", FAIL: "FAIL", INSUFFICIENT: "N/A "}[self.status]
        return f"[{tag}] {self.claim}: {self.detail}"


# ------------------------------------------------------------- sufficiency
def _insufficiency(results: dict, sizes_needed) -> str | None:
    """Why these results cannot support a verdict over ``sizes_needed``
    (``None`` when they can)."""
    if not results:
        return "no combos loaded"
    for (bench, chip), (res, _) in results.items():
        present = {a for a, _ in res.cells}
        missing = [a for a in ALGOS if a not in present]
        if missing:
            return f"{bench}x{chip} is missing algorithms {missing}"
        have_sizes = set(res.sample_sizes())
        lost = [s for s in sizes_needed if s not in have_sizes]
        if lost:
            return f"{bench}x{chip} has no cells at sample sizes {lost}"
        # the full (algorithm x needed-size) grid, cell by cell — a ragged
        # matrix (one algorithm lacking one size) cannot support winner
        # statistics either
        for s in sizes_needed:
            for algo in ALGOS:
                cell = res.cells.get((algo, s))
                if cell is None:
                    return f"{bench}x{chip} has no {algo}/S={s} cell"
                if len(cell.final_values) < MIN_EXPERIMENTS:
                    return (
                        f"{bench}x{chip} {algo}/S={s} has only "
                        f"{len(cell.final_values)} experiments "
                        f"(< {MIN_EXPERIMENTS} needed for winner statistics)"
                    )
    return None


def _range_split(results: dict):
    """The small/large sample sizes actually observed (range claims need at
    least one on each side)."""
    sizes = sorted(
        {s for res, _ in results.values() for s in res.sample_sizes()}
    )
    return [s for s in sizes if s in SMALL], [s for s in sizes if s in LARGE]


def _winner_counts(winners: dict, sizes) -> dict:
    wins = {a: 0 for a in ALGOS}
    for s in sizes:
        for algo, n in winners.get(s, {}).items():
            wins[algo] += n
    return wins


# ------------------------------------------------------------------ checks
def check_claims(results: dict) -> dict[str, ClaimVerdict]:
    """Evaluate every paper claim against loaded results.

    ``results`` is the ``load_all`` dict; returns ``{claim_id:
    ClaimVerdict}`` in the paper's order.
    """
    small, large = _range_split(results)
    checks: dict[str, ClaimVerdict] = {}

    def winners():
        # computed lazily, only after a claim's sufficiency check passed —
        # ragged matrices must yield insufficient-data, never a crash here
        return winners_by_size(results)

    def verdict(cid, statement, sizes_needed, evaluate):
        reason = _insufficiency(results, sizes_needed)
        if reason is None and not sizes_needed:
            reason = "required sample-size range not observed"
        if reason is not None:
            checks[cid] = ClaimVerdict(cid, statement, INSUFFICIENT,
                                       {"reason": reason})
            return
        ok, detail = evaluate()
        checks[cid] = ClaimVerdict(cid, statement, PASS if ok else FAIL, detail)

    # C1 — BO best at small S -------------------------------------------------
    def c1():
        wins = _winner_counts(winners(), small)
        return max(wins, key=wins.get) in ("bo_gp", "bo_tpe"), wins

    verdict("C1_bo_wins_small_S",
            "BO-GP or BO-TPE is the best algorithm at S in 25-100",
            small, c1)

    # C2 — GA best at large S (per-cell winners; TPE tolerated as in the
    # paper's own 'TPE is a good balance' reading) ---------------------------
    def c2():
        wins = _winner_counts(winners(), large)
        best = max(wins, key=wins.get)
        return best in ("ga", "bo_tpe"), {"strict_ga": best == "ga", **wins}

    verdict("C2_ga_wins_large_S",
            "GA is the best algorithm at S in 200-400 (per-cell winners)",
            large, c2)

    # C2b — the Fig. 3 aggregate form ----------------------------------------
    def c2b():
        agg = fig3_aggregate(results)
        ga_best = all(
            agg["ga"][s][0]
            >= max(agg[a][s][0] for a in ALGOS if a != "ga") - 1e-9
            for s in large
            if s in agg["ga"]
        )
        detail = {
            a: {s: round(agg[a][s][0], 2) for s in large if s in agg[a]}
            for a in ALGOS
        }
        return bool(ga_best), detail

    verdict("C2b_ga_best_aggregate_large_S",
            "GA has the best aggregate mean pct-of-optimum at S in 200-400",
            large, c2b)

    # C3 — speedup over RS is larger at small S ------------------------------
    def c3():
        speed = fig4a_speedup(results)
        sp_small = np.mean(
            [speed[k][a][s] for k in speed for a in speed[k] for s in small]
        )
        sp_large = np.mean(
            [speed[k][a][s] for k in speed for a in speed[k] for s in large]
        )
        return bool(sp_small > sp_large), {
            "mean_speedup_small_S": float(sp_small),
            "mean_speedup_large_S": float(sp_large),
        }

    both_ranges = small + large if (small and large) else []
    verdict("C3_speedup_larger_at_small_S",
            "speedup over RS is largest in the low sample-size range",
            both_ranges, c3)

    # C4 — higher CLES (more consistent wins) at large S ---------------------
    def c4():
        cles = fig4b_cles(results)
        cl_small = np.mean(
            [cles[k][a][s] for k in cles for a in cles[k] for s in small]
        )
        cl_large = np.mean(
            [cles[k][a][s] for k in cles for a in cles[k] for s in large]
        )
        return bool(cl_large > cl_small), {
            "mean_cles_small": float(cl_small),
            "mean_cles_large": float(cl_large),
        }

    verdict("C4_more_consistent_at_large_S",
            "algorithms beat RS more consistently (higher CLES) at large S",
            both_ranges, c4)

    # C5 — RF is never the overall winner at S >= 100 ------------------------
    c5_sizes = [s for s in (100, *LARGE) if s in small + large]

    def c5():
        wins = _winner_counts(winners(), c5_sizes)
        return max(wins, key=wins.get) != "rf", wins

    verdict("C5_rf_not_overall_winner",
            "RF never outperforms all other algorithms at S >= 100",
            c5_sizes, c5)

    # C6 — BO-GP dips somewhere while RS is monotone -------------------------
    def c6():
        f2 = fig2_pct_optimum(results)
        dip = monotone_rs = 0
        for table in f2.values():
            sizes = sorted(table["bo_gp"])
            gp = [table["bo_gp"][s] for s in sizes]
            rs = [table["rs"][s] for s in sizes]
            if any(gp[i + 1] < gp[i] - 1e-9 for i in range(len(gp) - 1)):
                dip += 1
            if all(rs[i + 1] >= rs[i] - 0.5 for i in range(len(rs) - 1)):
                monotone_rs += 1
        return dip >= 1, {
            "combos_with_gp_dip": dip,
            "combos_rs_monotone": monotone_rs,
            "n_combos": len(f2),
        }

    # monotonicity needs the full size ladder, not just the range endpoints
    verdict("C6_bo_gp_nonmonotone_somewhere",
            "BO-GP shows a dip in 100-400 while RS improves monotonically",
            small + large if len(small + large) >= 4 else [], c6)

    return checks


def validate(results_dir: str) -> dict[str, ClaimVerdict]:
    """Load a results directory and evaluate every claim."""
    return check_claims(load_all(results_dir))


def main(argv=None) -> int:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("results_dir", nargs="?", default="results/paper_matrix")
    args = ap.parse_args(argv)
    checks = validate(args.results_dir)
    for v in checks.values():
        print(v)
    n_pass = sum(v.passed for v in checks.values())
    n_data = sum(v.status != INSUFFICIENT for v in checks.values())
    print(f"\n{n_pass}/{n_data} decidable paper claims reproduced "
          f"({len(checks) - n_data} insufficient-data)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
