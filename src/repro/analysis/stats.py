"""Comparison statistics over loaded matrix results.

One function per paper artifact (all consume the ``{(bench, chip):
(MatrixResults, meta)}`` dict from :func:`repro.analysis.load_all`):

* :func:`fig2_pct_optimum` — fraction-of-optimum per (algo x S) per combo
  (the true costmodel optimum when the record carries one, else relative to
  the best observed final — ``meta["optimum_is_true"]`` says which),
* :func:`fig3_aggregate` — aggregate mean + bootstrap CI across combos,
* :func:`fig4a_speedup` / :func:`speedup_with_ci` — median speedup over
  Random Search, point estimate and seeded-bootstrap CI over the repeats,
* :func:`fig4b_cles` — CLES (probability of beating RS),
* :func:`mwu_vs_rs` — the MWU significance companion (alpha = 0.01),
* :func:`rank_table` / :func:`mean_ranks` / :func:`winners_by_size` — the
  per-benchmark/per-architecture winner rankings the claims layer consumes,
* :func:`search_cost` — per-cell wall-clock from
  ``RunRecord.extra["cell_wall_s"]``, split into compile vs. measure
  seconds where the backend's staged pipeline recorded them.

The scalar machinery (MWU, CLES, percentile bootstrap) lives in
:mod:`repro.core.stats`; this module applies it across a results directory.
Budget-resolved curves build on the single budget-clipping convention
defined by :meth:`TuningResult.trajectory` (see :func:`best_at_budget`).
"""

from __future__ import annotations

import numpy as np

from ..core import stats as core_stats
from ..core.runner import stable_seed
from ..core.searchers.base import TuningResult
from .records import ALGOS


def best_at_budget(result: TuningResult, budget: int) -> float:
    """Best value a search had found after ``budget`` samples.

    Defers to ``TuningResult.trajectory(budget)`` — the ONE place the
    early-termination convention is defined (searches that ended early hold
    their final best; histories never exceed the budget).
    """
    return float(result.trajectory(budget)[budget - 1])


def budget_curve(result: TuningResult, budgets) -> np.ndarray:
    """Best-so-far at each requested budget (Schoonhoven-style
    budget-resolved performance curve for a single search)."""
    budgets = np.asarray(budgets, dtype=np.int64)
    full = result.trajectory(int(budgets.max()))
    return full[budgets - 1]


# ------------------------------------------------------------- paper tables
def _cell_sizes(res, algo: str) -> list[int]:
    """Sample sizes where this algorithm actually has a cell (matrices may
    be ragged — a combo can lack some (algo, S) cells; tables include only
    what exists instead of raising)."""
    return [s for s in res.sample_sizes() if (algo, s) in res.cells]


def fig2_pct_optimum(results: dict) -> dict:
    """{(bench, chip): {algo: {S: median pct-of-optimum}}}."""
    table = {}
    for key, (res, meta) in results.items():
        opt = meta["optimum"]
        table[key] = {
            algo: {
                s: float(
                    np.median(core_stats.pct_of_optimum(res.finals(algo, s), opt))
                )
                for s in _cell_sizes(res, algo)
            }
            for algo in ALGOS
            if _cell_sizes(res, algo)
        }
    return table


def fig3_aggregate(results: dict) -> dict:
    """{algo: {S: (mean, lo, hi)}} across all combos (bootstrap CI)."""
    f2 = fig2_pct_optimum(results)
    sample_sizes = sorted({s for t in f2.values() for a in t.values() for s in a})
    out = {}
    for algo in ALGOS:
        out[algo] = {}
        for s in sample_sizes:
            vals = np.array(
                [t[algo][s] for t in f2.values() if algo in t and s in t[algo]]
            )
            if len(vals):
                out[algo][s] = core_stats.bootstrap_ci(vals)
    return out


def fig4a_speedup(results: dict) -> dict:
    """{(bench, chip): {algo: {S: median speedup over RS}}}."""
    table = {}
    for key, (res, _) in results.items():
        table[key] = {}
        for algo in ALGOS:
            sizes = _vs_rs_sizes(res, algo)
            if not sizes:
                continue
            table[key][algo] = {
                s: core_stats.median_speedup(
                    res.finals("rs", s), res.finals(algo, s)
                )
                for s in sizes
            }
    return table


def _vs_rs_sizes(res, algo: str) -> list[int]:
    """Sizes where both the algorithm and the RS baseline have cells."""
    if algo == "rs":
        return []
    return [s for s in _cell_sizes(res, algo) if ("rs", s) in res.cells]


def speedup_with_ci(
    results: dict, n_boot: int = 2000, ci: float = 0.95, seed: int = 0
) -> dict:
    """{(bench, chip): {algo: {S: (speedup, lo, hi)}}} over Random Search.

    The point estimate is the paper's ``median(RS) / median(algo)``; the CI
    is a percentile bootstrap over the experiment repeats — both populations
    resampled independently per draw.  Each cell's draws come from a
    dedicated rng seeded by ``stable_seed(seed, bench, chip, algo, S)``, so
    the table is bit-stable regardless of dict iteration order, combo
    subsetting, or which executor produced the results.
    """
    lo_q, hi_q = (1 - ci) / 2 * 100, (1 + ci) / 2 * 100
    table = {}
    for (bench, chip), (res, _) in results.items():
        table[(bench, chip)] = {}
        for algo in ALGOS:
            sizes = _vs_rs_sizes(res, algo)
            if not sizes:
                continue
            row = {}
            for s in sizes:
                rs_v = np.asarray(res.finals("rs", s), dtype=np.float64)
                a_v = np.asarray(res.finals(algo, s), dtype=np.float64)
                rng = np.random.default_rng(
                    stable_seed(seed, bench, chip, algo, s)
                )
                rs_b = rs_v[rng.integers(0, len(rs_v), size=(n_boot, len(rs_v)))]
                a_b = a_v[rng.integers(0, len(a_v), size=(n_boot, len(a_v)))]
                boots = np.median(rs_b, axis=1) / np.median(a_b, axis=1)
                lo, hi = np.percentile(boots, [lo_q, hi_q])
                row[s] = (
                    core_stats.median_speedup(rs_v, a_v),
                    float(lo),
                    float(hi),
                )
            table[(bench, chip)][algo] = row
    return table


def fig4b_cles(results: dict) -> dict:
    """{(bench, chip): {algo: {S: P(algo beats RS)}}}."""
    table = {}
    for key, (res, _) in results.items():
        table[key] = {}
        for algo in ALGOS:
            sizes = _vs_rs_sizes(res, algo)
            if not sizes:
                continue
            table[key][algo] = {
                s: core_stats.cles_lower_better(
                    res.finals(algo, s), res.finals("rs", s)
                )
                for s in sizes
            }
    return table


def mwu_vs_rs(results: dict) -> dict:
    """{(bench, chip): {algo: {S: p-value}}} (alpha = 0.01 in the paper)."""
    table = {}
    for key, (res, _) in results.items():
        table[key] = {}
        for algo in ALGOS:
            sizes = _vs_rs_sizes(res, algo)
            if not sizes:
                continue
            table[key][algo] = {
                s: core_stats.mann_whitney_u(
                    res.finals(algo, s), res.finals("rs", s)
                ).p_value
                for s in sizes
            }
    return table


# --------------------------------------------------------- rankings/winners
def rank_table(results: dict) -> dict:
    """{(bench, chip): {algo: {S: rank}}} — 1 = best median final runtime.

    Ranks are computed among the algorithms present at each sample size
    (ragged matrices rank whatever exists there).
    """
    table = {}
    for key, (res, _) in results.items():
        t: dict = {}
        for s in res.sample_sizes():
            algos = [a for a in ALGOS if (a, s) in res.cells]
            medians = {a: float(np.median(res.finals(a, s))) for a in algos}
            # canonical-order tiebreak keeps ranks deterministic
            by_median = sorted(algos, key=lambda a: (medians[a], ALGOS.index(a)))
            for rank, a in enumerate(by_median, start=1):
                t.setdefault(a, {})[s] = rank
        table[key] = t
    return table


def mean_ranks(results: dict) -> dict:
    """{algo: {S: mean rank across combos}} — the rank-heatmap payload."""
    ranks = rank_table(results)
    out: dict = {}
    for t in ranks.values():
        for algo, row in t.items():
            for s, r in row.items():
                out.setdefault(algo, {}).setdefault(s, []).append(r)
    return {
        algo: {s: float(np.mean(v)) for s, v in sorted(rows.items())}
        for algo, rows in out.items()
    }


def winners_by_size(results: dict) -> dict:
    """{S: {algo: number of combos it wins at S}} (win = rank 1)."""
    ranks = rank_table(results)
    out: dict = {}
    for t in ranks.values():
        for algo, row in t.items():
            for s, r in row.items():
                out.setdefault(s, {}).setdefault(algo, 0)
                if r == 1:
                    out[s][algo] += 1
    return {s: dict(sorted(w.items())) for s, w in sorted(out.items())}


# ------------------------------------------------------------- search cost
def search_cost(results: dict) -> dict:
    """{(bench, chip): {algo: {S: {"wall", "compile", "measure"}}}} —
    per-cell search cost with the staged pipeline's breakdown.

    The work-unit layer records wall-clock per executed unit and the session
    aggregates it per cell into ``RunRecord.extra["cell_wall_s"]`` (sums of
    unit walls, so the number is total compute even for parallel runs).
    Staged backends (pallas) additionally charge each pipeline stage to a
    clock, so ``compile`` (validity screen + compilation) and ``measure``
    (fenced timing) split the wall per cell; unstaged backends report 0 for
    both.  Read alongside the quality tables: the paper's 'which algorithm
    at which sample size' question is really quality *per unit of search
    cost* — and a cell whose wall is mostly ``compile`` is bounded by the
    toolchain, not the tuner.  Combos recorded before the wall-clock landed
    are skipped; records from before the breakdown carry 0 for both splits.
    """
    table = {}
    for key, (_, meta) in results.items():
        rows = meta.get("cell_wall_s")
        if not rows:
            continue
        t: dict = {}
        for r in rows:
            t.setdefault(r["algo"], {})[r["sample_size"]] = {
                "wall": float(r["wall_s"]),
                "compile": float(r.get("compile_s", 0.0)),
                "measure": float(r.get("measure_s", 0.0)),
            }
        table[key] = t
    return table
