"""One-command report generation: a results directory in, ``REPORT.md`` out.

``python -m repro.analysis.report results/smoke_matrix`` (or
``benchmarks.paper_matrix --report``, or :func:`generate_report` from code)
renders everything the paper's analysis needs from the on-disk
``RunRecord`` + ``.npz`` artifacts alone:

* provenance (spec fingerprints, backend, record versions, wall-clock),
* the figures (``figures/*.png``, skipped gracefully without matplotlib),
* fraction-of-optimum, speedup-over-RS (with bootstrap CIs), CLES, MWU,
  rank/winner and search-cost tables,
* the claim verdicts (pass / fail / insufficient-data).

The markdown table renderers (:func:`render_grid` & friends) are public —
``benchmarks.run`` and ``EXPERIMENTS.md`` generation reuse them.
"""

from __future__ import annotations

import argparse
import json
import os

from ..core.runner import stable_seed
from .claims import INSUFFICIENT, check_claims
from .figures import HAVE_MATPLOTLIB, make_figures
from .records import load_all
from .stats import (
    fig2_pct_optimum,
    fig3_aggregate,
    fig4b_cles,
    mean_ranks,
    mwu_vs_rs,
    search_cost,
    speedup_with_ci,
    winners_by_size,
)

# ------------------------------------------------------------ table renderers


def render_fig2(table: dict) -> str:
    return render_grid(table, fmt="{:.1f}%", title="pct-of-optimum")


def render_grid(table: dict, fmt: str = "{:.3f}", title: str = "") -> str:
    """One markdown table per combo.  Combos with nothing to show (e.g. a
    speedup table for RS-only results) are skipped; ragged rows render
    ``-`` where an (algo, S) cell is absent."""
    lines = []
    for (bench, chip), algos in sorted(table.items()):
        if not algos:
            continue
        sizes = sorted({s for row in algos.values() for s in row})
        lines.append(f"\n### {title} — {bench} x {chip}")
        lines.append("| algo | " + " | ".join(f"S={s}" for s in sizes) + " |")
        lines.append("|---|" + "---|" * len(sizes))
        for algo, row in algos.items():
            cells = [fmt.format(row[s]) if s in row else "-" for s in sizes]
            lines.append(f"| {algo} | " + " | ".join(cells) + " |")
    return "\n".join(lines) if lines else "(no data)"


def render_fig3(agg: dict) -> str:
    sizes = sorted({s for rows in agg.values() for s in rows})
    lines = ["| algo | " + " | ".join(f"S={s}" for s in sizes) + " |",
             "|---|" + "---|" * len(sizes)]
    for algo, rows in agg.items():
        cells = []
        for s in sizes:
            if s in rows:
                m, lo, hi = rows[s]
                cells.append(f"{m:.1f}% [{lo:.1f}, {hi:.1f}]")
            else:
                cells.append("-")
        lines.append(f"| {algo} | " + " | ".join(cells) + " |")
    return "\n".join(lines)


def _render_speedup_ci(table: dict) -> str:
    return render_grid(
        table,
        fmt="{0[0]:.3f}x [{0[1]:.2f}, {0[2]:.2f}]",
        title="median speedup over RS (95% bootstrap CI)",
    )


def _render_ranks(results: dict) -> str:
    ranks = mean_ranks(results)
    if not ranks:
        return "(no data)"
    sizes = sorted({s for rows in ranks.values() for s in rows})
    winners = winners_by_size(results)
    lines = ["| algo | " + " | ".join(f"S={s}" for s in sizes) + " |",
             "|---|" + "---|" * len(sizes)]
    for algo, rows in ranks.items():
        cells = []
        for s in sizes:
            wins = winners.get(s, {}).get(algo, 0)
            cells.append(f"{rows[s]:.1f}" + (f" ({wins}W)" if wins else ""))
        lines.append(f"| {algo} | " + " | ".join(cells) + " |")
    lines.append("\nmean rank across combos, 1 = best; `(nW)` = combos won.")
    return "\n".join(lines)


def _telemetry_section(results: dict, results_dir: str) -> str:
    """Counter totals from each combo's RunRecord plus per-stage duration
    percentiles from the run's merged trace (when one exists).  Empty when
    every combo ran with telemetry disabled and no trace file is present."""
    lines: list[str] = []
    combo_counters = {
        key: meta["telemetry"].get("counters", {})
        for key, (_, meta) in sorted(results.items())
        if isinstance(meta.get("telemetry"), dict)
        and meta["telemetry"].get("counters")
    }
    if combo_counters:
        names = sorted({n for c in combo_counters.values() for n in c})
        lines += ["### Counter totals", "",
                  "| combo | " + " | ".join(names) + " |",
                  "|---|" + "---|" * len(names)]
        for (bench, chip), c in combo_counters.items():
            cells = [str(c.get(n, 0)) for n in names]
            lines.append(f"| {bench} x {chip} | " + " | ".join(cells) + " |")
    from ..telemetry import TRACE_FILE, read_run, stage_percentiles

    if os.path.exists(os.path.join(results_dir, TRACE_FILE)):
        stages = stage_percentiles(read_run(results_dir))
        if stages:
            if lines:
                lines.append("")
            lines += [
                "### Pipeline stage durations",
                "",
                "| stage | n | total (s) | p50 (ms) | p90 (ms) | p99 (ms) "
                "| max (ms) |",
                "|---|---|---|---|---|---|---|",
            ]
            for name, st in stages.items():
                lines.append(
                    f"| {name} | {st['count']} | {st['total_s']:.3f} | "
                    f"{st['p50'] * 1e3:.3f} | {st['p90'] * 1e3:.3f} | "
                    f"{st['p99'] * 1e3:.3f} | {st['max'] * 1e3:.3f} |"
                )
    return "\n".join(lines)


def _spec_fingerprint(spec: dict) -> str:
    """Stable 8-hex id of a recorded spec (storage fields and the
    pipeline_workers / compile_cache speed knobs excluded, matching the
    unit journal's namespace convention)."""
    d = {k: v for k, v in spec.items() if k not in ("store", "store_path")}
    if isinstance(d.get("backend_kwargs"), dict):
        d["backend_kwargs"] = {
            k: v for k, v in d["backend_kwargs"].items()
            if k not in ("pipeline_workers", "compile_cache")
        }
    try:
        return f"{stable_seed(json.dumps(d, sort_keys=True)):08x}"
    except (TypeError, ValueError):
        return "n/a"


def _provenance_section(results: dict) -> str:
    lines = [
        "| combo | backend | spec fingerprint | record v | created | "
        "wall (s) | search cost (s) |",
        "|---|---|---|---|---|---|---|",
    ]
    for (bench, chip), (_, meta) in sorted(results.items()):
        prov = meta.get("provenance", {})
        cell_walls = meta.get("cell_wall_s") or []
        cost = sum(w["wall_s"] for w in cell_walls)
        lines.append(
            f"| {bench} x {chip} | {meta.get('backend', '?')} "
            f"| `{_spec_fingerprint(meta.get('spec', {}))}` "
            f"| {meta.get('run_record_version', 'legacy')} "
            f"| {prov.get('created_at', '?')} "
            f"| {prov.get('wall_s', '?')} "
            f"| {cost:.1f} |"
        )
    bp = {
        k: meta["backend_provenance"]
        for k, (_, meta) in sorted(results.items())
        if meta.get("backend_provenance")
    }
    if bp:
        (bench, chip), one = next(iter(bp.items()))
        lines.append(
            f"\nBackend provenance ({bench} x {chip}): "
            f"`{json.dumps(one, sort_keys=True)}`"
        )
    return "\n".join(lines)


def _claims_section(results: dict) -> str:
    checks = check_claims(results)
    mark = {"pass": "✅ pass", "fail": "❌ fail",
            INSUFFICIENT: "⬜ insufficient-data"}
    lines = ["| claim | verdict | detail |", "|---|---|---|"]
    for v in checks.values():
        detail = json.dumps(v.detail, sort_keys=True)
        lines.append(f"| **{v.claim}** — {v.statement} | {mark[v.status]} "
                     f"| `{detail}` |")
    n_pass = sum(v.passed for v in checks.values())
    n_dec = sum(v.status != INSUFFICIENT for v in checks.values())
    lines.append(
        f"\n**{n_pass}/{n_dec} decidable claims reproduced"
        + (f"; {len(checks) - n_dec} need more data (see "
           "`repro.analysis.claims.MIN_EXPERIMENTS`)**"
           if len(checks) != n_dec else "**")
    )
    return "\n".join(lines)


# ---------------------------------------------------------------- generator


def generate_report(
    results_dir: str,
    out_path: str | None = None,
    fig_dir: str | None = None,
    n_boot: int = 2000,
    seed: int = 0,
) -> str:
    """Render ``REPORT.md`` (plus ``figures/``) from a results directory.

    Returns the report path.  ``out_path`` defaults to
    ``<results_dir>/REPORT.md`` and ``fig_dir`` to ``<results_dir>/figures``
    (figure links in the report are relative to the report's directory).
    """
    results = load_all(results_dir)
    out_path = out_path or os.path.join(results_dir, "REPORT.md")
    fig_dir = fig_dir or os.path.join(results_dir, "figures")
    # the bootstrap is the report's most expensive computation — run it once
    # and share it between the figure and the table
    speedup = speedup_with_ci(results, n_boot=n_boot, seed=seed)
    fig_paths = make_figures(results, fig_dir, n_boot=n_boot, seed=seed,
                             speedup_table=speedup)

    n_exp = sum(
        len(cell.final_values)
        for res, _ in results.values()
        for cell in res.cells.values()
    )
    parts = [
        "# Autotuning analysis report",
        "",
        "Reproduction artifacts for *Analyzing Search Techniques for "
        "Autotuning Image-based GPU Kernels: The Impact of Sample Sizes* "
        "(Tørring & Elster 2022), generated by `repro.analysis.report` "
        f"from `{results_dir}`: {len(results)} (benchmark × chip) combos, "
        f"{n_exp} tuning experiments.",
        "",
        "## Provenance",
        "",
        _provenance_section(results) if results else "(no combos found)",
        "",
    ]
    if fig_paths:
        out_dir = os.path.dirname(os.path.abspath(out_path))
        parts += ["## Figures", ""]
        for p in fig_paths:
            rel = os.path.relpath(os.path.abspath(p), out_dir)
            name = os.path.splitext(os.path.basename(p))[0]
            parts.append(f"![{name}]({rel})")
            parts.append("")
    elif not HAVE_MATPLOTLIB:
        parts += ["## Figures", "", "(matplotlib unavailable — tables only)",
                  ""]
    if results:
        opt_kinds = {meta["optimum_is_true"] for _, meta in results.values()}
        denom = (
            "the backend's noise-free true optimum"
            if opt_kinds == {True}
            else "the best observed final (no analytic optimum recorded)"
            if opt_kinds == {False}
            else "the true optimum where recorded, else the best observed final"
        )
        parts += [
            "## Quality vs sample size",
            "",
            f"Fraction-of-optimum denominators: {denom}.",
            "",
            "### Aggregate mean pct-of-optimum (95% bootstrap CI)",
            "",
            render_fig3(fig3_aggregate(results)),
            render_fig2(fig2_pct_optimum(results)),
            "",
            "## Speedup over Random Search",
            _render_speedup_ci(speedup),
            render_grid(fig4b_cles(results), "{:.2f}",
                        "CLES: P(algo beats RS)"),
            render_grid(mwu_vs_rs(results), "{:.2g}",
                        "MWU p-value vs RS (alpha = 0.01)"),
            "",
            "## Algorithm ranking",
            "",
            _render_ranks(results),
        ]
        cost = search_cost(results)
        if cost:
            # wall with the staged pipeline's compile/measure split; cells
            # from unstaged backends (or pre-breakdown records) show 0c+0m
            parts += [
                render_grid(
                    cost,
                    "{0[wall]:.2f}s ({0[compile]:.2f}c + {0[measure]:.2f}m)",
                    "search cost (wall = compile + measure)",
                )
            ]
        tel = _telemetry_section(results, results_dir)
        if tel:
            parts += ["", "## Telemetry", "", tel]
    parts += ["", "## Paper-claim verdicts", "", _claims_section(results), ""]

    os.makedirs(os.path.dirname(os.path.abspath(out_path)), exist_ok=True)
    with open(out_path, "w") as f:
        f.write("\n".join(parts))
    return out_path


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Render REPORT.md (tables + figures + claim verdicts) "
        "from a matrix results directory."
    )
    ap.add_argument("results_dir", help="e.g. results/smoke_matrix")
    ap.add_argument("--out", default=None,
                    help="report path (default <results_dir>/REPORT.md)")
    ap.add_argument("--fig-dir", default=None,
                    help="figure directory (default <results_dir>/figures)")
    ap.add_argument("--n-boot", type=int, default=2000,
                    help="bootstrap draws for the CI tables/bands")
    ap.add_argument("--seed", type=int, default=0,
                    help="bootstrap seed (CIs are deterministic per seed)")
    args = ap.parse_args(argv)
    path = generate_report(args.results_dir, out_path=args.out,
                           fig_dir=args.fig_dir, n_boot=args.n_boot,
                           seed=args.seed)
    print(f"[report] wrote {path}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
