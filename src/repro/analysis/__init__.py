"""repro.analysis — the paper's contribution, as a subsystem.

The paper's result is an *analysis*: speedup-over-RS per sample size,
per-benchmark/per-architecture winner rankings, and the claim that BO
GP/TPE win at 25–100 samples while GA wins at 200+.  This package consumes
versioned :class:`~repro.core.api.RunRecord` JSON (+ ``.npz`` result
arrays) from any results directory and reproduces those artifacts
end-to-end:

* :mod:`~repro.analysis.records` — loading + RunRecord normalization,
* :mod:`~repro.analysis.stats`   — comparison tables (fraction-of-optimum,
  speedup-over-RS with seeded bootstrap CIs, CLES/MWU, ranks/winners,
  search cost) and budget-resolved curves,
* :mod:`~repro.analysis.claims`  — the paper's claims as machine-checkable
  predicates with pass / fail / insufficient-data verdicts,
* :mod:`~repro.analysis.figures` — matplotlib reproductions (headless Agg),
* :mod:`~repro.analysis.report`  — ``REPORT.md`` generation
  (``python -m repro.analysis.report <results_dir>``).

See ``docs/analysis_and_report.md`` for the on-disk schema and usage.
"""

from . import claims, figures, records, report, stats
from .claims import ClaimVerdict, check_claims, validate
from .figures import HAVE_MATPLOTLIB, make_figures
from .records import ALGOS, load_all, normalize_meta, present_algorithms
from .report import generate_report
from .stats import best_at_budget, budget_curve, speedup_with_ci

__all__ = [
    "ALGOS",
    "ClaimVerdict",
    "HAVE_MATPLOTLIB",
    "best_at_budget",
    "budget_curve",
    "check_claims",
    "claims",
    "figures",
    "generate_report",
    "load_all",
    "make_figures",
    "normalize_meta",
    "present_algorithms",
    "records",
    "report",
    "speedup_with_ci",
    "stats",
    "validate",
]
