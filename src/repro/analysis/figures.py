"""Matplotlib reproductions of the paper's figures (headless Agg backend).

Three artifacts, all written as PNG by :func:`make_figures`:

* ``speedup_vs_sample_size.png`` — median speedup over RS vs sample size,
  one panel per (benchmark, chip) combo, bootstrap-CI bands (Fig. 4a),
* ``rank_heatmap.png`` — mean algorithm rank across combos per sample size,
* ``pct_of_optimum.png`` — aggregate fraction-of-optimum curve with CI
  bands (Fig. 3).

matplotlib is an optional dependency: importing this module without it
works (``HAVE_MATPLOTLIB`` is False) and ``make_figures`` returns ``[]`` so
the report generator degrades to tables-only.

Colors follow one fixed algorithm→hue assignment (a colorblind-validated
categorical palette; identity is never re-cycled per chart), and the rank
heatmap uses a single-hue light→dark sequential ramp — dark = rank 1.
"""

from __future__ import annotations

import os

import numpy as np

from .records import ALGOS
from .stats import fig3_aggregate, mean_ranks, speedup_with_ci

try:  # gate, don't require: report generation degrades to tables-only
    import matplotlib

    matplotlib.use("Agg")  # headless: must precede the pyplot import
    import matplotlib.pyplot as plt

    HAVE_MATPLOTLIB = True
except ImportError:  # pragma: no cover - exercised only without matplotlib
    HAVE_MATPLOTLIB = False

#: fixed algorithm -> color map (categorical slots of a CVD-validated
#: palette, assigned once in the paper's algorithm order — an algorithm
#: keeps its hue in every figure, whatever subset is plotted).
ALGO_COLORS = {
    "rs": "#2a78d6",      # blue
    "rf": "#eb6834",      # orange
    "ga": "#1baf7a",      # aqua
    "bo_gp": "#eda100",   # yellow
    "bo_tpe": "#e87ba4",  # magenta
}

#: light→dark steps of the blue ramp (sequential: magnitude only).
_BLUE_RAMP = ["#cde2fb", "#9ec5f4", "#6da7ec", "#3987e5", "#256abf", "#184f95"]

_INK = "#3d3d3a"          # neutral text/axis ink — series color never labels


def _style_axes(ax):
    ax.grid(True, axis="y", color="#e3e2d9", linewidth=0.6, zorder=0)
    for side in ("top", "right"):
        ax.spines[side].set_visible(False)
    for side in ("left", "bottom"):
        ax.spines[side].set_color("#c9c8bf")
    ax.tick_params(colors=_INK, labelsize=8)


def fig_speedup_vs_sample_size(
    results: dict, path: str, n_boot: int = 2000, seed: int = 0,
    table: dict | None = None,
) -> str | None:
    """Median speedup over RS vs sample size, one panel per combo, with
    percentile-bootstrap CI bands (the paper's Fig. 4a, budget-resolved).

    ``table`` accepts a precomputed :func:`speedup_with_ci` result (the
    report generator passes its own so the bootstrap runs once).  Returns
    ``None`` without writing when there is nothing to compare (results
    holding only the RS baseline)."""
    if table is None:
        table = speedup_with_ci(results, n_boot=n_boot, seed=seed)
    if not any(table.values()):
        return None
    keys = sorted(table)
    ncols = min(3, len(keys))
    nrows = int(np.ceil(len(keys) / ncols))
    fig, axes = plt.subplots(
        nrows, ncols, figsize=(3.6 * ncols, 2.8 * nrows),
        squeeze=False, sharey=True,
    )
    for ax in axes.flat[len(keys):]:
        ax.set_visible(False)
    for ax, key in zip(axes.flat, keys, strict=False):
        bench, chip = key
        for algo in ALGOS:
            if algo not in table[key]:
                continue
            rows = table[key][algo]
            sizes = sorted(rows)
            mid = [rows[s][0] for s in sizes]
            lo = [rows[s][1] for s in sizes]
            hi = [rows[s][2] for s in sizes]
            color = ALGO_COLORS.get(algo, _INK)
            ax.plot(sizes, mid, color=color, linewidth=2, marker="o",
                    markersize=4, label=algo, zorder=3)
            ax.fill_between(sizes, lo, hi, color=color, alpha=0.15,
                            linewidth=0, zorder=2)
        ax.axhline(1.0, color="#8a8a85", linewidth=1, linestyle="--", zorder=1)
        ax.set_xscale("log", base=2)
        sizes_all = sorted({s for a in table[key].values() for s in a})
        ax.set_xticks(sizes_all)
        ax.set_xticklabels([str(s) for s in sizes_all])
        ax.set_title(f"{bench} × {chip}", fontsize=9, color=_INK)
        _style_axes(ax)
    for ax in axes[-1]:
        ax.set_xlabel("sample size (budget)", fontsize=8, color=_INK)
    for row in axes:
        row[0].set_ylabel("speedup over RS", fontsize=8, color=_INK)
    by_label = {}
    for ax in axes.flat:
        handles, labels = ax.get_legend_handles_labels()
        by_label.update(zip(labels, handles, strict=True))
    if by_label:
        fig.legend(by_label.values(), by_label.keys(), loc="upper center",
                   ncol=len(by_label), frameon=False, fontsize=8,
                   bbox_to_anchor=(0.5, 1.02))
    fig.suptitle("Median speedup over Random Search (95% bootstrap CI)",
                 fontsize=10, color=_INK, y=1.07)
    fig.tight_layout()
    fig.savefig(path, dpi=150, bbox_inches="tight")
    plt.close(fig)
    return path


def fig_rank_heatmap(results: dict, path: str) -> str:
    """Mean algorithm rank (1 = best median runtime) across combos, per
    sample size — dark = better, annotated with the mean rank."""
    ranks = mean_ranks(results)
    algos = [a for a in ALGOS if a in ranks]
    sizes = sorted({s for rows in ranks.values() for s in rows})
    grid = np.array(
        [[ranks[a].get(s, np.nan) for s in sizes] for a in algos]
    )
    n_algos = max(2, len(algos))
    cmap = matplotlib.colors.LinearSegmentedColormap.from_list(
        "blues", list(reversed(_BLUE_RAMP))  # dark (rank 1) → light (worst)
    )
    fig, ax = plt.subplots(
        figsize=(1.1 * len(sizes) + 2.4, 0.55 * len(algos) + 1.4)
    )
    im = ax.imshow(grid, cmap=cmap, vmin=1, vmax=n_algos, aspect="auto")
    ax.set_xticks(range(len(sizes)), [f"S={s}" for s in sizes], fontsize=8)
    ax.set_yticks(range(len(algos)), algos, fontsize=8)
    ax.tick_params(colors=_INK, length=0)
    for spine in ax.spines.values():
        spine.set_visible(False)
    mid = 1 + (n_algos - 1) / 2
    for i in range(len(algos)):
        for j in range(len(sizes)):
            v = grid[i, j]
            if np.isnan(v):
                continue
            ax.text(j, i, f"{v:.1f}", ha="center", va="center", fontsize=8,
                    color="#ffffff" if v < mid else _INK)
    cbar = fig.colorbar(im, ax=ax, shrink=0.85)
    cbar.set_label("mean rank (1 = best)", fontsize=8, color=_INK)
    cbar.ax.tick_params(colors=_INK, labelsize=7)
    ax.set_title("Mean algorithm rank across benchmark × chip combos",
                 fontsize=10, color=_INK)
    fig.tight_layout()
    fig.savefig(path, dpi=150, bbox_inches="tight")
    plt.close(fig)
    return path


def fig_pct_optimum(results: dict, path: str) -> str:
    """Aggregate mean fraction-of-optimum vs sample size with bootstrap CI
    bands (the paper's Fig. 3)."""
    agg = fig3_aggregate(results)
    fig, ax = plt.subplots(figsize=(5.2, 3.4))
    for algo in ALGOS:
        rows = agg.get(algo)
        if not rows:
            continue
        sizes = sorted(rows)
        mid = [rows[s][0] for s in sizes]
        lo = [rows[s][1] for s in sizes]
        hi = [rows[s][2] for s in sizes]
        color = ALGO_COLORS.get(algo, _INK)
        ax.plot(sizes, mid, color=color, linewidth=2, marker="o",
                markersize=4, label=algo, zorder=3)
        ax.fill_between(sizes, lo, hi, color=color, alpha=0.15,
                        linewidth=0, zorder=2)
    ax.set_xscale("log", base=2)
    sizes_all = sorted({s for rows in agg.values() for s in rows})
    ax.set_xticks(sizes_all)
    ax.set_xticklabels([str(s) for s in sizes_all])
    ax.set_xlabel("sample size (budget)", fontsize=8, color=_INK)
    ax.set_ylabel("% of optimum (mean across combos)", fontsize=8, color=_INK)
    ax.legend(frameon=False, fontsize=8, loc="lower right")
    ax.set_title("Tuned-runtime quality vs sample size (95% bootstrap CI)",
                 fontsize=10, color=_INK)
    _style_axes(ax)
    fig.tight_layout()
    fig.savefig(path, dpi=150, bbox_inches="tight")
    plt.close(fig)
    return path


def make_figures(results: dict, fig_dir: str, n_boot: int = 2000,
                 seed: int = 0, speedup_table: dict | None = None) -> list[str]:
    """Render every figure into ``fig_dir``; returns the written paths
    (empty — with no error — when matplotlib is unavailable or there is
    nothing to plot; figures without data, e.g. the speedup panel on
    RS-only results, are skipped individually)."""
    if not HAVE_MATPLOTLIB or not results:
        return []
    os.makedirs(fig_dir, exist_ok=True)
    paths = [
        fig_speedup_vs_sample_size(
            results, os.path.join(fig_dir, "speedup_vs_sample_size.png"),
            n_boot=n_boot, seed=seed, table=speedup_table,
        ),
        fig_rank_heatmap(results, os.path.join(fig_dir, "rank_heatmap.png")),
        fig_pct_optimum(results, os.path.join(fig_dir, "pct_of_optimum.png")),
    ]
    return [p for p in paths if p is not None]
