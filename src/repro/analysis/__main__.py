"""``python -m repro.analysis <results_dir>`` — alias for the report CLI."""

from .report import main

raise SystemExit(main())
