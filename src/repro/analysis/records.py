"""Loading matrix results + versioned :class:`RunRecord` metadata from disk.

A results directory (see ``docs/analysis_and_report.md`` for the full layout)
holds one ``<bench>_<chip>.npz`` / ``<bench>_<chip>.json`` pair per
(benchmark, chip) combo, plus measurement caches (``*_cache.*``), datasets
(``*_dataset_*.npz``) and report artifacts (``figures/``, ``REPORT.md``) the
loader skips.  The JSON side is a versioned RunRecord (the ``tune_matrix``
facade's output); the legacy flat meta dict written before the record
existed is still accepted.
"""

from __future__ import annotations

import json
import os

from ..core import MatrixResults

#: the paper's five algorithms, in its fixed presentation order.  Every
#: table, figure, and color assignment downstream uses THIS order — never a
#: per-call ordering — so an algorithm keeps its identity across artifacts.
ALGOS = ("rs", "rf", "ga", "bo_gp", "bo_tpe")


def normalize_meta(meta: dict) -> dict:
    """Accept both a versioned RunRecord dict and the legacy flat meta dict;
    always expose:

    * ``meta["optimum"]`` — the pct-of-optimum denominator: the backend's
      noise-free true optimum when recorded, else the best observed final,
    * ``meta["optimum_is_true"]`` — which of the two it was,
    * ``meta["spec"]`` / ``meta["provenance"]`` — empty dicts for legacy
      records,
    * ``meta["backend"]`` — which measurement produced the numbers
      ("costmodel" analytical vs "pallas" real execution; the
      ``backend_provenance`` extra carries the detail when recorded).
    """
    if "run_record_version" not in meta:
        out = dict(meta)
        out.setdefault("optimum_is_true", "optimum" in meta)
        out.setdefault("spec", {})
        out.setdefault("provenance", {})
        out.setdefault("backend", "costmodel")
        return out
    result = dict(meta.get("result", {}))
    flat = {**meta.get("extra", {}), **result}
    flat["optimum"] = result.get("true_optimum", result.get("best_observed"))
    flat["optimum_is_true"] = "true_optimum" in result
    flat["spec"] = meta.get("spec", {})
    flat["provenance"] = meta.get("provenance", {})
    flat["run_record_version"] = meta["run_record_version"]
    flat["backend"] = flat["spec"].get("backend", "costmodel")
    return flat


def load_all(results_dir: str) -> dict:
    """``{(bench, chip): (MatrixResults, meta)}`` for every stored combo.

    ``meta`` is the :func:`normalize_meta` flat view of the combo's
    RunRecord.  Raises ``FileNotFoundError`` when the directory does not
    exist; returns ``{}`` when it holds no result pairs.
    """
    out = {}
    for fname in sorted(os.listdir(results_dir)):
        if not fname.endswith(".npz") or "_dataset_" in fname:
            continue
        bench, chip = fname[:-4].rsplit("_", 1)
        res = MatrixResults.load(os.path.join(results_dir, fname))
        with open(os.path.join(results_dir, f"{bench}_{chip}.json")) as f:
            meta = normalize_meta(json.load(f))
        out[(bench, chip)] = (res, meta)
    return out


def present_algorithms(results: dict) -> list[str]:
    """Algorithms present in every loaded combo, in the canonical order."""
    present = None
    for res, _ in results.values():
        algos = {a for a, _ in res.cells}
        present = algos if present is None else (present & algos)
    present = present or set()
    return [a for a in ALGOS if a in present] + sorted(
        a for a in present if a not in ALGOS
    )
