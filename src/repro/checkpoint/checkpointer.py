"""Checkpointing: atomic, resumable, optionally asynchronous.

Layout:  <dir>/step_<n>/  with one .npy per tree leaf (path-encoded
filenames) + manifest.json (step, leaf paths, tree structure hash).  Writes
go to a temp directory first and are renamed into place, so a failure
mid-save never corrupts the latest checkpoint (restart-safety on flaky
clusters — DESIGN.md section 5).

``AsyncCheckpointer`` snapshots to host memory synchronously (cheap) and
writes on a background thread, overlapping I/O with the next training
steps; ``wait()`` joins before the next save or at shutdown.

On multi-host clusters each host would write only its addressable shards;
this container is single-host, so the full array path is exercised and the
shard path is documented.
"""

from __future__ import annotations

import json
import os
import shutil
import threading

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        out[key] = leaf
    return out


def save(ckpt_dir: str, step: int, state) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    leaves = _flatten_with_paths(state)
    manifest = {"step": step, "leaves": sorted(leaves)}
    for key, leaf in leaves.items():
        fname = key.replace("/", "__") + ".npy"
        np.save(os.path.join(tmp, fname), np.asarray(leaf))
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [
        int(d.split("_")[1])
        for d in os.listdir(ckpt_dir)
        if d.startswith("step_") and not d.endswith(".tmp")
    ]
    return max(steps) if steps else None


def restore(ckpt_dir: str, state_like, step: int | None = None):
    """Restore into the structure of ``state_like``; returns (state, step)."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    leaves = {}
    for key in manifest["leaves"]:
        leaves[key] = np.load(os.path.join(d, key.replace("/", "__") + ".npy"))
    ref = _flatten_with_paths(state_like)
    missing = set(ref) - set(leaves)
    if missing:
        raise ValueError(f"checkpoint missing leaves: {sorted(missing)[:5]} ...")
    flat, treedef = jax.tree_util.tree_flatten_with_path(state_like)
    vals = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        arr = leaves[key]
        vals.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, vals), manifest["step"]


class AsyncCheckpointer:
    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: threading.Thread | None = None

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save(self, step: int, state) -> None:
        self.wait()
        snapshot = jax.tree_util.tree_map(lambda x: np.asarray(x), state)

        def work():
            save(self.ckpt_dir, step, snapshot)
            self._gc()

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def _gc(self) -> None:
        steps = sorted(
            int(d.split("_")[1])
            for d in os.listdir(self.ckpt_dir)
            if d.startswith("step_") and not d.endswith(".tmp")
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.ckpt_dir, f"step_{s:08d}"))
