"""Production meshes.

``make_production_mesh`` is a FUNCTION (never a module-level constant) so
importing this module never touches jax device state.  Single pod:
(data=16, model=16) = 256 chips.  Multi-pod: (pod=2, data=16, model=16) =
512 chips; the "pod" axis only ever carries batch parallelism, so its
collectives are the per-step gradient all-reduce — the right shape for
cross-pod DCI links.
"""

from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)} — "
            "the dry-run entrypoint must set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
            "any jax import"
        )
    dev = np.asarray(devices[:n]).reshape(shape)
    return Mesh(dev, axes)


def make_host_mesh(model_parallel: int = 1) -> Mesh:
    """Small mesh over whatever devices exist (tests / examples)."""
    devices = np.asarray(jax.devices())
    n = len(devices)
    mp = max(1, min(model_parallel, n))
    data = n // mp
    return Mesh(devices[: data * mp].reshape(data, mp), ("data", "model"))
