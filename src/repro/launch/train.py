"""Production training launcher: ``--arch <id>`` selects any assigned
architecture (full or --reduced), builds the mesh-aware train step, and
runs under the fault-tolerant runtime (checkpoints, crash-resume,
straggler watchdog).

On this CPU container use --reduced; on a TPU slice the same entrypoint
builds the (data, model) mesh over the real devices and shards state via
the logical-axis rules.

    PYTHONPATH=src python -m repro.launch.train --arch mamba2-130m \\
        --reduced --steps 50 --batch 4 --seq 128
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import REGISTRY
from repro.data import DataConfig, make_train_batch
from repro.launch.mesh import make_host_mesh
from repro.models import abstract_params, build_model, init_params, param_axes, param_count
from repro.optim import AdamWConfig
from repro.runtime import RunnerConfig, TrainingRunner
from repro.sharding.rules import ShardingRules
from repro.train import TrainSettings, init_train_state, make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(REGISTRY))
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--remat", default="none", choices=("none", "dots", "full"))
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    cfg = REGISTRY[args.arch]
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    print(f"[train] arch={cfg.name} reduced={args.reduced} "
          f"params={param_count(model.spec())/1e6:.1f}M devices={len(jax.devices())}")

    mesh = make_host_mesh(model_parallel=args.model_parallel)
    rules = ShardingRules()
    spec = model.spec()
    p_shard = rules.tree_shardings(param_axes(spec), abstract_params(spec), mesh)

    with mesh:
        params = init_params(spec, jax.random.PRNGKey(0))
        state = init_train_state(model, params)
        settings = TrainSettings(
            remat=args.remat, accum=args.accum,
            optimizer=AdamWConfig(lr=args.lr, warmup_steps=min(20, args.steps)),
        )
        step_fn = jax.jit(make_train_step(model, settings, grad_shardings=p_shard))
        dc = DataConfig(seed=0)
        make_batch = lambda s: make_train_batch(dc, cfg, args.seq, args.batch, s)

        ckpt_dir = args.ckpt or f"/tmp/repro_{cfg.name}_ckpt"
        runner = TrainingRunner(
            RunnerConfig(ckpt_dir=ckpt_dir, ckpt_every=args.ckpt_every),
            step_fn, make_batch,
        )
        t0 = time.time()
        state, report = runner.run(state, n_steps=args.steps)
        dt = time.time() - t0

    tok = report.steps_run * args.batch * args.seq
    print(f"[train] {report.steps_run} steps in {dt:.0f}s "
          f"({tok/max(dt,1e-9):.0f} tok/s), resumed_from={report.restored_from}")
    if report.losses:
        k = max(1, len(report.losses) // 10)
        print(f"[train] loss {np.mean(report.losses[:k]):.3f} -> "
              f"{np.mean(report.losses[-k:]):.3f}")


if __name__ == "__main__":
    main()
