"""ShapeDtypeStruct stand-ins for every (arch x shape) dry-run cell.

``input_specs`` returns abstract inputs for the step function selected by
the shape kind (train / prefill / decode); nothing is ever allocated.
Modality frontends are stubs per the task spec: whisper gets precomputed
frame embeddings, chameleon gets VQ token ids (ordinary vocab entries).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig, ShapeCfg
from ..models import abstract_params
from ..models.layers import COMPUTE_DTYPE


def sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def train_batch_specs(arch: ArchConfig, shape: ShapeCfg) -> dict:
    b, s = shape.global_batch, shape.seq_len
    if arch.family == "encdec":
        return {
            "src_embeds": sds((b, s, arch.d_model), jnp.float32),
            "dec_tokens": sds((b, arch.encdec.dec_len), jnp.int32),
            "dec_labels": sds((b, arch.encdec.dec_len), jnp.int32),
        }
    return {
        "tokens": sds((b, s), jnp.int32),
        "labels": sds((b, s), jnp.int32),
    }


def prefill_batch_specs(arch: ArchConfig, shape: ShapeCfg) -> dict:
    b, s = shape.global_batch, shape.seq_len
    if arch.family == "encdec":
        return {
            "src_embeds": sds((b, s, arch.d_model), jnp.float32),
            "dec_tokens": sds((b, arch.encdec.dec_len), jnp.int32),
        }
    return {"tokens": sds((b, s), jnp.int32)}


def decode_specs(arch: ArchConfig, shape: ShapeCfg, model) -> dict:
    """Abstract (cache, cache_len, tokens) for one decode step with a KV
    cache of shape.seq_len tokens."""
    b, s = shape.global_batch, shape.seq_len
    if arch.family == "encdec":
        enc_out = sds((b, s, arch.d_model), COMPUTE_DTYPE)
        cache = jax.eval_shape(
            lambda p, e: model.init_cache(p, e, b),
            abstract_params(model.spec()),
            enc_out,
        )
    else:
        cache = jax.eval_shape(lambda: model.init_cache(b, s))
    return {
        "cache": cache,
        "cache_len": sds((b,), jnp.int32),
        "tokens": sds((b, 1), jnp.int32),
    }
