import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this builds the jitted step (train_step for train shapes,
prefill/decode for serving shapes) with in/out shardings derived from the
logical-axis rules, runs ``.lower(...)`` on ShapeDtypeStructs (no
allocation), ``.compile()``s it, and records:

  * memory_analysis()      — per-device bytes (proves it fits),
  * cost_analysis()        — HLO FLOPs / bytes accessed,
  * collective bytes       — parsed from the post-SPMD optimized HLO
                             (all-gather / all-reduce / reduce-scatter /
                              all-to-all / collective-permute operand sizes),

into results/dryrun/<arch>_<shape>_<mesh>.json for EXPERIMENTS.md and the
roofline layer (launch/roofline.py).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun                     # all cells
    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-34b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --mesh single       # one mesh
"""

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import REGISTRY, SHAPES, applicable_shapes
from repro.configs.base import ArchConfig, ShapeCfg
from repro.launch.hlo_analysis import collective_stats, dot_flops
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import decode_specs, prefill_batch_specs, train_batch_specs
from repro.models import abstract_params, build_model, param_axes, param_count
from repro.sharding.rules import ShardingRules
from repro.train.step import (
    TrainSettings,
    make_decode_step,
    make_prefill_step,
    make_train_step,
)

RESULTS_DIR = os.path.join("results", "dryrun")



def build_step(arch: ArchConfig, shape: ShapeCfg, mesh, rules: ShardingRules,
               settings: TrainSettings | None = None):
    """Returns (jitted_fn, abstract_args tuple)."""
    data_shards = int(np.prod([mesh.shape[a] for a in ("pod", "data")
                               if a in mesh.axis_names]))
    moe_groups = data_shards if shape.global_batch % data_shards == 0 else 1
    model = build_model(arch, moe_groups=moe_groups)
    spec = model.spec()
    aparams = abstract_params(spec)
    axes = param_axes(spec)
    p_shard = rules.tree_shardings(axes, aparams, mesh)

    def bshard(v):  # batch-leading arrays, divisibility-aware (B=1 long_500k)
        return rules.sharding_for(
            ("batch",) + (None,) * (v.ndim - 1), v.shape, mesh
        )

    if shape.kind == "train":
        settings = settings or TrainSettings(remat="dots", accum=1)
        step = make_train_step(model, settings, grad_shardings=p_shard)
        astate = {
            "params": aparams,
            "opt": {
                "m": jax.tree_util.tree_map(
                    lambda a: jax.ShapeDtypeStruct(a.shape, jnp.float32), aparams),
                "v": jax.tree_util.tree_map(
                    lambda a: jax.ShapeDtypeStruct(a.shape, jnp.float32), aparams),
                "step": jax.ShapeDtypeStruct((), jnp.int32),
            },
        }
        s_shard = {
            "params": p_shard,
            "opt": {
                "m": p_shard,
                "v": p_shard,
                "step": jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec()),
            },
        }
        abatch = train_batch_specs(arch, shape)
        b_shard = {k: bshard(v) for k, v in abatch.items()}
        fn = jax.jit(step, in_shardings=(s_shard, b_shard),
                     out_shardings=(s_shard, None))
        return fn, (astate, abatch)

    if shape.kind == "prefill":
        step = make_prefill_step(model)
        abatch = prefill_batch_specs(arch, shape)
        b_shard = {k: bshard(v) for k, v in abatch.items()}
        fn = jax.jit(step, in_shardings=(p_shard, b_shard), out_shardings=None)
        return fn, (aparams, abatch)

    # decode
    step = make_decode_step(model)
    dspecs = decode_specs(arch, shape, model)
    c_axes = model.cache_axes()
    c_shard = rules.tree_shardings(c_axes, dspecs["cache"], mesh)
    len_shard = bshard(dspecs["cache_len"])
    tok_shard = bshard(dspecs["tokens"])
    fn = jax.jit(step, in_shardings=(p_shard, c_shard, len_shard, tok_shard),
                 out_shardings=(None, c_shard))
    return fn, (aparams, dspecs["cache"], dspecs["cache_len"], dspecs["tokens"])


def run_cell(arch_name: str, shape_name: str, multi_pod: bool,
             rules: ShardingRules | None = None, save: bool = True,
             settings: TrainSettings | None = None) -> dict:
    arch = REGISTRY[arch_name]
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = rules or ShardingRules()
    mesh_name = "multipod" if multi_pod else "single"
    t0 = time.time()
    with mesh:
        fn, args = build_step(arch, shape, mesh, rules, settings=settings)
        lowered = fn.lower(*args)
        compiled = lowered.compile()
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0] if cost else {}
        hlo = compiled.as_text()
    coll = collective_stats(hlo)
    dots = dot_flops(hlo)
    n_dev = int(np.prod(list(mesh.shape.values())))
    record = {
        "arch": arch_name,
        "shape": shape_name,
        "mesh": mesh_name,
        "n_devices": n_dev,
        "params": param_count(build_model(arch).spec()),
        "flops_total": float(cost.get("flops", 0.0)),
        "flops_dot_corrected": dots["flops"],
        "flops_dot_uncorrected": dots["flops_uncorrected"],
        "bytes_total": float(cost.get("bytes accessed", 0.0)),
        "collectives": coll,
        "memory": {
            # argument/output/peak are PER-DEVICE on this backend;
            # temp_size is module-global (divide by n_devices)
            "argument_bytes_per_dev": getattr(mem, "argument_size_in_bytes", 0),
            "output_bytes_per_dev": getattr(mem, "output_size_in_bytes", 0),
            "temp_bytes_module": getattr(mem, "temp_size_in_bytes", 0),
            "peak_bytes_per_dev": getattr(mem, "peak_memory_in_bytes", 0),
        },
        "wall_s": time.time() - t0,
    }
    if save:
        os.makedirs(RESULTS_DIR, exist_ok=True)
        path = os.path.join(RESULTS_DIR, f"{arch_name}_{shape_name}_{mesh_name}.json")
        with open(path, "w") as f:
            json.dump(record, f, indent=2)
    print(f"[dryrun] {arch_name:20s} {shape_name:12s} {mesh_name:8s} "
          f"flops={record['flops_total']:.3e} bytes={record['bytes_total']:.3e} "
          f"coll={coll['total_bytes']:.3e} "
          f"peak/dev={record['memory']['peak_bytes_per_dev']/2**30:.2f}GiB "
          f"args/dev={record['memory']['argument_bytes_per_dev']/2**30:.2f}GiB "
          f"({record['wall_s']:.0f}s)")
    return record


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=("single", "multipod", "both"), default="both")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list(REGISTRY)
    meshes = {"single": [False], "multipod": [True], "both": [False, True]}[args.mesh]
    failures = []
    for arch_name in archs:
        shapes = (
            [args.shape] if args.shape else applicable_shapes(REGISTRY[arch_name])
        )
        for shape_name in shapes:
            for mp in meshes:
                mesh_name = "multipod" if mp else "single"
                path = os.path.join(
                    RESULTS_DIR, f"{arch_name}_{shape_name}_{mesh_name}.json"
                )
                if os.path.exists(path) and not args.force:
                    print(f"[dryrun] skip existing {path}")
                    continue
                try:
                    run_cell(arch_name, shape_name, mp)
                except Exception as e:  # noqa: BLE001 — record and continue
                    failures.append((arch_name, shape_name, mesh_name, repr(e)))
                    print(f"[dryrun] FAIL {arch_name} {shape_name} {mesh_name}: {e}")
                    traceback.print_exc()
    if failures:
        print(f"\n[dryrun] {len(failures)} FAILURES:")
        for f in failures:
            print("   ", *f[:3], f[3][:200])
        raise SystemExit(1)
    print("\n[dryrun] ALL CELLS PASSED")


if __name__ == "__main__":
    main()
