"""Roofline analysis from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch x shape) cell on the single-pod mesh, three terms in seconds:

    compute    = FLOPs / (chips * 197e12)         [bf16 MXU peak, v5e]
    memory     = HBM bytes / (chips * 819e9)
    collective = per-device collective bytes / 50e9  [~1 ICI link serial]

Sources:
  * FLOPs: the loop-corrected dot-FLOP count parsed from the post-SPMD HLO
    (repro.launch.hlo_analysis.dot_flops) — XLA's cost_analysis counts scan
    bodies once and is reported alongside for reference.  These are
    per-device; global = x chips.
  * HBM bytes: analytic traffic model (documented below) — XLA's
    'bytes accessed' has the same while-body undercount AND counts fusion
    internals, so an explicit model is both more transparent and closer to
    real HBM traffic.
  * collective bytes: loop-corrected per-device result-shape sum from the
    HLO (hlo_analysis.collective_stats).

MODEL_FLOPS = 6*N*D for training (N = matmul-visible params, D = tokens),
2*N*D for prefill, 2*N*B per decode step (+ attention cache terms) — the
'useful' FLOPs.  The ratio MODEL_FLOPS / HLO_FLOPs exposes remat recompute
(~4/3 for gradient checkpointing) and any redundancy.

Memory-traffic model (per device, per step):
  train:   (2+2+2) * N_bytes_bf16 / chips        fwd + remat + bwd weight reads
           + 16 * N * 4 / chips                   AdamW fp32 m,v,p read+write
           + A * activation_bytes / chips         residual-stream traffic
  prefill: 2 * N / chips * bf16  + activations
  decode:  (2 * N * bf16 + cache_bytes) / chips   weights + full KV cache read
"""

from __future__ import annotations

import argparse
import json
import os
from dataclasses import dataclass

from repro.configs import REGISTRY, SHAPES, applicable_shapes
from repro.configs.base import ArchConfig, ShapeCfg
from repro.models import build_model, param_count

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
RESULTS_DIR = os.path.join("results", "dryrun")


# --------------------------------------------------------------- analytics
def matmul_params(arch: ArchConfig) -> tuple[int, int]:
    """(total matmul-visible params, active matmul params per token)."""
    total = param_count(build_model(arch).spec())
    # embedding table is a gather (no flops); head matmul counts (tied or not)
    embed = arch.vocab * arch.d_model
    total_matmul = total - embed if not arch.tie_embeddings else total
    if arch.moe is None:
        return total_matmul, total_matmul
    m = arch.moe
    expert_p = 3 * arch.d_model * m.d_ff_expert
    routed_total = arch.n_layers * m.n_experts * expert_p
    routed_active = arch.n_layers * m.top_k * expert_p
    return total_matmul, total_matmul - routed_total + routed_active


def attention_flops_per_token(arch: ArchConfig, s: int) -> float:
    """2 * (scores + pv) per token with causal 1/2 factor."""
    if arch.family == "ssm":
        return 0.0
    if arch.mla:
        e = arch.mla.d_nope + arch.mla.d_rope + arch.mla.d_v
    else:
        e = 2 * arch.head_dim
    if arch.family == "hybrid":
        L = arch.n_layers // arch.shared_attn_every  # shared-attn insertions
    elif arch.family == "encdec":
        L = arch.encdec.n_enc_layers
    else:
        L = arch.n_layers
    return 2.0 * L * arch.n_heads * e * (s / 2.0)


def model_flops(arch: ArchConfig, shape: ShapeCfg) -> float:
    """Global 'useful' FLOPs for one step (MODEL_FLOPS)."""
    _, n_active = matmul_params(arch)
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        tokens = b * (s if arch.family != "encdec" else s + arch.encdec.dec_len)
        return 6.0 * n_active * tokens + 3.0 * b * s * attention_flops_per_token(arch, s)
    if shape.kind == "prefill":
        tokens = b * s
        return 2.0 * n_active * tokens + b * s * attention_flops_per_token(arch, s)
    # decode: one token per sequence, attends to the full cache
    return 2.0 * n_active * b + b * 2.0 * attention_flops_per_token(arch, s)


def cache_bytes(arch: ArchConfig, shape: ShapeCfg) -> float:
    """KV/state cache bytes read per decode step (global)."""
    b, s = shape.global_batch, shape.seq_len
    if arch.family == "ssm":
        d = arch.ssm
        di = d.expand * arch.d_model
        return b * arch.n_layers * (di // d.head_dim) * d.head_dim * d.d_state * 4
    if arch.family == "hybrid":
        d = arch.ssm
        di = d.expand * arch.d_model
        ssm = b * arch.n_layers * (di // d.head_dim) * d.head_dim * d.d_state * 4
        n_apps = arch.n_layers // arch.shared_attn_every
        attn = b * n_apps * s * arch.n_kv_heads * arch.head_dim * 2 * 2
        return ssm + attn
    if arch.mla:
        return b * arch.n_layers * s * (arch.mla.kv_lora + arch.mla.d_rope) * 2
    if arch.family == "encdec":
        ed = arch.encdec
        self_c = b * ed.n_dec_layers * ed.dec_len * arch.n_heads * arch.head_dim * 2 * 2
        cross_c = b * ed.n_dec_layers * s * arch.n_heads * arch.head_dim * 2 * 2
        return self_c + cross_c
    return b * arch.n_layers * s * arch.n_kv_heads * arch.head_dim * 2 * 2


def memory_bytes(arch: ArchConfig, shape: ShapeCfg) -> float:
    """Global HBM traffic estimate for one step (documented in module doc)."""
    n_total, _ = matmul_params(arch)
    b, s = shape.global_batch, shape.seq_len
    d = arch.d_model
    if shape.kind == "train":
        weights = 6 * n_total            # bf16 reads: fwd + remat + bwd
        opt = 16 * n_total               # fp32 p,m,v read + p,m,v write
        act_layers = arch.n_layers
        acts = 12 * b * s * d * act_layers  # residual stream r/w, bf16, few ops
        return weights + opt + acts
    if shape.kind == "prefill":
        return 2 * n_total + 8 * b * s * d * arch.n_layers
    return 2 * n_total + cache_bytes(arch, shape) + 4 * b * d * arch.n_layers


# --------------------------------------------------------------- terms
@dataclass
class RooflineRow:
    arch: str
    shape: str
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float
    hlo_flops: float
    useful_ratio: float
    peak_gib: float
    dominant: str

    @property
    def step_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """useful-compute time / modelled step time (MFU-style score)."""
        ideal = self.model_flops / (256 * PEAK_FLOPS)
        return ideal / self.step_s if self.step_s else 0.0


def load_record(arch: str, shape: str, mesh: str = "single") -> dict:
    path = os.path.join(RESULTS_DIR, f"{arch}_{shape}_{mesh}.json")
    with open(path) as f:
        return json.load(f)


def roofline_row(arch_name: str, shape_name: str, record: dict | None = None) -> RooflineRow:
    arch = REGISTRY[arch_name]
    shape = SHAPES[shape_name]
    rec = record or load_record(arch_name, shape_name)
    chips = rec["n_devices"]
    mf = model_flops(arch, shape)
    # per-device HLO dot flops -> global
    hlo_flops = rec.get("flops_dot_corrected", 0.0) * chips
    compute_s = hlo_flops / (chips * PEAK_FLOPS)
    memory_s = memory_bytes(arch, shape) / (chips * HBM_BW)
    collective_s = rec["collectives"]["total_bytes"] / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    dominant = max(terms, key=terms.get)
    return RooflineRow(
        arch=arch_name,
        shape=shape_name,
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        model_flops=mf,
        hlo_flops=hlo_flops,
        useful_ratio=mf / hlo_flops if hlo_flops else 0.0,
        peak_gib=rec["memory"]["peak_bytes_per_dev"] / 2**30,
        dominant=dominant,
    )


def all_rows() -> list[RooflineRow]:
    rows = []
    for arch_name, arch in REGISTRY.items():
        for shape_name in applicable_shapes(arch):
            try:
                rows.append(roofline_row(arch_name, shape_name))
            except FileNotFoundError:
                pass
    return rows


def markdown_table(rows: list[RooflineRow]) -> str:
    hdr = (
        "| arch | shape | compute (s) | memory (s) | collective (s) | dominant | "
        "MODEL_FLOPS | HLO_FLOPs | useful | roofline frac | peak GiB/dev |\n"
        "|---|---|---|---|---|---|---|---|---|---|---|\n"
    )
    body = "".join(
        f"| {r.arch} | {r.shape} | {r.compute_s:.4g} | {r.memory_s:.4g} | "
        f"{r.collective_s:.4g} | **{r.dominant}** | {r.model_flops:.3e} | "
        f"{r.hlo_flops:.3e} | {r.useful_ratio:.2f} | {r.roofline_fraction:.3f} | "
        f"{r.peak_gib:.2f} |\n"
        for r in rows
    )
    return hdr + body


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=os.path.join("results", "roofline.md"))
    args = ap.parse_args()
    rows = all_rows()
    md = markdown_table(rows)
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        f.write(md)
    print(md)
    if rows:
        worst = min(rows, key=lambda r: r.roofline_fraction)
        coll = max(rows, key=lambda r: r.collective_s / max(r.step_s, 1e-12))
        print(f"\nworst roofline fraction : {worst.arch} {worst.shape} "
              f"({worst.roofline_fraction:.3f})")
        print(f"most collective-bound   : {coll.arch} {coll.shape} "
              f"(coll {coll.collective_s:.4g}s vs step {coll.step_s:.4g}s)")


if __name__ == "__main__":
    main()
