"""Post-SPMD HLO analysis: loop-aware collective accounting.

XLA's HloCostAnalysis (and a naive text scan) counts each computation ONCE
— but lax.scan lowers to a `while` whose body executes trip-count times, so
per-layer collectives (the FSDP all-gathers, TP reduce-scatters, MoE
all-to-alls) would be undercounted by a factor of n_layers.  This module
parses the optimized HLO text into its computations, recovers the while
call graph with trip counts (from the loop-condition `constant(N)`), and
multiplies each computation's collective bytes by the product of trip
counts on its call chain.

Shapes in the post-SPMD module are per-participant, so totals are
per-device bytes (global = per-device x n_devices).
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(
    r"\b(f64|s64|u64|f32|s32|u32|bf16|f16|s8|u8|pred|f8e4m3fn|f8e5m2)\[([0-9,]*)\]"
)
_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1,
}
_COMP_START = re.compile(r"^(ENTRY\s+)?%([\w.\-]+)")
_WHILE_RE = re.compile(r"while\(.*?\).*?condition=%?([\w.\-]+).*?body=%?([\w.\-]+)")
_CONST_RE = re.compile(r"s(?:32|64)\[\]\s+constant\((\d+)\)")
_CALL_RE = re.compile(r"(?:call|fusion)\(.*?\).*?(?:to_apply|calls)=%?([\w.\-]+)")


def shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _BYTES[dt]
    return total


@dataclass
class Computation:
    name: str
    lines: list = field(default_factory=list)
    entry: bool = False


def parse_computations(hlo_text: str) -> dict[str, Computation]:
    """Computation headers are column-0 lines `[ENTRY] %name (...) ... {`;
    bodies are indented; a computation ends at a bare `}` line.  (Brace
    *counting* is unusable: HLO layouts `{1,0}` and metadata={...} put
    braces on instruction lines.)"""
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for raw in hlo_text.splitlines():
        if cur is None:
            if raw and not raw[0].isspace() and raw.rstrip().endswith("{"):
                m = _COMP_START.match(raw)
                if m:
                    cur = Computation(m.group(2), entry=bool(m.group(1)))
            continue
        if raw.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        cur.lines.append(raw.strip())
    if cur is not None:
        comps[cur.name] = cur
    return comps


def _trip_count(cond: Computation) -> int:
    consts = [int(c) for line in cond.lines for c in _CONST_RE.findall(line)]
    return max(consts) if consts else 1


def computation_multipliers(comps: dict[str, Computation]) -> dict[str, float]:
    """Execution-count multiplier per computation (product of enclosing
    while trip counts), via DFS from the entry computation."""
    entry = next((c for c in comps.values() if c.entry), None)
    if entry is None:
        return {name: 1.0 for name in comps}
    mult: dict[str, float] = {}

    def visit(name: str, m: float) -> None:
        if name not in comps:
            return
        mult[name] = max(mult.get(name, 0.0), m)
        for line in comps[name].lines:
            wm = _WHILE_RE.search(line)
            if wm:
                cond_name, body_name = wm.group(1), wm.group(2)
                trips = _trip_count(comps.get(cond_name, Computation(cond_name)))
                visit(cond_name, m * (trips + 1))
                visit(body_name, m * trips)
                continue
            cm = _CALL_RE.search(line)
            if cm:
                visit(cm.group(1), m)

    visit(entry.name, 1.0)
    # computations never reached (dead or referenced by fusions only): x1
    for name in comps:
        mult.setdefault(name, 1.0)
    return mult


# Operands of `dot(...)` come in two HLO dialects: bare names
# `dot(%lhs, %rhs)` (pre-optimization text) and typed operands
# `dot(f32[128,256]{1,0} %lhs, ...)` (optimized/compiled text).  The inline
# type, when present, is captured so the lhs shape needs no symbol lookup.
_DOT_CALL_RE = re.compile(
    r"(\(.*?\)|\S+)\s+dot\(\s*"
    r"(?:(\w+\[[0-9,]*\](?:\{[0-9,*:a-zA-Z()]*\})?)\s+)?"
    r"%?([\w.\-]+)\s*[,)]"
)
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_DEF_RE = re.compile(r"^(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(.*?\)|\S+)\s+[\w\-]+")


def _first_shape_dims(type_str: str) -> list[int]:
    m = _SHAPE_RE.search(type_str)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",")]


def dot_flops(hlo_text: str) -> dict:
    """Loop-corrected FLOPs of every `dot` op (text-level, per-device).

    flops(dot) = 2 * prod(output dims) * prod(lhs contracting dim sizes) —
    the standard matmul count; XLA's HloCostAnalysis uses the same formula
    but counts while bodies once (no trip-count scaling), which undercounts
    scan-over-layers models by ~n_layers x.  Elementwise/reduce flops are
    excluded (an order of magnitude below the dots for these models)."""
    comps = parse_computations(hlo_text)
    mult = computation_multipliers(comps)
    total = 0.0
    raw = 0.0
    for comp in comps.values():
        m = mult.get(comp.name, 1.0)
        shapes: dict[str, str] = {}
        for s in comp.lines:
            dm = _DEF_RE.match(s)
            if dm:
                shapes[dm.group(1)] = dm.group(2)
        for s in comp.lines:
            if " dot(" not in s:
                continue
            body = s[5:] if s.startswith("ROOT ") else s
            if " = " not in body:
                continue
            name, rhs = body.split(" = ", 1)
            om = _DOT_CALL_RE.match(rhs)
            if not om:
                continue
            out_shape, lhs_type, lhs_name = om.groups()
            out_elems = 1
            for d in _first_shape_dims(out_shape):
                out_elems *= d
            lhs_dims = _first_shape_dims(lhs_type or shapes.get(lhs_name, ""))
            cm = _LHS_CONTRACT_RE.search(s)
            contract = 1
            if cm and cm.group(1) and lhs_dims:
                for ix in cm.group(1).split(","):
                    i = int(ix)
                    if i < len(lhs_dims):
                        contract *= lhs_dims[i]
            f = 2.0 * out_elems * contract
            total += f * m
            raw += f
    return {"flops": total, "flops_uncorrected": raw}


def collective_stats(hlo_text: str) -> dict:
    """Loop-corrected per-device collective bytes + op counts by kind."""
    comps = parse_computations(hlo_text)
    mult = computation_multipliers(comps)
    bytes_by_kind = {k: 0.0 for k in _COLLECTIVES}
    counts = {k: 0.0 for k in _COLLECTIVES}
    raw_bytes = {k: 0.0 for k in _COLLECTIVES}
    for comp in comps.values():
        m = mult.get(comp.name, 1.0)
        for s in comp.lines:
            if s.startswith("ROOT "):
                s = s[5:]
            if " = " not in s:
                continue
            rhs = s.split(" = ", 1)[1]
            om = re.match(r"(\(.*?\)|\S+)\s+([\w\-]+)\(", rhs)
            if not om:
                continue
            shape_str, op = om.groups()
            if op.endswith("-done"):
                continue
            base = op[: -len("-start")] if op.endswith("-start") else op
            if base in _COLLECTIVES:
                b = shape_bytes(shape_str)
                bytes_by_kind[base] += b * m
                raw_bytes[base] += b
                counts[base] += m
    return {
        "bytes": bytes_by_kind,
        "bytes_uncorrected": raw_bytes,
        "counts": counts,
        "total_bytes": sum(bytes_by_kind.values()),
        "total_bytes_uncorrected": sum(raw_bytes.values()),
    }
