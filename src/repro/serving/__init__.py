"""Tuning-as-a-service: serve tuned winners, enqueue what's missing.

The paper's result — which search algorithm wins depends on the sample
budget — only pays off in production if tuned configurations are *served*
rather than rediscovered per process tree.  This package layers three
pieces over the measurement store:

* :mod:`repro.serving.winners` — a per-``(kernel, x, y, device)`` best-config
  index living in the store itself (a ``winners`` table in the sqlite
  backend, a ``"winners"`` mapping in the JSON format), maintained
  transactionally as :class:`~repro.core.api.TuningSession` records results.
* :mod:`repro.serving.api` — the query layer: :func:`best_config` answers
  instantly on an exact-geometry hit, falls back to the nearest geometry,
  and on a miss optionally enqueues an async tuning job.  ``repro.serve``
  re-exports it as the stable entry point.
* :mod:`repro.serving.queue` / :mod:`repro.serving.fleet` — a shared-store
  work queue with the same ``O_EXCL`` claim + stale-claim-steal discipline
  as the persistent compile cache, so fleet workers on any host can claim
  :class:`~repro.core.workunits.ExperimentUnit` jobs, crash, and be resumed
  by peers.

``python -m repro.serving`` exposes the whole flow (HTTP endpoint, query,
enqueue, worker, collect) on the command line; see ``docs/serving.md``.
"""

from .api import ServeResult, best_config, default_miss_spec, open_serve_store
from .fleet import FleetWorker, collect_jobs
from .queue import JobQueue, job_id_for_spec
from .winners import (
    WinnerRecord,
    all_winners,
    index_winners,
    lookup_winner,
    nearest_winner,
    record_session_winner,
    record_winner,
    spec_geometry,
)

__all__ = [
    "FleetWorker",
    "JobQueue",
    "ServeResult",
    "WinnerRecord",
    "all_winners",
    "best_config",
    "collect_jobs",
    "default_miss_spec",
    "index_winners",
    "job_id_for_spec",
    "lookup_winner",
    "nearest_winner",
    "open_serve_store",
    "record_session_winner",
    "record_winner",
    "spec_geometry",
]
