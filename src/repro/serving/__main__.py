"""Command-line surface for tuning-as-a-service.

A *serve dir* is one directory holding the serving store (``store.sqlite``
by default — WAL-mode sqlite, safe for concurrent readers) and the fleet
claim dir (``queue/``)::

    python -m repro.serving index  --dir serve results/matrix/*_cache.json
    python -m repro.serving query  --dir serve --kernel add --x 8192 \\
        --y 8192 --device v5e --expect hit --max-ms 10
    python -m repro.serving enqueue --dir serve --kernel harris --x 8192 \\
        --y 8192 --device v5e
    python -m repro.serving worker --dir serve --max-jobs 1 --telemetry
    python -m repro.serving collect --dir serve
    python -m repro.serving serve  --dir serve --port 8777

``query`` prints the :class:`ServeResult` JSON (plus ``serve_ms``, the
wall-clock of the lookup against a cold store handle); ``--expect STATUS``
and ``--max-ms N`` turn it into an assertion for CI.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time


def _store_path(args) -> str:
    ext = "sqlite" if args.store == "sqlite" else "json"
    return os.path.join(args.dir, f"store.{ext}")


def _qdir(args) -> str:
    return os.path.join(args.dir, "queue")


def _open(args):
    from .api import open_serve_store

    os.makedirs(args.dir, exist_ok=True)
    return open_serve_store(_store_path(args), args.store)


def _telemetry(args, src: str):
    if not getattr(args, "telemetry", False):
        return None
    from ..telemetry.tracer import Telemetry

    return Telemetry(
        getattr(args, "trace", None) or os.path.join(args.dir, "trace.jsonl"),
        src=src,
    )


def cmd_index(args) -> int:
    from .api import store_kind_for_path
    from .winners import index_winners

    store, kind = _open(args)
    from ..core.stores import make_store

    total = 0
    for src_path in args.sources:
        src = make_store(store_kind_for_path(src_path), src_path)
        n = index_winners(store, src, save=False)
        if hasattr(src, "close"):
            src.close()
        print(f"[serving] indexed {n} winner(s) from {src_path}")
        total += n
    store.save()
    if hasattr(store, "close"):
        store.close()
    print(f"[serving] winners index <- {total} record(s) ({kind})")
    return 0


def cmd_query(args) -> int:
    from .api import best_config, open_serve_store
    from .queue import JobQueue

    tel = _telemetry(args, src="serve-query")
    t0 = time.perf_counter()
    # a COLD query: open the store handle and resolve, end to end
    store, kind = open_serve_store(_store_path(args), args.store)
    queue = None
    if args.enqueue:
        queue = JobQueue(store, kind, _store_path(args), _qdir(args),
                         telemetry=tel)
    res = best_config(store, args.kernel, args.x, args.y, args.device,
                      max_age_s=args.max_age_s, queue=queue, telemetry=tel)
    ms = (time.perf_counter() - t0) * 1e3
    if hasattr(store, "close"):
        store.close()
    if tel is not None:
        tel.close()
    out = res.to_dict()
    out["serve_ms"] = round(ms, 3)
    print(json.dumps(out, sort_keys=True))
    if args.expect is not None and res.status != args.expect:
        print(f"[serving] FAIL: expected status {args.expect!r}, "
              f"got {res.status!r}", file=sys.stderr)
        return 2
    if args.max_ms is not None and ms > args.max_ms:
        print(f"[serving] FAIL: query took {ms:.3f} ms "
              f"(limit {args.max_ms} ms)", file=sys.stderr)
        return 3
    return 0


def cmd_enqueue(args) -> int:
    from .api import default_miss_spec
    from .queue import JobQueue

    store, kind = _open(args)
    queue = JobQueue(store, kind, _store_path(args), _qdir(args))
    spec = default_miss_spec(args.kernel, args.x, args.y, args.device)
    jid = queue.enqueue(spec)
    if hasattr(store, "close"):
        store.close()
    print(jid)
    return 0


def cmd_jobs(args) -> int:
    from .queue import JobQueue

    store, kind = _open(args)
    queue = JobQueue(store, kind, _store_path(args), _qdir(args))
    for job in queue.jobs():
        print(json.dumps({"id": job["id"], "state": job.get("state"),
                          "kernel": job["spec"].get("kernel")},
                         sort_keys=True))
    if hasattr(store, "close"):
        store.close()
    return 0


def cmd_worker(args) -> int:
    from .fleet import FleetWorker

    tel = _telemetry(args, src=f"fleet-{args.ident or 'worker'}")
    worker = FleetWorker(
        args.store, _store_path(args), _qdir(args),
        ident=args.ident, claim_timeout_s=args.claim_timeout_s,
        poll_s=args.poll_s, stall_s=args.stall_s, telemetry=tel,
    )
    n = worker.drain(max_jobs=args.max_jobs, timeout_s=args.timeout_s)
    if tel is not None:
        tel.close()
    print(f"[serving] worker {worker.ident}: {n} job(s) completed")
    return 0


def cmd_collect(args) -> int:
    from .fleet import collect_jobs

    tel = _telemetry(args, src="serve-collect")
    done = collect_jobs(args.store, _store_path(args), _qdir(args),
                        telemetry=tel)
    if tel is not None:
        tel.close()
    print(f"[serving] collected {len(done)} job(s): {', '.join(done) or '-'}")
    return 0


def cmd_replay(args) -> int:
    """Serially re-run a job's spec into a fresh store — the byte-identity
    reference for the fleet's merged store (compare with
    ``tools/compare_stores.py``)."""
    from ..core.api import TuningSession, TuningSpec
    from .api import store_kind_for_path
    from .queue import JobQueue

    store, kind = _open(args)
    queue = JobQueue(store, kind, _store_path(args), _qdir(args))
    job = queue.job(args.job)
    if hasattr(store, "close"):
        store.close()
    if job is None:
        print(f"[serving] no job {args.job!r}", file=sys.stderr)
        return 1
    spec = TuningSpec.from_dict(job["spec"]).replace(
        store=store_kind_for_path(args.out), store_path=args.out,
    )
    TuningSession(spec).run_matrix()
    print(f"[serving] replayed job {args.job} -> {args.out}")
    return 0


def cmd_serve(args) -> int:
    from .http import ServingState, make_server
    from .queue import JobQueue

    tel = _telemetry(args, src="serve-http")
    store, kind = _open(args)
    queue = JobQueue(store, kind, _store_path(args), _qdir(args),
                     telemetry=tel)
    state = ServingState(store, queue=queue, telemetry=tel)
    server = make_server(state, host=args.host, port=args.port)
    host, port = server.server_address[:2]
    print(f"[serving] http://{host}:{port} over {_store_path(args)} ({kind})")
    try:
        server.serve_forever()
    except KeyboardInterrupt:
        pass
    finally:
        server.server_close()
        if hasattr(store, "close"):
            store.close()
        if tel is not None:
            tel.close()
    return 0


def _add_dir(p: argparse.ArgumentParser) -> None:
    p.add_argument("--dir", required=True, help="serve dir (store + queue/)")
    p.add_argument("--store", choices=("sqlite", "json"), default="sqlite",
                   help="serving store backend (sqlite: WAL-mode, safe for "
                        "concurrent readers — the default)")


def _add_geometry(p: argparse.ArgumentParser) -> None:
    p.add_argument("--kernel", required=True)
    p.add_argument("--x", type=int, required=True)
    p.add_argument("--y", type=int, required=True)
    p.add_argument("--device", required=True,
                   help="chip model name (costmodel) or device kind (pallas)")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.serving")
    sub = ap.add_subparsers(dest="cmd", required=True)

    p = sub.add_parser("index", help="fold winners from tuned stores into "
                                     "the serving store")
    _add_dir(p)
    p.add_argument("sources", nargs="+", help="tuned combo store files")
    p.set_defaults(fn=cmd_index)

    p = sub.add_parser("query", help="resolve best_config once (CI-friendly: "
                                     "--expect / --max-ms assert)")
    _add_dir(p)
    _add_geometry(p)
    p.add_argument("--max-age-s", type=float, default=None)
    p.add_argument("--enqueue", action="store_true",
                   help="on miss, enqueue a tuning job for the geometry")
    p.add_argument("--expect",
                   choices=("hit", "stale", "nearest", "miss"), default=None)
    p.add_argument("--max-ms", type=float, default=None)
    p.add_argument("--telemetry", action="store_true")
    p.add_argument("--trace", default=None)
    p.set_defaults(fn=cmd_query)

    p = sub.add_parser("enqueue", help="queue a tuning job for a geometry")
    _add_dir(p)
    _add_geometry(p)
    p.set_defaults(fn=cmd_enqueue)

    p = sub.add_parser("jobs", help="list queued jobs")
    _add_dir(p)
    p.set_defaults(fn=cmd_jobs)

    p = sub.add_parser("worker", help="run a fleet worker until the queue "
                                      "drains (or --max-jobs / --timeout-s)")
    _add_dir(p)
    p.add_argument("--ident", default=None)
    p.add_argument("--max-jobs", type=int, default=None)
    p.add_argument("--timeout-s", type=float, default=None)
    p.add_argument("--claim-timeout-s", type=float, default=60.0)
    p.add_argument("--poll-s", type=float, default=0.05)
    p.add_argument("--stall-s", type=float, default=0.0,
                   help="test seam: sleep after each claim before running "
                        "(the chaos tests' kill window)")
    p.add_argument("--telemetry", action="store_true")
    p.add_argument("--trace", default=None)
    p.set_defaults(fn=cmd_worker)

    p = sub.add_parser("collect", help="absorb finished workers' shards, "
                                       "refresh winners, mark jobs done")
    _add_dir(p)
    p.add_argument("--telemetry", action="store_true")
    p.add_argument("--trace", default=None)
    p.set_defaults(fn=cmd_collect)

    p = sub.add_parser("replay", help="serially re-run a job into --out (the "
                                      "byte-identity reference store)")
    _add_dir(p)
    p.add_argument("--job", required=True)
    p.add_argument("--out", required=True)
    p.set_defaults(fn=cmd_replay)

    p = sub.add_parser("serve", help="stdlib JSON endpoint over best_config")
    _add_dir(p)
    p.add_argument("--host", default="127.0.0.1")
    p.add_argument("--port", type=int, default=8777)
    p.add_argument("--telemetry", action="store_true")
    p.add_argument("--trace", default=None)
    p.set_defaults(fn=cmd_serve)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    raise SystemExit(main())
