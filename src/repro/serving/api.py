"""The serving query layer: ``best_config`` and friends.

Resolution order for ``(kernel, x, y, device)``:

1. **hit** — an exact-geometry winner exists and is younger than
   ``max_age_s`` (one keyed store read; the hot path).
2. **stale** — an exact-geometry winner exists but is older than
   ``max_age_s``; the config is still returned (stale beats nothing) with
   the status making the age explicit.
3. **nearest** — no exact winner, but the same kernel+device has one at a
   different geometry; the closest in log-space answers.
4. **miss** — nothing to serve.  With a queue attached, a tuning job for
   the missing geometry is enqueued (idempotently) so a fleet worker can
   fill the hole; the returned ``job_id`` tracks it.

Every outcome bumps a serving counter (``serve.hits`` / ``serve.stale`` /
``serve.nearest`` / ``serve.misses`` / ``serve.enqueued``) and the
``serve.queue_depth`` gauge on the attached telemetry — observability only.
"""

from __future__ import annotations

from dataclasses import asdict, dataclass

from ..core.api import TuningSpec
from ..core.stores import make_store
from ..telemetry.null import NULL_TELEMETRY
from .queue import JobQueue
from .winners import lookup_winner, nearest_winner, now_stamp


def store_kind_for_path(path: str) -> str:
    """Store kind from a path's extension (``.sqlite`` -> sqlite, else json)."""
    return "sqlite" if str(path).endswith(".sqlite") else "json"


def open_serve_store(path: str, kind: str | None = None):
    """Open a measurement store for serving; returns ``(store, kind)``."""
    kind = kind or store_kind_for_path(path)
    return make_store(kind, path), kind


@dataclass(frozen=True)
class ServeResult:
    """What a ``best_config`` query resolved to."""

    status: str                 # "hit" | "stale" | "nearest" | "miss"
    kernel: str
    x: int
    y: int
    device: str
    config: dict | None = None
    value: float | None = None
    fresh: float | None = None
    age_s: float | None = None
    fingerprint: str | None = None
    matched_key: str | None = None   # the winner key that answered (if any)
    job_id: str | None = None        # the job a miss enqueued (if any)

    def to_dict(self) -> dict:
        return asdict(self)


def default_miss_spec(kernel: str, x: int, y: int, device: str, *,
                      algorithms=("rs", "ga"), design=None,
                      seed: int = 0) -> TuningSpec:
    """The tuning job a miss enqueues: a smoke-design run of the missing
    problem.  A device naming a costmodel chip tunes through the analytical
    model at the kernel's workload geometry; anything else is a real pallas
    run at the requested ``(x, y)``."""
    from ..core import ExperimentDesign
    from ..costmodel import CHIPS

    if design is None:
        design = ExperimentDesign.smoke()
    if device in CHIPS:
        return TuningSpec(
            kernel=kernel, backend="costmodel",
            backend_kwargs={"chip": device},
            algorithms=tuple(algorithms), design=design, seed=seed,
            cache_key=f"{kernel}/{device}",
        )
    return TuningSpec(
        kernel=kernel, backend="pallas",
        backend_kwargs={"x": int(x), "y": int(y)},
        algorithms=tuple(algorithms), design=design, seed=seed,
    )


def best_config(store, kernel: str, x: int, y: int, device: str, *,
                max_age_s: float | None = None, queue: JobQueue | None = None,
                enqueue_spec: TuningSpec | None = None, telemetry=None,
                now: float | None = None) -> ServeResult:
    """Answer "give me the best config for ``(kernel, x, y, device)``".

    ``store`` is a live store handle (see :func:`open_serve_store`).
    ``max_age_s`` turns exact hits older than that into ``"stale"``.
    ``queue`` (a :class:`JobQueue`) arms enqueue-on-miss; ``enqueue_spec``
    overrides the default smoke-design job.  ``now`` pins the clock for
    age math (tests).
    """
    tel = telemetry if telemetry is not None else NULL_TELEMETRY
    x, y = int(x), int(y)
    rec = lookup_winner(store, kernel, x, y, device)
    if rec is not None:
        t = now if now is not None else now_stamp()
        age = max(0.0, t - rec.fresh)
        stale = max_age_s is not None and age > float(max_age_s)
        tel.inc("serve.stale" if stale else "serve.hits")
        return ServeResult(
            status="stale" if stale else "hit",
            kernel=kernel, x=x, y=y, device=device,
            config=rec.config, value=rec.value, fresh=rec.fresh, age_s=age,
            fingerprint=rec.fingerprint, matched_key=rec.key,
        )
    near = nearest_winner(store, kernel, x, y, device)
    if near is not None:
        tel.inc("serve.nearest")
        return ServeResult(
            status="nearest",
            kernel=kernel, x=x, y=y, device=device,
            config=near.config, value=near.value, fresh=near.fresh,
            fingerprint=near.fingerprint, matched_key=near.key,
        )
    tel.inc("serve.misses")
    job_id = None
    if queue is not None:
        spec = enqueue_spec if enqueue_spec is not None else default_miss_spec(
            kernel, x, y, device
        )
        job_id = queue.enqueue(spec)
    if queue is not None:
        tel.gauge("serve.queue_depth", queue.depth())
    return ServeResult(
        status="miss", kernel=kernel, x=x, y=y, device=device, job_id=job_id,
    )
