"""The winners index: per-geometry best configs living in the store.

Schema — one record per geometry key::

    <kernel>|x=<x>|y=<y>|<device>  ->  {"config": {...}, "value": <seconds>,
                                        "fingerprint": "<spec digest>",
                                        "fresh": <unix stamp>,
                                        "source": "<cache_key>",
                                        "store_key": "<measurement key>"}

The record rides the store's winners side-channel (``winners`` table in
sqlite, ``"winners"`` mapping in JSON format 3) and is written by
:func:`record_session_winner` right after a :class:`TuningSession` saves
its measurements — same store, same save, so a winner never points at
measurements the store doesn't hold.  Concurrent writers and shard merges
resolve through :func:`repro.core.stores.merge_winner_payloads`: the lower
value wins and the freshness stamp never moves backwards.

Freshness is a wall-clock stamp (serving liveness policy, never part of any
measured value — this module is outside the determinism-critical core).
"""

from __future__ import annotations

import json
import math
import time
from dataclasses import dataclass, replace

from ..core.stores import merge_winner_payloads


def now_stamp() -> float:
    """Wall-clock freshness stamp (seconds since the epoch)."""
    return time.time()


# ------------------------------------------------------------------ records


@dataclass(frozen=True)
class WinnerRecord:
    """One served winner: the best known config for a geometry."""

    kernel: str
    x: int
    y: int
    device: str
    config: dict
    value: float
    fingerprint: str = ""
    fresh: float = 0.0
    source: str = ""
    store_key: str = ""

    @property
    def key(self) -> str:
        return winner_key(self.kernel, self.x, self.y, self.device)

    def to_payload(self) -> str:
        return json.dumps(
            {
                "config": self.config,
                "value": float(self.value),
                "fingerprint": self.fingerprint,
                "fresh": float(self.fresh),
                "source": self.source,
                "store_key": self.store_key,
            },
            sort_keys=True,
        )

    @classmethod
    def from_payload(cls, key: str, payload: str) -> "WinnerRecord | None":
        parsed = parse_winner_key(key)
        if parsed is None:
            return None
        kernel, x, y, device = parsed
        try:
            d = json.loads(payload)
        except ValueError:
            return None
        if not isinstance(d, dict) or not isinstance(d.get("config"), dict):
            return None
        try:
            value = float(d.get("value"))
            fresh = float(d.get("fresh", 0.0))
        except (TypeError, ValueError):
            return None
        return cls(
            kernel=kernel,
            x=x,
            y=y,
            device=device,
            config=d["config"],
            value=value,
            fingerprint=str(d.get("fingerprint", "")),
            fresh=fresh,
            source=str(d.get("source", "")),
            store_key=str(d.get("store_key", "")),
        )


def winner_key(kernel: str, x: int, y: int, device: str) -> str:
    return f"{kernel}|x={int(x)}|y={int(y)}|{device}"


def parse_winner_key(key: str) -> tuple[str, int, int, str] | None:
    parts = key.split("|")
    if len(parts) != 4:
        return None
    kernel, xs, ys, device = parts
    if not (xs.startswith("x=") and ys.startswith("y=")):
        return None
    try:
        return kernel, int(xs[2:]), int(ys[2:]), device
    except ValueError:
        return None


# ----------------------------------------------------------------- geometry


def spec_geometry(spec) -> tuple[int, int, str] | None:
    """The ``(x, y, device)`` a spec's winner is indexed under.

    The costmodel backend measures a fixed per-kernel workload geometry
    (``repro.costmodel.WORKLOADS``) on a named chip model; the pallas
    backend measures the geometry in its backend kwargs on the live device.
    Backends with no geometry notion (``timing`` / ``callable`` wrappers)
    return ``None`` — their runs don't index winners.
    """
    if spec.backend == "costmodel":
        from ..costmodel import WORKLOADS

        w = WORKLOADS.get(spec.kernel)
        if w is None:
            return None
        return int(w.x), int(w.y), str(spec.backend_kwargs.get("chip", "v5e"))
    if spec.backend == "pallas":
        from ..pallas_bench import DEFAULT_X, DEFAULT_Y

        x = int(spec.backend_kwargs.get("x") or DEFAULT_X)
        y = int(spec.backend_kwargs.get("y") or DEFAULT_Y)
        return x, y, str(spec.backend_kwargs.get("device") or "pallas")
    return None


def parse_config_from_store_key(store_key: str) -> dict | None:
    """Recover the config dict from a measurement key
    (``{cache_key}/seed={s}|k=v,k2=v2,...`` with an optional trailing
    ``|final{repeats}`` marker from final-timing re-measurement)."""
    parts = store_key.split("|")
    if len(parts) < 2:
        return None
    config: dict = {}
    for pair in parts[1].split(","):
        if "=" not in pair:
            return None
        k, v = pair.split("=", 1)
        for cast in (int, float):
            try:
                config[k] = cast(v)
                break
            except ValueError:
                continue
        else:
            config[k] = v
    return config or None


def best_store_entry(store, cache_key: str) -> tuple[dict, float, str] | None:
    """The best finite measurement under ``{cache_key}/`` as
    ``(config, value, store_key)`` (ties break on key, deterministically).

    Final re-measured timings (``|final`` keys) outrank search samples:
    a served config should be the one that won the careful re-measurement,
    not a lucky draw from a noisy single-repeat search probe.  Stores
    without final entries fall back to the global best.
    """
    prefix = f"{cache_key}/"
    if hasattr(store, "best_item"):
        try:
            best = store.best_item(prefix, contains="|final")
        except TypeError:  # duck-typed stores with a prefix-only best_item
            best = None
        if best is None:
            best = store.best_item(prefix)
    else:  # duck-typed minimal stores: python scan
        best = best_final = None
        for k, v in store.items():
            if not k.startswith(prefix) or not math.isfinite(v):
                continue
            if best is None or (v, k) < (best[1], best[0]):
                best = (k, float(v))
            if "|final" in k and (
                best_final is None or (v, k) < (best_final[1], best_final[0])
            ):
                best_final = (k, float(v))
        best = best_final or best
    if best is None:
        return None
    key, value = best
    config = parse_config_from_store_key(key)
    if config is None:
        return None
    return config, float(value), key


# ------------------------------------------------------------------ writing


def record_winner(store, rec: WinnerRecord, *, save: bool = True) -> WinnerRecord:
    """Merge ``rec`` into the store's winners channel (better-value /
    never-staler policy) and return what's now stored."""
    fresh = rec.fresh if rec.fresh else now_stamp()
    rec = replace(rec, fresh=float(fresh))
    merged = merge_winner_payloads(store.get_winner(rec.key), rec.to_payload())
    store.put_winner(rec.key, merged)
    if save:
        store.save()
    return WinnerRecord.from_payload(rec.key, merged) or rec


def record_session_winner(session) -> WinnerRecord | None:
    """Index the session's best measurement as a winner.

    Called by :class:`TuningSession` right after it saves results — the
    winner update rides the same store, so the index is maintained
    transactionally with the measurements behind it.  Returns the stored
    record, or ``None`` when the session has no store / no geometry / no
    finite measurement yet.
    """
    store = getattr(session, "store", None)
    if store is None:
        return None
    geom = spec_geometry(session.spec)
    if geom is None:
        return None
    best = best_store_entry(store, session.cache_key)
    if best is None:
        return None
    config, value, store_key = best
    x, y, device = geom[0], geom[1], geom[2]
    fingerprint = session.journal_namespace() or str(session.cache_key)
    rec = WinnerRecord(
        kernel=session.spec.kernel,
        x=x,
        y=y,
        device=device,
        config=config,
        value=value,
        fingerprint=fingerprint,
        fresh=now_stamp(),
        source=str(session.cache_key),
        store_key=store_key,
    )
    return record_winner(store, rec)


# ------------------------------------------------------------------ reading


def all_winners(store) -> list[WinnerRecord]:
    out = []
    for key, payload in store.winner_items():
        rec = WinnerRecord.from_payload(key, payload)
        if rec is not None:
            out.append(rec)
    return out


def lookup_winner(store, kernel: str, x: int, y: int, device: str
                  ) -> WinnerRecord | None:
    """Exact-geometry lookup: one keyed get, the serving hot path."""
    key = winner_key(kernel, x, y, device)
    payload = store.get_winner(key)
    if payload is None:
        return None
    return WinnerRecord.from_payload(key, payload)


def nearest_winner(store, kernel: str, x: int, y: int, device: str
                   ) -> WinnerRecord | None:
    """The same-kernel, same-device winner closest in log-geometry space
    (``|log(x/x0)| + |log(y/y0)|`` — a 2x-wider image is as near as a
    2x-narrower one).  Ties break on the winner key, deterministically."""
    best: tuple[float, str, WinnerRecord] | None = None
    for rec in all_winners(store):
        if rec.kernel != kernel or rec.device != device:
            continue
        if rec.x <= 0 or rec.y <= 0 or x <= 0 or y <= 0:
            continue
        dist = abs(math.log(x / rec.x)) + abs(math.log(y / rec.y))
        cand = (dist, rec.key, rec)
        if best is None or cand[:2] < best[:2]:
            best = cand
    return None if best is None else best[2]


def index_winners(dst_store, src_store, *, save: bool = True) -> int:
    """Fold ``src_store``'s winners into ``dst_store`` (merge policy applies)
    — how ``paper_matrix --serve-dir`` aggregates per-combo stores into one
    serving store.  Returns how many records were considered."""
    n = 0
    for key, payload in src_store.winner_items():
        dst_store.put_winner(
            key, merge_winner_payloads(dst_store.get_winner(key), payload)
        )
        n += 1
    if save and n:
        dst_store.save()
    return n
