"""Fleet workers: claim queued tuning jobs unit by unit, from any host.

A worker never shares a parent process with the queue owner — it rebuilds a
:class:`TuningSession` from each job's serialized spec, seeds a private
shard store (``<parent>.<ns8>.shard<ident>``, the executor layer's
namespaced shard naming) from the warm parent store, and journals every
completed :class:`ExperimentUnit` into it.  Claims, steals, and done
markers go through :class:`repro.serving.queue.JobQueue`.

Crash semantics are the executor layer's kill-and-resume guarantee lifted
across hosts: a SIGKILLed worker leaves (a) a stale claim a peer steals
after ``claim_timeout_s`` and (b) a shard store whose journal holds
everything it finished.  The peer re-runs only the claimed-but-unfinished
unit; determinism (``stable_seed`` per experiment) makes its values
byte-identical to what the dead worker would have produced, so the
collected parent store is byte-identical to a serial run of the same spec.

:func:`collect_jobs` is the owner side: absorb this spec's shards, check
unit-journal coverage, refresh the winners index, and flip the job record
to ``"done"``.  Run it when workers are idle — absorbing a shard removes
the file.
"""

from __future__ import annotations

import os
import re
import socket
import time

from ..core.api import TuningSession, TuningSpec
from ..core.executors import absorb_store, recover_shard_stores, shard_store_path
from ..core.workunits import build_units
from ..telemetry.null import NULL_TELEMETRY
from .queue import FLEET_MIN_UNITS, JobQueue
from .winners import record_session_winner


def default_worker_ident() -> str:
    """Fleet-unique worker identity: ``<host>-<pid>``, filesystem- and
    shard-name-safe (the shard glob admits ``[A-Za-z0-9_-]``)."""
    host = re.sub(r"[^A-Za-z0-9_-]", "-", socket.gethostname()) or "host"
    return f"{host}-{os.getpid()}"


def job_units(session: TuningSession, job: dict) -> list:
    """The job's deterministic unit decomposition — a property of the JOB
    (``min_units`` rides in the job record), not of whoever runs it, so
    every worker and the collector agree on the unit list."""
    return build_units(
        session.cells(),
        min_units=int(job.get("min_units", FLEET_MIN_UNITS)),
        cost=session._unit_cost(),
    )


class FleetWorker:
    """One worker process draining a shared job queue.

    ``stall_s`` is a test seam: sleep that long after every claim, before
    running the unit — the window chaos tests SIGKILL a worker in.
    """

    def __init__(self, store_kind: str, store_path: str, qdir: str, *,
                 ident: str | None = None, claim_timeout_s: float = 60.0,
                 poll_s: float = 0.05, stall_s: float = 0.0, telemetry=None):
        self.store_kind = str(store_kind)
        self.store_path = str(store_path)
        self.qdir = str(qdir)
        self.ident = ident if ident is not None else default_worker_ident()
        if not re.fullmatch(r"[A-Za-z0-9_-]+", self.ident):
            raise ValueError(
                f"worker ident {self.ident!r} must match [A-Za-z0-9_-]+ "
                "(it names the shard store file)"
            )
        self.claim_timeout_s = float(claim_timeout_s)
        self.poll_s = float(poll_s)
        self.stall_s = float(stall_s)
        self.telemetry = telemetry if telemetry is not None else NULL_TELEMETRY
        self._jobs: dict[str, dict] = {}        # jid -> worker-side job state
        self._completed: set[str] = set()

    # -- internals -------------------------------------------------------------
    def _open_queue(self) -> JobQueue:
        return JobQueue.open(
            self.store_kind, self.store_path, self.qdir,
            claim_timeout_s=self.claim_timeout_s, poll_s=self.poll_s,
            telemetry=self.telemetry,
        )

    def _job_state(self, job: dict) -> dict:
        jid = str(job["id"])
        state = self._jobs.get(jid)
        if state is not None:
            return state
        spec = TuningSpec.from_dict(job["spec"])
        parent = TuningSession(spec)     # read-only: units + shard namespace
        units = job_units(parent, job)
        shard = shard_store_path(parent, self.ident)
        if hasattr(parent.store, "close"):
            parent.store.close()
        wsession = TuningSession(spec, store_path=shard,
                                 telemetry=self.telemetry)
        if (wsession.store is not None and spec.store_path
                and os.path.exists(spec.store_path)):
            # seed from the warm parent: previously-measured entries are
            # served as hits, so a resumed fleet re-measures nothing
            absorb_store(wsession.store, spec.store, spec.store_path)
        state = {
            "units": units,
            "wsession": wsession,
            "journal": wsession.unit_journal(),
        }
        self._jobs[jid] = state
        return state

    def _work_job(self, queue: JobQueue, job: dict) -> tuple[bool, bool]:
        """Claim and run what we can of one job.  Returns
        ``(ran_any_unit, job_complete)``."""
        jid = str(job["id"])
        state = self._job_state(job)
        ran = False
        for unit in state["units"]:
            if queue.unit_done(jid, unit.key) is not None:
                continue
            claim = queue.claim_unit(jid, unit.key, self.ident)
            if claim is None:
                continue
            try:
                if claim == "stolen":
                    self.telemetry.inc("fleet.steals")
                if self.stall_s:
                    time.sleep(self.stall_s)   # chaos-test kill window
                covered = (state["journal"].cover(unit)
                           if state["journal"] is not None else None)
                if covered is None:
                    with self.telemetry.span("fleet_unit", unit=unit.key,
                                             job=jid, ident=self.ident):
                        result = state["wsession"].run_unit(unit)
                    if state["journal"] is not None:
                        state["journal"].put(result)
                    self.telemetry.inc("fleet.units_run")
                state["wsession"].save_store()
                queue.write_unit_done(jid, unit.key, {
                    "ident": self.ident,
                    "stolen": claim == "stolen",
                    "unit": unit.key,
                })
                ran = True
            finally:
                queue.release_unit(jid, unit.key)
        complete = all(
            queue.unit_done(jid, u.key) is not None for u in state["units"]
        )
        if complete and jid not in self._completed:
            self._completed.add(jid)
            self.telemetry.inc("fleet.jobs_completed")
        return ran, complete

    def _close_jobs(self) -> None:
        for state in self._jobs.values():
            wsession = state["wsession"]
            wsession.save_store()
            if wsession.store is not None and hasattr(wsession.store, "close"):
                wsession.store.close()
        self._jobs.clear()

    # -- public ----------------------------------------------------------------
    def run_once(self) -> bool:
        """One pass over pending jobs; ``True`` if any unit ran here."""
        queue = self._open_queue()
        try:
            ran = False
            for job in queue.pending_jobs():
                ran_job, _ = self._work_job(queue, job)
                ran = ran or ran_job
            return ran
        finally:
            queue.close()

    def drain(self, *, max_jobs: int | None = None,
              timeout_s: float | None = None) -> int:
        """Work until every pending job is unit-complete (all done markers
        present — a peer may have run some units), ``max_jobs`` jobs
        completed, or ``timeout_s`` elapsed.  Returns completed-job count."""
        completed = 0
        deadline = None if timeout_s is None else time.monotonic() + timeout_s
        try:
            while True:
                queue = self._open_queue()
                try:
                    actionable = [
                        j for j in queue.pending_jobs()
                        if str(j["id"]) not in self._completed
                    ]
                    if not actionable:
                        return completed
                    ran = False
                    for job in actionable:
                        ran_job, complete = self._work_job(queue, job)
                        ran = ran or ran_job
                        if complete:
                            completed += 1
                            if max_jobs is not None and completed >= max_jobs:
                                return completed
                finally:
                    queue.close()
                if deadline is not None and time.monotonic() >= deadline:
                    return completed
                if not ran:
                    # peers hold the remaining claims: wait for their done
                    # markers, or for their claims to go stale and be stolen
                    time.sleep(self.poll_s)
        finally:
            self._close_jobs()


def collect_jobs(store_kind: str, store_path: str, qdir: str, *,
                 telemetry=None) -> list[str]:
    """Owner-side collection: for every pending job whose units are fully
    journaled across this spec's shard stores, absorb the shards into the
    parent store, refresh the winners index, flip the job to ``"done"``, and
    drop its claim/done files.  Returns the collected job ids."""
    tel = telemetry if telemetry is not None else NULL_TELEMETRY
    queue = JobQueue.open(store_kind, store_path, qdir, telemetry=tel)
    try:
        jobs = queue.pending_jobs()
    finally:
        queue.close()
    collected: list[str] = []
    for job in jobs:
        jid = str(job["id"])
        spec = TuningSpec.from_dict(job["spec"])
        session = TuningSession(spec, telemetry=tel)
        try:
            recover_shard_stores(session)    # namespaced: only OUR shards
            journal = session.unit_journal()
            if journal is None:
                continue
            _, pending = journal.partition(job_units(session, job))
            if pending:
                continue                     # workers still have units to run
            session.save_store()
            record_session_winner(session)
            # mark done through the session's own handle so a JSON store's
            # whole-file save can't clobber the absorbed measurements
            owner_q = JobQueue(session.store, store_kind, store_path, qdir,
                               telemetry=tel)
            owner_q.mark_done(jid, ident="collect")
            owner_q.cleanup_job_files(jid)
            collected.append(jid)
            tel.inc("fleet.jobs_collected")
        finally:
            if session.store is not None and hasattr(session.store, "close"):
                session.store.close()
    return collected
