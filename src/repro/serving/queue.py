"""The fleet job queue: tuning jobs journaled through store metadata,
claims arbitrated through ``O_EXCL`` files.

A *job* is one serialized :class:`TuningSpec` pointed at the queue's shared
store.  Job records live in the store's metadata side-channel under
``__job__|<job_id>`` — the same channel the unit journal uses, so a job
survives anything the store survives.  The job id is a digest of the spec
minus its storage fields: enqueueing the same tuning problem twice is a
no-op, whatever store it was first queued against.

Work arbitration mirrors :mod:`repro.pallas_bench.compile_cache` exactly:

* a worker claims one :class:`ExperimentUnit` of a job by creating
  ``<qdir>/<job_id>.u<digest>.claim`` with ``O_CREAT | O_EXCL`` (the atomic
  "I own this" primitive on every filesystem);
* a claim whose mtime is older than ``claim_timeout_s`` belongs to a dead
  worker; stealing it is serialized under an advisory ``flock`` on the
  queue-wide lock file, so exactly one peer takes over;
* a finished unit publishes ``<qdir>/<job_id>.u<digest>.done`` atomically
  (tmp file + ``os.replace``) recording who ran it and whether the claim
  was stolen.

Workers never write the shared parent store — they journal into their own
namespaced shard stores (``repro.core.executors.shard_store_path``), and
the owner-side :func:`repro.serving.fleet.collect_jobs` absorbs those
shards, checks unit-journal coverage, and flips the job record to
``"done"``.  Determinism does the rest: every unit's values are a pure
function of the spec, so a unit re-run by a stealing peer produces the
same bytes the dead worker would have.
"""

from __future__ import annotations

import hashlib
import json
import os
import tempfile
import time

try:
    import fcntl
except ImportError:  # pragma: no cover - non-POSIX hosts
    fcntl = None

from ..core.api import TuningSpec
from ..core.stores import make_store
from ..core.workunits import unit_digest
from ..telemetry.null import NULL_TELEMETRY

#: store-metadata prefix for job records (the unit journal owns ``__unit__``)
JOB_META_PREFIX = "__job__|"

#: deterministic work-unit decomposition for fleet jobs: fixed, NOT derived
#: from the (elastic) worker count, so every worker and the collector build
#: the identical unit list for a job
FLEET_MIN_UNITS = 8


def job_id_for_spec(spec_dict: dict) -> str:
    """Digest of the spec minus storage fields: the same tuning problem maps
    to the same job id whichever store serves it."""
    d = {k: v for k, v in spec_dict.items() if k not in ("store", "store_path")}
    blob = json.dumps(d, sort_keys=True, default=str)
    return hashlib.sha256(blob.encode("utf-8")).hexdigest()[:12]


class JobQueue:
    """Enqueue / claim / publish over one shared store + one claim dir.

    ``store`` is a live store handle (the owner's — pass the same object the
    serving layer reads through, so JSON-store saves never clobber each
    other); :meth:`open` builds its own handle from ``(kind, path)`` for
    worker processes.
    """

    def __init__(self, store, store_kind: str, store_path: str, qdir: str, *,
                 claim_timeout_s: float = 60.0, poll_s: float = 0.05,
                 telemetry=None):
        self.store = store
        self.store_kind = str(store_kind)
        self.store_path = str(store_path)
        self.qdir = str(qdir)
        self.claim_timeout_s = float(claim_timeout_s)
        self.poll_s = float(poll_s)
        self.telemetry = telemetry if telemetry is not None else NULL_TELEMETRY
        os.makedirs(self.qdir, exist_ok=True)

    @classmethod
    def open(cls, store_kind: str, store_path: str, qdir: str, **kwargs
             ) -> "JobQueue":
        return cls(make_store(store_kind, store_path), store_kind, store_path,
                   qdir, **kwargs)

    def close(self) -> None:
        if hasattr(self.store, "close"):
            self.store.close()

    # -- job records -----------------------------------------------------------
    def enqueue(self, spec: TuningSpec, *, min_units: int = FLEET_MIN_UNITS
                ) -> str:
        """Queue one tuning job (idempotent: re-enqueueing the same problem
        returns the existing job id untouched).  The job's spec is re-pointed
        at the queue's shared store so every worker resolves the same parent."""
        spec = spec.replace(store=self.store_kind, store_path=self.store_path)
        d = spec.to_dict()
        jid = job_id_for_spec(d)
        meta_key = JOB_META_PREFIX + jid
        if self.store.get_meta(meta_key) is None:
            payload = {
                "id": jid,
                "spec": d,
                "min_units": int(min_units),
                "state": "pending",
                # wall stamp: queue bookkeeping, never part of a measurement
                "fresh": time.time(),
            }
            self.store.put_meta(meta_key, json.dumps(payload, sort_keys=True))
            self.store.save()
            self.telemetry.inc("serve.enqueued")
        self.telemetry.gauge("serve.queue_depth", self.depth())
        return jid

    def jobs(self) -> list[dict]:
        out = []
        for key, note in self.store.meta_items(JOB_META_PREFIX):
            try:
                d = json.loads(note)
            except ValueError:
                continue
            if isinstance(d, dict) and d.get("id"):
                out.append(d)
        return sorted(out, key=lambda d: str(d["id"]))

    def job(self, jid: str) -> dict | None:
        note = self.store.get_meta(JOB_META_PREFIX + jid)
        if note is None:
            return None
        try:
            d = json.loads(note)
        except ValueError:
            return None
        return d if isinstance(d, dict) else None

    def pending_jobs(self) -> list[dict]:
        return [d for d in self.jobs() if d.get("state") == "pending"]

    def depth(self) -> int:
        return len(self.pending_jobs())

    def mark_done(self, jid: str, *, ident: str = "") -> None:
        """Owner-side: flip a job record to done (after coverage checked)."""
        job = self.job(jid)
        if job is None:
            return
        job["state"] = "done"
        job["done_ident"] = str(ident)
        job["fresh"] = time.time()
        self.store.put_meta(JOB_META_PREFIX + jid, json.dumps(job, sort_keys=True))
        self.store.save()

    # -- unit claims (compile_cache's discipline, per unit) --------------------
    def _claim_path(self, jid: str, unit_key: str) -> str:
        return os.path.join(self.qdir, f"{jid}.u{unit_digest(unit_key)}.claim")

    def _done_path(self, jid: str, unit_key: str) -> str:
        return os.path.join(self.qdir, f"{jid}.u{unit_digest(unit_key)}.done")

    def _locked(self):
        return _flocked(os.path.join(self.qdir, ".lock"))

    def claim_unit(self, jid: str, unit_key: str, ident: str) -> str | None:
        """Try to own one unit.  ``"fresh"``: clean claim; ``"stolen"``: a
        dead worker's stale claim was removed first; ``None``: a live peer
        holds it."""
        os.makedirs(self.qdir, exist_ok=True)
        path = self._claim_path(jid, unit_key)
        stole = False
        for attempt in range(2):
            try:
                fd = os.open(path, os.O_CREAT | os.O_EXCL | os.O_WRONLY)
            except FileExistsError:
                if attempt or not self._steal_stale_claim(path):
                    return None
                stole = True
                continue  # stale claim removed — race for a fresh one
            with os.fdopen(fd, "w") as f:
                f.write(str(ident))
            return "stolen" if stole else "fresh"
        return None

    def _steal_stale_claim(self, path: str) -> bool:
        """Remove ``path`` if its holder looks dead (mtime older than the
        claim timeout); serialized under the queue lock so at most one peer
        steals.  Wall clock against file mtime: pure liveness policy."""
        now = time.time()
        try:
            age = now - os.path.getmtime(path)
        except OSError:
            return True  # already released
        if age <= self.claim_timeout_s:
            return False
        with self._locked():
            try:
                if now - os.path.getmtime(path) > self.claim_timeout_s:
                    os.remove(path)
            except OSError:
                pass  # another peer stole it first — equally gone
        return not os.path.exists(path)

    def release_unit(self, jid: str, unit_key: str) -> None:
        try:
            os.remove(self._claim_path(jid, unit_key))
        except OSError:
            pass

    def heartbeat_unit(self, jid: str, unit_key: str) -> None:
        """Refresh the claim mtime so long units aren't stolen mid-run."""
        try:
            os.utime(self._claim_path(jid, unit_key))
        except OSError:
            pass

    def unit_claimed(self, jid: str, unit_key: str) -> bool:
        return os.path.exists(self._claim_path(jid, unit_key))

    def write_unit_done(self, jid: str, unit_key: str, payload: dict) -> None:
        """Atomically publish a unit-done marker (tmp + ``os.replace``)."""
        os.makedirs(self.qdir, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=self.qdir, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(payload, f, sort_keys=True)
            os.replace(tmp, self._done_path(jid, unit_key))
        except BaseException:
            try:
                os.remove(tmp)
            except OSError:
                pass
            raise

    def unit_done(self, jid: str, unit_key: str) -> dict | None:
        try:
            with open(self._done_path(jid, unit_key)) as f:
                d = json.load(f)
        except (OSError, ValueError):
            return None
        return d if isinstance(d, dict) else None

    def cleanup_job_files(self, jid: str) -> None:
        """Owner-side: drop a finished job's claim/done files."""
        for f in os.listdir(self.qdir):
            if f.startswith(f"{jid}.u"):
                try:
                    os.remove(os.path.join(self.qdir, f))
                except OSError:
                    pass


class _flocked:
    """Advisory exclusive lock on ``path`` (no-op where ``fcntl`` is
    unavailable — O_EXCL/rename atomicity still holds; only the stale-claim
    steal gets racier)."""

    def __init__(self, path: str):
        self.path = path
        self._fd: int | None = None

    def __enter__(self):
        if fcntl is None:  # pragma: no cover - non-POSIX hosts
            return self
        d = os.path.dirname(self.path)
        if d:
            os.makedirs(d, exist_ok=True)
        self._fd = os.open(self.path, os.O_CREAT | os.O_RDWR)
        fcntl.flock(self._fd, fcntl.LOCK_EX)
        return self

    def __exit__(self, *exc):
        if self._fd is not None:
            fcntl.flock(self._fd, fcntl.LOCK_UN)
            os.close(self._fd)
            self._fd = None
        return False
