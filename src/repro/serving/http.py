"""A stdlib JSON endpoint over :func:`best_config` — no dependencies, one
thread per request (``ThreadingHTTPServer``), a lock around the shared
store handle.

Routes::

    GET /best_config?kernel=add&x=8192&y=8192&device=v5e[&max_age_s=...]
    GET /healthz
    GET /stats

``/best_config`` always answers 200 with a :class:`ServeResult` JSON body —
a miss is an answer (status ``"miss"``, plus the enqueued ``job_id`` when a
queue is attached), not an error.  400 covers malformed queries only.
"""

from __future__ import annotations

import json
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from urllib.parse import parse_qs, urlparse

from ..telemetry.null import NULL_TELEMETRY
from .api import best_config


class ServingState:
    """What the handler threads share: the store, the optional queue, the
    telemetry sink, and the lock serializing store access."""

    def __init__(self, store, *, queue=None, telemetry=None):
        self.store = store
        self.queue = queue
        self.telemetry = telemetry if telemetry is not None else NULL_TELEMETRY
        self.lock = threading.Lock()


class ServingHandler(BaseHTTPRequestHandler):
    # quiet by default; the telemetry trace is the observability channel
    def log_message(self, fmt, *args):  # noqa: ARG002 - stdlib signature
        pass

    @property
    def state(self) -> ServingState:
        return self.server.serving_state  # type: ignore[attr-defined]

    def _reply(self, code: int, payload: dict) -> None:
        body = json.dumps(payload, sort_keys=True).encode("utf-8")
        self.send_response(code)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def do_GET(self) -> None:  # noqa: N802 - stdlib handler name
        url = urlparse(self.path)
        if url.path == "/healthz":
            self._reply(200, {"ok": True})
            return
        if url.path == "/stats":
            st = self.state
            with st.lock:
                winners = sum(1 for _ in st.store.winner_items())
                depth = st.queue.depth() if st.queue is not None else 0
            self._reply(200, {
                "winners": winners,
                "queue_depth": depth,
                "counters": st.telemetry.counters_snapshot(),
            })
            return
        if url.path == "/best_config":
            q = parse_qs(url.query)

            def one(name, default=None):
                vals = q.get(name)
                return vals[0] if vals else default

            kernel = one("kernel")
            device = one("device")
            try:
                x = int(one("x", ""))
                y = int(one("y", ""))
            except ValueError:
                x = y = None
            if not kernel or not device or x is None or y is None:
                self._reply(400, {"error": "kernel, x, y, device are required"})
                return
            max_age = one("max_age_s")
            try:
                max_age_s = float(max_age) if max_age is not None else None
            except ValueError:
                self._reply(400, {"error": "max_age_s must be a number"})
                return
            st = self.state
            with st.lock:
                res = best_config(
                    st.store, kernel, x, y, device,
                    max_age_s=max_age_s, queue=st.queue,
                    telemetry=st.telemetry,
                )
            self._reply(200, res.to_dict())
            return
        self._reply(404, {"error": f"no route {url.path!r}"})


def make_server(state: ServingState, host: str = "127.0.0.1",
                port: int = 0) -> ThreadingHTTPServer:
    """Build (but don't start) the endpoint; ``port=0`` picks a free port
    (read it back from ``server.server_address``)."""
    server = ThreadingHTTPServer((host, port), ServingHandler)
    server.serving_state = state  # type: ignore[attr-defined]
    return server
