"""Config-validity layer: pre-screen geometries, classify failures.

Real autotuning spaces are full of configurations that cannot run (or should
never run): kernel_tuner marks these with a failure value instead of
crashing the search, and the paper's own space carries a workgroup
constraint for exactly this reason.  This module is the TPU analogue:

* :func:`validate_config` pre-screens a config's :class:`KernelGeometry`
  against the kernel's resource model BEFORE any compile — VMEM footprint,
  tile alignment/divisibility, grid bounds — and returns a structured reason
  string (``None`` when the config is runnable).
* :class:`InvalidMeasurement` is the penalty record a failing config maps to:
  ``float("inf")`` plus the reason and the stage it failed at
  (``validity`` pre-screen, ``compile``, or ``run``).  Searchers receive the
  ``inf`` through the ordinary ``tell`` path and keep proposing; the disk
  cache persists the reason alongside the penalty.
* :func:`fit_constraint` packages the pre-screen as a *named* SearchSpace
  constraint (stable id ``pallas_fit:<kernel>:<x>:<y>:<mb>:<grid>``) so
  constrained searchers only propose runnable configs while SMBO methods —
  which per the paper get no constraint specification — discover penalties
  empirically, and specs using the space still round-trip through JSON.

The VMEM footprint formula is kept in exact agreement with
``costmodel.kernel_cost.vmem_bytes`` (the bench descriptors share the same
fields), so the analytical backend and the real backend reject the same
geometries.
"""

from __future__ import annotations

from dataclasses import dataclass
from math import ceil

from ..kernels.common import Config, KernelBenchSpec, KernelGeometry, geometry_from_config
from .workloads import PallasWorkload

#: default VMEM budget — the v5e figure the cost model targets (128 MiB).
DEFAULT_VMEM_LIMIT = 128 * 1024 * 1024
#: max total grid steps: interpret mode walks the grid in Python, and even on
#: hardware a degenerate million-step grid is pure launch overhead.
DEFAULT_MAX_GRID = 65536
SUBLANES = 8    # f32 min tile rows
LANES = 128     # lane count (last-dim tile)


@dataclass(frozen=True)
class InvalidMeasurement:
    """Structured penalty for a config that cannot be (or failed to be)
    measured: served to searchers as ``float("inf")``, persisted to the
    measurement store with its reason."""

    reason: str
    stage: str = "validity"       # "validity" | "compile" | "run"
    penalty: float = float("inf")

    def to_meta(self) -> str:
        """Serialized form stored in the measurement-store metadata."""
        return f"{self.stage}:{self.reason}"

    @classmethod
    def from_meta(cls, meta: str) -> "InvalidMeasurement":
        stage, _, reason = meta.partition(":")
        if stage not in ("validity", "compile", "run"):
            stage, reason = "validity", meta
        return cls(reason=reason, stage=stage)


def vmem_footprint(bench: KernelBenchSpec, g: KernelGeometry) -> int:
    """Per-step VMEM bytes — identical arithmetic to costmodel's vmem_bytes."""
    rows = g.rows_step
    in_block = bench.n_inputs * (rows + 2 * bench.halo) * (g.bn + 2 * bench.halo) * bench.bpe
    out_block = bench.n_outputs * rows * g.bn * bench.bpe
    scratch = bench.scratch_tiles * g.bm * g.bn * bench.bpe
    return (in_block + out_block) * g.wz + scratch


def grid_steps(g: KernelGeometry, x: int, y: int) -> int:
    """Total pipeline steps of the clamped region-split grid (see
    kernels/common.split_grid): (wx * steps_r) * (wy * steps_c)."""
    steps_r = ceil(ceil(x / g.wx) / g.rows_step)
    steps_c = ceil(ceil(y / g.wy) / g.bn)
    return g.wx * steps_r * g.wy * steps_c


def validate_geometry(
    bench: KernelBenchSpec,
    g: KernelGeometry,
    x: int,
    y: int,
    vmem_limit: int = DEFAULT_VMEM_LIMIT,
    max_grid: int = DEFAULT_MAX_GRID,
) -> str | None:
    """Reason the geometry cannot run on problem (x, y), or None if it can.

    Checks, in order of cheapness:
    * tile alignment — block dims must be multiples of the (8, 128) f32 tile
      (always true for config-derived geometries; guards custom spaces),
    * block-vs-image bounds — a block taller/wider than the (tile-aligned)
      image is >=50% padding waste; on hardware it also multiplies the VMEM
      bill for work that is entirely masked out,
    * grid bounds — degenerate splits must not explode the step count,
    * VMEM footprint — the hard per-core limit, the analogue of the paper's
      ``prod(workgroup) <= 256`` executability rule.
    """
    if g.bm % SUBLANES or g.bn % LANES:
        return f"align:block ({g.bm},{g.bn}) not a multiple of ({SUBLANES},{LANES})"
    x_pad = ceil(x / SUBLANES) * SUBLANES
    y_pad = ceil(y / LANES) * LANES
    if g.rows_step > x_pad or g.bn > y_pad:
        return (
            f"block:({g.rows_step},{g.bn}) exceeds padded image ({x_pad},{y_pad})"
        )
    n_steps = grid_steps(g, x, y)
    if n_steps > max_grid:
        return f"grid:{n_steps} steps > {max_grid}"
    vmem = vmem_footprint(bench, g)
    if vmem > vmem_limit:
        return f"vmem:{vmem} bytes > {vmem_limit}"
    return None


def validate_config(
    workload: PallasWorkload,
    cfg: Config,
    vmem_limit: int = DEFAULT_VMEM_LIMIT,
    max_grid: int = DEFAULT_MAX_GRID,
) -> str | None:
    """Pre-screen one config against a workload; reason string or None."""
    return validate_geometry(
        workload.bench,
        geometry_from_config(cfg),
        workload.x,
        workload.y,
        vmem_limit=vmem_limit,
        max_grid=max_grid,
    )


def fit_constraint(
    workload: PallasWorkload,
    vmem_limit: int = DEFAULT_VMEM_LIMIT,
    max_grid: int = DEFAULT_MAX_GRID,
):
    """The pre-screen as a named SearchSpace constraint predicate.

    The stable ``constraint_id`` lets TuningSpec serialization rebuild the
    constrained space by name in shard workers (resolved in
    ``repro.core.api._resolve_constraint``).
    """

    def fn(cfg: Config) -> bool:
        return validate_config(workload, cfg, vmem_limit, max_grid) is None

    fn.constraint_id = (
        f"pallas_fit:{workload.name}:{workload.x}:{workload.y}"
        f":{vmem_limit}:{max_grid}"
    )
    return fn
