"""repro.pallas_bench — real-measurement backend for the tuning engine.

Turns the repo from a cost-model simulator into a real autotuner: compiles
and times actual ``pl.pallas_call`` kernels (interpret mode on CPU, Mosaic
on TPU with no code change) behind the same batched ``measure_batch``
protocol the analytical backend serves.  Registered as the name-serializable
``BACKENDS["pallas"]`` entry, so

    repro.tune(TuningSpec(kernel="harris", backend="pallas", budget=100))

and sharded ``tune_matrix`` runs work end-to-end from JSON alone.

Layout:
    workloads.py  deterministic problem materialization from spec kwargs
    validity.py   geometry pre-screen + structured InvalidMeasurement penalty
    measure.py    PallasMeasurement: compile cache, warmup, N-repeat timing

See docs/pallas_backend.md for the timing protocol and cache keying.
"""

from ..core.space import Param, SearchSpace
from .compile_cache import CompileCache
from .measure import PallasMeasurement
from .validity import (
    DEFAULT_MAX_GRID,
    DEFAULT_VMEM_LIMIT,
    InvalidMeasurement,
    fit_constraint,
    validate_config,
    vmem_footprint,
)
from .workloads import DEFAULT_X, DEFAULT_Y, PallasWorkload, make_workload

__all__ = [
    "CompileCache",
    "DEFAULT_MAX_GRID",
    "DEFAULT_VMEM_LIMIT",
    "DEFAULT_X",
    "DEFAULT_Y",
    "InvalidMeasurement",
    "PallasMeasurement",
    "PallasWorkload",
    "default_space",
    "fit_constraint",
    "make_workload",
    "validate_config",
    "vmem_footprint",
]


def default_space(
    kernel: str = "add",
    x: int = DEFAULT_X,
    y: int = DEFAULT_Y,
    vmem_limit: int = DEFAULT_VMEM_LIMIT,
    max_grid: int = DEFAULT_MAX_GRID,
    **_,
) -> SearchSpace:
    """The paper's 6-parameter space constrained to runnable geometries.

    Mirrors the costmodel backend's executable-config space: constrained
    searchers only propose configs that pass the validity pre-screen, while
    SMBO methods (which strip the constraint per the paper) propose freely
    and observe ``inf`` penalties.  The constraint carries a stable id
    (``pallas_fit:...``) so serialized specs rebuild it by name.
    """
    workload = make_workload(kernel, x=x, y=y)
    params = [
        Param.int_range("t_x", 1, 16),
        Param.int_range("t_y", 1, 16),
        Param.int_range("t_z", 1, 16),
        Param.int_range("w_x", 1, 8),
        Param.int_range("w_y", 1, 8),
        Param.int_range("w_z", 1, 8),
    ]
    return SearchSpace(
        params, constraint=fit_constraint(workload, vmem_limit, max_grid)
    )
