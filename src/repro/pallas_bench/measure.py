"""Compile-and-time measurement of real ``pl.pallas_call`` kernels.

:class:`PallasMeasurement` is the objective function the ISSUE's real-
measurement path plugs into the batched ask/tell engine:

* **compile once per geometry** — a keyed compilation cache maps each
  distinct kernel geometry to its warmed, ready-to-time callable.  Configs
  that lower to the same program (today: any two configs differing only in
  ``w_z``, which the Mosaic pipeliner owns) share one cache entry, so the
  searcher revisiting a geometry never pays tracing/lowering again.
  ``n_compiles`` counts actual compilations — the figure a warm disk cache
  drives to zero.
* **warmup + N-repeat timing** — every measurement runs ``warmup`` fenced
  calls (the compile call counts as the first), then ``repeats`` timed calls,
  each fenced with ``jax.block_until_ready`` INSIDE the timed region (the
  analogue of the paper timing after H2D and before D2H).  The robust
  aggregate is the median; all repeats are recorded (``repeats_for``) so the
  run record can carry the raw distribution.
* **failures become penalties** — the validity pre-screen and any
  compile/run exception map to a structured
  :class:`~repro.pallas_bench.validity.InvalidMeasurement`:
  the searcher sees ``float("inf")`` through the ordinary ``tell`` path
  (kernel_tuner-style) and the reason survives into the measurement store.

On CPU the kernels run in Pallas interpret mode (``kernels.common
.use_interpret``); on a real TPU the same ``pallas_call`` lowers to Mosaic
with no change here — only the provenance dict's ``interpret``/
``device_kind`` fields flip.
"""

from __future__ import annotations

import time
from typing import Callable, Sequence

import numpy as np

from ..core.measurement import BaseMeasurement, fence
from ..core.engine import config_key
from ..kernels.common import Config, geometry_from_config
from .validity import (
    DEFAULT_MAX_GRID,
    DEFAULT_VMEM_LIMIT,
    InvalidMeasurement,
    validate_config,
)
from .workloads import PallasWorkload


class PallasMeasurement(BaseMeasurement):
    """Measures real kernel wall-clock; never raises on a bad config.

    ``repeats``/``warmup`` follow the kernel_tuner defaults (time several
    runs, keep a robust aggregate).  ``validate=False`` disables the
    pre-screen (compile/run failures are still caught) — useful to audit the
    screen itself.  ``seed`` is accepted for backend-factory uniformity;
    wall-clock timing has no noise stream to seed.
    """

    def __init__(
        self,
        workload: PallasWorkload,
        *,
        repeats: int = 5,
        warmup: int = 1,
        vmem_limit: int = DEFAULT_VMEM_LIMIT,
        max_grid: int = DEFAULT_MAX_GRID,
        validate: bool = True,
    ):
        super().__init__()
        if repeats < 1:
            raise ValueError("repeats must be >= 1")
        self.workload = workload
        self.repeats = int(repeats)
        self.warmup = int(warmup)
        self.vmem_limit = int(vmem_limit)
        self.max_grid = int(max_grid)
        self.validate = validate
        self.n_compiles = 0
        #: config_key -> InvalidMeasurement for every penalized config served
        self.invalid: dict[str, InvalidMeasurement] = {}
        #: config_key -> per-repeat seconds of the last search measurement
        self.repeat_log: dict[str, list[float]] = {}
        #: config_key -> per-repeat seconds of the last final re-measurement
        self.final_repeat_log: dict[str, list[float]] = {}
        self._inputs: tuple | None = None
        #: geometry key -> warmed callable (or InvalidMeasurement for a
        #: geometry whose compile failed — retrying would fail identically)
        self._compiled: dict[tuple, Callable | InvalidMeasurement] = {}

    # -- compilation cache -----------------------------------------------------
    def _geom_key(self, cfg: Config) -> tuple:
        g = geometry_from_config(cfg)
        key = (g.bm, g.bn, g.tz, g.wx, g.wy)
        return key + (g.wz,) if self.workload.bench.wz_in_program else key

    def _run_config(self, cfg: Config) -> Config:
        """The config actually launched: ``w_z`` is pinned when it does not
        enter the program, so jax's jit cache coalesces with ours."""
        if self.workload.bench.wz_in_program:
            return cfg
        return {**cfg, "w_z": 1}

    def _get_compiled(self, cfg: Config) -> Callable | InvalidMeasurement:
        """Warmed zero-arg runner for cfg's geometry, compiling on first use."""
        gkey = self._geom_key(cfg)
        hit = self._compiled.get(gkey)
        if hit is not None:
            return hit
        if self._inputs is None:
            self._inputs = self.workload.materialize()
        inputs, run_cfg = self._inputs, self._run_config(cfg)

        def fn():
            return self.workload.run(inputs, run_cfg)

        try:
            self.n_compiles += 1
            fence(fn())                       # trace + lower + first run
            for _ in range(max(0, self.warmup - 1)):
                fence(fn())
        except Exception as e:  # noqa: BLE001 — any compile failure is a penalty
            bad = InvalidMeasurement(
                reason=f"{type(e).__name__}: {e}", stage="compile"
            )
            self._compiled[gkey] = bad
            return bad
        self._compiled[gkey] = fn
        return fn

    # -- timing ----------------------------------------------------------------
    def _timed_repeats(self, fn: Callable, repeats: int) -> list[float] | InvalidMeasurement:
        times = []
        for _ in range(repeats):
            try:
                t0 = time.perf_counter()
                fence(fn())
                times.append(time.perf_counter() - t0)
            except Exception as e:  # noqa: BLE001 — runtime failure -> penalty
                return InvalidMeasurement(
                    reason=f"{type(e).__name__}: {e}", stage="run"
                )
        return times

    def _measure_repeats(self, config: Config, repeats: int) -> list[float] | InvalidMeasurement:
        if self.validate:
            reason = validate_config(
                self.workload, config, self.vmem_limit, self.max_grid
            )
            if reason is not None:
                return InvalidMeasurement(reason=reason, stage="validity")
        fn = self._get_compiled(config)
        if isinstance(fn, InvalidMeasurement):
            return fn
        return self._timed_repeats(fn, repeats)

    def _measure_one(self, config: Config) -> float:
        key = config_key(config)
        out = self._measure_repeats(config, self.repeats)
        if isinstance(out, InvalidMeasurement):
            self.invalid[key] = out
            return out.penalty
        self.repeat_log[key] = out
        return float(np.median(out))

    def measure_batch(self, configs: Sequence[Config]) -> np.ndarray:
        """One Python-level dispatch per batch; kernels still execute
        sequentially (device timing must not overlap)."""
        self.n_samples += len(configs)
        self.n_dispatches += 1
        return np.array(
            [float(self._measure_one(c)) for c in configs], dtype=np.float64
        )

    def measure_final(self, config: Config, repeats: int = 10) -> float:
        """Paper protocol: the winner re-measured ``repeats`` times, median
        kept; raw repeats land in ``final_repeat_log`` for the run record."""
        key = config_key(config)
        out = self._measure_repeats(config, repeats)
        if isinstance(out, InvalidMeasurement):
            self.invalid[key] = out
            return out.penalty
        self.final_repeat_log[key] = out
        return float(np.median(out))

    # -- introspection (RunRecord provenance, disk-cache metadata) ------------
    def reason_for(self, config: Config) -> str | None:
        bad = self.invalid.get(config_key(config))
        return None if bad is None else bad.to_meta()

    def repeats_for(self, config: Config) -> list[float] | None:
        key = config_key(config)
        return self.final_repeat_log.get(key) or self.repeat_log.get(key)

    def provenance(self) -> dict:
        """Backend provenance for the versioned RunRecord: how timings were
        taken and on what — the fields that distinguish an interpret-mode CPU
        run from a real-TPU run of the same spec."""
        import jax

        dev = jax.devices()[0]
        return {
            "backend": "pallas",
            "kernel": self.workload.name,
            "x": self.workload.x,
            "y": self.workload.y,
            "input_seed": self.workload.input_seed,
            "interpret": bool(self.workload.interpret()),
            "platform": jax.default_backend(),
            "device_kind": dev.device_kind,
            "repeats": self.repeats,
            "warmup": self.warmup,
            "timer": "perf_counter",
            "n_compiles": self.n_compiles,
            "n_invalid": len(self.invalid),
        }

    def reset(self) -> None:
        """Clear counters and logs; the compilation cache survives (compiled
        programs are still valid — that is the point of the cache)."""
        super().reset()
        self.invalid.clear()
        self.repeat_log.clear()
        self.final_repeat_log.clear()
