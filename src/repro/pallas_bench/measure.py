"""Compile-and-time measurement of real ``pl.pallas_call`` kernels.

:class:`PallasMeasurement` is the objective function the ISSUE's real-
measurement path plugs into the batched ask/tell engine.  Measurement is a
staged pipeline — **screen → compile → time → record** — with each stage a
method of its own and a :class:`~repro.core.measurement.StageClock` charging
per-stage wall-clock into provenance (``screen_s`` / ``compile_s`` /
``time_s``), so the analysis layer can split search cost into "compiling"
vs "measuring":

* **screen** — the validity pre-screen (:mod:`.validity`) rejects bad
  geometries before any compile; failures become structured
  :class:`~repro.pallas_bench.validity.InvalidMeasurement` penalties
  (``float("inf")`` through the ordinary ``tell`` path, kernel_tuner-style)
  whose reasons survive into the measurement store.
* **compile once per geometry** — a keyed compilation cache maps each
  distinct kernel geometry to its warmed, ready-to-time callable.  Configs
  that lower to the same program (today: any two configs differing only in
  ``w_z``, which the Mosaic pipeliner owns) share one cache entry.
  ``n_compiles`` counts actual compilations — the figure a warm disk cache
  drives to zero.  With ``pipeline_workers > 0``, ``measure_batch`` runs
  two-phase: a *compile phase* resolves the whole batch's geometry keys
  through a thread-pool prefetcher (upcoming geometries compile while the
  device times the current config), then the *timing phase* walks the batch
  strictly sequentially — device measurements never overlap each other, only
  host-side compilation overlaps them.  ``pipeline_workers=0`` (default) is
  byte-for-byte today's inline path.
* **warmup + N-repeat timing** — every measurement runs ``warmup`` fenced
  calls (the compile call counts as the first), then ``repeats`` timed calls,
  each fenced with ``jax.block_until_ready`` INSIDE the timed region (the
  analogue of the paper timing after H2D and before D2H).  The robust
  aggregate is the median; all repeats are recorded (``repeats_for``) so the
  run record can carry the raw distribution.

On CPU the kernels run in Pallas interpret mode (``kernels.common
.use_interpret``); on a real TPU the same ``pallas_call`` lowers to Mosaic
with no change here — only the provenance dict's ``interpret``/
``device_kind`` fields flip.
"""

from __future__ import annotations

import threading
from concurrent.futures import Future, ThreadPoolExecutor
from contextlib import contextmanager
from typing import Callable, Sequence

import numpy as np

from ..core.clock import monotonic
from ..core.engine import config_key
from ..core.measurement import BaseMeasurement, StageClock, fence
from ..kernels.common import Config, geometry_from_config
from .compile_cache import CompileCache, deserialize_compiled, serialize_compiled
from .validity import (
    DEFAULT_MAX_GRID,
    DEFAULT_VMEM_LIMIT,
    InvalidMeasurement,
    validate_config,
)
from .workloads import PallasWorkload


class PallasMeasurement(BaseMeasurement):
    """Measures real kernel wall-clock; never raises on a bad config.

    ``repeats``/``warmup`` follow the kernel_tuner defaults (time several
    runs, keep a robust aggregate).  ``validate=False`` disables the
    pre-screen (compile/run failures are still caught) — useful to audit the
    screen itself.  ``pipeline_workers=N`` enables the batch compile
    prefetcher (N pool threads); 0 keeps the inline compile-then-time loop.
    ``timer`` is the timing-stage clock (default: the injectable monotonic
    seam in :mod:`repro.core.clock`, i.e. ``perf_counter``) —
    injectable so tests can prove pipeline on/off equivalence on
    deterministic timestamps.  ``seed`` is accepted for backend-factory
    uniformity; wall-clock timing has no noise stream to seed.

    ``compile_cache`` layers the persistent cross-process compile cache
    (:class:`~repro.pallas_bench.compile_cache.CompileCache`, or a cache
    directory path) under the in-memory one: compiled executables are
    served across measurement instances, worker processes, and runs, and
    in-flight compiles dedup across process boundaries.  A pure speed knob —
    ``n_compiles`` drops (to zero against a fully warm cache), values do
    not change.
    """

    def __init__(
        self,
        workload: PallasWorkload,
        *,
        repeats: int = 5,
        warmup: int = 1,
        vmem_limit: int = DEFAULT_VMEM_LIMIT,
        max_grid: int = DEFAULT_MAX_GRID,
        validate: bool = True,
        pipeline_workers: int = 0,
        timer: Callable[[], float] | None = None,
        compile_cache: "CompileCache | str | None" = None,
    ):
        super().__init__()
        if repeats < 1:
            raise ValueError("repeats must be >= 1")
        if pipeline_workers < 0:
            raise ValueError("pipeline_workers must be >= 0")
        self.workload = workload
        self.repeats = int(repeats)
        self.warmup = int(warmup)
        self.vmem_limit = int(vmem_limit)
        self.max_grid = int(max_grid)
        self.validate = validate
        self.pipeline_workers = int(pipeline_workers)
        # default to the injectable clock seam (repro.core.clock) rather than
        # a direct perf_counter reference: one allowlist entry, one override
        self._timer = timer if timer is not None else monotonic
        #: per-stage wall-clock (screen / compile / time), per run — reset()
        #: zeroes it together with the per-run counters below
        self.clock = StageClock()
        if isinstance(compile_cache, str):
            compile_cache = CompileCache(compile_cache)
        #: persistent cross-process compile cache, or None (memory-only)
        self.pcache: CompileCache | None = compile_cache
        #: lifetime compile count == compilation-cache fills (the cache
        #: survives reset() by design, and so does this)
        self.n_compiles = 0
        #: lifetime persistent-cache hits (entries served instead of compiled)
        self.n_pcache_hits = 0
        #: per-run counters — what provenance reports, so a later matrix
        #: cell reusing this instance never over-reports earlier cells' work
        self.run_compiles = 0
        self.run_pcache_hits = 0
        self._run_invalid: set[str] = set()
        #: config_key -> InvalidMeasurement for every penalized config served
        #: (lifetime, like the compile cache: reasons stay addressable)
        self.invalid: dict[str, InvalidMeasurement] = {}
        #: config_key -> per-repeat seconds of the last search measurement
        self.repeat_log: dict[str, list[float]] = {}
        #: config_key -> per-repeat seconds of the last final re-measurement
        self.final_repeat_log: dict[str, list[float]] = {}
        self._inputs: tuple | None = None
        #: geometry key -> warmed callable (or InvalidMeasurement for a
        #: geometry whose compile failed — retrying would fail identically)
        self._compiled: dict[tuple, Callable | InvalidMeasurement] = {}
        #: geometry key -> in-flight prefetch compile (pipelined batches)
        self._inflight: dict[tuple, Future] = {}
        self._cache_lock = threading.RLock()
        self._pool: ThreadPoolExecutor | None = None

    # -- compilation cache -----------------------------------------------------
    def _geom_key(self, cfg: Config) -> tuple:
        g = geometry_from_config(cfg)
        key = (g.bm, g.bn, g.tz, g.wx, g.wy)
        return key + (g.wz,) if self.workload.bench.wz_in_program else key

    def _run_config(self, cfg: Config) -> Config:
        """The config actually launched: ``w_z`` is pinned when it does not
        enter the program, so jax's jit cache coalesces with ours."""
        if self.workload.bench.wz_in_program:
            return cfg
        return {**cfg, "w_z": 1}

    def _pcache_key(self, gkey: tuple) -> str:
        w = self.workload
        return self.pcache.key(
            kernel=w.name,
            x=w.x,
            y=w.y,
            input_seed=w.input_seed,
            interpret=bool(w.interpret()),
            geometry=list(gkey),
        )

    def _pcache_hit(self) -> None:
        with self._cache_lock:
            self.n_pcache_hits += 1
            self.run_pcache_hits += 1
        if self.telemetry.enabled:
            self.telemetry.inc("pcache.hits")

    def _pcache_serve(
        self, entry: dict, gkey: tuple, inputs: tuple
    ) -> Callable | InvalidMeasurement | None:
        """Turn a persistent-cache entry into a warmed callable (or cached
        penalty); ``None`` means the entry cannot substitute for a compile
        here (no artifact, or the artifact fails to load) and the caller
        compiles locally."""
        if entry.get("status") == "invalid":
            bad = InvalidMeasurement(
                reason=entry.get("reason") or "cached compile failure",
                stage=entry.get("stage") or "compile",
            )
            with self._cache_lock:
                self._compiled[gkey] = bad
            self._pcache_hit()
            return bad
        blob = entry.get("artifact")
        if blob is None:
            return None
        try:
            loaded = deserialize_compiled(blob)

            def fn():
                return loaded(*inputs)

            for _ in range(max(1, self.warmup)):
                fence(fn())
        except Exception:  # noqa: BLE001 — a bad artifact degrades to a recompile
            return None
        with self._cache_lock:
            self._compiled[gkey] = fn
        self._pcache_hit()
        return fn

    def _compile_aot(self, inputs: tuple, run_cfg: Config):
        """AOT-compile the program (``jit(...).lower().compile()``) so its
        executable can be published to the persistent cache.  Returns
        ``(warmed callable, serialized blob | None)``, or ``(None, None)``
        when AOT lowering fails — the jit-closure fallback then owns the
        compile (and the penalty, if the config is genuinely invalid)."""
        import jax

        try:
            compiled = (
                jax.jit(lambda *arrays: self.workload.run(arrays, run_cfg))
                .lower(*inputs)
                .compile()
            )

            def fn():
                return compiled(*inputs)

            fence(fn())                   # first run (compile() is lazy-free)
            for _ in range(max(0, self.warmup - 1)):
                fence(fn())
        except Exception:  # noqa: BLE001 — fall back to the closure path
            return None, None
        return fn, serialize_compiled(compiled)

    def _compile_now(self, cfg: Config, gkey: tuple) -> Callable | InvalidMeasurement:
        """Trace + lower + warm cfg's geometry, populating the cache.  Called
        from the main thread (inline path) or a prefetch pool thread; all
        shared state mutates under the cache lock.

        With a persistent cache attached, the order is: serve the on-disk
        entry (no compile counted) -> claim the key and compile -> or, when
        another process holds the claim, wait for its entry.  Claim holders
        publish ok/invalid entries so every other process — including ones
        started later — skips this geometry entirely."""
        with self._cache_lock:
            if self._inputs is None:
                self._inputs = self.workload.materialize()
            inputs = self._inputs
        run_cfg = self._run_config(cfg)
        pc = self.pcache
        pckey = None
        claimed = False
        if pc is not None:
            pckey = self._pcache_key(gkey)
            entry = pc.get(pckey)
            if entry is None:
                claimed = pc.claim(pckey)
                if claimed:
                    # double-check under the claim: the previous holder may
                    # have published between our miss and our claim (entries
                    # land before claims are released), so this read is
                    # authoritative — each geometry compiles exactly once
                    # across processes
                    entry = pc.get(pckey)
                else:
                    # another process is compiling this geometry right now;
                    # waiting is the cross-process analogue of the prefetch
                    # future join
                    if self.telemetry.enabled:
                        self.telemetry.inc("pcache.waits")
                    entry = pc.wait(pckey)
            if entry is not None:
                got = self._pcache_serve(entry, gkey, inputs)
                if got is not None:
                    if claimed:
                        pc.release(pckey)
                    return got
            if self.telemetry.enabled:
                self.telemetry.inc("pcache.misses")
        try:
            with self._cache_lock:
                self.n_compiles += 1
                self.run_compiles += 1
            if self.telemetry.enabled:
                self.telemetry.inc("compiles")
            fn = None
            artifact = None
            if pc is not None:
                fn, artifact = self._compile_aot(inputs, run_cfg)
            if fn is None:
                def fn():
                    return self.workload.run(inputs, run_cfg)

                try:
                    fence(fn())                   # trace + lower + first run
                    for _ in range(max(0, self.warmup - 1)):
                        fence(fn())
                except Exception as e:  # noqa: BLE001 — any compile failure is a penalty
                    bad = InvalidMeasurement(
                        reason=f"{type(e).__name__}: {e}", stage="compile"
                    )
                    with self._cache_lock:
                        self._compiled[gkey] = bad
                    if claimed:
                        pc.put(
                            pckey, status="invalid",
                            reason=bad.reason, stage="compile",
                        )
                    return bad
            with self._cache_lock:
                self._compiled[gkey] = fn
            if claimed:
                pc.put(pckey, status="ok", artifact=artifact)
                if self.telemetry.enabled:
                    self.telemetry.inc("pcache.stores")
            return fn
        finally:
            if claimed:
                pc.release(pckey)

    # -- pipeline stages -------------------------------------------------------
    @contextmanager
    def _staged(self, name: str, **attrs):
        """Charge the stage clock AND (when telemetry is on) emit a ``stage``
        trace event with the same duration — one timing source for both, so
        the trace's per-stage totals reconcile exactly with ``stage_times``.
        Thread-safe like the clock: prefetch pool threads use it too."""
        t0 = monotonic()
        try:
            yield
        finally:
            dur = monotonic() - t0
            self.clock.add(name, dur)
            if self.telemetry.enabled:
                self.telemetry.stage(
                    name, dur,
                    **{k: v for k, v in attrs.items() if v is not None},
                )

    def _stage_screen(self, config: Config) -> InvalidMeasurement | None:
        """Validity pre-screen; ``None`` means the config may compile."""
        if not self.validate:
            return None
        with self._staged("screen"):
            reason = validate_config(
                self.workload, config, self.vmem_limit, self.max_grid
            )
        if reason is None:
            return None
        return InvalidMeasurement(reason=reason, stage="validity")

    def _stage_compile(self, config: Config) -> Callable | InvalidMeasurement:
        """Warmed zero-arg runner for cfg's geometry: cache hit, prefetched
        compile (pipelined batches), or inline compile on first use."""
        gkey = self._geom_key(config)
        with self._cache_lock:
            hit = self._compiled.get(gkey)
            fut = None if hit is not None else self._inflight.pop(gkey, None)
        if hit is not None:
            if self.telemetry.enabled:
                self.telemetry.inc("compile_cache_hits")
            return hit
        if fut is not None:
            # the pool thread charged the compile stage; waiting here is the
            # pipeline's (ideally zero) bubble
            return fut.result()
        with self._staged("compile", key=str(gkey)):
            return self._compile_now(config, gkey)

    def _stage_time(
        self, fn: Callable, repeats: int, key: str | None = None
    ) -> list[float] | InvalidMeasurement:
        """Strictly sequential fenced timing — never overlapped, so device
        measurements stay honest even while the prefetcher compiles."""
        times = []
        with self._staged("time", key=key):
            for _ in range(repeats):
                try:
                    t0 = self._timer()
                    fence(fn())
                    times.append(self._timer() - t0)
                except Exception as e:  # noqa: BLE001 — runtime failure -> penalty
                    return InvalidMeasurement(
                        reason=f"{type(e).__name__}: {e}", stage="run"
                    )
        return times

    def _stage_record(
        self,
        key: str,
        out: list[float] | InvalidMeasurement,
        log: dict[str, list[float]],
    ) -> float:
        """Fold a stage-pipeline outcome into the served value + the logs."""
        with self._staged("record", key=key):
            if isinstance(out, InvalidMeasurement):
                self.invalid[key] = out
                self._run_invalid.add(key)
                if self.telemetry.enabled:
                    # histogram by validity rule (align:/block:/grid:/vmem:)
                    # or by the failing stage for compile/run penalties
                    rule = (
                        out.reason.split(":", 1)[0]
                        if out.stage == "validity"
                        else out.stage
                    )
                    self.telemetry.inc(f"invalid.{rule}")
                return out.penalty
            log[key] = out
            return float(np.median(out))

    def _measure_repeats(
        self, config: Config, repeats: int
    ) -> list[float] | InvalidMeasurement:
        bad = self._stage_screen(config)
        if bad is not None:
            return bad
        fn = self._stage_compile(config)
        if isinstance(fn, InvalidMeasurement):
            return fn
        return self._stage_time(fn, repeats, key=config_key(config))

    def _measure_one(self, config: Config) -> float:
        return self._stage_record(
            config_key(config),
            self._measure_repeats(config, self.repeats),
            self.repeat_log,
        )

    # -- the two-phase batch path ----------------------------------------------
    def _prefetch_compiles(self, configs: Sequence[Config]) -> None:
        """Compile phase: submit every geometry this batch will compile to
        the pool, in batch order.  Only configs that pass the pre-screen are
        prefetched (the inline path never compiles a screened-out config),
        so ``n_compiles`` is identical with the pipeline on or off."""
        if self._pool is None:
            self._pool = ThreadPoolExecutor(
                max_workers=self.pipeline_workers,
                thread_name_prefix="pallas-compile",
            )
        for cfg in configs:
            if self.validate and validate_config(
                self.workload, cfg, self.vmem_limit, self.max_grid
            ) is not None:
                continue
            gkey = self._geom_key(cfg)
            with self._cache_lock:
                if gkey in self._compiled or gkey in self._inflight:
                    continue
                self._inflight[gkey] = self._pool.submit(
                    self._prefetch_task, dict(cfg), gkey
                )
                depth = len(self._inflight)
            if self.telemetry.enabled:
                self.telemetry.gauge("prefetch_inflight", depth)

    def _prefetch_task(self, cfg: Config, gkey: tuple):
        with self._staged("compile", key=str(gkey)):
            return self._compile_now(cfg, gkey)

    def measure_batch(self, configs: Sequence[Config]) -> np.ndarray:
        """One Python-level dispatch per batch.  With ``pipeline_workers``
        set, the batch runs two-phase — compile prefetch, then timing —
        but the timing phase itself walks configs strictly sequentially
        (device measurements must not overlap each other)."""
        self.n_samples += len(configs)
        self.n_dispatches += 1
        if self.pipeline_workers > 0 and len(configs) > 1:
            self._prefetch_compiles(configs)
        return np.array(
            [float(self._measure_one(c)) for c in configs], dtype=np.float64
        )

    def measure_final(self, config: Config, repeats: int = 10) -> float:
        """Paper protocol: the winner re-measured ``repeats`` times, median
        kept; raw repeats land in ``final_repeat_log`` for the run record."""
        return self._stage_record(
            config_key(config),
            self._measure_repeats(config, repeats),
            self.final_repeat_log,
        )

    def close(self) -> None:
        """Shut the prefetch pool down (idempotent; the pool is rebuilt on
        the next pipelined batch if the instance keeps measuring)."""
        if self._pool is not None:
            self._pool.shutdown(wait=True)
            self._pool = None

    def __del__(self):  # pragma: no cover — interpreter-exit ordering
        try:
            self.close()
        except Exception:  # noqa: BLE001
            pass

    # -- introspection (RunRecord provenance, disk-cache metadata) ------------
    def reason_for(self, config: Config) -> str | None:
        bad = self.invalid.get(config_key(config))
        return None if bad is None else bad.to_meta()

    def repeats_for(self, config: Config) -> list[float] | None:
        key = config_key(config)
        return self.final_repeat_log.get(key) or self.repeat_log.get(key)

    def stage_times(self) -> dict[str, float]:
        return self.clock.times()

    def provenance(self) -> dict:
        """Backend provenance for the versioned RunRecord: how timings were
        taken and on what — the fields that distinguish an interpret-mode CPU
        run from a real-TPU run of the same spec.  Counters are per-run
        (since the last ``reset()``): a later matrix cell reports its own
        compiles/penalties, not lifetime totals; ``n_compiles_total`` keeps
        the lifetime figure (== compilation-cache fills)."""
        import jax

        dev = jax.devices()[0]
        stage_s = {k: round(v, 6) for k, v in self.clock.times().items()}
        return {
            "backend": "pallas",
            "kernel": self.workload.name,
            "x": self.workload.x,
            "y": self.workload.y,
            "input_seed": self.workload.input_seed,
            "interpret": bool(self.workload.interpret()),
            "platform": jax.default_backend(),
            "device_kind": dev.device_kind,
            "repeats": self.repeats,
            "warmup": self.warmup,
            "timer": "perf_counter",
            "pipeline_workers": self.pipeline_workers,
            "compile_cache": self.pcache is not None,
            "stage_s": stage_s,
            "n_compiles": self.run_compiles,
            "n_compiles_total": self.n_compiles,
            "n_pcache_hits": self.run_pcache_hits,
            "n_invalid": len(self._run_invalid),
        }

    def reset(self) -> None:
        """Clear per-run counters, logs, and stage clocks; the compilation
        cache — and its lifetime ``n_compiles`` — survives (compiled
        programs are still valid — that is the point of the cache)."""
        super().reset()
        self.run_compiles = 0
        self.run_pcache_hits = 0
        self._run_invalid.clear()
        self.repeat_log.clear()
        self.final_repeat_log.clear()
        self.clock.reset()
