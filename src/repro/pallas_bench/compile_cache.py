"""Persistent cross-process compile-artifact cache for pallas measurements.

The in-memory compilation cache in :class:`~repro.pallas_bench.measure
.PallasMeasurement` dedups compiles within ONE measurement instance — but a
matrix run builds a fresh instance per experiment, every worker process
builds its own, and a re-run starts cold.  This module adds the layer under
it: an on-disk cache of compiled kernel executables keyed by *(kernel
identity, geometry, jax/backend fingerprint)*, shared by every process that
points at the same directory.

Three guarantees, and how the file protocol provides them:

* **Atomic entries** — an entry is a single pickle file written to a temp
  name and ``os.replace``\\ d into place, so a reader never sees a torn
  entry; concurrent writers of the same key write identical content and the
  last rename wins harmlessly.
* **Cross-process in-flight dedup** — before compiling, a worker *claims*
  the key by creating ``<key>.claim`` with ``O_CREAT | O_EXCL`` (the atomic
  "I am compiling this" marker).  Losers poll for the entry instead of
  compiling the same program in parallel.  A claim left behind by a killed
  worker goes stale after ``claim_timeout_s`` and is removed under an
  advisory ``flock`` on the cache-wide lock file, so exactly one waiter
  inherits the compile.
* **Runtime fingerprinting** — every entry records the jax version,
  platform, and device kind it was compiled under; an entry from a
  different runtime is a miss, never a wrong executable.

Entries carry either a serialized AOT executable (``artifact``; see
:func:`serialize_compiled` — ``jax.experimental.serialize_executable``) or,
for programs whose executables cannot be serialized, just the compile
*outcome* so failures (``status="invalid"``) are still served without
recompiling.  The cache is a pure speed knob: values served from it are the
output of the same compiled program, so measurement results keep the repo's
bit-identity invariant, and the ``compile_cache`` spec kwarg is excluded
from cache keys / journal namespaces / spec fingerprints (staticcheck
PROV001 pins that).
"""

from __future__ import annotations

import hashlib
import json
import os
import pickle
import tempfile
import time
from contextlib import contextmanager
from typing import Any, Callable

from ..core.clock import monotonic

try:  # POSIX advisory locking; degrade gracefully where absent
    import fcntl
except ImportError:  # pragma: no cover - non-POSIX hosts
    fcntl = None

__all__ = [
    "CompileCache",
    "deserialize_compiled",
    "runtime_fingerprint",
    "serialize_compiled",
]

#: bump when the entry layout changes — old entries become misses, not errors
FORMAT_VERSION = 1


def runtime_fingerprint() -> dict:
    """What an executable's validity depends on: the jax build and the
    device it was compiled for.  Part of every entry; mismatches are misses."""
    import jax

    dev = jax.devices()[0]
    return {
        "format": FORMAT_VERSION,
        "jax": jax.__version__,
        "platform": jax.default_backend(),
        "device_kind": dev.device_kind,
    }


def serialize_compiled(compiled) -> bytes | None:
    """Pickle an AOT-compiled jax executable (``jit(...).lower().compile()``)
    into a self-contained blob, or ``None`` when this executable cannot be
    serialized (the caller then stores an artifact-free entry)."""
    try:
        from jax.experimental import serialize_executable

        payload, in_tree, out_tree = serialize_executable.serialize(compiled)
        return pickle.dumps((payload, in_tree, out_tree))
    except Exception:  # noqa: BLE001 — any failure means "no artifact", never a crash
        return None


def deserialize_compiled(blob: bytes):
    """Rebuild the callable from :func:`serialize_compiled`'s blob.  Raises
    on mismatch — the caller treats that as a miss and recompiles."""
    from jax.experimental import serialize_executable

    payload, in_tree, out_tree = pickle.loads(blob)
    return serialize_executable.deserialize_and_load(payload, in_tree, out_tree)


class CompileCache:
    """On-disk, file-locked compile cache shared across processes and runs.

    ``root`` is the cache directory (created on first use).  Entry files are
    ``<key>.pkl``; in-flight claims are ``<key>.claim``; the advisory lock
    serializing claim-steals is ``.lock``.  All methods are safe to call
    concurrently from threads and processes — the protocol is built from
    atomic filesystem operations, with ``flock`` only narrowing the
    stale-claim steal race.
    """

    def __init__(
        self,
        root: str,
        *,
        claim_timeout_s: float = 120.0,
        poll_s: float = 0.05,
        fingerprint: dict | None = None,
    ):
        self.root = str(root)
        self.claim_timeout_s = float(claim_timeout_s)
        self.poll_s = float(poll_s)
        self._fingerprint = fingerprint

    # -- identity --------------------------------------------------------------
    def fingerprint(self) -> dict:
        if self._fingerprint is None:
            self._fingerprint = runtime_fingerprint()
        return self._fingerprint

    def key(self, **identity: Any) -> str:
        """Stable hex key over the JSON-able identity fields (kernel name,
        input sizes, geometry tuple, ...) plus the runtime fingerprint."""
        d = {**identity, "fp": self.fingerprint()}
        blob = json.dumps(d, sort_keys=True, default=str)
        return hashlib.sha256(blob.encode("utf-8")).hexdigest()[:32]

    # -- paths -----------------------------------------------------------------
    def _entry_path(self, key: str) -> str:
        return os.path.join(self.root, f"{key}.pkl")

    def _claim_path(self, key: str) -> str:
        return os.path.join(self.root, f"{key}.claim")

    @contextmanager
    def _locked(self):
        """Advisory exclusive lock on the cache-wide lock file (no-op where
        ``fcntl`` is unavailable — O_EXCL/rename atomicity still holds; only
        the stale-claim steal gets racier)."""
        if fcntl is None:  # pragma: no cover - non-POSIX hosts
            yield
            return
        os.makedirs(self.root, exist_ok=True)
        fd = os.open(os.path.join(self.root, ".lock"), os.O_CREAT | os.O_RDWR)
        try:
            fcntl.flock(fd, fcntl.LOCK_EX)
            yield
        finally:
            fcntl.flock(fd, fcntl.LOCK_UN)
            os.close(fd)

    # -- entries ---------------------------------------------------------------
    def get(self, key: str) -> dict | None:
        """The entry for ``key``, or ``None``.  Entries are dicts with
        ``status`` (``"ok"`` / ``"invalid"``), ``reason`` / ``stage`` for
        invalid ones, and ``artifact`` (serialized executable bytes or
        ``None``).  Unreadable or wrong-runtime entries are misses."""
        try:
            with open(self._entry_path(key), "rb") as f:
                entry = pickle.load(f)
        except (OSError, pickle.UnpicklingError, EOFError, AttributeError):
            return None
        if not isinstance(entry, dict) or entry.get("fp") != self.fingerprint():
            return None
        return entry

    def put(
        self,
        key: str,
        *,
        status: str,
        reason: str | None = None,
        stage: str | None = None,
        artifact: bytes | None = None,
    ) -> None:
        """Atomically publish an entry (tmp file + ``os.replace``)."""
        entry = {
            "status": str(status),
            "reason": reason,
            "stage": stage,
            "artifact": artifact,
            "fp": self.fingerprint(),
        }
        os.makedirs(self.root, exist_ok=True)
        fd, tmp = tempfile.mkstemp(prefix=f"{key}.", suffix=".tmp", dir=self.root)
        try:
            with os.fdopen(fd, "wb") as f:
                pickle.dump(entry, f)
            os.replace(tmp, self._entry_path(key))
        except BaseException:
            try:
                os.remove(tmp)
            except OSError:
                pass
            raise

    # -- in-flight claims ------------------------------------------------------
    def claim(self, key: str) -> bool:
        """Try to become the one process compiling ``key``.  ``True`` means
        the caller owns the compile and MUST :meth:`release` (after
        :meth:`put`); ``False`` means someone else holds a live claim — use
        :meth:`wait`."""
        os.makedirs(self.root, exist_ok=True)
        path = self._claim_path(key)
        for attempt in range(2):
            try:
                fd = os.open(path, os.O_CREAT | os.O_EXCL | os.O_WRONLY)
            except FileExistsError:
                if attempt or not self._steal_stale_claim(path):
                    return False
                continue  # stale claim removed — race for a fresh one
            with os.fdopen(fd, "w") as f:
                f.write(str(os.getpid()))
            return True
        return False

    def _steal_stale_claim(self, path: str) -> bool:
        """Remove ``path`` if its holder looks dead (mtime older than the
        claim timeout).  Serialized under the cache lock so at most one
        waiter steals; returns whether the claim is gone."""
        # wall clock against the claim file's mtime — pure liveness policy,
        # never part of any measured value
        now = time.time()  # repro: allow[DET001]
        try:
            age = now - os.path.getmtime(path)
        except OSError:
            return True  # already released
        if age <= self.claim_timeout_s:
            return False
        with self._locked():
            try:
                if now - os.path.getmtime(path) > self.claim_timeout_s:
                    os.remove(path)
            except OSError:
                pass  # another waiter stole it first — equally gone
        return not os.path.exists(path)

    def release(self, key: str) -> None:
        try:
            os.remove(self._claim_path(key))
        except OSError:
            pass

    def wait(self, key: str, timeout_s: float | None = None) -> dict | None:
        """Poll for the entry another process claimed.  Returns the entry,
        or ``None`` when the claim holder vanished without publishing or the
        timeout elapsed (the caller then compiles locally)."""
        deadline = monotonic() + (
            timeout_s if timeout_s is not None else self.claim_timeout_s
        )
        claim = self._claim_path(key)
        while True:
            entry = self.get(key)
            if entry is not None:
                return entry
            if not os.path.exists(claim):
                return self.get(key)  # holder finished or died; final look
            if monotonic() >= deadline:
                return None
            time.sleep(self.poll_s)

    # -- convenience -----------------------------------------------------------
    def compute(self, key: str, fn: Callable[[], dict]) -> tuple[dict, bool]:
        """Get-or-compute with cross-process dedup: serve the entry if
        present; otherwise claim and run ``fn()`` (which returns the entry
        kwargs to :meth:`put`); if another process holds the claim, wait it
        out and serve its entry.  Returns ``(entry, computed_here)``."""
        entry = self.get(key)
        if entry is not None:
            return entry, False
        if self.claim(key):
            try:
                # double-check under the claim: another process may have
                # published between our miss and our claim (its release is
                # what let this claim succeed) — entries are published
                # before claims are released, so this read is authoritative
                # and each key is computed exactly once across processes
                entry = self.get(key)
                if entry is not None:
                    return entry, False
                kwargs = fn()
                self.put(key, **kwargs)
            finally:
                self.release(key)
            return self.get(key), True
        entry = self.wait(key)
        if entry is not None:
            return entry, False
        # claim holder wedged past the timeout: compute without publishing
        kwargs = fn()
        return {**kwargs, "fp": self.fingerprint()}, True
