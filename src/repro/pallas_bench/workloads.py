"""Workload plumbing: materialize deterministic per-kernel problems.

A :class:`PallasWorkload` binds one kernel's :class:`KernelBenchSpec`
(published by the kernel package itself — see ``kernels/*/ops.py``) to a
concrete image size and input seed.  Everything a workload needs is derivable
from ``(kernel, x, y, input_seed)``, i.e. from a JSON-serialized
:class:`~repro.core.api.TuningSpec` alone, so shard workers rebuild
bit-identical problems without any live objects crossing process boundaries.

Input arrays are drawn from ``np.random.default_rng(stable_seed(...))`` —
``stable_seed`` is crc32-based and process-invariant (Python's ``hash`` is
salted), the same discipline the matrix runner uses for experiment seeds.
"""

from __future__ import annotations

from dataclasses import dataclass, field

from ..core.runner import stable_seed
from ..kernels import KERNEL_BENCHES
from ..kernels.common import Config, KernelBenchSpec, use_interpret

#: default problem size — small enough that interpret mode (Python-level
#: grid execution) measures a config in milliseconds, large enough that the
#: tunable geometry actually changes the grid.
DEFAULT_X = 128
DEFAULT_Y = 256


@dataclass(frozen=True)
class PallasWorkload:
    """One kernel bound to a concrete problem: the unit pallas_bench measures."""

    bench: KernelBenchSpec = field(repr=False)
    x: int = DEFAULT_X
    y: int = DEFAULT_Y
    input_seed: int = 0

    @property
    def name(self) -> str:
        return self.bench.name

    def materialize(self) -> tuple:
        """Deterministic input arrays for this problem (pure function of the
        workload fields — any process gets byte-identical data)."""
        seed = stable_seed("pallas_inputs", self.name, self.x, self.y, self.input_seed)
        return tuple(self.bench.make_inputs(self.x, self.y, seed))

    def run(self, inputs: tuple, cfg: Config):
        """Launch the kernel; returns the (possibly in-flight) device array.
        The measurement layer owns fencing and timing."""
        return self.bench.run(inputs, cfg, self.x, self.y)

    def interpret(self) -> bool:
        """Whether ``pl.pallas_call`` runs in interpret mode here (CPU) —
        the kernels decide via ``kernels.common.use_interpret``."""
        return use_interpret()


def make_workload(
    kernel: str,
    x: int = DEFAULT_X,
    y: int = DEFAULT_Y,
    input_seed: int = 0,
) -> PallasWorkload:
    """Resolve a kernel id to a measurable workload."""
    if kernel not in KERNEL_BENCHES:
        raise KeyError(
            f"unknown pallas kernel {kernel!r}; have {sorted(KERNEL_BENCHES)}"
        )
    if x < 8 or y < 128:
        raise ValueError(
            f"problem size ({x}, {y}) below the minimum f32 tile (8, 128)"
        )
    return PallasWorkload(bench=KERNEL_BENCHES[kernel], x=int(x), y=int(y),
                          input_seed=int(input_seed))
