from .pipeline import DataConfig, make_decode_inputs, make_train_batch

__all__ = ["DataConfig", "make_decode_inputs", "make_train_batch"]
