"""Deterministic synthetic LM data pipeline.

Production posture: batches are a pure function of (seed, step, shard), so
  * resume-after-failure replays exactly the right data (the checkpoint
    stores only the step number),
  * each host materializes only its addressable shard
    (``global_batch(...)`` uses make_array_from_callback when a mesh is
    given; on this single-host container that degenerates gracefully).

Token streams are drawn from a Zipfian-ish distribution so the loss curve
is non-trivial (uniform tokens make CE flat at log V from step 0).
"""

from __future__ import annotations

import zlib
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig


@dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    zipf_a: float = 1.2


def _tokens_for(
    cfg: DataConfig, vocab: int, shape: tuple, step: int, tag: str
) -> np.ndarray:
    rng = np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, zlib.crc32(tag.encode())])
    )
    # zipf with rejection to stay inside vocab
    draw = rng.zipf(cfg.zipf_a, size=shape).astype(np.int64)
    return (draw % vocab).astype(np.int32)


def make_train_batch(
    cfg: DataConfig, arch: ArchConfig, seq_len: int, batch: int, step: int
) -> dict:
    """Next-token LM batch: labels are tokens shifted left."""
    if arch.family == "encdec":
        frames = _tokens_for(cfg, 997, (batch, seq_len), step, "frames")
        # frame embeddings via a fixed random projection of frame ids (stub
        # frontend: deterministic, cheap, well-conditioned)
        rng = np.random.default_rng(cfg.seed + 1)
        table = rng.normal(0, 0.02, size=(997, arch.d_model)).astype(np.float32)
        src = table[frames]
        dec = _tokens_for(cfg, arch.vocab, (batch, arch.encdec.dec_len + 1), step, "dec")
        return {
            "src_embeds": jnp.asarray(src),
            "dec_tokens": jnp.asarray(dec[:, :-1]),
            "dec_labels": jnp.asarray(dec[:, 1:]),
        }
    toks = _tokens_for(cfg, arch.vocab, (batch, seq_len + 1), step, "lm")
    return {
        "tokens": jnp.asarray(toks[:, :-1]),
        "labels": jnp.asarray(toks[:, 1:]),
    }


def make_decode_inputs(
    cfg: DataConfig, arch: ArchConfig, batch: int, step: int, fill: int
) -> dict:
    tok = _tokens_for(cfg, arch.vocab, (batch, 1), step, "decode")
    return {
        "tokens": jnp.asarray(tok),
        "cache_len": jnp.full((batch,), fill, jnp.int32),
    }
