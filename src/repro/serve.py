"""Stable serving facade: ``repro.serve.best_config(...)``.

The one-import answer to "give me the best config for this kernel on this
geometry and device" — backed by the winners index :mod:`repro.serving`
maintains inside the measurement store::

    import repro.serve

    store, kind = repro.serve.open_serve_store("serve/store.sqlite")
    res = repro.serve.best_config(store, "add", 8192, 8192, "v5e")
    if res.status in ("hit", "stale", "nearest"):
        launch(res.config)

See :mod:`repro.serving` for the query semantics (hit / stale / nearest /
miss), the job queue behind enqueue-on-miss, and the fleet workers that
fill misses in.
"""

from __future__ import annotations

from .serving.api import (
    ServeResult,
    best_config,
    default_miss_spec,
    open_serve_store,
    store_kind_for_path,
)

__all__ = [
    "ServeResult",
    "best_config",
    "default_miss_spec",
    "open_serve_store",
    "store_kind_for_path",
]
