from .elastic import degraded_mesh, reshard
from .fault_tolerance import (
    InjectedFailure,
    RunnerConfig,
    RunnerReport,
    StragglerEvent,
    TrainingRunner,
)

__all__ = [
    "InjectedFailure",
    "RunnerConfig",
    "RunnerReport",
    "StragglerEvent",
    "TrainingRunner",
    "degraded_mesh",
    "reshard",
]
