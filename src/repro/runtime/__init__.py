from .fault_tolerance import (
    InjectedFailure,
    RunnerConfig,
    RunnerReport,
    StragglerEvent,
    TrainingRunner,
)
from .elastic import degraded_mesh, reshard

__all__ = [
    "InjectedFailure",
    "RunnerConfig",
    "RunnerReport",
    "StragglerEvent",
    "TrainingRunner",
    "degraded_mesh",
    "reshard",
]
