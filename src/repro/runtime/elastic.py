"""Elastic rescaling: move a train state between meshes.

Shardings in this framework are *derived* (logical axes x rules x mesh),
never stored — so elastic scale-down/up is: build the new mesh, recompute
shardings, device_put the restored state.  ``reshard`` implements that;
``degraded_mesh`` builds the standard fallback meshes (lose a pod -> single
pod; lose data rows -> shrink the data axis) used by the elasticity test
and the multi-pod runbook in launch/.
"""

from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh

from ..sharding.rules import ShardingRules


def degraded_mesh(devices: np.ndarray, lost_fraction: float = 0.5) -> Mesh:
    """Rebuild the largest (data, model) mesh from surviving devices."""
    devs = devices.reshape(-1)
    n = len(devs)
    keep = max(1, int(n * (1.0 - lost_fraction)))
    # largest power-of-two split
    model = 1
    while model * 2 <= min(16, keep) and keep % (model * 2) == 0:
        model *= 2
    data = keep // model
    return Mesh(devs[:keep].reshape(data, model), ("data", "model"))


def reshard(state, axes_tree, rules: ShardingRules, new_mesh: Mesh):
    """device_put every leaf with shardings recomputed for ``new_mesh``."""

    def one(axes, leaf):
        sh = rules.sharding_for(tuple(axes), leaf.shape, new_mesh)
        return jax.device_put(leaf, sh)

    return jax.tree_util.tree_map(
        one, axes_tree, state,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(a, (str, type(None))) for a in x
        ),
    )
