"""Fault-tolerant training runtime.

``TrainingRunner`` wraps the jitted train step with the operational layer a
1000+-node fleet needs:

  * periodic asynchronous checkpoints (atomic; resume picks up the exact
    step, and the data pipeline is a pure function of the step, so the
    token stream replays identically),
  * automatic restore-on-start,
  * a straggler watchdog: per-step wall times feed a rolling median; any
    step slower than ``straggler_factor`` x median raises a
    :class:`StragglerEvent` through the callback (on a real fleet this is
    where you evict/re-slice the slow host — here it is logged and counted),
  * failure injection for tests (``fail_at_step``) proving the
    checkpoint/restart path end-to-end,
  * an elastic-rescale hook (see repro.runtime.elastic): on mesh shrink the
    same checkpoint restores onto the reduced mesh because shardings are
    recomputed from logical axes, never hard-coded device ids.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np

from ..checkpoint.checkpointer import AsyncCheckpointer, latest_step, restore


class InjectedFailure(RuntimeError):
    pass


@dataclass
class StragglerEvent:
    step: int
    step_time: float
    median: float


@dataclass
class RunnerConfig:
    ckpt_dir: str
    ckpt_every: int = 50
    keep: int = 3
    straggler_factor: float = 3.0
    fail_at_step: int | None = None      # test hook


@dataclass
class RunnerReport:
    steps_run: int = 0
    restored_from: int | None = None
    stragglers: list = field(default_factory=list)
    losses: list = field(default_factory=list)


class TrainingRunner:
    def __init__(self, cfg: RunnerConfig, train_step, make_batch):
        """train_step: (state, batch) -> (state, metrics);
        make_batch: step -> batch (pure function of the step)."""
        self.cfg = cfg
        self.train_step = train_step
        self.make_batch = make_batch
        self.ckpt = AsyncCheckpointer(cfg.ckpt_dir, keep=cfg.keep)

    def run(self, state, n_steps: int, start_step: int = 0,
            on_straggler=None) -> tuple[dict, RunnerReport]:
        report = RunnerReport()
        # resume if a checkpoint exists
        last = latest_step(self.cfg.ckpt_dir)
        if last is not None and last > start_step:
            state, start_step = restore(self.cfg.ckpt_dir, state, last)
            report.restored_from = last
        times: list[float] = []
        step = start_step
        try:
            while step < n_steps:
                if self.cfg.fail_at_step is not None and step == self.cfg.fail_at_step:
                    raise InjectedFailure(f"injected failure at step {step}")
                t0 = time.perf_counter()
                batch = self.make_batch(step)
                state, metrics = self.train_step(state, batch)
                loss = float(np.asarray(metrics["loss"]))
                dt = time.perf_counter() - t0
                times.append(dt)
                report.losses.append(loss)
                med = float(np.median(times[-20:]))
                if len(times) > 5 and dt > self.cfg.straggler_factor * med:
                    ev = StragglerEvent(step, dt, med)
                    report.stragglers.append(ev)
                    if on_straggler:
                        on_straggler(ev)
                step += 1
                report.steps_run += 1
                if step % self.cfg.ckpt_every == 0:
                    self.ckpt.save(step, state)
        finally:
            self.ckpt.wait()
        self.ckpt.save(step, state)
        self.ckpt.wait()
        return state, report
