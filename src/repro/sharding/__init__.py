from .rules import DEFAULT_RULES, ShardingRules, batch_spec

__all__ = ["DEFAULT_RULES", "ShardingRules", "batch_spec"]
