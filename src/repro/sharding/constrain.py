"""Activation sharding constraints (sequence-parallel residual stream).

Between layers the residual stream x (B, S, D) is constrained to
    B -> (pod, data),  S -> model,  D -> replicated
i.e. Megatron-style sequence parallelism: scan-saved residuals shrink by
the TP degree (without this, 48 x (8, 4096, 8192) bf16 carries = 24 GB/chip
on chameleon train_4k — over v5e HBM).  XLA inserts the all-gather before
attention (which needs the full sequence) and the reduce-scatter after the
output projection.

``constrain`` is a no-op when no mesh context is active (CPU smoke tests)
or when the dim does not divide the axis, so model code can call it
unconditionally.
"""

from __future__ import annotations

import jax
import numpy as np
from jax.sharding import PartitionSpec


def _active_mesh():
    try:
        m = jax.sharding.get_abstract_mesh()
        if m is not None and not m.empty:
            return m
    except Exception:
        pass
    try:
        from jax.interpreters import pxla

        m = pxla.thread_resources.env.physical_mesh
        if m is not None and not m.empty:
            return m
    except Exception:
        pass
    return None


#: §Perf H4 — FSDP weight-gather mode.  XLA's SPMD partitioner sometimes
#: contracts einsums against the FSDP-sharded weight dim and ALL-REDUCES the
#: activation-sized partial sums (e.g. 86 GB/device/layer fp32 on olmoe
#: train_4k) instead of all-gathering the far smaller weights.  When this
#: flag is on, models constrain each layer's weights — cast to bf16 — to
#: their sharding WITHOUT the data axis at the top of the scan body, which
#: forces a (cheap, bf16) weight all-gather and makes every contraction
#: local.  Toggled by benchmarks/hillclimb.py; default off (baseline).
FSDP_GATHER_WEIGHTS = False


def gather_layer_weights(lp_tree, axes_tree):
    """Constrain per-layer weights to a no-data-axis sharding (see above).

    axes_tree: logical axes per leaf with the leading "layers" dim already
    stripped.  No-op without an active mesh or when the flag is off.
    """
    if not FSDP_GATHER_WEIGHTS:
        return lp_tree
    mesh = _active_mesh()
    if mesh is None:
        return lp_tree
    from jax import numpy as jnp

    from .rules import ShardingRules

    rules = ShardingRules().with_overrides(embed=())

    def one(axes, p):
        v = p.astype(jnp.bfloat16) if p.dtype == jnp.float32 else p
        spec = rules.spec_for(tuple(axes), v.shape, mesh)
        return jax.lax.with_sharding_constraint(v, spec)

    return jax.tree_util.tree_map(
        one, axes_tree, lp_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(a, (str, type(None))) for a in x
        ),
    )


def strip_layer_axis(axes_tree):
    """('layers', a, b, ...) -> (a, b, ...) for every leaf."""
    return jax.tree_util.tree_map(
        lambda axes: tuple(a for a in axes if a != "layers"),
        axes_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(a, (str, type(None))) for a in x
        ),
    )


def constrain_residual(x):
    """x: (B, S, D) residual stream -> batch/data + sequence/model."""
    mesh = _active_mesh()
    if mesh is None or x.ndim != 3:
        return x
    b, s, _ = x.shape
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    nb = int(np.prod([mesh.shape[a] for a in batch_axes])) if batch_axes else 1
    parts: list = [None, None, None]
    if batch_axes and b % nb == 0:
        parts[0] = batch_axes if len(batch_axes) > 1 else batch_axes[0]
    if "model" in mesh.axis_names and s > 1 and s % mesh.shape["model"] == 0:
        parts[1] = "model"
    return jax.lax.with_sharding_constraint(x, PartitionSpec(*parts))
