"""Logical-axis sharding rules (MaxText-style).

Model code annotates every parameter dim with a *logical* axis name
(repro.models.param); this module maps logical names to *mesh* axes and
produces NamedShardings for params, optimizer state, batches and caches.

Default rule set (single pod mesh ("data", "model") and multi-pod mesh
("pod", "data", "model")):

    batch      -> ("pod", "data")     data parallel across pods x data axis
    vocab      -> "model"             embedding / logits TP
    heads      -> "model"             attention TP
    kv_heads   -> "model"             (falls back to replicated if indivisible,
                                       e.g. MQA kv=1 — XLA broadcasts)
    ffn        -> "model"             MLP TP
    experts    -> "model"             expert parallelism
    ssm_inner  -> "model"             Mamba2 inner dim TP
    q_lora/kv_lora/rope_dim -> None   MLA latents replicated (small)
    embed      -> "data" on params    FSDP weight sharding (ZeRO-3); the
                                       optimizer state inherits it
    layers     -> None                scan dim, never sharded
    kv_seq     -> "data"              decode KV caches: sequence parallelism
                                       for huge caches (long_500k B=1)

Any dim whose size does not divide its mesh axis falls back to replicated
— production behaviour (XLA requires divisibility), checked centrally here
rather than ad-hoc per model.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec


DEFAULT_RULES: dict[str, tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "vocab": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "head_dim": (),
    "ffn": ("model",),
    "experts": ("model",),
    "ssm_inner": ("model",),
    "ssm_state": (),
    "q_lora": (),
    "kv_lora": (),
    "rope_dim": (),
    # FSDP weight shard: over ALL data-parallel axes (pod included) — ZeRO-3
    # across the full DP replica set.  Param tensors have no batch dim, so
    # there is no conflict with activations' batch -> (pod, data).
    "embed": ("pod", "data"),
    "layers": (),
    "kv_seq": ("data",),
    "seq": (),
    "conv": (),
    # decode-cache-specific axes: when kv_heads doesn't divide the model
    # axis (MQA/GQA kv in {1, 8, 10}), the cache MUST still shard 16-way or
    # a 32k-cache decode cell blows past HBM (122 GiB/dev on yi-34b).  The
    # per-head feature dim always divides (128 % 16 == 0), so cache tensors
    # use these names for their trailing dims.
    "kv_head_dim": ("model",),
    "kv_lora_cache": ("model",),
    "rope_cache": ("model",),
}


@dataclass(frozen=True)
class ShardingRules:
    rules: dict = field(default_factory=lambda: dict(DEFAULT_RULES))

    def with_overrides(self, **overrides) -> "ShardingRules":
        r = dict(self.rules)
        for k, v in overrides.items():
            r[k] = tuple(v) if v else ()
        return ShardingRules(r)

    def mesh_axes_for(self, logical: str | None, mesh: Mesh) -> tuple[str, ...]:
        if logical is None:
            return ()
        axes = self.rules.get(logical, ())
        return tuple(a for a in axes if a in mesh.axis_names)

    def spec_for(
        self, axes: tuple, shape: tuple, mesh: Mesh
    ) -> PartitionSpec:
        """PartitionSpec for one array given its logical axes + shape.

        Falls back to replication per-dim when the dim size does not divide
        the mesh-axis product, and never assigns one mesh axis twice.
        """
        used: set[str] = set()
        parts = []
        for dim, logical in zip(shape, axes, strict=False):
            mesh_axes = self.mesh_axes_for(logical, mesh)
            mesh_axes = tuple(a for a in mesh_axes if a not in used)
            if mesh_axes:
                total = int(np.prod([mesh.shape[a] for a in mesh_axes]))
                if dim % total == 0:
                    used.update(mesh_axes)
                    parts.append(mesh_axes if len(mesh_axes) > 1 else mesh_axes[0])
                    continue
                # try a prefix of the axes (e.g. batch=("pod","data") with a
                # batch that only divides "pod")
                ok = None
                for cut in range(len(mesh_axes) - 1, 0, -1):
                    sub = mesh_axes[:cut]
                    t = int(np.prod([mesh.shape[a] for a in sub]))
                    if dim % t == 0:
                        ok = sub
                        break
                if ok:
                    used.update(ok)
                    parts.append(ok if len(ok) > 1 else ok[0])
                    continue
            parts.append(None)
        return PartitionSpec(*parts)

    def sharding_for(
        self, axes: tuple, shape: tuple, mesh: Mesh
    ) -> NamedSharding:
        return NamedSharding(mesh, self.spec_for(axes, shape, mesh))

    # -- tree-level helpers ---------------------------------------------------
    def tree_shardings(self, axes_tree, abstract_tree, mesh: Mesh):
        """Matching trees of logical axes + ShapeDtypeStructs -> shardings."""
        def one(axes, arr):
            return self.sharding_for(tuple(axes), arr.shape, mesh)

        return jax.tree_util.tree_map(
            one, axes_tree, abstract_tree,
            is_leaf=lambda x: isinstance(x, tuple) and all(
                isinstance(a, (str, type(None))) for a in x
            ),
        )


def batch_spec(mesh: Mesh, extra_dims: int = 1) -> PartitionSpec:
    """(B, S, ...) activations: batch over (pod, data)."""
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return PartitionSpec(axes if len(axes) > 1 else axes[0], *([None] * extra_dims))
