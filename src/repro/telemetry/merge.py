"""Deterministic shard-trace merging (the trace twin of shard-store merging).

Workers write ``trace.shard<k>.jsonl`` beside their shard stores; the parent
absorbs them into its ``trace.jsonl`` when the pool joins — and, after a
kill, on the next resumed run (:meth:`Telemetry.recover`).  The merge is an
append: shard files in ascending shard order, each file's internal line
order (its writer's ``seq`` order) preserved, then the file is deleted.
Merging the same shard files into the same parent therefore always produces
the same bytes — the property ``tests/test_telemetry.py`` pins.

Events are never rewritten: ``(src, seq)`` already identifies a writer's
stream, and timestamps are only comparable within one ``src`` anyway
(monotonic epochs differ across processes), so interleaving by time would
fabricate an ordering the data cannot support.
"""

from __future__ import annotations

import os


def absorb_traces(telemetry, paths) -> int:
    """Append each existing trace file in ``paths`` (given order) onto
    ``telemetry``'s file; delete absorbed files.  Returns the count."""
    existing = [p for p in paths if p and os.path.exists(p)]
    if not existing:
        return 0
    with telemetry._lock:
        if telemetry._fh is not None:
            telemetry._fh.close()
            telemetry._fh = None
        d = telemetry.dir
        if d:
            os.makedirs(d, exist_ok=True)
        with open(telemetry.path, "a", encoding="utf-8") as out:
            for path in existing:
                with open(path, encoding="utf-8") as f:
                    data = f.read()
                if data and not data.endswith("\n"):
                    data += "\n"   # a torn final line must not glue onto ours
                out.write(data)
                os.remove(path)
    return len(existing)
