"""Post-hoc trace analysis: the tables behind ``python -m repro.telemetry``.

Consumes the event stream (:mod:`.events`) and produces plain data the CLI
renders and the report layer embeds: merged counter totals, the per-cell
stage breakdown, per-stage duration percentiles, the top-N slowest compiles,
and the invalid-config histogram.

Counter semantics: every writer emits ONE cumulative ``counters`` snapshot
per lifetime (on ``close()``), so summing all ``counters`` events is correct
across shards AND across kill/resume sessions (each lifetime's increments
are counted exactly once).  ``totals`` events additionally carry the
parent's merged view (including worker counters returned in-band through
``UnitResult.counters``); when present, the last one wins for display.
"""

from __future__ import annotations

import numpy as np

from .events import read_run


def sum_counters(events: list[dict]) -> dict:
    """Merged counter totals: the last ``totals`` event if any (the parent's
    authoritative merge), else the sum of all ``counters`` snapshots."""
    totals = [e for e in events if e.get("ev") == "totals"]
    if totals:
        return dict(totals[-1].get("counters", {}))
    acc: dict = {}
    for e in events:
        if e.get("ev") == "counters":
            for k, v in e.get("counters", {}).items():
                acc[k] = acc.get(k, 0) + v
    return acc


def cell_table(events: list[dict]) -> list[dict]:
    """Per-cell aggregates from the parent's ``cell`` events (last per cell
    wins — a resumed run re-emits its cells with the merged numbers)."""
    cells: dict[tuple, dict] = {}
    for e in events:
        if e.get("ev") == "cell":
            cells[(e.get("algo"), e.get("sample_size"))] = e
    return [
        {
            "algo": algo,
            "sample_size": s,
            "n_experiments": e.get("n_experiments"),
            "wall_s": e.get("wall_s", 0.0),
            "compile_s": e.get("compile_s", 0.0),
            "measure_s": e.get("measure_s", 0.0),
        }
        for (algo, s), e in sorted(
            cells.items(), key=lambda kv: (str(kv[0][0]), kv[0][1] or 0)
        )
    ]


def stage_percentiles(events: list[dict]) -> dict[str, dict]:
    """Duration percentiles per pipeline stage (seconds), from ``stage``
    events across every writer."""
    durs: dict[str, list[float]] = {}
    for e in events:
        if e.get("ev") == "stage" and "dur" in e:
            durs.setdefault(str(e.get("stage")), []).append(float(e["dur"]))
    out: dict[str, dict] = {}
    for stage, vals in sorted(durs.items()):
        a = np.asarray(vals, dtype=np.float64)
        out[stage] = {
            "count": int(a.size),
            "total_s": float(a.sum()),
            "p50": float(np.percentile(a, 50)),
            "p90": float(np.percentile(a, 90)),
            "p99": float(np.percentile(a, 99)),
            "max": float(a.max()),
        }
    return out


def slowest_compiles(events: list[dict], top: int = 10) -> list[dict]:
    """The ``top`` slowest compile-stage executions, with the geometry key
    that compiled (the 'what is Mosaic chewing on' table)."""
    compiles = [
        e for e in events if e.get("ev") == "stage" and e.get("stage") == "compile"
    ]
    compiles.sort(key=lambda e: -float(e.get("dur", 0.0)))
    return [
        {
            "dur": float(e.get("dur", 0.0)),
            "key": e.get("key"),
            "src": e.get("src"),
        }
        for e in compiles[: max(0, top)]
    ]


def invalid_histogram(counters: dict) -> dict[str, int]:
    """``invalid.<rule>`` counters -> ``{rule: count}`` (validity rules by
    reason prefix — align/block/grid/vmem — plus compile/run failures)."""
    return {
        k.split(".", 1)[1]: int(v)
        for k, v in sorted(counters.items())
        if k.startswith("invalid.")
    }


def serving_counters(counters: dict) -> dict:
    """The serving/fleet slice of the counter totals: query outcomes
    (``serve.hits`` / ``serve.misses`` / ``serve.nearest`` / ``serve.stale``
    / ``serve.enqueued``), the ``serve.queue_depth`` gauge, and the fleet's
    ``fleet.*`` progress counters."""
    return {
        k: counters[k]
        for k in sorted(counters)
        if k.startswith(("serve.", "fleet."))
    }


def summarize(run_dir: str, top: int = 10) -> dict:
    """Everything the ``summarize`` subcommand renders, as plain data."""
    events = read_run(run_dir)
    counters = sum_counters(events)
    units_done = sum(
        1 for e in events if e.get("ev") == "end" and e.get("span") == "unit"
    )
    experiments_done = sum(
        1 for e in events if e.get("ev") == "end" and e.get("span") == "experiment"
    )
    return {
        "n_events": len(events),
        "units_done": units_done,
        "experiments_done": experiments_done,
        "counters": counters,
        "cells": cell_table(events),
        "stages": stage_percentiles(events),
        "slowest_compiles": slowest_compiles(events, top=top),
        "invalid": invalid_histogram(counters),
        "serving": serving_counters(counters),
    }


# ---------------------------------------------------------------- rendering


def _table(rows: list[list[str]], header: list[str]) -> str:
    widths = [
        max(len(str(r[i])) for r in [header, *rows]) for i in range(len(header))
    ]
    def fmt(row):
        return "  ".join(str(c).ljust(w) for c, w in zip(row, widths, strict=True))
    lines = [fmt(header), fmt(["-" * w for w in widths])]
    lines.extend(fmt(r) for r in rows)
    return "\n".join(lines)


def render_summary(s: dict) -> str:
    """Human-readable text for one :func:`summarize` result."""
    out = [
        f"events: {s['n_events']}   units done: {s['units_done']}   "
        f"experiments done: {s['experiments_done']}"
    ]
    if s["cells"]:
        rows = [
            [c["algo"], c["sample_size"], c["n_experiments"],
             f"{c['wall_s']:.3f}", f"{c['compile_s']:.3f}",
             f"{c['measure_s']:.3f}"]
            for c in s["cells"]
        ]
        out.append("\nper-cell stage breakdown (seconds)")
        out.append(_table(rows, ["algo", "S", "E", "wall", "compile", "measure"]))
    if s["stages"]:
        rows = [
            [name, st["count"], f"{st['total_s']:.3f}", f"{st['p50']*1e3:.3f}",
             f"{st['p90']*1e3:.3f}", f"{st['p99']*1e3:.3f}",
             f"{st['max']*1e3:.3f}"]
            for name, st in s["stages"].items()
        ]
        out.append("\nper-stage durations (count, total s, p50/p90/p99/max ms)")
        out.append(_table(rows, ["stage", "n", "total", "p50", "p90", "p99", "max"]))
    if s["slowest_compiles"]:
        rows = [
            [f"{c['dur']*1e3:.3f}", c["src"] or "-", c["key"] or "-"]
            for c in s["slowest_compiles"]
        ]
        out.append("\nslowest compiles (ms)")
        out.append(_table(rows, ["ms", "src", "geometry"]))
    if s["invalid"]:
        rows = [[rule, n] for rule, n in s["invalid"].items()]
        out.append("\ninvalid configs by rule")
        out.append(_table(rows, ["rule", "count"]))
    if s["serving"]:
        rows = [[k, s["serving"][k]] for k in sorted(s["serving"])]
        out.append("\nserving / fleet")
        out.append(_table(rows, ["counter", "total"]))
    if s["counters"]:
        rows = [[k, s["counters"][k]] for k in sorted(s["counters"])]
        out.append("\ncounter totals")
        out.append(_table(rows, ["counter", "total"]))
    return "\n".join(out)
