"""The trace event schema + the tolerant JSONL reader every consumer shares.

A trace is an append-only JSONL file: one JSON object per line, written by
one :class:`~repro.telemetry.tracer.Telemetry` writer per process (the
parent writes ``trace.jsonl``, worker *k* writes ``trace.shard<k>.jsonl``
beside its shard store; the parent appends the shard files into the main
trace at join — see :mod:`.merge`).

Common fields on every event:

``t``    timestamp from the injectable ``repro.core.clock`` seam
         (monotonic seconds; epochs are per-process, so timestamps are only
         comparable WITHIN one ``src``)
``seq``  per-writer sequence number (total order within a ``src``)
``src``  writer id: ``"main"`` or ``"shard<k>"``
``ev``   event type (below)

Event types:

``begin`` / ``end``  span boundaries; ``span`` names the level of the fixed
                     hierarchy matrix > cell > unit > round > experiment >
                     stage.  ``end`` carries ``dur`` (seconds) and ``ok:
                     false`` when the span died on an exception.  ``cell``
                     spans are not emitted live (a cell's units may run on
                     several workers); consumers derive them by grouping
                     unit spans, and the parent emits aggregate ``cell``
                     events at merge time.
``stage``            a completed pipeline-stage interval (screen / compile /
                     time / record) with ``dur`` and an optional config
                     ``key`` — the high-frequency complete-span form.
``plan``             emitted once by the parent when the unit plan is fixed:
                     ``units`` (keys), ``units_total``,
                     ``experiments_total``, and on resume
                     ``units_done_resume`` / ``experiments_done_resume``.
``counters``         a cumulative counter snapshot for this writer (the
                     final one is emitted on ``close()``).
``totals``           the parent's merged counter totals across all writers.
``gauge``            an instantaneous value (e.g. prefetch in-flight depth).
``cell``             per-cell aggregate (wall/compile/measure seconds).
"""

from __future__ import annotations

import json
import os
import re

#: file names (the run directory is the unit of discovery for the CLI)
TRACE_FILE = "trace.jsonl"
SHARD_RE = re.compile(r"^trace\.shard(\d+)\.jsonl$")

#: the fixed span hierarchy, outermost first ("cell" is derived, "stage"
#: events are the innermost level in complete-span form)
SPAN_LEVELS = ("matrix", "cell", "unit", "round", "experiment", "stage")

PIPELINE_STAGES = ("screen", "compile", "time", "record")


def shard_file(trace_path: str, shard: int) -> str:
    """``trace.shard<k>.jsonl`` beside ``trace_path``."""
    d = os.path.dirname(trace_path)
    return os.path.join(d, f"trace.shard{int(shard)}.jsonl")


def trace_paths(run_dir: str) -> list[str]:
    """Every trace file of a run dir: the merged trace first, then any
    unmerged shard traces in shard order (a live run's workers are still
    writing theirs)."""
    out = []
    main = os.path.join(run_dir, TRACE_FILE)
    if os.path.exists(main):
        out.append(main)
    shards = []
    try:
        names = os.listdir(run_dir)
    except OSError:
        names = []
    for name in names:
        m = SHARD_RE.match(name)
        if m:
            shards.append((int(m.group(1)), os.path.join(run_dir, name)))
    out.extend(p for _, p in sorted(shards))
    return out


def read_events(path: str) -> list[dict]:
    """Parse one trace file, skipping malformed lines (a killed writer may
    leave a torn final line — a trace is diagnostics, never a source of
    truth, so it degrades instead of raising)."""
    events: list[dict] = []
    try:
        with open(path, encoding="utf-8") as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    ev = json.loads(line)
                except json.JSONDecodeError:
                    continue
                if isinstance(ev, dict):
                    events.append(ev)
    except OSError:
        return []
    return events


def read_run(run_dir: str) -> list[dict]:
    """All events of a run dir (merged trace + leftover shard traces)."""
    events: list[dict] = []
    for path in trace_paths(run_dir):
        events.extend(read_events(path))
    return events
