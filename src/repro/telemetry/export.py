"""Chrome trace-event export: ``about://tracing`` / Perfetto flamegraphs.

Maps the JSONL stream onto the Chrome trace-event JSON object format
(https://docs.google.com/document/d/1CvAClvFfyA5R-PhYUmn5OOQtYMH4h6I0nSsKchNAySU):

* each writer (``src``) becomes its own *process* track, named by a
  metadata event — monotonic epochs differ across processes, so every
  track's timestamps are normalized to that writer's own first event
  (cross-track alignment would be fabricated and is not attempted),
* ``begin``/``end`` span events map to ``ph: "B"``/``"E"`` (the flame
  stack: matrix > unit > round > experiment),
* ``stage`` events map to complete ``ph: "X"`` slices with ``dur``,
* ``gauge`` events map to ``ph: "C"`` counter tracks,
* ``plan`` / ``cell`` / ``counters`` / ``totals`` map to instant events
  (``ph: "i"``) carrying their payload in ``args``.
"""

from __future__ import annotations

import json
import os

from .events import read_run


def _src_order(srcs) -> list[str]:
    """main first, then shards numerically, then anything else by name."""
    def key(s):
        if s == "main":
            return (0, 0, s)
        if s.startswith("shard") and s[5:].isdigit():
            return (1, int(s[5:]), s)
        return (2, 0, s)
    return sorted(srcs, key=key)


def _name(e: dict) -> str:
    span = e.get("span") or e.get("stage") or e.get("ev")
    if e.get("span") == "experiment" and "experiment" in e:
        return f"experiment {e['experiment']}"
    if e.get("span") == "round" and "round" in e:
        return f"round {e['round']}"
    if "unit" in e and e.get("span") == "unit":
        return f"unit {e['unit']}"
    return str(span)


def _args(e: dict) -> dict:
    skip = {"t", "seq", "src", "ev", "span", "stage", "dur"}
    return {k: v for k, v in e.items() if k not in skip}


def chrome_trace(events: list[dict]) -> dict:
    """The Chrome trace-event JSON object for an event list."""
    srcs = _src_order({str(e.get("src", "main")) for e in events})
    pid = {s: i + 1 for i, s in enumerate(srcs)}
    t0 = {}
    for e in events:
        s = str(e.get("src", "main"))
        t = float(e.get("t", 0.0))
        if s not in t0 or t < t0[s]:
            t0[s] = t
    out = [
        {
            "name": "process_name",
            "ph": "M",
            "pid": pid[s],
            "tid": 0,
            "args": {"name": s},
        }
        for s in srcs
    ]
    for e in events:
        s = str(e.get("src", "main"))
        base = {
            "pid": pid[s],
            "tid": 1,
            "ts": round((float(e.get("t", 0.0)) - t0[s]) * 1e6, 3),
        }
        ev = e.get("ev")
        if ev == "begin":
            out.append({**base, "name": _name(e), "ph": "B", "args": _args(e)})
        elif ev == "end":
            out.append({**base, "name": _name(e), "ph": "E", "args": _args(e)})
        elif ev == "stage":
            out.append(
                {
                    **base,
                    "name": str(e.get("stage")),
                    "ph": "X",
                    "dur": round(float(e.get("dur", 0.0)) * 1e6, 3),
                    "args": _args(e),
                }
            )
        elif ev == "gauge":
            out.append(
                {
                    **base,
                    "name": str(e.get("gauge")),
                    "ph": "C",
                    "args": {str(e.get("gauge")): e.get("value")},
                }
            )
        else:  # plan / cell / counters / totals / unknown -> instants
            out.append(
                {
                    **base,
                    "name": str(ev),
                    "ph": "i",
                    "s": "p",
                    "args": _args(e),
                }
            )
    return {"traceEvents": out, "displayTimeUnit": "ms"}


def export_chrome(run_dir: str, out_path: str | None = None) -> str:
    """Render ``run_dir``'s trace to Chrome trace JSON; returns the path."""
    trace = chrome_trace(read_run(run_dir))
    if out_path is None:
        out_path = os.path.join(run_dir, "trace_chrome.json")
    d = os.path.dirname(out_path)
    if d:
        os.makedirs(d, exist_ok=True)
    with open(out_path, "w", encoding="utf-8") as f:
        json.dump(trace, f)
    return out_path
