"""Live progress over a run's trace stream: done/total units + ETA.

The parent's ``plan`` event fixes the denominators (``build_units`` totals,
minus what resume already served); everything after it in the merged stream
is current-session activity.  That positional rule is sound because the
trace is append-only and shard traces are only ever appended AFTER the plan
that scheduled them — a recovered pre-kill shard trace is absorbed before
the resumed session emits its plan, so stale experiment spans never inflate
the current session's progress.

Two consumers share this module: ``python -m repro.telemetry tail
[--follow]`` and the ``--progress`` reporter thread in
``benchmarks/paper_matrix.py`` (which fixes the historical silence between
journal checkpoints during ``--executor process`` runs).
"""

from __future__ import annotations

import sys
import threading
from dataclasses import dataclass

from ..core.clock import monotonic
from .events import read_run


@dataclass
class ProgressState:
    """A snapshot of matrix progress derived from the trace."""

    units_total: int | None = None
    experiments_total: int | None = None
    units_done: int = 0
    experiments_done: int = 0
    has_plan: bool = False

    @property
    def complete(self) -> bool:
        return (
            self.experiments_total is not None
            and self.experiments_total > 0
            and self.experiments_done >= self.experiments_total
        )


def scan_events(events: list[dict]) -> ProgressState:
    """Progress from an event list (see the module docstring for why the
    position of the last ``plan`` event partitions past from present)."""
    state = ProgressState()
    plan_idx = -1
    for i, e in enumerate(events):
        if e.get("ev") == "plan":
            plan_idx = i
    if plan_idx >= 0:
        plan = events[plan_idx]
        state.has_plan = True
        state.units_total = plan.get("units_total")
        state.experiments_total = plan.get("experiments_total")
        state.units_done = int(plan.get("units_done_resume", 0) or 0)
        state.experiments_done = int(plan.get("experiments_done_resume", 0) or 0)
    for e in events[plan_idx + 1 :]:
        if e.get("ev") != "end":
            continue
        if e.get("span") == "unit":
            state.units_done += 1
        elif e.get("span") == "experiment":
            state.experiments_done += 1
    return state


def scan_progress(run_dir: str) -> ProgressState:
    """Progress snapshot for a run directory (merged + live shard traces)."""
    return scan_events(read_run(run_dir))


def format_progress(state: ProgressState, eta_s: float | None = None) -> str:
    """One status line: ``units 3/8 · experiments 120/400 (30%) · ETA 45s``."""
    def frac(done, total):
        return f"{done}/{total}" if total else f"{done}/?"
    parts = [
        f"units {frac(state.units_done, state.units_total)}",
        f"experiments {frac(state.experiments_done, state.experiments_total)}",
    ]
    if state.experiments_total:
        pct = 100.0 * state.experiments_done / state.experiments_total
        parts[-1] += f" ({pct:.0f}%)"
    if eta_s is not None:
        parts.append(f"ETA {eta_s:.0f}s" if eta_s < 3600 else f"ETA {eta_s/3600:.1f}h")
    return " · ".join(parts)


class ProgressReporter:
    """Periodically prints one progress line for a run dir to ``out``.

    ETA is rate-based over the reporter's own observation window (completed
    experiments per second since it started watching) — trace timestamps
    cannot be compared across writers, so the watcher's clock is the only
    honest timeline.  ``follow()`` blocks until the run completes or
    ``stop()`` is called; ``start()`` runs it on a daemon thread (the
    ``--progress`` flag's shape).
    """

    def __init__(self, run_dir: str, interval: float = 5.0, out=None):
        self.run_dir = run_dir
        self.interval = float(interval)
        self.out = out if out is not None else sys.stderr
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None
        self._t0: float | None = None
        self._done0: int | None = None

    def eta_s(self, state: ProgressState) -> float | None:
        now = monotonic()
        if self._t0 is None:
            self._t0, self._done0 = now, state.experiments_done
            return None
        dt = now - self._t0
        delta = state.experiments_done - (self._done0 or 0)
        if dt <= 0 or delta <= 0 or not state.experiments_total:
            return None
        remaining = max(0, state.experiments_total - state.experiments_done)
        return remaining / (delta / dt)

    def tick(self) -> ProgressState:
        state = scan_progress(self.run_dir)
        line = format_progress(state, self.eta_s(state))
        print(f"[progress] {line}", file=self.out, flush=True)
        return state

    def follow(self) -> None:
        while not self._stop.is_set():
            state = self.tick()
            if state.complete:
                break
            self._stop.wait(self.interval)

    def start(self) -> "ProgressReporter":
        self._thread = threading.Thread(
            target=self.follow, name="telemetry-progress", daemon=True
        )
        self._thread.start()
        return self

    def stop(self, final_tick: bool = True) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=self.interval + 5)
            self._thread = None
        if final_tick:
            self.tick()
