"""The trace writer: spans, events, counters — one JSONL stream per writer.

:class:`Telemetry` is the enabled implementation of the sink API whose
no-op twin lives in :mod:`.null`.  One writer owns one append-only JSONL
file and stamps every event with ``(t, seq, src)`` — ``t`` from the
injectable ``repro.core.clock`` seam (so tests can drive traces on
deterministic timestamps), ``seq`` a per-writer total order, ``src`` the
writer id (``"main"`` in the parent, ``"shard<k>"`` in workers).

Writes are line-buffered and flushed per event: ``tail --follow`` and the
``--progress`` reporter read the file while the run is live, and a killed
process loses at most one torn line (the readers skip it).  All methods are
thread-safe — the pallas compile prefetcher emits stage events from pool
threads while the main thread emits timing stages.

Telemetry is a pure observability knob: nothing here feeds cache keys,
journal namespaces, or measurement values (staticcheck rule OBS001 pins
that), and a run with a writer attached produces bit-identical measurement
stores to one without.
"""

from __future__ import annotations

import json
import os
import threading
from contextlib import contextmanager

from ..core.clock import monotonic
from .events import SHARD_RE, TRACE_FILE, shard_file


class Telemetry:
    """Append-only JSONL trace writer + counters registry.

    ``path`` is the trace file (created lazily on the first event, in append
    mode — a resumed run extends the same trace).  ``src`` tags every event
    with the writer's identity.  ``clock`` overrides the timestamp source
    (default: the ``repro.core.clock`` seam).
    """

    enabled = True

    def __init__(self, path: str, *, src: str = "main", clock=None):
        self.path = str(path)
        self.src = str(src)
        self._clock = clock if clock is not None else monotonic
        self._lock = threading.Lock()
        self._fh = None
        self._seq = 0
        self._counters: dict[str, float] = {}

    # -- plumbing --------------------------------------------------------------
    @property
    def dir(self) -> str:
        return os.path.dirname(self.path) or "."

    def _emit(self, ev: str, fields: dict) -> None:
        record = {"t": round(float(self._clock()), 6), "src": self.src, "ev": ev}
        record.update(fields)
        with self._lock:
            # seq is assigned under the lock so it is a true total order for
            # this writer even with pool threads emitting concurrently
            record["seq"] = self._seq
            self._seq += 1
            line = json.dumps(record, sort_keys=True)
            if self._fh is None:
                d = self.dir
                if d:
                    os.makedirs(d, exist_ok=True)
                self._fh = open(self.path, "a", encoding="utf-8")
            self._fh.write(line + "\n")
            self._fh.flush()

    # -- spans & events --------------------------------------------------------
    @contextmanager
    def span(self, name: str, **attrs):
        """Emit ``begin``/``end`` around a code region.  The ``end`` event
        carries ``dur`` (seconds, same clock as ``t``) and ``ok: false``
        when the region raised — a wedged or crashed worker leaves either a
        dangling ``begin`` or a failed ``end``, both visible in the trace."""
        t0 = self._clock()
        self._emit("begin", {"span": name, **attrs})
        try:
            yield
        except BaseException:
            self._emit(
                "end",
                {"span": name, "dur": round(self._clock() - t0, 6),
                 "ok": False, **attrs},
            )
            raise
        self._emit(
            "end", {"span": name, "dur": round(self._clock() - t0, 6), **attrs}
        )

    def event(self, ev: str, **fields) -> None:
        """Emit one complete event of type ``ev`` (plan/cell/totals/...)."""
        self._emit(ev, fields)

    def stage(self, name: str, dur: float, **attrs) -> None:
        """A completed pipeline-stage interval (the high-frequency form:
        one line per stage execution, no begin/end pair)."""
        self._emit("stage", {"stage": name, "dur": round(float(dur), 6), **attrs})

    # -- counters & gauges -----------------------------------------------------
    def inc(self, name: str, n=1) -> None:
        with self._lock:
            self._counters[name] = self._counters.get(name, 0) + n

    def gauge(self, name: str, value) -> None:
        """Instantaneous values are emitted immediately (they are a time
        series, not a total)."""
        self._emit("gauge", {"gauge": name, "value": value})

    def counters_snapshot(self) -> dict:
        with self._lock:
            return dict(self._counters)

    def emit_counters(self) -> None:
        snap = self.counters_snapshot()
        if snap:
            self._emit("counters", {"counters": snap})

    # -- shard plumbing (multi-process / multi-device runs) --------------------
    def shard_path(self, shard: int) -> str:
        """Where worker ``shard`` writes its trace — ``trace.shard<k>.jsonl``
        beside this writer's file, mirroring the shard-store pattern."""
        return shard_file(self.path, shard)

    def shard_src(self, shard: int) -> str:
        return f"shard{int(shard)}"

    def absorb(self, paths) -> int:
        """Append shard trace files into this trace, deterministically:
        files in shard order, each file's own line order preserved.  Absorbed
        files are deleted (mirrors ``merge_shard_stores``).  Returns how
        many files were absorbed."""
        from .merge import absorb_traces  # lazy: merge imports events only

        return absorb_traces(self, paths)

    def recover(self) -> int:
        """Absorb shard traces a killed run left beside this trace (the
        kill-and-resume path; mirrors ``recover_shard_stores``)."""
        try:
            names = os.listdir(self.dir)
        except OSError:
            return 0
        leftovers = sorted(
            (int(m.group(1)), os.path.join(self.dir, n))
            for n in names
            if (m := SHARD_RE.match(n))
        )
        return self.absorb([p for _, p in leftovers])

    def close(self) -> None:
        """Flush the final counter snapshot and close the file handle."""
        self.emit_counters()
        with self._lock:
            if self._fh is not None:
                self._fh.close()
                self._fh = None


def for_run_dir(run_dir: str, *, src: str = "main") -> Telemetry:
    """The conventional writer for a results directory: ``<dir>/trace.jsonl``."""
    return Telemetry(os.path.join(run_dir, TRACE_FILE), src=src)
