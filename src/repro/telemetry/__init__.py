"""repro.telemetry — span tracing, metrics, and live progress for runs.

The observability layer: a :class:`Telemetry` writer emits append-only
JSONL trace events (spans over the fixed hierarchy matrix > cell > unit >
ask/tell round > experiment > pipeline stage, plus counters and gauges);
:data:`NULL_TELEMETRY` is the no-op default so the disabled path stays the
current code path.  Workers write ``trace.shard<k>.jsonl`` beside their
shard stores and the parent merges them deterministically at join
(:mod:`.merge`).  Consumers: ``python -m repro.telemetry`` (summarize /
tail / export), :mod:`.progress` (the ``--progress`` reporter), and the
report layer's Telemetry section.

Telemetry is a pure observability knob — never part of cache keys, journal
namespaces, or spec fingerprints (staticcheck rule OBS001), and a
telemetry-enabled run produces bit-identical measurement stores to a
disabled one.

Enable it per run::

    import repro
    from repro.core import ExperimentDesign, TuningSpec

    spec = TuningSpec(kernel="harris", algorithms=("rs", "ga"),
                      design=ExperimentDesign.scaled(budget=200))
    repro.tune_matrix(spec, out_dir="results/demo",
                      telemetry_dir="results/demo")
    # then: python -m repro.telemetry summarize results/demo
"""

from __future__ import annotations

from .events import TRACE_FILE, read_events, read_run, trace_paths
from .export import chrome_trace, export_chrome
from .null import NULL_TELEMETRY, NullTelemetry
from .progress import ProgressReporter, ProgressState, format_progress, scan_progress
from .summarize import render_summary, stage_percentiles, summarize
from .tracer import Telemetry, for_run_dir

__all__ = [
    "NULL_TELEMETRY",
    "TRACE_FILE",
    "NullTelemetry",
    "ProgressReporter",
    "ProgressState",
    "Telemetry",
    "chrome_trace",
    "export_chrome",
    "for_run_dir",
    "format_progress",
    "read_events",
    "read_run",
    "render_summary",
    "scan_progress",
    "stage_percentiles",
    "summarize",
    "trace_paths",
]
