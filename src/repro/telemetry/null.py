"""The disabled telemetry path: a no-op object with the full Telemetry API.

:data:`NULL_TELEMETRY` is the default everywhere a telemetry sink is
accepted (``TuningSession``, ``BaseMeasurement``, the engine's ``drive``):
callers never branch on "is telemetry on", they just call the sink.  The
null object is deliberately allocation-free in steady state — ``span()``
returns one shared reusable context manager regardless of arguments, every
other method is a bare ``pass`` — so the disabled path is the current code
path plus a dynamic dispatch per call site.  Hot loops that would pay even
for argument packing guard on :attr:`NullTelemetry.enabled` instead.

This module imports nothing from the rest of the package (or the repo), so
determinism-critical core modules can depend on it without import cycles.
"""

from __future__ import annotations


class _NullSpan:
    """A reusable no-op context manager (one instance serves every span)."""

    __slots__ = ()

    def __enter__(self):
        return None

    def __exit__(self, *exc) -> bool:
        return False


_NULL_SPAN = _NullSpan()


class NullTelemetry:
    """No-op stand-in for :class:`repro.telemetry.Telemetry`.

    ``enabled`` is the cheap guard hot paths check before doing any work
    (counting non-finite values, formatting attributes) purely for
    telemetry's benefit.
    """

    enabled = False
    path = None
    src = "main"

    def span(self, name, **attrs):
        return _NULL_SPAN

    def event(self, ev, **fields) -> None:
        pass

    def stage(self, name, dur, **attrs) -> None:
        pass

    def inc(self, name, n=1) -> None:
        pass

    def gauge(self, name, value) -> None:
        pass

    def counters_snapshot(self) -> dict:
        return {}

    def emit_counters(self) -> None:
        pass

    def shard_path(self, shard):
        return None

    def shard_src(self, shard):
        return None

    def absorb(self, paths) -> int:
        return 0

    def recover(self) -> int:
        return 0

    def close(self) -> None:
        pass


NULL_TELEMETRY = NullTelemetry()
