"""CLI: inspect a run's trace — ``python -m repro.telemetry <run_dir>``.

Subcommands (a bare run dir defaults to ``summarize``):

* ``summarize <run_dir> [--top N]`` — per-cell stage breakdown, per-stage
  percentiles, top-N slowest compiles, invalid-config histogram, counters.
* ``tail <run_dir> [--follow] [--interval S]`` — one progress line (or a
  live stream of them) with ETA, usable while the matrix is running.
* ``export <run_dir> [--format chrome] [-o OUT]`` — Chrome trace-event
  JSON for ``about://tracing`` / Perfetto.
"""

from __future__ import annotations

import argparse
import sys

from .export import export_chrome
from .progress import ProgressReporter, format_progress, scan_progress
from .summarize import render_summary, summarize

_COMMANDS = ("summarize", "tail", "export")


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.telemetry", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter,
    )
    sub = ap.add_subparsers(dest="cmd", required=True)
    p = sub.add_parser("summarize", help="stage/counter tables from the trace")
    p.add_argument("run_dir")
    p.add_argument("--top", type=int, default=10,
                   help="how many slowest compiles to list")
    p = sub.add_parser("tail", help="progress + ETA from the live trace")
    p.add_argument("run_dir")
    p.add_argument("--follow", action="store_true",
                   help="keep printing until the run completes")
    p.add_argument("--interval", type=float, default=5.0)
    p = sub.add_parser("export", help="convert the trace for external viewers")
    p.add_argument("run_dir")
    p.add_argument("--format", choices=("chrome",), default="chrome")
    p.add_argument("-o", "--out", default=None)

    if argv is None:
        argv = sys.argv[1:]
    if argv and argv[0] not in _COMMANDS and not argv[0].startswith("-"):
        argv = ["summarize", *argv]          # `<run_dir>` alone summarizes
    args = ap.parse_args(argv)

    if args.cmd == "summarize":
        print(render_summary(summarize(args.run_dir, top=args.top)))
    elif args.cmd == "tail":
        if args.follow:
            reporter = ProgressReporter(
                args.run_dir, interval=args.interval, out=sys.stderr
            )
            try:
                reporter.follow()
            except KeyboardInterrupt:
                pass
        else:
            print(format_progress(scan_progress(args.run_dir)))
    elif args.cmd == "export":
        print(export_chrome(args.run_dir, args.out))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
