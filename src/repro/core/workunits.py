"""Work-unit layer: a matrix run decomposed into serializable experiment units.

The paper's experiment matrix is a grid of (algorithm x sample-size) cells,
each holding E independent experiments.  A monolithic per-cell loop cannot
fan a single big-E row (S=25 has E=800 in the paper design) across workers,
and an interrupted multi-million-sample run had to rely on the measurement
cache alone to catch up.  This module makes the *unit of scheduling* explicit:

* :class:`ExperimentUnit` — a contiguous experiment range ``[exp_lo, exp_hi)``
  of one cell, JSON-serializable, with a stable :attr:`ExperimentUnit.key`.
  Experiment seeds derive from ``stable_seed(spec.seed, algo, S, e)`` with
  the *global* experiment index ``e``, so any split of a cell into units
  yields bit-identical results to the monolithic loop.
* :func:`build_units` — the deterministic decomposition policy: one unit per
  cell, then the largest units split in half until there are at least
  ``min_units`` (so N workers stay busy even on a single-cell matrix), with
  an optional hard cap ``max_unit_experiments`` for checkpoint granularity.
* :func:`merge_unit_results` — folds executor-returned fragments back into
  per-cell :class:`~repro.core.runner.CellResult` arrays, deterministically
  by unit key, verifying full contiguous coverage of every cell.
* :class:`UnitJournal` — the checkpoint layer: completed units are recorded
  as JSON payloads in the measurement store's metadata side-channel, so a
  resumed run (``run_matrix(resume=True)``) serves finished units straight
  from the journal — zero re-measurements, not even cache hits.

Executors (:mod:`repro.core.executors`) consume units and return
:class:`UnitResult` fragments; the session merges them.
"""

from __future__ import annotations

import json
import zlib
from dataclasses import dataclass, field

import numpy as np

from .clock import monotonic
from .runner import CellResult

__all__ = [
    "ExperimentUnit",
    "UnitJournal",
    "UnitResult",
    "build_units",
    "merge_unit_results",
    "unit_digest",
]


def unit_digest(unit_key: str) -> str:
    """Filesystem-safe 8-hex digest of a unit key (keys carry ``/`` + ``:``).

    The unit's cross-host identity: the serving fleet names claim and done
    marker files ``<job>.u<digest>.*`` with it, so every worker — sharing
    nothing but the queue directory — derives the same name for the same
    unit.  crc32 over the stable :attr:`ExperimentUnit.key`, so the digest
    survives process restarts and host boundaries.
    """
    return f"{zlib.crc32(unit_key.encode('utf-8')) & 0xFFFFFFFF:08x}"


@dataclass(frozen=True)
class ExperimentUnit:
    """A contiguous slice of one matrix cell's experiments.

    ``n_exp`` is the parent cell's TOTAL experiment count — part of the
    identity, so a journal entry from one design never masquerades as a unit
    of another, and the RF batched path can regenerate the full-cell
    bootstrap stream and slice its rows.
    """

    algo: str
    sample_size: int
    exp_lo: int
    exp_hi: int
    n_exp: int

    def __post_init__(self):
        if not (0 <= self.exp_lo < self.exp_hi <= self.n_exp):
            raise ValueError(
                f"invalid experiment range [{self.exp_lo}, {self.exp_hi}) "
                f"for a cell of {self.n_exp} experiments"
            )

    @property
    def n_unit_exp(self) -> int:
        return self.exp_hi - self.exp_lo

    @property
    def cell(self) -> tuple[str, int]:
        return (self.algo, self.sample_size)

    @property
    def key(self) -> str:
        """Stable id used for journaling and deterministic merging."""
        return (
            f"{self.algo}/S{self.sample_size}/E{self.n_exp}"
            f"/e{self.exp_lo}:{self.exp_hi}"
        )

    def to_dict(self) -> dict:
        return {
            "algo": self.algo,
            "sample_size": self.sample_size,
            "exp_lo": self.exp_lo,
            "exp_hi": self.exp_hi,
            "n_exp": self.n_exp,
        }

    @classmethod
    def from_dict(cls, d: dict) -> "ExperimentUnit":
        return cls(
            algo=str(d["algo"]),
            sample_size=int(d["sample_size"]),
            exp_lo=int(d["exp_lo"]),
            exp_hi=int(d["exp_hi"]),
            n_exp=int(d["n_exp"]),
        )


@dataclass
class UnitResult:
    """One executed unit's arrays + its wall-clock cost.

    The arrays cover experiments ``[unit.exp_lo, unit.exp_hi)`` in order.
    JSON-serializable both ways — the remote-executor seam ships these back
    as plain dicts.  ``stage_s`` is the unit's per-stage wall-clock breakdown
    (``{"screen": ..., "compile": ..., "time": ...}``) when the backend is a
    staged pipeline; ``{}`` for unstaged backends and pre-breakdown journal
    entries.  ``counters`` is the unit's telemetry counter delta (compiles,
    cache hits, invalid configs...) — observability only, ``{}`` when
    telemetry is disabled, never part of the unit's scientific identity.
    """

    unit: ExperimentUnit
    final_values: np.ndarray
    search_best_values: np.ndarray
    n_samples_used: np.ndarray
    wall_s: float = 0.0
    stage_s: dict = field(default_factory=dict)
    counters: dict = field(default_factory=dict)

    def __post_init__(self):
        n = self.unit.n_unit_exp
        for name in ("final_values", "search_best_values", "n_samples_used"):
            arr = np.asarray(getattr(self, name))
            if arr.shape != (n,):
                raise ValueError(
                    f"{name} has shape {arr.shape}, expected ({n},) for "
                    f"unit {self.unit.key}"
                )
            setattr(self, name, arr)

    def to_dict(self) -> dict:
        return {
            "unit": self.unit.to_dict(),
            "final_values": [float(v) for v in self.final_values],
            "search_best_values": [float(v) for v in self.search_best_values],
            "n_samples_used": [int(v) for v in self.n_samples_used],
            "wall_s": float(self.wall_s),
            "stage_s": {k: float(v) for k, v in self.stage_s.items()},
            "counters": {k: float(v) for k, v in self.counters.items()},
        }

    @classmethod
    def from_dict(cls, d: dict) -> "UnitResult":
        return cls(
            unit=ExperimentUnit.from_dict(d["unit"]),
            final_values=np.array(d["final_values"], dtype=np.float64),
            search_best_values=np.array(
                d["search_best_values"], dtype=np.float64
            ),
            n_samples_used=np.array(d["n_samples_used"], dtype=np.int64),
            wall_s=float(d.get("wall_s", 0.0)),
            stage_s={
                str(k): float(v) for k, v in d.get("stage_s", {}).items()
            },
            counters={
                str(k): float(v) for k, v in d.get("counters", {}).items()
            },
        )


def _sum_stage_s(weighted) -> dict[str, float]:
    """Weighted sum of per-stage breakdowns (fragment pro-rating)."""
    acc: dict[str, float] = {}
    for stage_s, frac in weighted:
        for k, v in stage_s.items():
            acc[k] = acc.get(k, 0.0) + float(v) * frac
    return acc


# ------------------------------------------------------------- decomposition


def build_units(
    cells: list[tuple[str, int, int]],
    *,
    min_units: int = 1,
    max_unit_experiments: int | None = None,
    cost=None,
) -> list[ExperimentUnit]:
    """Decompose ``(algo, sample_size, n_experiments)`` cells into units.

    Deterministic policy: start with one unit per cell (monolithic, exactly
    today's per-cell loop); if ``max_unit_experiments`` is set, chunk every
    cell to at most that many experiments per unit (checkpoint granularity
    for big-E rows); then, while there are fewer than ``min_units`` units,
    split the most expensive splittable unit at its experiment midpoint
    (first-in-order on ties), so a request for N workers produces at least N
    units whenever the matrix holds that many experiments — including a
    single-cell matrix.

    ``cost`` is the unit-duration predictor driving that split order — a
    pure function ``ExperimentUnit -> float`` (e.g. samples x the cost
    model's mean per-sample runtime, see
    :func:`repro.costmodel.mean_runtime_estimate`).  It must be
    deterministic in the unit alone: the decomposition is part of the
    journaled plan, and two runs of the same spec must split identically.
    Without one, a unit's experiment count is its cost — the widest unit
    splits first.

    The returned order is canonical: cells in their given order, units by
    ascending ``exp_lo`` within each cell.
    """
    if cost is None:
        def cost(u):
            return u.n_unit_exp
    units: list[ExperimentUnit] = []
    for algo, s, e in cells:
        if e < 1:
            raise ValueError(f"cell ({algo}, {s}) has {e} experiments")
        step = e if max_unit_experiments is None else max(1, max_unit_experiments)
        for lo in range(0, e, step):
            units.append(
                ExperimentUnit(
                    algo=algo,
                    sample_size=s,
                    exp_lo=lo,
                    exp_hi=min(lo + step, e),
                    n_exp=e,
                )
            )
    while len(units) < min_units:
        best_i = -1
        best_cost = float("-inf")
        for i, u in enumerate(units):
            if u.n_unit_exp <= 1:
                continue  # single-experiment units cannot split further
            c = float(cost(u))
            if c > best_cost:
                best_i, best_cost = i, c
        if best_i < 0:
            break
        u = units[best_i]
        mid = u.exp_lo + u.n_unit_exp // 2
        units[best_i : best_i + 1] = [
            ExperimentUnit(u.algo, u.sample_size, u.exp_lo, mid, u.n_exp),
            ExperimentUnit(u.algo, u.sample_size, mid, u.exp_hi, u.n_exp),
        ]
    cell_order = {(algo, s): i for i, (algo, s, _) in enumerate(cells)}
    units.sort(key=lambda u: (cell_order[u.cell], u.exp_lo))
    return units


def merge_unit_results(
    cells: list[tuple[str, int, int]],
    results: list[UnitResult],
) -> tuple[list[CellResult], dict[tuple[str, int], dict[str, float]]]:
    """Fold unit fragments into full per-cell results, in ``cells`` order.

    Fragments merge deterministically by unit key regardless of the order an
    executor returned them in; every cell must be covered contiguously from
    0 to its experiment count or a ``ValueError`` names the gap.  Returns
    the cell results plus per-cell cost breakdowns ``{"wall_s", "compile_s",
    "measure_s"}`` (the sum of unit walls — aggregate *search cost*,
    meaningful even when units ran in parallel; ``compile_s`` charges the
    staged pipeline's screen + compile stages, ``measure_s`` its timing
    stage — both 0.0 for unstaged backends).
    """
    by_key: dict[str, UnitResult] = {}
    for r in results:
        if r.unit.key in by_key:
            raise ValueError(f"duplicate unit result {r.unit.key!r}")
        by_key[r.unit.key] = r
    grouped: dict[tuple[str, int], list[UnitResult]] = {}
    for r in by_key.values():
        grouped.setdefault(r.unit.cell, []).append(r)
    out: list[CellResult] = []
    walls: dict[tuple[str, int], dict[str, float]] = {}
    for algo, s, e in cells:
        frags = sorted(grouped.get((algo, s), []), key=lambda r: r.unit.exp_lo)
        covered = 0
        for f in frags:
            if f.unit.exp_lo != covered or f.unit.n_exp != e:
                raise ValueError(
                    f"cell ({algo}, S={s}) has a unit-coverage gap at "
                    f"experiment {covered}: got {f.unit.key!r}"
                )
            covered = f.unit.exp_hi
        if covered != e:
            raise ValueError(
                f"cell ({algo}, S={s}) covered only {covered}/{e} experiments"
            )
        out.append(
            CellResult(
                algo=algo,
                sample_size=s,
                final_values=np.concatenate([f.final_values for f in frags]),
                search_best_values=np.concatenate(
                    [f.search_best_values for f in frags]
                ),
                n_samples_used=np.concatenate(
                    [f.n_samples_used for f in frags]
                ),
            )
        )
        walls[(algo, s)] = {
            "wall_s": float(sum(f.wall_s for f in frags)),
            "compile_s": float(
                sum(
                    f.stage_s.get("screen", 0.0) + f.stage_s.get("compile", 0.0)
                    for f in frags
                )
            ),
            "measure_s": float(
                sum(f.stage_s.get("time", 0.0) for f in frags)
            ),
        }
    return out, walls


# ------------------------------------------------------------- checkpointing


class UnitJournal:
    """Completed-unit checkpoint journal over a measurement store's metadata.

    Entries live in the store's per-key string metadata side-channel (both
    the JSON and sqlite stores carry one) under
    ``__unit__|{namespace}|{unit.key}``, where the namespace binds the spec
    identity (cache key, root seed, final-repeats, dispatch).  The payload
    is the full :class:`UnitResult` as JSON, so a resumed matrix run
    rehydrates finished units without touching the measurement layer at all.

    ``put`` flushes the store — a journal that only exists in memory
    protects nothing from a kill — but throttled to once per
    ``min_flush_s`` seconds: the JSON store rewrites its whole file per
    flush, and a matrix of many cheap units would otherwise spend its
    wall-clock checkpointing.  The loss window on a kill is bounded by the
    throttle (and anything lost re-runs as pure measurement-cache hits);
    the caller's end-of-run ``save_store`` flushes the tail.
    """

    PREFIX = "__unit__"

    def __init__(self, store, namespace: str, min_flush_s: float = 5.0):
        if not hasattr(store, "put_meta") or not hasattr(store, "get_meta"):
            raise TypeError(
                f"store {type(store).__name__} has no metadata side-channel; "
                "unit journaling needs get_meta/put_meta"
            )
        self.store = store
        self.namespace = namespace
        self.min_flush_s = min_flush_s
        self._last_flush = float("-inf")   # first put always flushes

    def key(self, unit: ExperimentUnit) -> str:
        return f"{self.PREFIX}|{self.namespace}|{unit.key}"

    def get(self, unit: ExperimentUnit) -> UnitResult | None:
        raw = self.store.get_meta(self.key(unit))
        if raw is None:
            return None
        try:
            payload = json.loads(raw)
        except json.JSONDecodeError:
            return None  # a corrupt entry degrades to a re-run, never a crash
        if payload.get("unit") != unit.to_dict():
            return None
        return UnitResult.from_dict(payload)

    def put(self, result: UnitResult) -> None:
        self.store.put_meta(self.key(result.unit), json.dumps(result.to_dict()))
        now = monotonic()
        if now - self._last_flush >= self.min_flush_s:
            self.store.save()
            self._last_flush = now

    def _cell_fragments(self, unit: ExperimentUnit) -> list[UnitResult]:
        """Every journaled fragment of ``unit``'s cell (any range)."""
        if not hasattr(self.store, "meta_items"):
            return []
        prefix = (
            f"{self.PREFIX}|{self.namespace}|"
            f"{unit.algo}/S{unit.sample_size}/E{unit.n_exp}/e"
        )
        out = []
        for _, raw in self.store.meta_items(prefix=prefix):
            try:
                r = UnitResult.from_dict(json.loads(raw))
            except (json.JSONDecodeError, KeyError, ValueError, TypeError):
                continue
            if r.unit.cell == unit.cell and r.unit.n_exp == unit.n_exp:
                out.append(r)
        return out

    def cover(self, unit: ExperimentUnit) -> UnitResult | None:
        """The journaled result for ``unit`` — exact, or assembled from
        fragments journaled under DIFFERENT unit boundaries (a run resumed
        with a different ``max_workers`` re-splits its cells; per-experiment
        results are positional, so fragments slice and concatenate).
        ``wall_s`` and ``stage_s`` of partially-used fragments are
        pro-rated."""
        exact = self.get(unit)
        if exact is not None:
            return exact
        frags = self._cell_fragments(unit)
        if not frags:
            return None
        pieces: list[tuple[UnitResult, slice, float]] = []
        p = unit.exp_lo
        while p < unit.exp_hi:
            best = None
            for f in frags:
                if f.unit.exp_lo <= p < f.unit.exp_hi and (
                    best is None or f.unit.exp_hi > best.unit.exp_hi
                ):
                    best = f
            if best is None:
                return None
            hi = min(best.unit.exp_hi, unit.exp_hi)
            sl = slice(p - best.unit.exp_lo, hi - best.unit.exp_lo)
            pieces.append((best, sl, (hi - p) / best.unit.n_unit_exp))
            p = hi
        return UnitResult(
            unit=unit,
            final_values=np.concatenate(
                [b.final_values[s] for b, s, _ in pieces]
            ),
            search_best_values=np.concatenate(
                [b.search_best_values[s] for b, s, _ in pieces]
            ),
            n_samples_used=np.concatenate(
                [b.n_samples_used[s] for b, s, _ in pieces]
            ),
            wall_s=float(sum(b.wall_s * frac for b, _, frac in pieces)),
            stage_s=_sum_stage_s(
                (b.stage_s, frac) for b, _, frac in pieces
            ),
            counters=_sum_stage_s(
                (b.counters, frac) for b, _, frac in pieces
            ),
        )

    def partition(
        self, units: list[ExperimentUnit]
    ) -> tuple[list[UnitResult], list[ExperimentUnit]]:
        """Split ``units`` into (journaled results, still-pending units)."""
        done: list[UnitResult] = []
        pending: list[ExperimentUnit] = []
        for u in units:
            r = self.cover(u)
            (done.append(r) if r is not None else pending.append(u))
        return done, pending

    def entries(self) -> list[str]:
        """All journal keys in this namespace (diagnostics)."""
        prefix = f"{self.PREFIX}|{self.namespace}|"
        if not hasattr(self.store, "meta_items"):
            return []
        return sorted(
            k for k, _ in self.store.meta_items(prefix=prefix)
        )
