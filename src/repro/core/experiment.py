"""Experiment design: the paper's sample-size methodology.

Section V.B: experiment counts scale inversely with sample size because
result variance falls as the sample size grows.  'With the assumption that we
wanted at least 50 experiments for our sample_size = 400 case, we performed
800 experiments for our sample_size = 25 case and scaled the number of
experiments for the rest of the sample sizes similarly.'

i.e. E(S) = (400 * 50) / S = 20000 / S:

    S:  25  50  100 200 400
    E: 800 400  200 100  50

which also makes every (S, E) row consume exactly the 20,000-sample
pre-generated dataset used by the non-SMBO methods (section VI.B).
"""

from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class ExperimentDesign:
    sample_sizes: tuple[int, ...]
    n_experiments: tuple[int, ...]
    final_repeats: int = 10

    def __post_init__(self):
        if len(self.sample_sizes) != len(self.n_experiments):
            raise ValueError("sample_sizes and n_experiments length mismatch")

    @classmethod
    def paper(cls) -> "ExperimentDesign":
        return cls(sample_sizes=(25, 50, 100, 200, 400),
                   n_experiments=(800, 400, 200, 100, 50))

    @classmethod
    def scaled(cls, budget: int = 20000,
               sample_sizes: tuple[int, ...] = (25, 50, 100, 200, 400),
               min_experiments: int = 3) -> "ExperimentDesign":
        """Same inverse scaling with a different total budget per cell."""
        return cls(
            sample_sizes=tuple(sample_sizes),
            n_experiments=tuple(max(min_experiments, budget // s) for s in sample_sizes),
        )

    @classmethod
    def smoke(cls) -> "ExperimentDesign":
        """Tiny design for tests."""
        return cls(sample_sizes=(25, 50), n_experiments=(8, 4), final_repeats=3)

    # -- serialization (TuningSpec round-trips through JSON) -----------------
    def to_dict(self) -> dict:
        return {
            "sample_sizes": list(self.sample_sizes),
            "n_experiments": list(self.n_experiments),
            "final_repeats": self.final_repeats,
        }

    @classmethod
    def from_dict(cls, d: dict) -> "ExperimentDesign":
        return cls(
            sample_sizes=tuple(int(s) for s in d["sample_sizes"]),
            n_experiments=tuple(int(e) for e in d["n_experiments"]),
            final_repeats=int(d.get("final_repeats", 10)),
        )

    @property
    def total_search_samples(self) -> int:
        return sum(s * e for s, e in zip(self.sample_sizes, self.n_experiments, strict=True))

    def rows(self):
        return list(zip(self.sample_sizes, self.n_experiments, strict=True))
