"""Random-forest regression, from scratch on numpy.

The paper uses sklearn's RandomForestRegressor as the RF surrogate (Breiman
2001: bootstrap bagging over variance-reduction decision trees with random
feature selection).  sklearn is not available in this environment, so this is
a faithful re-implementation with the same defaults that matter:
``n_estimators=100, bootstrap=True, min_samples_leaf=1, min_samples_split=2``.

Trees are stored as flat arrays so batch prediction is a vectorized
level-by-level traversal (no Python recursion at predict time).
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class _FlatTree:
    feature: np.ndarray   # int32, -1 for leaf
    threshold: np.ndarray # float64
    left: np.ndarray      # int32 child index
    right: np.ndarray     # int32 child index
    value: np.ndarray     # float64 leaf prediction


class RegressionTree:
    """CART regression tree: greedy SSE-minimizing axis-aligned splits."""

    def __init__(
        self,
        max_depth: int = 32,
        min_samples_split: int = 2,
        min_samples_leaf: int = 1,
        max_features: float | str = 1.0,
        rng: np.random.Generator | None = None,
    ):
        self.max_depth = max_depth
        self.min_samples_split = min_samples_split
        self.min_samples_leaf = min_samples_leaf
        self.max_features = max_features
        self.rng = rng or np.random.default_rng(0)
        self.tree_: _FlatTree | None = None

    # -- fitting ------------------------------------------------------------
    def fit(self, X: np.ndarray, y: np.ndarray) -> "RegressionTree":
        X = np.asarray(X, dtype=np.float64)
        y = np.asarray(y, dtype=np.float64)
        n, d = X.shape
        if self.max_features == "sqrt":
            n_feat = max(1, int(np.sqrt(d)))
        elif self.max_features == "third":
            n_feat = max(1, d // 3)
        else:
            n_feat = max(1, int(round(float(self.max_features) * d)))

        feature, threshold, left, right, value = [], [], [], [], []

        def new_node() -> int:
            feature.append(-1)
            threshold.append(0.0)
            left.append(-1)
            right.append(-1)
            value.append(0.0)
            return len(feature) - 1

        # iterative build with an explicit stack: (node_id, sample_idx, depth)
        root = new_node()
        stack = [(root, np.arange(n), 0)]
        while stack:
            nid, idx, depth = stack.pop()
            y_node = y[idx]
            value[nid] = float(y_node.mean())
            if (
                depth >= self.max_depth
                or len(idx) < self.min_samples_split
                or np.ptp(y_node) == 0.0
            ):
                continue
            feats = self.rng.permutation(d)[:n_feat]
            best = self._best_split(X[idx], y_node, feats)
            if best is None:
                continue
            f, thr = best
            mask = X[idx, f] <= thr
            li, ri = idx[mask], idx[~mask]
            if len(li) < self.min_samples_leaf or len(ri) < self.min_samples_leaf:
                continue
            feature[nid] = int(f)
            threshold[nid] = float(thr)
            lid, rid = new_node(), new_node()
            left[nid], right[nid] = lid, rid
            stack.append((lid, li, depth + 1))
            stack.append((rid, ri, depth + 1))

        self.tree_ = _FlatTree(
            feature=np.array(feature, dtype=np.int32),
            threshold=np.array(threshold, dtype=np.float64),
            left=np.array(left, dtype=np.int32),
            right=np.array(right, dtype=np.int32),
            value=np.array(value, dtype=np.float64),
        )
        return self

    def _best_split(self, X: np.ndarray, y: np.ndarray, feats: np.ndarray):
        """Vectorized best (feature, threshold) by SSE reduction.

        Uses the prefix-sum identity:  SSE_left + SSE_right is minimized by
        maximizing  (S_L^2 / n_L + S_R^2 / n_R)  where S is the y-prefix-sum
        over the feature-sorted order.
        """
        n = len(y)
        best_gain, best = 0.0, None
        total = y.sum()
        base = (total * total) / n
        for f in feats:
            xf = X[:, f]
            order = np.argsort(xf, kind="mergesort")
            xs, ys = xf[order], y[order]
            # candidate split points: between distinct consecutive x values
            diff = xs[1:] != xs[:-1]
            if not diff.any():
                continue
            csum = np.cumsum(ys)[:-1]            # sum of left part, size n-1
            n_l = np.arange(1, n, dtype=np.float64)
            n_r = n - n_l
            score = csum**2 / n_l + (total - csum) ** 2 / n_r
            score = np.where(diff, score, -np.inf)
            k = int(np.argmax(score))
            gain = score[k] - base
            if gain > best_gain + 1e-12:
                best_gain = gain
                best = (int(f), 0.5 * (xs[k] + xs[k + 1]))
        return best

    # -- prediction ---------------------------------------------------------
    def predict(self, X: np.ndarray) -> np.ndarray:
        if self.tree_ is None:
            raise RuntimeError("call fit first")
        X = np.asarray(X, dtype=np.float64)
        t = self.tree_
        node = np.zeros(len(X), dtype=np.int32)
        active = t.feature[node] >= 0
        while active.any():
            f = t.feature[node[active]]
            thr = t.threshold[node[active]]
            go_left = X[active, f] <= thr
            nxt = np.where(go_left, t.left[node[active]], t.right[node[active]])
            node[active] = nxt
            active = t.feature[node] >= 0
        return t.value[node]


class RandomForestRegressor:
    """Bagged ensemble of regression trees (Breiman 2001)."""

    def __init__(
        self,
        n_estimators: int = 100,
        max_depth: int = 32,
        min_samples_leaf: int = 1,
        max_features: float | str = 1.0,
        bootstrap: bool = True,
        seed: int = 0,
    ):
        self.n_estimators = n_estimators
        self.max_depth = max_depth
        self.min_samples_leaf = min_samples_leaf
        self.max_features = max_features
        self.bootstrap = bootstrap
        self.seed = seed
        self.trees_: list[RegressionTree] = []

    def fit(self, X: np.ndarray, y: np.ndarray) -> "RandomForestRegressor":
        X = np.asarray(X, dtype=np.float64)
        y = np.asarray(y, dtype=np.float64)
        n = len(X)
        root_rng = np.random.default_rng(self.seed)
        self.trees_ = []
        for _ in range(self.n_estimators):
            rng = np.random.default_rng(root_rng.integers(0, 2**63))
            idx = rng.integers(0, n, size=n) if self.bootstrap else np.arange(n)
            tree = RegressionTree(
                max_depth=self.max_depth,
                min_samples_leaf=self.min_samples_leaf,
                max_features=self.max_features,
                rng=rng,
            )
            tree.fit(X[idx], y[idx])
            self.trees_.append(tree)
        return self

    def predict(self, X: np.ndarray) -> np.ndarray:
        preds = np.stack([t.predict(X) for t in self.trees_], axis=0)
        return preds.mean(axis=0)
