"""Gaussian-process regression, from scratch on numpy.

The paper uses scikit-optimize's ``gp_minimize`` (Matérn kernel + Expected
Improvement, 8% random initialization).  Neither skopt nor sklearn are
available here, so this module implements the GP surrogate directly:

* Matérn-5/2 kernel with a shared lengthscale on unit-cube inputs,
* observation-noise variance (the measurement IS noisy — the paper runs each
  config once during search),
* hyperparameters chosen by log-marginal-likelihood over a log-space grid,
  re-selected only when the training set doubles (grid search is O(n^3) per
  combo; doubling keeps total refit cost O(n^3) amortized),
* **incremental Cholesky**: appending one observation extends L with one
  triangular solve — O(n^2) per BO step instead of O(n^3).  This is what
  makes the paper's full 3M-sample experiment matrix tractable on one CPU
  core (see EXPERIMENTS.md §Repro-perf).

y is standardized internally (against the *current* observation set), so the
signal variance is fixed at 1.
"""

from __future__ import annotations

import numpy as np

_SQRT5 = np.sqrt(5.0)


def matern52(a: np.ndarray, b: np.ndarray, lengthscale: float) -> np.ndarray:
    """Matérn-5/2 kernel matrix for row-vector inputs in the unit cube."""
    d2 = np.maximum(
        (a**2).sum(1)[:, None] + (b**2).sum(1)[None, :] - 2.0 * a @ b.T, 0.0
    )
    r = np.sqrt(d2) / lengthscale
    return (1.0 + _SQRT5 * r + 5.0 / 3.0 * r**2) * np.exp(-_SQRT5 * r)


class GaussianProcess:
    """Online GP for sequential model-based optimization (minimization)."""

    def __init__(
        self,
        lengthscales: tuple[float, ...] = (0.1, 0.25, 0.6, 1.5),
        noises: tuple[float, ...] = (1e-4, 1e-2, 1e-1),
        max_points: int | None = None,
    ):
        self.lengthscales = lengthscales
        self.noises = noises
        self.max_points = max_points
        self.lengthscale = lengthscales[len(lengthscales) // 2]
        self.noise = noises[1]
        self._X: list[np.ndarray] = []
        self._y: list[float] = []
        self._L: np.ndarray | None = None
        self._alpha: np.ndarray | None = None
        self._y_mean = 0.0
        self._y_std = 1.0
        self._last_refit_n = 0

    # -- internals ------------------------------------------------------------
    @staticmethod
    def _chol(K: np.ndarray) -> np.ndarray:
        jitter = 1e-10
        for _ in range(10):
            try:
                return np.linalg.cholesky(K + jitter * np.eye(len(K)))
            except np.linalg.LinAlgError:
                jitter *= 100.0
        raise np.linalg.LinAlgError("kernel matrix not PD even with jitter")

    def _standardize(self) -> np.ndarray:
        y = np.asarray(self._y)
        self._y_mean = float(y.mean())
        self._y_std = float(y.std()) or 1.0
        return (y - self._y_mean) / self._y_std

    def _lml(self, X: np.ndarray, yn: np.ndarray, ls: float, nz: float) -> float:
        K = matern52(X, X, ls) + nz * np.eye(len(X))
        try:
            L = self._chol(K)
        except np.linalg.LinAlgError:
            return -np.inf
        a = np.linalg.solve(L.T, np.linalg.solve(L, yn))
        return float(-0.5 * yn @ a - np.log(np.diag(L)).sum())

    def _full_refit(self) -> None:
        X = np.stack(self._X)
        yn = self._standardize()
        best = -np.inf
        for ls in self.lengthscales:
            for nz in self.noises:
                lml = self._lml(X, yn, ls, nz)
                if lml > best:
                    best, self.lengthscale, self.noise = lml, ls, nz
        K = matern52(X, X, self.lengthscale) + self.noise * np.eye(len(X))
        self._L = self._chol(K)
        self._alpha = np.linalg.solve(self._L.T, np.linalg.solve(self._L, yn))
        self._last_refit_n = len(X)

    def _refresh_alpha(self) -> None:
        yn = self._standardize()
        from scipy.linalg import solve_triangular  # fast dtrsv path

        z = solve_triangular(self._L, yn, lower=True)
        self._alpha = solve_triangular(self._L.T, z, lower=False)

    # -- public API ------------------------------------------------------------
    @property
    def n(self) -> int:
        return len(self._y)

    def add(self, x: np.ndarray, y: float) -> None:
        """Add one observation; O(n^2) unless a hyperparameter refit fires."""
        x = np.asarray(x, dtype=np.float64)
        self._X.append(x)
        self._y.append(float(y))
        n = len(self._y)
        if self._L is None or n >= 2 * max(self._last_refit_n, 4):
            self._full_refit()
            return
        # rank-1 Cholesky append:  K' = [[K, k], [k^T, k_nn + noise]]
        X_old = np.stack(self._X[:-1])
        k = matern52(x[None, :], X_old, self.lengthscale)[0]
        from scipy.linalg import solve_triangular

        b = solve_triangular(self._L, k, lower=True)
        d2 = 1.0 + self.noise - b @ b
        d = np.sqrt(max(d2, 1e-10))
        n_old = len(X_old)
        L_new = np.zeros((n_old + 1, n_old + 1))
        L_new[:n_old, :n_old] = self._L
        L_new[n_old, :n_old] = b
        L_new[n_old, n_old] = d
        self._L = L_new
        self._refresh_alpha()

    def fit(self, X: np.ndarray, y: np.ndarray) -> "GaussianProcess":
        """Batch (re)fit — resets the online state."""
        self._X = [np.asarray(r, dtype=np.float64) for r in np.asarray(X)]
        self._y = [float(v) for v in np.asarray(y)]
        self._full_refit()
        return self

    def predict(self, Xs: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """Posterior mean and stddev (in the original y units)."""
        if self._L is None:
            raise RuntimeError("call fit first: GP has no observations")
        from scipy.linalg import solve_triangular

        Xs = np.asarray(Xs, dtype=np.float64)
        X = np.stack(self._X)
        Ks = matern52(Xs, X, self.lengthscale)
        mu = Ks @ self._alpha
        v = solve_triangular(self._L, Ks.T, lower=True)
        var = np.maximum(1.0 + self.noise - (v**2).sum(axis=0), 1e-12)
        return (
            mu * self._y_std + self._y_mean,
            np.sqrt(var) * self._y_std,
        )


def _erf(x: np.ndarray) -> np.ndarray:
    """Vectorized erf (Abramowitz & Stegun 7.1.26, |err| < 1.5e-7)."""
    s = np.sign(x)
    x = np.abs(x)
    t = 1.0 / (1.0 + 0.3275911 * x)
    poly = t * (
        0.254829592
        + t * (-0.284496736 + t * (1.421413741 + t * (-1.453152027 + t * 1.061405429)))
    )
    return s * (1.0 - poly * np.exp(-x * x))


def expected_improvement(
    mu: np.ndarray, sigma: np.ndarray, best: float, xi: float = 0.0
) -> np.ndarray:
    """EI for MINIMIZATION:  E[max(best - Y - xi, 0)]."""
    sigma = np.maximum(sigma, 1e-12)
    z = (best - mu - xi) / sigma
    cdf = 0.5 * (1.0 + _erf(z / np.sqrt(2.0)))
    pdf = np.exp(-0.5 * z**2) / np.sqrt(2.0 * np.pi)
    return (best - mu - xi) * cdf + sigma * pdf
