"""Vectorized histogram random forests over integer feature spaces.

Autotuning search spaces are small-cardinality integer grids (here: 16^3 x
8^3), so tree splits can be found with *histograms* (bincount per feature
value) instead of per-node sorts — and, crucially, ALL trees of ALL forests
of an experiment cell can be grown level-synchronously in one numpy pass
(the LightGBM trick, applied across the forest/experiment axes).

This replaces the per-node recursive CART in ``forest.py`` for the paper's
experiment matrix: fitting 800 experiments x 100 trees at sample size 25
drops from ~8 min to ~2 s.  ``forest.py`` remains the reference
implementation; ``tests/test_surrogates.py`` cross-checks the two.

Semantics per tree match sklearn's RandomForestRegressor defaults used by
the paper: bootstrap resampling, variance-reduction (SSE) splits over all
features, grown to purity (min_samples_leaf=1, min_samples_split=2).
"""

from __future__ import annotations

import numpy as np


class BatchedForest:
    """G independent forests fit simultaneously.

    Parameters
    ----------
    cards: per-feature cardinalities (features are integer indices in
        ``[0, card)``).
    """

    def __init__(
        self,
        cards: np.ndarray,
        n_estimators: int = 100,
        max_depth: int = 32,
        min_samples_leaf: int = 1,
        min_samples_split: int = 2,
        seed: int = 0,
    ):
        self.cards = np.asarray(cards, dtype=np.int64)
        self.n_estimators = n_estimators
        self.max_depth = max_depth
        self.min_samples_leaf = min_samples_leaf
        self.min_samples_split = min_samples_split
        self.seed = seed
        # node storage (filled by fit)
        self.feature: np.ndarray | None = None  # (M,) int32, -1 => leaf
        self.thresh: np.ndarray | None = None   # (M,) int32 (go left if x <= t)
        self.left: np.ndarray | None = None     # (M,) int64
        self.right: np.ndarray | None = None    # (M,) int64
        self.value: np.ndarray | None = None    # (M,) float64
        self.root: np.ndarray | None = None     # (B,) roots, B = G * T
        self.n_forests = 0

    # ------------------------------------------------------------------ fit
    def fit(
        self,
        X: np.ndarray,
        y: np.ndarray,
        bootstrap_idx: np.ndarray | None = None,
    ) -> "BatchedForest":
        """X: (G, n, d) integer indices; y: (G, n).

        ``bootstrap_idx`` optionally supplies the per-tree resampling rows,
        shape ``(G * n_estimators, n)`` — forest ``g`` uses rows
        ``[g*T, (g+1)*T)``.  The work-unit layer uses this to fit a SLICE of
        an experiment cell with the exact draws the full-cell fit would
        have used, keeping within-cell splits bit-identical.  Default:
        drawn here from ``seed`` (one ``integers(0, n, (G*T, n))`` call, so
        an external draw of the full cell sliced to ``[lo*T, hi*T)``
        reproduces it exactly).
        """
        X = np.asarray(X)
        y = np.asarray(y, dtype=np.float64)
        if X.ndim == 2:
            X, y = X[None], y[None]
        G, n, d = X.shape
        T = self.n_estimators
        B = G * T
        self.n_forests = G

        # bootstrap: each tree resamples n rows from its forest's data
        if bootstrap_idx is None:
            rng = np.random.default_rng(self.seed)
            samp = rng.integers(0, n, size=(B, n))
        else:
            samp = np.asarray(bootstrap_idx)
            if samp.shape != (B, n):
                raise ValueError(
                    f"bootstrap_idx shape {samp.shape} != ({B}, {n})"
                )
        forest_of_tree = np.repeat(np.arange(G), T)
        Xb = X[forest_of_tree[:, None], samp]          # (B, n, d)
        yb = y[forest_of_tree[:, None], samp]          # (B, n)

        # flatten to the sample axis
        Xv = Xb.reshape(B * n, d).astype(np.int64)
        yv = yb.reshape(B * n)

        # growing node tables
        feature = [np.full(B, -1, dtype=np.int32)]
        thresh = [np.zeros(B, dtype=np.int32)]
        left = [np.full(B, -1, dtype=np.int64)]
        right = [np.full(B, -1, dtype=np.int64)]
        value = [np.zeros(B, dtype=np.float64)]
        n_nodes = B
        self.root = np.arange(B, dtype=np.int64)

        # frontier state: every active sample points at a frontier slot
        leaf = np.repeat(np.arange(B, dtype=np.int64), n)  # frontier slot per sample
        frontier_nodes = np.arange(B, dtype=np.int64)       # node id per slot
        active = np.ones(B * n, dtype=bool)
        depth = 0
        min_leaf = self.min_samples_leaf

        while len(frontier_nodes) and depth < self.max_depth:
            F = len(frontier_nodes)
            lv, Xa, ya = leaf[active], Xv[active], yv[active]
            N = np.bincount(lv, minlength=F).astype(np.float64)
            S = np.bincount(lv, weights=ya, minlength=F)
            base = np.where(N > 0, S * S / np.maximum(N, 1.0), 0.0)

            best_gain = np.full(F, 1e-12)
            best_feat = np.full(F, -1, dtype=np.int64)
            best_thr = np.zeros(F, dtype=np.int64)
            for f in range(d):
                V = int(self.cards[f])
                key = lv * V + Xa[:, f]
                cnt = np.bincount(key, minlength=F * V).reshape(F, V)
                ysum = np.bincount(key, weights=ya, minlength=F * V).reshape(F, V)
                cl = cnt.cumsum(1)[:, :-1].astype(np.float64)
                sl = ysum.cumsum(1)[:, :-1]
                nr = N[:, None] - cl
                sr = S[:, None] - sl
                ok = (cl >= min_leaf) & (nr >= min_leaf)
                score = np.where(
                    ok,
                    sl * sl / np.maximum(cl, 1.0) + sr * sr / np.maximum(nr, 1.0),
                    -np.inf,
                )
                t = score.argmax(1)
                g = score[np.arange(F), t] - base
                better = g > best_gain
                best_gain = np.where(better, g, best_gain)
                best_feat = np.where(better, f, best_feat)
                best_thr = np.where(better, t, best_thr)

            split = (best_feat >= 0) & (N >= self.min_samples_split)
            # finalize non-splitting leaves
            done = ~split
            value_arr = np.where(N > 0, S / np.maximum(N, 1.0), 0.0)
            if done.any():
                nodes_done = frontier_nodes[done]
                value[0][...]  # noop to appease linters
                self._scatter(value, nodes_done, value_arr[done])
            if not split.any():
                break

            # allocate children for splitting leaves
            n_split = int(split.sum())
            kids = n_nodes + np.arange(2 * n_split, dtype=np.int64)
            n_nodes += 2 * n_split
            for arr, fill in (
                (feature, np.full(2 * n_split, -1, dtype=np.int32)),
                (thresh, np.zeros(2 * n_split, dtype=np.int32)),
                (left, np.full(2 * n_split, -1, dtype=np.int64)),
                (right, np.full(2 * n_split, -1, dtype=np.int64)),
                (value, np.zeros(2 * n_split, dtype=np.float64)),
            ):
                arr.append(fill)
            nodes_split = frontier_nodes[split]
            self._scatter(feature, nodes_split, best_feat[split].astype(np.int32))
            self._scatter(thresh, nodes_split, best_thr[split].astype(np.int32))
            self._scatter(left, nodes_split, kids[0::2])
            self._scatter(right, nodes_split, kids[1::2])

            # route samples: new frontier slot = 2*rank(split leaf) (+1 right)
            slot_of_leaf = np.full(F, -1, dtype=np.int64)
            slot_of_leaf[split] = np.arange(n_split) * 2
            samp_slot = slot_of_leaf[lv]
            still = samp_slot >= 0
            f_per = best_feat[lv[still]]
            x_per = Xa[still][np.arange(int(still.sum())), f_per]
            go_left = x_per <= best_thr[lv[still]]
            new_leaf = samp_slot[still] + np.where(go_left, 0, 1)

            # compact the active set
            idx_active = np.flatnonzero(active)
            keep = idx_active[still]
            active[:] = False
            active[keep] = True
            leaf[keep] = new_leaf
            frontier_nodes = kids
            depth += 1

        # any frontier leaves left at max depth: finalize with their mean
        if len(frontier_nodes):
            lv, ya = leaf[active], yv[active]
            F = len(frontier_nodes)
            N = np.bincount(lv, minlength=F).astype(np.float64)
            S = np.bincount(lv, weights=ya, minlength=F)
            self._scatter(value, frontier_nodes, np.where(N > 0, S / np.maximum(N, 1), 0.0))

        self.feature = np.concatenate(feature)
        self.thresh = np.concatenate(thresh)
        self.left = np.concatenate(left)
        self.right = np.concatenate(right)
        self.value = np.concatenate(value)
        return self

    @staticmethod
    def _scatter(chunks: list[np.ndarray], idx: np.ndarray, vals: np.ndarray) -> None:
        """Scatter into a chunked (growing) array by global index."""
        offsets = np.cumsum([0] + [len(c) for c in chunks])
        for i, c in enumerate(chunks):
            m = (idx >= offsets[i]) & (idx < offsets[i + 1])
            if m.any():
                c[idx[m] - offsets[i]] = vals[m]

    def _freeze_leaves(self) -> None:
        """Make leaves self-looping so predict needs no masking:
        leaf.left = leaf.right = leaf, leaf.feature = 0, leaf.thresh = big."""
        if getattr(self, "_frozen", False):
            return
        is_leaf = self.left < 0
        ids = np.arange(len(self.left), dtype=np.int64)
        self.left = np.where(is_leaf, ids, self.left)
        self.right = np.where(is_leaf, ids, self.right)
        self.thresh = np.where(is_leaf, np.int32(2**30), self.thresh)
        self.feature = np.where(is_leaf, np.int32(0), self.feature)
        self._is_leaf = is_leaf
        self._frozen = True

    # -------------------------------------------------------------- predict
    def predict(self, Xp: np.ndarray, chunk_forests: int = 32) -> np.ndarray:
        """Xp: (P, d) shared pool or (G, P, d) per-forest pools -> (G, P).

        Level-synchronous descent with self-looping leaves: every iteration
        is 4 flat gathers + a compare over (chunk*T*P,) arrays — no boolean
        mask bookkeeping.  Early-exits when the whole chunk is at leaves.
        """
        if self.feature is None:
            raise RuntimeError("call fit first")
        self._freeze_leaves()
        Xp = np.asarray(Xp)
        shared = Xp.ndim == 2
        G, T = self.n_forests, self.n_estimators
        P = Xp.shape[-2]
        d = Xp.shape[-1]
        out = np.zeros((G, P), dtype=np.float64)
        for g0 in range(0, G, chunk_forests):
            g1 = min(G, g0 + chunk_forests)
            nB = (g1 - g0) * T
            node = np.repeat(self.root[g0 * T : g1 * T], P)  # (nB*P,)
            if shared:
                xp_flat = np.ascontiguousarray(Xp, dtype=np.int32).reshape(-1)
                base = np.tile(np.arange(P, dtype=np.int64) * d, nB)
            else:
                xp_flat = (
                    np.ascontiguousarray(Xp[g0:g1], dtype=np.int32).reshape(-1)
                )
                fidx = np.repeat(np.arange(g1 - g0, dtype=np.int64), T * P)
                base = fidx * (P * d) + np.tile(np.arange(P, dtype=np.int64) * d, nB)
            for _ in range(self.max_depth + 1):
                f = self.feature[node]
                xv = xp_flat[base + f]
                go_left = xv <= self.thresh[node]
                node = np.where(go_left, self.left[node], self.right[node])
                if self._is_leaf[node].all():
                    break
            preds = self.value[node].reshape(g1 - g0, T, P)
            out[g0:g1] = preds.mean(axis=1)
        return out
