from .forest import RandomForestRegressor, RegressionTree
from .gp import GaussianProcess

__all__ = ["RandomForestRegressor", "RegressionTree", "GaussianProcess"]
