"""Search-space definitions for autotuning.

The paper's space: 6 integer parameters — thread dims {X,Y,Z}_t in [1..16]
and work-group dims {X,Y,Z}_w in [1..8] — giving |S| = 2,097,152 configs,
with the constraint prod(workgroup) <= 256 available only to non-SMBO
methods.  Our TPU adaptation keeps the same cardinalities (see DESIGN.md
section 2.1) but the machinery below is generic: integer ranges, categorical
choices, optional log2 semantics, and arbitrary predicate constraints.

Configs are plain dicts ``{param_name: value}``.  Internally every searcher
works on an *index vector* (one integer index per parameter) so crossover,
mutation, Parzen estimators and tree splits are uniform across param types.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import Callable, Iterator, Sequence

import numpy as np

Config = dict
ConstraintFn = Callable[[Config], bool]


@dataclass(frozen=True)
class Param:
    """A single tunable parameter over an explicit, ordered value list."""

    name: str
    values: tuple

    @staticmethod
    def int_range(name: str, lo: int, hi: int) -> "Param":
        """Inclusive integer range [lo..hi]."""
        return Param(name, tuple(range(lo, hi + 1)))

    @staticmethod
    def pow2(name: str, lo: int, hi: int) -> "Param":
        """Powers of two 2**lo .. 2**hi."""
        return Param(name, tuple(2**e for e in range(lo, hi + 1)))

    @staticmethod
    def choice(name: str, options: Sequence) -> "Param":
        return Param(name, tuple(options))

    @property
    def cardinality(self) -> int:
        return len(self.values)

    def index_of(self, value) -> int:
        return self.values.index(value)


class SearchSpace:
    """An ordered collection of :class:`Param` with an optional constraint.

    The constraint mirrors the paper's design point: constrained generation is
    offered to non-SMBO methods (RS/RF dataset generation, GA init), while
    SMBO methods (BO-GP / BO-TPE) search the raw space.  Use
    :meth:`unconstrained` to get the raw view.
    """

    def __init__(self, params: Sequence[Param], constraint: ConstraintFn | None = None):
        if not params:
            raise ValueError("SearchSpace needs at least one Param")
        names = [p.name for p in params]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate param names: {names}")
        self.params: tuple[Param, ...] = tuple(params)
        self.constraint = constraint
        self._cards = np.array([p.cardinality for p in self.params], dtype=np.int64)

    # -- basic properties ---------------------------------------------------
    @property
    def names(self) -> tuple[str, ...]:
        return tuple(p.name for p in self.params)

    @property
    def n_params(self) -> int:
        return len(self.params)

    @property
    def cardinality(self) -> int:
        return int(np.prod(self._cards))

    @property
    def cardinalities(self) -> np.ndarray:
        return self._cards.copy()

    def unconstrained(self) -> "SearchSpace":
        return SearchSpace(self.params, constraint=None)

    def with_constraint(self, fn: ConstraintFn) -> "SearchSpace":
        return SearchSpace(self.params, constraint=fn)

    # -- encode / decode ----------------------------------------------------
    def decode(self, idx: np.ndarray) -> Config:
        """Index vector -> config dict."""
        return {p.name: p.values[int(i)] for p, i in zip(self.params, idx, strict=True)}

    def encode(self, config: Config) -> np.ndarray:
        return np.array(
            [p.index_of(config[p.name]) for p in self.params], dtype=np.int64
        )

    def decode_batch(self, idxs: np.ndarray) -> list[Config]:
        return [self.decode(row) for row in idxs]

    def encode_batch(self, configs: Sequence[Config]) -> np.ndarray:
        """Config dicts -> (n, d) index-vector matrix (inverse of
        :meth:`decode_batch`) — for external ask/tell drivers that key their
        evaluation history by index row rather than by config dict."""
        lut = [{v: i for i, v in enumerate(p.values)} for p in self.params]
        try:
            return np.array(
                [[m[c[p.name]] for p, m in zip(self.params, lut, strict=True)] for c in configs],
                dtype=np.int64,
            ).reshape(len(configs), self.n_params)
        except KeyError as e:
            raise ValueError(f"config value {e.args[0]!r} not in this space") from e

    def to_unit(self, idxs: np.ndarray) -> np.ndarray:
        """Index vectors -> points in the unit cube (for GP kernels).

        Cell-centred: index i of a k-ary param maps to (i + 0.5) / k.
        """
        return (idxs.astype(np.float64) + 0.5) / self._cards.astype(np.float64)

    def from_unit(self, x: np.ndarray) -> np.ndarray:
        idx = np.floor(np.clip(x, 0.0, np.nextafter(1.0, 0.0)) * self._cards)
        return idx.astype(np.int64)

    # -- validity -----------------------------------------------------------
    def is_valid(self, config: Config) -> bool:
        return self.constraint is None or bool(self.constraint(config))

    def valid_mask(self, idxs: np.ndarray) -> np.ndarray:
        if self.constraint is None:
            return np.ones(len(idxs), dtype=bool)
        return np.array([self.is_valid(self.decode(r)) for r in idxs], dtype=bool)

    # -- sampling -----------------------------------------------------------
    def sample_indices(self, rng: np.random.Generator, n: int) -> np.ndarray:
        """n random index vectors, rejection-sampled against the constraint."""
        if self.constraint is None:
            return self._raw(rng, n)
        out = np.empty((0, self.n_params), dtype=np.int64)
        # rejection sampling; the paper's constraint keeps ~57% of the space,
        # so a few rounds always suffice for any sane constraint.
        for _ in range(1000):
            cand = self._raw(rng, max(n - len(out), 1) * 2)
            cand = cand[self.valid_mask(cand)]
            out = np.concatenate([out, cand])[: n]
            if len(out) == n:
                return out
        raise RuntimeError("constraint rejection sampling failed to converge")

    def _raw(self, rng: np.random.Generator, n: int) -> np.ndarray:
        cols = [rng.integers(0, c, size=n) for c in self._cards]
        return np.stack(cols, axis=1).astype(np.int64)

    def sample(self, rng: np.random.Generator) -> Config:
        return self.decode(self.sample_indices(rng, 1)[0])

    def sample_batch(self, rng: np.random.Generator, n: int) -> list[Config]:
        return self.decode_batch(self.sample_indices(rng, n))

    # -- enumeration (small spaces / grid search) ----------------------------
    def iter_indices(self) -> Iterator[np.ndarray]:
        for combo in itertools.product(*(range(c) for c in self._cards)):
            yield np.array(combo, dtype=np.int64)

    def mutate(
        self, rng: np.random.Generator, idx: np.ndarray, p_mut: float
    ) -> np.ndarray:
        """Per-gene uniform resample with probability ``p_mut`` (GA/SA)."""
        out = idx.copy()
        for j, c in enumerate(self._cards):
            if rng.random() < p_mut:
                out[j] = rng.integers(0, c)
        return out

    def mutate_batch(
        self, rng: np.random.Generator, idx: np.ndarray, p_mut: float, n: int
    ) -> np.ndarray:
        """n independent mutations of one index vector, fully vectorized."""
        out = np.broadcast_to(idx, (n, self.n_params)).copy()
        mask = rng.random((n, self.n_params)) < p_mut
        rand = self._raw(rng, n)
        return np.where(mask, rand, out)

    def flat_keys(self, idxs: np.ndarray) -> np.ndarray:
        """Row-wise unique int64 key (mixed-radix encoding) for dedup."""
        strides = np.concatenate(
            [np.cumprod(self._cards[::-1])[::-1][1:], [1]]
        ).astype(np.int64)
        return idxs @ strides

    def neighbor(self, rng: np.random.Generator, idx: np.ndarray) -> np.ndarray:
        """+-1 step on one random axis (simulated-annealing move)."""
        out = idx.copy()
        j = int(rng.integers(0, self.n_params))
        step = 1 if rng.random() < 0.5 else -1
        out[j] = int(np.clip(out[j] + step, 0, self._cards[j] - 1))
        return out

    def __repr__(self) -> str:  # pragma: no cover
        ps = ", ".join(f"{p.name}[{p.cardinality}]" for p in self.params)
        constrained = self.constraint is not None
        return f"SearchSpace({ps}, |S|={self.cardinality}, constrained={constrained})"


def _paper_wg256(cfg: Config) -> bool:
    """The paper's workgroup constraint: prod(w) <= 256 threads."""
    return cfg["w_x"] * cfg["w_y"] * cfg["w_z"] <= 256


#: stable id used by TuningSpec serialization (see repro.core.api)
_paper_wg256.constraint_id = "paper_wg256"


def paper_space(constrained: bool = True) -> SearchSpace:
    """The paper's 6-parameter space, TPU-adapted (DESIGN.md section 2.1).

    t_x, t_y, t_z in [1..16]  (block-row mult, block-col mult, coarsening)
    w_x, w_y, w_z in [1..8]   (grid splits, pipeline depth)

    |S| = 16^3 * 8^3 = 2,097,152.  The paper's constraint prod(w) <= 256 maps
    onto the *raw parameter* form used by the paper; the TPU VMEM-footprint
    constraint is applied at measurement level per kernel (see
    repro.costmodel.kernel_cost.vmem_bytes).  Here we keep the paper's exact
    arithmetic constraint so the constrained/unconstrained split matches.
    """
    params = [
        Param.int_range("t_x", 1, 16),
        Param.int_range("t_y", 1, 16),
        Param.int_range("t_z", 1, 16),
        Param.int_range("w_x", 1, 8),
        Param.int_range("w_y", 1, 8),
        Param.int_range("w_z", 1, 8),
    ]
    return SearchSpace(params, constraint=_paper_wg256 if constrained else None)
