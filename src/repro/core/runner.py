"""Matrix result containers: :class:`CellResult` / :class:`MatrixResults`.

The matrix driver itself lives in :mod:`repro.core.api`: a
:class:`~repro.core.api.TuningSession` built from a declarative
:class:`~repro.core.api.TuningSpec` owns the (algorithm x sample-size x
experiment) loop, decomposed into work units (:mod:`repro.core.workunits`)
run through the executor registry (:mod:`repro.core.executors`).  This
module keeps the result dataclasses and the :func:`stable_seed` helper every
layer derives experiment seeds from.  (The deprecated ``MatrixRunner`` shim
that used to live here is gone — construct a :class:`TuningSession` with
keyword overrides for in-process space/measurement/dataset objects.)
"""

from __future__ import annotations

import json
import os
import zlib
from dataclasses import dataclass, field

import numpy as np


def stable_seed(*parts) -> int:
    """Deterministic 31-bit seed from arbitrary parts (python's ``hash`` is
    process-salted and would break run-to-run reproducibility)."""
    return zlib.crc32("|".join(map(str, parts)).encode()) & 0x7FFFFFFF


@dataclass
class CellResult:
    """All experiments of one (algorithm, sample_size) cell."""

    algo: str
    sample_size: int
    final_values: np.ndarray          # (E,) median-of-10 runtimes
    search_best_values: np.ndarray    # (E,) best value observed during search
    n_samples_used: np.ndarray        # (E,) budget audit


@dataclass
class MatrixResults:
    cells: dict = field(default_factory=dict)  # (algo, S) -> CellResult
    optimum: float = np.inf

    def add(self, cell: CellResult) -> None:
        self.cells[(cell.algo, cell.sample_size)] = cell
        self.optimum = min(self.optimum, float(cell.final_values.min(initial=np.inf)))

    def finals(self, algo: str, sample_size: int) -> np.ndarray:
        return self.cells[(algo, sample_size)].final_values

    def algorithms(self) -> list[str]:
        return sorted({a for a, _ in self.cells})

    def sample_sizes(self) -> list[int]:
        return sorted({s for _, s in self.cells})

    # -- persistence --------------------------------------------------------
    def save(self, path: str) -> None:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        arrays, meta = {}, []
        for i, ((algo, s), cell) in enumerate(sorted(self.cells.items())):
            arrays[f"final_{i}"] = cell.final_values
            arrays[f"search_{i}"] = cell.search_best_values
            arrays[f"nsamp_{i}"] = cell.n_samples_used
            meta.append({"algo": algo, "sample_size": s, "index": i})
        meta_json = json.dumps({"cells": meta, "optimum": self.optimum})
        np.savez_compressed(path, meta=meta_json, **arrays)

    @classmethod
    def load(cls, path: str) -> "MatrixResults":
        data = np.load(path, allow_pickle=False)
        meta = json.loads(str(data["meta"]))
        out = cls(optimum=meta["optimum"])
        for m in meta["cells"]:
            i = m["index"]
            out.cells[(m["algo"], m["sample_size"])] = CellResult(
                algo=m["algo"],
                sample_size=m["sample_size"],
                final_values=data[f"final_{i}"],
                search_best_values=data[f"search_{i}"],
                n_samples_used=data[f"nsamp_{i}"],
            )
        return out
