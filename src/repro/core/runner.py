"""Matrix result containers + the deprecated :class:`MatrixRunner` shim.

The matrix driver itself lives in :mod:`repro.core.api` now: a
:class:`~repro.core.api.TuningSession` built from a declarative
:class:`~repro.core.api.TuningSpec` owns the (algorithm x sample-size x
experiment) loop, the dataset-served non-SMBO paths, the persistent
measurement store, and the multiprocess ``shards=N`` fan-out.  This module
keeps the result dataclasses (:class:`CellResult`, :class:`MatrixResults`),
the :func:`stable_seed` helper every layer derives experiment seeds from,
and ``MatrixRunner`` — a thin deprecated facade over the session for callers
that hold live space/measurement objects.
"""

from __future__ import annotations

import json
import os
import warnings
import zlib
from dataclasses import dataclass, field

import numpy as np


def stable_seed(*parts) -> int:
    """Deterministic 31-bit seed from arbitrary parts (python's ``hash`` is
    process-salted and would break run-to-run reproducibility)."""
    return zlib.crc32("|".join(map(str, parts)).encode()) & 0x7FFFFFFF


from .dataset import SampleDataset
from .engine import DISPATCH_MODES, MeasurementStore
from .experiment import ExperimentDesign
from .searchers import SEARCHERS
from .space import SearchSpace


@dataclass
class CellResult:
    """All experiments of one (algorithm, sample_size) cell."""

    algo: str
    sample_size: int
    final_values: np.ndarray          # (E,) median-of-10 runtimes
    search_best_values: np.ndarray    # (E,) best value observed during search
    n_samples_used: np.ndarray        # (E,) budget audit


@dataclass
class MatrixResults:
    cells: dict = field(default_factory=dict)  # (algo, S) -> CellResult
    optimum: float = np.inf

    def add(self, cell: CellResult) -> None:
        self.cells[(cell.algo, cell.sample_size)] = cell
        self.optimum = min(self.optimum, float(cell.final_values.min(initial=np.inf)))

    def finals(self, algo: str, sample_size: int) -> np.ndarray:
        return self.cells[(algo, sample_size)].final_values

    def algorithms(self) -> list[str]:
        return sorted({a for a, _ in self.cells})

    def sample_sizes(self) -> list[int]:
        return sorted({s for _, s in self.cells})

    # -- persistence --------------------------------------------------------
    def save(self, path: str) -> None:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        arrays, meta = {}, []
        for i, ((algo, s), cell) in enumerate(sorted(self.cells.items())):
            arrays[f"final_{i}"] = cell.final_values
            arrays[f"search_{i}"] = cell.search_best_values
            arrays[f"nsamp_{i}"] = cell.n_samples_used
            meta.append({"algo": algo, "sample_size": s, "index": i})
        np.savez_compressed(path, meta=json.dumps({"cells": meta, "optimum": self.optimum}), **arrays)

    @classmethod
    def load(cls, path: str) -> "MatrixResults":
        data = np.load(path, allow_pickle=False)
        meta = json.loads(str(data["meta"]))
        out = cls(optimum=meta["optimum"])
        for m in meta["cells"]:
            i = m["index"]
            out.cells[(m["algo"], m["sample_size"])] = CellResult(
                algo=m["algo"],
                sample_size=m["sample_size"],
                final_values=data[f"final_{i}"],
                search_best_values=data[f"search_{i}"],
                n_samples_used=data[f"nsamp_{i}"],
            )
        return out


class MatrixRunner:
    """Deprecated shim: delegates to :class:`repro.core.api.TuningSession`.

    Prefer the declarative facade::

        repro.tune_matrix(TuningSpec(kernel=..., algorithms=..., design=...))

    This class remains for callers that hold live objects (a constructed
    space, a measurement factory closure, a pre-generated dataset); it wires
    them into a session as in-process overrides.  Such sessions cannot be
    sharded — use a fully spec-described ``tune_matrix`` for that.
    """

    def __init__(
        self,
        space: SearchSpace,
        measurement_factory,           # (seed: int) -> BaseMeasurement
        design: ExperimentDesign,
        dataset: SampleDataset | None = None,
        algorithms: tuple[str, ...] = ("rs", "rf", "ga", "bo_gp", "bo_tpe"),
        seed: int = 0,
        verbose: bool = False,
        dispatch: str = "batch",
        store: MeasurementStore | None = None,
        cache_key: str = "",
    ):
        warnings.warn(
            "MatrixRunner is deprecated; use repro.tune_matrix(TuningSpec(...))",
            DeprecationWarning,
            stacklevel=2,
        )
        unknown = [a for a in algorithms if a not in SEARCHERS]
        if unknown:
            raise KeyError(f"unknown algorithms {unknown}")
        if dispatch not in DISPATCH_MODES:
            raise ValueError(f"dispatch must be one of {DISPATCH_MODES}")
        from .api import TuningSession, TuningSpec  # runner must not import api at module level

        spec = TuningSpec(
            kernel=cache_key or "objective",
            searcher=algorithms[0],
            algorithms=tuple(algorithms),
            design=design,
            seed=seed,
            dispatch=dispatch,
            cache_key=cache_key or "objective",
        )
        self.session = TuningSession(
            spec,
            space=space,
            measurement_factory=measurement_factory,
            dataset=dataset,
            store=store,
            verbose=verbose,
        )

    def run(self) -> MatrixResults:
        return self.session.run_matrix()
