"""Matrix runner: algorithms x sample sizes x experiments (paper section V-VI).

Responsibilities:
  * run E independent experiments per (algorithm, sample size) cell with
    independent seeds / noise streams,
  * serve the non-SMBO methods (RS, RF-training) from the 20k pre-generated
    :class:`SampleDataset` exactly as the paper does,
  * re-measure every experiment's winning config ``final_repeats`` (10) times
    and record the median as the experiment result,
  * persist results as .npz + JSON for the statistics/figure layer.
"""

from __future__ import annotations

import json
import os
import zlib
from dataclasses import dataclass, field

import numpy as np


def stable_seed(*parts) -> int:
    """Deterministic 31-bit seed from arbitrary parts (python's ``hash`` is
    process-salted and would break run-to-run reproducibility)."""
    return zlib.crc32("|".join(map(str, parts)).encode()) & 0x7FFFFFFF

from .dataset import SampleDataset
from .engine import DISPATCH_MODES, DiskCachedMeasurement, MeasurementStore
from .experiment import ExperimentDesign
from .measurement import BaseMeasurement
from .searchers import SEARCHERS, make_searcher
from .searchers.base import TuningResult
from .space import SearchSpace
from .surrogates.forest_batched import BatchedForest


@dataclass
class CellResult:
    """All experiments of one (algorithm, sample_size) cell."""

    algo: str
    sample_size: int
    final_values: np.ndarray          # (E,) median-of-10 runtimes
    search_best_values: np.ndarray    # (E,) best value observed during search
    n_samples_used: np.ndarray        # (E,) budget audit


@dataclass
class MatrixResults:
    cells: dict = field(default_factory=dict)  # (algo, S) -> CellResult
    optimum: float = np.inf

    def add(self, cell: CellResult) -> None:
        self.cells[(cell.algo, cell.sample_size)] = cell
        self.optimum = min(self.optimum, float(cell.final_values.min(initial=np.inf)))

    def finals(self, algo: str, sample_size: int) -> np.ndarray:
        return self.cells[(algo, sample_size)].final_values

    def algorithms(self) -> list[str]:
        return sorted({a for a, _ in self.cells})

    def sample_sizes(self) -> list[int]:
        return sorted({s for _, s in self.cells})

    # -- persistence --------------------------------------------------------
    def save(self, path: str) -> None:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        arrays, meta = {}, []
        for i, ((algo, s), cell) in enumerate(sorted(self.cells.items())):
            arrays[f"final_{i}"] = cell.final_values
            arrays[f"search_{i}"] = cell.search_best_values
            arrays[f"nsamp_{i}"] = cell.n_samples_used
            meta.append({"algo": algo, "sample_size": s, "index": i})
        np.savez_compressed(path, meta=json.dumps({"cells": meta, "optimum": self.optimum}), **arrays)

    @classmethod
    def load(cls, path: str) -> "MatrixResults":
        data = np.load(path, allow_pickle=False)
        meta = json.loads(str(data["meta"]))
        out = cls(optimum=meta["optimum"])
        for m in meta["cells"]:
            i = m["index"]
            out.cells[(m["algo"], m["sample_size"])] = CellResult(
                algo=m["algo"],
                sample_size=m["sample_size"],
                final_values=data[f"final_{i}"],
                search_best_values=data[f"search_{i}"],
                n_samples_used=data[f"nsamp_{i}"],
            )
        return out


class MatrixRunner:
    """Executes the (algorithm x sample-size x experiment) matrix through the
    batched ask/tell engine.

    ``dispatch`` selects the engine driver: ``"batch"`` (default) routes each
    proposal batch through ``measure_batch`` — ONE Python-level dispatch per
    batch on the vectorized cost-model backend; ``"one"`` measures config-by-
    config (the parity-audit path; per-cell ``n_samples_used`` is identical).

    ``store`` (a :class:`MeasurementStore`) enables the persistent on-disk
    cache: every served value is memoized under
    ``{cache_key}/seed={exp_seed}|{config}``, so re-running a matrix cell —
    same kernel, same experiment stream — never re-measures.
    """

    def __init__(
        self,
        space: SearchSpace,
        measurement_factory,           # (seed: int) -> BaseMeasurement
        design: ExperimentDesign,
        dataset: SampleDataset | None = None,
        algorithms: tuple[str, ...] = ("rs", "rf", "ga", "bo_gp", "bo_tpe"),
        seed: int = 0,
        verbose: bool = False,
        dispatch: str = "batch",
        store: MeasurementStore | None = None,
        cache_key: str = "",
    ):
        unknown = [a for a in algorithms if a not in SEARCHERS]
        if unknown:
            raise KeyError(f"unknown algorithms {unknown}")
        if dispatch not in DISPATCH_MODES:
            raise ValueError(f"dispatch must be one of {DISPATCH_MODES}")
        self.space = space
        self.measurement_factory = measurement_factory
        self.design = design
        self.dataset = dataset
        self.algorithms = algorithms
        self.seed = seed
        self.verbose = verbose
        self.dispatch = dispatch
        self.store = store
        self.cache_key = cache_key

    def _make_measurement(self, exp_seed: int) -> BaseMeasurement:
        m = self.measurement_factory(exp_seed)
        if self.store is not None:
            m = DiskCachedMeasurement(
                m, self.store, prefix=f"{self.cache_key}/seed={exp_seed}"
            )
        return m

    # -- dataset-served paths (paper section VI.B) ---------------------------
    def _rs_from_dataset(self, experiment: int, budget: int) -> TuningResult:
        idx, vals = self.dataset.chunk(experiment, budget)
        j = int(np.argmin(vals))
        return TuningResult(
            algo="rs",
            best_config=self.space.decode(idx[j]),
            best_value=float(vals[j]),
            history_values=list(vals),
            history_configs=[],
            n_samples=budget,
        )

    def _rf_cell_batched(
        self, sample_size: int, n_exp: int, rf_pool: int = 2048
    ) -> list[TuningResult]:
        """All RF experiments of one sample-size cell, fit in ONE vectorized
        histogram-forest pass (see surrogates/forest_batched.py).  Semantics
        per experiment match the paper: train on a disjoint S-10 dataset
        chunk, measure the model's top-10 predictions over a candidate pool,
        keep the best prediction."""
        top_k = min(10, max(1, sample_size // 2))
        n_train = sample_size - top_k
        chunks = [self.dataset.chunk(e, n_train) for e in range(n_exp)]
        Xc = np.stack([c[0] for c in chunks])
        yc = np.stack([c[1] for c in chunks])
        forest = BatchedForest(
            self.space.cardinalities, n_estimators=100, seed=self.seed
        )
        forest.fit(Xc, yc)
        pool_rng = np.random.default_rng(self.seed + 7)
        pool = self.space.sample_indices(pool_rng, rf_pool)
        preds = forest.predict(pool)                    # (E, P)
        results = []
        for e in range(n_exp):
            exp_seed = stable_seed(self.seed, "rf", sample_size, e)
            measurement = self._make_measurement(exp_seed)
            best = np.argsort(preds[e], kind="stable")[:top_k]
            run_vals = measurement.measure_batch(self.space.decode_batch(pool[best]))
            j = int(np.argmin(run_vals))
            results.append(
                TuningResult(
                    algo="rf",
                    best_config=self.space.decode(pool[best][j]),
                    best_value=float(run_vals[j]),
                    history_values=list(yc[e]) + list(run_vals),
                    history_configs=[],
                    n_samples=sample_size,
                )
            )
        return results

    # -- main loop ------------------------------------------------------------
    def run(self) -> MatrixResults:
        results = MatrixResults()
        for algo in self.algorithms:
            for sample_size, n_exp in self.design.rows():
                finals = np.empty(n_exp)
                search_best = np.empty(n_exp)
                n_used = np.empty(n_exp, dtype=np.int64)
                rf_batch = (
                    self._rf_cell_batched(sample_size, n_exp)
                    if (self.dataset is not None and algo == "rf")
                    else None
                )
                for e in range(n_exp):
                    exp_seed = stable_seed(self.seed, algo, sample_size, e)
                    measurement = self._make_measurement(exp_seed)
                    if rf_batch is not None:
                        tr = rf_batch[e]
                    elif self.dataset is not None and algo == "rs":
                        tr = self._rs_from_dataset(e, sample_size)
                    else:
                        searcher = make_searcher(algo, self.space, seed=exp_seed)
                        tr = searcher.run(
                            measurement, sample_size, dispatch=self.dispatch
                        )
                    finals[e] = measurement.measure_final(
                        tr.best_config, self.design.final_repeats
                    )
                    search_best[e] = tr.best_value
                    n_used[e] = tr.n_samples
                results.add(
                    CellResult(
                        algo=algo,
                        sample_size=sample_size,
                        final_values=finals,
                        search_best_values=search_best,
                        n_samples_used=n_used,
                    )
                )
                if self.verbose:
                    print(
                        f"[runner] {algo:7s} S={sample_size:4d} E={n_exp:4d} "
                        f"median={np.median(finals):.6g} best={finals.min():.6g}"
                    )
        if self.store is not None:
            self.store.save()
        return results
