"""Measurement functions — the objective an autotuner minimizes.

The paper measures kernel wall-clock on GPUs (timer started after H2D copy,
stopped before D2H).  On this CPU-only container the framework offers three
backends (DESIGN.md section 2.2):

* :class:`CallableMeasurement` — wraps any ``f(config) -> seconds`` (used for
  the analytical TPU cost model and for compiled-artifact cost measurements).
* :class:`TimingMeasurement`  — wall-clock of a real callable (interpret-mode
  Pallas kernels in the examples).
* :class:`CachedMeasurement`  — memoizes another measurement (the paper runs a
  config once during search; re-measuring during search would leak budget).

Every measurement counts how many *samples* it has served, so searchers can
be budget-audited, and exposes ``measure_final`` which re-runs the winning
config ``final_repeats`` times (paper: 10) and returns the median.
"""

from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Callable, Protocol, Sequence

import numpy as np

from ..telemetry.null import NULL_TELEMETRY
from .clock import monotonic
from .space import Config


class Measurement(Protocol):
    def measure(self, config: Config) -> float: ...
    def measure_batch(self, configs: Sequence[Config]) -> np.ndarray: ...
    def measure_final(self, config: Config, repeats: int = 10) -> float: ...


class StageClock:
    """Accumulates wall-clock per named pipeline stage.

    A staged measurement backend (screen -> compile -> time -> record) charges
    each stage's cost here, so provenance can split "how long did this search
    take" into "how long did it compile" vs "how long did it measure".  Adds
    are thread-safe: a compile prefetcher charges the compile stage from pool
    threads while the main thread charges the timing stage.
    """

    def __init__(self) -> None:
        self._acc: dict[str, float] = {}
        self._lock = threading.Lock()

    @contextmanager
    def stage(self, name: str):
        t0 = monotonic()
        try:
            yield
        finally:
            self.add(name, monotonic() - t0)

    def add(self, name: str, seconds: float) -> None:
        with self._lock:
            self._acc[name] = self._acc.get(name, 0.0) + float(seconds)

    def times(self) -> dict[str, float]:
        with self._lock:
            return dict(self._acc)

    def reset(self) -> None:
        with self._lock:
            self._acc.clear()


def fence(out) -> None:
    """Block until async work behind ``out`` retires.

    jax dispatch is asynchronous: a runner that returns a DeviceArray has
    only *enqueued* the computation.  Timing backends must call this INSIDE
    the timed region (and on warmup results, so leftover async work never
    leaks into the first timed call).  Non-jax results are materialized
    through numpy; ``None`` means the runner blocked on its own.
    """
    if out is None:
        return
    if hasattr(out, "block_until_ready"):
        out.block_until_ready()
    else:
        np.asarray(out)


class BaseMeasurement:
    """Common bookkeeping: sample + dispatch counting, final-config repetition.

    ``n_samples`` audits the search budget (one per config served).
    ``n_dispatches`` counts Python-level entries into the backend — the
    batched engine's figure of merit: a vectorized backend serves a whole
    batch in ONE dispatch, the scalar fallback pays one per config.
    """

    def __init__(self) -> None:
        self.n_samples = 0
        self.n_dispatches = 0
        #: telemetry sink (observability only — never feeds values); the
        #: no-op default keeps the disabled path identical to the old code
        self.telemetry = NULL_TELEMETRY

    def set_telemetry(self, telemetry) -> None:
        """Attach a telemetry sink (``None`` resets to the no-op default).
        Wrapper measurements forward to their inner backend so stage events
        and counters come from the layer that actually does the work."""
        self.telemetry = telemetry if telemetry is not None else NULL_TELEMETRY

    def _measure_one(self, config: Config) -> float:  # pragma: no cover
        raise NotImplementedError

    def measure(self, config: Config) -> float:
        self.n_samples += 1
        self.n_dispatches += 1
        return float(self._measure_one(config))

    def measure_batch(self, configs: Sequence[Config]) -> np.ndarray:
        return np.array([self.measure(c) for c in configs], dtype=np.float64)

    def skip_samples(self, n: int) -> None:
        """Advance any per-sample state (e.g. a noise counter) WITHOUT
        measuring — called by caching layers when serving hits, so a
        warm-cache run keeps the same per-sample noise alignment as a cold
        one.  Default: nothing to advance."""

    def measure_final(self, config: Config, repeats: int = 10) -> float:
        """Re-measure the chosen config ``repeats`` times; return the median.

        Per the paper (section VI.A): 'When the autotuning algorithm has
        terminated, we test the final sample 10 times to compensate for
        runtime variance.'  These repeats are NOT counted against the search
        budget.
        """
        vals = [float(self._measure_one(config)) for _ in range(repeats)]
        return float(np.median(vals))

    def reset(self) -> None:
        self.n_samples = 0
        self.n_dispatches = 0

    # -- introspection hooks (wrappers delegate; defaults are inert) ----------
    def provenance(self) -> dict:
        """How this backend produced its numbers (timer, device, repeats...).
        Recorded into the versioned RunRecord; ``{}`` means nothing to say."""
        return {}

    def reason_for(self, config: Config) -> str | None:
        """Why ``config`` was penalized (``inf``), if this backend knows."""
        return None

    def repeats_for(self, config: Config) -> list | None:
        """Raw per-repeat timings behind the last aggregate for ``config``."""
        return None

    def stage_times(self) -> dict[str, float]:
        """Per-stage wall-clock (seconds) accumulated since the last reset —
        staged backends report ``{"screen": ..., "compile": ..., "time": ...}``
        from their :class:`StageClock`; ``{}`` means the backend is unstaged."""
        return {}


class CallableMeasurement(BaseMeasurement):
    def __init__(self, fn: Callable[[Config], float],
                 batch_fn: Callable[[Sequence[Config]], np.ndarray] | None = None):
        super().__init__()
        self._fn = fn
        self._batch_fn = batch_fn

    def _measure_one(self, config: Config) -> float:
        return self._fn(config)

    def measure_batch(self, configs: Sequence[Config]) -> np.ndarray:
        if self._batch_fn is None:
            return super().measure_batch(configs)
        self.n_samples += len(configs)
        self.n_dispatches += 1
        return np.asarray(self._batch_fn(configs), dtype=np.float64)


class TimingMeasurement(BaseMeasurement):
    """Times ``runner(config)`` with a monotonic clock.

    At least one warmup call runs per distinct config before timing (more
    with ``warmup > 1``), so compilation/tracing cost is always excluded —
    the analogue of the paper starting the timer only after host->device
    transfer.  Warmup results AND the timed result are fenced
    (:func:`fence`): async dispatch retires inside the timed region, never
    before it or after it.
    """

    def __init__(self, runner: Callable[[Config], None], warmup: int = 1):
        super().__init__()
        self._runner = runner
        self._warmup = max(1, warmup)
        self._warmed: set = set()

    def _key(self, config: Config):
        return tuple(sorted(config.items()))

    def _measure_one(self, config: Config) -> float:
        k = self._key(config)
        if k not in self._warmed:
            for _ in range(self._warmup):
                fence(self._runner(config))
            self._warmed.add(k)
        t0 = monotonic()
        fence(self._runner(config))
        return monotonic() - t0


class CachedMeasurement(BaseMeasurement):
    """Memoizes an inner measurement by config.

    During search the paper evaluates each configuration once ('We only run
    the sample once during the training and sampling process').  Searchers
    that revisit a config (GA elites, SA plateaus) therefore see the *same*
    noisy observation rather than a fresh draw, and the revisit does not
    consume extra budget.
    """

    def __init__(self, inner: BaseMeasurement):
        super().__init__()
        self._inner = inner
        self._cache: dict = {}

    def _key(self, config: Config):
        return tuple(sorted(config.items()))

    def measure(self, config: Config) -> float:
        self.n_dispatches += 1
        k = self._key(config)
        if k not in self._cache:
            self._cache[k] = self._inner.measure(config)
            self.n_samples += 1
        return self._cache[k]

    def measure_batch(self, configs: Sequence[Config]) -> np.ndarray:
        """Batch-aware memoization: only uncached configs reach the inner
        backend, in ONE dispatch (duplicates within the batch collapse)."""
        self.n_dispatches += 1
        keys = [self._key(c) for c in configs]
        fresh_keys: list = []
        fresh_cfgs: list = []
        seen_fresh: set = set()
        for k, c in zip(keys, configs, strict=True):
            if k not in self._cache and k not in seen_fresh:
                seen_fresh.add(k)
                fresh_keys.append(k)
                fresh_cfgs.append(c)
        if fresh_cfgs:
            vals = self._inner.measure_batch(fresh_cfgs)
            self.n_samples += len(fresh_cfgs)
            self._cache.update(zip(fresh_keys, (float(v) for v in vals), strict=True))
        return np.array([self._cache[k] for k in keys], dtype=np.float64)

    def _measure_one(self, config: Config) -> float:
        return self._inner._measure_one(config)

    def measure_final(self, config: Config, repeats: int = 10) -> float:
        return self._inner.measure_final(config, repeats)

    def skip_samples(self, n: int) -> None:
        self._inner.skip_samples(n)

    def set_telemetry(self, telemetry) -> None:
        super().set_telemetry(telemetry)
        self._inner.set_telemetry(telemetry)

    def provenance(self) -> dict:
        return self._inner.provenance()

    def reason_for(self, config: Config) -> str | None:
        return self._inner.reason_for(config)

    def repeats_for(self, config: Config) -> list | None:
        return self._inner.repeats_for(config)

    def stage_times(self) -> dict[str, float]:
        return self._inner.stage_times()

    def reset(self) -> None:
        super().reset()
        self._cache.clear()
        self._inner.reset()
