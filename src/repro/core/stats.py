"""Statistics for comparing autotuning algorithms.

The paper's toolkit (sections II.C, V.A):

* Mann-Whitney U test (two-sided, normal approximation with tie correction)
  at alpha = 0.01 — non-parametric because tuned-runtime populations are
  "obviously non-gaussian".
* Common Language Effect Size (CLES / Vargha-Delaney A, eq. 1):
  A(X_A, X_B) = P(X_A > X_B) + 0.5 P(X_A = X_B).

Implemented from first principles on numpy (validated against scipy in the
test suite) so the library has no hard scipy dependency at runtime.
"""

from __future__ import annotations

import math
from dataclasses import dataclass

import numpy as np

ALPHA = 0.01  # the paper's significance threshold


def _rankdata(x: np.ndarray) -> np.ndarray:
    """Average ranks (1-based), ties share the mean rank."""
    order = np.argsort(x, kind="mergesort")
    ranks = np.empty(len(x), dtype=np.float64)
    sx = x[order]
    i = 0
    while i < len(sx):
        j = i
        while j + 1 < len(sx) and sx[j + 1] == sx[i]:
            j += 1
        ranks[order[i : j + 1]] = 0.5 * (i + j) + 1.0
        i = j + 1
    return ranks


def _norm_sf(z: float) -> float:
    """Standard normal survival function via erfc."""
    return 0.5 * math.erfc(z / math.sqrt(2.0))


@dataclass(frozen=True)
class MWUResult:
    u: float
    p_value: float
    n_a: int
    n_b: int

    def significant(self, alpha: float = ALPHA) -> bool:
        return self.p_value < alpha


def mann_whitney_u(a: np.ndarray, b: np.ndarray) -> MWUResult:
    """Two-sided MWU with tie-corrected normal approximation.

    Matches scipy.stats.mannwhitneyu(method="asymptotic", use_continuity=True)
    (see tests/test_stats.py for the cross-check).
    """
    a = np.asarray(a, dtype=np.float64)
    b = np.asarray(b, dtype=np.float64)
    n_a, n_b = len(a), len(b)
    if n_a == 0 or n_b == 0:
        raise ValueError("empty sample")
    both = np.concatenate([a, b])
    ranks = _rankdata(both)
    r_a = ranks[:n_a].sum()
    u_a = r_a - n_a * (n_a + 1) / 2.0
    mu = n_a * n_b / 2.0
    # tie correction
    _, counts = np.unique(both, return_counts=True)
    n = n_a + n_b
    tie_term = ((counts**3 - counts).sum()) / (n * (n - 1)) if n > 1 else 0.0
    sigma2 = n_a * n_b / 12.0 * ((n + 1) - tie_term)
    if sigma2 <= 0:
        return MWUResult(u=u_a, p_value=1.0, n_a=n_a, n_b=n_b)
    # two-sided with continuity correction
    z = (u_a - mu - 0.5 * np.sign(u_a - mu)) / math.sqrt(sigma2)
    p = min(1.0, 2.0 * _norm_sf(abs(z)))
    return MWUResult(u=u_a, p_value=p, n_a=n_a, n_b=n_b)


def cles(a: np.ndarray, b: np.ndarray) -> float:
    """Common Language Effect Size  A(X_A, X_B) = P(A > B) + 0.5 P(A = B).

    Computed exactly from ranks in O((n+m) log(n+m)) rather than the O(n*m)
    pairwise comparison — equivalent by the U-statistic identity.
    """
    a = np.asarray(a, dtype=np.float64)
    b = np.asarray(b, dtype=np.float64)
    n_a, n_b = len(a), len(b)
    both = np.concatenate([a, b])
    ranks = _rankdata(both)
    r_a = ranks[:n_a].sum()
    u_a = r_a - n_a * (n_a + 1) / 2.0  # = #(A>B) + 0.5 #(A==B)
    return float(u_a / (n_a * n_b))


def cles_lower_better(a: np.ndarray, b: np.ndarray) -> float:
    """P(algorithm A beats B) when the metric is runtime (lower is better).

    The paper's Fig. 4b plots 'probability of the algorithm's solution
    outperforming Random Search' — with runtimes, A outperforms B when
    X_A < X_B, i.e. CLES(B, A) in the eq.-1 sense.
    """
    return cles(np.asarray(b), np.asarray(a))


def median_speedup(baseline: np.ndarray, algo: np.ndarray) -> float:
    """median(baseline) / median(algo): >1 means algo is faster (Fig. 4a)."""
    return float(np.median(baseline) / np.median(algo))


def pct_of_optimum(values: np.ndarray, optimum: float) -> np.ndarray:
    """Percentage-of-optimum performance for runtimes: optimum / value * 100.

    100% means the tuned config matches the study's best-known runtime
    (the paper's Fig. 2 metric).
    """
    values = np.asarray(values, dtype=np.float64)
    return optimum / values * 100.0


def bootstrap_ci(
    x: np.ndarray,
    stat=np.mean,
    n_boot: int = 2000,
    ci: float = 0.95,
    seed: int = 0,
) -> tuple[float, float, float]:
    """(stat, lo, hi) percentile-bootstrap confidence interval (Fig. 3 bands)."""
    x = np.asarray(x, dtype=np.float64)
    rng = np.random.default_rng(seed)
    idx = rng.integers(0, len(x), size=(n_boot, len(x)))
    boots = stat(x[idx], axis=1)
    lo, hi = np.percentile(boots, [(1 - ci) / 2 * 100, (1 + ci) / 2 * 100])
    return float(stat(x)), float(lo), float(hi)


def compare_algorithms(
    results_a: np.ndarray, results_b: np.ndarray
) -> dict:
    """Full paper-style comparison of two runtime populations (lower=better)."""
    mwu = mann_whitney_u(results_a, results_b)
    return {
        "median_a": float(np.median(results_a)),
        "median_b": float(np.median(results_b)),
        "speedup_a_over_b": median_speedup(results_b, results_a),
        "cles_a_beats_b": cles_lower_better(results_a, results_b),
        "mwu_p": mwu.p_value,
        "significant": mwu.significant(),
    }
