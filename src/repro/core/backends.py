"""Measurement-backend registry: ``make_measurement(name, **kwargs)``.

Mirrors the ``SEARCHERS`` registry for the evaluation side of the tuner, so
a :class:`~repro.core.api.TuningSpec` can name its backend declaratively and
the sharded session driver can rebuild the exact measurement in a worker
process.  Built-in backends:

* ``"costmodel"`` — the analytical TPU cost model with counter-based noise
  (``kernel=..., chip=..., seed=..., noise=...``); also provides the default
  :class:`SearchSpace` (executable configs) and the noise-free true optimum.
* ``"pallas"``    — REAL ``pl.pallas_call`` execution through
  :mod:`repro.pallas_bench` (compile-once-per-geometry cache, warmup +
  N-repeat fenced timing, validity pre-screen mapping failures to ``inf``
  penalties); name-serializable, so specs using it shard cleanly.  Interpret
  mode on CPU, Mosaic on TPU, selected automatically.
* ``"timing"``    — wall-clock of a real callable (``runner=..., warmup=...``),
  for custom objectives the ``pallas`` backend doesn't cover.
* ``"cached"``    — in-memory memoization of an ``inner`` backend (paper: a
  config is measured once during search).
* ``"disk"``      — persistent memoization of an ``inner`` backend through a
  measurement store (``store="json"|"sqlite"``, ``store_path=...``).

``inner`` is either a backend *name* (resolved recursively, with
``inner_kwargs``) or an already-built measurement instance.  Register custom
backends with :func:`register_backend`.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

from .engine import DiskCachedMeasurement
from .measurement import (
    BaseMeasurement,
    CachedMeasurement,
    CallableMeasurement,
    TimingMeasurement,
)
from .space import SearchSpace


@dataclass(frozen=True)
class Backend:
    """A named measurement backend.

    ``make(kernel=..., seed=..., **kwargs)`` builds a measurement; backends
    that don't need the kernel id / seed accept and ignore them, so the
    session driver can call every backend uniformly.  ``default_space`` /
    ``true_optimum`` are optional hooks the costmodel backend provides so a
    spec can omit its space and records can carry the exact optimum.
    ``serializable`` marks whether specs using this backend can round-trip
    through JSON (a backend whose kwargs hold callables cannot be shipped to
    shard workers).  ``pipeline`` marks whether ``make`` accepts a
    ``pipeline_workers=`` kwarg (the staged compile-prefetch pipeline); the
    session driver refuses to silently drop the knob on backends without it.
    """

    name: str
    make: Callable[..., BaseMeasurement]
    default_space: Callable[..., SearchSpace] | None = None
    true_optimum: Callable[..., tuple[dict, float]] | None = None
    serializable: bool = True
    pipeline: bool = False


BACKENDS: dict[str, Backend] = {}


def register_backend(backend: Backend) -> Backend:
    BACKENDS[backend.name] = backend
    return backend


def make_measurement(name: str, **kwargs) -> BaseMeasurement:
    """Build a measurement backend by registry name."""
    if name not in BACKENDS:
        raise KeyError(f"unknown backend {name!r}; have {sorted(BACKENDS)}")
    return BACKENDS[name].make(**kwargs)


# --------------------------------------------------------------- costmodel


def _costmodel_parts(kernel: str, chip: str):
    # lazy import: core must stay importable without the costmodel package
    from ..costmodel import CHIPS, WORKLOADS

    if kernel not in WORKLOADS:
        raise KeyError(f"unknown kernel {kernel!r}; have {sorted(WORKLOADS)}")
    if chip not in CHIPS:
        raise KeyError(f"unknown chip {chip!r}; have {sorted(CHIPS)}")
    return WORKLOADS[kernel], CHIPS[chip]


def _make_costmodel(
    kernel: str = "harris", chip: str = "v5e", seed: int = 0, noise: bool = True
) -> BaseMeasurement:
    from ..costmodel import CostModelMeasurement

    w, c = _costmodel_parts(kernel, chip)
    return CostModelMeasurement(w, c, seed=seed, noise=noise)


def _costmodel_space(kernel: str = "harris", chip: str = "v5e", **_) -> SearchSpace:
    from ..costmodel import executable_space

    w, c = _costmodel_parts(kernel, chip)
    return executable_space(w, c)


def _costmodel_optimum(kernel: str = "harris", chip: str = "v5e", **_):
    from ..costmodel import true_optimum

    w, c = _costmodel_parts(kernel, chip)
    return true_optimum(w, c)


# ------------------------------------------------------------------ pallas


def _make_pallas(
    kernel: str = "add",
    seed: int = 0,
    *,
    x: int | None = None,
    y: int | None = None,
    input_seed: int = 0,
    repeats: int = 5,
    warmup: int = 1,
    vmem_limit: int | None = None,
    max_grid: int | None = None,
    validate: bool = True,
    pipeline_workers: int = 0,
    compile_cache: str | None = None,
) -> BaseMeasurement:
    # lazy import: core must stay importable without jax/pallas_bench
    from ..pallas_bench import (
        DEFAULT_MAX_GRID,
        DEFAULT_VMEM_LIMIT,
        DEFAULT_X,
        DEFAULT_Y,
        PallasMeasurement,
        make_workload,
    )

    workload = make_workload(
        kernel,
        x=x if x is not None else DEFAULT_X,
        y=y if y is not None else DEFAULT_Y,
        input_seed=input_seed,
    )
    return PallasMeasurement(
        workload,
        repeats=repeats,
        warmup=warmup,
        vmem_limit=vmem_limit if vmem_limit is not None else DEFAULT_VMEM_LIMIT,
        max_grid=max_grid if max_grid is not None else DEFAULT_MAX_GRID,
        validate=validate,
        pipeline_workers=pipeline_workers,
        compile_cache=compile_cache,
    )


def _pallas_space(kernel: str = "add", **kwargs) -> SearchSpace:
    from ..pallas_bench import (
        DEFAULT_MAX_GRID,
        DEFAULT_VMEM_LIMIT,
        DEFAULT_X,
        DEFAULT_Y,
        default_space,
    )

    return default_space(
        kernel,
        x=kwargs.get("x") or DEFAULT_X,
        y=kwargs.get("y") or DEFAULT_Y,
        vmem_limit=kwargs.get("vmem_limit") or DEFAULT_VMEM_LIMIT,
        max_grid=kwargs.get("max_grid") or DEFAULT_MAX_GRID,
    )


# --------------------------------------------------------------- wrappers


def _make_timing(
    kernel: str | None = None,
    seed: int = 0,
    *,
    runner: Callable,
    warmup: int = 1,
) -> BaseMeasurement:
    return TimingMeasurement(runner, warmup=warmup)


def _make_callable(
    kernel: str | None = None,
    seed: int = 0,
    *,
    fn: Callable,
    batch_fn: Callable | None = None,
) -> BaseMeasurement:
    return CallableMeasurement(fn, batch_fn=batch_fn)


def _resolve_inner(inner, inner_kwargs, kernel, seed) -> BaseMeasurement:
    if isinstance(inner, str):
        return make_measurement(inner, kernel=kernel, seed=seed, **(inner_kwargs or {}))
    if isinstance(inner, BaseMeasurement):
        return inner
    raise TypeError(
        f"inner must be a backend name or a BaseMeasurement, got {type(inner).__name__}"
    )


def _make_cached(
    kernel: str | None = None,
    seed: int = 0,
    *,
    inner,
    inner_kwargs: dict | None = None,
) -> BaseMeasurement:
    return CachedMeasurement(_resolve_inner(inner, inner_kwargs, kernel, seed))


def _make_disk(
    kernel: str | None = None,
    seed: int = 0,
    *,
    inner,
    inner_kwargs: dict | None = None,
    store="json",
    store_path: str | None = None,
    prefix: str | None = None,
) -> BaseMeasurement:
    from .stores import make_store

    if isinstance(store, str):
        store = make_store(store, store_path)
    if prefix is None:
        prefix = f"{kernel or 'objective'}/seed={seed}"
    return DiskCachedMeasurement(
        _resolve_inner(inner, inner_kwargs, kernel, seed), store, prefix
    )


register_backend(
    Backend(
        name="costmodel",
        make=_make_costmodel,
        default_space=_costmodel_space,
        true_optimum=_costmodel_optimum,
    )
)
register_backend(
    Backend(
        name="pallas", make=_make_pallas, default_space=_pallas_space, pipeline=True
    )
)
register_backend(Backend(name="timing", make=_make_timing, serializable=False))
register_backend(Backend(name="callable", make=_make_callable, serializable=False))
register_backend(Backend(name="cached", make=_make_cached))
register_backend(Backend(name="disk", make=_make_disk))
