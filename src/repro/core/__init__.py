"""repro.core — the paper's contribution: sample-size-aware empirical
autotuning with RS / RF / GA / BO-GP / BO-TPE searchers and the
MWU + CLES statistics layer."""

from .space import Config, Param, SearchSpace, paper_space
from .measurement import (
    BaseMeasurement,
    CachedMeasurement,
    CallableMeasurement,
    TimingMeasurement,
)
from .engine import (
    DiskCachedMeasurement,
    MeasurementStore,
    config_key,
    drive,
)
from .experiment import ExperimentDesign
from .dataset import SampleDataset
from .runner import CellResult, MatrixResults, MatrixRunner
from .searchers import (
    EXTRA_ALGORITHMS,
    PAPER_ALGORITHMS,
    SEARCHERS,
    Searcher,
    TuningResult,
    make_searcher,
)
from . import stats

__all__ = [
    "Config",
    "Param",
    "SearchSpace",
    "paper_space",
    "BaseMeasurement",
    "CachedMeasurement",
    "CallableMeasurement",
    "TimingMeasurement",
    "DiskCachedMeasurement",
    "MeasurementStore",
    "config_key",
    "drive",
    "ExperimentDesign",
    "SampleDataset",
    "CellResult",
    "MatrixResults",
    "MatrixRunner",
    "SEARCHERS",
    "PAPER_ALGORITHMS",
    "EXTRA_ALGORITHMS",
    "Searcher",
    "TuningResult",
    "make_searcher",
    "stats",
]
