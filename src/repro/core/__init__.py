"""repro.core — the paper's contribution: sample-size-aware empirical
autotuning with RS / RF / GA / BO-GP / BO-TPE searchers, the MWU + CLES
statistics layer, and the declarative ``tune()`` facade on top."""

from . import stats
from .api import (
    RunRecord,
    TuningSession,
    TuningSpec,
    register_constraint,
    tune,
    tune_matrix,
)
from .backends import BACKENDS, Backend, make_measurement, register_backend
from .dataset import SampleDataset
from .engine import DiskCachedMeasurement, MeasurementStore, config_key, drive
from .executors import EXECUTORS, Executor, register_executor
from .experiment import ExperimentDesign
from .measurement import (
    BaseMeasurement,
    CachedMeasurement,
    CallableMeasurement,
    StageClock,
    TimingMeasurement,
)
from .runner import CellResult, MatrixResults, stable_seed
from .searchers import (
    EXTRA_ALGORITHMS,
    PAPER_ALGORITHMS,
    SEARCHERS,
    Searcher,
    TuningResult,
    make_searcher,
)
from .space import Config, Param, SearchSpace, paper_space
from .stores import STORES, SqliteMeasurementStore, make_store
from .workunits import (
    ExperimentUnit,
    UnitJournal,
    UnitResult,
    build_units,
    merge_unit_results,
)

__all__ = [
    "Config",
    "Param",
    "SearchSpace",
    "paper_space",
    "BaseMeasurement",
    "CachedMeasurement",
    "CallableMeasurement",
    "StageClock",
    "TimingMeasurement",
    "DiskCachedMeasurement",
    "MeasurementStore",
    "SqliteMeasurementStore",
    "STORES",
    "make_store",
    "BACKENDS",
    "Backend",
    "make_measurement",
    "register_backend",
    "config_key",
    "drive",
    "ExperimentDesign",
    "SampleDataset",
    "CellResult",
    "MatrixResults",
    "stable_seed",
    "ExperimentUnit",
    "UnitJournal",
    "UnitResult",
    "build_units",
    "merge_unit_results",
    "EXECUTORS",
    "Executor",
    "register_executor",
    "SEARCHERS",
    "PAPER_ALGORITHMS",
    "EXTRA_ALGORITHMS",
    "Searcher",
    "TuningResult",
    "make_searcher",
    "RunRecord",
    "TuningSession",
    "TuningSpec",
    "register_constraint",
    "tune",
    "tune_matrix",
    "stats",
]
