"""Executor registry: pluggable strategies for running experiment units.

Mirrors ``SEARCHERS`` / ``BACKENDS`` / ``STORES``: an executor is resolved by
name and runs a list of :class:`~repro.core.workunits.ExperimentUnit`\\ s for
a session, returning :class:`~repro.core.workunits.UnitResult` fragments the
session merges deterministically by unit key.  Built-ins:

* ``"serial"``  — the in-process loop; journals each completed unit.
* ``"process"`` — ``multiprocessing`` (spawn) fan-out.  Under the default
  *work-stealing* scheduler each worker process builds ONE persistent
  session at pool start (initializer), then pulls units one at a time from
  the shared submit queue — a worker that finishes early simply takes the
  next pending unit instead of idling behind a static partition.  Each
  worker writes to its own ``store_path.<ns8>.shard<pid>`` (seeded from the warm
  parent store), journals completed units into it, and the parent glob-
  merges shard stores when the pool joins.
* ``"futures"`` — the grouped worker payload submitted to ANY
  ``concurrent.futures.Executor``.  Pass a live pool via
  ``run_matrix(futures_pool=...)`` (a ``ThreadPoolExecutor``, a cluster
  client's pool adapter, ...); without one a spawn-context
  ``ProcessPoolExecutor`` is created for the call.  This is the
  remote-executor seam: the payload is ``(spec_dict, unit dicts,
  store paths)`` and the results come back as plain JSON-able dicts, so an
  executor whose workers live on other hosts only needs to ship the payload
  and a store path visible to the worker.  Under the stealing scheduler
  every payload carries exactly one unit, so any pool balances the queue;
  the cost is one session rebuild per unit (document-level knob: use
  ``scheduler="static"`` for pools where rebuilds dominate).
* ``"device"``  — multi-chip fan-out WITHIN one process: worker threads,
  each pinned to one of ``jax.devices()`` via ``jax.default_device``, with
  one shard store per device.  Under the stealing scheduler each thread
  keeps a persistent session (compilation caches warm across units) and
  pulls units as it frees up.  An 8-chip host runs the matrix ~8x wider
  with no process spawn or re-import; merges are bit-identical to
  ``serial`` because workers rebuild sessions from the same serialized
  spec and seeds derive from the spec alone.

Scheduling: ``ExecutionPlan.scheduler`` selects ``"steal"`` (default — one
unit per submission, ``as_completed`` streaming, telemetry counters for
steals and a queue-depth gauge) or ``"static"`` (the round-robin
one-payload-per-worker partition; same results, coarser balancing).  Unit
*results* merge by unit key, so both schedules — and any completion order —
are bit-identical to the serial loop.

Parallel executors collect worker results as they complete and fail fast:
the first worker exception cancels outstanding work, absorbs completed
workers' shard stores (their journaled units survive into the parent) and
trace shards, and re-raises.

Worker crash/kill recovery: because workers journal completed units into
their shard stores as they go, :func:`recover_shard_stores` can absorb
leftover ``*.<ns8>.shard<k>`` files from a killed run into the parent store
before a resumed run partitions its units — nothing a dead worker finished is
lost.  Shard filenames are namespaced by the session's journal-namespace
digest, so recovery never absorbs shards a *different* spec left behind in a
shared store directory.
"""

from __future__ import annotations

import os
import re
import zlib
from dataclasses import dataclass, field
from typing import Any, Callable

from .stores import absorb_winners, make_store
from .workunits import ExperimentUnit, UnitResult

__all__ = [
    "EXECUTORS",
    "ExecutionPlan",
    "Executor",
    "recover_shard_stores",
    "register_executor",
    "run_units",
    "shard_namespace",
    "shard_store_path",
]


@dataclass
class ExecutionPlan:
    """Everything an executor needs for one fan-out."""

    session: Any                      # TuningSession (duck-typed; no import cycle)
    units: list[ExperimentUnit] = field(default_factory=list)
    max_workers: int = 1
    futures_pool: Any = None          # concurrent.futures.Executor, "futures" only
    scheduler: str = "steal"          # "steal" (shared unit queue) | "static"


@dataclass(frozen=True)
class Executor:
    """A named unit-execution strategy.

    ``parallel`` marks executors that ship work out of the calling process:
    they require a fully serializable spec (no in-process overrides, a
    name-resolvable backend) and degrade to ``serial`` — with a warning —
    when the plan cannot keep more than one worker busy.
    """

    name: str
    run: Callable[[ExecutionPlan], list[UnitResult]]
    parallel: bool = True


EXECUTORS: dict[str, Executor] = {}


def register_executor(executor: Executor) -> Executor:
    EXECUTORS[executor.name] = executor
    return executor


def run_units(name: str, plan: ExecutionPlan) -> list[UnitResult]:
    """Run ``plan`` through the named executor."""
    if name not in EXECUTORS:
        raise KeyError(f"unknown executor {name!r}; have {sorted(EXECUTORS)}")
    return EXECUTORS[name].run(plan)


# -------------------------------------------------------------------- serial


def _run_serial(plan: ExecutionPlan) -> list[UnitResult]:
    session = plan.session
    journal = session.unit_journal()
    out = []
    for unit in plan.units:
        result = session.run_unit(unit)
        if journal is not None:
            journal.put(result)   # flushed (throttled) — a kill loses little
        out.append(result)
    return out


register_executor(Executor(name="serial", run=_run_serial, parallel=False))


# ----------------------------------------------------- shard-store plumbing


def shard_namespace(session) -> str:
    """8-hex digest namespacing this session's shard-store filenames.

    Derived from :meth:`TuningSession.journal_namespace` — the same
    fingerprint that scopes unit-journal entries — so two different specs
    sharing one store directory (or one store *path*) can never absorb each
    other's leftover shards on recovery."""
    ns = session.journal_namespace()
    if ns is None:
        # no stable fingerprint (live callables in the spec): fall back to
        # the cache key, which still separates kernels/chips
        ns = str(session.cache_key)
    return f"{zlib.crc32(ns.encode('utf-8')) & 0xFFFFFFFF:08x}"


def shard_store_path(session, ident) -> str | None:
    """The shard-store filename for worker ``ident`` (pid, device index, or
    a fleet worker's host-pid string): ``<store>.<ns8>.shard<ident>``."""
    if session.spec.store is None or session._store_path is None:
        return None
    return f"{session._store_path}.{shard_namespace(session)}.shard{ident}"


def _shard_store_path(session, shard) -> str | None:
    return shard_store_path(session, shard)


def absorb_store(dst, kind: str, path: str) -> None:
    """Copy one store file's values AND metadata (which carries the unit
    journal) into ``dst``; serving winner records merge under the
    better-value / never-staler policy."""
    src = make_store(kind, path)
    dst.update(src.items())
    if hasattr(src, "meta_items"):
        dst.update_meta(src.meta_items())
    absorb_winners(dst, src)
    if hasattr(src, "close"):
        src.close()


def merge_shard_stores(session, paths: list[str]) -> None:
    """Fold worker shard stores into the session's main store, then delete
    the shard files."""
    if session.store is None:
        return
    for path in paths:
        if path is None or not os.path.exists(path):
            continue
        absorb_store(session.store, session.spec.store, path)
        os.remove(path)
    session.store.save()


def recover_shard_stores(session) -> int:
    """Absorb shard stores left behind by a killed parallel run.

    Workers journal completed units into their shard stores incrementally,
    so even though the dead parent never merged them, their measurements and
    journal entries are intact on disk.  Returns how many files were
    recovered.
    """
    base = session._store_path
    if session.store is None or base is None:
        return 0
    # the namespace digest scopes recovery to THIS spec's shards: a different
    # spec writing through the same store path leaves shards this glob must
    # not absorb (its journal entries would be orphaned, its values wrong)
    pattern = re.compile(
        re.escape(f"{os.path.basename(base)}.{shard_namespace(session)}")
        + r"\.shard[A-Za-z0-9_-]+$"
    )
    d = os.path.dirname(base) or "."
    if not os.path.isdir(d):
        return 0
    leftovers = sorted(
        os.path.join(d, f) for f in os.listdir(d) if pattern.fullmatch(f)
    )
    merge_shard_stores(session, leftovers)
    # a killed run's workers also leave trace.shard<k>.jsonl files beside the
    # parent trace; fold them in so the resumed trace keeps their spans
    session.telemetry.recover()
    return len(leftovers)


# ----------------------------------------------------------- worker payloads


def _check_shippable(session) -> dict:
    """Validate that the session can be rebuilt in a worker; return the
    serialized spec.  Raises the same errors for every parallel executor."""
    if session._has_overrides:
        raise RuntimeError(
            "parallel matrix runs rebuild the session from the serialized "
            "spec in worker processes; in-process overrides (space/"
            "measurement_factory/dataset/store objects) cannot be shipped"
        )
    if not session._backend.serializable:
        raise RuntimeError(
            f"backend {session.spec.backend!r} holds in-process callables and "
            "cannot be rebuilt in shard workers; use a name-resolvable "
            "backend (e.g. 'costmodel') for parallel runs"
        )
    return session.spec.to_dict()  # raises early if not serializable


def _make_payloads(
    plan: ExecutionPlan, spec_dict: dict
) -> list[dict]:
    """Group units round-robin into at most ``max_workers`` payloads (the
    static schedule — one payload per worker)."""
    n = max(1, min(plan.max_workers, len(plan.units)))
    return _payloads_for_groups(plan, spec_dict, [plan.units[k::n] for k in range(n)])


def _make_unit_payloads(plan: ExecutionPlan, spec_dict: dict) -> list[dict]:
    """One payload per unit (the stealing schedule for the generic futures
    seam): any pool drains the queue in completion order, at the cost of a
    session rebuild per unit."""
    return _payloads_for_groups(plan, spec_dict, [[u] for u in plan.units])


def _payloads_for_groups(
    plan: ExecutionPlan, spec_dict: dict, groups: list[list[ExperimentUnit]]
) -> list[dict]:
    """One worker payload per unit group.

    The payload is the remote-executor seam: ``spec`` / ``units`` /
    ``store_path`` are plain JSON; ``dataset`` ships the parent's
    pre-generated sample arrays so N workers never redo the 20k-sample
    generation (remote workers that cannot receive arrays should use
    ``TuningSpec.dataset_cache`` on a shared path instead).
    """
    session = plan.session
    n = len(groups)
    dataset = session._get_dataset()
    dataset_payload = (
        None if dataset is None else (dataset.indices, dataset.values)
    )
    # a warm parent store is shipped (by path) to every worker: shard stores
    # start as copies, so previously-measured entries are served as hits — a
    # second parallel run performs zero re-measurements and the merged store
    # comes back bit-identical
    base_store_path = (
        session._store_path
        if session.spec.store is not None
        and session._store_path is not None
        and os.path.exists(session._store_path)
        else None
    )
    # telemetry fan-out: each worker appends to its own trace.shard<k>.jsonl
    # beside the parent trace (None when telemetry is off — workers then run
    # the exact disabled path)
    tel = session.telemetry
    return [
        {
            "spec": spec_dict,
            "units": [u.to_dict() for u in groups[k]],
            "store_path": _shard_store_path(session, k),
            "base_store_path": base_store_path,
            "dataset": dataset_payload,
            "trace_path": tel.shard_path(k),
            "trace_src": tel.shard_src(k),
        }
        for k in range(n)
    ]


def _unit_worker(payload: dict) -> list[dict]:
    """Runs one payload's units in a worker (any process, any host with the
    package importable and the store paths reachable).  Rebuilds the session
    from the serialized spec, journals each completed unit into the shard
    store, and returns JSON-able :class:`UnitResult` dicts."""
    from .api import TuningSession, TuningSpec  # lazy: avoid an import cycle
    from .dataset import SampleDataset

    spec = TuningSpec.from_dict(payload["spec"])
    telemetry = None
    if payload.get("trace_path") is not None:
        from ..telemetry.tracer import Telemetry

        telemetry = Telemetry(
            payload["trace_path"], src=payload.get("trace_src") or "shard"
        )
    session = TuningSession(
        spec, store_path=payload["store_path"], telemetry=telemetry
    )
    base_path = payload.get("base_store_path")
    if (
        base_path is not None
        and session.store is not None
        and os.path.exists(base_path)
    ):
        # seed the shard store from the parent's warm store: hits are served
        # without re-measuring (or recompiling, for the pallas backend)
        absorb_store(session.store, spec.store, base_path)
    if payload.get("dataset") is not None:
        indices, values = payload["dataset"]
        session._dataset = SampleDataset(
            space=session.space, indices=indices, values=values
        )
    journal = session.unit_journal()
    out = []
    try:
        for d in payload["units"]:
            result = session.run_unit(ExperimentUnit.from_dict(d))
            if journal is not None:
                journal.put(result)
            out.append(result.to_dict())
        session.save_store()
    finally:
        if telemetry is not None:
            # flush the shard trace (counters event + fh) even on a crash, so
            # the parent's fail-fast absorb keeps the spans written so far
            telemetry.close()
    return out


def _absorb_trace_shards(plan: ExecutionPlan, payloads: list[dict]) -> None:
    """Fold worker trace shards into the parent trace, deterministically
    (shard-index order; each shard's own event order preserved)."""
    paths = [p.get("trace_path") for p in payloads]
    plan.session.telemetry.absorb([p for p in paths if p is not None])


def _collect(plan: ExecutionPlan, payloads: list[dict],
             worker_results: list[list[dict]]) -> list[UnitResult]:
    merge_shard_stores(
        plan.session, [p["store_path"] for p in payloads]
    )
    _absorb_trace_shards(plan, payloads)
    return [
        UnitResult.from_dict(d) for results in worker_results for d in results
    ]


def _drain_futures(plan: ExecutionPlan, payloads: list[dict],
                   futures: list) -> list[list[dict]]:
    """Collect worker futures as they complete, failing fast.

    On the first worker exception: cancel every outstanding future, wait for
    the ones already running to retire (so no worker is still writing its
    shard store), absorb completed workers' shard stores — their journaled
    units survive into the parent store for ``resume=True`` — and re-raise.
    A slow healthy worker can no longer hide a failed one behind an
    in-submission-order ``f.result()`` wait.
    """
    import concurrent.futures

    tel = plan.session.telemetry
    results: list[list[dict] | None] = [None] * len(futures)
    index = {f: i for i, f in enumerate(futures)}
    done = 0
    try:
        for f in concurrent.futures.as_completed(futures):
            results[index[f]] = f.result()
            done += 1
            if tel.enabled:
                # payloads not yet retired (per-unit payloads under the
                # stealing scheduler, per-worker groups under static)
                tel.gauge("scheduler.queue_depth", len(futures) - done)
    except BaseException:
        for f in futures:
            f.cancel()
        concurrent.futures.wait(futures)
        merge_shard_stores(plan.session, [p["store_path"] for p in payloads])
        _absorb_trace_shards(plan, payloads)
        raise
    return results


# ------------------------------------------------- work-stealing machinery


def _steal_context(plan: ExecutionPlan, spec_dict: dict) -> dict:
    """The per-WORKER context for the stealing scheduler, shipped once per
    worker (pool initializer / thread init) instead of once per unit: the
    serialized spec, the warm parent store path, the dataset arrays, and the
    parent trace path (workers derive their own shard names from their
    identity, so the parent need not know worker pids up front)."""
    session = plan.session
    dataset = session._get_dataset()
    tel = session.telemetry
    base_store_path = (
        session._store_path
        if session.spec.store is not None
        and session._store_path is not None
        and os.path.exists(session._store_path)
        else None
    )
    return {
        "spec": spec_dict,
        "store_base": (
            session._store_path
            if session.spec.store is not None and session._store_path is not None
            else None
        ),
        # workers build `<store_base>.<shard_ns>.shard<ident>` — the parent
        # computes the namespace once so every worker agrees on it
        "shard_ns": (
            shard_namespace(session)
            if session.spec.store is not None and session._store_path is not None
            else None
        ),
        "base_store_path": base_store_path,
        "dataset": (
            None if dataset is None else (dataset.indices, dataset.values)
        ),
        "trace_path": getattr(tel, "path", None) if tel.enabled else None,
    }


def _build_worker_state(ctx: dict, ident: int) -> dict:
    """One persistent worker session keyed by ``ident`` (pid for process
    workers, device index for device threads): shard store
    ``<base>.<ns8>.shard<ident>``, trace shard ``trace.shard<ident>.jsonl``
    — both names the parent's glob-based recovery already understands."""
    from .api import TuningSession, TuningSpec  # lazy: avoid an import cycle
    from .dataset import SampleDataset

    spec = TuningSpec.from_dict(ctx["spec"])
    telemetry = None
    if ctx.get("trace_path"):
        from ..telemetry.events import shard_file
        from ..telemetry.tracer import Telemetry

        telemetry = Telemetry(
            shard_file(ctx["trace_path"], ident), src=f"shard{ident}"
        )
    store_path = (
        None
        if ctx.get("store_base") is None
        else f"{ctx['store_base']}.{ctx['shard_ns']}.shard{ident}"
    )
    session = TuningSession(spec, store_path=store_path, telemetry=telemetry)
    base = ctx.get("base_store_path")
    if base is not None and session.store is not None and os.path.exists(base):
        # seed the shard store from the parent's warm store: hits are served
        # without re-measuring (or recompiling, for the pallas backend)
        absorb_store(session.store, spec.store, base)
    if ctx.get("dataset") is not None:
        indices, values = ctx["dataset"]
        session._dataset = SampleDataset(
            space=session.space, indices=indices, values=values
        )
    return {
        "session": session,
        "journal": session.unit_journal(),
        "telemetry": telemetry,
        "ident": int(ident),
    }


def _close_worker_state(state: dict | None) -> None:
    """Flush a worker's shard store tail and its trace (counters + fh)."""
    if state is None:
        return
    try:
        state["session"].save_store()
    finally:
        if state["telemetry"] is not None:
            state["telemetry"].close()


def _run_state_unit(state: dict, unit_dict: dict) -> tuple[int, dict]:
    """Run one pulled unit against a persistent worker state, journaling it
    into the worker's shard store.  Returns ``(worker ident, result dict)``
    so the parent can attribute completions (steal accounting)."""
    session = state["session"]
    result = session.run_unit(ExperimentUnit.from_dict(unit_dict))
    if state["journal"] is not None:
        state["journal"].put(result)   # throttled flush — a kill loses little
    return state["ident"], result.to_dict()


def _drain_steal(plan: ExecutionPlan, futures: list, n_workers: int) -> list[dict]:
    """Collect per-unit futures as they complete, failing fast (the caller
    owns pool shutdown + shard merge on both paths).

    Steal accounting: worker identities are mapped to slots in first-seen
    completion order; a completed unit whose worker slot differs from its
    static round-robin owner (``unit_index % n_workers``) counts as one
    ``scheduler.steals`` — an approximate but cheap measure of how much the
    queue rebalanced versus the static partition.  ``scheduler.queue_depth``
    gauges units not yet retired after each completion."""
    import concurrent.futures

    tel = plan.session.telemetry
    n = len(futures)
    results: list[dict | None] = [None] * n
    index = {f: i for i, f in enumerate(futures)}
    slot_of: dict[int, int] = {}
    done = 0
    for f in concurrent.futures.as_completed(futures):
        ident, rd = f.result()        # re-raises the worker's exception
        i = index[f]
        results[i] = rd
        done += 1
        if tel.enabled:
            slot = slot_of.setdefault(ident, len(slot_of))
            tel.gauge("scheduler.queue_depth", n - done)
            if slot != i % n_workers:
                tel.inc("scheduler.steals")
    return results


# ------------------------------------------------------------------- process

#: per-process worker state for the stealing scheduler (set by the pool
#: initializer in each spawned worker; module-global because pool tasks
#: can only receive picklable arguments)
_STEAL_STATE: dict = {}


def _steal_init(ctx: dict) -> None:
    """Pool initializer (runs once per spawned worker process): build the
    persistent session keyed by pid and register its flush at process exit
    — ``ProcessPoolExecutor.shutdown(wait=True)`` joins workers, so the
    parent merges only after every shard store is saved."""
    import atexit

    state = _build_worker_state(ctx, ident=os.getpid())
    _STEAL_STATE["state"] = state
    atexit.register(_close_worker_state, state)


def _steal_unit_task(unit_dict: dict) -> tuple[int, dict]:
    return _run_state_unit(_STEAL_STATE["state"], unit_dict)


def _merge_steal_shards(session) -> None:
    """Fold worker shard stores and trace shards into the parent.  Worker
    identities (pids / device indices) are not known to the parent up
    front, so this is the same glob the kill-recovery path uses."""
    recover_shard_stores(session)


def _run_process_static(plan: ExecutionPlan) -> list[UnitResult]:
    """The static schedule: one round-robin payload per worker, submitted to
    a spawn pool and drained ``as_completed`` — same fail-fast semantics as
    every other parallel path (the first worker exception absorbs completed
    workers' shard stores and traces before re-raising)."""
    import concurrent.futures
    import multiprocessing

    spec_dict = _check_shippable(plan.session)
    payloads = _make_payloads(plan, spec_dict)
    pool = concurrent.futures.ProcessPoolExecutor(
        max_workers=len(payloads),
        mp_context=multiprocessing.get_context("spawn"),
    )
    try:
        futures = [pool.submit(_unit_worker, p) for p in payloads]
        worker_results = _drain_futures(plan, payloads, futures)
    finally:
        pool.shutdown()
    return _collect(plan, payloads, worker_results)


def _run_process(plan: ExecutionPlan) -> list[UnitResult]:
    """Spawn-process fan-out.  Stealing (default): persistent per-process
    sessions pull units from the shared pool queue; static: the legacy
    one-payload-per-worker partition."""
    if plan.scheduler == "static":
        return _run_process_static(plan)
    import concurrent.futures
    import multiprocessing

    spec_dict = _check_shippable(plan.session)
    ctx = _steal_context(plan, spec_dict)
    n = max(1, min(plan.max_workers, len(plan.units)))
    pool = concurrent.futures.ProcessPoolExecutor(
        max_workers=n,
        mp_context=multiprocessing.get_context("spawn"),
        initializer=_steal_init,
        initargs=(ctx,),
    )
    try:
        futures = [
            pool.submit(_steal_unit_task, u.to_dict()) for u in plan.units
        ]
        try:
            dicts = _drain_steal(plan, futures, n)
        except BaseException:
            for f in futures:
                f.cancel()
            # join workers first (their exit handlers flush shard stores),
            # THEN absorb what they completed — fail-fast parity with
            # _drain_futures: journaled units survive into the parent
            pool.shutdown(wait=True)
            _merge_steal_shards(plan.session)
            raise
    finally:
        pool.shutdown(wait=True)
    _merge_steal_shards(plan.session)
    return [UnitResult.from_dict(d) for d in dicts]


register_executor(Executor(name="process", run=_run_process, parallel=True))


# ------------------------------------------------------------------- futures


def _run_futures(plan: ExecutionPlan) -> list[UnitResult]:
    """The generic ``concurrent.futures`` seam.  Under the stealing
    scheduler each payload carries exactly one unit, so ANY pool — thread,
    process, or remote adapter — drains the queue in completion order; under
    ``static`` the legacy one-payload-per-worker grouping is submitted."""
    spec_dict = _check_shippable(plan.session)
    if plan.scheduler == "static":
        payloads = _make_payloads(plan, spec_dict)
    else:
        payloads = _make_unit_payloads(plan, spec_dict)
    pool = plan.futures_pool
    owned = pool is None
    if owned:
        import concurrent.futures
        import multiprocessing

        pool = concurrent.futures.ProcessPoolExecutor(
            max_workers=max(1, min(plan.max_workers, len(payloads))),
            mp_context=multiprocessing.get_context("spawn"),
        )
    try:
        futures = [pool.submit(_unit_worker, p) for p in payloads]
        worker_results = _drain_futures(plan, payloads, futures)
    finally:
        if owned:
            pool.shutdown()
    return _collect(plan, payloads, worker_results)


register_executor(Executor(name="futures", run=_run_futures, parallel=True))


# -------------------------------------------------------------------- device


def _device_worker(payload: dict, device) -> list[dict]:
    """One shard's units pinned to one jax device.  ``jax.default_device``
    is thread-local, so concurrent shard threads each keep their own pin."""
    import jax

    with jax.default_device(device):
        return _unit_worker(payload)


def _run_device(plan: ExecutionPlan) -> list[UnitResult]:
    """Fan units across ``jax.devices()`` within this process.

    Same payloads and shard-store plumbing as the process executor, but the
    workers are threads pinned to devices instead of spawned interpreters —
    the right shape for a multi-chip host where process spawn (and per-worker
    jax re-initialization) costs more than the matrix.  On a host faking
    devices via ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` this
    exercises the exact fan-out path with CPU "chips".
    """
    import concurrent.futures
    import warnings

    import jax

    spec_dict = _check_shippable(plan.session)
    devices = jax.devices()
    if plan.max_workers > len(devices):
        warnings.warn(
            f"device executor: {plan.max_workers} workers requested but only "
            f"{len(devices)} jax device(s) present; capping"
        )
        plan = ExecutionPlan(
            session=plan.session,
            units=plan.units,
            max_workers=len(devices),
            futures_pool=plan.futures_pool,
            scheduler=plan.scheduler,
        )
    if plan.scheduler == "static":
        payloads = _make_payloads(plan, spec_dict)
        with concurrent.futures.ThreadPoolExecutor(
            max_workers=len(payloads), thread_name_prefix="device-shard"
        ) as pool:
            futures = [
                pool.submit(_device_worker, p, devices[k])
                for k, p in enumerate(payloads)
            ]
            worker_results = _drain_futures(plan, payloads, futures)
        return _collect(plan, payloads, worker_results)
    return _run_device_steal(plan, spec_dict, devices)


def _run_device_steal(
    plan: ExecutionPlan, spec_dict: dict, devices: list
) -> list[UnitResult]:
    """Stealing schedule over device-pinned worker threads.  Each thread
    builds ONE persistent session at thread start (compilation caches stay
    warm across units) and pulls units from the pool queue as it frees up;
    the worker identity is the device index, so shard stores and trace
    shards use the same ``shard<k>`` names as the static path."""
    import concurrent.futures
    import threading

    import jax

    ctx = _steal_context(plan, spec_dict)
    n = max(1, min(plan.max_workers, len(plan.units)))
    states: list[dict | None] = []
    states_lock = threading.Lock()
    tls = threading.local()

    def _thread_init() -> None:
        with states_lock:
            k = len(states)
            states.append(None)
        state = _build_worker_state(ctx, ident=k)
        state["device"] = devices[k]
        states[k] = state
        tls.state = state

    def _thread_task(unit_dict: dict) -> tuple[int, dict]:
        state = tls.state
        with jax.default_device(state["device"]):
            return _run_state_unit(state, unit_dict)

    pool = concurrent.futures.ThreadPoolExecutor(
        max_workers=n,
        thread_name_prefix="device-steal",
        initializer=_thread_init,
    )
    try:
        futures = [
            pool.submit(_thread_task, u.to_dict()) for u in plan.units
        ]
        try:
            dicts = _drain_steal(plan, futures, n)
        except BaseException:
            for f in futures:
                f.cancel()
            pool.shutdown(wait=True)
            for s in states:
                _close_worker_state(s)
            _merge_steal_shards(plan.session)
            raise
    finally:
        pool.shutdown(wait=True)
    for s in states:
        _close_worker_state(s)
    _merge_steal_shards(plan.session)
    return [UnitResult.from_dict(d) for d in dicts]


register_executor(Executor(name="device", run=_run_device, parallel=True))
