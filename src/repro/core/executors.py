"""Executor registry: pluggable strategies for running experiment units.

Mirrors ``SEARCHERS`` / ``BACKENDS`` / ``STORES``: an executor is resolved by
name and runs a list of :class:`~repro.core.workunits.ExperimentUnit`\\ s for
a session, returning :class:`~repro.core.workunits.UnitResult` fragments the
session merges deterministically by unit key.  Built-ins:

* ``"serial"``  — the in-process loop; journals each completed unit.
* ``"process"`` — ``multiprocessing`` (spawn) fan-out: units are grouped
  round-robin across ``max_workers`` workers, each worker rebuilds the
  session from the serialized spec, writes to its own ``store_path.shard<k>``
  (seeded from the warm parent store), journals into it, and the parent
  merges shard stores when the pool joins.
* ``"futures"`` — the same worker payload submitted to ANY
  ``concurrent.futures.Executor``.  Pass a live pool via
  ``run_matrix(futures_pool=...)`` (a ``ThreadPoolExecutor``, a cluster
  client's pool adapter, ...); without one a spawn-context
  ``ProcessPoolExecutor`` is created for the call.  This is the
  remote-executor seam: the payload is ``(spec_dict, unit dicts,
  store paths)`` and the results come back as plain JSON-able dicts, so an
  executor whose workers live on other hosts only needs to ship the payload
  and a store path visible to the worker.
* ``"device"``  — multi-chip fan-out WITHIN one process: the same payloads
  run on worker threads, each pinned to one of ``jax.devices()`` via
  ``jax.default_device``, with one shard store per device.  An 8-chip host
  runs the matrix ~8x wider with no process spawn, no re-import, and a
  shared in-memory compilation story per worker; merges are bit-identical
  to ``serial`` because workers rebuild sessions from the same serialized
  spec and seeds derive from the spec alone.

Parallel executors collect worker results as they complete and fail fast:
the first worker exception cancels outstanding work, absorbs completed
workers' shard stores (their journaled units survive into the parent), and
re-raises.

Worker crash/kill recovery: because workers journal completed units into
their shard stores as they go, :func:`recover_shard_stores` can absorb
leftover ``*.shard<k>`` files from a killed run into the parent store before
a resumed run partitions its units — nothing a dead worker finished is lost.
"""

from __future__ import annotations

import os
import re
from dataclasses import dataclass, field
from typing import Any, Callable

from .stores import make_store
from .workunits import ExperimentUnit, UnitResult

__all__ = [
    "EXECUTORS",
    "ExecutionPlan",
    "Executor",
    "recover_shard_stores",
    "register_executor",
    "run_units",
]


@dataclass
class ExecutionPlan:
    """Everything an executor needs for one fan-out."""

    session: Any                      # TuningSession (duck-typed; no import cycle)
    units: list[ExperimentUnit] = field(default_factory=list)
    max_workers: int = 1
    futures_pool: Any = None          # concurrent.futures.Executor, "futures" only


@dataclass(frozen=True)
class Executor:
    """A named unit-execution strategy.

    ``parallel`` marks executors that ship work out of the calling process:
    they require a fully serializable spec (no in-process overrides, a
    name-resolvable backend) and degrade to ``serial`` — with a warning —
    when the plan cannot keep more than one worker busy.
    """

    name: str
    run: Callable[[ExecutionPlan], list[UnitResult]]
    parallel: bool = True


EXECUTORS: dict[str, Executor] = {}


def register_executor(executor: Executor) -> Executor:
    EXECUTORS[executor.name] = executor
    return executor


def run_units(name: str, plan: ExecutionPlan) -> list[UnitResult]:
    """Run ``plan`` through the named executor."""
    if name not in EXECUTORS:
        raise KeyError(f"unknown executor {name!r}; have {sorted(EXECUTORS)}")
    return EXECUTORS[name].run(plan)


# -------------------------------------------------------------------- serial


def _run_serial(plan: ExecutionPlan) -> list[UnitResult]:
    session = plan.session
    journal = session.unit_journal()
    out = []
    for unit in plan.units:
        result = session.run_unit(unit)
        if journal is not None:
            journal.put(result)   # flushed (throttled) — a kill loses little
        out.append(result)
    return out


register_executor(Executor(name="serial", run=_run_serial, parallel=False))


# ----------------------------------------------------- shard-store plumbing


def _shard_store_path(session, shard: int) -> str | None:
    if session.spec.store is None or session._store_path is None:
        return None
    return f"{session._store_path}.shard{shard}"


def absorb_store(dst, kind: str, path: str) -> None:
    """Copy one store file's values AND metadata (which carries the unit
    journal) into ``dst``."""
    src = make_store(kind, path)
    dst.update(src.items())
    if hasattr(src, "meta_items"):
        dst.update_meta(src.meta_items())
    if hasattr(src, "close"):
        src.close()


def merge_shard_stores(session, paths: list[str]) -> None:
    """Fold worker shard stores into the session's main store, then delete
    the shard files."""
    if session.store is None:
        return
    for path in paths:
        if path is None or not os.path.exists(path):
            continue
        absorb_store(session.store, session.spec.store, path)
        os.remove(path)
    session.store.save()


def recover_shard_stores(session) -> int:
    """Absorb shard stores left behind by a killed parallel run.

    Workers journal completed units into their shard stores incrementally,
    so even though the dead parent never merged them, their measurements and
    journal entries are intact on disk.  Returns how many files were
    recovered.
    """
    base = session._store_path
    if session.store is None or base is None:
        return 0
    pattern = re.compile(re.escape(os.path.basename(base)) + r"\.shard\d+$")
    d = os.path.dirname(base) or "."
    if not os.path.isdir(d):
        return 0
    leftovers = sorted(
        os.path.join(d, f) for f in os.listdir(d) if pattern.fullmatch(f)
    )
    merge_shard_stores(session, leftovers)
    # a killed run's workers also leave trace.shard<k>.jsonl files beside the
    # parent trace; fold them in so the resumed trace keeps their spans
    session.telemetry.recover()
    return len(leftovers)


# ----------------------------------------------------------- worker payloads


def _check_shippable(session) -> dict:
    """Validate that the session can be rebuilt in a worker; return the
    serialized spec.  Raises the same errors for every parallel executor."""
    if session._has_overrides:
        raise RuntimeError(
            "parallel matrix runs rebuild the session from the serialized "
            "spec in worker processes; in-process overrides (space/"
            "measurement_factory/dataset/store objects) cannot be shipped"
        )
    if not session._backend.serializable:
        raise RuntimeError(
            f"backend {session.spec.backend!r} holds in-process callables and "
            "cannot be rebuilt in shard workers; use a name-resolvable "
            "backend (e.g. 'costmodel') for parallel runs"
        )
    return session.spec.to_dict()  # raises early if not serializable


def _make_payloads(
    plan: ExecutionPlan, spec_dict: dict
) -> list[dict]:
    """Group units round-robin into at most ``max_workers`` payloads.

    The payload is the remote-executor seam: ``spec`` / ``units`` /
    ``store_path`` are plain JSON; ``dataset`` ships the parent's
    pre-generated sample arrays so N workers never redo the 20k-sample
    generation (remote workers that cannot receive arrays should use
    ``TuningSpec.dataset_cache`` on a shared path instead).
    """
    session = plan.session
    n = max(1, min(plan.max_workers, len(plan.units)))
    groups = [plan.units[k::n] for k in range(n)]
    dataset = session._get_dataset()
    dataset_payload = (
        None if dataset is None else (dataset.indices, dataset.values)
    )
    # a warm parent store is shipped (by path) to every worker: shard stores
    # start as copies, so previously-measured entries are served as hits — a
    # second parallel run performs zero re-measurements and the merged store
    # comes back bit-identical
    base_store_path = (
        session._store_path
        if session.spec.store is not None
        and session._store_path is not None
        and os.path.exists(session._store_path)
        else None
    )
    # telemetry fan-out: each worker appends to its own trace.shard<k>.jsonl
    # beside the parent trace (None when telemetry is off — workers then run
    # the exact disabled path)
    tel = session.telemetry
    return [
        {
            "spec": spec_dict,
            "units": [u.to_dict() for u in groups[k]],
            "store_path": _shard_store_path(session, k),
            "base_store_path": base_store_path,
            "dataset": dataset_payload,
            "trace_path": tel.shard_path(k),
            "trace_src": tel.shard_src(k),
        }
        for k in range(n)
    ]


def _unit_worker(payload: dict) -> list[dict]:
    """Runs one payload's units in a worker (any process, any host with the
    package importable and the store paths reachable).  Rebuilds the session
    from the serialized spec, journals each completed unit into the shard
    store, and returns JSON-able :class:`UnitResult` dicts."""
    from .api import TuningSession, TuningSpec  # lazy: avoid an import cycle
    from .dataset import SampleDataset

    spec = TuningSpec.from_dict(payload["spec"])
    telemetry = None
    if payload.get("trace_path") is not None:
        from ..telemetry.tracer import Telemetry

        telemetry = Telemetry(
            payload["trace_path"], src=payload.get("trace_src") or "shard"
        )
    session = TuningSession(
        spec, store_path=payload["store_path"], telemetry=telemetry
    )
    base_path = payload.get("base_store_path")
    if (
        base_path is not None
        and session.store is not None
        and os.path.exists(base_path)
    ):
        # seed the shard store from the parent's warm store: hits are served
        # without re-measuring (or recompiling, for the pallas backend)
        absorb_store(session.store, spec.store, base_path)
    if payload.get("dataset") is not None:
        indices, values = payload["dataset"]
        session._dataset = SampleDataset(
            space=session.space, indices=indices, values=values
        )
    journal = session.unit_journal()
    out = []
    try:
        for d in payload["units"]:
            result = session.run_unit(ExperimentUnit.from_dict(d))
            if journal is not None:
                journal.put(result)
            out.append(result.to_dict())
        session.save_store()
    finally:
        if telemetry is not None:
            # flush the shard trace (counters event + fh) even on a crash, so
            # the parent's fail-fast absorb keeps the spans written so far
            telemetry.close()
    return out


def _absorb_trace_shards(plan: ExecutionPlan, payloads: list[dict]) -> None:
    """Fold worker trace shards into the parent trace, deterministically
    (shard-index order; each shard's own event order preserved)."""
    paths = [p.get("trace_path") for p in payloads]
    plan.session.telemetry.absorb([p for p in paths if p is not None])


def _collect(plan: ExecutionPlan, payloads: list[dict],
             worker_results: list[list[dict]]) -> list[UnitResult]:
    merge_shard_stores(
        plan.session, [p["store_path"] for p in payloads]
    )
    _absorb_trace_shards(plan, payloads)
    return [
        UnitResult.from_dict(d) for results in worker_results for d in results
    ]


def _drain_futures(plan: ExecutionPlan, payloads: list[dict],
                   futures: list) -> list[list[dict]]:
    """Collect worker futures as they complete, failing fast.

    On the first worker exception: cancel every outstanding future, wait for
    the ones already running to retire (so no worker is still writing its
    shard store), absorb completed workers' shard stores — their journaled
    units survive into the parent store for ``resume=True`` — and re-raise.
    A slow healthy worker can no longer hide a failed one behind an
    in-submission-order ``f.result()`` wait.
    """
    import concurrent.futures

    results: list[list[dict] | None] = [None] * len(futures)
    index = {f: i for i, f in enumerate(futures)}
    try:
        for f in concurrent.futures.as_completed(futures):
            results[index[f]] = f.result()
    except BaseException:
        for f in futures:
            f.cancel()
        concurrent.futures.wait(futures)
        merge_shard_stores(plan.session, [p["store_path"] for p in payloads])
        _absorb_trace_shards(plan, payloads)
        raise
    return results


# ------------------------------------------------------------------- process


def _run_process(plan: ExecutionPlan) -> list[UnitResult]:
    import multiprocessing

    spec_dict = _check_shippable(plan.session)
    payloads = _make_payloads(plan, spec_dict)
    ctx = multiprocessing.get_context("spawn")
    with ctx.Pool(processes=len(payloads)) as pool:
        worker_results = pool.map(_unit_worker, payloads)
    return _collect(plan, payloads, worker_results)


register_executor(Executor(name="process", run=_run_process, parallel=True))


# ------------------------------------------------------------------- futures


def _run_futures(plan: ExecutionPlan) -> list[UnitResult]:
    spec_dict = _check_shippable(plan.session)
    payloads = _make_payloads(plan, spec_dict)
    pool = plan.futures_pool
    owned = pool is None
    if owned:
        import concurrent.futures
        import multiprocessing

        pool = concurrent.futures.ProcessPoolExecutor(
            max_workers=len(payloads),
            mp_context=multiprocessing.get_context("spawn"),
        )
    try:
        futures = [pool.submit(_unit_worker, p) for p in payloads]
        worker_results = _drain_futures(plan, payloads, futures)
    finally:
        if owned:
            pool.shutdown()
    return _collect(plan, payloads, worker_results)


register_executor(Executor(name="futures", run=_run_futures, parallel=True))


# -------------------------------------------------------------------- device


def _device_worker(payload: dict, device) -> list[dict]:
    """One shard's units pinned to one jax device.  ``jax.default_device``
    is thread-local, so concurrent shard threads each keep their own pin."""
    import jax

    with jax.default_device(device):
        return _unit_worker(payload)


def _run_device(plan: ExecutionPlan) -> list[UnitResult]:
    """Fan units across ``jax.devices()`` within this process.

    Same payloads and shard-store plumbing as the process executor, but the
    workers are threads pinned to devices instead of spawned interpreters —
    the right shape for a multi-chip host where process spawn (and per-worker
    jax re-initialization) costs more than the matrix.  On a host faking
    devices via ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` this
    exercises the exact fan-out path with CPU "chips".
    """
    import concurrent.futures
    import warnings

    import jax

    spec_dict = _check_shippable(plan.session)
    devices = jax.devices()
    if plan.max_workers > len(devices):
        warnings.warn(
            f"device executor: {plan.max_workers} workers requested but only "
            f"{len(devices)} jax device(s) present; capping"
        )
        plan = ExecutionPlan(
            session=plan.session,
            units=plan.units,
            max_workers=len(devices),
        )
    payloads = _make_payloads(plan, spec_dict)
    with concurrent.futures.ThreadPoolExecutor(
        max_workers=len(payloads), thread_name_prefix="device-shard"
    ) as pool:
        futures = [
            pool.submit(_device_worker, p, devices[k])
            for k, p in enumerate(payloads)
        ]
        worker_results = _drain_futures(plan, payloads, futures)
    return _collect(plan, payloads, worker_results)


register_executor(Executor(name="device", run=_run_device, parallel=True))
