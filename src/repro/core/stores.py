"""Measurement store backends: persistent ``str -> float`` mappings.

The JSON :class:`~repro.core.engine.MeasurementStore` (the default) rewrites
its whole file per flush — fine at the scaled designs' ~10^5 entries, but the
paper-exact ~3M-sample design needs incremental writes.  The sqlite backend
here keeps the same duck-typed interface (``get`` / ``put`` / ``save`` /
``items`` / ``update`` / ``__len__``) over a single-table database with
batched commits, so :class:`~repro.core.engine.DiskCachedMeasurement`, the
executor layer's shard-store merge, and the work-unit journal (which lives
in the per-key metadata side-channel) work unchanged against either.

Select a backend by name through :func:`make_store` (``TuningSpec.store``
routes here): ``make_store("sqlite", path)`` / ``make_store("json", path)``.
"""

from __future__ import annotations

import os
import sqlite3
from typing import Iterable, Iterator

from .engine import MeasurementStore


class SqliteMeasurementStore:
    """Sqlite-backed measurement store (same interface as the JSON store).

    Writes accumulate in the sqlite connection and are committed every
    ``autosave_every`` puts (0 disables autocommit batching; call
    :meth:`save`).  ``path=None`` gives an in-memory database — useful for
    tests and for shard workers that return their entries to the parent.
    Unlike the JSON store, entries hit the file incrementally: a 3M-entry
    run never rewrites the full history per flush.
    """

    def __init__(self, path: str | None, autosave_every: int = 4096):
        self.path = path
        self.autosave_every = autosave_every
        self._dirty = 0
        if path is not None:
            d = os.path.dirname(path)
            if d:
                os.makedirs(d, exist_ok=True)
        self._conn = sqlite3.connect(path if path is not None else ":memory:")
        self._conn.execute(
            "CREATE TABLE IF NOT EXISTS measurements "
            "(key TEXT PRIMARY KEY, value REAL NOT NULL)"
        )
        # per-key string metadata (penalty reasons from the real-measurement
        # backend); mirrors MeasurementStore's meta side-channel
        self._conn.execute(
            "CREATE TABLE IF NOT EXISTS meta "
            "(key TEXT PRIMARY KEY, note TEXT NOT NULL)"
        )
        self._conn.commit()

    def __len__(self) -> int:
        (n,) = self._conn.execute("SELECT COUNT(*) FROM measurements").fetchone()
        return int(n)

    def get(self, key: str) -> float | None:
        row = self._conn.execute(
            "SELECT value FROM measurements WHERE key = ?", (key,)
        ).fetchone()
        return None if row is None else float(row[0])

    def put(self, key: str, value: float) -> None:
        self._conn.execute(
            "INSERT OR REPLACE INTO measurements (key, value) VALUES (?, ?)",
            (key, float(value)),
        )
        self._dirty += 1
        if self.autosave_every and self._dirty >= self.autosave_every:
            self.save()

    def save(self) -> None:
        self._conn.commit()
        self._dirty = 0

    def items(self) -> Iterator[tuple[str, float]]:
        for key, value in self._conn.execute(
            "SELECT key, value FROM measurements"
        ):
            yield key, float(value)

    def update(self, entries: Iterable[tuple[str, float]]) -> None:
        self._conn.executemany(
            "INSERT OR REPLACE INTO measurements (key, value) VALUES (?, ?)",
            ((k, float(v)) for k, v in entries),
        )
        self.save()

    # -- per-key metadata (penalty reasons) ------------------------------------
    def get_meta(self, key: str) -> str | None:
        row = self._conn.execute(
            "SELECT note FROM meta WHERE key = ?", (key,)
        ).fetchone()
        return None if row is None else str(row[0])

    def put_meta(self, key: str, note: str) -> None:
        self._conn.execute(
            "INSERT OR REPLACE INTO meta (key, note) VALUES (?, ?)",
            (key, str(note)),
        )
        self._dirty += 1
        if self.autosave_every and self._dirty >= self.autosave_every:
            self.save()

    def meta_items(self, prefix: str | None = None) -> Iterator[tuple[str, str]]:
        if prefix is None:
            rows = self._conn.execute("SELECT key, note FROM meta")
        else:
            like = prefix.replace("\\", "\\\\").replace("%", "\\%").replace("_", "\\_")
            rows = self._conn.execute(
                "SELECT key, note FROM meta WHERE key LIKE ? ESCAPE '\\'",
                (like + "%",),
            )
        for key, note in rows:
            yield key, str(note)

    def update_meta(self, entries: Iterable[tuple[str, str]]) -> None:
        self._conn.executemany(
            "INSERT OR REPLACE INTO meta (key, note) VALUES (?, ?)",
            ((k, str(v)) for k, v in entries),
        )
        self.save()

    def close(self) -> None:
        self._conn.commit()
        self._conn.close()


#: store-kind registry, mirroring SEARCHERS / BACKENDS.
STORES: dict[str, type] = {
    "json": MeasurementStore,
    "sqlite": SqliteMeasurementStore,
}


def make_store(kind: str, path: str | None = None, **kwargs):
    """Resolve a measurement-store backend by name."""
    if kind not in STORES:
        raise KeyError(f"unknown store kind {kind!r}; have {sorted(STORES)}")
    return STORES[kind](path, **kwargs)
