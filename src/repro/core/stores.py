"""Measurement store backends: persistent ``str -> float`` mappings.

The JSON :class:`~repro.core.engine.MeasurementStore` (the default) rewrites
its whole file per flush — fine at the scaled designs' ~10^5 entries, but the
paper-exact ~3M-sample design needs incremental writes.  The sqlite backend
here keeps the same duck-typed interface (``get`` / ``put`` / ``save`` /
``items`` / ``update`` / ``__len__``) over a single-table database with
batched commits, so :class:`~repro.core.engine.DiskCachedMeasurement`, the
executor layer's shard-store merge, and the work-unit journal (which lives
in the per-key metadata side-channel) work unchanged against either.

Select a backend by name through :func:`make_store` (``TuningSpec.store``
routes here): ``make_store("sqlite", path)`` / ``make_store("json", path)``.
"""

from __future__ import annotations

import json
import math
import os
import sqlite3
from typing import Iterable, Iterator

from .engine import MeasurementStore


class SqliteMeasurementStore:
    """Sqlite-backed measurement store (same interface as the JSON store).

    Writes accumulate in the sqlite connection and are committed every
    ``autosave_every`` puts (0 disables autocommit batching; call
    :meth:`save`).  ``path=None`` gives an in-memory database — useful for
    tests and for shard workers that return their entries to the parent.
    Unlike the JSON store, entries hit the file incrementally: a 3M-entry
    run never rewrites the full history per flush.

    File-backed databases run in WAL journal mode with a busy timeout
    (``busy_timeout_ms``): the serving layer opens the same file from many
    reader processes while a tuning session appends, and WAL gives readers a
    consistent snapshot without blocking the writer.
    """

    def __init__(self, path: str | None, autosave_every: int = 4096,
                 busy_timeout_ms: int = 5000):
        self.path = path
        self.autosave_every = autosave_every
        self.busy_timeout_ms = busy_timeout_ms
        self._dirty = 0
        if path is not None:
            d = os.path.dirname(path)
            if d:
                os.makedirs(d, exist_ok=True)
        # check_same_thread=False: the serving HTTP endpoint answers from
        # handler threads behind one lock (ServingState.lock); sqlite itself
        # is compiled serialized, so cross-thread use under external
        # serialization is safe
        self._conn = sqlite3.connect(
            path if path is not None else ":memory:", check_same_thread=False
        )
        if path is not None:
            # WAL is persistent: every later opener of the same file inherits
            # it even if they skip the pragma
            self._conn.execute("PRAGMA journal_mode=WAL")
            self._conn.execute(f"PRAGMA busy_timeout={int(busy_timeout_ms)}")
        self._conn.execute(
            "CREATE TABLE IF NOT EXISTS measurements "
            "(key TEXT PRIMARY KEY, value REAL NOT NULL)"
        )
        # per-key string metadata (penalty reasons from the real-measurement
        # backend); mirrors MeasurementStore's meta side-channel
        self._conn.execute(
            "CREATE TABLE IF NOT EXISTS meta "
            "(key TEXT PRIMARY KEY, note TEXT NOT NULL)"
        )
        # serving winners (repro.serving best-config index); mirrors
        # MeasurementStore's winners side-channel
        self._conn.execute(
            "CREATE TABLE IF NOT EXISTS winners "
            "(key TEXT PRIMARY KEY, payload TEXT NOT NULL)"
        )
        self._conn.commit()

    def __len__(self) -> int:
        (n,) = self._conn.execute("SELECT COUNT(*) FROM measurements").fetchone()
        return int(n)

    def get(self, key: str) -> float | None:
        row = self._conn.execute(
            "SELECT value FROM measurements WHERE key = ?", (key,)
        ).fetchone()
        return None if row is None else float(row[0])

    def put(self, key: str, value: float) -> None:
        self._conn.execute(
            "INSERT OR REPLACE INTO measurements (key, value) VALUES (?, ?)",
            (key, float(value)),
        )
        self._dirty += 1
        if self.autosave_every and self._dirty >= self.autosave_every:
            self.save()

    def save(self) -> None:
        self._conn.commit()
        self._dirty = 0

    def items(self) -> Iterator[tuple[str, float]]:
        for key, value in self._conn.execute(
            "SELECT key, value FROM measurements"
        ):
            yield key, float(value)

    def update(self, entries: Iterable[tuple[str, float]]) -> None:
        self._conn.executemany(
            "INSERT OR REPLACE INTO measurements (key, value) VALUES (?, ?)",
            ((k, float(v)) for k, v in entries),
        )
        self.save()

    def best_item(self, prefix: str, contains: str | None = None
                  ) -> tuple[str, float] | None:
        """The minimum-value finite entry under ``prefix`` (ties break on
        key), resolved inside sqlite — the serving winner refresh never
        pages a 3M-row store through Python.  ``contains`` restricts to keys
        holding that substring (e.g. ``"|final"``)."""

        def esc(s: str) -> str:
            return s.replace("\\", "\\\\").replace("%", "\\%").replace("_", "\\_")

        sql = ("SELECT key, value FROM measurements "
               "WHERE key LIKE ? ESCAPE '\\' AND value <= ? AND value >= ? ")
        params: list = [esc(prefix) + "%",
                        1.7976931348623157e308, -1.7976931348623157e308]
        if contains is not None:
            sql += "AND key LIKE ? ESCAPE '\\' "
            params.append("%" + esc(contains) + "%")
        row = self._conn.execute(
            sql + "ORDER BY value ASC, key ASC LIMIT 1", params
        ).fetchone()
        return None if row is None else (str(row[0]), float(row[1]))

    # -- per-key metadata (penalty reasons) ------------------------------------
    def get_meta(self, key: str) -> str | None:
        row = self._conn.execute(
            "SELECT note FROM meta WHERE key = ?", (key,)
        ).fetchone()
        return None if row is None else str(row[0])

    def put_meta(self, key: str, note: str) -> None:
        self._conn.execute(
            "INSERT OR REPLACE INTO meta (key, note) VALUES (?, ?)",
            (key, str(note)),
        )
        self._dirty += 1
        if self.autosave_every and self._dirty >= self.autosave_every:
            self.save()

    def meta_items(self, prefix: str | None = None) -> Iterator[tuple[str, str]]:
        if prefix is None:
            rows = self._conn.execute("SELECT key, note FROM meta")
        else:
            like = prefix.replace("\\", "\\\\").replace("%", "\\%").replace("_", "\\_")
            rows = self._conn.execute(
                "SELECT key, note FROM meta WHERE key LIKE ? ESCAPE '\\'",
                (like + "%",),
            )
        for key, note in rows:
            yield key, str(note)

    def update_meta(self, entries: Iterable[tuple[str, str]]) -> None:
        self._conn.executemany(
            "INSERT OR REPLACE INTO meta (key, note) VALUES (?, ?)",
            ((k, str(v)) for k, v in entries),
        )
        self.save()

    # -- serving winners (repro.serving best-config index) ---------------------
    def get_winner(self, key: str) -> str | None:
        row = self._conn.execute(
            "SELECT payload FROM winners WHERE key = ?", (key,)
        ).fetchone()
        return None if row is None else str(row[0])

    def put_winner(self, key: str, payload: str) -> None:
        self._conn.execute(
            "INSERT OR REPLACE INTO winners (key, payload) VALUES (?, ?)",
            (key, str(payload)),
        )
        self._dirty += 1
        if self.autosave_every and self._dirty >= self.autosave_every:
            self.save()

    def winner_items(self) -> Iterator[tuple[str, str]]:
        for key, payload in self._conn.execute("SELECT key, payload FROM winners"):
            yield key, str(payload)

    def update_winners(self, entries: Iterable[tuple[str, str]]) -> None:
        self._conn.executemany(
            "INSERT OR REPLACE INTO winners (key, payload) VALUES (?, ?)",
            ((k, str(v)) for k, v in entries),
        )
        self.save()

    def close(self) -> None:
        self._conn.commit()
        self._conn.close()


def merge_winner_payloads(old: str | None, new: str) -> str:
    """Resolve two winner records for the same key: the lower measured value
    wins (ties keep the newer record), and the freshness stamp never moves
    backwards — merging a stale shard into a store that already saw a newer
    update must not make the entry look older than it is.  Unparseable
    payloads lose to parseable ones (last-writer-wins between two)."""
    if old is None:
        return str(new)

    def _load(payload: str) -> dict | None:
        try:
            d = json.loads(payload)
        except ValueError:
            return None
        return d if isinstance(d, dict) else None

    a, b = _load(old), _load(new)
    if b is None:
        return str(old) if a is not None else str(new)
    if a is None:
        return str(new)

    def _value(d: dict) -> float:
        try:
            return float(d.get("value", math.inf))
        except (TypeError, ValueError):
            return math.inf

    def _fresh(d: dict) -> float:
        try:
            return float(d.get("fresh", 0.0))
        except (TypeError, ValueError):
            return 0.0

    if _value(b) != _value(a):
        keep = dict(b if _value(b) < _value(a) else a)
    else:  # value tie: the fresher record answers — merge-order independent
        keep = dict(b if _fresh(b) >= _fresh(a) else a)
    keep["fresh"] = max(_fresh(a), _fresh(b))
    return json.dumps(keep, sort_keys=True)


def absorb_winners(dst, src) -> None:
    """Fold ``src``'s winner records into ``dst`` under the merge policy."""
    if not (hasattr(src, "winner_items") and hasattr(dst, "put_winner")):
        return
    for key, payload in src.winner_items():
        dst.put_winner(key, merge_winner_payloads(dst.get_winner(key), payload))


#: store-kind registry, mirroring SEARCHERS / BACKENDS.
STORES: dict[str, type] = {
    "json": MeasurementStore,
    "sqlite": SqliteMeasurementStore,
}


def make_store(kind: str, path: str | None = None, **kwargs):
    """Resolve a measurement-store backend by name."""
    if kind not in STORES:
        raise KeyError(f"unknown store kind {kind!r}; have {sorted(STORES)}")
    return STORES[kind](path, **kwargs)
