"""The one wall-clock seam for determinism-critical code.

Results must never depend on when they were computed, so library code in the
determinism-critical modules (searchers, surrogates, engine, workunits,
stores, the session driver) is forbidden from calling ``time.time()`` /
``time.perf_counter()`` directly — `repro.staticcheck` rule DET001 enforces
this at lint time.  Wall-clock readings that are *legitimate* (run-record
provenance, per-unit cost accounting, stage clocks) all route through this
module instead: one injectable monotonic timer, one audited allowlist entry.

``set_timer`` swaps the clock for tests (fake time, zero time, recorded
ticks) and restores the default on ``set_timer(None)``.
"""

from __future__ import annotations

import time
from typing import Callable

__all__ = ["default_timer", "monotonic", "set_timer"]

#: the process-default monotonic clock.  The sole sanctioned direct wall-clock
#: reference in determinism-critical code; everything else calls monotonic().
default_timer: Callable[[], float] = time.perf_counter  # repro: allow[DET001]

_timer: Callable[[], float] = default_timer


def monotonic() -> float:
    """Seconds from the injectable monotonic clock (durations only — the
    epoch is arbitrary, so readings are only meaningful as differences)."""
    return _timer()


def set_timer(timer: Callable[[], float] | None) -> Callable[[], float]:
    """Swap the clock; ``None`` restores the default.  Returns the previous
    timer so tests can restore it in a ``finally``."""
    global _timer
    prev = _timer
    _timer = default_timer if timer is None else timer
    return prev
