"""The public tuning facade: ``repro.tune(spec)`` / ``repro.tune_matrix(spec)``.

One declarative entry point replaces the bespoke wiring that used to live in
`MatrixRunner`, `Searcher.run`, the benchmark scripts, and the examples:

* :class:`TuningSpec` — a frozen, JSON-serializable description of a tuning
  run: kernel/objective id, search space, searcher name + kwargs, measurement
  backend name + kwargs (resolved via :mod:`repro.core.backends`), a sample
  budget or an :class:`ExperimentDesign`, seed, and cache/store settings.
* :class:`TuningSession` — the driver that owns evaluation: it runs the
  ask/tell loop (through the engine's ``drive`` primitive, on
  ``Searcher.start/ask/tell/finish`` + ``MeasurementStore``), runs single
  searches and full experiment matrices.  Matrix runs decompose into
  serializable :class:`~repro.core.workunits.ExperimentUnit` work units
  (contiguous experiment ranges of a cell) executed through the pluggable
  ``EXECUTORS`` registry (``serial`` / ``process`` / ``futures``), with
  completed units journaled through the measurement store for
  ``resume=True`` checkpointing.  Experiment seeds derive from the spec
  alone, so every executor — and every split of a cell into units — is
  bit-identical to the serial loop.
* :class:`RunRecord` — a versioned JSON schema (spec + result summary +
  provenance) emitted next to each saved result; the stats/figure layer
  consumes it.

Example::

    import repro
    from repro.core import TuningSpec

    result = repro.tune(TuningSpec(kernel="harris", searcher="ga", budget=100))
    print(result.best_config, result.final_value)

    matrix = repro.tune_matrix(
        TuningSpec(kernel="harris", algorithms=("rs", "ga"),
                   design=ExperimentDesign.scaled(budget=500)),
        shards=2,
    )
"""

from __future__ import annotations

import json
import os
import platform
import socket
import warnings
from dataclasses import dataclass, field, replace
from datetime import datetime, timezone
from typing import Callable

import numpy as np

from ..telemetry.null import NULL_TELEMETRY
from .backends import BACKENDS, make_measurement
from .clock import monotonic
from .dataset import SampleDataset
from .engine import DISPATCH_MODES, DiskCachedMeasurement, drive
from .executors import EXECUTORS, ExecutionPlan, recover_shard_stores, run_units
from .experiment import ExperimentDesign
from .measurement import BaseMeasurement
from .runner import CellResult, MatrixResults, stable_seed
from .searchers import SEARCHERS, make_searcher
from .searchers.base import TuningResult
from .space import Config, Param, SearchSpace, _paper_wg256
from .stores import STORES, make_store
from .surrogates.forest_batched import BatchedForest
from .workunits import (
    ExperimentUnit,
    UnitJournal,
    UnitResult,
    build_units,
    merge_unit_results,
)

SPEC_VERSION = 1
RUN_RECORD_VERSION = 1

#: units per worker the stealing scheduler aims for — enough queue slack to
#: rebalance around a straggler cell without shrinking units so far that
#: per-unit dispatch overhead dominates
STEAL_OVERSPLIT = 4

__all__ = [
    "RUN_RECORD_VERSION",
    "SPEC_VERSION",
    "RunRecord",
    "TuningSession",
    "TuningSpec",
    "register_constraint",
    "tune",
    "tune_matrix",
]


# ------------------------------------------------------- space serialization

#: named constraints a serialized spec can refer to.  ``vmem:<kernel>:<chip>``
#: ids are resolved dynamically against the costmodel backend.
CONSTRAINTS: dict[str, Callable[[Config], bool]] = {
    "paper_wg256": _paper_wg256,
}


def register_constraint(name: str, fn: Callable[[Config], bool]):
    """Register a constraint predicate under a stable id so spaces using it
    survive TuningSpec JSON round-trips."""
    fn.constraint_id = name
    CONSTRAINTS[name] = fn
    return fn


def _resolve_constraint(cid: str | None) -> Callable[[Config], bool] | None:
    if cid is None:
        return None
    if cid in CONSTRAINTS:
        return CONSTRAINTS[cid]
    if cid.startswith("vmem:"):
        from ..costmodel import CHIPS, WORKLOADS, is_executable

        _, kernel, chip = cid.split(":")
        w, c = WORKLOADS[kernel], CHIPS[chip]

        def fn(cfg: Config) -> bool:
            return is_executable(w, c, cfg)

        fn.constraint_id = cid
        return fn
    if cid.startswith("pallas_fit:"):
        # pallas_fit:<kernel>:<x>:<y>:<vmem_limit>:<max_grid> — the real
        # measurement backend's validity pre-screen as a named constraint
        from ..pallas_bench import fit_constraint, make_workload

        _, kernel, x, y, vmem_limit, max_grid = cid.split(":")
        return fit_constraint(
            make_workload(kernel, x=int(x), y=int(y)),
            int(vmem_limit),
            int(max_grid),
        )
    raise KeyError(
        f"unknown constraint id {cid!r}; register it with "
        f"repro.core.api.register_constraint(name, fn)"
    )


def space_to_dict(space: SearchSpace) -> dict:
    cid = getattr(space.constraint, "constraint_id", None)
    if space.constraint is not None and cid is None:
        raise ValueError(
            "SearchSpace constraint is not serializable: give the predicate a "
            "stable id via register_constraint(name, fn), or leave "
            "TuningSpec.space=None so the backend derives the space"
        )
    return {
        "params": [{"name": p.name, "values": list(p.values)} for p in space.params],
        "constraint": cid,
    }


def space_from_dict(d: dict) -> SearchSpace:
    params = [Param(p["name"], tuple(p["values"])) for p in d["params"]]
    return SearchSpace(params, constraint=_resolve_constraint(d.get("constraint")))


# ---------------------------------------------------------------- TuningSpec


@dataclass(frozen=True)
class TuningSpec:
    """Declarative description of a tuning run (frozen, JSON-serializable).

    ``budget`` drives a single :func:`tune`; ``design`` (+ ``algorithms``)
    drives a :func:`tune_matrix`.  ``space=None`` derives the search space
    from the backend (the costmodel backend yields the executable-config
    space for ``kernel`` x ``chip``).  ``store``/``store_path`` select the
    persistent measurement cache (``"json"`` default file store or
    ``"sqlite"`` for paper-exact multi-million-sample designs).
    ``searcher_kwargs`` apply to the named ``searcher`` only — other
    algorithms on a matrix axis run with their own defaults.
    """

    kernel: str
    searcher: str = "ga"
    searcher_kwargs: dict = field(default_factory=dict)
    backend: str = "costmodel"
    backend_kwargs: dict = field(default_factory=dict)
    space: SearchSpace | None = None
    budget: int | None = None
    design: ExperimentDesign | None = None
    algorithms: tuple[str, ...] | None = None
    seed: int = 0
    dispatch: str = "batch"
    final_repeats: int = 10
    store: str | None = None
    store_path: str | None = None
    cache_key: str | None = None
    dataset_size: int | None = None
    dataset_seed: int = 7
    dataset_gen_seed: int = 999
    dataset_cache: str | None = None

    def __post_init__(self):
        if not self.kernel or not isinstance(self.kernel, str):
            raise ValueError("TuningSpec.kernel must be a non-empty string id")
        if self.searcher not in SEARCHERS:
            raise KeyError(
                f"unknown searcher {self.searcher!r}; have {sorted(SEARCHERS)}"
            )
        if self.backend not in BACKENDS:
            raise KeyError(
                f"unknown backend {self.backend!r}; have {sorted(BACKENDS)}"
            )
        if self.dispatch not in DISPATCH_MODES:
            raise ValueError(f"dispatch must be one of {DISPATCH_MODES}")
        if self.store is not None and self.store not in STORES:
            raise KeyError(f"unknown store {self.store!r}; have {sorted(STORES)}")
        if self.budget is not None and self.budget < 1:
            raise ValueError("budget must be >= 1")
        if isinstance(self.design, dict):
            object.__setattr__(self, "design", ExperimentDesign.from_dict(self.design))
        if self.algorithms is not None:
            algos = tuple(self.algorithms)
            unknown = [a for a in algos if a not in SEARCHERS]
            if unknown:
                raise KeyError(f"unknown algorithms {unknown}; have {sorted(SEARCHERS)}")
            object.__setattr__(self, "algorithms", algos)
        object.__setattr__(self, "searcher_kwargs", dict(self.searcher_kwargs))
        object.__setattr__(self, "backend_kwargs", dict(self.backend_kwargs))

    # -- derived --------------------------------------------------------------
    @property
    def matrix_algorithms(self) -> tuple[str, ...]:
        return self.algorithms if self.algorithms is not None else (self.searcher,)

    def default_cache_key(self) -> str:
        # pipeline_workers / compile_cache change how fast measurements
        # happen, never what they are — leaving them out keeps warm caches
        # warm across the knobs
        kwargs = {
            k: v
            for k, v in self.backend_kwargs.items()
            if k not in ("pipeline_workers", "compile_cache")
        }
        # the common costmodel case keeps its compact, store-compatible form
        if set(kwargs) == {"chip"}:
            return f"{self.kernel}/{kwargs['chip']}"
        if kwargs:
            # backend kwargs change what a measurement MEANS (problem size,
            # repeats, noise, validity limits...) — bake them into the
            # namespace so a shared store never serves values from a
            # different problem.  Non-scalar kwargs (live callables) have no
            # stable repr; they collapse to a type token — set cache_key
            # explicitly to separate two such specs sharing one store.
            def stable(v):
                return v if isinstance(v, (str, int, float, bool, type(None))) \
                    else f"<{type(v).__name__}>"

            kw = ",".join(f"{k}={stable(kwargs[k])}" for k in sorted(kwargs))
            return f"{self.kernel}/{self.backend}/{kw}"
        return f"{self.kernel}/{self.backend}"

    def replace(self, **changes) -> "TuningSpec":
        return replace(self, **changes)

    # -- serialization --------------------------------------------------------
    def to_dict(self) -> dict:
        return {
            "spec_version": SPEC_VERSION,
            "kernel": self.kernel,
            "searcher": self.searcher,
            "searcher_kwargs": dict(self.searcher_kwargs),
            "backend": self.backend,
            "backend_kwargs": dict(self.backend_kwargs),
            "space": None if self.space is None else space_to_dict(self.space),
            "budget": self.budget,
            "design": None if self.design is None else self.design.to_dict(),
            "algorithms": None if self.algorithms is None else list(self.algorithms),
            "seed": self.seed,
            "dispatch": self.dispatch,
            "final_repeats": self.final_repeats,
            "store": self.store,
            "store_path": self.store_path,
            "cache_key": self.cache_key,
            "dataset_size": self.dataset_size,
            "dataset_seed": self.dataset_seed,
            "dataset_gen_seed": self.dataset_gen_seed,
            "dataset_cache": self.dataset_cache,
        }

    @classmethod
    def from_dict(cls, d: dict) -> "TuningSpec":
        d = dict(d)
        version = d.pop("spec_version", SPEC_VERSION)
        if version > SPEC_VERSION:
            raise ValueError(
                f"spec_version {version} is newer than supported {SPEC_VERSION}"
            )
        if d.get("space") is not None:
            d["space"] = space_from_dict(d["space"])
        if d.get("design") is not None:
            d["design"] = ExperimentDesign.from_dict(d["design"])
        if d.get("algorithms") is not None:
            d["algorithms"] = tuple(d["algorithms"])
        return cls(**d)

    def to_json(self, **kwargs) -> str:
        try:
            return json.dumps(self.to_dict(), **kwargs)
        except TypeError as e:
            raise TypeError(
                f"TuningSpec is not JSON-serializable ({e}). Backends wired "
                "with in-process callables (timing runners, raw measurement "
                "instances) cannot be serialized or sharded — name the "
                "backend and pass plain kwargs instead."
            ) from e

    @classmethod
    def from_json(cls, s: str) -> "TuningSpec":
        return cls.from_dict(json.loads(s))


# ----------------------------------------------------------------- RunRecord


_GIT_STATE: dict | None = None


def _git_state() -> dict:
    """Best-effort code provenance: the checkout's commit SHA and a dirty
    flag, memoized per process (two subprocess calls, once).  ``{}`` outside
    a git checkout or without a ``git`` binary — records never *depend* on
    it, it only answers "which code produced this result" when it can."""
    global _GIT_STATE
    if _GIT_STATE is None:
        state: dict = {}
        try:
            import subprocess

            root = os.path.dirname(os.path.abspath(__file__))
            sha = subprocess.run(
                ["git", "rev-parse", "HEAD"],
                cwd=root, capture_output=True, text=True, timeout=5,
            )
            if sha.returncode == 0 and sha.stdout.strip():
                state["git_sha"] = sha.stdout.strip()
                st = subprocess.run(
                    ["git", "status", "--porcelain"],
                    cwd=root, capture_output=True, text=True, timeout=5,
                )
                if st.returncode == 0:
                    state["git_dirty"] = bool(st.stdout.strip())
        except Exception:
            state = {}
        _GIT_STATE = state
    return _GIT_STATE


def _provenance(wall_s: float | None = None) -> dict:
    p = {
        # a provenance timestamp SHOULD be the real wall clock; results never
        # read it back
        "created_at": datetime.now(timezone.utc).isoformat(  # repro: allow[DET001]
            timespec="seconds"
        ),
        "hostname": socket.gethostname(),
        "platform": platform.platform(),
        "python": platform.python_version(),
        "numpy": np.__version__,
    }
    try:
        from .. import __version__ as _repro_version
        p["repro_version"] = _repro_version
    except ImportError:  # pragma: no cover - package always carries a version
        pass
    p.update(_git_state())
    if wall_s is not None:
        p["wall_s"] = round(float(wall_s), 3)
    return p


@dataclass
class RunRecord:
    """Versioned provenance record written alongside saved results.

    ``result`` holds a JSON summary (per-cell medians for a matrix, the best
    config for a single run) plus ``artifact`` — the relative path of the
    full ``.npz`` payload when one was saved.  The figure layer reads the
    ``true_optimum`` (falling back to ``best_observed``) as the
    pct-of-optimum denominator.
    """

    kind: str                      # "tune" | "tune_matrix"
    spec: dict
    result: dict
    provenance: dict
    extra: dict = field(default_factory=dict)
    version: int = RUN_RECORD_VERSION

    def to_dict(self) -> dict:
        return {
            "run_record_version": self.version,
            "kind": self.kind,
            "spec": self.spec,
            "result": self.result,
            "provenance": self.provenance,
            "extra": self.extra,
        }

    @classmethod
    def from_dict(cls, d: dict) -> "RunRecord":
        return cls(
            kind=d["kind"],
            spec=d["spec"],
            result=d["result"],
            provenance=d.get("provenance", {}),
            extra=d.get("extra", {}),
            version=d.get("run_record_version", RUN_RECORD_VERSION),
        )

    def save(self, path: str) -> None:
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.to_dict(), f, indent=2)

    @classmethod
    def load(cls, path: str) -> "RunRecord":
        with open(path) as f:
            return cls.from_dict(json.load(f))


# -------------------------------------------------------------- TuningSession


class TuningSession:
    """Drives tuning runs described by a :class:`TuningSpec`.

    The session owns evaluation end to end: it builds searchers and
    measurement backends from the spec (via the ``SEARCHERS`` / ``BACKENDS``
    registries), drives the ask/tell loop (the engine's ``drive`` primitive),
    wraps measurements in the persistent store cache when configured,
    re-measures winners per the paper's final-repeats protocol, and — for
    matrix runs — decomposes the matrix into work units executed through the
    ``EXECUTORS`` registry (:meth:`run_matrix` with ``executor=...`` /
    ``max_workers=N``; the legacy ``shards=N`` spelling delegates there).

    Keyword overrides (``space`` / ``measurement_factory`` / ``dataset`` /
    ``store``) exist for in-process callers that hold live objects; a
    session with overrides only runs under the ``serial`` executor because
    parallel workers rebuild everything from the serialized spec.
    """

    def __init__(
        self,
        spec: TuningSpec,
        *,
        space: SearchSpace | None = None,
        measurement_factory: Callable[[int], BaseMeasurement] | None = None,
        dataset: SampleDataset | None = None,
        store=None,
        store_path: str | None = None,
        verbose: bool = False,
        telemetry=None,
    ):
        if not isinstance(spec, TuningSpec):
            raise TypeError(f"spec must be a TuningSpec, got {type(spec).__name__}")
        self.spec = spec
        self.verbose = verbose
        # observability sink, NEVER part of the run's identity: it is a
        # session/runtime knob (not a spec field) precisely so it can't leak
        # into cache keys, journal namespaces, or spec fingerprints
        self.telemetry = telemetry if telemetry is not None else NULL_TELEMETRY
        self._last_telemetry: dict = {}
        self._backend = BACKENDS[spec.backend]
        self._has_overrides = any(
            x is not None for x in (space, measurement_factory, dataset, store)
        )
        self.space = space if space is not None else spec.space
        if self.space is None and self._backend.default_space is not None:
            self.space = self._backend.default_space(
                kernel=spec.kernel, **spec.backend_kwargs
            )
        if self.space is None:
            raise ValueError(
                f"backend {spec.backend!r} has no default space; set "
                "TuningSpec.space explicitly"
            )
        # the default factory reads the CURRENT spec (not the ctor argument):
        # run_matrix(pipeline_workers=N) re-points self.spec at a replaced
        # spec and the next measurement picks the knob up
        self._factory = measurement_factory or (
            lambda s: make_measurement(
                self.spec.backend,
                kernel=self.spec.kernel,
                seed=s,
                **self.spec.backend_kwargs,
            )
        )
        self._store_path = store_path if store_path is not None else spec.store_path
        if store is not None:
            self.store = store
        elif spec.store is not None:
            self.store = make_store(spec.store, self._store_path)
        else:
            self.store = None
        self.cache_key = spec.cache_key or spec.default_cache_key()
        self._dataset = dataset
        self.measurement: BaseMeasurement | None = None  # last single-run backend
        self.last_record: RunRecord | None = None
        self.last_unit_plan: list[ExperimentUnit] = []
        self._last_cell_walls: dict[tuple[str, int], dict[str, float]] = {}

    # -- wiring ---------------------------------------------------------------
    def _make_measurement(self, exp_seed: int) -> BaseMeasurement:
        m = self._factory(exp_seed)
        if self.store is not None:
            m = DiskCachedMeasurement(
                m, self.store, prefix=f"{self.cache_key}/seed={exp_seed}"
            )
        m.set_telemetry(self.telemetry)
        return m

    def _get_dataset(self) -> SampleDataset | None:
        if self._dataset is None and self.spec.dataset_size:
            self._dataset = SampleDataset.generate(
                self.space,
                self._factory(self.spec.dataset_gen_seed),
                n=self.spec.dataset_size,
                seed=self.spec.dataset_seed,
                cache_path=self.spec.dataset_cache,
            )
        return self._dataset

    def save_store(self) -> None:
        if self.store is not None:
            self.store.save()

    # -- single run (the ask/tell loop lives HERE) ----------------------------
    def run(self) -> TuningResult:
        """One budgeted search + the paper's final re-measurement."""
        spec = self.spec
        if spec.budget is None:
            raise ValueError("TuningSpec.budget is required for tune(); "
                            "use tune_matrix() for design-driven runs")
        t0 = monotonic()
        searcher = make_searcher(
            spec.searcher, self.space, seed=spec.seed, **spec.searcher_kwargs
        )
        measurement = self.measurement = self._make_measurement(spec.seed)
        result = drive(searcher, measurement, spec.budget,
                       dispatch=spec.dispatch, telemetry=self.telemetry)
        result.final_value = measurement.measure_final(
            result.best_config, spec.final_repeats
        )
        self.save_store()
        self._record_winner()
        res = {
            "best_config": result.best_config,
            "best_value": result.best_value,
            "final_value": result.final_value,
            "n_samples": result.n_samples,
        }
        reason = measurement.reason_for(result.best_config)
        if reason is not None:
            res["invalid_reason"] = reason
        repeats = measurement.repeats_for(result.best_config)
        if repeats is not None:
            # raw per-repeat seconds behind final_value's median
            res["final_repeat_times"] = [float(v) for v in repeats]
        self.last_record = RunRecord(
            kind="tune",
            spec=self._spec_dict_or_repr(),
            result=res,
            provenance=_provenance(monotonic() - t0),
            extra=self._backend_extra(measurement),
        )
        return result

    def _backend_extra(self, measurement: BaseMeasurement | None) -> dict:
        """Backend provenance (interpret flag, device kind, repeats, warmup,
        timer...) for the run record — how the numbers were produced, which
        is what lets the figure layer tell costmodel runs from pallas runs."""
        prov = measurement.provenance() if measurement is not None else {}
        return {"backend_provenance": prov} if prov else {}

    # -- matrix runs ----------------------------------------------------------
    def cells(self) -> list[tuple[str, int, int]]:
        """Canonical cell order: ``(algo, sample_size, n_experiments)``."""
        if self.spec.design is None:
            raise ValueError("TuningSpec.design is required for matrix runs")
        return [
            (algo, s, e)
            for algo in self.spec.matrix_algorithms
            for s, e in self.spec.design.rows()
        ]

    def run_matrix(
        self,
        shards: int = 1,
        *,
        executor: str | None = None,
        max_workers: int | None = None,
        resume: bool = False,
        unit_experiments: int | None = None,
        futures_pool=None,
        pipeline_workers: int | None = None,
        scheduler: str = "steal",
        compile_cache: str | None = None,
    ) -> MatrixResults:
        """Run the experiment matrix through the executor layer.

        The matrix decomposes into :class:`ExperimentUnit` work units —
        whole cells by default, within-cell experiment ranges when
        ``max_workers`` exceeds the cell count or ``unit_experiments`` caps
        the unit size — executed through ``EXECUTORS[executor]`` and merged
        deterministically by unit key, so every executor (and every split)
        is bit-identical to the serial loop.

        ``shards=N`` is the legacy spelling of ``executor="process",
        max_workers=N``.  ``resume=True`` replays completed units from the
        store's unit journal (zero re-measurements) and first absorbs any
        shard stores a killed parallel run left behind.
        ``pipeline_workers=N`` enables the staged backend's compile-prefetch
        pipeline (backends with ``Backend.pipeline``; the knob changes
        wall-clock, not results, so caches and journals stay valid across
        it).

        ``scheduler`` picks how parallel executors hand units to workers:
        ``"steal"`` (default) over-splits cells by cost-model-predicted
        duration and lets workers pull units from a shared queue as they
        free up; ``"static"`` is the legacy one-partition-per-worker
        schedule.  ``compile_cache=DIR`` points staged backends at a
        persistent on-disk compile-artifact cache shared across worker
        processes and across runs.  Both are pure speed knobs: results,
        stores, cache keys, and journals are bit-identical across them.
        """
        with self.telemetry.span("matrix", cache_key=self.cache_key):
            return self._run_matrix_impl(
                shards,
                executor=executor,
                max_workers=max_workers,
                resume=resume,
                unit_experiments=unit_experiments,
                futures_pool=futures_pool,
                pipeline_workers=pipeline_workers,
                scheduler=scheduler,
                compile_cache=compile_cache,
            )

    def _run_matrix_impl(
        self,
        shards: int,
        *,
        executor: str | None,
        max_workers: int | None,
        resume: bool,
        unit_experiments: int | None,
        futures_pool,
        pipeline_workers: int | None,
        scheduler: str = "steal",
        compile_cache: str | None = None,
    ) -> MatrixResults:
        t0 = monotonic()
        if scheduler not in ("steal", "static"):
            raise ValueError(
                f"unknown scheduler {scheduler!r}; use 'steal' or 'static'"
            )
        if pipeline_workers is not None:
            if not self._backend.pipeline:
                raise ValueError(
                    f"backend {self.spec.backend!r} has no compile pipeline; "
                    "pipeline_workers applies to staged backends only "
                    "(BACKENDS[...].pipeline)"
                )
            self.spec = self.spec.replace(
                backend_kwargs={
                    **self.spec.backend_kwargs,
                    "pipeline_workers": int(pipeline_workers),
                }
            )
        if compile_cache is not None:
            if not self._backend.pipeline:
                raise ValueError(
                    f"backend {self.spec.backend!r} has no compile stage; "
                    "compile_cache applies to staged backends only "
                    "(BACKENDS[...].pipeline)"
                )
            self.spec = self.spec.replace(
                backend_kwargs={
                    **self.spec.backend_kwargs,
                    "compile_cache": os.path.abspath(compile_cache),
                }
            )
        cells = self.cells()
        name = executor
        if name is None:
            name = "futures" if futures_pool is not None else None
        if futures_pool is not None and name != "futures":
            raise ValueError(
                f"futures_pool only applies to executor='futures', not {name!r}"
            )
        if max_workers is None and futures_pool is not None:
            # a supplied pool IS the parallelism request; size from the pool
            max_workers = getattr(futures_pool, "_max_workers", None) or 2
        workers = int(max_workers if max_workers is not None else shards)
        if workers < 1:
            raise ValueError("max_workers must be >= 1")
        if name is None:
            name = "process" if workers > 1 else "serial"
        if name not in EXECUTORS:
            raise KeyError(f"unknown executor {name!r}; have {sorted(EXECUTORS)}")
        # the stealing scheduler wants more units than workers so the queue
        # can rebalance around stragglers; the static schedule keeps the
        # legacy one-unit-per-worker floor (identical decomposition, and so
        # identical journals, to every release before the scheduler existed)
        oversplit = (
            STEAL_OVERSPLIT
            if scheduler == "steal" and EXECUTORS[name].parallel and workers > 1
            else 1
        )
        units = build_units(
            cells,
            min_units=(workers * oversplit) if EXECUTORS[name].parallel else 1,
            max_unit_experiments=unit_experiments,
            cost=self._unit_cost(),
        )
        self.last_unit_plan = units
        journal = self.unit_journal()
        if resume and journal is None:
            warnings.warn(
                "resume=True needs a spec-described persistent store "
                "(TuningSpec.store, no in-process overrides); running "
                "everything fresh"
            )
        done: list[UnitResult] = []
        pending = units
        if resume and journal is not None:
            recover_shard_stores(self)
            done, pending = journal.partition(units)
            if self.verbose and done:
                print(
                    f"[session] resume: {len(done)}/{len(units)} units served "
                    "from the journal"
                )
        tel = self.telemetry
        if tel.enabled:
            # the plan event anchors live progress: consumers count unit /
            # experiment "end" events AFTER the last plan in the stream
            tel.event(
                "plan",
                executor=name,
                workers=workers,
                scheduler=scheduler,
                units=[u.key for u in pending],
                units_total=len(units),
                experiments_total=sum(u.n_unit_exp for u in units),
                units_done_resume=len(done),
                experiments_done_resume=sum(r.unit.n_unit_exp for r in done),
            )
            if done:
                tel.inc("units_skipped_resume", len(done))
        # snapshot BEFORE fresh units run: under the serial executor their
        # counter deltas land in this same sink, so totals = pre-run snapshot
        # + per-unit deltas is correct for every executor (workers ship their
        # deltas back inside UnitResult.counters)
        c_pre = tel.counters_snapshot()
        fresh: list[UnitResult] = []
        if pending:
            run_name = name
            if EXECUTORS[name].parallel and (workers <= 1 or len(pending) <= 1):
                if workers > 1:
                    warnings.warn(
                        f"executor {name!r} degrades to serial: only "
                        f"{len(pending)} pending unit(s) for {workers} workers"
                    )
                run_name = "serial"
            plan = ExecutionPlan(
                session=self,
                units=pending,
                max_workers=min(workers, len(pending)),
                futures_pool=futures_pool,
                scheduler=scheduler,
            )
            fresh = run_units(run_name, plan)
        cell_results, self._last_cell_walls = merge_unit_results(
            cells, done + fresh
        )
        results = MatrixResults()
        for cell in cell_results:
            results.add(cell)
        self.save_store()
        self._record_winner()
        if tel.enabled:
            n_exp = {(algo, s): e for algo, s, e in cells}
            for (algo, s), w in sorted(self._last_cell_walls.items()):
                tel.event(
                    "cell",
                    algo=algo,
                    sample_size=s,
                    n_experiments=n_exp.get((algo, s)),
                    wall_s=round(w["wall_s"], 6),
                    compile_s=round(w.get("compile_s", 0.0), 6),
                    measure_s=round(w.get("measure_s", 0.0), 6),
                )
            totals: dict[str, float] = dict(c_pre)
            for r in done + fresh:
                for k, v in r.counters.items():
                    totals[k] = totals.get(k, 0) + v
            totals = {
                k: int(v) if float(v).is_integer() else float(v)
                for k, v in sorted(totals.items())
            }
            tel.event("totals", counters=totals)
            self._last_telemetry = {"counters": totals}
        self.last_record = self.make_record(results, wall_s=monotonic() - t0)
        return results

    def _record_winner(self) -> None:
        """Refresh the serving winners index after results land — the update
        rides the store the results were just saved to, so the index is
        maintained transactionally with its measurements.  Best-effort: the
        serving index must never fail a tuning run."""
        if self.store is None:
            return
        try:
            from ..serving.winners import record_session_winner

            record_session_winner(self)
        except Exception as e:
            warnings.warn(f"serving winner index update failed: {e}")

    # -- the work-unit layer --------------------------------------------------
    def _unit_cost(self) -> Callable[[ExperimentUnit], float]:
        """Predicted unit duration driving the stealing scheduler's initial
        split: experiments x samples, scaled by the cost model's mean
        per-measurement runtime for this spec's kernel/chip when it knows
        them.  MUST be a pure deterministic function of the unit — the
        decomposition is part of the journaled plan, so a resumed run has to
        rebuild the exact same units.  Cost only shapes which units get
        split first, never their results, so a fallback to the uniform
        per-experiment weight (unknown kernels, live overrides) is safe."""
        per_measure = 1.0
        try:
            from ..costmodel import CHIPS, WORKLOADS, mean_runtime_estimate

            workload = WORKLOADS[self.spec.kernel]
            chip = CHIPS[self.spec.backend_kwargs.get("chip", "v5e")]
            per_measure = float(mean_runtime_estimate(workload, chip))
        except Exception:
            per_measure = 1.0

        def cost(u: ExperimentUnit) -> float:
            return float(u.n_unit_exp) * float(u.sample_size) * per_measure

        return cost

    def journal_namespace(self) -> str | None:
        """Binds unit-journal entries to everything that changes a unit's
        numbers: the cache key plus a fingerprint of the FULL spec (searcher
        kwargs, dataset seeds, design, root seed, dispatch, ...) minus the
        storage fields — pointing the same experiment at a different store
        must not orphan its journal, but changing anything that alters a
        result must.  The unit key itself carries (algo, S, experiment
        range, cell size).  ``None`` for specs with no stable fingerprint
        (live callables stringify with memory addresses, which would orphan
        the journal on every process restart)."""
        d = dict(self._spec_dict_or_repr())
        for k in ("store", "store_path"):
            d.pop(k, None)
        if isinstance(d.get("backend_kwargs"), dict):
            # the pipeline / persistent-compile-cache knobs change execution
            # speed, never results — journaled units stay valid with the
            # prefetcher or the artifact cache on or off
            bk = dict(d["backend_kwargs"])
            bk.pop("pipeline_workers", None)
            bk.pop("compile_cache", None)
            d["backend_kwargs"] = bk
        try:
            fp = stable_seed(json.dumps(d, sort_keys=True))
        except (TypeError, ValueError):
            return None
        return f"{self.cache_key}|{fp:08x}"

    def unit_journal(self) -> UnitJournal | None:
        # sessions with live in-process overrides are not spec-described, so
        # a journal entry's validity could never be re-established on resume
        if self.store is None or self._has_overrides:
            return None
        ns = self.journal_namespace()
        if ns is None:
            return None
        return UnitJournal(self.store, ns)

    def run_cell(self, algo: str, sample_size: int, n_exp: int) -> CellResult:
        """All experiments of one (algorithm, sample-size) cell — one
        whole-cell unit through :meth:`run_unit`."""
        unit = ExperimentUnit(
            algo=algo, sample_size=sample_size, exp_lo=0, exp_hi=n_exp,
            n_exp=n_exp,
        )
        r = self.run_unit(unit)
        return CellResult(
            algo=algo,
            sample_size=sample_size,
            final_values=r.final_values,
            search_best_values=r.search_best_values,
            n_samples_used=r.n_samples_used,
        )

    def run_unit(self, unit: ExperimentUnit) -> UnitResult:
        """Experiments ``[unit.exp_lo, unit.exp_hi)`` of one cell.

        Experiment seeds derive from ``(spec.seed, algo, sample_size, e)``
        with the GLOBAL experiment index ``e``, so any process can run any
        unit — and any split of a cell into units — and get results
        bit-identical to the monolithic per-cell loop.
        """
        spec = self.spec
        tel = self.telemetry
        t0 = monotonic()
        c0 = tel.counters_snapshot()
        with tel.span(
            "unit", unit=unit.key, algo=unit.algo, sample_size=unit.sample_size
        ):
            dataset = self._get_dataset()
            n = unit.n_unit_exp
            finals = np.empty(n)
            search_best = np.empty(n)
            n_used = np.empty(n, dtype=np.int64)
            rf_batch = (
                self._rf_unit_batched(unit)
                if (dataset is not None and unit.algo == "rf")
                else None
            )
            stage_acc: dict[str, float] = {}
            for i, e in enumerate(range(unit.exp_lo, unit.exp_hi)):
                with tel.span("experiment", experiment=e, unit=unit.key):
                    exp_seed = stable_seed(
                        spec.seed, unit.algo, unit.sample_size, e
                    )
                    measurement = self.measurement = self._make_measurement(
                        exp_seed
                    )
                    if rf_batch is not None:
                        tr = rf_batch[i]
                    elif dataset is not None and unit.algo == "rs":
                        tr = self._rs_from_dataset(e, unit.sample_size)
                    else:
                        # searcher_kwargs belong to the spec's named searcher;
                        # other algorithms on the matrix axis use their own
                        # defaults (SA would reject GA's pop_size, etc.)
                        kwargs = (
                            spec.searcher_kwargs
                            if unit.algo == spec.searcher
                            else {}
                        )
                        searcher = make_searcher(
                            unit.algo, self.space, seed=exp_seed, **kwargs
                        )
                        tr = searcher.run(
                            measurement,
                            unit.sample_size,
                            dispatch=spec.dispatch,
                            telemetry=tel,
                        )
                    finals[i] = measurement.measure_final(
                        tr.best_config, spec.design.final_repeats
                    )
                    search_best[i] = tr.best_value
                    n_used[i] = tr.n_samples
                    # staged backends (pallas) report per-stage clocks;
                    # unstaged ones report {} and the unit carries no breakdown
                    for k, v in measurement.stage_times().items():
                        stage_acc[k] = stage_acc.get(k, 0.0) + float(v)
                if tel.enabled:
                    tel.inc("experiments_completed")
            if tel.enabled:
                tel.inc("units_completed")
        wall = monotonic() - t0
        counters: dict[str, float] = {}
        if tel.enabled:
            c1 = tel.counters_snapshot()
            counters = {
                k: v - c0.get(k, 0) for k, v in c1.items() if v != c0.get(k, 0)
            }
        if self.verbose:
            print(
                f"[session] {unit.algo:7s} S={unit.sample_size:4d} "
                f"e[{unit.exp_lo}:{unit.exp_hi})/{unit.n_exp:4d} "
                f"median={np.median(finals):.6g} best={finals.min():.6g} "
                f"wall={wall:.2f}s"
            )
        return UnitResult(
            unit=unit,
            final_values=finals,
            search_best_values=search_best,
            n_samples_used=n_used,
            wall_s=wall,
            stage_s=stage_acc,
            counters=counters,
        )

    # -- dataset-served paths (paper section VI.B) ---------------------------
    def _rs_from_dataset(self, experiment: int, budget: int) -> TuningResult:
        dataset = self._get_dataset()
        idx, vals = dataset.chunk(experiment, budget)
        j = int(np.argmin(vals))
        return TuningResult(
            algo="rs",
            best_config=self.space.decode(idx[j]),
            best_value=float(vals[j]),
            history_values=list(vals),
            history_configs=[],
            n_samples=budget,
        )

    def _rf_unit_batched(self, unit: ExperimentUnit, rf_pool: int = 2048
                         ) -> list[TuningResult]:
        """The unit's RF experiments, fit in ONE vectorized histogram-forest
        pass (see surrogates/forest_batched.py).  Semantics per experiment
        match the paper: train on a disjoint S-10 dataset chunk, measure the
        model's top-10 predictions over a candidate pool, keep the best
        prediction.

        Bootstrap draws come from the FULL cell's stream (one
        ``(E_total * trees, n_train)`` draw from ``spec.seed``), sliced to
        this unit's rows — experiment ``e`` resamples identically however
        the cell is split, so within-cell RF units stay bit-identical to
        the monolithic cell fit.
        """
        spec = self.spec
        dataset = self._get_dataset()
        sample_size = unit.sample_size
        top_k = min(10, max(1, sample_size // 2))
        n_train = sample_size - top_k
        chunks = [dataset.chunk(e, n_train) for e in range(unit.exp_lo, unit.exp_hi)]
        Xc = np.stack([c[0] for c in chunks])
        yc = np.stack([c[1] for c in chunks])
        n_trees = 100
        # bounded `integers` draws consume the stream sequentially in fill
        # order with data-dependent rejection, so rows can be skipped only
        # by generating everything before them (bit_generator.advance would
        # desync); the prefix up to exp_hi suffices, and the paper design's
        # worst cell is ~20k x 100 draws (~16 MB) — cheap either way
        boot = np.random.default_rng(spec.seed).integers(
            0, n_train, size=(unit.exp_hi * n_trees, n_train)
        )
        forest = BatchedForest(
            self.space.cardinalities, n_estimators=n_trees, seed=spec.seed
        )
        forest.fit(Xc, yc, bootstrap_idx=boot[unit.exp_lo * n_trees :])
        pool_rng = np.random.default_rng(spec.seed + 7)
        pool = self.space.sample_indices(pool_rng, rf_pool)
        preds = forest.predict(pool)                    # (unit E, P)
        results = []
        for i, e in enumerate(range(unit.exp_lo, unit.exp_hi)):
            exp_seed = stable_seed(spec.seed, "rf", sample_size, e)
            measurement = self._make_measurement(exp_seed)
            best = np.argsort(preds[i], kind="stable")[:top_k]
            run_vals = measurement.measure_batch(self.space.decode_batch(pool[best]))
            j = int(np.argmin(run_vals))
            results.append(
                TuningResult(
                    algo="rf",
                    best_config=self.space.decode(pool[best][j]),
                    best_value=float(run_vals[j]),
                    history_values=list(yc[i]) + list(run_vals),
                    history_configs=[],
                    n_samples=sample_size,
                )
            )
        return results

    # -- records --------------------------------------------------------------
    def _spec_dict_or_repr(self) -> dict:
        try:
            return self.spec.to_dict()
        except (TypeError, ValueError):
            return {"repr": repr(self.spec)}

    def make_record(
        self,
        results: MatrixResults,
        wall_s: float | None = None,
        artifact: str | None = None,
        extra: dict | None = None,
        with_optimum: bool = False,
    ) -> RunRecord:
        result = {
            "best_observed": float(results.optimum),
            "cells": [
                {
                    "algo": algo,
                    "sample_size": s,
                    "n_experiments": int(len(cell.final_values)),
                    "median_final": float(np.median(cell.final_values)),
                    "best_final": float(cell.final_values.min()),
                }
                for (algo, s), cell in sorted(results.cells.items())
            ],
        }
        if artifact is not None:
            result["artifact"] = artifact
        if (
            with_optimum
            and self._backend.true_optimum is not None
            and not self._has_overrides
        ):
            cfg, opt = self._backend.true_optimum(
                kernel=self.spec.kernel, **self.spec.backend_kwargs
            )
            result["true_optimum"] = float(opt)
            result["true_optimum_config"] = cfg
        dataset = self._dataset
        if dataset is not None:
            result["dataset_best"] = float(dataset.optimum)
        extra_out = {**self._backend_extra(self.measurement), **dict(extra or {})}
        if self._last_telemetry:
            # counter totals snapshotted at matrix completion (observability
            # only — the report's Telemetry section reads them back)
            extra_out["telemetry"] = self._last_telemetry
        if self._last_cell_walls:
            # per-cell search cost (sum of unit wall-clocks, parallel or
            # not), recorded by the work-unit layer, with the staged
            # pipeline's compile-vs-measure split; the figure layer plots
            # it alongside result quality (figures.search_cost)
            extra_out["cell_wall_s"] = [
                {
                    "algo": algo,
                    "sample_size": s,
                    "wall_s": round(w["wall_s"], 3),
                    "compile_s": round(w.get("compile_s", 0.0), 3),
                    "measure_s": round(w.get("measure_s", 0.0), 3),
                }
                for (algo, s), w in sorted(self._last_cell_walls.items())
            ]
        return RunRecord(
            kind="tune_matrix",
            spec=self._spec_dict_or_repr(),
            result=result,
            provenance=_provenance(wall_s),
            # backend provenance from the last in-process unit measurement
            # (parallel-run parents hold none — workers own the measurements)
            extra=extra_out,
        )


# -------------------------------------------------------------------- facade


def tune(
    spec: TuningSpec, *, record_path: str | None = None, verbose: bool = False
) -> TuningResult:
    """Run one budgeted search described by ``spec``.

    Returns the budget-audited :class:`TuningResult` with ``final_value``
    filled by the paper's median-of-``final_repeats`` re-measurement.  When
    ``record_path`` is given, a :class:`RunRecord` JSON lands there.
    """
    session = TuningSession(spec, verbose=verbose)
    result = session.run()
    if record_path is not None:
        session.last_record.save(record_path)
    return result


def tune_matrix(
    spec: TuningSpec,
    *,
    shards: int = 1,
    executor: str | None = None,
    max_workers: int | None = None,
    resume: bool = False,
    unit_experiments: int | None = None,
    futures_pool=None,
    pipeline_workers: int | None = None,
    scheduler: str = "steal",
    compile_cache: str | None = None,
    out_dir: str | None = None,
    verbose: bool = False,
    extra: dict | None = None,
    telemetry_dir: str | None = None,
) -> MatrixResults:
    """Run the (algorithms x design) experiment matrix described by ``spec``.

    The matrix decomposes into serializable work units run through the
    ``EXECUTORS`` registry: ``executor="process", max_workers=N`` fans units
    (including within-cell splits of big-E rows) across N spawned workers;
    ``executor="futures"`` submits the same payloads to any
    ``concurrent.futures.Executor`` (``futures_pool=...``).  ``shards=N``
    is the legacy spelling of the process executor.  Experiment seeds
    derive from the spec, so every executor is bit-identical to the serial
    loop.  ``resume=True`` skips units already journaled in the measurement
    store.  When ``out_dir`` is given, the full results land in
    ``<cache_key>.npz`` with a versioned :class:`RunRecord` JSON (including
    the backend's true optimum, when it can compute one) next to it.

    ``scheduler="steal"`` (default) over-splits cells by cost-model-predicted
    duration and lets workers pull units from a shared queue as they free
    up; ``scheduler="static"`` keeps the legacy one-partition-per-worker
    schedule.  ``compile_cache=DIR`` points staged backends (pallas) at a
    persistent on-disk compile-artifact cache shared across worker
    processes and across runs — a warm re-run recompiles nothing even from
    a cold process.  Both are pure speed knobs excluded from cache keys and
    journal namespaces.

    ``telemetry_dir`` enables span tracing: the run appends JSONL trace
    events to ``<telemetry_dir>/trace.jsonl`` (parallel workers write
    ``trace.shard<k>.jsonl`` beside their shard stores, merged at join) —
    inspect with ``python -m repro.telemetry <telemetry_dir>``.  Pure
    observability: results, stores, and journals are bit-identical with it
    on or off.
    """
    telemetry = None
    if telemetry_dir is not None:
        from ..telemetry.events import TRACE_FILE
        from ..telemetry.tracer import Telemetry

        os.makedirs(telemetry_dir, exist_ok=True)
        telemetry = Telemetry(os.path.join(telemetry_dir, TRACE_FILE))
    session = TuningSession(spec, verbose=verbose, telemetry=telemetry)
    t0 = monotonic()
    try:
        results = session.run_matrix(
            shards=shards,
            executor=executor,
            max_workers=max_workers,
            resume=resume,
            unit_experiments=unit_experiments,
            futures_pool=futures_pool,
            pipeline_workers=pipeline_workers,
            scheduler=scheduler,
            compile_cache=compile_cache,
        )
        if out_dir is not None:
            name = (spec.cache_key or spec.default_cache_key()).replace("/", "_")
            os.makedirs(out_dir, exist_ok=True)
            artifact = f"{name}.npz"
            results.save(os.path.join(out_dir, artifact))
            record = session.make_record(
                results,
                wall_s=monotonic() - t0,
                artifact=artifact,
                extra=extra,
                with_optimum=True,
            )
            record.save(os.path.join(out_dir, f"{name}.json"))
            session.last_record = record
    finally:
        if telemetry is not None:
            telemetry.close()
    return results
