"""Pre-generated sample datasets (paper section VI.B).

'For our non-SMBO approaches, we streamline the experimental sample
collection process by creating a dataset of 20 000 samples in one go for each
architecture and benchmark. We can then subdivide the samples for each sample
size and experiment.'

RS experiments draw disjoint chunks of S samples; RF experiments draw chunks
of S-10 for training.  Chunking is deterministic given the dataset seed.

Generation routes through ``measure_batch`` — on the vectorized cost-model
backend the whole 20k-sample dataset is ONE Python-level dispatch — and can
be persisted (``save``/``load`` or ``generate(..., cache_path=...)``) so a
re-run of the same (kernel, seed) combo never re-measures it.
"""

from __future__ import annotations

import os
from dataclasses import dataclass

import numpy as np

from .measurement import BaseMeasurement
from .space import SearchSpace


@dataclass
class SampleDataset:
    space: SearchSpace
    indices: np.ndarray   # (n, d) index vectors
    values: np.ndarray    # (n,) measured runtimes

    @classmethod
    def generate(
        cls,
        space: SearchSpace,
        measurement: BaseMeasurement,
        n: int = 20000,
        seed: int = 0,
        cache_path: str | None = None,
    ) -> "SampleDataset":
        rng = np.random.default_rng(seed)
        idx = space.sample_indices(rng, n)
        if cache_path is not None and os.path.exists(cache_path):
            ds = cls.load(space, cache_path)
            # the cache is only valid for this exact draw: same n, same
            # sample seed, same space (a changed measurement seed writes a
            # new file at the caller's discretion; a changed sample stream
            # is detected here by index equality)
            if len(ds) == n and np.array_equal(ds.indices, idx):
                return ds
        vals = measurement.measure_batch(space.decode_batch(idx))
        ds = cls(space=space, indices=idx, values=np.asarray(vals, dtype=np.float64))
        if cache_path is not None:
            ds.save(cache_path)
        return ds

    def save(self, path: str) -> None:
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        # write through a file handle so the data lands at ``path`` exactly
        # (np.savez_compressed appends '.npz' to bare string paths, which
        # would break the generate() existence check)
        with open(path, "wb") as f:
            np.savez_compressed(f, indices=self.indices, values=self.values)

    @classmethod
    def load(cls, space: SearchSpace, path: str) -> "SampleDataset":
        data = np.load(path, allow_pickle=False)
        return cls(space=space, indices=data["indices"], values=data["values"])

    def __len__(self) -> int:
        return len(self.values)

    def chunk(self, experiment: int, size: int) -> tuple[np.ndarray, np.ndarray]:
        """Disjoint chunk ``experiment`` of ``size`` samples (wraps around if
        the design over-asks, which the paper's design never does)."""
        start = (experiment * size) % len(self)
        stop = start + size
        if stop <= len(self):
            sl = slice(start, stop)
            return self.indices[sl], self.values[sl]
        first = len(self) - start
        return (
            np.concatenate([self.indices[start:], self.indices[: size - first]]),
            np.concatenate([self.values[start:], self.values[: size - first]]),
        )

    @property
    def optimum(self) -> float:
        """Best runtime observed in the dataset (used as the denominator of
        'percentage of optimum' alongside search-discovered optima)."""
        return float(self.values.min())
