"""Pre-generated sample datasets (paper section VI.B).

'For our non-SMBO approaches, we streamline the experimental sample
collection process by creating a dataset of 20 000 samples in one go for each
architecture and benchmark. We can then subdivide the samples for each sample
size and experiment.'

RS experiments draw disjoint chunks of S samples; RF experiments draw chunks
of S-10 for training.  Chunking is deterministic given the dataset seed.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .measurement import BaseMeasurement
from .space import SearchSpace


@dataclass
class SampleDataset:
    space: SearchSpace
    indices: np.ndarray   # (n, d) index vectors
    values: np.ndarray    # (n,) measured runtimes

    @classmethod
    def generate(
        cls,
        space: SearchSpace,
        measurement: BaseMeasurement,
        n: int = 20000,
        seed: int = 0,
    ) -> "SampleDataset":
        rng = np.random.default_rng(seed)
        idx = space.sample_indices(rng, n)
        vals = measurement.measure_batch(space.decode_batch(idx))
        return cls(space=space, indices=idx, values=np.asarray(vals, dtype=np.float64))

    def __len__(self) -> int:
        return len(self.values)

    def chunk(self, experiment: int, size: int) -> tuple[np.ndarray, np.ndarray]:
        """Disjoint chunk ``experiment`` of ``size`` samples (wraps around if
        the design over-asks, which the paper's design never does)."""
        start = (experiment * size) % len(self)
        stop = start + size
        if stop <= len(self):
            sl = slice(start, stop)
            return self.indices[sl], self.values[sl]
        first = len(self) - start
        return (
            np.concatenate([self.indices[start:], self.indices[: size - first]]),
            np.concatenate([self.values[start:], self.values[: size - first]]),
        )

    @property
    def optimum(self) -> float:
        """Best runtime observed in the dataset (used as the denominator of
        'percentage of optimum' alongside search-discovered optima)."""
        return float(self.values.min())
