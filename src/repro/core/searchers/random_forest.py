"""Random-Forest model-based autotuning (the paper's non-SMBO RF method).

Section VI.B: 'For model-based approaches like Random Forest (RF), we train
the models with the subset of size S-10 for each experiment and then run the
top 10 predictions. The top performing prediction is then stored as the
output.'

So with budget S: S-10 random (constrained) training samples are measured
(ONE batch through the engine), an RF regressor is fit on them, the model
ranks a large candidate pool, and the 10 best-predicted configs are actually
measured (a second batch); the best of those 10 is the result.  The
candidate pool is a constraint-valid random subsample of the space
(pool_size=16384 by default — predicting over all 2.1M configs with a
pure-python forest would only change which near-tied candidate wins; noted
as a deviation in DESIGN.md).
"""

from __future__ import annotations

import numpy as np

from ..surrogates.forest_batched import BatchedForest
from .base import ProposalGen, Searcher, TuningResult, register


@register
class RandomForestSearcher(Searcher):
    name = "rf"
    uses_constraints = True

    def __init__(
        self,
        space,
        seed: int = 0,
        n_estimators: int = 100,
        top_k: int = 10,
        pool_size: int = 16384,
    ):
        super().__init__(space, seed)
        self.n_estimators = n_estimators
        self.top_k = top_k
        self.pool_size = pool_size

    def _propose(self, budget: int, result: TuningResult) -> ProposalGen:
        top_k = min(self.top_k, max(1, budget // 2))
        n_train = budget - top_k
        train_idx = self.space.sample_indices(self.rng, n_train)
        train_vals = yield self.space.decode_batch(train_idx)

        forest = BatchedForest(
            self.space.cardinalities,
            n_estimators=self.n_estimators,
            seed=int(self.rng.integers(0, 2**31)),
        )
        forest.fit(train_idx[None], np.asarray(train_vals)[None])

        pool = self.space.sample_indices(self.rng, self.pool_size)
        preds = forest.predict(pool)[0]
        best = np.argsort(preds, kind="stable")[: top_k]
        pred_cfgs = self.space.decode_batch(pool[best])
        pred_vals = yield pred_cfgs
        # The RF result is the best of the top-k *predictions* actually run —
        # NOT the best training sample (the paper stores the top performing
        # prediction).  The engine tracked the global best including training
        # samples, so override with the prediction-only best:
        j = int(np.argmin(pred_vals))
        result.best_value = float(pred_vals[j])
        result.best_config = pred_cfgs[j]
