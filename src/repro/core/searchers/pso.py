"""Particle Swarm Optimization — beyond-paper searcher (CLTune related work).

Particles live in the continuous unit cube and are decoded to index vectors
for measurement (standard discrete-PSO relaxation).  Velocity update with
inertia w, cognitive c1, social c2 (Kernel-Tuner-like defaults)."""

from __future__ import annotations

import numpy as np

from ..measurement import BaseMeasurement
from .base import Searcher, TuningResult, register


@register
class ParticleSwarm(Searcher):
    name = "pso"
    uses_constraints = True

    def __init__(
        self,
        space,
        seed: int = 0,
        n_particles: int = 16,
        w: float = 0.7,
        c1: float = 1.6,
        c2: float = 1.6,
    ):
        super().__init__(space, seed)
        self.n_particles = n_particles
        self.w, self.c1, self.c2 = w, c1, c2

    def _search(self, measurement: BaseMeasurement, budget: int, result: TuningResult):
        n_p = min(self.n_particles, budget)
        d = self.space.n_params
        pos = self.space.to_unit(self.space.sample_indices(self.rng, n_p))
        vel = self.rng.uniform(-0.1, 0.1, size=(n_p, d))

        def measure_pos(p: np.ndarray) -> float:
            cfg = self.space.decode(self.space.from_unit(p))
            return self._observe(measurement, cfg, result)

        pbest, pbest_v = pos.copy(), np.array([measure_pos(p) for p in pos])
        g = int(np.argmin(pbest_v))
        gbest, gbest_v = pbest[g].copy(), pbest_v[g]
        remaining = budget - n_p

        while remaining > 0:
            for i in range(n_p):
                if remaining <= 0:
                    break
                r1, r2 = self.rng.random(d), self.rng.random(d)
                vel[i] = (
                    self.w * vel[i]
                    + self.c1 * r1 * (pbest[i] - pos[i])
                    + self.c2 * r2 * (gbest - pos[i])
                )
                pos[i] = np.clip(pos[i] + vel[i], 0.0, 1.0)
                v = measure_pos(pos[i])
                remaining -= 1
                if v < pbest_v[i]:
                    pbest[i], pbest_v[i] = pos[i].copy(), v
                    if v < gbest_v:
                        gbest, gbest_v = pos[i].copy(), v
