"""Particle Swarm Optimization — beyond-paper searcher (CLTune related work).

Particles live in the continuous unit cube and are decoded to index vectors
for measurement (standard discrete-PSO relaxation).  Velocity update with
inertia w, cognitive c1, social c2 (Kernel-Tuner-like defaults).

Synchronous PSO under the ask/tell engine: every iteration moves the whole
swarm using the previous iteration's personal/global bests, then proposes
all particle positions as ONE batch (the textbook synchronous variant —
per-particle gbest updates would serialize the swarm)."""

from __future__ import annotations

import numpy as np

from .base import ProposalGen, Searcher, TuningResult, register


@register
class ParticleSwarm(Searcher):
    name = "pso"
    uses_constraints = True

    def __init__(
        self,
        space,
        seed: int = 0,
        n_particles: int = 16,
        w: float = 0.7,
        c1: float = 1.6,
        c2: float = 1.6,
    ):
        super().__init__(space, seed)
        self.n_particles = n_particles
        self.w, self.c1, self.c2 = w, c1, c2

    def _propose(self, budget: int, result: TuningResult) -> ProposalGen:
        n_p = min(self.n_particles, budget)
        d = self.space.n_params
        pos = self.space.to_unit(self.space.sample_indices(self.rng, n_p))
        vel = self.rng.uniform(-0.1, 0.1, size=(n_p, d))

        def repair(p: np.ndarray) -> np.ndarray:
            """Re-seed constraint-violating particles at valid random
            positions (the swarm must only propose measurable configs)."""
            bad = ~self.space.valid_mask(self.space.from_unit(p))
            if bad.any():
                p = p.copy()
                p[bad] = self.space.to_unit(
                    self.space.sample_indices(self.rng, int(bad.sum()))
                )
            return p

        def decode_all(p: np.ndarray) -> list:
            return self.space.decode_batch(self.space.from_unit(p))

        pbest_v = yield decode_all(pos)
        pbest = pos.copy()
        g = int(np.argmin(pbest_v))
        gbest, gbest_v = pbest[g].copy(), pbest_v[g]

        while True:
            r1 = self.rng.random((n_p, d))
            r2 = self.rng.random((n_p, d))
            vel = (
                self.w * vel
                + self.c1 * r1 * (pbest - pos)
                + self.c2 * r2 * (gbest[None, :] - pos)
            )
            pos = repair(np.clip(pos + vel, 0.0, 1.0))
            vals = yield decode_all(pos)
            improved = vals < pbest_v
            pbest[improved] = pos[improved]
            pbest_v = np.where(improved, vals, pbest_v)
            g = int(np.argmin(pbest_v))
            if pbest_v[g] < gbest_v:
                gbest, gbest_v = pbest[g].copy(), pbest_v[g]
