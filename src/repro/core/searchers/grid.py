"""Grid search — included for completeness (Bergstra & Bengio 2012 showed RS
beats it; our harness lets that claim be re-verified).  With budget < |S| it
measures an evenly-strided subset of the enumeration order."""

from __future__ import annotations

import numpy as np

from ..measurement import BaseMeasurement
from .base import Searcher, TuningResult, register


@register
class GridSearch(Searcher):
    name = "grid"
    uses_constraints = True

    def _search(self, measurement: BaseMeasurement, budget: int, result: TuningResult):
        total = self.space.cardinality
        stride = max(1, total // budget)
        cards = self.space.cardinalities
        taken = 0
        for flat in range(0, total, stride):
            if taken >= budget:
                break
            idx = np.zeros(len(cards), dtype=np.int64)
            rem = flat
            for j in range(len(cards) - 1, -1, -1):
                idx[j] = rem % cards[j]
                rem //= cards[j]
            cfg = self.space.decode(idx)
            if not self.space.is_valid(cfg):
                continue
            self._observe(measurement, cfg, result)
            taken += 1
