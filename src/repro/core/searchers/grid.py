"""Grid search — included for completeness (Bergstra & Bengio 2012 showed RS
beats it; our harness lets that claim be re-verified).  With budget < |S| it
measures an evenly-strided subset of the enumeration order, proposed as ONE
vectorized batch.  Constraint-invalid strided points are replaced by
continuing the strided enumeration at the next offset, so grid consumes its
exact budget whenever the space holds enough valid configs."""

from __future__ import annotations

import numpy as np

from .base import ProposalGen, Searcher, TuningResult, register


@register
class GridSearch(Searcher):
    name = "grid"
    uses_constraints = True

    def _propose(self, budget: int, result: TuningResult) -> ProposalGen:
        total = self.space.cardinality
        cards = self.space.cardinalities
        stride = max(1, total // budget)
        batch: list = []
        for offset in range(stride):
            flats = np.arange(offset, total, stride, dtype=np.int64)
            idxs = np.stack(
                np.unravel_index(flats, tuple(cards)), axis=1
            ).astype(np.int64)
            valid = self.space.valid_mask(idxs)
            batch.extend(self.space.decode_batch(idxs[valid]))
            if len(batch) >= budget:
                break
        yield batch[:budget]
