"""Bayesian Optimization with Tree-Parzen Estimators (Bergstra et al. 2011).

The paper uses the HyperOpt library.  HyperOpt is unavailable here, so this
is a from-scratch TPE over the integer/categorical index space:

* the first ``n_startup`` samples (HyperOpt default: 20) are random,
* observations are split into 'good' l(x) and 'bad' g(x) groups with
  HyperOpt's rule  n_good = min(ceil(gamma * sqrt(n)), 25), gamma = 0.25
  (a linear quantile would make l(x) far too broad at large sample sizes
  and visibly degrades TPE beyond S=200),
* each parameter dimension is modeled with a smoothed Parzen histogram over
  its index values (uniform prior weight + triangular [0.25, 0.5, 0.25]
  neighbor smoothing for ordered ints — the discrete analogue of HyperOpt's
  gaussian-smoothed quantized-uniform),
* ``n_ei_candidates`` (24) draws from l(x) are scored by l(x)/g(x); the
  argmax is measured.

Like the paper, TPE gets no constraint specification (section V.C).
"""

from __future__ import annotations

import numpy as np

from .base import ProposalGen, Searcher, TuningResult, register


def _parzen_pmf(
    indices: np.ndarray, cardinality: int, prior_weight: float = 1.0
) -> np.ndarray:
    """Smoothed pmf over [0..cardinality): prior + kernel-smoothed counts."""
    counts = np.bincount(indices, minlength=cardinality).astype(np.float64)
    # triangular smoothing over neighbors (ordered-integer kernel)
    smoothed = counts * 0.5
    smoothed[1:] += counts[:-1] * 0.25
    smoothed[:-1] += counts[1:] * 0.25
    # reflect mass lost at the edges back in so sum(counts) is preserved
    smoothed[0] += counts[0] * 0.25
    smoothed[-1] += counts[-1] * 0.25
    pmf = smoothed + prior_weight / cardinality
    return pmf / pmf.sum()


@register
class BOTPESearcher(Searcher):
    name = "bo_tpe"
    uses_constraints = False

    def __init__(
        self,
        space,
        seed: int = 0,
        n_startup: int = 20,
        gamma: float = 0.25,
        n_ei_candidates: int = 24,
        prior_weight: float = 1.0,
    ):
        super().__init__(space, seed)
        self.n_startup = n_startup
        self.gamma = gamma
        self.n_ei_candidates = n_ei_candidates
        self.prior_weight = prior_weight

    def _propose(self, budget: int, result: TuningResult) -> ProposalGen:
        n_startup = min(self.n_startup, budget)
        init = self.space.sample_indices(self.rng, n_startup)
        init_vals = yield self.space.decode_batch(init)

        X = [np.asarray(r) for r in init]
        y = [float(v) for v in init_vals]

        for _ in range(budget - n_startup):
            Xa = np.stack(X)
            ya = np.asarray(y)
            n_good = max(1, min(int(np.ceil(self.gamma * np.sqrt(len(ya)))), 25))
            order = np.argsort(ya, kind="stable")
            good, bad = Xa[order[:n_good]], Xa[order[n_good:]]
            if len(bad) == 0:  # degenerate early case
                bad = Xa

            # per-dimension Parzen pmfs
            l_pmfs, g_pmfs = [], []
            for d, card in enumerate(self.space.cardinalities):
                l_pmfs.append(_parzen_pmf(good[:, d], card, self.prior_weight))
                g_pmfs.append(_parzen_pmf(bad[:, d], card, self.prior_weight))

            # sample candidates from l(x), score by l/g
            n_c = self.n_ei_candidates
            cand = np.stack(
                [
                    self.rng.choice(len(pmf), size=n_c, p=pmf)
                    for pmf in l_pmfs
                ],
                axis=1,
            ).astype(np.int64)
            log_ratio = np.zeros(n_c)
            for d in range(self.space.n_params):
                log_ratio += np.log(l_pmfs[d][cand[:, d]]) - np.log(
                    g_pmfs[d][cand[:, d]]
                )
            pick = cand[int(np.argmax(log_ratio))]
            v = float((yield [self.space.decode(pick)])[0])
            X.append(pick)
            y.append(v)
