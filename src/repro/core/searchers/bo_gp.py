"""Bayesian Optimization with a Gaussian-Process surrogate.

The paper uses scikit-optimize's ``gp_minimize`` with Expected Improvement;
'Initialization uses 8% of the samples, and the remaining 92% are used as
prediction samples in the search.'  SMBO methods do NOT receive the
constraint specification (section V.C).

Per step: fit the GP on all observations (unit-cube inputs), score a
candidate set (fresh random configs + perturbations of the incumbent) by EI,
measure the argmax.
"""

from __future__ import annotations

import numpy as np

from ..surrogates.gp import GaussianProcess, expected_improvement
from .base import ProposalGen, Searcher, TuningResult, register


@register
class BOGPSearcher(Searcher):
    name = "bo_gp"
    uses_constraints = False  # paper: no constraint support in SMBO searches

    def __init__(
        self,
        space,
        seed: int = 0,
        init_frac: float = 0.08,
        n_candidates: int = 1024,
        n_local: int = 256,
    ):
        super().__init__(space, seed)
        self.init_frac = init_frac
        self.n_candidates = n_candidates
        self.n_local = n_local

    @staticmethod
    def _gp_value(v: float, y_finite: list) -> float:
        """Observation as the GP sees it: non-finite penalties (invalid
        configs from a real-measurement backend) become a large FINITE value
        — strictly worse than any *finite* observation so far — so
        standardization and the Cholesky stay defined while the surrogate
        still learns to avoid the region (kernel_tuner does the same with
        its failure value).  The cap derives from finite observations only:
        deriving it from previous penalties would compound exponentially.
        """
        v = float(v)
        if np.isfinite(v):
            return v
        if y_finite:
            m = max(y_finite)
            return m + abs(m) + 1.0
        return 1.0

    def _candidates(self, incumbent: np.ndarray, n: int) -> np.ndarray:
        """Random + incumbent-local candidate pool.

        The pool shrinks as the GP grows (posterior-variance evaluation is
        O(n^2) per candidate), keeping per-step cost roughly constant.
        """
        n_rand = int(np.clip(self.n_candidates * 64 // max(n, 64), 256, self.n_candidates))
        n_loc = int(np.clip(self.n_local * 64 // max(n, 64), 64, self.n_local))
        rand = self.space.sample_indices(self.rng, n_rand)
        local = self.space.mutate_batch(self.rng, incumbent, 0.3, n_loc)
        return np.concatenate([rand, local])

    def _propose(self, budget: int, result: TuningResult) -> ProposalGen:
        n_init = max(1, min(budget, int(round(self.init_frac * budget))))
        init_idx = self.space.sample_indices(self.rng, n_init)
        init_vals = yield self.space.decode_batch(init_idx)

        X = list(init_idx)
        X_unit: list[np.ndarray] = []
        y: list[float] = []          # as the GP sees them (penalties clipped)
        y_fin: list[float] = []      # finite observations only (clip basis)
        pen_idx: list[int] = []      # positions in y holding clipped penalties
        gp = GaussianProcess()

        def observe(row: np.ndarray, raw: float) -> None:
            """Feed one observation to the GP, keeping every stored penalty
            strictly worse than every finite observation: when the finite
            max overtakes the current clip value, old penalties are
            re-clipped and the GP batch-refit (rare — the max only grows
            O(log n) times), so argmin/EI can never chase an invalid
            config."""
            raw = float(raw)
            if np.isfinite(raw):
                y_fin.append(raw)
            else:
                pen_idx.append(len(y))
            u = self.space.to_unit(row[None, :])[0]
            X_unit.append(u)
            y.append(self._gp_value(raw, y_fin))
            clip = self._gp_value(float("inf"), y_fin)
            if pen_idx and any(y[i] != clip for i in pen_idx):
                for i in pen_idx:
                    y[i] = clip
                gp.fit(np.stack(X_unit), np.asarray(y))
            else:
                gp.add(u, y[-1])

        for r, v in zip(init_idx, init_vals, strict=True):
            observe(r, v)
        seen_keys = self.space.flat_keys(init_idx).tolist()

        for _ in range(budget - n_init):
            inc = X[int(np.argmin(y))]
            cand = self._candidates(np.asarray(inc), gp.n)
            # drop already-measured configs (re-measuring wastes budget)
            fresh = cand[~np.isin(self.space.flat_keys(cand), seen_keys)]
            if len(fresh) == 0:
                fresh = self.space.sample_indices(self.rng, 256)
            mu, sigma = gp.predict(self.space.to_unit(fresh))
            ei = expected_improvement(mu, sigma, best=float(np.min(y)))
            pick = fresh[int(np.argmax(ei))]
            raw = float((yield [self.space.decode(pick)])[0])
            X.append(pick)
            observe(pick, raw)
            seen_keys.append(int(self.space.flat_keys(pick[None, :])[0]))
