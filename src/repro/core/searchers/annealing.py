"""Simulated Annealing — beyond-paper searcher (CLTune / related work III).

Geometric cooling over +-1 neighborhood moves in index space; acceptance by
the Metropolis criterion on the (noisy) runtime.  Included so the CLTune-era
claim 'SA outperforms RS' can be re-examined inside the same harness
(the paper lists SA/PSO as related work it did not compare).

SA is inherently sequential — each move depends on the previous acceptance —
so its ask/tell proposals are single-config batches."""

from __future__ import annotations

import numpy as np

from .base import ProposalGen, Searcher, TuningResult, register


@register
class SimulatedAnnealing(Searcher):
    name = "sa"
    uses_constraints = True

    def __init__(self, space, seed: int = 0, t0: float = 1.0, t1: float = 1e-3):
        super().__init__(space, seed)
        self.t0 = t0
        self.t1 = t1

    def _propose(self, budget: int, result: TuningResult) -> ProposalGen:
        cur = self.space.sample_indices(self.rng, 1)[0]
        cur_v = float((yield [self.space.decode(cur)])[0])
        scale = abs(cur_v) if np.isfinite(cur_v) and cur_v else 1.0
        for step in range(budget - 1):
            frac = step / max(1, budget - 2)
            temp = self.t0 * (self.t1 / self.t0) ** frac
            for _ in range(100):
                nxt = self.space.neighbor(self.rng, cur)
                if self.space.is_valid(self.space.decode(nxt)):
                    break
            nxt_v = float((yield [self.space.decode(nxt)])[0])
            # non-finite values (invalid-config penalties from real
            # measurement backends) short-circuit the Metropolis rule:
            # inf - inf is NaN, which would wedge the walk on an invalid
            # start forever
            if not np.isfinite(nxt_v):
                continue
            if not np.isfinite(cur_v):
                cur, cur_v = nxt, nxt_v
                scale = abs(cur_v) or 1.0
                continue
            delta = (nxt_v - cur_v) / scale
            if delta <= 0 or self.rng.random() < np.exp(-delta / max(temp, 1e-12)):
                cur, cur_v = nxt, nxt_v
