"""Genetic Algorithm, following van Werkhoven's Kernel Tuner implementation
(the paper: 'we based our Genetic Algorithm implementation on the
implementation that van Werkhoven used in their study').

Kernel Tuner's GA (kernel_tuner/strategies/genetic_algorithm.py):
  * population size 20, generations = budget / popsize,
  * selection: population sorted by fitness, the better half survives,
  * crossover: "single_point" / uniform mix of two parents — we use the
    paper's description: half the variables from parent A, half from B,
  * mutation: each gene mutates with low probability (10%).

Each generation is proposed as ONE batch through the ask/tell engine.
Re-visited chromosomes consume no extra budget (their previous observation
is reused), matching tuners that memoize; the engine trims the final batch
so the search stops precisely at the sample budget.

Late in a run the population converges and most offspring are revisits, so
the post-dedup proposal batches shrink (~3x smaller than the population on
the paper space).  With ``refill=True`` (default) the GA speculatively
breeds extra offspring until the batch holds a full population's worth of
*unseen* chromosomes (bounded attempts — a fully converged population stops
early), keeping batched dispatch efficient without changing the budget
accounting.  The post-evaluation population is truncated back to
``pop_size`` best, so selection pressure is unchanged.
"""

from __future__ import annotations

import numpy as np

from .base import ProposalGen, Searcher, TuningResult, register


@register
class GeneticAlgorithm(Searcher):
    name = "ga"
    uses_constraints = True

    def __init__(
        self,
        space,
        seed: int = 0,
        pop_size: int = 20,
        p_mut: float = 0.1,
        refill: bool = True,
    ):
        super().__init__(space, seed)
        self.pop_size = pop_size
        self.p_mut = p_mut
        self.refill = refill

    def _crossover(self, a: np.ndarray, b: np.ndarray) -> np.ndarray:
        """Half the variables from A, the other half from B (paper III.B.2)."""
        d = len(a)
        take_a = np.zeros(d, dtype=bool)
        take_a[self.rng.permutation(d)[: d // 2 + d % 2]] = True
        return np.where(take_a, a, b)

    def _evaluate(self, idxs: np.ndarray, seen: dict):
        """Sub-generator: yield only unseen rows as one batch; return the
        fitness of every row (revisits served from ``seen`` for free)."""
        keys = [tuple(int(v) for v in row) for row in idxs]
        fresh_keys: list = []
        fresh_rows: list = []
        for key, row in zip(keys, idxs, strict=True):
            if key not in seen and key not in fresh_keys:
                fresh_keys.append(key)
                fresh_rows.append(row)
        if fresh_rows:
            vals = yield self.space.decode_batch(np.array(fresh_rows))
            seen.update(zip(fresh_keys, (float(v) for v in vals), strict=True))
        # a trimmed final batch leaves some keys unmeasured; the engine never
        # resumes the generator in that case, so every key is present here.
        return np.array([seen[k] for k in keys], dtype=np.float64)

    def _propose(self, budget: int, result: TuningResult) -> ProposalGen:
        pop_n = min(self.pop_size, budget)
        seen: dict[tuple, float] = {}

        population = self.space.sample_indices(self.rng, pop_n)
        fitness = yield from self._evaluate(population, seen)

        stale = 0  # generations that measured nothing new
        while len(population) >= 2:
            order = np.argsort(fitness)
            n_keep = max(2, len(population) // 2)
            survivors = population[order[:n_keep]]
            target = pop_n - n_keep
            children: list = []
            fresh_keys: set = set()
            attempts = 0
            # base quota: `target` offspring, revisits included.  refill:
            # keep breeding speculative extras until `target` of them are
            # actually UNSEEN (a full post-dedup batch), bounded so a
            # converged population can't spin forever.
            max_attempts = 200 if not self.refill else max(200, 40 * target)
            while attempts < max_attempts and (
                len(children) < target
                or (self.refill and len(fresh_keys) < target)
            ):
                attempts += 1
                i, j = self.rng.choice(n_keep, size=2, replace=False)
                child = self._crossover(survivors[i], survivors[j])
                child = self.space.mutate(self.rng, child, self.p_mut)
                if not self.space.is_valid(self.space.decode(child)):
                    continue
                children.append(child)
                key = tuple(int(v) for v in child)
                if key not in seen:
                    fresh_keys.add(key)
            if not children:
                break
            child_idx = np.array(children)
            n_seen = len(seen)
            child_fit = yield from self._evaluate(child_idx, seen)
            # a small (or fully explored) space can leave every breedable
            # child a revisit: without a yield the generator would spin
            # forever while the engine waits for proposals.  Stop when the
            # space is provably exhausted, or after many consecutive
            # all-revisit generations (a converged population on a large
            # space recovers within a couple via mutation — 50 without a
            # single fresh config means there is nothing left to measure).
            if len(seen) >= self.space.cardinality:
                break
            stale = stale + 1 if len(seen) == n_seen else 0
            if stale >= 50:
                break
            population = np.concatenate([survivors, child_idx])
            fitness = np.concatenate([fitness[order[:n_keep]], child_fit])
            if len(population) > pop_n:
                # speculative extras joined the generation; truncate back to
                # the configured population size (best-first, stable)
                sel = np.argsort(fitness, kind="stable")[:pop_n]
                population, fitness = population[sel], fitness[sel]
