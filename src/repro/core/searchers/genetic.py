"""Genetic Algorithm, following van Werkhoven's Kernel Tuner implementation
(the paper: 'we based our Genetic Algorithm implementation on the
implementation that van Werkhoven used in their study').

Kernel Tuner's GA (kernel_tuner/strategies/genetic_algorithm.py):
  * population size 20, generations = budget / popsize,
  * selection: population sorted by fitness, the better half survives,
  * crossover: "single_point" / uniform mix of two parents — we use the
    paper's description: half the variables from parent A, half from B,
  * mutation: each gene mutates with low probability (10%).

Re-visited chromosomes consume no extra budget when the measurement is
cached, matching tuners that memoize; to be budget-exact we only evaluate
*unseen* individuals and stop precisely at the sample budget.
"""

from __future__ import annotations

import numpy as np

from ..measurement import BaseMeasurement
from ..space import Config
from .base import Searcher, TuningResult, register


@register
class GeneticAlgorithm(Searcher):
    name = "ga"
    uses_constraints = True

    def __init__(self, space, seed: int = 0, pop_size: int = 20, p_mut: float = 0.1):
        super().__init__(space, seed)
        self.pop_size = pop_size
        self.p_mut = p_mut

    def _crossover(self, a: np.ndarray, b: np.ndarray) -> np.ndarray:
        """Half the variables from A, the other half from B (paper III.B.2)."""
        d = len(a)
        take_a = np.zeros(d, dtype=bool)
        take_a[self.rng.permutation(d)[: d // 2 + d % 2]] = True
        return np.where(take_a, a, b)

    def _search(self, measurement: BaseMeasurement, budget: int, result: TuningResult):
        pop_n = min(self.pop_size, budget)
        seen: dict[tuple, float] = {}

        def evaluate(idxs: np.ndarray, remaining: int) -> tuple[np.ndarray, np.ndarray, int]:
            """Measure unseen rows up to the remaining budget."""
            vals = np.full(len(idxs), np.inf)
            for i, row in enumerate(idxs):
                key = tuple(int(v) for v in row)
                if key in seen:
                    vals[i] = seen[key]  # re-visit: previous observation, free
                    continue
                if remaining <= 0:
                    continue
                vals[i] = self._observe(measurement, self.space.decode(row), result)
                seen[key] = vals[i]
                remaining -= 1
            keep = np.isfinite(vals)
            return idxs[keep], vals[keep], remaining

        population = self.space.sample_indices(self.rng, pop_n)
        population, fitness, remaining = evaluate(population, budget)

        while remaining > 0 and len(population) >= 2:
            order = np.argsort(fitness)
            n_keep = max(2, len(population) // 2)
            survivors = population[order[:n_keep]]
            children = []
            attempts = 0
            while len(children) < pop_n - n_keep and attempts < 200:
                attempts += 1
                i, j = self.rng.choice(n_keep, size=2, replace=False)
                child = self._crossover(survivors[i], survivors[j])
                child = self.space.mutate(self.rng, child, self.p_mut)
                if not self.space.is_valid(self.space.decode(child)):
                    continue
                children.append(child)
            if not children:
                break
            child_idx, child_fit, remaining = evaluate(np.array(children), remaining)
            population = np.concatenate([survivors, child_idx])
            fitness = np.concatenate([fitness[order[:n_keep]], child_fit])
