from .annealing import SimulatedAnnealing
from .base import SEARCHERS, Searcher, TuningResult, make_searcher, register
from .bo_gp import BOGPSearcher
from .bo_tpe import BOTPESearcher
from .genetic import GeneticAlgorithm
from .grid import GridSearch
from .pso import ParticleSwarm
from .random_forest import RandomForestSearcher
from .random_search import RandomSearch

PAPER_ALGORITHMS = ("rs", "rf", "ga", "bo_gp", "bo_tpe")
EXTRA_ALGORITHMS = ("sa", "pso", "grid")

__all__ = [
    "SEARCHERS",
    "Searcher",
    "TuningResult",
    "make_searcher",
    "register",
    "RandomSearch",
    "RandomForestSearcher",
    "GeneticAlgorithm",
    "BOGPSearcher",
    "BOTPESearcher",
    "SimulatedAnnealing",
    "ParticleSwarm",
    "GridSearch",
    "PAPER_ALGORITHMS",
    "EXTRA_ALGORITHMS",
]
