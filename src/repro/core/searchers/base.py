"""Searcher interface + registry.

A searcher minimizes a (noisy) measurement over a :class:`SearchSpace` with a
fixed *sample budget* — the paper's central experimental axis.  ``run``
returns a :class:`TuningResult` containing the best configuration the
searcher chose, the value observed for it during the search, and the full
sample history (used by the statistics layer and the benchmark figures).
"""

from __future__ import annotations

from abc import ABC, abstractmethod
from dataclasses import dataclass, field

import numpy as np

from ..measurement import BaseMeasurement
from ..space import Config, SearchSpace


@dataclass
class TuningResult:
    algo: str
    best_config: Config
    best_value: float               # value observed during search
    final_value: float | None = None  # median of 10 re-measurements (runner fills)
    history_configs: list = field(default_factory=list)
    history_values: list = field(default_factory=list)
    n_samples: int = 0

    def trajectory(self) -> np.ndarray:
        """Best-so-far curve over the sample history."""
        return np.minimum.accumulate(np.asarray(self.history_values, dtype=np.float64))


class Searcher(ABC):
    """Budgeted minimizer.  Subclasses set ``name`` and implement ``_search``."""

    name: str = "base"
    #: whether this searcher receives the constrained space (paper: SMBO
    #: methods could not use constraint specification).
    uses_constraints: bool = True

    def __init__(self, space: SearchSpace, seed: int = 0, **kwargs):
        self.space = space if self.uses_constraints else space.unconstrained()
        self.seed = seed
        self.rng = np.random.default_rng(seed)

    def run(self, measurement: BaseMeasurement, budget: int) -> TuningResult:
        if budget < 1:
            raise ValueError("budget must be >= 1")
        result = TuningResult(algo=self.name, best_config={}, best_value=np.inf)
        self._search(measurement, budget, result)
        result.n_samples = len(result.history_values)
        if result.n_samples > budget:
            raise RuntimeError(
                f"{self.name} exceeded budget: {result.n_samples} > {budget}"
            )
        return result

    # -- helpers for subclasses ----------------------------------------------
    def _observe(
        self, measurement: BaseMeasurement, config: Config, result: TuningResult
    ) -> float:
        v = measurement.measure(config)
        result.history_configs.append(config)
        result.history_values.append(v)
        if v < result.best_value:
            result.best_value = v
            result.best_config = config
        return v

    def _observe_batch(
        self, measurement: BaseMeasurement, configs: list[Config], result: TuningResult
    ) -> np.ndarray:
        vals = measurement.measure_batch(configs)
        for c, v in zip(configs, vals):
            result.history_configs.append(c)
            result.history_values.append(float(v))
            if v < result.best_value:
                result.best_value = float(v)
                result.best_config = c
        return vals

    @abstractmethod
    def _search(
        self, measurement: BaseMeasurement, budget: int, result: TuningResult
    ) -> None: ...


SEARCHERS: dict[str, type[Searcher]] = {}


def register(cls: type[Searcher]) -> type[Searcher]:
    SEARCHERS[cls.name] = cls
    return cls


def make_searcher(name: str, space: SearchSpace, seed: int = 0, **kw) -> Searcher:
    if name not in SEARCHERS:
        raise KeyError(f"unknown searcher {name!r}; have {sorted(SEARCHERS)}")
    return SEARCHERS[name](space, seed=seed, **kw)
