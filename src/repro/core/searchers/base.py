"""Searcher interface + registry: the batched ask/tell evaluation protocol.

A searcher minimizes a (noisy) measurement over a :class:`SearchSpace` with a
fixed *sample budget* — the paper's central experimental axis.  Searchers are
written as *proposal generators* (:meth:`Searcher._propose`): they yield
batches of configurations and receive the measured values back, so one
algorithm definition serves three consumers:

* the **ask/tell protocol** — ``start(budget)``, ``ask(n) -> list[Config]``,
  ``tell(configs, values)``, ``finish() -> TuningResult`` — for callers that
  own the evaluation loop (distributed/sharded matrix runs),
* the **batched driver** ``run(measurement, budget)`` which routes every
  proposal batch through ``BaseMeasurement.measure_batch`` (one Python-level
  dispatch per batch on vectorized backends),
* the **sequential driver** ``run(..., dispatch="one")`` which measures one
  config at a time — same proposals, same history, used for parity audits.

``run`` returns a :class:`TuningResult` containing the best configuration the
searcher chose, the value observed for it during the search, and the full
sample history (used by the statistics layer and the benchmark figures).
"""

from __future__ import annotations

from abc import ABC, abstractmethod
from dataclasses import dataclass, field
from typing import Generator, Sequence

import numpy as np

from ..measurement import BaseMeasurement
from ..space import Config, SearchSpace

#: type of the proposal generators: yields batches of configs, receives the
#: corresponding measured values (np.ndarray) via ``send``.
ProposalGen = Generator[list, np.ndarray, None]


@dataclass
class TuningResult:
    algo: str
    best_config: Config
    best_value: float               # value observed during search
    final_value: float | None = None  # median of 10 re-measurements (runner fills)
    history_configs: list = field(default_factory=list)
    history_values: list = field(default_factory=list)
    n_samples: int = 0

    def trajectory(self, budget: int | None = None) -> np.ndarray:
        """Best-so-far curve over the sample history.

        THE budget-clipping convention lives here, once (the analysis layer's
        budget-resolved statistics call this method instead of re-deriving
        curves — see ``repro.analysis.stats.best_at_budget``):

        * ``budget=None`` returns the raw curve, length ``len(history_values)``.
        * With ``budget``, the returned curve has length **exactly** ``budget``.
          A search that ended early — exhausted space, the GA all-revisit
          livelock break — holds its final best for the remaining samples
          (right-padding with ``curve[-1]``): spending budget a terminated
          search cannot use changes nothing, so best-at-budget is well defined
          past the end of the history.
        * A history *longer* than ``budget`` is a caller error (the engine's
          ``finish()`` already enforces ``n_samples <= budget``) and raises.
        """
        if not self.history_values:
            raise ValueError(
                "TuningResult has an empty sample history — no trajectory. "
                "Was the search run (finish() before any tell())?"
            )
        curve = np.minimum.accumulate(
            np.asarray(self.history_values, dtype=np.float64)
        )
        if budget is None:
            return curve
        if budget < 1:
            raise ValueError("budget must be >= 1")
        if len(curve) > budget:
            raise ValueError(
                f"history has {len(curve)} samples > budget {budget}: "
                "trajectories never clip — pass the budget the search ran with"
            )
        if len(curve) < budget:
            curve = np.concatenate(
                [curve, np.full(budget - len(curve), curve[-1])]
            )
        return curve


class Searcher(ABC):
    """Budgeted minimizer.  Subclasses set ``name`` and implement ``_propose``."""

    name: str = "base"
    #: whether this searcher receives the constrained space (paper: SMBO
    #: methods could not use constraint specification).
    uses_constraints: bool = True

    def __init__(self, space: SearchSpace, seed: int = 0, **kwargs):
        self.space = space if self.uses_constraints else space.unconstrained()
        self.seed = seed
        self.rng = np.random.default_rng(seed)
        self._session = None

    # -- ask/tell protocol ----------------------------------------------------
    def start(self, budget: int) -> TuningResult:
        """Begin an ask/tell session; returns the live (mutating) result."""
        if budget < 1:
            raise ValueError("budget must be >= 1")
        result = TuningResult(algo=self.name, best_config={}, best_value=np.inf)
        self._session = _Session(
            budget=budget,
            remaining=budget,
            result=result,
            gen=self._propose(budget, result),
        )
        self._pull_next_batch()
        return result

    def ask(self, n: int | None = None) -> list:
        """Up to ``n`` configs to evaluate next (all pending ones if None).

        Returns ``[]`` when the search is finished.  The returned configs
        must be answered with :meth:`tell` before the next :meth:`ask`.
        """
        s = self._require_session()
        if s.outstanding:
            raise RuntimeError("tell() the previous ask() before asking again")
        if s.done:
            return []
        k = len(s.queue) if n is None else max(0, min(int(n), len(s.queue)))
        out, s.queue = s.queue[:k], s.queue[k:]
        s.outstanding = list(out)
        return list(out)

    def tell(self, configs: Sequence[Config], values) -> None:
        """Report measured ``values`` for the configs of the last ask()."""
        s = self._require_session()
        values = np.asarray(values, dtype=np.float64).reshape(-1)
        configs = list(configs)
        if not configs:
            raise ValueError("tell() with no configs (ask() returned empty?)")
        if len(configs) != len(values):
            raise ValueError(f"{len(configs)} configs vs {len(values)} values")
        if configs != s.outstanding:
            raise ValueError("tell() configs must match the last ask() exactly")
        r = s.result
        for c, v in zip(configs, values, strict=True):
            r.history_configs.append(c)
            r.history_values.append(float(v))
            if v < r.best_value:
                r.best_value = float(v)
                r.best_config = c
        s.remaining -= len(configs)
        s.batch_values.extend(float(v) for v in values)
        s.outstanding = []
        if s.queue:
            return                      # current proposal batch not fully asked yet
        if s.batch_trimmed:
            s.done = True               # generator expected more slots than budget
            s.gen.close()
            return
        self._pull_next_batch(np.asarray(s.batch_values, dtype=np.float64))

    @property
    def done(self) -> bool:
        s = self._require_session()
        return s.done and not s.queue and not s.outstanding

    def finish(self) -> TuningResult:
        """End the session and return the (budget-audited) result.

        The pure ask/tell path never re-measures the winner, so
        ``final_value`` is always ``None`` here; drivers that apply the
        paper's 10x final re-measurement (``repro.tune``, the matrix
        session) fill it afterwards.
        """
        s = self._require_session()
        result = s.result
        result.final_value = None
        result.n_samples = len(result.history_values)
        if result.n_samples > s.budget:
            raise RuntimeError(
                f"{self.name} exceeded budget: {result.n_samples} > {s.budget}"
            )
        self._session = None
        return result

    # -- drivers --------------------------------------------------------------
    def run(
        self,
        measurement: BaseMeasurement,
        budget: int,
        dispatch: str = "batch",
        telemetry=None,
    ) -> TuningResult:
        """Drive a full search: ``dispatch="batch"`` routes each proposal
        batch through ``measurement.measure_batch`` (the hot path);
        ``dispatch="one"`` measures sequentially (identical history).

        .. deprecated::
            ``run`` is kept as a thin shim over the engine loop; new code
            should go through the declarative facade —
            ``repro.tune(TuningSpec(...))`` — which owns measurement
            construction, caching, and the final re-measurement.
        """
        from ..engine import drive   # local import: engine depends on this module

        return drive(self, measurement, budget, dispatch=dispatch,
                     telemetry=telemetry)

    # -- internals ------------------------------------------------------------
    def _require_session(self) -> "_Session":
        if self._session is None:
            raise RuntimeError("no active session; call start(budget) first")
        return self._session

    def _pull_next_batch(self, values: np.ndarray | None = None) -> None:
        s = self._require_session()
        if s.remaining <= 0:
            # resume once more so the generator can finalize (e.g. RF picks
            # its best *prediction*); any further proposals are discarded.
            try:
                if values is not None:
                    s.gen.send(values)
            except StopIteration:
                pass
            s.gen.close()
            s.done = True
            return
        try:
            batch = s.gen.send(values) if values is not None else next(s.gen)
        except StopIteration:
            s.done = True
            return
        batch = list(batch)
        if not batch:
            s.done = True
            s.gen.close()
            return
        s.batch_trimmed = len(batch) > s.remaining
        s.queue = batch[: s.remaining]
        s.batch_values = []

    @abstractmethod
    def _propose(self, budget: int, result: TuningResult) -> ProposalGen:
        """Yield batches of configs; receive their measured values via send().

        The engine trims a batch that would exceed the remaining budget and
        never resumes the generator afterwards, so implementations may yield
        full population-sized batches without budget arithmetic.
        """


@dataclass
class _Session:
    budget: int
    remaining: int
    result: TuningResult
    gen: ProposalGen
    queue: list = field(default_factory=list)        # proposed, not yet asked
    outstanding: list = field(default_factory=list)  # asked, awaiting tell
    batch_values: list = field(default_factory=list)
    batch_trimmed: bool = False
    done: bool = False


SEARCHERS: dict[str, type[Searcher]] = {}


def register(cls: type[Searcher]) -> type[Searcher]:
    SEARCHERS[cls.name] = cls
    return cls


def make_searcher(name: str, space: SearchSpace, seed: int = 0, **kw) -> Searcher:
    if name not in SEARCHERS:
        raise KeyError(f"unknown searcher {name!r}; have {sorted(SEARCHERS)}")
    return SEARCHERS[name](space, seed=seed, **kw)
