"""Random Search — the paper's baseline.

'For the case of Random Search (RS), we simply select the minimum runtime
from the collection of S samples for the given experiment.' (section VI.B)

RS samples the *constrained* space (constraint specification is available to
non-SMBO methods).  Under the ask/tell engine the whole budget is proposed
as ONE batch — a single measurement dispatch on vectorized backends.
"""

from __future__ import annotations

from .base import ProposalGen, Searcher, TuningResult, register


@register
class RandomSearch(Searcher):
    name = "rs"
    uses_constraints = True

    def _propose(self, budget: int, result: TuningResult) -> ProposalGen:
        yield self.space.sample_batch(self.rng, budget)
