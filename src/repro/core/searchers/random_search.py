"""Random Search — the paper's baseline.

'For the case of Random Search (RS), we simply select the minimum runtime
from the collection of S samples for the given experiment.' (section VI.B)

RS samples the *constrained* space (constraint specification is available to
non-SMBO methods).
"""

from __future__ import annotations

from ..measurement import BaseMeasurement
from .base import Searcher, TuningResult, register


@register
class RandomSearch(Searcher):
    name = "rs"
    uses_constraints = True

    def _search(self, measurement: BaseMeasurement, budget: int, result: TuningResult):
        configs = self.space.sample_batch(self.rng, budget)
        self._observe_batch(measurement, configs, result)
