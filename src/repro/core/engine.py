"""Batched evaluation engine: drivers over the searcher ask/tell protocol
plus a persistent on-disk measurement cache.

The paper's experiment is a matrix of (algorithm x sample size x experiment)
cells over a >2M-point space; its cost is dominated by evaluation dispatch.
The engine separates *proposal* (searchers yield batches via ask/tell) from
*evaluation* (a measurement backend serves a whole batch in one Python-level
dispatch), and memoizes served values on disk keyed by (kernel, config) so
re-running a matrix cell never re-measures.

  drive(searcher, measurement, budget)        batched driver (the hot path)
  drive(..., dispatch="one")                  sequential driver (parity audit)
  MeasurementStore / DiskCachedMeasurement    persistent (kernel, config) cache
"""

from __future__ import annotations

import json
import os
import tempfile
from typing import Sequence

import numpy as np

from ..telemetry.null import NULL_TELEMETRY
from .measurement import BaseMeasurement
from .searchers.base import Searcher, TuningResult
from .space import Config

DISPATCH_MODES = ("batch", "one")


def drive(
    searcher: Searcher,
    measurement: BaseMeasurement,
    budget: int,
    dispatch: str = "batch",
    batch_size: int | None = None,
    telemetry=None,
) -> TuningResult:
    """Run ``searcher`` to completion against ``measurement``.

    ``dispatch="batch"`` hands each proposal batch to ``measure_batch`` in
    one call; ``dispatch="one"`` measures config-by-config.  Both consume the
    same proposals in the same order, so for a dispatch-invariant backend the
    histories are identical.  ``batch_size`` optionally caps how many configs
    are asked per iteration (e.g. to bound a remote executor's batch).
    ``telemetry`` (a :mod:`repro.telemetry` sink; default no-op) wraps each
    ask/tell iteration in a ``round`` span and counts the non-finite
    penalties told to the searcher — observability only, the loop's results
    are identical with or without it.
    """
    if dispatch not in DISPATCH_MODES:
        raise ValueError(f"dispatch must be one of {DISPATCH_MODES}")
    tel = telemetry if telemetry is not None else NULL_TELEMETRY
    searcher.start(budget)
    rnd = 0
    while True:
        configs = searcher.ask(batch_size)
        if not configs:
            break
        with tel.span("round", round=rnd, algo=searcher.name, asked=len(configs)):
            if dispatch == "batch":
                values = measurement.measure_batch(configs)
            else:
                values = np.array(
                    [measurement.measure(c) for c in configs], dtype=np.float64
                )
            searcher.tell(configs, values)
        if tel.enabled:
            bad = int(len(values) - np.count_nonzero(np.isfinite(values)))
            if bad:
                tel.inc("inf_penalties_told", bad)
        rnd += 1
    return searcher.finish()


# ---------------------------------------------------------------- disk cache


def config_key(config: Config) -> str:
    """Canonical string key for a config dict (sorted, compact)."""
    return ",".join(f"{k}={config[k]}" for k in sorted(config))


class MeasurementStore:
    """A persistent str -> float mapping backing :class:`DiskCachedMeasurement`.

    One store (one JSON file) is shared by every measurement of a matrix run;
    entries are namespaced by the wrapping measurement's ``prefix``.  Writes
    are atomic (temp file + rename) so an interrupted run never corrupts the
    cache.  ``autosave_every`` new entries trigger a flush; 0 disables
    autosave (call :meth:`save` explicitly).

    Besides values, the store carries optional string *metadata* per key —
    used by the real-measurement backend to persist WHY a config was
    penalized (``inf``), so a warm-cache run can still report failure
    reasons.  A store without metadata keeps the legacy flat-JSON file
    format; one with metadata writes ``{"__format__": 2, "values": ...,
    "meta": ...}`` (both formats load transparently).  ``inf`` itself
    round-trips through Python's JSON (``Infinity`` literal).

    A third side-channel holds serving *winners* — per-geometry best-config
    records maintained by ``repro.serving`` (format 3 adds a ``"winners"``
    mapping; a store without winners keeps writing format <= 2, so
    measurement-only stores stay byte-compatible across versions).
    """

    def __init__(self, path: str | None, autosave_every: int = 4096):
        self.path = path
        self.autosave_every = autosave_every
        self._data: dict[str, float] = {}
        self._meta: dict[str, str] = {}
        self._winners: dict[str, str] = {}
        self._dirty = 0
        if path is not None and os.path.exists(path):
            try:
                with open(path) as f:
                    raw = json.load(f)
                if isinstance(raw, dict) and raw.get("__format__") in (2, 3):
                    self._data = {k: float(v) for k, v in raw["values"].items()}
                    self._meta = {k: str(v) for k, v in raw.get("meta", {}).items()}
                    self._winners = {
                        k: str(v) for k, v in raw.get("winners", {}).items()
                    }
                else:
                    self._data = {k: float(v) for k, v in raw.items()}
            except (json.JSONDecodeError, ValueError, TypeError, OSError) as e:
                # a cache is not a source of truth: a corrupt/truncated file
                # (killed run, disk full) must degrade to a cold cache, not
                # kill the matrix run
                import warnings

                warnings.warn(
                    f"measurement cache {path!r} unreadable ({e}); starting cold"
                )

    def __len__(self) -> int:
        return len(self._data)

    def get(self, key: str) -> float | None:
        return self._data.get(key)

    def items(self):
        return self._data.items()

    def update(self, entries) -> None:
        """Bulk-insert ``(key, value)`` pairs (shard-store merging).  Entries
        are only marked dirty — call :meth:`save` once after the last batch
        so an N-shard merge doesn't rewrite the file N times."""
        for k, v in entries:
            self._data[k] = float(v)
            self._dirty += 1

    def best_item(self, prefix: str, contains: str | None = None
                  ) -> tuple[str, float] | None:
        """The minimum-value finite entry under ``prefix`` (ties break on
        key) — the scan behind the serving winner refresh.  ``contains``
        restricts to keys holding that substring (e.g. ``"|final"`` to rank
        only re-measured final timings, not noisy search samples)."""
        best: tuple[str, float] | None = None
        for k, v in self._data.items():
            if not k.startswith(prefix) or not np.isfinite(v):
                continue
            if contains is not None and contains not in k:
                continue
            if best is None or (v, k) < (best[1], best[0]):
                best = (k, float(v))
        return best

    def put(self, key: str, value: float) -> None:
        self._data[key] = float(value)
        self._dirty += 1
        if self.autosave_every and self._dirty >= self.autosave_every:
            self.save()

    # -- per-key metadata (penalty reasons) ------------------------------------
    def get_meta(self, key: str) -> str | None:
        return self._meta.get(key)

    def put_meta(self, key: str, note: str) -> None:
        self._meta[key] = str(note)
        self._dirty += 1

    def meta_items(self, prefix: str | None = None):
        if prefix is None:
            return self._meta.items()
        return [(k, v) for k, v in self._meta.items() if k.startswith(prefix)]

    def update_meta(self, entries) -> None:
        for k, v in entries:
            self._meta[k] = str(v)
            self._dirty += 1

    # -- serving winners (repro.serving best-config index) ---------------------
    def get_winner(self, key: str) -> str | None:
        return self._winners.get(key)

    def put_winner(self, key: str, payload: str) -> None:
        self._winners[key] = str(payload)
        self._dirty += 1

    def winner_items(self):
        return self._winners.items()

    def update_winners(self, entries) -> None:
        for k, v in entries:
            self._winners[k] = str(v)
            self._dirty += 1

    def save(self) -> None:
        if self.path is None:
            return
        d = os.path.dirname(self.path)
        if d:
            os.makedirs(d, exist_ok=True)
        if self._winners:
            payload = {
                "__format__": 3,
                "values": self._data,
                "meta": self._meta,
                "winners": self._winners,
            }
        elif self._meta:
            payload = {"__format__": 2, "values": self._data, "meta": self._meta}
        else:
            payload = self._data
        fd, tmp = tempfile.mkstemp(dir=d or ".", suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                # sorted keys: two stores holding the same entries produce
                # byte-identical files regardless of insertion order (the
                # executor-equivalence guarantee is checkable on bytes)
                json.dump(payload, f, sort_keys=True)
            os.replace(tmp, self.path)
        except BaseException:
            if os.path.exists(tmp):
                os.unlink(tmp)
            raise
        self._dirty = 0


class DiskCachedMeasurement(BaseMeasurement):
    """Serves measurements from a :class:`MeasurementStore`, falling back to
    (and recording) the inner measurement on miss.

    Keys are ``{prefix}|{config_key}`` — the prefix identifies the kernel /
    chip / experiment stream (e.g. ``"harris/v5e/seed=123"``), so repeated
    runs of the same matrix cell are served entirely from disk while distinct
    noise streams never collide.

    Budget accounting: ``n_samples`` counts every sample *served* (hit or
    miss), so searcher budget audits are identical whether the cache is cold
    or warm; ``n_misses`` counts actual inner measurements.
    """

    def __init__(self, inner: BaseMeasurement, store: MeasurementStore, prefix: str):
        super().__init__()
        self._inner = inner
        self._store = store
        self.prefix = prefix
        self.n_misses = 0

    def _key(self, config: Config) -> str:
        return f"{self.prefix}|{config_key(config)}"

    def _record(self, key: str, config: Config, value: float) -> None:
        """Persist a fresh measurement; penalized (non-finite) values carry
        the inner backend's failure reason as store metadata, so warm-cache
        runs can still explain WHY a config is invalid."""
        self._store.put(key, value)
        if not np.isfinite(value) and hasattr(self._store, "put_meta"):
            reason = self._inner.reason_for(config)
            self._store.put_meta(key, reason or "non-finite measurement")

    def set_telemetry(self, telemetry) -> None:
        super().set_telemetry(telemetry)
        self._inner.set_telemetry(telemetry)

    def measure(self, config: Config) -> float:
        self.n_samples += 1
        self.n_dispatches += 1
        k = self._key(config)
        v = self._store.get(k)
        if v is None:
            v = self._inner.measure(config)
            self.n_misses += 1
            self._record(k, config, v)
            if self.telemetry.enabled:
                self.telemetry.inc("store_misses")
        else:
            self._inner.skip_samples(1)
            if self.telemetry.enabled:
                self.telemetry.inc("store_hits")
        return float(v)

    def measure_batch(self, configs: Sequence[Config]) -> np.ndarray:
        self.n_samples += len(configs)
        self.n_dispatches += 1
        keys = [self._key(c) for c in configs]
        cached = [self._store.get(k) for k in keys]
        vals = np.array(
            [np.nan if v is None else v for v in cached], dtype=np.float64
        )
        miss = np.array([v is None for v in cached], dtype=bool)
        if self.telemetry.enabled:
            n_miss = int(miss.sum())
            if n_miss:
                self.telemetry.inc("store_misses", n_miss)
            if len(configs) - n_miss:
                self.telemetry.inc("store_hits", len(configs) - n_miss)
        if not miss.any():
            self._inner.skip_samples(len(configs))
            return vals
        # Walk the batch in contiguous hit/miss runs so the inner backend's
        # per-sample state (noise counters) stays aligned with a cold run:
        # hits advance it via skip_samples, misses via measure_batch, in the
        # batch's own order.
        i = 0
        n = len(configs)
        while i < n:
            j = i
            while j < n and miss[j] == miss[i]:
                j += 1
            if miss[i]:
                fresh_cfgs = list(configs[i:j])
                fresh = self._inner.measure_batch(fresh_cfgs)
                self.n_misses += len(fresh_cfgs)
                vals[i:j] = fresh
                for k, c, v in zip(keys[i:j], fresh_cfgs, fresh, strict=True):
                    self._record(k, c, float(v))
            else:
                self._inner.skip_samples(j - i)
            i = j
        return vals

    def measure_final(self, config: Config, repeats: int = 10) -> float:
        k = f"{self._key(config)}|final{repeats}"
        v = self._store.get(k)
        if v is None:
            v = self._inner.measure_final(config, repeats)
            self._record(k, config, float(v))
        return float(v)

    # -- introspection ---------------------------------------------------------
    def provenance(self) -> dict:
        p = self._inner.provenance()
        if p:
            p = {**p, "cache_hits": self.n_samples - self.n_misses,
                 "cache_misses": self.n_misses}
        return p

    def reason_for(self, config: Config) -> str | None:
        """Served-from-cache penalties keep their reason: store metadata wins,
        the live inner backend is the fallback."""
        if hasattr(self._store, "get_meta"):
            meta = self._store.get_meta(self._key(config))
            if meta is not None:
                return meta
        return self._inner.reason_for(config)

    def repeats_for(self, config: Config) -> list | None:
        return self._inner.repeats_for(config)

    def stage_times(self) -> dict[str, float]:
        return self._inner.stage_times()

    def reset(self) -> None:
        super().reset()
        self.n_misses = 0
        self._inner.reset()

    def save(self) -> None:
        self._store.save()
