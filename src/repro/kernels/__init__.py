"""Tunable Pallas TPU kernels for the paper's three ImageCL benchmarks.

Each kernel directory holds:
    kernel.py — pl.pallas_call + BlockSpec implementation (tunable geometry)
    ops.py    — jitted public wrapper taking the paper's 6-param config
    ref.py    — pure-jnp oracle

Validation policy (tests/test_kernels.py): add and harris are compared with
assert_allclose across shape/dtype/config sweeps.  Mandelbrot's escape-time
loop is chaotic at the set boundary — 1-ulp FMA-contraction differences
between the two compiled programs legitimately shift a handful of pixels by
a few iterations — so its oracle check is '>= 99.5% pixels exactly equal,
violations within +-4 iterations' (the 'discrete boundary' tolerance class).

``TUNABLE_KERNELS`` maps the cost-model workload names to real-runnable
entry points for the InterpretTimer measurement backend (examples/).
"""

from .add.ops import BENCH as _add_bench
from .add.ops import add
from .add.ref import add_ref
from .harris.ops import BENCH as _harris_bench
from .harris.ops import harris
from .harris.ref import harris_ref
from .mandelbrot.ops import BENCH as _mandelbrot_bench
from .mandelbrot.ops import mandelbrot
from .mandelbrot.ref import mandelbrot_ref

TUNABLE_KERNELS = {
    "add": add,
    "harris": harris,
    "mandelbrot": mandelbrot,
}

#: per-kernel resource/input descriptors consumed by the real-measurement
#: backend (repro.pallas_bench) — each kernel package owns its own entry.
KERNEL_BENCHES = {
    b.name: b for b in (_add_bench, _harris_bench, _mandelbrot_bench)
}

__all__ = [
    "add",
    "add_ref",
    "harris",
    "harris_ref",
    "mandelbrot",
    "mandelbrot_ref",
    "TUNABLE_KERNELS",
    "KERNEL_BENCHES",
]
