"""Tunable Mandelbrot Pallas TPU kernel.

Compute-bound, zero input bytes: each grid step derives its pixel
coordinates from the block indices with broadcasted iota and runs the
fixed-trip escape loop on the VPU.  Tunables shape the grid exactly like
the add kernel (blocks (8*t_x*t_z, 128*t_y), region splits w_x/w_y with
clamped idempotent indices).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..common import KernelGeometry, clamped_index, split_grid, use_interpret
from .ref import MAX_ITER, VIEW


def _mandel_kernel(
    o_ref, *, rows: int, bn: int, x: int, y: int,
    steps_r: int, nblk_r: int, steps_c: int, nblk_c: int,
    max_iter: int, view,
):
    gi, gj = pl.program_id(0), pl.program_id(1)
    rb = clamped_index(gi // steps_r, gi % steps_r, steps_r, nblk_r)
    cb = clamped_index(gj // steps_c, gj % steps_c, steps_c, nblk_c)

    xmin, xmax, ymin, ymax = view
    dtype = o_ref.dtype
    row0 = (rb * rows).astype(dtype)
    col0 = (cb * bn).astype(dtype)
    rr = row0 + jax.lax.broadcasted_iota(dtype, (rows, bn), 0)
    cc = col0 + jax.lax.broadcasted_iota(dtype, (rows, bn), 1)
    cre = xmin + (cc + 0.5) * ((xmax - xmin) / y)
    cim = ymin + (rr + 0.5) * ((ymax - ymin) / x)

    def body(_, state):
        zr, zi, count = state
        alive = zr * zr + zi * zi < 4.0
        zr2 = zr * zr - zi * zi + cre
        zi2 = 2.0 * zr * zi + cim
        return (
            jnp.where(alive, zr2, zr),
            jnp.where(alive, zi2, zi),
            count + alive.astype(dtype),
        )

    zeros = jnp.zeros((rows, bn), dtype)
    _, _, count = jax.lax.fori_loop(0, max_iter, body, (zeros, zeros, zeros))
    o_ref[...] = count


def mandelbrot_pallas(
    x: int,
    y: int,
    g: KernelGeometry,
    max_iter: int = MAX_ITER,
    view=VIEW,
    dtype=jnp.float32,
) -> jnp.ndarray:
    rows = g.rows_step
    steps_r, nblk_r = split_grid(x, rows, g.wx)
    steps_c, nblk_c = split_grid(y, g.bn, g.wy)

    def idx(gi, gj):
        return (
            clamped_index(gi // steps_r, gi % steps_r, steps_r, nblk_r),
            clamped_index(gj // steps_c, gj % steps_c, steps_c, nblk_c),
        )

    return pl.pallas_call(
        lambda o: _mandel_kernel(
            o, rows=rows, bn=g.bn, x=x, y=y,
            steps_r=steps_r, nblk_r=nblk_r, steps_c=steps_c, nblk_c=nblk_c,
            max_iter=max_iter, view=view,
        ),
        grid=(g.wx * steps_r, g.wy * steps_c),
        in_specs=[],
        out_specs=pl.BlockSpec((rows, g.bn), idx),
        out_shape=jax.ShapeDtypeStruct((x, y), dtype),
        interpret=use_interpret(),
    )()
