"""Pure-jnp oracle for the Mandelbrot benchmark (paper section V.D):
escape-iteration counts over the classic view window, vectorized over the
whole image with a fixed-trip-count loop (SIMD semantics — no early exit,
matching how both a GPU warp and the TPU VPU execute it)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

VIEW = (-2.5, 1.0, -1.25, 1.25)  # xmin, xmax, ymin, ymax
MAX_ITER = 64


def mandelbrot_ref(
    x: int, y: int, max_iter: int = MAX_ITER, view=VIEW, dtype=jnp.float32
) -> jnp.ndarray:
    xmin, xmax, ymin, ymax = view
    re = xmin + (jnp.arange(y, dtype=dtype) + 0.5) * ((xmax - xmin) / y)
    im = ymin + (jnp.arange(x, dtype=dtype) + 0.5) * ((ymax - ymin) / x)
    cre = jnp.broadcast_to(re[None, :], (x, y))
    cim = jnp.broadcast_to(im[:, None], (x, y))

    def body(_, state):
        zr, zi, count = state
        alive = zr * zr + zi * zi < 4.0
        zr2 = zr * zr - zi * zi + cre
        zi2 = 2.0 * zr * zi + cim
        zr = jnp.where(alive, zr2, zr)
        zi = jnp.where(alive, zi2, zi)
        count = count + alive.astype(dtype)
        return zr, zi, count

    zr = jnp.zeros((x, y), dtype)
    zi = jnp.zeros((x, y), dtype)
    count = jnp.zeros((x, y), dtype)
    _, _, count = jax.lax.fori_loop(0, max_iter, body, (zr, zi, count))
    return count
