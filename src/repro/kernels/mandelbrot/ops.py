"""Jitted public wrapper for the tunable Mandelbrot kernel."""

from __future__ import annotations

from functools import partial

import jax

from ..common import Config, KernelBenchSpec, geometry_from_config
from .kernel import mandelbrot_pallas
from .ref import MAX_ITER


@partial(jax.jit, static_argnames=("x", "y", "max_iter", "t_x", "t_y", "t_z", "w_x", "w_y", "w_z"))
def _mandelbrot(*, x, y, max_iter, t_x=1, t_y=1, t_z=1, w_x=1, w_y=1, w_z=1):
    g = geometry_from_config(
        dict(t_x=t_x, t_y=t_y, t_z=t_z, w_x=w_x, w_y=w_y, w_z=w_z)
    )
    return mandelbrot_pallas(x, y, g, max_iter=max_iter)


def mandelbrot(x: int, y: int, config: Config | None = None, max_iter: int = MAX_ITER):
    cfg = config or {}
    return _mandelbrot(
        x=x,
        y=y,
        max_iter=max_iter,
        t_x=cfg.get("t_x", 1),
        t_y=cfg.get("t_y", 1),
        t_z=cfg.get("t_z", 1),
        w_x=cfg.get("w_x", 1),
        w_y=cfg.get("w_y", 1),
        w_z=cfg.get("w_z", 1),
    )


#: generator kernel — no input arrays; the image size IS the problem
BENCH = KernelBenchSpec(
    name="mandelbrot",
    n_inputs=0,
    make_inputs=lambda x, y, seed: (),
    run=lambda inputs, cfg, x, y: mandelbrot(x, y, cfg),
    scratch_tiles=2,
)
