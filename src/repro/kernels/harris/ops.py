"""Jitted public wrapper for the tunable Harris kernel.

Pads rows to a multiple of the band height (zero padding — identical to the
oracle's boundary condition as long as the pad is >= the stencil radius,
which rows_step >= 8 always satisfies) and crops the result.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from ..common import Config, KernelBenchSpec, geometry_from_config
from .kernel import harris_pallas


@partial(jax.jit, static_argnames=("t_x", "t_y", "t_z", "w_x", "w_y", "w_z"))
def _harris(img, *, t_x=1, t_y=1, t_z=1, w_x=1, w_y=1, w_z=1):
    g = geometry_from_config(
        dict(t_x=t_x, t_y=t_y, t_z=t_z, w_x=w_x, w_y=w_y, w_z=w_z)
    )
    x, y = img.shape
    rows = g.rows_step
    x_pad = (-x) % rows
    padded = jnp.pad(img, ((0, x_pad), (0, 0)))
    out = harris_pallas(padded, g)
    return out[:x]


def harris(img: jnp.ndarray, config: Config | None = None) -> jnp.ndarray:
    cfg = config or {}
    return _harris(
        img,
        t_x=cfg.get("t_x", 1),
        t_y=cfg.get("t_y", 1),
        t_z=cfg.get("t_z", 1),
        w_x=cfg.get("w_x", 1),
        w_y=cfg.get("w_y", 1),
        w_z=cfg.get("w_z", 1),
    )


def _bench_inputs(x: int, y: int, seed: int) -> tuple:
    rng = np.random.default_rng(seed)
    return (jnp.asarray(rng.standard_normal((x, y)), jnp.float32),)


#: resource model mirrors costmodel.HARRIS (halo-2 stencil, 5 scratch tiles)
BENCH = KernelBenchSpec(
    name="harris",
    n_inputs=1,
    make_inputs=_bench_inputs,
    run=lambda inputs, cfg, x, y: harris(inputs[0], cfg),
    halo=2,
    scratch_tiles=5,
)
